"""Kernel microbenchmarks: Pallas (interpret on CPU — correctness-path
timing only) vs the pure-jnp oracle vs, where meaningful, the XLA-native
composition.  On-TPU numbers come from the same harness with interpret=False.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(log=print) -> list[dict]:
    from repro.kernels.covgram.ops import covgram
    from repro.kernels.covgram.ref import covgram_ref
    from repro.kernels.prox_l1.ops import prox_step
    from repro.kernels.prox_l1.ref import prox_step_ref
    from repro.kernels.threshold_cc.ops import labelprop_step
    from repro.kernels.threshold_cc.ref import labelprop_step_ref

    rng = np.random.default_rng(0)
    out = []

    x = jnp.asarray(rng.standard_normal((2048, 512)), jnp.float32)
    for name, fn in (("covgram_pallas_interp", covgram), ("covgram_ref", covgram_ref)):
        us = _time(fn, x) * 1e6
        out.append({"bench": name, "us_per_call": round(us, 1)})
        log(f"{name:26s} {us:12.1f} us")

    S = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.float32)
    labels = jnp.arange(1024, dtype=jnp.int32)
    for name, fn in (
        ("labelprop_pallas_interp", lambda: labelprop_step(S, labels, 0.5)),
        ("labelprop_ref", lambda: labelprop_step_ref(S, labels, 0.5)),
    ):
        us = _time(fn) * 1e6
        out.append({"bench": name, "us_per_call": round(us, 1)})
        log(f"{name:26s} {us:12.1f} us")

    theta = jnp.asarray(rng.standard_normal((8, 256, 256)), jnp.float32)
    grad = jnp.asarray(rng.standard_normal((8, 256, 256)), jnp.float32)
    for name, fn in (
        ("prox_pallas_interp", lambda: prox_step(theta, grad, 0.1, 0.3)),
        ("prox_ref", lambda: prox_step_ref(theta, grad, 0.1, 0.3)),
    ):
        us = _time(fn) * 1e6
        out.append({"bench": name, "us_per_call": round(us, 1)})
        log(f"{name:26s} {us:12.1f} us")

    # fused in-kernel BCD over a packed small-block stack (one megabatch
    # lane per block; the wave packer's per-launch unit)
    from repro.kernels.bucket_glasso.bucket_glasso import fused_bcd_pallas
    from repro.kernels.bucket_glasso.ref import fused_bcd_ref_stack

    N, b = 16, 16
    A = rng.standard_normal((N, b, b)) * (rng.random((N, b, b)) < 0.4)
    Sb = jnp.asarray(A @ A.transpose(0, 2, 1) / b + np.eye(b)[None])
    lams = jnp.full(N, 0.3, Sb.dtype)
    eye = jnp.eye(b, dtype=Sb.dtype)[None]
    scales = jnp.abs(Sb - eye * jnp.diagonal(Sb, axis1=1, axis2=2)[:, None, :]
                     * eye).mean(axis=(1, 2)) + 1e-12
    W0 = Sb + lams[:, None, None] * eye
    T0 = jnp.broadcast_to(jnp.eye(b, dtype=Sb.dtype), (N, b, b))
    for name, fn in (
        ("bucket_glasso_pallas_interp",
         lambda: fused_bcd_pallas(Sb, lams.reshape(N, 1),
                                  scales.reshape(N, 1), W0, T0,
                                  interpret=True)),
        ("bucket_glasso_ref",
         lambda: fused_bcd_ref_stack(Sb, lams, scales, W0, T0)),
    ):
        us = _time(fn) * 1e6
        out.append({"bench": name, "us_per_call": round(us, 1)})
        log(f"{name:26s} {us:12.1f} us")
    return out


if __name__ == "__main__":
    run()

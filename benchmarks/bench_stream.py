"""Streaming-vs-dense screening benchmark: peak RSS + tile-skip rate.

The out-of-core screener's acceptance claims are MEMORY claims, so each arm
runs in its own subprocess and reports ``ru_maxrss`` — the OS's answer, not
our own accounting (the ``stream.bytes_peak`` watermark rides along as the
self-reported cross-check).  Per p in {8k, 16k}:

  * ``dense``   materialize S = (X-mu)'(X-mu)/n (the (p, p) allocation the
                streamer exists to avoid), then the dense planner's
                screening pass (``labels_at_thresholds``) over the grid;
  * ``stream``  ``stream_screen(X, grid)`` — tiled Gram, compacted edges,
                materialized blocks; plus a second screen over the TOP HALF
                of the grid, where the higher lambda floor must make the
                Cauchy-Schwarz tile-skip fire (the acceptance's "nonzero
                skip fraction on the top half").

The workload plants factor-correlated column groups in the leading tiles
(real edges at the grid lambdas) over power-law column scales (most tile
pairs bounded below the grid floor — the skippable mass).  Columns arrive
scale-sorted; that is the favorable case for a per-tile max bound and is the
regime the bench tracks.

``--json FILE`` writes the record; ``--check BASELINE`` fails (exit 1) when
the stream/dense peak-RSS ratio regresses >20% over the committed baseline,
the top-half skip rate drops >20% below it (or to zero), or the streamed
partition stops matching the dense one.  ``--smoke`` is the fast in-process
equivalence arm (p=1536) for the CI gate.

    PYTHONPATH=src python -m benchmarks.bench_stream [--smoke] \
        [--json BENCH_stream.json] [--check benchmarks/baseline_stream.json]
"""

from __future__ import annotations

import argparse
import json
import resource
import subprocess
import sys
import time

import numpy as np

N_ROWS = 192
TILE = 512
GRID = (0.30, 0.26, 0.22, 0.18, 0.14, 0.10, 0.075, 0.05)  # descending


def _workload(p: int, seed: int = 0) -> np.ndarray:
    """(n, p) data: planted correlated groups up front, power-law scales."""
    rng = np.random.default_rng(seed)
    n = N_ROWS
    scales = 0.04 + 0.96 * (1.0 - np.arange(p) / p) ** 4
    X = rng.standard_normal((n, p)) * scales
    # factor groups of 8 columns across the leading tiles: |S_ij| ~ 0.5 there
    n_groups = max(2, p // 400)
    f = rng.standard_normal((n, n_groups))
    for g in range(n_groups):
        cols = slice(g * 8, g * 8 + 8)
        X[:, cols] = 0.75 * f[:, [g]] + 0.66 * X[:, cols] / scales[cols]
    return X


def _grid(p: int) -> list[float]:
    return [float(v) for v in GRID]


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_arm(arm: str, p: int, seed: int = 0) -> dict:
    """One screening arm in THIS process; returns its record (the parent
    launches each arm in a subprocess so ru_maxrss is per-arm)."""
    import jax

    jax.config.update("jax_enable_x64", True)
    X = _workload(p, seed)
    lams = _grid(p)
    t0 = time.perf_counter()
    if arm == "dense":
        from repro.core.partition import labels_at_thresholds

        Xc = X - X.mean(axis=0)
        S = Xc.T @ Xc / X.shape[0]  # the (p, p) allocation
        labels = labels_at_thresholds(S, lams)
        rec = {
            # component counts per lambda: a cheap cross-process partition
            # fingerprint (full label equality is the smoke arm's job)
            "labels_checksum": [int(np.unique(lab).size) for lab in labels],
        }
    elif arm == "stream":
        from repro.core.instrument import counts, reset

        from repro.stream import stream_screen

        reset("stream")
        sc = stream_screen(X, lams, config={"tile": TILE, "chunk": 64})
        top = stream_screen(
            X, lams[: len(lams) // 2], config={"tile": TILE, "chunk": 64},
            materialize=False,
        )
        c = counts("stream.")
        rec = {
            "labels_checksum": [int(np.unique(lab).size) for lab in sc.labels],
            "tiles_total": sc.tiles_total,
            "tiles_skipped": sc.tiles_skipped,
            "skip_rate": round(sc.tiles_skipped / max(sc.tiles_total, 1), 4),
            "skip_rate_top_half": round(
                top.tiles_skipped / max(top.tiles_total, 1), 4
            ),
            "edges_emitted": int(sc.stats[0].edges_emitted),
            "bytes_peak_mb": round(c.get("stream.bytes_peak", 0) / 2**20, 1),
        }
    else:
        raise ValueError(arm)
    rec.update(
        {"arm": arm, "p": p, "seconds": round(time.perf_counter() - t0, 2),
         "rss_mb": round(_rss_mb(), 1)}
    )
    return rec


def _spawn_arm(arm: str, p: int) -> dict:
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_stream", "--arm", arm,
         "--p", str(p)],
        capture_output=True, text=True, check=True,
    )
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(ps=(8000, 16000), log=print) -> dict:
    per_p = []
    for p in ps:
        dense = _spawn_arm("dense", p)
        stream = _spawn_arm("stream", p)
        assert dense["labels_checksum"] == stream["labels_checksum"], (
            f"streamed partition diverged from dense at p={p}"
        )
        row = {
            "p": p,
            "dense_rss_mb": dense["rss_mb"],
            "stream_rss_mb": stream["rss_mb"],
            "rss_ratio": round(stream["rss_mb"] / max(dense["rss_mb"], 1e-9), 4),
            "dense_seconds": dense["seconds"],
            "stream_seconds": stream["seconds"],
            "skip_rate": stream["skip_rate"],
            "skip_rate_top_half": stream["skip_rate_top_half"],
            "edges_emitted": stream["edges_emitted"],
            "bytes_peak_mb": stream["bytes_peak_mb"],
        }
        per_p.append(row)
        log(
            f"p={p}: dense rss {row['dense_rss_mb']}MB / {row['dense_seconds']}s"
            f"  vs  stream rss {row['stream_rss_mb']}MB / "
            f"{row['stream_seconds']}s (ratio {row['rss_ratio']}), "
            f"skip {row['skip_rate']:.1%} (top-half {row['skip_rate_top_half']:.1%}), "
            f"{row['edges_emitted']} edges, "
            f"stream.bytes_peak {row['bytes_peak_mb']}MB"
        )
    return {"n_rows": N_ROWS, "tile": TILE, "grid": list(GRID), "per_p": per_p}


def smoke(log=print) -> None:
    """In-process equivalence gate: streamed == dense partitions + stats."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.core.components import partitions_equal
    from repro.core.partition import labels_at_thresholds
    from repro.stream import stream_screen

    p = 1536
    X = _workload(p, seed=3)
    lams = _grid(p)
    Xc = X - X.mean(axis=0)
    S = Xc.T @ Xc / X.shape[0]
    dense = labels_at_thresholds(S, lams)
    sc = stream_screen(X, lams, config={"tile": 256, "chunk": 64})
    for lam, dl, sl in zip(lams, dense, sc.labels):
        assert partitions_equal(dl, sl), f"smoke: partitions differ at {lam}"
    iu, ju = np.triu_indices(p, 1)
    w = np.abs(S[iu, ju])
    for lam, st in zip(lams, sc.stats):
        assert st.n_edges == int((w > lam).sum()), f"smoke: edges at {lam}"
    assert sc.tiles_skipped > 0, "smoke: no tiles skipped"
    log(
        f"stream smoke OK: {len(lams)} lambdas at p={p}, "
        f"{sc.tiles_skipped}/{sc.tiles_total} tiles skipped, "
        f"{sc.stats[0].edges_emitted} edges"
    )


def check(rec: dict, baseline_path: str, log=print) -> int:
    """CI gate: >20% RSS-ratio or skip-rate regression vs baseline fails."""
    with open(baseline_path) as f:
        base = json.load(f)
    base_by_p = {row["p"]: row for row in base["per_p"]}
    failures = []
    for row in rec["per_p"]:
        b = base_by_p.get(row["p"])
        if b is None:
            continue
        max_ratio = b["rss_ratio"] * 1.2
        if row["rss_ratio"] > max_ratio:
            failures.append(
                f"p={row['p']}: stream/dense RSS ratio {row['rss_ratio']} > "
                f"{max_ratio:.3f} (baseline {b['rss_ratio']} + 20%)"
            )
        min_skip = b["skip_rate_top_half"] * 0.8
        if row["skip_rate_top_half"] < min_skip or row["skip_rate_top_half"] == 0:
            failures.append(
                f"p={row['p']}: top-half skip rate {row['skip_rate_top_half']} "
                f"< {min_skip:.3f} (baseline {b['skip_rate_top_half']} - 20%)"
            )
    for msg in failures:
        log(f"REGRESSION: {msg}")
    if not failures:
        log(f"stream bench within baseline ({baseline_path})")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arm", choices=("dense", "stream"), default=None)
    ap.add_argument("--p", type=int, default=8000)
    ap.add_argument("--ps", type=int, nargs="+", default=[8000, 16000])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--check", default=None)
    args = ap.parse_args()

    if args.arm:  # subprocess mode: one arm, JSON on stdout
        print(json.dumps(run_arm(args.arm, args.p)))
        return
    if args.smoke:
        smoke()
        return
    rec = run(tuple(args.ps))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        sys.exit(check(rec, args.check))


if __name__ == "__main__":
    main()

"""Model-selection benchmark: warm homotopy vs cold restarts + EBIC recovery.

The ``repro.select`` acceptance bench on the p=2400 path workload
(``structured_synthetic`` with the chordless-cycle fraction raised so most
planted blocks are solver-bound — warm starts only matter where a solver
actually iterates): solve the same 20-point descending grid through the
warm-started homotopy executor, and against the true cold-restart baseline
— one independent ``glasso(S, lam)`` call per grid point, each paying its
own screening, planning and cold solver starts (exactly the loop
``select_path`` replaces).  A third diagnostic arm runs the homotopy with
``warm_start=False`` (shared single-pass plan, cold solver starts) to
separate the planner amortization from the solver warm-start savings.
Reported:

  * min-of-``reps`` wall clock for the homotopy and cold-restart arms and
    the warm speedup (acceptance: warm is gated FASTER than cold-restart
    via the committed baseline, >20% regression fails CI),
  * the warm fraction from the ``select.warm.*`` counters — reused + merged
    over all solver-bound buckets (acceptance, asserted here: >= 0.5 of
    non-trivial buckets solve warm),
  * per-stage attribution totals (``GlassoResult.stages_us``) for both arms
    — where along screen/solve/assemble the homotopy saves its time,
  * warm == cold exactness (max |Theta| diff vs the independent solves,
    asserted < 1e-5).

Both arms run ``output="sparse"``: selection criteria are computed from
sparse results (DESIGN.md Section 14), and a dense (p, p) assembly per grid
point would swamp the solver signal this bench exists to measure.

``smoke()`` is the CI correctness gate: EBIC on a planted block-chain
precision recovers the true support (F1 of the selected graph within 90% of
the best-on-path F1, best >= 0.8), and ``submit(PathSpec(...))`` through the
serving control plane returns bitwise the same selection as the offline
``select_path`` call.

    PYTHONPATH=src python -m benchmarks.bench_select [--quick] [--smoke] \
        [--json BENCH_select.json] [--check benchmarks/baseline_select.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _workload(K: int, p1: int, n_lambdas: int, seed: int = 1):
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.covariance import structured_synthetic

    # tree/chordal fractions LOWERED vs bench_routes: chordless cycles route
    # iterative, which is where warm starts pay — the route bench measures
    # the ladder, this bench measures homotopy reuse on the solver-bound tail
    S = structured_synthetic(K, p1, tree_frac=0.2, chordal_frac=0.2, seed=seed)
    lams = [float(v) for v in np.linspace(0.75, 0.32, n_lambdas)]
    return S, lams


def run(
    K: int = 60, p1: int = 40, n_lambdas: int = 20, reps: int = 3, log=print
) -> dict:
    from repro.core import EngineOptions, glasso
    from repro.core.instrument import reset, tail_counts
    from repro.select import homotopy_path

    S, lams = _workload(K, p1, n_lambdas)
    p = K * p1
    opts = EngineOptions(output="sparse", solver_opts={"tol": 1e-7})
    log(f"select bench: p={p} ({K} planted blocks of {p1}), {len(lams)} "
        f"lambdas in [{lams[-1]:.3f}, {lams[0]:.3f}]")

    # warm the compiled caches off the clock (compile time is not the metric):
    # a full pass per arm — each grid point's bucket shapes compile once
    homotopy_path(S, lambdas=lams, options=opts)
    for lam in lams:
        glasso(S, lam, options=opts)

    wall_w, wall_c = [], []
    warm_path = cold_path = None
    warm_counts_rep: dict = {}
    for rep in range(reps):
        reset("select.warm.")
        t0 = time.perf_counter()
        warm_path = homotopy_path(S, lambdas=lams, options=opts)
        wall_w.append(time.perf_counter() - t0)
        warm_counts_rep = tail_counts("select.warm.")
        if rep < max(1, reps - 1):  # the slow arm: one fewer rep
            t0 = time.perf_counter()
            cold_path = [glasso(S, lam, options=opts) for lam in lams]
            wall_c.append(time.perf_counter() - t0)

    # diagnostic arm: shared single-pass plan, cold solver starts — isolates
    # the solver warm-start savings from the planner amortization
    t0 = time.perf_counter()
    homotopy_path(S, lambdas=lams, options=opts, warm_start=False)
    wall_shared_cold = time.perf_counter() - t0

    # order-insensitive block compare: the homotopy's lifetime bucketing
    # enumerates components differently than an independent solve's plan
    worst = 0.0
    for rw, rc in zip(warm_path, cold_path):
        by_comp = {
            np.asarray(c).tobytes(): blk for c, blk in rw.Theta.blocks()
        }
        for c, blk in rc.Theta.blocks():
            diff = np.abs(by_comp[np.asarray(c).tobytes()] - blk).max()
            worst = max(worst, float(diff))
    assert worst < 1e-5, f"warm vs cold-restart diverged: {worst:.2e}"

    total = sum(warm_counts_rep.values())
    reused = warm_counts_rep.get("reused", 0) + warm_counts_rep.get("merged", 0)
    warm_fraction = reused / total if total else 0.0
    # the tentpole acceptance criterion: at least half of the solver-bound
    # buckets along the grid start warm
    assert warm_fraction >= 0.5, (
        f"homotopy warm fraction {warm_fraction:.2f} < 0.5 "
        f"(counters: {warm_counts_rep})"
    )

    def _stage_totals(path):
        # dispatch_us is the stage that explains the old solve_us anomaly:
        # the warm homotopy arm issues ~6x the dispatches of a cold solve
        # (lifetime bucketing), and before the dispatch stage existed that
        # host overhead was silently folded into solve_us — making the warm
        # arm's "solve" look slower than cold despite a faster wall clock
        tot = {"screen_us": 0, "solve_us": 0, "dispatch_us": 0, "assemble_us": 0}
        for r in path:
            for k, v in r.stages_us.items():
                tot[k] += v
        return tot

    rec = {
        "p": p,
        "planted_blocks": K,
        "block_size": p1,
        "n_lambdas": len(lams),
        "reps": reps,
        "wall_warm_s": round(min(wall_w), 3),
        "wall_cold_s": round(min(wall_c), 3),
        "wall_shared_plan_cold_s": round(wall_shared_cold, 3),
        "warm_speedup": round(min(wall_c) / max(min(wall_w), 1e-9), 3),
        "warm_fraction": round(warm_fraction, 4),
        "warm_counts": warm_counts_rep,
        "stages_warm_us": _stage_totals(warm_path),
        "stages_cold_us": _stage_totals(cold_path),
        "max_theta_diff": worst,
    }
    log(f"select bench: warm homotopy {rec['wall_warm_s']}s vs cold-restart "
        f"{rec['wall_cold_s']}s -> {rec['warm_speedup']}x (shared-plan cold "
        f"{rec['wall_shared_plan_cold_s']}s), warm fraction "
        f"{warm_fraction:.3f} ({warm_counts_rep}), solve stage "
        f"{rec['stages_warm_us']['solve_us']}us vs "
        f"{rec['stages_cold_us']['solve_us']}us")
    return rec


def _planted_chain(K: int = 6, b: int = 10, n: int = 400, seed: int = 7):
    """Block-diagonal chain precision: K blocks of b, tridiagonal with
    alternating-sign 0.6 couplings — every true edge is comfortably above
    the noise floor at n rows, so EBIC has a clean support to find."""
    rng = np.random.default_rng(seed)
    p = K * b
    Theta0 = np.zeros((p, p))
    for k in range(K):
        i0 = k * b
        blk = np.eye(b) * 2.0
        for i in range(b - 1):
            blk[i, i + 1] = blk[i + 1, i] = 0.6 * (1 if (i + k) % 2 == 0 else -1)
        Theta0[i0:i0 + b, i0:i0 + b] = blk
    L = np.linalg.cholesky(np.linalg.inv(Theta0))
    return Theta0, rng.standard_normal((n, p)) @ L.T


def smoke() -> None:
    """CI correctness gate: EBIC planted-support recovery + served == offline."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.core import EngineOptions
    from repro.launch.control_plane import PathSpec
    from repro.launch.serve_glasso import GlassoServer
    from repro.select import select_path

    # -- EBIC recovers a planted chain support --------------------------
    Theta0, X = _planted_chain()
    sel = select_path(X=X, grid={"auto": 10}, criterion="ebic", gamma=1.0)
    true_edges = set(map(tuple, np.argwhere(np.triu(np.abs(Theta0) > 1e-12, 1))))

    def f1(r):
        est = set(map(tuple, r.support_edges()))
        tp = len(est & true_edges)
        prec = tp / max(len(est), 1)
        rec = tp / len(true_edges)
        return 2 * prec * rec / max(prec + rec, 1e-12)

    f1s = [f1(r) for r in sel.path]
    best = max(f1s)
    picked = f1s[sel.report.selected_index]
    assert best >= 0.8, f"no grid point recovers the planted support: {f1s}"
    assert picked >= 0.9 * best, (
        f"EBIC picked F1={picked:.3f}, best on path {best:.3f}"
    )
    print(f"smoke: EBIC planted-support F1={picked:.3f} "
          f"(best on path {best:.3f}, selected lam="
          f"{sel.report.selected_lam:.4f})")

    # -- submit(PathSpec) is bitwise the offline select_path ------------
    rng = np.random.default_rng(3)
    p = 24
    A = rng.standard_normal((p, p)) * (rng.random((p, p)) < 0.15)
    S = A @ A.T / p + np.eye(p)
    grid = [0.6, 0.4, 0.25]
    opts = EngineOptions(output="sparse", solver_opts={"tol": 1e-8})
    offline = select_path(S, grid=grid, criterion="ebic", n=150, options=opts)
    with GlassoServer(options=opts) as server:
        served = server.submit(
            PathSpec(S=S, grid=grid, criterion="ebic", n=150)
        ).result(timeout=300)
    assert served.report.scores == offline.report.scores
    assert served.report.selected_index == offline.report.selected_index
    for (ca, ba), (cb, bb) in zip(
        served.result.Theta.blocks(), offline.result.Theta.blocks()
    ):
        assert np.array_equal(ca, cb) and np.array_equal(ba, bb)
    assert np.array_equal(
        served.result.support_edges(), offline.result.support_edges()
    )
    print("smoke: submit(PathSpec) == offline select_path (bitwise)")


def check(rec: dict, baseline_path: str, log=print) -> int:
    """CI regression gate: >20% warm-speedup regression, warm fraction below
    the 0.5 acceptance floor (or below baseline - 20%), or a warm class that
    the baseline exercised going dead."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    min_speedup = base["warm_speedup"] / 1.2
    if rec["warm_speedup"] < min_speedup:
        failures.append(
            f"warm speedup {rec['warm_speedup']} < {min_speedup:.2f} "
            f"(baseline {base['warm_speedup']} - 20%)"
        )
    if rec["warm_speedup"] < 1.0:
        failures.append(
            f"warm homotopy slower than cold restarts "
            f"({rec['warm_speedup']}x)"
        )
    floor = max(0.5, base["warm_fraction"] / 1.2)
    if rec["warm_fraction"] < floor:
        failures.append(
            f"warm fraction {rec['warm_fraction']} < {floor:.2f} "
            f"(acceptance floor / baseline {base['warm_fraction']} - 20%)"
        )
    for cls in ("reused", "merged"):
        # only classes the baseline exercised SOLIDLY (>2 buckets) gate —
        # a class the workload barely grazes is plan-perturbation noise
        if rec["warm_counts"].get(cls, 0) == 0 and base["warm_counts"].get(cls, 0) > 2:
            failures.append(f"warm class {cls!r} was never taken")
    for msg in failures:
        log(f"REGRESSION: {msg}")
    if not failures:
        log(f"select bench within baseline ({baseline_path})")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="p=640 smoke variant")
    ap.add_argument("--smoke", action="store_true",
                    help="CI correctness gate (EBIC recovery + served==offline)")
    ap.add_argument("--json", default=None, help="write the record to FILE")
    ap.add_argument("--check", default=None, help="baseline JSON to gate against")
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return
    if args.quick:
        rec = run(K=20, p1=32, n_lambdas=10, reps=2)
    else:
        rec = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        sys.exit(check(rec, args.check))


if __name__ == "__main__":
    main()

"""Fused wave-packer benchmark: one launch per bin per wave vs per-bucket
dispatches (DESIGN.md Section 16).

The workload is the regime the tentpole targets: MANY small solver-bound
blocks — planted chordless cycles (structure "general", sizes spanning the
fused bins) with staggered dyadic cross couplings so components keep merging
down a descending lambda grid.  Lifetime bucketing then fragments every grid
step into dozens of tiny iterative buckets (the warm-homotopy dispatch storm
``bench_select`` first exposed as a stage-attribution anomaly), and the two
arms solve the identical warm-started path:

  * **unfused** — one compiled-solver launch per bucket per wave,
  * **fused**   — all fused-eligible buckets re-packed across bucket
    boundaries into size-binned megabatches, ONE launch per occupied bin.

Reported: min-of-reps wall clock per arm and the fused speedup (gated via
the committed baseline, >20% regression fails CI), per-stage attribution
(solve/dispatch) per arm, dispatch counts (acceptance, asserted here: the
fused arm's iterative-tail launches collapse to at most one per occupied
bin per wave), ``solver.fused.*`` counters including the lockstep sweeps the
in-kernel early exit would save on TPU, and fused == unfused BITWISE
equality (asserted, not approximated — the packer's whole contract).

``smoke()`` is the CI correctness gate: bitwise equality plus the dispatch
collapse on a small merging grid.

    PYTHONPATH=src python -m benchmarks.bench_fused [--quick] [--smoke] \
        [--json BENCH_fused.json] [--check benchmarks/baseline_fused.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _workload(K: int, seed: int = 0) -> np.ndarray:
    """Block-diagonal S of K chordless cycles, sizes cycling over the fused
    bins, dyadic in-cycle couplings in [0.453, 0.5] (above the whole grid,
    so every block stays solver-bound) and staggered dyadic cross couplings
    in [0.218, 0.406] between alternating neighbor blocks (each activates
    at its own grid point — merges all along the path)."""
    rng = np.random.default_rng(seed)
    sizes = [(4, 5, 6, 8, 10, 12)[k % 6] for k in range(K)]
    p = sum(sizes)
    S = np.zeros((p, p))
    off, starts = 0, []
    for b in sizes:
        starts.append(off)
        for i in range(b):
            j = (i + 1) % b
            mag = rng.integers(29, 33) / 64.0
            sgn = 1.0 if rng.random() < 0.5 else -1.0
            S[off + i, off + j] = S[off + j, off + i] = sgn * mag
        off += b
    for k, (a, b) in enumerate(zip(starts, starts[1:])):
        if k % 2 == 0:
            S[a, b] = S[b, a] = (14 + (k * 3) % 13) / 64.0
    np.fill_diagonal(S, 1.0)
    return S


def _grid(n_lambdas: int) -> list[float]:
    return [float(v) for v in np.linspace(0.44, 0.18, n_lambdas)]


def _assert_bitwise(path_a, path_b) -> None:
    """Sparse results compare block by block, order-insensitively (the two
    arms' planners enumerate identically here, but stay safe)."""
    for ra, rb in zip(path_a, path_b):
        assert np.array_equal(ra.labels, rb.labels), "labels diverged"
        by_comp = {np.asarray(c).tobytes(): blk for c, blk in ra.Theta.blocks()}
        for c, blk in rb.Theta.blocks():
            ref = by_comp[np.asarray(c).tobytes()]
            assert np.array_equal(ref, blk), (
                f"fused != unfused at lam={ra.lam:.4f} (comp of {len(c)})"
            )


def run(K: int = 80, n_lambdas: int = 15, reps: int = 3, log=print) -> dict:
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.core import EngineOptions, glasso_path
    from repro.core.instrument import reset, tail_counts
    from repro.engine.waves import FUSED_BINS

    S = _workload(K)
    p = S.shape[0]
    lams = _grid(n_lambdas)
    o_un = EngineOptions(
        output="sparse", solver_opts={"tol": 1e-7}, fused=False
    )
    o_f = o_un.replace(fused=True)
    log(f"fused bench: p={p} ({K} chordless-cycle blocks), "
        f"{len(lams)} lambdas in [{lams[-1]:.3f}, {lams[0]:.3f}]")

    # warm the compiled caches off the clock; the warm pass doubles as the
    # bitwise gate — the packer's contract is exactness, not closeness
    path_un = glasso_path(S, lams, options=o_un)
    path_f = glasso_path(S, lams, options=o_f)
    _assert_bitwise(path_un, path_f)
    log("fused == unfused bitwise across the path: OK")

    rec: dict = {"p": p, "planted_blocks": K, "n_lambdas": len(lams),
                 "reps": reps}
    for arm, opts in (("unfused", o_un), ("fused", o_f)):
        reset("executor.")
        reset("solver.fused.")
        best, path = 1e9, None
        for _ in range(reps):
            t0 = time.perf_counter()
            path = glasso_path(S, lams, options=opts)
            best = min(best, time.perf_counter() - t0)
        fused_c = tail_counts("solver.fused.")
        rec[f"wall_{arm}_s"] = round(best, 3)
        rec[f"stages_{arm}_us"] = {
            k: sum(r.stages_us[k] for r in path)
            for k in ("solve_us", "dispatch_us", "assemble_us")
        }
        rec[f"dispatches_{arm}"] = (
            tail_counts("executor.")["dispatches"] // reps
        )
        if arm == "fused":
            rec["fused_launches"] = fused_c.get("dispatches", 0) // reps
            rec["blocks_packed"] = fused_c.get("blocks_packed", 0) // reps
            rec["lockstep_sweeps_saved"] = (
                fused_c.get("lockstep_sweeps_saved", 0) // reps
            )
    rec["fused_speedup"] = round(
        rec["wall_unfused_s"] / max(rec["wall_fused_s"], 1e-9), 3
    )

    # acceptance: the iterative tail collapses to <= one launch per occupied
    # bin per wave (closed-form/chordal dispatches are not fused-eligible
    # and are excluded by construction: fused_launches counts only packer
    # launches)
    max_launches = len(lams) * len(FUSED_BINS)
    assert rec["fused_launches"] <= max_launches, (
        f"{rec['fused_launches']} fused launches > one-per-bin-per-wave "
        f"bound {max_launches}"
    )
    assert rec["fused_speedup"] >= 1.0, (
        f"fused arm slower than unfused ({rec['fused_speedup']}x)"
    )
    log(f"fused bench: unfused {rec['wall_unfused_s']}s vs fused "
        f"{rec['wall_fused_s']}s -> {rec['fused_speedup']}x; dispatches "
        f"{rec['dispatches_unfused']} -> {rec['dispatches_fused']} "
        f"({rec['fused_launches']} fused launches, "
        f"{rec['blocks_packed']} blocks packed, "
        f"{rec['lockstep_sweeps_saved']} lockstep sweeps saved)")
    return rec


def smoke() -> None:
    """CI correctness gate: bitwise fused == unfused + dispatch collapse."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.core import EngineOptions, glasso_path
    from repro.core.instrument import count, reset
    from repro.engine.waves import FUSED_BINS

    S = _workload(12, seed=3)
    lams = _grid(6)
    o_un = EngineOptions(
        output="sparse", solver_opts={"tol": 1e-7}, fused=False
    )
    path_un = glasso_path(S, lams, options=o_un)
    reset("executor.")
    reset("solver.fused.")
    path_f = glasso_path(S, lams, options=o_un.replace(fused=True))
    _assert_bitwise(path_un, path_f)
    launches = count("solver.fused.dispatches")
    assert 0 < launches <= len(lams) * len(FUSED_BINS), (
        f"fused launches {launches} outside (0, one-per-bin-per-wave]"
    )
    assert count("solver.fused.blocks_packed") > 0
    print(f"smoke: fused == unfused bitwise over {len(lams)}-lambda merging "
          f"path ({launches} fused launches, "
          f"{count('solver.fused.blocks_packed')} blocks packed)")


def check(rec: dict, baseline_path: str, log=print) -> int:
    """CI regression gate: >20% fused-speedup regression, a fused arm slower
    than unfused, or the dispatch collapse coming undone (fused launch count
    above the baseline's by more than 20%)."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    min_speedup = base["fused_speedup"] / 1.2
    if rec["fused_speedup"] < min_speedup:
        failures.append(
            f"fused speedup {rec['fused_speedup']} < {min_speedup:.2f} "
            f"(baseline {base['fused_speedup']} - 20%)"
        )
    if rec["fused_speedup"] < 1.0:
        failures.append(
            f"fused arm slower than unfused ({rec['fused_speedup']}x)"
        )
    if rec["fused_launches"] > base["fused_launches"] * 1.2:
        failures.append(
            f"fused launches {rec['fused_launches']} > baseline "
            f"{base['fused_launches']} + 20% (packing coming undone)"
        )
    for msg in failures:
        log(f"REGRESSION: {msg}")
    if not failures:
        log(f"fused bench within baseline ({baseline_path})")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="K=24 smoke variant")
    ap.add_argument("--smoke", action="store_true",
                    help="CI correctness gate (bitwise + dispatch collapse)")
    ap.add_argument("--json", default=None, help="write the record to FILE")
    ap.add_argument("--check", default=None, help="baseline JSON to gate against")
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return
    if args.quick:
        rec = run(K=24, n_lambdas=8, reps=2)
    else:
        rec = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        sys.exit(check(rec, args.check))


if __name__ == "__main__":
    main()

"""§Perf hillclimb C — the cell most representative of the paper's technique:
screened, bucketed block solves (wall-clock measurable on this CPU, unlike
the TPU dry-run cells).

Workload: paper_synthetic(K=5, p1=60) at lambda_I — 5 components of 60,
bucketed to one vmapped stack of 64-padded blocks.  Variants are the
enumerated §Perf candidates; each records hypothesis / measure / verdict.
Correctness gate: every variant's Theta must match the baseline to 1e-4 and
pass KKT < 1e-4.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def _timed(fn, reps=3):
    fn()  # warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def run(log=print) -> list[dict]:
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import glasso, kkt_residual
    from repro.covariance import lambda_interval_for_k, paper_synthetic

    K, p1 = 5, 60
    S = paper_synthetic(K, p1, seed=0)
    lam_min, lam_max = lambda_interval_for_k(S, K)
    lam = 0.5 * (lam_min + lam_max)

    variants = [
        dict(
            name="baseline: bcd f64 bucketed",
            hypothesis="paper-faithful reference point",
            kwargs=dict(solver="bcd", dtype=jnp.float64, tol=1e-7),
        ),
        dict(
            name="C1: f32 blocks",
            hypothesis="CD sweeps are CPU-SIMD bound; f32 doubles lane width "
                        "=> ~1.5-2x; KKT worsens to ~1e-5 (still sound)",
            kwargs=dict(solver="bcd", dtype=jnp.float32, tol=1e-6),
        ),
        dict(
            name="C2: proximal-gradient solver",
            hypothesis="PG replaces sequential CD with batched O(b^3) "
                        "cholesky iterations => better vectorization on "
                        "wide blocks, ~2x at b=60",
            kwargs=dict(solver="pg", dtype=jnp.float64, tol=1e-8),
        ),
        dict(
            name="C3: admm solver",
            hypothesis="eigh per iteration costs ~4x a cholesky; expect "
                        "slower than PG but more robust",
            kwargs=dict(solver="admm", dtype=jnp.float64, tol=1e-7),
        ),
    ]

    base_theta = None
    out = []
    for v in variants:
        t, res = _timed(lambda kw=v["kwargs"]: glasso(S, lam, screen=True, **kw))
        theta = res.Theta
        kkt = float(kkt_residual(jnp.asarray(S), jnp.asarray(theta, jnp.float64), lam, zero_tol=1e-6))
        if base_theta is None:
            base_theta = theta
            agree = 0.0
        else:
            agree = float(np.abs(theta - base_theta).max())
        rec = {
            "variant": v["name"], "hypothesis": v["hypothesis"],
            "seconds": round(t, 4), "kkt": kkt, "max_diff_vs_baseline": agree,
        }
        out.append(rec)
        log(f"{v['name']:34s} {t:8.3f}s  kkt={kkt:.2e}  diff={agree:.2e}")
        assert agree < 5e-4, v["name"]
    return out


if __name__ == "__main__":
    run()

"""Joint multi-class benchmark on the planted shared-structure workload.

Two instruments, mirroring the paper's screen-vs-no-screen story on the
class axis:

* **Planted workload** (K=4 classes, p=2400: 150 planted 16-vertex blocks,
  ``shared_fraction`` of them IDENTICAL across classes — the joint-forest
  closed-form regime — the rest class-specific — the joint-ADMM regime).
  Measured: hybrid screen seconds, screened joint solve seconds, the joint
  route mix, and fallbacks (hard-asserted ZERO — every shared-path
  candidate must verify).

* **Solve-stage speedup vs K independent glasso calls**, on the
  FULLY-SHARED twin of the workload (shared_fraction = 1.0): there the
  joint solve and the K per-class solves compute the same per-component
  structures, and the joint engine amortizes — one screen/plan over the
  union instead of K, every component solved ONCE and replicated (the
  joint_forest / joint_chordal / joint_shared rungs) with per-class KKT
  certificates.  On the MIXED workload the ratio is also reported but is
  structurally < 1: class-specific components force the K-coupled joint
  ADMM, work the independent baseline simply does not do (it solves a
  different estimator) — the honest cost of coupling.

* **Screen speedup vs the unscreened joint arm**, at a reduced p (the
  whole point of the hybrid screen is that the unscreened joint solve is
  hopeless at p=2400 — a (K, 2400, 2400) eigh per ADMM sweep; the ratio is
  measured where the unscreened arm is feasible and the result is
  hard-asserted equal to the screened one within tolerance).

``--smoke`` is the CI equivalence gate (no timing): joint == K independent
glasso at lam2=0 (Theta per class within tolerance) and hybrid-screened ==
unscreened joint at lam2>0, both penalties, zero fallbacks.

``--json FILE`` writes the record; ``--check BASELINE`` exits non-zero on a
speedup regression past the per-metric margin (33% for the assembly-bound
shared-solve ratio, half-baseline for the orders-of-magnitude screen ratio
— see ``check`` for why each), any fallback, or a dead route class.

    PYTHONPATH=src python -m benchmarks.bench_joint [--smoke] \
        [--json BENCH_joint.json] [--check benchmarks/baseline_joint.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def smoke() -> None:
    """Equivalence gates on fixed seeds; asserts, no timing."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.core import EngineOptions, glasso
    from repro.joint import joint_glasso

    opts = EngineOptions(solver_opts={"tol": 1e-9})
    rng = np.random.default_rng(0)
    K, p, n = 3, 24, 40
    base = rng.standard_normal((n, p)) * (0.3 + rng.random(p))
    Ss = []
    for _ in range(K):
        X = base + 0.7 * rng.standard_normal((n, p))
        Xc = X - X.mean(axis=0)
        Ss.append(Xc.T @ Xc / n)
    M = np.max(np.abs(np.stack(Ss)), axis=0)
    iu = np.triu_indices(p, 1)
    lam1 = float(np.quantile(np.abs(M[iu]), 0.85))
    lam2 = 0.4 * lam1

    for penalty in ("group", "fused"):
        res = joint_glasso(Ss, lam1, 0.0, penalty=penalty, options=opts)
        assert res.fallbacks == 0
        for k in range(K):
            direct = glasso(
                Ss[k], lam1,
                options=EngineOptions(solver="admm",
                                      solver_opts={"tol": 1e-9}),
            )
            err = float(np.abs(res.Theta[k] - direct.Theta).max())
            assert err < 1e-6, f"{penalty} lam2=0 class {k}: diff {err:.2e}"
        print(f"smoke: {penalty:5s} lam2=0 joint == {K} independent glasso")

        screened = joint_glasso(Ss, lam1, lam2, penalty=penalty, options=opts)
        brute = joint_glasso(
            Ss, lam1, lam2, penalty=penalty, screen=False,
            options=EngineOptions(route=False, solver_opts={"tol": 1e-9}),
        )
        err = float(np.abs(screened.Theta - brute.Theta).max())
        assert err < 1e-6, f"{penalty} screened vs unscreened: diff {err:.2e}"
        assert screened.fallbacks == 0
        print(
            f"smoke: {penalty:5s} hybrid-screened == unscreened joint "
            f"(diff {err:.2e}, {screened.screen.n_components} components)"
        )
    print("smoke: joint gates OK")


def run(
    K_blocks: int = 150,
    p1: int = 16,
    n_classes: int = 4,
    shared_fraction: float = 0.85,
    reps: int = 3,
    p1_unscreened: int = 16,
    blocks_unscreened: int = 20,
    penalty: str = "group",
    log=print,
) -> dict:
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.core import EngineOptions, glasso
    from repro.core.instrument import reset, tail_counts
    from repro.covariance import structured_synthetic
    from repro.joint import joint_glasso

    lam1, lam2 = 0.4, 0.1
    tol = 1e-9  # tight enough that every joint-ADMM block clears the 1e-6
               # KKT gate without a fallback re-dispatch (the acceptance bar)
    opts = EngineOptions(solver_opts={"tol": tol})
    Ss = structured_synthetic(
        K_blocks, p1, classes=n_classes, shared_fraction=shared_fraction,
        seed=1,
    )
    p = K_blocks * p1
    log(
        f"joint bench: K={n_classes} classes, p={p} ({K_blocks} planted "
        f"blocks of {p1}, {shared_fraction:.0%} shared), penalty={penalty}, "
        f"lam1={lam1}, lam2={lam2}"
    )

    # warm the compiled caches off the clock
    joint_glasso(list(Ss), lam1, lam2, penalty=penalty, options=opts)
    for k in range(n_classes):
        glasso(Ss[k], lam1, options=opts)

    screen_s, solve_s, indep_s = [], [], []
    res = None
    measured_fallbacks = 0
    mix = fallback_counts = {}
    for _ in range(reps):
        reset("router")
        reset("joint")
        res = joint_glasso(list(Ss), lam1, lam2, penalty=penalty, options=opts)
        screen_s.append(res.screen.seconds)
        solve_s.append(res.solve_seconds)
        mix = tail_counts("router.route.")
        fallback_counts = tail_counts("router.fallback.")
        measured_fallbacks += res.fallbacks
        assert res.fallbacks == 0, f"joint fallbacks: {res.fallbacks}"
        indep_s.append(
            sum(
                glasso(Ss[k], lam1, options=opts).solve_seconds
                for k in range(n_classes)
            )
        )

    # fully-shared twin: the amortization story (same per-component
    # structures in both arms; joint solves each ONCE and replicates)
    Sh = structured_synthetic(
        K_blocks, p1, classes=n_classes, shared_fraction=1.0, seed=1
    )
    joint_glasso(list(Sh), lam1, lam2, penalty=penalty, options=opts)  # warm
    for k in range(n_classes):
        glasso(Sh[k], lam1, options=opts)
    shared_joint_s, shared_indep_s = [], []
    shared_fb = 0
    for _ in range(max(reps, 5)):
        r = joint_glasso(list(Sh), lam1, lam2, penalty=penalty, options=opts)
        shared_fb += r.fallbacks
        shared_joint_s.append(r.solve_seconds)
        shared_indep_s.append(
            sum(
                glasso(Sh[k], lam1, options=opts).solve_seconds
                for k in range(n_classes)
            )
        )
    measured_fallbacks += shared_fb
    assert shared_fb == 0, f"shared-workload fallbacks: {shared_fb}"

    # screen-vs-unscreened joint, at a feasible reduced p
    Su = structured_synthetic(
        blocks_unscreened, p1_unscreened, classes=n_classes,
        shared_fraction=shared_fraction, seed=2,
    )
    joint_glasso(list(Su), lam1, lam2, penalty=penalty, options=opts)  # warm
    t0 = time.perf_counter()
    scr = joint_glasso(list(Su), lam1, lam2, penalty=penalty, options=opts)
    screened_small = time.perf_counter() - t0
    t0 = time.perf_counter()
    uns = joint_glasso(
        list(Su), lam1, lam2, penalty=penalty, screen=False,
        options=EngineOptions(route=False, solver_opts={"tol": tol}),
    )
    unscreened_small = time.perf_counter() - t0
    worst = float(np.abs(scr.Theta - uns.Theta).max())
    assert worst < 1e-5, f"screened vs unscreened joint diverged: {worst:.2e}"

    rec = {
        "p": p,
        "n_classes": n_classes,
        "planted_blocks": K_blocks,
        "block_size": p1,
        "shared_fraction": shared_fraction,
        "penalty": penalty,
        "lam1": lam1,
        "lam2": lam2,
        "reps": reps,
        "screen_s": round(min(screen_s), 3),
        "solve_joint_s": round(min(solve_s), 3),
        "solve_independent_s": round(min(indep_s), 3),
        "solve_ratio_vs_independent_mixed": round(
            min(indep_s) / max(min(solve_s), 1e-9), 3
        ),
        "solve_shared_joint_s": round(min(shared_joint_s), 4),
        "solve_shared_independent_s": round(min(shared_indep_s), 4),
        "solve_speedup_vs_independent": round(
            min(shared_indep_s) / max(min(shared_joint_s), 1e-9), 3
        ),
        "route_counts": mix,
        "fallbacks": fallback_counts,
        "joint_fallbacks": measured_fallbacks,
        "n_components": res.screen.n_components,
        "p_unscreened": blocks_unscreened * p1_unscreened,
        "screened_small_s": round(screened_small, 3),
        "unscreened_small_s": round(unscreened_small, 3),
        "screen_speedup_vs_unscreened": round(
            unscreened_small / max(screened_small, 1e-9), 3
        ),
        "max_theta_diff_vs_unscreened": worst,
    }
    log(
        f"joint bench: screen {rec['screen_s']}s, mixed-workload joint "
        f"solve {rec['solve_joint_s']}s (vs {n_classes} independent "
        f"{rec['solve_independent_s']}s -> "
        f"{rec['solve_ratio_vs_independent_mixed']}x, coupling included); "
        f"shared-workload solve {rec['solve_shared_joint_s']}s vs "
        f"independent {rec['solve_shared_independent_s']}s -> "
        f"{rec['solve_speedup_vs_independent']}x; unscreened joint arm "
        f"(p={rec['p_unscreened']}) {rec['unscreened_small_s']}s vs screened "
        f"{rec['screened_small_s']}s -> "
        f"{rec['screen_speedup_vs_unscreened']}x; mix={mix}; fallbacks=0"
    )
    return rec


def check(rec: dict, baseline_path: str, log=print) -> int:
    """CI gate: >20% regression on either speedup, any fallback, or a dead
    joint route class fails."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    # the unscreened arm is a single ~1-minute eigh-bound run whose wall
    # time swings +-30%, so this orders-of-magnitude ratio gates at half
    # the baseline: a real regression (screening stops decomposing) drops
    # it to ~1x, far below any half-baseline floor
    if rec["screen_speedup_vs_unscreened"] < base["screen_speedup_vs_unscreened"] / 2:
        failures.append(
            f"screen speedup {rec['screen_speedup_vs_unscreened']} < "
            f"{base['screen_speedup_vs_unscreened'] / 2:.2f} "
            f"(baseline {base['screen_speedup_vs_unscreened']} / 2)"
        )
    # both arms of the shared-workload ratio are assembly-bound memory
    # traffic at p=2400, so it is noisier than the compute-bound gates —
    # the regression margin is 33% instead of 20%
    if rec["solve_speedup_vs_independent"] < base["solve_speedup_vs_independent"] / 1.5:
        failures.append(
            f"shared-workload solve speedup {rec['solve_speedup_vs_independent']} < "
            f"{base['solve_speedup_vs_independent'] / 1.5:.2f} "
            f"(baseline {base['solve_speedup_vs_independent']} - 33%)"
        )
    if sum(rec["fallbacks"].values()) or rec["joint_fallbacks"]:
        failures.append(f"fallbacks nonzero: {rec['fallbacks']}")
    for cls in ("singleton", "joint_forest", "joint_shared", "joint_general"):
        if rec["route_counts"].get(cls, 0) == 0 and base["route_counts"].get(cls, 0):
            failures.append(f"joint route class {cls!r} was never taken")
    for msg in failures:
        log(f"REGRESSION: {msg}")
    if not failures:
        log(f"joint bench within baseline ({baseline_path})")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI equivalence gate (joint == per-class at lam2=0; "
                         "screened == unscreened)")
    ap.add_argument("--quick", action="store_true", help="smaller workload")
    ap.add_argument("--json", default=None, help="write the record to FILE")
    ap.add_argument("--check", default=None, help="baseline JSON to gate against")
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return
    if args.quick:
        rec = run(K_blocks=40, reps=2, blocks_unscreened=10)
    else:
        rec = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        sys.exit(check(rec, args.check))


if __name__ == "__main__":
    main()

"""Giant-component benchmark: the sharded oversize route vs the
single-device dense solve.

The paper's regime of interest for this PR: moderate rho leaves one
connected component near size p, so the solve stage is ONE giant block and
the per-device memory of the solver is the scale cap.  Two arms, each in
its OWN subprocess (per-arm ``ru_maxrss``, like bench_stream):

  * ``dense``    single-device ADMM oracle on the giant block (the eigh
                 path every PR-2 route bottoms out in);
  * ``sharded``  8 emulated devices (``xla_force_host_platform_device_
                 count``), the full engine path with an oversize threshold
                 below the giant block: screen -> oversize class ->
                 shard-direct gather -> mesh-spanning no-eigh ADMM ->
                 distributed KKT verification.

MEMORY METRIC.  Under host-device emulation every "device" shares one
process, so OS RSS cannot see per-device footprints; the acceptance metric
is the ACCOUNTING per-device peak both arms publish (DESIGN.md Section 11
memory model): dense = blocks.SINGLE_DEVICE_BUFFERS * b^2 * 8 bytes on its
one device, sharded = the ``solver.oversize.device_bytes_peak`` watermark
(12 row-shards of (b_pad/d, b_pad)).  Subprocess RSS is reported alongside
as the whole-process sanity number.

Acceptance facts recorded per run (gated by --check against the committed
``baseline_giant.json``; >20% regression fails):

  * Theta of the sharded arm matches the dense ADMM oracle within
    route_check_tol * max(1, max|S|)   (max_diff, kkt_residual)
  * zero unexplained fallbacks         (oversize.fallbacks == 0)
  * sharded per-device bytes strictly below the dense arm's single-device
    bytes                              (device_bytes_ratio < 1)

    PYTHONPATH=src python -m benchmarks.bench_giant [--smoke] \
        [--json BENCH_giant.json] [--check benchmarks/baseline_giant.json]
"""

from __future__ import annotations

import argparse
import json
import os
import resource
import subprocess
import sys
import time

import numpy as np

P = 256            # total vertices; the giant component covers most of them
N_ROWS = 320
LAM = 0.12
DEVICES = 8
TOL = 1e-6         # route_check_tol for the sharded arm's KKT acceptance


def _workload(p: int = P, seed: int = 0) -> np.ndarray:
    """(p, p) covariance with one giant factor-coupled component plus a
    fringe of small/isolated blocks — Figure-1-style heavy tail.  Loadings
    are kept moderate: ADMM iteration counts grow with the giant block's
    conditioning, and the bench should measure the sharded machinery, not
    an adversarial spectrum (the multidevice tests cover harder blocks)."""
    rng = np.random.default_rng(seed)
    n = N_ROWS
    X = 0.8 * rng.standard_normal((n, p))
    giant = int(0.8 * p)
    f = rng.standard_normal((n, 3))
    load = 0.5 + 0.2 * rng.random(giant)
    X[:, :giant] += f[:, rng.integers(0, 3, giant)] * load
    # a few planted pairs in the fringe
    for k in range(giant, p - 1, 6):
        X[:, k + 1] += 0.9 * X[:, k]
    S = np.cov(X, rowvar=False, bias=True)
    return 0.5 * (S + S.T)


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _giant_block(S: np.ndarray, lam: float) -> np.ndarray:
    from repro.core.components import component_lists, components_from_covariance_host

    labels = components_from_covariance_host(S, lam)
    comps = component_lists(labels)
    comp = max(comps, key=len)
    return S[np.ix_(comp, comp)]


def run_arm(arm: str, p: int, seed: int = 0) -> dict:
    """One arm in THIS process (the parent spawns each in a subprocess)."""
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import blocks as blocks_mod

    S = _workload(p, seed)
    blk = _giant_block(S, LAM)
    b = blk.shape[0]
    t0 = time.perf_counter()
    if arm == "dense":
        from repro.core.solvers.admm import glasso_admm_info

        Theta, iters = glasso_admm_info(jnp.asarray(blk), LAM, tol=1e-9)
        Theta = np.asarray(jax.block_until_ready(Theta))
        rec = {
            "iters": int(iters),
            "device_bytes": int(
                blocks_mod.SINGLE_DEVICE_BUFFERS * b * b * 8
            ),
            "theta_trace": float(np.trace(Theta)),
            "theta_absum": float(np.abs(Theta).sum()),
        }
    elif arm == "sharded":
        from repro.core import EngineOptions
        from repro.core.glasso import glasso
        from repro.core.instrument import counts

        assert jax.device_count() == DEVICES, (
            f"sharded arm expected {DEVICES} emulated devices, got "
            f"{jax.device_count()} — spawn via the parent"
        )
        res = glasso(
            S, LAM,
            options=EngineOptions(
                solver="admm", route_check_tol=TOL,
                oversize_threshold=b - 1,  # giant block is oversize, rest not
                solver_opts={"tol": 1e-9},
            ),
        )
        c = counts("solver.oversize.")
        # oracle comparison runs in the PARENT via the theta fingerprints +
        # cross-arm max_diff on the giant block
        comp_theta = _giant_theta(res)
        rec = {
            "oversize": res.oversize,
            "fallbacks": int(c.get("solver.oversize.fallbacks", 0)),
            "dispatched": int(c["solver.oversize.dispatched"]),
            "inner_iters": int(c["solver.oversize.cg_iters"]),
            "device_bytes": int(c["solver.oversize.device_bytes_peak"]),
            "theta_trace": float(np.trace(comp_theta)),
            "theta_absum": float(np.abs(comp_theta).sum()),
            "theta_file": _dump_theta(comp_theta),
        }
    else:
        raise ValueError(arm)
    rec.update(
        {
            "arm": arm,
            "p": p,
            "b_giant": b,
            "seconds": round(time.perf_counter() - t0, 2),
            "rss_mb": round(_rss_mb(), 1),
        }
    )
    return rec


def _giant_theta(res) -> np.ndarray:
    from repro.core.components import component_lists

    comp = max(component_lists(res.labels), key=len)
    return res.Theta[np.ix_(comp, comp)]


def _dump_theta(theta: np.ndarray) -> str:
    path = os.path.join(
        os.environ.get("TMPDIR", "/tmp"), f"bench_giant_theta_{os.getpid()}.npy"
    )
    np.save(path, theta)
    return path


def _spawn_arm(arm: str, p: int) -> dict:
    env = dict(os.environ)
    if arm == "sharded":
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={DEVICES} "
            + env.get("XLA_FLAGS", "")
        ).strip()
        env.setdefault("JAX_PLATFORMS", "cpu")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_giant", "--arm", arm,
         "--p", str(p)],
        capture_output=True, text=True, check=True, env=env,
    )
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(p: int = P, log=print) -> dict:
    dense = _spawn_arm("dense", p)
    sharded = _spawn_arm("sharded", p)
    # cross-arm equivalence: the sharded giant-block Theta vs the oracle's
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core.solvers.admm import glasso_admm

    S = _workload(p)
    blk = _giant_block(S, LAM)
    oracle = np.asarray(glasso_admm(jnp.asarray(blk), LAM, tol=1e-9))
    theta_sharded = np.load(sharded["theta_file"])
    os.unlink(sharded["theta_file"])
    max_diff = float(np.abs(theta_sharded - oracle).max())
    scale = max(1.0, float(np.abs(blk).max()))
    rec = {
        "p": p,
        "b_giant": dense["b_giant"],
        "devices": DEVICES,
        "lam": LAM,
        "max_diff": max_diff,
        "tol_scaled": TOL * scale,
        "fallbacks": sharded["fallbacks"],
        "dispatched": sharded["dispatched"],
        "inner_iters": sharded["inner_iters"],
        "dense_iters": dense["iters"],
        "dense_device_bytes": dense["device_bytes"],
        "sharded_device_bytes": sharded["device_bytes"],
        "device_bytes_ratio": round(
            sharded["device_bytes"] / dense["device_bytes"], 4
        ),
        "dense_seconds": dense["seconds"],
        "sharded_seconds": sharded["seconds"],
        "dense_rss_mb": dense["rss_mb"],
        "sharded_rss_mb": sharded["rss_mb"],
    }
    log(
        f"p={p} giant b={rec['b_giant']}: dense {dense['seconds']}s "
        f"({dense['iters']} eigh iters, {dense['device_bytes']/2**20:.1f}MB "
        f"on 1 device)  vs  sharded {sharded['seconds']}s "
        f"({sharded['inner_iters']} inner iters across {DEVICES} devices, "
        f"{sharded['device_bytes']/2**20:.1f}MB/device, ratio "
        f"{rec['device_bytes_ratio']}); max|dTheta|={max_diff:.2e} "
        f"(accept {rec['tol_scaled']:.2e}), fallbacks={rec['fallbacks']}"
    )
    if max_diff > rec["tol_scaled"]:
        raise AssertionError(
            f"sharded Theta diverged from the ADMM oracle: {max_diff:.3e} > "
            f"{rec['tol_scaled']:.3e}"
        )
    if rec["fallbacks"]:
        raise AssertionError(
            f"{rec['fallbacks']} unexplained sharded fallbacks on the bench "
            "workload"
        )
    if rec["device_bytes_ratio"] >= 1.0:
        raise AssertionError(
            "sharded per-device bytes not below the dense single-device arm: "
            f"ratio {rec['device_bytes_ratio']}"
        )
    return rec


def smoke(log=print) -> None:
    """In-process sharded == dense equivalence on the 1-device mesh (the CI
    gate's cheap arm: same code path, no emulation)."""
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import EngineOptions
    from repro.core.glasso import glasso
    from repro.core.instrument import counts, reset
    from repro.core.solvers.admm import glasso_admm

    p = 96
    S = _workload(p, seed=3)
    blk = _giant_block(S, LAM)
    reset("solver.oversize")
    base = glasso(
        S, LAM,
        options=EngineOptions(solver="admm", solver_opts={"tol": 1e-9}),
    )
    over = glasso(
        S, LAM,
        options=EngineOptions(solver="admm", solver_opts={"tol": 1e-9},
                              oversize_threshold=blk.shape[0] - 1),
    )
    c = counts("solver.oversize.")
    assert c.get("solver.oversize.dispatched", 0) >= 1, "oversize never routed"
    assert c.get("solver.oversize.fallbacks", 0) == 0, "smoke: fallbacks"
    diff = float(np.abs(over.Theta - base.Theta).max())
    assert diff < 1e-6, f"smoke: sharded != dense ({diff:.3e})"
    oracle = np.asarray(glasso_admm(jnp.asarray(blk), LAM, tol=1e-9))
    from repro.core.components import component_lists

    comp = max(component_lists(over.labels), key=len)
    diff2 = float(np.abs(over.Theta[np.ix_(comp, comp)] - oracle).max())
    assert diff2 < 1e-6, f"smoke: giant block vs oracle ({diff2:.3e})"
    log(
        f"giant smoke OK: p={p}, giant b={blk.shape[0]}, "
        f"max|dTheta|={diff:.2e}, {c['solver.oversize.cg_iters']} inner iters, "
        "0 fallbacks"
    )


def check(rec: dict, baseline_path: str, log=print) -> int:
    """CI gate: correctness facts are hard asserts in run(); this gates the
    QUANTITIES against the committed baseline (>20% regression fails)."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    max_ratio = base["device_bytes_ratio"] * 1.2
    if rec["device_bytes_ratio"] > max_ratio:
        failures.append(
            f"device-bytes ratio {rec['device_bytes_ratio']} > {max_ratio:.3f}"
            f" (baseline {base['device_bytes_ratio']} + 20%)"
        )
    max_inner = base["inner_iters"] * 1.2
    if rec["inner_iters"] > max_inner:
        failures.append(
            f"inner iterations {rec['inner_iters']} > {max_inner:.0f} "
            f"(baseline {base['inner_iters']} + 20%)"
        )
    for msg in failures:
        log(f"REGRESSION: {msg}")
    if not failures:
        log(f"giant bench within baseline ({baseline_path})")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arm", choices=("dense", "sharded"), default=None)
    ap.add_argument("--p", type=int, default=P)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--check", default=None)
    args = ap.parse_args()

    if args.arm:  # subprocess mode: one arm, JSON on stdout
        print(json.dumps(run_arm(args.arm, args.p)))
        return
    if args.smoke:
        smoke()
        return
    rec = run(args.p)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        sys.exit(check(rec, args.check))


if __name__ == "__main__":
    main()

"""Route-mix and ladder-speedup benchmark on the p=2400 lambda-path workload.

The routing-ladder acceptance bench: solve ``structured_synthetic`` (p=2400:
150 planted 16-vertex components — 60% trees, 25% chordal 2-trees, 15%
chordless cycles, edge weights spread across the lambda interval) along a
descending lambda path twice: once with the structure-routed solver ladder,
once with routing off (every block iterative — the PR-1 executor behavior).
Descending the grid progressively reveals then densifies the planted
structures, so one path sweeps the whole classification story; at the two
largest lambdas the thresholded graph is the paper's large-rho regime
(everything singleton/pair/tree).  Reported:

  * the per-lambda route mix (singleton/pair/tree/chordal/general blocks),
  * the non-iterative block fraction at the two largest lambdas
    (acceptance: >= 0.8; in this regime it is ~1.0),
  * the PATH SOLVE stage speedup, routed vs unrouted, min-of-``reps`` wall
    (acceptance: >= 1.5x).  Planning (one shared union-find/argsort pass) is
    identical in both variants and reported separately via the end-to-end
    wall columns.  Both variants run the CURRENT executor, which this PR
    also made faster (batched assembly scatter, warm-started repairs), so
    the unrouted baseline is at least as fast as the literal PR-1 code —
    the measured ratio is a LOWER bound on the improvement vs PR 1.
  * fallback counts (closed-form candidates the KKT check rejected).

``--json FILE`` writes the record for the CI artifact; ``--check BASELINE``
exits non-zero when the measured solve speedup regresses more than 20% below
the committed baseline, the route-mix fraction drops below it, or a ladder
class stops being exercised.

    PYTHONPATH=src python -m benchmarks.bench_routes [--quick] \
        [--json BENCH_routes.json] [--check benchmarks/baseline_routes.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _workload(K: int, p1: int, n_lambdas: int, seed: int = 1):
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.covariance import structured_synthetic

    S = structured_synthetic(K, p1, seed=seed)
    lams = [float(v) for v in np.linspace(0.75, 0.32, n_lambdas)]
    return S, lams


def run(
    K: int = 150, p1: int = 16, n_lambdas: int = 12, reps: int = 3, log=print
) -> dict:
    from repro.core import EngineOptions, glasso_path
    from repro.core.instrument import reset, tail_counts

    R, lams = _workload(K, p1, n_lambdas)
    p = K * p1
    log(f"route bench: p={p} ({K} planted blocks of {p1}), {len(lams)} "
        f"lambdas in [{lams[-1]:.3f}, {lams[0]:.3f}]")

    # warm the compiled caches off the clock (compile time is not the metric)
    glasso_path(R, lams, options=EngineOptions(solver_opts={"tol": 1e-7}))
    glasso_path(
        R, lams, options=EngineOptions(route=False, solver_opts={"tol": 1e-7})
    )

    wall_r, wall_u, solve_r, solve_u = [], [], [], []
    routed = unrouted = None
    mix_counts = fallbacks = {}
    for _ in range(reps):
        reset("router")
        t0 = time.perf_counter()
        routed = glasso_path(R, lams, options=EngineOptions(solver_opts={"tol": 1e-7}))
        wall_r.append(time.perf_counter() - t0)
        mix_counts = tail_counts("router.route.")
        fallbacks = tail_counts("router.fallback.")
        t0 = time.perf_counter()
        unrouted = glasso_path(
            R, lams,
            options=EngineOptions(route=False, solver_opts={"tol": 1e-7}),
        )
        wall_u.append(time.perf_counter() - t0)
        solve_r.append(sum(r.solve_seconds for r in routed))
        solve_u.append(sum(r.solve_seconds for r in unrouted))

    worst = 0.0
    for r, u in zip(routed, unrouted):
        worst = max(worst, float(np.abs(r.Theta - u.Theta).max()))
    assert worst < 1e-5, f"routed vs unrouted diverged: {worst:.2e}"

    per_lambda = []
    for r in routed:
        per_lambda.append(
            {
                "lam": round(r.lam, 5),
                "mix": r.route_mix,
                "noniterative_fraction": round(r.noniterative_fraction, 4),
            }
        )
        log(f"  lam={r.lam:7.4f}  mix={r.route_mix}  "
            f"noniter={r.noniterative_fraction:.3f}")

    frac_top2 = min(row["noniterative_fraction"] for row in per_lambda[:2])
    rec = {
        "p": p,
        "planted_blocks": K,
        "block_size": p1,
        "n_lambdas": len(lams),
        "reps": reps,
        "solve_routed_s": round(min(solve_r), 3),
        "solve_unrouted_s": round(min(solve_u), 3),
        "solve_speedup": round(min(solve_u) / max(min(solve_r), 1e-9), 3),
        "wall_routed_s": round(min(wall_r), 3),
        "wall_unrouted_s": round(min(wall_u), 3),
        "wall_speedup": round(min(wall_u) / max(min(wall_r), 1e-9), 3),
        "noniterative_fraction_top2": frac_top2,
        "route_counts": mix_counts,
        "fallbacks": fallbacks,
        "max_theta_diff": worst,
        "per_lambda": per_lambda,
    }
    log(f"route bench: solve stage {rec['solve_routed_s']}s vs "
        f"{rec['solve_unrouted_s']}s -> {rec['solve_speedup']}x "
        f"(end-to-end wall {rec['wall_routed_s']}s vs {rec['wall_unrouted_s']}s "
        f"-> {rec['wall_speedup']}x), top-2-lambda non-iterative fraction "
        f"{frac_top2:.3f}, fallbacks {sum(fallbacks.values())}")
    return rec


def check(rec: dict, baseline_path: str, log=print) -> int:
    """CI regression gate: >20% solve-speedup regression, any route-mix drop
    below the committed baseline, or a dead ladder class fails."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    min_speedup = base["solve_speedup"] / 1.2
    if rec["solve_speedup"] < min_speedup:
        failures.append(
            f"solve speedup {rec['solve_speedup']} < {min_speedup:.2f} "
            f"(baseline {base['solve_speedup']} - 20%)"
        )
    if rec["noniterative_fraction_top2"] < base["noniterative_fraction_top2"]:
        failures.append(
            f"non-iterative fraction {rec['noniterative_fraction_top2']} < "
            f"baseline {base['noniterative_fraction_top2']}"
        )
    for cls in ("singleton", "pair", "tree", "chordal"):
        if rec["route_counts"].get(cls, 0) == 0 and base["route_counts"].get(cls, 0):
            failures.append(f"route class {cls!r} was never taken")
    for msg in failures:
        log(f"REGRESSION: {msg}")
    if not failures:
        log(f"route bench within baseline ({baseline_path})")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="p=600 smoke variant")
    ap.add_argument("--json", default=None, help="write the record to FILE")
    ap.add_argument("--check", default=None, help="baseline JSON to gate against")
    args = ap.parse_args()

    if args.quick:
        rec = run(K=40, p1=16, n_lambdas=8, reps=2)
    else:
        rec = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        sys.exit(check(rec, args.check))


if __name__ == "__main__":
    main()

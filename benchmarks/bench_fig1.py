"""Paper Figure 1 analog: component-size distribution of the thresholded
covariance graph across lambda, for three microarray-like examples.

Emits CSV rows (example, lambda, size, count) — the exact data behind the
paper's heatmap — plus summary stats (n_components, max_comp per lambda).
The lambda range per example is chosen exactly as in the paper: from the
sorted |S_ij| values down to the smallest lambda whose maximal component
stays under a cap.
"""

from __future__ import annotations

import jax
import numpy as np


def run(cap: int = 300, n_lambdas: int = 12, log=print) -> list[dict]:
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import component_size_distribution, lambda_for_max_component
    from repro.covariance import microarray_like, sample_correlation

    examples = {
        "A-like": (62, 800),
        "B-like": (100, 1500),
        "C-like": (80, 2500),
    }
    out = []
    for name, (n, p) in examples.items():
        X = microarray_like(n, p, seed=hash(name) % 2**31)
        R = np.asarray(sample_correlation(jnp.asarray(X)))
        lam_min = lambda_for_max_component(R, cap)
        lam_hi = 1.0  # correlation input: all isolated at lambda >= 1
        lams = np.linspace(lam_min * 1.0005, lam_hi * 0.999, n_lambdas)
        dist = component_size_distribution(R, lams)
        for d in dist:
            out.append(
                {
                    "example": name, "lambda": d["lambda"],
                    "n_components": d["n_components"], "max_comp": d["max_comp"],
                    "sizes": d["sizes"].tolist(), "counts": d["counts"].tolist(),
                }
            )
        log(f"{name}: lambda in [{lam_min:.3f}, 1.0), max_comp at lam_min+ = "
            f"{dist[0]['max_comp']} (cap {cap}), components {dist[0]['n_components']} "
            f"-> {dist[-1]['n_components']} (isolated at lambda->1)")
    return out


if __name__ == "__main__":
    run()

"""Paper Table 1 analog: screen vs no-screen timings on the Section-4.1
synthetic block-diagonal problems, for both solver families.

Scaled to container-feasible sizes (the paper's largest no-screen columns ran
2 hours on a 3.3 GHz Xeon; we keep the (K, p1) grid structure and both
lambda_I / lambda_II points, at sizes where the unscreened baseline completes
in seconds-to-minutes on this CPU).  Columns mirror the paper: with screen,
without screen, speedup factor, graph-partition seconds.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def run(rows=None, solvers=("bcd", "pg"), cc_backend="host", log=print) -> list[dict]:
    jax.config.update("jax_enable_x64", True)
    from repro.core import EngineOptions, glasso
    from repro.covariance import lambda_interval_for_k, paper_synthetic
    from repro.engine import compiled_cache_stats

    rows = rows or [(2, 50), (2, 100), (5, 60), (8, 40)]
    out = []
    for K, p1 in rows:
        S = paper_synthetic(K, p1, seed=0)
        lam_min, lam_max = lambda_interval_for_k(S, K)
        lam_I = 0.5 * (lam_min + lam_max)
        lam_II = lam_max - 0.02 * (lam_max - lam_min)
        for lam_name, lam in (("lambda_I", lam_I), ("lambda_II", lam_II)):
            for solver in solvers:
                # warm BOTH paths' executables first (the engine's compiled
                # cache is process-global) — the paper's timings are solve
                # times, not compile times (Fortran/MATLAB have no JIT)
                glasso(S, lam, screen=True,
                       options=EngineOptions(solver=solver, cc_backend=cc_backend,
                                             solver_opts={"tol": 1e-7}))
                glasso(S, lam, screen=False,
                       options=EngineOptions(solver=solver,
                                             solver_opts={"tol": 1e-7}))
                t0 = time.perf_counter()
                r_screen2 = glasso(S, lam, screen=True,
                                   options=EngineOptions(
                                       solver=solver, cc_backend=cc_backend,
                                       solver_opts={"tol": 1e-7}))
                t_screen = time.perf_counter() - t0
                t0 = time.perf_counter()
                r_full = glasso(S, lam, screen=False,
                                options=EngineOptions(
                                    solver=solver, solver_opts={"tol": 1e-7}))
                t_full = time.perf_counter() - t0
                err = float(np.abs(r_screen2.Theta - r_full.Theta).max())
                rec = {
                    "K": K, "p1": p1, "p": K * p1, "lambda": lam_name,
                    "solver": solver,
                    "with_screen_s": round(t_screen, 4),
                    "without_screen_s": round(t_full, 4),
                    "speedup": round(t_full / max(t_screen, 1e-9), 2),
                    "graph_partition_s": round(r_screen2.screen.seconds, 6),
                    "n_components": r_screen2.screen.n_components,
                    "max_abs_diff": err,
                }
                out.append(rec)
                log(
                    f"K={K} p1={p1} {lam_name} {solver:4s} "
                    f"screen {rec['with_screen_s']:8.3f}s  full {rec['without_screen_s']:8.3f}s  "
                    f"speedup {rec['speedup']:6.2f}x  partition {rec['graph_partition_s']:.4f}s  "
                    f"diff {err:.2e}"
                )
    log(f"engine compiled cache after sweep: {compiled_cache_stats()}")
    return out


if __name__ == "__main__":
    run()

"""Benchmark harness — one module per paper table/figure + the kernel
microbench + the LM dry-run roofline summary.  Prints ``name,us_per_call,
derived`` CSV rows at the end for machine consumption.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--smoke]

``--smoke`` is the CI gate: a fast fixed-seed equivalence check that the
engine path (screen -> plan -> async batched solve) produces the same Theta
as the dense unscreened path, for single solves and for an incremental
warm-started lambda path.  Exits non-zero on any mismatch.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path


def smoke() -> None:
    """Engine-vs-dense equivalence on fixed seeds; asserts, no timing."""
    import jax

    jax.config.update("jax_enable_x64", True)
    import numpy as np

    from repro.core import glasso, glasso_path
    from repro.core.instrument import count, reset
    from repro.covariance import lambda_interval_for_k, paper_synthetic
    from repro.engine import EngineOptions, available_cc_backends

    S = paper_synthetic(3, 12, seed=0)
    lam_min, lam_max = lambda_interval_for_k(S, 3)
    lam = 0.5 * (lam_min + lam_max)

    # route=False pins the reference arm to the iterative dense path — the
    # gate must compare the engine against the pre-ladder behavior, not two
    # arms of the new closed-form code
    dense = glasso(S, lam, screen=False,
                   options=EngineOptions(route=False, solver_opts={"tol": 1e-9}))
    for backend in available_cc_backends():
        res = glasso(S, lam,
                     options=EngineOptions(cc_backend=backend,
                                           solver_opts={"tol": 1e-9}))
        err = float(np.abs(res.Theta - dense.Theta).max())
        assert err < 1e-6, f"backend {backend}: engine vs dense diff {err:.2e}"
        print(f"smoke: cc_backend={backend:10s} matches dense (diff {err:.2e})")

    lams = sorted(np.linspace(lam_min * 0.8, lam_max * 1.05, 6), reverse=True)
    reset()
    path = glasso_path(S, lams, options=EngineOptions(solver_opts={"tol": 1e-9}))
    assert count("partition.unionfind_passes") == 1, "path planner must plan in one pass"
    for r in path:
        ref = glasso(S, r.lam, screen=False,
                     options=EngineOptions(route=False, solver_opts={"tol": 1e-9}))
        err = float(np.abs(r.Theta - ref.Theta).max())
        assert err < 1e-5, f"path lam={r.lam:.4f}: engine vs dense diff {err:.2e}"
    print(f"smoke: {len(path)}-lambda warm-started path matches dense "
          f"(1 union-find pass)")

    # routing ladder: every structure class exercised, routed == unrouted.
    # One deterministic matrix with a singleton (vertex 0), a pair, a path
    # tree, a chorded 4-cycle (chordal) and a CHORDLESS 4-cycle on vertices
    # 11-14 (general — no (11,13)/(12,14) chord is ever set) at lam=0.3.
    from repro.core.instrument import route_mix_counts

    Ss = np.eye(15) * 2.0
    ladder_edges = [
        (1, 2, 0.8),                                              # pair
        (3, 4, 0.7), (4, 5, -0.6), (5, 6, 0.5),                   # tree
        (7, 8, 0.45), (8, 9, -0.45), (9, 10, 0.45),
        (10, 7, -0.45), (7, 9, 0.45),                             # chordal
        (11, 12, 0.5), (12, 13, 0.5), (13, 14, 0.5), (14, 11, 0.5),
    ]
    for i, j, v in ladder_edges:
        Ss[i, j] = Ss[j, i] = v
    reset()
    routed = glasso(Ss, 0.3, options=EngineOptions(solver_opts={"tol": 1e-9}))
    unrouted = glasso(
        Ss, 0.3, options=EngineOptions(route=False, solver_opts={"tol": 1e-9})
    )
    err = float(np.abs(routed.Theta - unrouted.Theta).max())
    assert err < 1e-6, f"ladder: routed vs unrouted diff {err:.2e}"
    mix = route_mix_counts()
    for cls in ("singleton", "pair", "tree", "chordal", "general"):
        assert mix.get(cls, 0) > 0, f"ladder class {cls!r} never routed"
    print(f"smoke: routing ladder matches iterative on all classes ({mix})")

    # joint multi-class gates: lam2=0 == K independent glasso; hybrid-
    # screened == unscreened joint (both penalties, zero fallbacks)
    from benchmarks import bench_joint

    bench_joint.smoke()

    # sparse-native results: sparse == dense on the from-data path, sparse-
    # aware KKT verification, no (p, p) allocation in the sparse container
    from benchmarks import bench_sparse

    bench_sparse.smoke()

    # serving control plane: typed specs == engine, tenant quota Overload,
    # deadline drop, result-cache hit, legacy-verb shim equivalence
    from benchmarks import bench_serve

    bench_serve.smoke()

    # model selection: EBIC recovers a planted chain's support on a small
    # grid, and submit(PathSpec) is bitwise-equal to offline select_path
    from benchmarks import bench_select

    bench_select.smoke()

    # fused wave packer: megabatched in-kernel BCD == per-bucket dispatches
    # bitwise, and the iterative tail collapses to one launch per bin per wave
    from benchmarks import bench_fused

    bench_fused.smoke()
    print("smoke: OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller Table-1 grid")
    ap.add_argument("--smoke", action="store_true",
                    help="CI equivalence gate (engine path == dense path)")
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return

    rows = []

    print("=" * 72)
    print("Table 1 analog: screen vs no-screen, synthetic blocks (Section 4.1)")
    print("=" * 72)
    from benchmarks import bench_table1

    grid = [(2, 40), (5, 30)] if args.quick else None
    for r in bench_table1.run(rows=grid):
        rows.append((f"table1/K{r['K']}p{r['p1']}/{r['lambda']}/{r['solver']}",
                     r["with_screen_s"] * 1e6, f"speedup={r['speedup']}"))

    print("=" * 72)
    print("Tables 2-3 analog: microarray-like lambda grids (Section 4.2)")
    print("=" * 72)
    from benchmarks import bench_table23

    for r in bench_table23.run():
        key = f"table{r['table']}/" + (r.get("regime") or r.get("example", ""))
        rows.append((key, (r.get("with_screen_s") or r.get("avg_solve_s", 0)) * 1e6,
                     f"max_comp={r['avg_max_component']:.0f}"))

    print("=" * 72)
    print("Routing ladder: structure-routed vs all-iterative path solving")
    print("=" * 72)
    from benchmarks import bench_routes

    route_rec = bench_routes.run(
        K=40 if args.quick else 150, n_lambdas=8 if args.quick else 12
    )
    rows.append((f"routes/p{route_rec['p']}", route_rec["solve_routed_s"] * 1e6,
                 f"solve_speedup={route_rec['solve_speedup']}"))

    print("=" * 72)
    print("Engine planner: incremental path planning vs per-lambda replanning")
    print("=" * 72)
    plan_rec = bench_table23.run_planning(p=1200 if args.quick else 2400,
                                          n=100 if args.quick else 80)
    rows.append((f"planner/p{plan_rec['p']}", plan_rec["incremental_s"] * 1e6,
                 f"speedup={plan_rec['speedup']}"))

    print("=" * 72)
    print("Model selection: warm homotopy path vs per-lambda cold restarts")
    print("=" * 72)
    from benchmarks import bench_select

    sel_rec = (bench_select.run(K=20, p1=32, n_lambdas=10, reps=2)
               if args.quick else bench_select.run())
    rows.append((f"select/p{sel_rec['p']}", sel_rec["wall_warm_s"] * 1e6,
                 f"warm_speedup={sel_rec['warm_speedup']}"))

    print("=" * 72)
    print("Fused wave packer: one launch per bin per wave vs per-bucket dispatch")
    print("=" * 72)
    from benchmarks import bench_fused

    fus_rec = (bench_fused.run(K=24, n_lambdas=8, reps=2)
               if args.quick else bench_fused.run())
    rows.append((f"fused/p{fus_rec['p']}", fus_rec["wall_fused_s"] * 1e6,
                 f"fused_speedup={fus_rec['fused_speedup']}"))

    print("=" * 72)
    print("Figure 1 analog: component-size profile across lambda")
    print("=" * 72)
    from benchmarks import bench_fig1

    fig_rows = bench_fig1.run(cap=200, n_lambdas=8)
    for name in ("A-like", "B-like", "C-like"):
        sub = [r for r in fig_rows if r["example"] == name]
        rows.append((f"fig1/{name}", 0.0,
                     f"ncomp_range={sub[0]['n_components']}..{sub[-1]['n_components']}"))

    print("=" * 72)
    print("Kernel microbenchmarks (interpret-mode on CPU)")
    print("=" * 72)
    from benchmarks import bench_kernels

    for r in bench_kernels.run():
        rows.append((f"kernels/{r['bench']}", r["us_per_call"], ""))

    print("=" * 72)
    print("LM pillar: dry-run roofline summary (see EXPERIMENTS.md for full table)")
    print("=" * 72)
    dry = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if dry.exists():
        sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
        from repro.launch.roofline import load_records, roofline_row

        recs = [roofline_row(r) for r in load_records()]
        ok = [r for r in recs if r["status"] == "ok"]
        print(f"cells ok={len(ok)} skipped={sum(1 for r in recs if r['status']=='skipped')}")
        for r in ok:
            if r["mesh"] == "single" and r["shape"] == "train_4k":
                print(f"  {r['arch']:24s} dominant={r['dominant']:10s} "
                      f"useful={r['useful_ratio']:.2f} frac={r['roofline_frac']:.3f}")
                rows.append((f"roofline/{r['arch']}/train_4k",
                             r["compute_s"] * 1e6, f"dominant={r['dominant']}"))

    print()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

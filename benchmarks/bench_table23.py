"""Paper Tables 2-3 analog: microarray-scale lambda grids with screening.

Table 2's structure: two lambda ranges (small max-component vs large),
summed solve time across the grid, speedup vs unscreened where feasible.
Table 3's structure: examples where the FULL problem is beyond the
unscreened solver's reach — only the screened path is run, reporting the
average per-lambda time and the graph-partition cost.

Both grids now run through the engine's ``glasso_path`` — one union-find
planning pass per grid, diffed bucket plans, warm starts — and
``run_planning`` measures exactly that: incremental path planning vs naive
per-lambda replanning on the Table-3 synthetic at p >= 2000.

Synthetic microarray generator matches the paper's (n, p) regimes
qualitatively (latent-factor modules, power-law sizes); see DESIGN.md §8.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def run(log=print) -> list[dict]:
    jax.config.update("jax_enable_x64", True)
    from repro.core import (
        EngineOptions,
        glasso,
        glasso_path,
        lambda_for_max_component,
        merge_profile,
    )
    from repro.core.instrument import counts, reset
    from repro.covariance import microarray_like, sample_correlation
    import jax.numpy as jnp

    out = []

    # ---- Table-2 analog: (n=62, p~400) "example (A)"-like, two regimes
    X = microarray_like(62, 400, seed=0)
    R = np.asarray(sample_correlation(jnp.asarray(X)))
    for regime, p_max in (("small_components", 12), ("large_components", 60)):
        lam0 = lambda_for_max_component(R, p_max)
        prof = merge_profile(R)
        vals = prof["value"][1:]
        pool = np.concatenate([[lam0 * 1.001], vals[vals > lam0][:4]])
        lams = sorted(set(pool), reverse=True)[:5]
        reset("planner")
        t0 = time.perf_counter()
        results = glasso_path(
            R, lams,
            options=EngineOptions(solver="bcd", solver_opts={"tol": 1e-6}),
        )
        t_screen_total = time.perf_counter() - t0
        mx = [r.screen.max_comp for r in results]
        reused = counts("planner").get("planner.buckets_reused", 0)
        t_full_total = 0.0
        feasible_full = p_max <= 20  # unscreened full p=400 only for the cheap regime
        if feasible_full:
            for lam in lams:
                t0 = time.perf_counter()
                glasso(
                    R, float(lam), screen=False,
                    options=EngineOptions(solver="bcd",
                                          solver_opts={"tol": 1e-6}),
                )
                t_full_total += time.perf_counter() - t0
        rec = {
            "table": "2", "p": 400, "regime": regime,
            "avg_max_component": float(np.mean(mx)),
            "grid_size": len(lams),
            "with_screen_s": round(t_screen_total, 3),
            "without_screen_s": round(t_full_total, 3) if feasible_full else None,
            "speedup": (round(t_full_total / max(t_screen_total, 1e-9), 2)
                        if feasible_full else None),
            "buckets_reused": int(reused),
        }
        out.append(rec)
        log(f"Table2 {regime}: avg max comp {rec['avg_max_component']:.1f} "
            f"path {rec['with_screen_s']}s (buckets reused {reused}) "
            f"full {rec['without_screen_s']} speedup {rec['speedup']}")

    # ---- Table-3 analog: larger p where only the screened path is viable
    for name, n, p in (("B-like", 100, 1200), ("C-like", 80, 2400)):
        X = microarray_like(n, p, seed=1)
        R = np.asarray(sample_correlation(jnp.asarray(X)))
        lam500 = lambda_for_max_component(R, 100)
        prof = merge_profile(R)
        vals = prof["value"][1:]
        lams = vals[vals > lam500][:3]
        if len(lams) == 0:
            lams = [lam500 * 1.01]
        t0 = time.perf_counter()
        results = glasso_path(
            R, [float(v) for v in lams],
            options=EngineOptions(solver="bcd", solver_opts={"tol": 1e-6}),
        )
        total = time.perf_counter() - t0
        parts = [r.screen.seconds for r in results]
        mx = [r.screen.max_comp for r in results]
        rec = {
            "table": "3", "example": name, "n": n, "p": p,
            "grid_size": len(lams),
            "avg_max_component": float(np.mean(mx)),
            "avg_solve_s": round(total / len(lams), 3),
            "avg_partition_s": round(float(np.mean(parts)), 5),
        }
        out.append(rec)
        log(f"Table3 {name} p={p}: avg max comp {rec['avg_max_component']:.0f} "
            f"avg solve {rec['avg_solve_s']}s partition {rec['avg_partition_s']}s")
    return out


def run_planning(p: int = 2400, n: int = 80, n_lambdas: int = 20, log=print) -> dict:
    """Incremental path planning vs per-lambda replanning (NO solving).

    The acceptance target for the engine planner: one union-find pass +
    diffed plans must beat n_lambdas x (threshold + union-find + re-pad)
    on the Table-3 C-like synthetic at p >= 2000."""
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp

    from repro.core import lambda_for_max_component, merge_profile, thresholded_components
    from repro.core.blocks import build_plan
    from repro.core.instrument import count, reset
    from repro.covariance import microarray_like, sample_correlation
    from repro.engine.planner import plan_path

    X = microarray_like(n, p, seed=1)
    R = np.asarray(sample_correlation(jnp.asarray(X)))
    lam0 = lambda_for_max_component(R, 100)
    vals = merge_profile(R)["value"][1:]
    grid = vals[vals > lam0]
    lams = [float(v) for v in grid[:: max(1, len(grid) // n_lambdas)][:n_lambdas]]

    reset("partition")
    t0 = time.perf_counter()
    path = plan_path(R, lams)
    t_inc = time.perf_counter() - t0
    passes = count("partition.unionfind_passes")

    t0 = time.perf_counter()
    for lam in lams:
        labels, _ = thresholded_components(R, lam)
        build_plan(R, lam, labels)
    t_naive = time.perf_counter() - t0

    rec = {
        "p": p, "n_lambdas": len(lams),
        "incremental_s": round(t_inc, 3),
        "replanning_s": round(t_naive, 3),
        "speedup": round(t_naive / max(t_inc, 1e-9), 2),
        "unionfind_passes": int(passes),
        "steps": len(path.steps),
    }
    log(f"planning p={p} grid={len(lams)}: incremental {rec['incremental_s']}s "
        f"({passes} union-find pass) vs replanning {rec['replanning_s']}s "
        f"-> {rec['speedup']}x")
    return rec


if __name__ == "__main__":
    run()
    run_planning()

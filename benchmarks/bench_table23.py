"""Paper Tables 2-3 analog: microarray-scale lambda grids with screening.

Table 2's structure: two lambda ranges (small max-component vs large),
summed solve time across the grid, speedup vs unscreened where feasible.
Table 3's structure: examples where the FULL problem is beyond the
unscreened solver's reach — only the screened path is run, reporting the
average per-lambda time and the graph-partition cost.

Synthetic microarray generator matches the paper's (n, p) regimes
qualitatively (latent-factor modules, power-law sizes); see DESIGN.md §8.
"""

from __future__ import annotations

import time

import jax
import numpy as np


def run(log=print) -> list[dict]:
    jax.config.update("jax_enable_x64", True)
    from repro.core import glasso, lambda_for_max_component, merge_profile
    from repro.core.screening import thresholded_components
    from repro.covariance import microarray_like, sample_correlation
    import jax.numpy as jnp

    out = []

    # ---- Table-2 analog: (n=62, p~400) "example (A)"-like, two regimes
    X = microarray_like(62, 400, seed=0)
    R = np.asarray(sample_correlation(jnp.asarray(X)))
    for regime, p_max in (("small_components", 12), ("large_components", 60)):
        lam0 = lambda_for_max_component(R, p_max)
        prof = merge_profile(R)
        vals = prof["value"][1:]
        lams = sorted(set(np.concatenate([[lam0 * 1.001], vals[vals > lam0][:4]])), reverse=True)[:5]
        t_screen_total, t_full_total, mx = 0.0, 0.0, []
        for lam in lams:
            t0 = time.perf_counter()
            r = glasso(R, float(lam), solver="bcd", tol=1e-6)
            t_screen_total += time.perf_counter() - t0
            mx.append(r.screen.max_comp)
        feasible_full = p_max <= 20  # unscreened full p=400 only for the cheap regime
        if feasible_full:
            for lam in lams:
                t0 = time.perf_counter()
                glasso(R, float(lam), solver="bcd", screen=False, tol=1e-6)
                t_full_total += time.perf_counter() - t0
        rec = {
            "table": "2", "p": 400, "regime": regime,
            "avg_max_component": float(np.mean(mx)),
            "grid_size": len(lams),
            "with_screen_s": round(t_screen_total, 3),
            "without_screen_s": round(t_full_total, 3) if feasible_full else None,
            "speedup": round(t_full_total / max(t_screen_total, 1e-9), 2) if feasible_full else None,
        }
        out.append(rec)
        log(f"Table2 {regime}: avg max comp {rec['avg_max_component']:.1f} "
            f"screen {rec['with_screen_s']}s full {rec['without_screen_s']} "
            f"speedup {rec['speedup']}")

    # ---- Table-3 analog: larger p where only the screened path is viable
    for name, n, p in (("B-like", 100, 1200), ("C-like", 80, 2400)):
        X = microarray_like(n, p, seed=1)
        R = np.asarray(sample_correlation(jnp.asarray(X)))
        lam500 = lambda_for_max_component(R, 100)
        prof = merge_profile(R)
        vals = prof["value"][1:]
        lams = vals[vals > lam500][:3]
        if len(lams) == 0:
            lams = [lam500 * 1.01]
        times, parts, mx = [], [], []
        for lam in lams:
            labels, stats = thresholded_components(R, float(lam))
            parts.append(stats.seconds)
            t0 = time.perf_counter()
            r = glasso(R, float(lam), solver="bcd", tol=1e-6)
            times.append(time.perf_counter() - t0)
            mx.append(r.screen.max_comp)
        rec = {
            "table": "3", "example": name, "n": n, "p": p,
            "grid_size": len(lams),
            "avg_max_component": float(np.mean(mx)),
            "avg_solve_s": round(float(np.mean(times)), 3),
            "avg_partition_s": round(float(np.mean(parts)), 5),
        }
        out.append(rec)
        log(f"Table3 {name} p={p}: avg max comp {rec['avg_max_component']:.0f} "
            f"avg solve {rec['avg_solve_s']}s partition {rec['avg_partition_s']}s")
    return out


if __name__ == "__main__":
    run()

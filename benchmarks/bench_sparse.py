"""Sparse-native result benchmark: kill the O(p^2) assembly wall.

The sparse result path's acceptance claims are MEMORY claims, so (like
bench_stream / bench_giant) each arm runs in its own subprocess and reports
``ru_maxrss``.  The workload is bench_stream's power-law data matrix —
factor-correlated 8-column groups in the leading tiles, so at LAM the
screened graph is a few hundred small components in a sea of isolated
vertices: the regime where the SOLVE is trivial and the (p, p) dense result
is the entire footprint.  Three arms:

  * ``dense``   from-data solve with ``output="dense"`` — the historical
                result path: assemble_dense allocates the (p, p) Theta
                (p=16k f64: 2 GiB) even though nnz is a few 10^4;
  * ``sparse``  same solve with ``output="auto"`` — which must RESOLVE to
                sparse at p=16k (> AUTO_SPARSE_P), assemble with zero (p, p)
                allocation, and verify via the sparse-aware KKT (the
                ``result.bytes_peak`` watermark rides along as the
                self-reported cross-check);
  * ``huge``    p=1e5 from-data under a hard RLIMIT_AS memory budget the
                dense path CANNOT meet (Theta alone would be 80 GB) — the
                end-to-end "p >= 1e5 completes" acceptance fact.

Each arm then derives the support graph from its result — the step every
consumer performs.  Dense, that scans the (p, p) Theta (np.abs writes a
full f64 temp, committing the pages the lazily-zeroed allocation deferred);
sparse, it reads the per-block nonzeros.  ru_maxrss therefore measures what
CONSUMING each representation costs, not just holding an untouched
zero-page mapping.

Cross-arm equality is a HARD assert: both arms dump their result as COO
triplets and the parent compares them entry-for-entry (same screen, same
solve, only the container differs — the dumps must match exactly).  Zero
router fallbacks is asserted in-arm.  The joint assembler's dense-vs-sparse
wall ratio is measured in-process on a p=2400, K=4 plan (the assembly-bound
slice of bench_joint's shared-solve workload).

``--json FILE`` writes the record; ``--check BASELINE`` fails (exit 1) when
the sparse/dense peak-RSS ratio, the huge-arm RSS, or the joint assembly
speedup regresses >20% against the committed baseline.  ``--smoke`` is the
fast in-process equivalence arm for the CI gate.

    PYTHONPATH=src python -m benchmarks.bench_sparse [--smoke] \
        [--json BENCH_sparse.json] [--check benchmarks/baseline_sparse.json]
"""

from __future__ import annotations

import argparse
import json
import os
import resource
import subprocess
import sys
import time

import numpy as np

P = 16000
P_HUGE = 100_000
N_ROWS = 192
LAM = 0.40
TILE = 2048
HUGE_BUDGET_MB = 8192   # RLIMIT_AS for the huge arm
HUGE_RSS_CAP_MB = 4096  # parent-side acceptance on the huge arm's peak RSS
RSS_RATIO_CAP = 0.35    # sparse arm RSS must be well under the dense arm's


def _workload(p: int, seed: int = 0) -> np.ndarray:
    """(n, p) data, bench_stream's recipe with a stronger factor: groups of
    8 columns in the leading tiles over power-law column scales.  The 0.9
    loading makes each group near-equicorrelated (intra-group |S_ij| ~ 0.8),
    so at LAM the group solutions are fully dense and the chordal clique-
    tree candidates verify — the zero-fallback regime the acceptance
    asserts; everything else is isolated or tiny — the sparse-result
    regime."""
    rng = np.random.default_rng(seed)
    n = N_ROWS
    scales = 0.04 + 0.96 * (1.0 - np.arange(p) / p) ** 4
    X = rng.standard_normal((n, p)) * scales
    n_groups = max(2, p // 400)
    f = rng.standard_normal((n, n_groups))
    for g in range(n_groups):
        cols = slice(g * 8, g * 8 + 8)
        X[:, cols] = 0.9 * f[:, [g]] + 0.44 * X[:, cols] / scales[cols]
    return X


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _dump_coo(Theta, path: str) -> int:
    """COO triplets of a result (dense array or SparseTheta), row-col sorted
    — the cross-arm equality artifact."""
    from repro.core.sparse import SparseTheta

    if isinstance(Theta, SparseTheta):
        r, c, v = Theta.to_coo()
    else:
        r, c = np.nonzero(Theta)
        v = Theta[r, c]
    order = np.lexsort((c, r))
    np.savez(path, rows=r[order], cols=c[order], vals=v[order])
    return int(len(r))


def run_arm(arm: str, p: int, seed: int = 0) -> dict:
    """One arm in THIS process (the parent spawns each in a subprocess)."""
    if arm == "huge":
        # the budget the dense path cannot meet: its Theta alone is
        # p^2 * 8 = 80 GB at p=1e5
        budget = HUGE_BUDGET_MB * 2**20
        resource.setrlimit(resource.RLIMIT_AS, (budget, budget))
    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.core import EngineOptions, glasso
    from repro.core.instrument import counts, reset
    from repro.core.solvers.kkt import kkt_residual_sparse
    from repro.core.sparse import SparseTheta

    X = _workload(p, seed)
    stream = {"tile": TILE, "chunk": 64}
    reset("")
    t0 = time.perf_counter()
    if arm == "dense":
        res = glasso(X=X, lam=LAM, from_data=True, stream=stream,
                     options=EngineOptions(output="dense",
                                           solver_opts={"tol": 1e-9}))
        assert not isinstance(res.Theta, SparseTheta)
    elif arm in ("sparse", "huge"):
        # output="auto": the arm PROVES the auto threshold fires at p > 8192
        res = glasso(X=X, lam=LAM, from_data=True, stream=stream,
                     options=EngineOptions(output="auto",
                                           solver_opts={"tol": 1e-9}))
        assert res.output == "sparse", f"auto did not resolve sparse at p={p}"
    else:
        raise ValueError(arm)
    seconds = time.perf_counter() - t0
    fallbacks = sum(counts("router.fallback.").values())
    assert fallbacks == 0, f"{arm}: {fallbacks} router fallbacks on the bench"
    # the result is FOR something: every consumer reads the support graph.
    # Dense, that is the O(p^2) wall this bench measures (np.abs over the
    # (p, p) Theta materializes every page); sparse, it comes from the
    # per-block nonzeros.  Same call, both arms.
    edges = res.support_edges()
    rec = {
        "arm": arm,
        "p": p,
        "n_components": int(res.screen.n_components),
        "nnz": int(
            res.Theta.nnz if isinstance(res.Theta, SparseTheta)
            else np.count_nonzero(res.Theta)
        ),
        "solve_seconds": round(res.solve_seconds, 3),
        "assemble_seconds": round(res.assemble_seconds, 4),
        "screen_seconds": round(res.screen_seconds, 3),
        "bytes_peak_mb": round(res.bytes_peak / 2**20, 2),
        "n_edges": int(len(edges)),
        "output": res.output,
    }
    if arm != "huge":
        path = os.path.join(
            os.environ.get("TMPDIR", "/tmp"), f"bench_sparse_{arm}_{p}.npz"
        )
        rec["coo_file"] = path
        rec["coo_nnz"] = _dump_coo(res.Theta, path)
    if arm in ("sparse", "huge"):
        # sparse-aware KKT: per-block residuals, never a (p, p) buffer —
        # proven by the result.bytes_peak watermark staying << p^2 * 8
        reset("result.")
        rec["kkt_residual"] = float(
            kkt_residual_sparse(_rematerialize(X, res), res.Theta, LAM)
        )
        peak = counts("result.").get("result.bytes_peak", 0)
        dense_bytes = p * p * 8
        assert 0 < peak < dense_bytes, (
            f"sparse KKT touched a dense-scale buffer: {peak} vs {dense_bytes}"
        )
        rec["kkt_bytes_peak_mb"] = round(peak / 2**20, 3)
    rec.update(
        {"seconds": round(time.perf_counter() - t0, 2),
         "total_seconds": round(seconds, 2),
         "rss_mb": round(_rss_mb(), 1)}
    )
    return rec


def _rematerialize(X: np.ndarray, res):
    """The KKT check needs S through the gather protocol; rebuild the
    materialized per-component covariance from X and the result's labels
    (the dense (p, p) S must never exist in the sparse arms)."""
    from repro.stream.materialize import materialize_components

    n = X.shape[0]
    mu = X.mean(axis=0)
    diag = ((X - mu) ** 2).sum(axis=0) / n
    return materialize_components(X, mu, diag, res.labels)


def _spawn_arm(arm: str, p: int) -> dict:
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sparse", "--arm", arm,
         "--p", str(p)],
        capture_output=True, text=True, check=True,
    )
    return json.loads(out.stdout.strip().splitlines()[-1])


def _assert_coo_equal(dense_rec: dict, sparse_rec: dict) -> None:
    """sparse == dense, entry for entry — the tentpole's hard equivalence."""
    d_path = dense_rec.pop("coo_file")
    s_path = sparse_rec.pop("coo_file")
    with np.load(d_path) as d, np.load(s_path) as s:
        equal = (
            np.array_equal(d["rows"], s["rows"])
            and np.array_equal(d["cols"], s["cols"])
            and np.array_equal(d["vals"], s["vals"])
        )
        n_d, n_s = len(d["rows"]), len(s["rows"])
    os.unlink(d_path)
    os.unlink(s_path)
    if not equal:
        raise AssertionError(
            f"sparse result != dense result (dense nnz={n_d}, sparse "
            f"nnz={n_s})"
        )


def _joint_assemble_ratio(reps: int = 5) -> dict:
    """Dense vs sparse JOINT assembly wall on a p=2400, K=4 plan — the
    assembly-bound slice of bench_joint's shared-solve workload.  The
    'solutions' are the plan's own padded stacks (assembly cost does not
    depend on their values), so this isolates exactly the stage the sparse
    path removes: the (K, p, p) = 184 MB allocation + scatter."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.covariance import paper_synthetic
    from repro.joint.blocks import assemble_joint, assemble_joint_sparse
    from repro.joint.engine import JointEngine

    K, p1, nblk = 4, 16, 150
    Ss = [paper_synthetic(nblk, p1, seed=7 + k) for k in range(K)]
    lam1 = 0.11
    engine = JointEngine()
    labels, _ = engine.screen(Ss, lam1, 0.0, penalty="group")
    plan = engine.plan(Ss, lam1, 0.0, labels, penalty="group")
    sols = [np.asarray(b.blocks) for b in plan.buckets]
    t_dense = min(
        _timed(lambda: assemble_joint(plan, sols, Ss)) for _ in range(reps)
    )
    t_sparse = min(
        _timed(lambda: assemble_joint_sparse(plan, sols, Ss))
        for _ in range(reps)
    )
    return {
        "joint_p": p1 * nblk,
        "joint_K": K,
        "joint_assemble_dense_s": round(t_dense, 6),
        "joint_assemble_sparse_s": round(t_sparse, 6),
        "joint_assemble_speedup": round(t_dense / max(t_sparse, 1e-6), 2),
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(p: int = P, p_huge: int = P_HUGE, log=print) -> dict:
    dense = _spawn_arm("dense", p)
    sparse = _spawn_arm("sparse", p)
    _assert_coo_equal(dense, sparse)
    huge = _spawn_arm("huge", p_huge)
    rec = {
        "p": p,
        "p_huge": p_huge,
        "lam": LAM,
        "nnz": sparse["nnz"],
        "n_components": sparse["n_components"],
        "dense_rss_mb": dense["rss_mb"],
        "sparse_rss_mb": sparse["rss_mb"],
        "rss_ratio": round(sparse["rss_mb"] / dense["rss_mb"], 4),
        "dense_bytes_peak_mb": dense["bytes_peak_mb"],
        "sparse_bytes_peak_mb": sparse["bytes_peak_mb"],
        "dense_assemble_s": dense["assemble_seconds"],
        "sparse_assemble_s": sparse["assemble_seconds"],
        "kkt_residual": sparse["kkt_residual"],
        "kkt_bytes_peak_mb": sparse["kkt_bytes_peak_mb"],
        "huge_rss_mb": huge["rss_mb"],
        "huge_budget_mb": HUGE_BUDGET_MB,
        "huge_nnz": huge["nnz"],
        "huge_seconds": huge["total_seconds"],
        "huge_bytes_peak_mb": huge["bytes_peak_mb"],
        "dense_seconds": dense["total_seconds"],
        "sparse_seconds": sparse["total_seconds"],
    }
    rec.update(_joint_assemble_ratio())
    log(
        f"p={p}: dense RSS {dense['rss_mb']:.0f}MB "
        f"(Theta {dense['bytes_peak_mb']:.0f}MB) vs sparse RSS "
        f"{sparse['rss_mb']:.0f}MB ({sparse['bytes_peak_mb']:.1f}MB resident"
        f") — ratio {rec['rss_ratio']}; nnz={rec['nnz']}, COO equal; "
        f"kkt={rec['kkt_residual']:.2e} in {rec['kkt_bytes_peak_mb']}MB peak"
    )
    log(
        f"p={p_huge} under {HUGE_BUDGET_MB}MB RLIMIT_AS: completed in "
        f"{huge['total_seconds']}s, RSS {huge['rss_mb']:.0f}MB, "
        f"nnz={huge['nnz']} (dense Theta would be "
        f"{p_huge * p_huge * 8 / 2**30:.0f}GB)"
    )
    log(
        f"joint assembly p={rec['joint_p']} K={rec['joint_K']}: dense "
        f"{rec['joint_assemble_dense_s']}s vs sparse "
        f"{rec['joint_assemble_sparse_s']}s "
        f"({rec['joint_assemble_speedup']}x)"
    )
    if rec["rss_ratio"] > RSS_RATIO_CAP:
        raise AssertionError(
            f"sparse arm RSS ratio {rec['rss_ratio']} > {RSS_RATIO_CAP}"
        )
    if huge["rss_mb"] > HUGE_RSS_CAP_MB:
        raise AssertionError(
            f"huge arm peak RSS {huge['rss_mb']}MB > {HUGE_RSS_CAP_MB}MB"
        )
    if rec["joint_assemble_speedup"] < 1.0:
        raise AssertionError(
            "sparse joint assembly slower than dense: "
            f"{rec['joint_assemble_speedup']}x"
        )
    return rec


def smoke(log=print) -> None:
    """In-process sparse == dense equivalence on the from-data path (the CI
    gate's cheap arm: same code paths, small p)."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.core import EngineOptions, glasso
    from repro.core.solvers.kkt import kkt_residual_sparse
    from repro.core.sparse import SparseTheta

    p = 1600
    X = _workload(p, seed=3)
    stream = {"tile": 512, "chunk": 64}
    rd = glasso(X=X, lam=LAM, from_data=True, stream=stream,
                options=EngineOptions(output="dense",
                                      solver_opts={"tol": 1e-9}))
    rs = glasso(X=X, lam=LAM, from_data=True, stream=stream,
                options=EngineOptions(output="sparse",
                                      solver_opts={"tol": 1e-9}))
    assert isinstance(rs.Theta, SparseTheta)
    assert np.array_equal(rs.Theta.toarray(), rd.Theta), "sparse != dense"
    assert rs.Theta.nnz == np.count_nonzero(rd.Theta)
    assert rs.bytes_peak < rd.bytes_peak, (rs.bytes_peak, rd.bytes_peak)
    res = kkt_residual_sparse(_rematerialize(X, rs), rs.Theta, LAM)
    assert res < 1e-6 * max(1.0, float(np.abs(X).max()) ** 2), res
    log(
        f"sparse smoke OK: p={p}, nnz={rs.Theta.nnz}, sparse bytes "
        f"{rs.bytes_peak / 2**20:.2f}MB vs dense "
        f"{rd.bytes_peak / 2**20:.1f}MB, kkt={res:.2e}"
    )


def check(rec: dict, baseline_path: str, log=print) -> int:
    """CI gate: correctness facts are hard asserts in run(); this gates the
    QUANTITIES against the committed baseline (>20% regression fails)."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    max_ratio = base["rss_ratio"] * 1.2
    if rec["rss_ratio"] > max_ratio:
        failures.append(
            f"sparse/dense RSS ratio {rec['rss_ratio']} > {max_ratio:.3f} "
            f"(baseline {base['rss_ratio']} + 20%)"
        )
    max_huge = base["huge_rss_mb"] * 1.2
    if rec["huge_rss_mb"] > max_huge:
        failures.append(
            f"huge-arm RSS {rec['huge_rss_mb']}MB > {max_huge:.0f}MB "
            f"(baseline {base['huge_rss_mb']} + 20%)"
        )
    # the sparse assembly wall sits at the timer noise floor, so its speedup
    # spans orders of magnitude run-to-run; gate with an absolute floor once
    # the baseline is far past it (a real regression — sparse assembly going
    # dense-scale — lands near 1x)
    min_speedup = min(base["joint_assemble_speedup"] * 0.8, 20.0)
    if rec["joint_assemble_speedup"] < min_speedup:
        failures.append(
            f"joint assembly speedup {rec['joint_assemble_speedup']} < "
            f"{min_speedup:.2f} (baseline {base['joint_assemble_speedup']})"
        )
    for msg in failures:
        log(f"REGRESSION: {msg}")
    if not failures:
        log(f"sparse bench within baseline ({baseline_path})")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arm", choices=("dense", "sparse", "huge"), default=None)
    ap.add_argument("--p", type=int, default=P)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--check", default=None)
    args = ap.parse_args()

    if args.arm:  # subprocess mode: one arm, JSON on stdout
        print(json.dumps(run_arm(args.arm, args.p)))
        return
    if args.smoke:
        smoke()
        return
    rec = run(args.p)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        sys.exit(check(rec, args.check))


if __name__ == "__main__":
    main()

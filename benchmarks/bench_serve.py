"""Serving control-plane benchmark: closed-loop mixed-tenant traffic
through ``GlassoServer.submit(spec, meta=...)``.

Four concurrent client populations drive one server (the control plane's
acceptance workload — every spec kind, both SLO classes, one throttled
tenant):

  * ``web``    interactive tenant, closed-loop: small dense requests
               alternating a fast-path-able shape (singletons after
               screening — solved at admission) with an iterative shape
               (rides the queue + batching window).  Its p50/p99 END-TO-END
               latency is the bench's headline number.
  * ``etl``    batch-SLO tenant, closed-loop: dense iterative requests that
               coalesce behind (and must YIELD to) the interactive class.
  * ``data``   batch-SLO tenant issuing from-data (``DataSpec``) requests —
               the streamed screen runs on the client thread, the solve
               coalesces with ``etl``'s buckets.
  * ``joint``  batch-SLO tenant issuing K-class ``JointSpec`` requests.
  * ``noisy``  a quota-throttled tenant blasting open-loop traffic at a
               token bucket sized far below its arrival rate: most of its
               submissions MUST be rejected with the typed ``Overload``
               (reason="quota") — per-tenant isolation under pressure, and
               the rejected fraction is recorded.

A final phase re-submits one identical dense spec against the server's
result cache (``result_cache=``): the repeat must hit
(``serve.cache.hits``) and return the finished result with zero planner
work.

Hard in-run asserts: every admitted future resolves; interactive latency
strictly observed (p99 recorded); noisy-tenant rejections > 0 with zero
rejections for the other tenants; cache hits fire.  ``--json FILE`` writes
the record; ``--check BASELINE`` fails (exit 1) when interactive p99 or
total throughput regresses >20% against the committed baseline (with
absolute noise floors — CI timers are coarse).  ``--smoke`` is the fast
in-process control-plane gate for CI.

OBSERVABILITY PHASES (DESIGN.md Section 17): the measured loop's web
latencies are re-derived SERVER-SIDE from the ``serve.request_seconds``
histogram (``REGISTRY.quantile(..., tenant="web", slo="interactive")``)
with an exact count reconciliation and a generous divergence gate against
the client-side p99 (the histogram estimate is a bucket upper bound, one
1.5x ratio wide).  A traced ``submit(PathSpec)`` request must produce a
span tree that reconciles with its own wall time and exports valid Chrome
trace JSON (written to ``TRACE_submit_path.json`` — a CI artifact); the
same path spec through ``EngineOptions(trace=False)`` measures tracing
overhead, gated at 5% (+10 ms slack for coarse CI timers).

    PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] \
        [--json BENCH_serve.json] [--check benchmarks/baseline_serve.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import threading
import time

import numpy as np

# closed-loop request counts per client population
N_WEB = 40          # interactive dense (per client; 2 clients)
N_ETL = 12          # batch dense
N_DATA = 6          # batch from-data
N_JOINT = 6         # batch joint
N_NOISY = 40        # open-loop blast against the throttled bucket
NOISY_RATE = 2.0    # tokens/s — far below the blast's arrival rate
NOISY_BURST = 3.0

# absolute noise floors for the CI gate: a laptop-class run sits far below
# these; only a real serving regression (lost fast path, queue convoy) can
# push p99/throughput past baseline*1.2 AND the floor simultaneously
P99_FLOOR_S = 0.25
THROUGHPUT_FLOOR = 0.5  # req/s

# tracing-overhead gate: traced median <= untraced * CAP + SLACK (the slack
# absorbs coarse shared-CI timers on a tens-of-ms path solve)
TRACE_OVERHEAD_CAP = 1.05
TRACE_OVERHEAD_SLACK_S = 0.010
# server-side histogram p99 vs client-side p99: the estimate is the upper
# bound of a 1.5x-wide bucket and the client adds submit/wakeup overhead,
# so the two only have to agree within a factor of 2 (+50 ms)
P99_DIVERGENCE_FACTOR = 2.0
P99_DIVERGENCE_SLACK_S = 0.05


def _dense_cases():
    """Two small dense shapes: one all-singleton at its lambda (fast path)
    and one mid-lambda 3x8 blocks (iterative, queue + coalescing)."""
    from repro.covariance import lambda_interval_for_k, paper_synthetic

    S_it = paper_synthetic(3, 8, seed=11)
    lo, hi = lambda_interval_for_k(S_it, 3)
    lam_it = float(0.5 * (lo + hi))
    S_fp = paper_synthetic(3, 8, seed=12)
    off = np.abs(S_fp - np.diag(np.diag(S_fp)))
    lam_fp = float(off.max() * 1.01)  # everything thresholds away
    return (S_fp, lam_fp), (S_it, lam_it)


def _data_case(seed=21):
    rng = np.random.default_rng(seed)
    p = 24
    X = rng.standard_normal((48, p)) * (0.1 + rng.random(p))
    return X, 0.08


def _joint_case():
    Ss = [np.eye(12) + 0.5 * (1 - np.eye(12)) * (0.9 ** k) for k in range(2)]
    return Ss, 0.35, 0.05


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def run(log=print) -> dict:
    import jax

    jax.config.update("jax_enable_x64", True)

    from repro.core.instrument import count, reset
    from repro.engine.options import EngineOptions
    from repro.launch.control_plane import (
        DataSpec,
        DenseSpec,
        JointSpec,
        Overload,
        PathSpec,
        Quota,
        RequestMeta,
    )
    from repro.launch.serve_glasso import GlassoServer
    from repro.obs.metrics import REGISTRY

    (S_fp, lam_fp), (S_it, lam_it) = _dense_cases()
    X, lam_x = _data_case()
    Ss, lam1, lam2 = _joint_case()

    options = EngineOptions(solver="bcd", solver_opts={"tol": 1e-7})
    quotas = {"noisy": Quota(rate=NOISY_RATE, burst=NOISY_BURST)}
    lat: dict[str, list[float]] = {"web": [], "etl": [], "data": [], "joint": []}
    noisy = {"ok": 0, "rejected": 0}
    errors: list[BaseException] = []
    lock = threading.Lock()

    def record(population, seconds):
        with lock:
            lat[population].append(seconds)

    reset("serve")
    reset("joint")
    with GlassoServer(
        options=options, max_delay=0.002, quotas=quotas, result_cache=8
    ) as server:
        # warm the compiled cache so the measured loop is the steady state
        # every serving claim is about (first compile would dominate p99)
        server.submit(DenseSpec(S_fp, lam_fp)).result(timeout=600)
        server.submit(DenseSpec(S_it, lam_it)).result(timeout=600)
        server.submit(
            JointSpec(Ss=Ss, lam1=lam1, lam2=lam2),
            meta=RequestMeta(slo="batch"),
        ).result(timeout=600)
        reset("serve")

        # per-request lambda perturbations (partition-preserving): identical
        # payloads would collapse into the result cache and the bench would
        # measure nothing but lookups
        def _jig(lam, i, k):
            return float(lam * (1.0 - 1e-7 * (1 + i * 1000 + k)))

        def web_client(i):
            meta = RequestMeta(tenant="web", slo="interactive")
            for k in range(N_WEB):
                S, lam = (S_fp, lam_fp) if k % 2 == 0 else (S_it, lam_it)
                t0 = time.perf_counter()
                server.submit(
                    DenseSpec(S, _jig(lam, i, k)), meta=meta
                ).result(timeout=600)
                record("web", time.perf_counter() - t0)

        def etl_client():
            # bursts of 3 in-flight requests: same padded size, different
            # lambdas — the shape the batcher coalesces into one dispatch
            meta = RequestMeta(tenant="etl", slo="batch")
            for k in range(0, N_ETL, 3):
                pending = []
                for j in range(3):
                    t0 = time.perf_counter()
                    f = server.submit(
                        DenseSpec(S_it, _jig(lam_it, 7, k + j)), meta=meta
                    )
                    pending.append((t0, f))
                for t0, f in pending:
                    f.result(timeout=600)
                    record("etl", time.perf_counter() - t0)

        def data_client():
            meta = RequestMeta(tenant="etl", slo="batch")
            for k in range(N_DATA):
                t0 = time.perf_counter()
                server.submit(
                    DataSpec(
                        X, _jig(lam_x, 8, k), stream={"tile": 12, "chunk": 24}
                    ),
                    meta=meta,
                ).result(timeout=600)
                record("data", time.perf_counter() - t0)

        def joint_client():
            meta = RequestMeta(tenant="joint", slo="batch")
            for k in range(N_JOINT):
                t0 = time.perf_counter()
                server.submit(
                    JointSpec(Ss=Ss, lam1=_jig(lam1, 9, k), lam2=lam2),
                    meta=meta,
                ).result(timeout=600)
                record("joint", time.perf_counter() - t0)

        def noisy_client():
            meta = RequestMeta(tenant="noisy", slo="interactive")
            for k in range(N_NOISY):
                # perturb lambda per request: identical payloads would hit
                # the result cache, which by design never charges the quota
                lam_k = lam_fp * (1.0 - 1e-7 * (k + 1))
                try:
                    server.submit(DenseSpec(S_fp, lam_k), meta=meta).result(
                        timeout=600
                    )
                    with lock:
                        noisy["ok"] += 1
                except Overload as e:
                    assert e.reason == "quota", e.reason
                    with lock:
                        noisy["rejected"] += 1

        def guard(fn, *a):
            def inner():
                try:
                    fn(*a)
                except BaseException as e:  # noqa: BLE001 - surfaced below
                    with lock:
                        errors.append(e)

            return inner

        clients = (
            [threading.Thread(target=guard(web_client, i)) for i in range(2)]
            + [
                threading.Thread(target=guard(fn))
                for fn in (etl_client, data_client, joint_client, noisy_client)
            ]
        )
        t0 = time.perf_counter()
        for t in clients:
            t.start()
        for t in clients:
            t.join(timeout=600)
        wall = time.perf_counter() - t0

        if errors:
            raise errors[0]

        # result-cache phase: the identical spec must hit
        hits0 = count("serve.cache.hits")
        server.submit(DenseSpec(S_it.copy(), lam_it)).result(timeout=600)
        t0 = time.perf_counter()
        server.submit(DenseSpec(S_it.copy(), lam_it)).result(timeout=600)
        cache_hit_s = time.perf_counter() - t0
        cache_hits = count("serve.cache.hits") - hits0

        # server-side latency: the same web/interactive p99 the clients
        # measured, re-derived from the serve.request_seconds histogram
        # (count reconciliation is exact; the quantile is a bucket upper
        # bound, so the cross-check gate is generous by design)
        n_web = len(lat["web"])
        hist_count = REGISTRY.histogram_totals(
            "serve.request_seconds", tenant="web", slo="interactive"
        )["count"]
        assert hist_count == n_web, (
            f"histogram saw {hist_count} web/interactive requests, "
            f"clients measured {n_web}"
        )
        server_p99 = REGISTRY.quantile(
            "serve.request_seconds", 0.99, tenant="web", slo="interactive"
        )
        client_p99 = _percentile(lat["web"], 99)
        lo_gate = client_p99 / P99_DIVERGENCE_FACTOR - P99_DIVERGENCE_SLACK_S
        hi_gate = client_p99 * P99_DIVERGENCE_FACTOR + P99_DIVERGENCE_SLACK_S
        assert lo_gate <= server_p99 <= hi_gate, (
            f"server-side p99 {server_p99:.4f}s diverges from client-side "
            f"{client_p99:.4f}s (gate [{lo_gate:.4f}, {hi_gate:.4f}])"
        )
        metrics_text = server.metrics()
        assert "serve_request_seconds_bucket" in metrics_text, (
            "metrics() exposition is missing the latency histogram"
        )

        # traced PathSpec: the span tree must reconcile with wall time and
        # export valid Chrome trace JSON (a CI artifact)
        path_spec = dict(grid=4, criterion="ebic", n=200)
        sel = server.submit(
            PathSpec(S=S_it, **path_spec),
            meta=RequestMeta(tenant="web", slo="batch"),
        ).result(timeout=600)
        tr = sel.result.trace
        assert tr is not None and tr.name == "serve.request", (
            "served path result carried no request trace"
        )
        child_sum = sum(sp.seconds for sp in tr.children(tr.root_id))
        assert child_sum <= tr.wall_seconds + 1e-3, (
            f"direct-child span sum {child_sum:.4f}s exceeds request wall "
            f"{tr.wall_seconds:.4f}s"
        )
        root = tr.root
        for sp in tr.spans:
            assert sp.t0 >= root.t0 - 1e-9 and sp.t1 <= root.t1 + 1e-9, (
                f"span {sp.name} escapes the request window"
            )
        chrome = tr.to_chrome_json("TRACE_submit_path.json")
        events = json.loads(chrome)["traceEvents"]
        assert events and all(
            e["ph"] == "M" or (e["ts"] >= 0 and e["dur"] >= 0)
            for e in events
        ), "Chrome trace export produced malformed events"
        trace_spans = len(tr.spans)

    # tracing-overhead arms: the identical path spec through a traced and
    # an untraced server (compiled cache is process-global and warm, so
    # the arms differ only by span recording)
    def _path_arm(trace_flag):
        arm_opts = EngineOptions(
            solver="bcd", solver_opts={"tol": 1e-7}, trace=trace_flag
        )
        with GlassoServer(options=arm_opts) as srv:
            srv.submit(PathSpec(S=S_it, **path_spec)).result(timeout=600)
            samples = []
            for _ in range(5):
                t0 = time.perf_counter()
                srv.submit(PathSpec(S=S_it, **path_spec)).result(timeout=600)
                samples.append(time.perf_counter() - t0)
        return float(np.median(samples))

    untraced_path_s = _path_arm(False)
    traced_path_s = _path_arm(True)
    overhead_cap = untraced_path_s * TRACE_OVERHEAD_CAP + TRACE_OVERHEAD_SLACK_S
    assert traced_path_s <= overhead_cap, (
        f"tracing overhead: traced path median {traced_path_s:.4f}s > "
        f"untraced {untraced_path_s:.4f}s * {TRACE_OVERHEAD_CAP} + "
        f"{TRACE_OVERHEAD_SLACK_S}s"
    )

    completed = sum(len(v) for v in lat.values()) + noisy["ok"]
    rec = {
        "clients": 6,
        "completed": completed,
        "wall_seconds": round(wall, 3),
        "throughput_rps": round(completed / wall, 2),
        "interactive_p50_s": round(_percentile(lat["web"], 50), 5),
        "interactive_p99_s": round(_percentile(lat["web"], 99), 5),
        "batch_p50_s": round(_percentile(lat["etl"] + lat["data"] + lat["joint"], 50), 5),
        "batch_p99_s": round(_percentile(lat["etl"] + lat["data"] + lat["joint"], 99), 5),
        "data_p99_s": round(_percentile(lat["data"], 99), 5),
        "joint_p99_s": round(_percentile(lat["joint"], 99), 5),
        "noisy_admitted": noisy["ok"],
        "noisy_rejected": noisy["rejected"],
        "noisy_rejected_frac": round(noisy["rejected"] / N_NOISY, 3),
        "rejected_quota": int(count("serve.rejected.quota")),
        "rejected_queue": int(count("serve.rejected.queue")),
        "rejected_deadline": int(count("serve.rejected.deadline")),
        "fastpath_requests": int(count("serve.fastpath_requests")),
        "coalesced_blocks": int(count("serve.coalesced_blocks")),
        "cache_hits": int(cache_hits),
        "cache_hit_seconds": round(cache_hit_s, 6),
        "server_interactive_p99_s": round(server_p99, 5),
        "traced_path_s": round(traced_path_s, 5),
        "untraced_path_s": round(untraced_path_s, 5),
        "trace_overhead_ratio": round(
            traced_path_s / untraced_path_s if untraced_path_s > 0 else 1.0, 4
        ),
        "trace_spans": int(trace_spans),
    }
    # control-plane facts are hard asserts — quantities go to the baseline
    assert rec["rejected_quota"] > 0, "noisy tenant was never throttled"
    assert noisy["rejected"] == rec["rejected_quota"]
    assert rec["cache_hits"] >= 1, "identical re-submission missed the cache"
    assert rec["fastpath_requests"] > 0, "interactive fast path never fired"
    assert rec["coalesced_blocks"] > 0, "batch traffic never coalesced"
    log(
        f"{completed} requests / {wall:.2f}s = {rec['throughput_rps']} req/s; "
        f"interactive p50={rec['interactive_p50_s'] * 1e3:.1f}ms "
        f"p99={rec['interactive_p99_s'] * 1e3:.1f}ms; batch "
        f"p99={rec['batch_p99_s'] * 1e3:.1f}ms"
    )
    log(
        f"noisy tenant: {noisy['ok']} admitted, {noisy['rejected']} rejected "
        f"({rec['noisy_rejected_frac'] * 100:.0f}% — quota "
        f"rate={NOISY_RATE}/s burst={NOISY_BURST}); other tenants rejected: 0"
    )
    log(
        f"cache: repeat hit in {rec['cache_hit_seconds'] * 1e3:.2f}ms "
        f"({rec['cache_hits']} hits); coalesced {rec['coalesced_blocks']} "
        f"blocks across requests"
    )
    log(
        f"obs: server-side p99={rec['server_interactive_p99_s'] * 1e3:.1f}ms "
        f"(client {rec['interactive_p99_s'] * 1e3:.1f}ms); traced path "
        f"{rec['traced_path_s'] * 1e3:.1f}ms vs untraced "
        f"{rec['untraced_path_s'] * 1e3:.1f}ms "
        f"(x{rec['trace_overhead_ratio']}); {rec['trace_spans']} spans -> "
        "TRACE_submit_path.json"
    )
    return rec


def smoke(log=print) -> None:
    """Fast in-process control-plane gate: typed rejection, SLO fast path,
    deadline drop, cache hit, and spec == legacy equivalence."""
    import jax

    jax.config.update("jax_enable_x64", True)
    import warnings

    from repro.core import glasso
    from repro.core.instrument import count, reset
    from repro.covariance import lambda_interval_for_k, paper_synthetic
    from repro.engine.options import EngineOptions
    from repro.launch.control_plane import (
        DeadlineExceeded,
        DenseSpec,
        Overload,
        Quota,
        RequestMeta,
    )
    from repro.launch.serve_glasso import GlassoServer

    S = paper_synthetic(3, 8, seed=5)
    lo, hi = lambda_interval_for_k(S, 3)
    lam = float(0.5 * (lo + hi))
    options = EngineOptions(solver="bcd", solver_opts={"tol": 1e-8})

    reset("serve")
    with GlassoServer(
        options=options,
        quotas={"noisy": Quota(rate=1e-6, burst=1.0)},
        result_cache=4,
    ) as server:
        # spec submit == direct engine solve, byte-for-byte
        res = server.submit(DenseSpec(S, lam)).result(timeout=300)
        ref = glasso(S, lam, options=options)
        assert np.array_equal(res.Theta, ref.Theta), "spec submit != engine"
        # cache: identical content (different buffer) returns the result
        res2 = server.submit(DenseSpec(S.copy(), lam)).result(timeout=300)
        assert res2 is res and count("serve.cache.hits") == 1
        # quota: second noisy admission rejects synchronously, typed
        server.submit(
            DenseSpec(S, lam * 0.99), meta=RequestMeta(tenant="noisy")
        ).result(timeout=300)
        try:
            server.submit(
                DenseSpec(S, lam * 0.98), meta=RequestMeta(tenant="noisy")
            )
            raise AssertionError("noisy tenant was not throttled")
        except Overload as e:
            assert e.reason == "quota" and e.tenant == "noisy"
        # legacy verb still equivalent (through its deprecation shim)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            res_legacy = server.submit(S, lam).result(timeout=300)
        assert np.array_equal(res_legacy.Theta, ref.Theta)
        # observability: the request trace rode the result and the /metrics
        # surface exposes the labeled latency histogram
        assert res.trace is not None and res.trace.name == "serve.request"
        assert res.trace.root.attrs["kind"] == "dense"
        m = server.metrics()
        assert "serve_request_seconds_bucket" in m and "serve_requests" in m

    # deadline: queued request expires before a late-starting batcher runs
    server = GlassoServer(options=options, fast_path=False)
    fut = server.submit(
        DenseSpec(S, lam), meta=RequestMeta(slo="batch", deadline=0.02)
    )
    time.sleep(0.08)
    server.start()
    try:
        fut.result(timeout=60)
        raise AssertionError("expired request was solved anyway")
    except DeadlineExceeded:
        pass
    finally:
        server.stop()
    assert count("serve.rejected.deadline") >= 1
    log(
        "serve smoke OK: spec==engine, cache hit, typed quota Overload, "
        "deadline drop, legacy shim equivalent, trace + metrics surface"
    )


def check(rec: dict, baseline_path: str, log=print) -> int:
    """CI gate: >20% regression on interactive p99 or throughput fails
    (with absolute floors — see module docstring)."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    p99_cap = max(base["interactive_p99_s"] * 1.2, P99_FLOOR_S)
    if rec["interactive_p99_s"] > p99_cap:
        failures.append(
            f"interactive p99 {rec['interactive_p99_s']}s > {p99_cap:.3f}s "
            f"(baseline {base['interactive_p99_s']}s + 20%, floor "
            f"{P99_FLOOR_S}s)"
        )
    tput_gate = base["throughput_rps"] * 0.8
    if tput_gate > THROUGHPUT_FLOOR and rec["throughput_rps"] < tput_gate:
        failures.append(
            f"throughput {rec['throughput_rps']} req/s < {tput_gate:.2f} "
            f"(baseline {base['throughput_rps']} - 20%)"
        )
    if rec["rejected_quota"] == 0:
        failures.append("no quota rejections recorded (throttle inert)")
    if rec["cache_hits"] < 1:
        failures.append("result cache never hit")
    for msg in failures:
        log(f"REGRESSION: {msg}")
    if not failures:
        log(f"serve bench within baseline ({baseline_path})")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--check", default=None)
    args = ap.parse_args()

    if args.smoke:
        smoke()
        return
    rec = run()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    if args.check:
        sys.exit(check(rec, args.check))


if __name__ == "__main__":
    main()

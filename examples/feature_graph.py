"""Bridging example: the paper's technique applied to an LM analysis task.

Estimates a sparse dependency graph over a trained (here: randomly
initialized, reduced) LM's residual-stream features: collect activations
over a token stream, form the feature correlation matrix, and run the exact
screening + blockwise graphical lasso.  This is the workload where the two
pillars of this framework meet (DESIGN.md Section 4): d_model-sized
covariance graphs are exactly the p ~ thousands regime the paper unlocks.

    PYTHONPATH=src python examples/feature_graph.py
"""

import sys

sys.path.insert(0, "src")

import jax

jax.config.update("jax_enable_x64", True)

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_arch
from repro.core import EngineOptions, glasso, lambda_for_max_component
from repro.covariance import sample_correlation
from repro.data.specs import make_batch
from repro.models import transformer as tfm
from repro.models.zoo import build_model


def main():
    cfg = dataclasses.replace(get_arch("granite_3_8b").reduced(), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))

    # collect residual-stream activations over a few batches
    shape = ShapeConfig("probe", seq_len=64, global_batch=4, kind="prefill")
    acts = []
    for seed in range(4):
        batch = make_batch(cfg, shape, seed=seed)
        x, _, _ = tfm.backbone_apply(params, cfg, batch, mode="causal")
        acts.append(np.asarray(x, np.float64).reshape(-1, cfg.d_model))
    A = np.concatenate(acts)        # (tokens, d_model)
    print(f"activation matrix: {A.shape}")

    R = np.asarray(sample_correlation(jnp.asarray(A)))
    lam = lambda_for_max_component(R, 24) * 1.0005
    res = glasso(
        R, lam,
        options=EngineOptions(solver="admm", solver_opts={"tol": 1e-7}),
    )
    print(f"lambda={lam:.3f}: {res.screen.n_components} feature modules, "
          f"max size {res.screen.max_comp}, solve {res.solve_seconds:.2f}s")
    nnz = int((np.abs(res.Theta) > 1e-8).sum() - cfg.d_model)
    print(f"precision-graph edges: {nnz // 2} "
          f"({nnz / (cfg.d_model * (cfg.d_model - 1)):.2%} dense)")


if __name__ == "__main__":
    main()

"""END-TO-END DRIVER (the paper's kind: large-scale optimization).

Full production pipeline on one box:
  raw samples -> streaming covariance (Pallas covgram twin) -> exact
  screening (Theorem 1) -> LPT scheduling of components onto the device
  mesh -> zero-communication distributed block solves (shard_map) ->
  assembled precision matrix -> KKT verification.

On a pod, the same code runs with make_production_mesh(); here the mesh is
the container's single device — the shard_map paths are identical.

    PYTHONPATH=src python examples/large_scale_glasso.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import time

import jax.numpy as jnp
import numpy as np

from repro.core import kkt_residual, lambda_for_max_component
from repro.core.blocks import build_plan
from repro.core.components import component_lists, partitions_equal
from repro.core.distributed import distributed_bucket_solve, distributed_components
from repro.core.schedule import lpt_assign
from repro.core.solvers import glasso_bcd
from repro.covariance import microarray_like
from repro.kernels.covgram.ops import covgram


def main():
    n, p = 80, 1200
    print(f"generating expression matrix: n={n}, p={p}")
    X = microarray_like(n, p, seed=7)

    t0 = time.perf_counter()
    S = np.asarray(covgram(jnp.asarray(X, jnp.float32)))  # Pallas kernel path
    d = np.sqrt(np.clip(np.diag(S), 1e-12, None))
    R = (S / np.outer(d, d)).astype(np.float64)
    np.fill_diagonal(R, 1.0)
    print(f"covariance via Pallas covgram: {time.perf_counter()-t0:.2f}s")

    p_max = 64  # per-worker capacity
    lam = lambda_for_max_component(R, p_max) * 1.0005
    print(f"capacity-bounded lambda (p_max={p_max}): {lam:.4f}")

    mesh = jax.make_mesh((jax.device_count(),), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))

    # distributed CC (label-prop, row-sharded) cross-checked against host
    t0 = time.perf_counter()
    labels_dist = np.asarray(distributed_components(jnp.asarray(R), lam, mesh))
    t_cc = time.perf_counter() - t0
    from repro.core.components import components_from_covariance_host

    assert partitions_equal(labels_dist, components_from_covariance_host(R, lam))
    comps = component_lists(labels_dist)
    sizes = [len(c) for c in comps if len(c) > 1]
    print(f"distributed CC: {t_cc:.2f}s; {len(comps)} components, "
          f"{len(sizes)} non-trivial, max {max(sizes)}")

    # LPT schedule across (simulated) workers
    a = lpt_assign(sizes, n_workers=8)
    print(f"LPT over 8 workers: makespan/mean = {a.balance:.3f}")

    # zero-communication distributed bucket solves
    plan = build_plan(R, lam, labels_dist)
    t0 = time.perf_counter()
    Theta = np.zeros_like(R)
    Theta[plan.isolated, plan.isolated] = 1.0 / (R[plan.isolated, plan.isolated] + lam)
    for bucket in plan.buckets:
        sols = np.asarray(
            distributed_bucket_solve(bucket.blocks, lam, glasso_bcd, mesh, tol=1e-7)
        )
        for comp, sol in zip(bucket.comps, sols):
            b = len(comp)
            Theta[np.ix_(comp, comp)] = sol[:b, :b]
    print(f"distributed block solves: {time.perf_counter()-t0:.2f}s")

    # verify blockwise KKT on the largest few components
    worst = 0.0
    for comp in comps[:5]:
        if len(comp) < 2:
            continue
        res = float(kkt_residual(jnp.asarray(R[np.ix_(comp, comp)]),
                                 jnp.asarray(Theta[np.ix_(comp, comp)]), lam))
        worst = max(worst, res)
    print(f"worst blockwise KKT residual (top-5 components): {worst:.2e}")
    print("OK" if worst < 1e-4 else "FAILED")


if __name__ == "__main__":
    main()

"""END-TO-END DRIVER (the paper's kind: large-scale optimization).

Full production pipeline on one box, all through the Plan->Execute engine:
  raw samples -> streaming covariance (Pallas covgram twin) -> exact
  screening via the engine's ``shard_map`` registry backend (row-sharded
  label propagation, cross-checked against the host backend) -> incremental
  bucket plan -> async LPT-placed batched block solves -> assembled
  precision matrix -> KKT verification.

On a pod, the same code runs with make_production_mesh(); here the mesh is
the container's single device — the shard_map paths are identical.

    PYTHONPATH=src python examples/large_scale_glasso.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import time

import jax.numpy as jnp
import numpy as np

from repro.core import kkt_residual, lambda_for_max_component
from repro.core.components import component_lists, partitions_equal
from repro.core.instrument import counts, reset
from repro.core.schedule import lpt_assign
from repro.covariance import microarray_like
from repro.engine import Engine, label_components
from repro.kernels.covgram.ops import covgram


def main():
    n, p = 80, 1200
    print(f"generating expression matrix: n={n}, p={p}")
    X = microarray_like(n, p, seed=7)

    t0 = time.perf_counter()
    S = np.asarray(covgram(jnp.asarray(X, jnp.float32)))  # Pallas kernel path
    d = np.sqrt(np.clip(np.diag(S), 1e-12, None))
    R = (S / np.outer(d, d)).astype(np.float64)
    np.fill_diagonal(R, 1.0)
    print(f"covariance via Pallas covgram: {time.perf_counter()-t0:.2f}s")

    p_max = 64  # per-worker capacity
    lam = lambda_for_max_component(R, p_max) * 1.0005
    print(f"capacity-bounded lambda (p_max={p_max}): {lam:.4f}")

    # distributed CC via the registry backend, cross-checked against host
    t0 = time.perf_counter()
    labels_dist = label_components(R, lam, backend="shard_map")
    t_cc = time.perf_counter() - t0
    assert partitions_equal(labels_dist, label_components(R, lam, backend="host"))
    comps = component_lists(labels_dist)
    sizes = [len(c) for c in comps if len(c) > 1]
    print(f"shard_map CC: {t_cc:.2f}s; {len(comps)} components, "
          f"{len(sizes)} non-trivial, max {max(sizes)}")

    # LPT preview across (simulated) workers; the engine executor applies the
    # same policy across the real local devices
    a = lpt_assign(sizes, n_workers=8)
    print(f"LPT over 8 workers: makespan/mean = {a.balance:.3f}")

    # engine solve: plan + async batched bucket dispatch + assembly (the
    # partition above is passed through — screening is not paid twice)
    reset()
    engine = Engine(solver="bcd", cc_backend="shard_map", tol=1e-7)
    t0 = time.perf_counter()
    res = engine.run(R, lam, p_max=p_max, labels=labels_dist)
    print(f"engine block solves: {time.perf_counter()-t0:.2f}s "
          f"(buckets padded {counts().get('planner.buckets_padded', 0)}, "
          f"dispatches {counts().get('executor.dispatches', 0)})")
    Theta = res.Theta

    # verify blockwise KKT on the largest few components
    worst = 0.0
    for comp in comps[:5]:
        if len(comp) < 2:
            continue
        res_kkt = float(kkt_residual(jnp.asarray(R[np.ix_(comp, comp)]),
                                     jnp.asarray(Theta[np.ix_(comp, comp)]), lam))
        worst = max(worst, res_kkt)
    print(f"worst blockwise KKT residual (top-5 components): {worst:.2e}")
    print("OK" if worst < 1e-4 else "FAILED")


if __name__ == "__main__":
    main()

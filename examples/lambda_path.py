"""Lambda-path driver: Theorem 2 in action.

Solves a descending lambda path on a microarray-like correlation matrix,
exploiting nestedness (components only merge), per-block warm starts, and
the capacity-bounded lambda floor of consequence 5.  Checkpoints the path
state after every lambda so a preempted sweep resumes where it stopped.

    PYTHONPATH=src python examples/lambda_path.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import tempfile

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import (
    EngineOptions,
    glasso_path,
    is_refinement,
    lambda_for_max_component,
    merge_profile,
)
from repro.covariance import microarray_like, sample_correlation


def main():
    n, p, p_max = 60, 500, 40
    X = microarray_like(n, p, seed=0)
    R = np.asarray(sample_correlation(jnp.asarray(X)))

    lam_floor = lambda_for_max_component(R, p_max)
    print(f"p={p}; smallest lambda with max component <= {p_max} (machine "
          f"capacity, consequence 5): {lam_floor:.4f}")

    prof = merge_profile(R)
    vals = prof["value"][1:]
    lams = sorted(vals[vals > lam_floor][::-1][:6].tolist(), reverse=True)
    print(f"path over {len(lams)} lambdas in [{lams[-1]:.3f}, {lams[0]:.3f}]")

    results = glasso_path(
        R, lams,
        options=EngineOptions(solver="bcd", solver_opts={"tol": 1e-6}),
    )
    mgr = CheckpointManager(tempfile.mkdtemp(prefix="lampath_"), every=1, async_save=False)
    prev_labels = None
    for i, res in enumerate(results):
        nested = (
            "-" if prev_labels is None
            else str(is_refinement(prev_labels, res.labels))
        )
        print(f"lambda={res.lam:.4f}  comps={res.screen.n_components:4d}  "
              f"max={res.screen.max_comp:3d}  solve={res.solve_seconds:6.2f}s  "
              f"nested_in_next={nested}")
        mgr.save(i, {"lambda": jnp.asarray(res.lam), "Theta": jnp.asarray(res.Theta)},
                 blocking=True)
        prev_labels = res.labels
    print("path state checkpointed at", mgr.directory)


if __name__ == "__main__":
    main()

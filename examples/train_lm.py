"""LM-pillar end-to-end: train a small decoder LM with the full substrate —
data pipeline, microbatched+remat train step, AdamW, checkpoints, resume.

Defaults are CPU-feasible (a ~20M-param model, a few hundred steps); pass
--d-model 768 --layers 12 --vocab 32000 on real hardware for the ~100M
configuration (same code path).

    PYTHONPATH=src python examples/train_lm.py --steps 150
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    from repro.checkpoint import CheckpointManager
    from repro.configs.base import ArchConfig, ShapeConfig
    from repro.data.synthetic_lm import SyntheticLM
    from repro.models.zoo import build_model, count_params
    from repro.optim import adamw, cosine_with_warmup
    from repro.train.state import init_state
    from repro.train.step import make_train_step
    import time

    cfg = ArchConfig(
        name=f"lm-{args.d_model}d{args.layers}L",
        family="dense",
        n_layers=args.layers,
        d_model=args.d_model,
        n_heads=max(4, args.d_model // 64),
        n_kv_heads=max(2, args.d_model // 128),
        d_ff=4 * args.d_model,
        vocab=args.vocab,
        dtype="float32",
    )
    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch, kind="train")
    model = build_model(cfg)
    optimizer = adamw(cosine_with_warmup(3e-4, warmup=20, total=args.steps))
    state, _ = init_state(model, optimizer, jax.random.key(0))
    print(f"model {cfg.name}: {count_params(state.params):,} params")

    mgr = CheckpointManager(args.ckpt_dir, every=50)
    start = 0
    if args.resume:
        try:
            state, start = mgr.restore_latest(state)
            print(f"resumed at step {start}")
        except FileNotFoundError:
            pass

    step_fn = jax.jit(make_train_step(model, optimizer, microbatches=2, remat="none"))
    data = SyntheticLM(cfg, shape, seed=0)
    losses = []
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        state, metrics = step_fn(state, data.batch_at(step))
        losses.append(float(metrics["loss"]))
        if step % 10 == 0:
            rate = (step - start + 1) * args.batch * args.seq / (time.perf_counter() - t0)
            print(f"step {step:4d}  loss {losses[-1]:.4f}  ({rate:,.0f} tok/s)")
        if mgr.should_save(step):
            mgr.save(int(state.step), state)
    mgr.save(int(state.step), state, blocking=True)
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"loss {first:.3f} -> {last:.3f} ({'LEARNING' if last < first - 0.1 else 'flat'})")


if __name__ == "__main__":
    main()

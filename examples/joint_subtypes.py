"""Multi-class example: one dependency graph family across K conditions.

The scenario the joint subsystem opens (DESIGN.md Section 12): the same
variables observed under K related conditions — cancer subtypes, brain
states, market regimes — where most of the network is SHARED and a minority
of components rewires per condition.  Estimating the classes jointly under
a fused/group penalty borrows strength across conditions; the exact hybrid
covariance thresholding screen (Tang et al., arXiv:1503.02128) decomposes
the joint problem into common components first, and the routing ladder
solves every shared component ONCE (forest/chordal/iterative single-class
at the effective lambda, per-class KKT-verified) while class-specific
components take the K-coupled joint ADMM.

    PYTHONPATH=src python examples/joint_subtypes.py
"""

import sys

sys.path.insert(0, "src")

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core.instrument import counts, reset, route_mix_counts
from repro.covariance import structured_synthetic
from repro.core import EngineOptions
from repro.joint import joint_glasso


def main():
    K, blocks, p1 = 3, 24, 10  # 3 "subtypes", p = 240 shared variables
    Ss = structured_synthetic(
        blocks, p1, classes=K, shared_fraction=0.75, seed=7
    )
    lam1, lam2 = 0.4, 0.1

    for penalty in ("group", "fused"):
        reset()
        res = joint_glasso(
            list(Ss), lam1, lam2, penalty=penalty,
            options=EngineOptions(solver_opts={"tol": 1e-8}),
        )
        shared_edges = res.support.sum() // 2
        per_class = [int(res.class_support(k).sum() // 2) for k in range(K)]
        print(f"[{penalty}] union components: {res.screen.n_components} "
              f"(max {res.screen.max_comp}), union edges kept: "
              f"{res.screen.n_edges}")
        print(f"[{penalty}] route mix: {res.route_mix}  "
              f"fallbacks: {res.fallbacks}")
        print(f"[{penalty}] union support edges: {shared_edges}, per class: "
              f"{per_class}")
        print(f"[{penalty}] router counters: {route_mix_counts()}")
        print(f"[{penalty}] joint counters: {counts('joint.')}")

    # the out-of-core path: the same estimate straight from per-class data
    # matrices (no dense per-class covariance is ever materialized)
    rng = np.random.default_rng(0)
    n, p = 400, 120
    base = rng.standard_normal((n, p))
    base[:, :12] += rng.standard_normal((n, 1))   # a shared module
    Xs = []
    for k in range(K):
        X = base + 0.5 * rng.standard_normal((n, p))
        X[:, 20 + 4 * k : 24 + 4 * k] += rng.standard_normal((n, 1))  # per-class
        Xs.append(X)
    res = joint_glasso(
        Xs=Xs, lam1=0.35, lam2=0.05, penalty="group", from_data=True,
        stream={"tile": 64, "chunk": 128},
        options=EngineOptions(solver_opts={"tol": 1e-8}),
    )
    print(f"[from-data] K={res.K} p={p}: {res.screen.n_components} "
          f"components, {res.screen.candidate_pairs} candidate pairs "
          f"completed, {res.screen.n_edges} union edges")


if __name__ == "__main__":
    main()

"""Quickstart: the paper's result in 40 lines.

Builds the Section-4.1 synthetic problem, screens, solves per component,
and verifies Theorem 1 (thresholded-graph partition == concentration-graph
partition) plus exactness vs the unscreened solve.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro.core import (
    EngineOptions,
    glasso,
    kkt_residual,
    partitions_equal,
    thresholded_components,
)
from repro.core.components import connected_components_host
from repro.covariance import lambda_interval_for_k, paper_synthetic


def main():
    K, p1 = 4, 25
    S = paper_synthetic(K, p1, seed=0)
    lam_min, lam_max = lambda_interval_for_k(S, K)
    lam = 0.5 * (lam_min + lam_max)
    print(f"p = {K * p1}, lambda interval for {K} components: "
          f"[{lam_min:.3f}, {lam_max:.3f}], using lambda_I = {lam:.3f}")

    labels, stats = thresholded_components(S, lam)
    print(f"screening: {stats.n_components} components, max size "
          f"{stats.max_comp}, partition took {stats.seconds*1e3:.2f} ms")

    opts = EngineOptions(solver="bcd", solver_opts={"tol": 1e-8})
    glasso(S, lam, options=opts)                    # warm the jit caches
    glasso(S, lam, screen=False, options=opts)
    res = glasso(S, lam, options=opts)
    print(f"screened solve: {res.solve_seconds:.2f}s over blocks {res.block_sizes}")

    # Theorem 1: concentration-graph partition == thresholded partition
    A = np.abs(res.Theta) > 1e-9
    np.fill_diagonal(A, False)
    conc = connected_components_host(A)
    print("Theorem 1 holds:", partitions_equal(labels, conc))

    # KKT optimality + exactness vs no screening
    import jax.numpy as jnp

    print(f"KKT residual: {float(kkt_residual(jnp.asarray(S), jnp.asarray(res.Theta), lam)):.2e}")
    full = glasso(S, lam, screen=False, options=opts)
    print(f"max |Theta_screen - Theta_full| = {np.abs(res.Theta - full.Theta).max():.2e}")
    print(f"speedup: {full.solve_seconds / res.solve_seconds:.1f}x")


if __name__ == "__main__":
    main()

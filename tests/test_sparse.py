"""Sparse-native result representation (DESIGN.md Section 13).

The contract under test: ``output="sparse"`` returns a ``SparseTheta`` /
``JointSparseTheta`` that is NUMERICALLY IDENTICAL to the dense result —
same solve, same blocks, only the container differs — across every screening
backend, every route class of the structure ladder, the joint K-class stack,
and the from-data streamed path; global views (COO/CSR/dense/support) round-
trip exactly; and the sparse-aware KKT verifier reproduces the dense
residual without ever allocating a (p, p) buffer (asserted through the
``result.bytes_peak`` watermark).
"""

import numpy as np
import pytest

from conftest import random_covariance
from repro.core import glasso, glasso_path
from repro.core.sparse import (
    AUTO_SPARSE_P,
    JointSparseTheta,
    SparseTheta,
    resolve_output,
)
from repro.covariance import (
    lambda_interval_for_k,
    paper_synthetic,
    structured_synthetic,
)


def _sparse_dense_pair(S, lam, **kw):
    rs = glasso(S, lam, output="sparse", **kw)
    rd = glasso(S, lam, output="dense", **kw)
    assert isinstance(rs.Theta, SparseTheta)
    assert not isinstance(rd.Theta, SparseTheta)
    return rs, rd


def _assert_equivalent(rs, rd, atol=1e-8):
    Ts = rs.Theta.toarray()
    assert Ts.dtype == rd.Theta.dtype
    np.testing.assert_allclose(Ts, rd.Theta, atol=atol, rtol=0)
    # support artifacts agree entry-for-entry, not just numerically
    assert rs.Theta.nnz == np.count_nonzero(Ts)
    np.testing.assert_array_equal(rs.support, rd.support)
    np.testing.assert_array_equal(rs.support_edges(), rd.support_edges())


# -- equivalence across screening backends ---------------------------------


@pytest.mark.parametrize("backend", ["host", "jax", "pallas", "shard_map"])
def test_sparse_equals_dense_all_backends(backend):
    S = paper_synthetic(4, 10, seed=3)
    lam_min, lam_max = lambda_interval_for_k(S, 4)
    lam = 0.5 * (lam_min + lam_max)
    rs, rd = _sparse_dense_pair(S, lam, cc_backend=backend, tol=1e-9)
    assert rs.output == "sparse" and rd.output == "dense"
    np.testing.assert_array_equal(rs.labels, rd.labels)
    _assert_equivalent(rs, rd)


# -- equivalence across every route class ----------------------------------


def test_sparse_equals_dense_structured_ladder():
    """structured_synthetic exercises singleton/pair/tree/chordal/general
    blocks in one plan; the sparse container must not depend on the route."""
    S = structured_synthetic(12, 16, seed=1)
    for lam in (0.7, 0.45):
        rs, rd = _sparse_dense_pair(S, lam, tol=1e-9)
        _assert_equivalent(rs, rd)
        if lam == 0.45:
            # several distinct ladder classes were actually exercised
            assert len(set(rs.route_mix) - {"singleton"}) >= 2


def test_sparse_equals_dense_oversize_route():
    """Oversize (sharded) blocks assemble into the same sparse container."""
    S = structured_synthetic(6, 16, seed=2)
    rs, rd = _sparse_dense_pair(S, 0.4, oversize_threshold=12, tol=1e-8)
    assert rs.oversize is not None and rs.oversize["dispatched"] >= 1
    _assert_equivalent(rs, rd, atol=1e-6)


def test_sparse_exact_on_dyadic_ties():
    """|S_ij| == lam exactly (dyadic, no rounding): the screen excludes the
    edge in both paths and sparse == dense BITWISE."""
    S = np.eye(6)
    S[0, 1] = S[1, 0] = 0.5       # == lam: excluded (strict >)
    S[2, 3] = S[3, 2] = 0.75      # > lam: kept
    rs, rd = _sparse_dense_pair(S, 0.5, tol=1e-10)
    assert np.array_equal(rs.Theta.toarray(), rd.Theta)
    assert rs.Theta.nnz == np.count_nonzero(rd.Theta)
    # the tied pair ended isolated in both representations
    assert {0, 1} <= set(rs.Theta.isolated.tolist())


# -- joint K-class ----------------------------------------------------------


@pytest.mark.parametrize("penalty", ["group", "fused"])
def test_joint_sparse_equals_dense(penalty):
    from repro.joint import joint_glasso

    Ss = [paper_synthetic(3, 8, seed=i) for i in range(3)]
    lam_min, lam_max = lambda_interval_for_k(Ss[0], 3)
    lam1 = 0.5 * (lam_min + lam_max)
    kw = dict(penalty=penalty, tol=1e-9)
    js = joint_glasso(Ss, lam1, 0.05, output="sparse", **kw)
    jd = joint_glasso(Ss, lam1, 0.05, output="dense", **kw)
    assert isinstance(js.Theta, JointSparseTheta)
    assert js.K == jd.K == 3
    np.testing.assert_allclose(js.Theta.toarray(), jd.Theta, atol=1e-7, rtol=0)
    np.testing.assert_array_equal(js.support, jd.support)
    np.testing.assert_array_equal(js.support_edges(), jd.support_edges())
    for k in range(3):
        np.testing.assert_array_equal(js.class_support(k), jd.class_support(k))
        np.testing.assert_allclose(
            js.Theta[k].toarray(), jd.Theta[k], atol=1e-7, rtol=0
        )


# -- from-data streamed path -------------------------------------------------


def test_sparse_from_data_streamed(rng):
    X = rng.standard_normal((300, 64))
    X[:, 32:40] += 2.0 * rng.standard_normal((300, 1))  # planted component
    lam = 0.35
    rs = glasso(X=X, lam=lam, from_data=True, output="sparse", tol=1e-9)
    rd = glasso(X=X, lam=lam, from_data=True, output="dense", tol=1e-9)
    assert isinstance(rs.Theta, SparseTheta)
    _assert_equivalent(rs, rd)


# -- global views / round-trips ---------------------------------------------


def test_coo_csr_dense_round_trips():
    S = structured_synthetic(8, 16, seed=4)
    r = glasso(S, 0.45, output="sparse", tol=1e-9)
    T = r.Theta
    dense = T.toarray()
    rows, cols, vals = T.to_coo()
    back = np.zeros_like(dense)
    back[rows, cols] = vals
    np.testing.assert_array_equal(back, dense)
    assert len(rows) == T.nnz == np.count_nonzero(dense)
    np.testing.assert_array_equal(T.to_csr().toarray(), dense)
    np.testing.assert_array_equal(np.asarray(T), dense)
    np.testing.assert_array_equal(T.diagonal(), np.diagonal(dense))
    # gather protocol: cross-component gathers are exact zeros off-block
    idx = np.arange(0, T.p, 7)
    np.testing.assert_array_equal(T.gather_block(idx), dense[np.ix_(idx, idx)])
    np.testing.assert_array_equal(T.diag_at(idx), np.diagonal(dense)[idx])


def test_densify_refusal_above_cap():
    S = paper_synthetic(3, 8, seed=0)
    lam = 0.5 * sum(lambda_interval_for_k(S, 3))
    T = glasso(S, lam, output="sparse").Theta
    T.densify_max = T.p - 1  # simulate an oversize result
    with pytest.raises(ValueError, match="refusing to densify"):
        T.toarray()
    with pytest.raises(ValueError, match="refusing to densify"):
        np.asarray(T)
    forced = T.toarray(force=True)
    assert forced.shape == (T.p, T.p)
    # support switches to scipy CSR above the cap — same adjacency
    sp_support = T.support()
    assert not isinstance(sp_support, np.ndarray)
    T.densify_max = T.p
    np.testing.assert_array_equal(sp_support.toarray(), T.support())


def test_resolve_output_thresholds():
    assert resolve_output("auto", AUTO_SPARSE_P) == "dense"
    assert resolve_output("auto", AUTO_SPARSE_P + 1) == "sparse"
    assert resolve_output(None, AUTO_SPARSE_P + 1) == "sparse"
    assert resolve_output("dense", 10**6) == "dense"
    assert resolve_output("sparse", 2) == "sparse"
    with pytest.raises(ValueError):
        resolve_output("csv", 10)


# -- sparse-aware KKT verification ------------------------------------------


def test_kkt_sparse_matches_dense_and_never_densifies():
    from repro.core.instrument import counts, reset
    from repro.core.solvers.kkt import kkt_residual, kkt_residual_sparse

    S = structured_synthetic(12, 16, seed=5)
    rs, rd = _sparse_dense_pair(S, 0.45, tol=1e-9)
    reset("result.")
    res_sparse = kkt_residual_sparse(S, rs.Theta, 0.45)
    res_dense = float(kkt_residual(S, np.asarray(rd.Theta), 0.45))
    assert res_sparse == pytest.approx(res_dense, abs=1e-9)
    # the watermark proves no (p, p) buffer was part of the verification
    peak = counts("result.")["result.bytes_peak"]
    assert 0 < peak < S.shape[0] ** 2 * np.dtype(np.float64).itemsize


def test_joint_kkt_sparse_matches_dense():
    from repro.core.instrument import counts, reset
    from repro.joint import joint_glasso
    from repro.joint.kkt import joint_kkt_residual, joint_kkt_residual_sparse

    Ss = [paper_synthetic(3, 8, seed=10 + i) for i in range(2)]
    lam1 = 0.5 * sum(lambda_interval_for_k(Ss[0], 3))
    js = joint_glasso(Ss, lam1, 0.05, output="sparse", tol=1e-9)
    jd = joint_glasso(Ss, lam1, 0.05, output="dense", tol=1e-9)
    reset("result.")
    res_sparse = joint_kkt_residual_sparse(Ss, js.Theta, lam1, 0.05)
    res_dense = joint_kkt_residual(Ss, jd.Theta, lam1, 0.05)
    assert res_sparse == pytest.approx(res_dense, abs=1e-8)
    p = Ss[0].shape[0]
    peak = counts("result.")["result.bytes_peak"]
    assert 0 < peak < 2 * p * p * np.dtype(np.float64).itemsize


# -- stage attribution -------------------------------------------------------


def test_stage_counters_and_bytes_peak():
    from repro.core.instrument import counts, reset

    S = paper_synthetic(4, 12, seed=6)
    lam = 0.5 * sum(lambda_interval_for_k(S, 4))
    reset("engine.")
    reset("result.")
    r = glasso(S, lam, output="sparse")
    eng = counts("engine.")
    assert eng.get("engine.solve_us", 0) > 0
    assert "engine.assemble_us" in eng
    assert eng.get("engine.screen_us", 0) > 0
    assert r.assemble_seconds >= 0.0
    assert r.solve_seconds >= 0.0  # assembly excluded, still non-negative
    assert r.screen_seconds > 0.0
    assert r.bytes_peak == r.Theta.nbytes()
    assert r.output == "sparse"
    # sparse container is strictly smaller than the dense result would be
    assert r.bytes_peak < S.shape[0] ** 2 * np.dtype(np.float64).itemsize


def test_support_derivation_no_dense_intermediate(rng):
    S = random_covariance(rng, 40)
    r = glasso(S, 0.3, output="sparse")
    rd = glasso(S, 0.3, output="dense")
    sup = r.support
    assert sup.dtype == bool and not sup.diagonal().any()
    np.testing.assert_array_equal(sup, rd.support)


# -- dtype regression (satellite 6) -----------------------------------------


def test_assemble_dense_dtype_from_S_when_no_buckets():
    from repro.core import blocks as blocks_mod
    from repro.core.screening import thresholded_components
    from repro.engine.planner import build_plan_incremental

    S = np.eye(12, dtype=np.float32)  # everything isolated at any lam > 0
    labels, _ = thresholded_components(S, 0.5)
    plan, _ = build_plan_incremental(S, 0.5, labels)
    assert not plan.buckets
    Theta = blocks_mod.assemble_dense(plan, [], S)
    assert Theta.dtype == np.float32  # was silently float64 before
    sp = blocks_mod.assemble_sparse(plan, [], S)
    assert sp.dtype == np.float32
    np.testing.assert_array_equal(sp.toarray(), Theta)


# -- path warm starts through sparse results ---------------------------------


def test_sparse_path_equals_dense_path():
    S = structured_synthetic(8, 16, seed=7)
    lams = [0.7, 0.5, 0.4]
    path_s = glasso_path(S, lams, output="sparse", tol=1e-9)
    path_d = glasso_path(S, lams, output="dense", tol=1e-9)
    for rs, rd in zip(path_s, path_d):
        assert isinstance(rs.Theta, SparseTheta)
        np.testing.assert_allclose(
            rs.Theta.toarray(), rd.Theta, atol=1e-7, rtol=0
        )
        np.testing.assert_array_equal(rs.labels, rd.labels)


def test_blockwise_inverse_sparse():
    from repro.engine.api import blockwise_inverse

    S = paper_synthetic(3, 10, seed=8)
    lam = 0.5 * sum(lambda_interval_for_k(S, 3))
    r = glasso(S, lam, output="sparse")
    needed = np.ones(S.shape[0], dtype=bool)
    W = blockwise_inverse(r.labels, r.Theta, needed)
    Wd = blockwise_inverse(r.labels, r.Theta.toarray(), needed)
    assert isinstance(W, SparseTheta)
    np.testing.assert_allclose(W.toarray(), np.asarray(Wd), atol=1e-9, rtol=0)


# -- serving payloads --------------------------------------------------------


def test_server_sparse_payloads():
    from repro.launch.serve_glasso import GlassoServer

    S = paper_synthetic(4, 10, seed=9)
    lam = 0.5 * sum(lambda_interval_for_k(S, 4))
    with GlassoServer(solver="bcd", tol=1e-8, fast_path=False) as srv:
        rs = srv.submit(S, lam, output="sparse").result(120)
        rd = srv.submit(S, lam, output="dense").result(120)
        ra = srv.submit(S, lam).result(120)  # auto at small p -> dense
    assert isinstance(rs.Theta, SparseTheta)
    assert ra.output == "dense"
    np.testing.assert_allclose(rs.Theta.toarray(), rd.Theta, atol=1e-8, rtol=0)
    np.testing.assert_array_equal(rs.support_edges(), rd.support_edges())
    r, c, v = rs.Theta.to_coo()  # the edge-list/COO payload a client ships
    assert len(r) == rs.Theta.nnz
    assert rs.assemble_seconds >= 0.0 and rs.bytes_peak > 0


def test_server_output_validation():
    from repro.launch.serve_glasso import GlassoServer

    with pytest.raises(ValueError, match="output"):
        GlassoServer(output="csv")

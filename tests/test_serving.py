"""Serving-path tests: cache padding invariants, greedy generation sanity,
multi-token generation consistency with repeated decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_arch
from repro.data.specs import make_batch
from repro.models.zoo import build_model
from repro.train.serving import greedy_generate, pad_caches


@pytest.mark.parametrize("arch", ["granite_3_8b", "deepseek_v2_lite_16b", "rwkv6_7b", "zamba2_1_2b"])
def test_pad_caches_preserves_prefix(arch):
    cfg = dataclasses.replace(get_arch(arch).reduced(), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    shape = ShapeConfig("s", seq_len=8, global_batch=2, kind="prefill")
    batch = make_batch(cfg, shape, seed=0)
    _, caches = model.prefill(params, batch)
    padded = pad_caches(cfg, caches, 8, to_len=16)
    for a, b in zip(jax.tree.leaves(caches), jax.tree.leaves(padded)):
        a, b = np.asarray(a), np.asarray(b)
        if a.shape == b.shape:
            np.testing.assert_array_equal(a, b)
        else:
            # padded along exactly one axis; prefix must be intact
            (axis,) = [i for i in range(a.ndim) if a.shape[i] != b.shape[i]]
            sl = tuple(slice(0, s) for s in a.shape)
            np.testing.assert_array_equal(b[sl], a)


def test_greedy_generate_matches_stepwise_prefill():
    """Token t+1 from the generate loop equals the argmax of a fresh prefill
    over the extended prompt (teacher-forcing equivalence for greedy)."""
    cfg = dataclasses.replace(get_arch("granite_3_8b").reduced(), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(1))
    shape = ShapeConfig("s", seq_len=6, global_batch=2, kind="prefill")
    batch = make_batch(cfg, shape, seed=3)

    gen = np.asarray(greedy_generate(model, params, batch, max_new_tokens=3))
    # reference: roll the prompt forward with fresh prefills
    tokens = np.asarray(batch["tokens"])
    for step in range(3):
        logits, _ = model.prefill(params, {"tokens": jnp.asarray(tokens)})
        nxt = np.asarray(jnp.argmax(logits, axis=-1))[:, None]
        np.testing.assert_array_equal(gen[:, step : step + 1], nxt)
        tokens = np.concatenate([tokens, nxt.astype(np.int32)], axis=1)


def test_generate_shapes_and_determinism():
    cfg = dataclasses.replace(get_arch("qwen2_5_3b").reduced(), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(2))
    shape = ShapeConfig("s", seq_len=5, global_batch=3, kind="prefill")
    batch = make_batch(cfg, shape, seed=4)
    a = np.asarray(greedy_generate(model, params, batch, max_new_tokens=4))
    b = np.asarray(greedy_generate(model, params, batch, max_new_tokens=4))
    assert a.shape == (3, 4)
    np.testing.assert_array_equal(a, b)
    assert (a >= 0).all() and (a < cfg.vocab + 256).all()

"""Sharded oversize-solver subsystem on the single real device.

The genuine 8-device semantics live in test_distributed_multidevice.py (a
subprocess with faked devices); everything here exercises the same code
paths on the 1-device mesh — the ring matmul / all_to_all fast paths, the
shard_prox kernel (interpret mode vs ref), the shard-direct gather, the
planner's oversize class, the Solver protocol, and the executor's
cost-model placement — cheaply enough for the main suite.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import lambda_between_edges, random_covariance
from repro.core import blocks as blocks_mod
from repro.core.instrument import counts, reset
from repro.core.solvers import (
    SOLVERS,
    WARM_START_SOLVERS,
    glasso_admm,
    glasso_sharded,
    solver_spec,
)
from repro.core.solvers.sharded import sharded_pad_size
from repro.kernels.shard_prox.ref import fused_prox_ref
from repro.kernels.shard_prox.shard_prox import fused_prox_pallas


# ------------------------------------------------------------ the solver


@settings(max_examples=6, deadline=None)
@given(p=st.integers(6, 28), seed=st.integers(0, 1000), q=st.floats(0.2, 0.7))
def test_sharded_matches_admm_oracle(p, seed, q):
    rng = np.random.default_rng(seed)
    S = random_covariance(rng, p)
    lam = lambda_between_edges(S, q)
    res = glasso_sharded(S, lam)
    ref = np.asarray(glasso_admm(jnp.asarray(S), lam, tol=1e-9))
    assert res.kkt_residual <= 1e-6 * max(1.0, res.s_max)
    np.testing.assert_allclose(res.Theta, ref, atol=1e-6)
    assert ((np.abs(res.Theta) > 1e-9) == (np.abs(ref) > 1e-9)).all()


def test_sharded_pad_size():
    assert sharded_pad_size(5, 1) == 8
    assert sharded_pad_size(8, 1) == 8
    assert sharded_pad_size(9, 1) == 16
    assert sharded_pad_size(100, 8) == 128
    assert sharded_pad_size(64, 8) == 64
    assert sharded_pad_size(1, 8) == 64


def test_sharded_presharded_input_validates():
    S = np.eye(16)
    arr = jnp.asarray(S)
    with pytest.raises(ValueError, match="true block size"):
        glasso_sharded(arr, 0.1)
    with pytest.raises(ValueError, match="padded size"):
        glasso_sharded(arr, 0.1, b=3)  # 3 pads to 8, not 16


def test_sharded_solver_spec():
    spec = solver_spec("sharded")
    assert spec.sharded and not spec.batched and spec.warm_startable
    assert "sharded" not in SOLVERS          # not a user-pickable block solver
    assert "sharded" not in WARM_START_SOLVERS  # no vmapped W0 stacks
    with pytest.raises(ValueError, match="unknown solver"):
        solver_spec("nope")


# --------------------------------------------------- shard_prox kernels


@pytest.mark.parametrize("rl,b", [(8, 8), (16, 24), (32, 128), (8, 136)])
def test_shard_prox_pallas_vs_ref(rl, b):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((rl, b)))
    u = jnp.asarray(rng.standard_normal((rl, b)))
    z = jnp.asarray(rng.standard_normal((rl, b)))
    t = 0.3
    zr, ur, rp2, rd2 = fused_prox_ref(x, u, z, t)
    zp, up, acc = fused_prox_pallas(x, u, z, jnp.asarray(t), interpret=True)
    np.testing.assert_allclose(np.asarray(zp), np.asarray(zr), atol=1e-12)
    np.testing.assert_allclose(np.asarray(up), np.asarray(ur), atol=1e-12)
    np.testing.assert_allclose(float(acc[0, 0]), float(rp2), rtol=1e-10)
    np.testing.assert_allclose(float(acc[0, 1]), float(rd2), rtol=1e-10)


def test_shard_prox_row_tiled_accumulation():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((32, 16)))
    u = jnp.asarray(rng.standard_normal((32, 16)))
    z = jnp.asarray(rng.standard_normal((32, 16)))
    _, _, rp2, rd2 = fused_prox_ref(x, u, z, 0.2)
    _, _, acc = fused_prox_pallas(
        x, u, z, jnp.asarray(0.2), row_tile=8, interpret=True
    )  # 4 grid steps accumulate into one (1, 2) block
    np.testing.assert_allclose(float(acc[0, 0]), float(rp2), rtol=1e-10)
    np.testing.assert_allclose(float(acc[0, 1]), float(rd2), rtol=1e-10)


# ------------------------------------------------- shard-direct gather


def test_shard_gather_dense_matches_pad():
    from repro.core.jax_compat import local_device_mesh
    from repro.core.solvers.sharded import mesh_axis_size
    from repro.stream.materialize import shard_gather

    rng = np.random.default_rng(0)
    S = random_covariance(rng, 30)
    comp = np.arange(3, 25)  # b=22 -> pads to 24 on 1 shard, 64 on 8
    mesh = local_device_mesh("data")
    arr = np.asarray(shard_gather(S, comp, mesh))
    bp = sharded_pad_size(comp.size, mesh_axis_size(mesh))
    assert arr.shape == (bp, bp)
    np.testing.assert_allclose(arr[: comp.size, : comp.size], S[np.ix_(comp, comp)])
    pad = np.arange(comp.size, bp)
    assert (arr[pad, pad] == 1.0).all()
    assert arr[comp.size :, : comp.size].sum() == 0.0


def test_materialize_deferred_oversize():
    """Oversize components keep NO host block; gathers recompute from X."""
    from repro.stream import stream_screen

    rng = np.random.default_rng(0)
    n, p = 64, 48
    f = rng.standard_normal((n, 1))
    X = 0.3 * rng.standard_normal((n, p))
    X[:, :30] += f * (0.8 + 0.2 * rng.random(30))
    lam = 0.1
    full = stream_screen(X, [lam])
    deferred = stream_screen(X, [lam], oversize=20)
    assert counts("stream.").get("stream.deferred_components", 0) >= 1
    # same labels, and every gather identical to the materialized blocks
    np.testing.assert_array_equal(full.labels[0], deferred.labels[0])
    from repro.core.components import component_lists

    for comp in component_lists(full.labels[0]):
        if comp.size == 1:
            continue
        np.testing.assert_allclose(
            deferred.S.gather_block(comp), full.S.gather_block(comp), atol=1e-12
        )
        np.testing.assert_allclose(
            deferred.S.gather_block_rows(comp[:3], comp),
            full.S.gather_block(comp)[:3, :],
            atol=1e-12,
        )


# ----------------------------------------- planner / engine integration


def test_oversize_threshold_model():
    # 8 buffers * 8 bytes * b^2 <= budget  ->  b = sqrt(budget/64)
    assert blocks_mod.oversize_threshold(64.0) == int(
        np.sqrt(64 * 2**20 / 64)
    )
    assert blocks_mod.oversize_threshold(0.001) >= 1


def test_resolve_oversize():
    from repro.engine.api import resolve_oversize

    assert resolve_oversize(None, None, np.float64) is None
    assert resolve_oversize(123, None, np.float64) == 123
    assert resolve_oversize(123, 64.0, np.float64) == 123  # explicit wins
    assert resolve_oversize(None, 64.0, np.float64) == blocks_mod.oversize_threshold(64.0)
    # "auto" on CPU: backend reports no memory -> route disabled
    assert resolve_oversize(None, "auto", np.float64) is None
    with pytest.raises(ValueError, match="route=True"):
        resolve_oversize(123, None, np.float64, route=False)


def test_oversize_bucket_has_no_host_blocks():
    from repro.engine.planner import build_plan_incremental

    rng = np.random.default_rng(0)
    S = random_covariance(rng, 24)
    lam = lambda_between_edges(S, 0.2)  # dense-ish: one big component
    plan, _ = build_plan_incremental(S, lam, np.zeros(24, dtype=np.int64) , oversize=10)
    # labels all-zero is the single-component case (it IS connected here in
    # spirit; the classifier is bypassed by the oversize short-circuit)
    big = [b for b in plan.buckets if b.structure == "oversize"]
    assert big and all(b.blocks is None for b in big)


def test_engine_oversize_route_equivalence():
    reset("solver.oversize")
    from repro.core.glasso import glasso

    rng = np.random.default_rng(3)
    S = random_covariance(rng, 26)
    lam = lambda_between_edges(S, 0.3)
    base = glasso(S, lam, solver="admm", tol=1e-9)
    over = glasso(S, lam, solver="admm", tol=1e-9, oversize_threshold=12)
    np.testing.assert_allclose(over.Theta, base.Theta, atol=1e-6)
    if "oversize" in over.route_mix:
        assert over.oversize["dispatched"] >= 1
        assert counts("solver.oversize.")["solver.oversize.dispatched"] >= 1
        assert over.noniterative_fraction > 0.0


def test_path_oversize_warm_reuse():
    """A reused oversize bucket warm-starts from its previous solution."""
    from repro.core.glasso import glasso_path

    rng = np.random.default_rng(5)
    S = random_covariance(rng, 24)
    lams = [lambda_between_edges(S, 0.45), lambda_between_edges(S, 0.4)]
    res = glasso_path(S, lams, solver="admm", tol=1e-9, oversize_threshold=10)
    ref = glasso_path(S, lams, solver="admm", tol=1e-9)
    for r, b in zip(res, ref):
        np.testing.assert_allclose(r.Theta, b.Theta, atol=1e-6)


# ------------------------------------------------------ serving admission


def test_serving_oversize_admission():
    """An oversize request is admitted, skips the synchronous fast path,
    solves via the batcher's sharded group, and reports its counters."""
    from repro.core.glasso import glasso
    from repro.launch.serve_glasso import GlassoServer, serve_stats

    rng = np.random.default_rng(7)
    S = random_covariance(rng, 22)
    lam = lambda_between_edges(S, 0.3)
    ref = glasso(S, lam, solver="admm", tol=1e-9)
    reset("serve")
    with GlassoServer(solver="admm", tol=1e-9, oversize_threshold=10) as srv:
        res = srv.submit(S, lam).result(timeout=600)
    np.testing.assert_allclose(res.Theta, ref.Theta, atol=1e-6)
    if "oversize" in res.route_mix:
        assert res.oversize["dispatched"] >= 1
        stats = serve_stats()
        assert stats.get("serve.fastpath_requests", 0) == 0  # queued, not sync
        assert stats["solver.oversize.dispatched"] >= 1


# ------------------------------------------------ executor placement cost


def test_place_weighs_routes_not_just_size():
    """LPT placement must weight device cost by route: a chordal bucket
    solves on the HOST and must not claim a device's worth of b^3."""
    from repro.engine.executor import BucketExecutor

    ex = BucketExecutor(devices=["d0", "d1"])
    mk = lambda size, n, structure: blocks_mod.Bucket(
        size=size,
        comps=[np.arange(size)] * n,
        blocks=np.zeros((n, size, size)),
        structure=structure,
    )
    # route-aware costs: chordal -> 0, closed_form -> n*b^2, general -> n*b^3
    assert ex._bucket_cost(mk(16, 2, "chordal")) == 0.0
    assert ex._bucket_cost(mk(16, 2, "tree")) == 2 * 16.0**2
    assert ex._bucket_cost(mk(16, 2, "general")) == 2 * 16.0**3
    assert ex._bucket_cost(
        blocks_mod.Bucket(size=64, comps=[np.arange(64)], blocks=None,
                          structure="oversize")
    ) == 0.0
    # two iterative buckets of equal size + one huge chordal bucket: the
    # iterative pair must land on DIFFERENT devices (the chordal bucket is
    # free); a size-only model would pair one iterative with the chordal.
    chordal_big = mk(32, 4, "chordal")
    it_a = mk(16, 1, "general")
    it_b = mk(16, 1, "general")
    placed = ex._place([chordal_big, it_a, it_b])
    assert placed[1] != placed[2]
    # with routing off, everything is iterative again
    ex_off = BucketExecutor(devices=["d0", "d1"], route=False)
    assert ex_off._bucket_cost(mk(16, 2, "chordal")) == 2 * 16.0**3

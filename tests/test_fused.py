"""Bitwise property tests for the fused wave packer (DESIGN.md Section 16).

The wave packer's contract is EXACTNESS, not closeness: re-packing small
iterative buckets across bucket boundaries into size-binned megabatches and
solving each bin with one ``kernels.bucket_glasso`` launch must reproduce the
per-bucket unfused dispatches bit for bit (``==`` / ``np.array_equal``, the
repo's bitwise gate — -0.0 == +0.0 by design).  That rests on three pinned
invariants, each exercised here:

* bin re-padding with an identity diagonal is screened-exact and the
  convergence scale is injected at the SOURCE shape;
* cold lanes synthesize the warm pair the solver would have built, so warm
  and cold source buckets share one executable;
* no launch has leading dim 1 (``waves.min_batch2``) — XLA's unit-batch
  codegen differs by 1 ulp, the only batch-size dependence there is.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import EngineOptions, glasso, glasso_path
from repro.core.instrument import count, reset
from repro.engine.registry import ROUTES, set_route
from repro.engine.waves import FUSED_BINS, fused_bin


def planted_general_blocks(sizes, seed=0, cross=0.0):
    """Block-diagonal S whose blocks are chordless cycles (structure
    "general" for size >= 4, so they route to the iterative tail).  Entries
    are dyadic (multiples of 1/64) so |S_ij| == lam ties are exact in every
    cc backend's arithmetic.  ``cross`` plants dyadic couplings between
    consecutive blocks — below-threshold at high lambda, merging at low."""
    rng = np.random.default_rng(seed)
    p = int(sum(sizes))
    S = np.zeros((p, p))
    off = 0
    starts = []
    for b in sizes:
        starts.append(off)
        for i in range(b):
            j = (i + 1) % b
            mag = rng.integers(24, 33) / 64.0  # in [0.375, 0.5], dyadic
            sgn = 1.0 if rng.random() < 0.5 else -1.0
            S[off + i, off + j] = S[off + j, off + i] = sgn * mag
        off += b
    if cross:
        for a, b in zip(starts, starts[1:]):
            S[a, b] = S[b, a] = cross
    np.fill_diagonal(S, 1.0)
    return S


MIXED_SIZES = [4, 4, 4, 5, 7, 7, 12, 13, 20, 40]  # spans every bin, and
# includes single-block buckets (5, 12, 13, 20, 40) — the min-batch-2 rule


def _path_bitwise_equal(pa, pb):
    for ra, rb in zip(pa, pb):
        assert np.array_equal(ra.labels, rb.labels)
        if not np.array_equal(ra.Theta, rb.Theta):
            return False
    return True


@pytest.mark.parametrize("backend", ("host", "jax", "pallas", "shard_map"))
def test_fused_bitwise_equals_unfused_per_backend(backend):
    """One plan step, mixed bucket sizes, a dyadic tie |S_ij| == lam: the
    fused megabatch reproduces the per-bucket dispatches bit for bit under
    every screening backend."""
    S = planted_general_blocks(MIXED_SIZES, seed=1, cross=0.25)
    lam = 0.25  # == the planted cross coupling: an exact eq.-(4) tie
    base = EngineOptions(solver="bcd", cc_backend=backend)
    r_un = glasso(S, lam, options=base)
    r_f = glasso(S, lam, options=base.replace(fused=True))
    assert np.array_equal(r_un.labels, r_f.labels)
    assert np.array_equal(r_un.Theta, r_f.Theta)


def test_fused_warm_path_with_midgrid_merges_bitwise():
    """A descending grid whose components MERGE mid-path (cross couplings
    activate): warm-started fused == warm-started unfused bitwise at every
    grid point — reused-bucket warm stacks, merged-component blockwise
    inverses, and cold first points all pack transparently."""
    S = planted_general_blocks([4, 5, 6, 7, 4, 9], seed=2, cross=0.25)
    lams = [0.45, 0.35, 0.25, 0.2]  # merges activate at the 0.25 tie point
    opts = EngineOptions(solver="bcd", solver_opts={"tol": 1e-7})
    p_un = glasso_path(S, lams, options=opts)
    p_f = glasso_path(S, lams, options=opts.replace(fused=True))
    # sanity: the grid really merges (fewer components at the tail)
    n_first = len(np.unique(p_un[0].labels))
    n_last = len(np.unique(p_un[-1].labels))
    assert n_last < n_first
    assert _path_bitwise_equal(p_un, p_f)


def test_fused_solver_and_route_are_bitwise_too():
    """The two other opt-in surfaces — solver="fused_bcd" and
    registry.set_route("general", "fused") — produce the same bits as the
    plain unfused solve."""
    S = planted_general_blocks([4, 4, 6, 11], seed=3)
    lam = 0.3
    r_un = glasso(S, lam, options=EngineOptions(solver="bcd"))
    r_solver = glasso(S, lam, options=EngineOptions(solver="fused_bcd"))
    assert np.array_equal(r_un.Theta, r_solver.Theta)
    set_route("general", "fused")
    try:
        r_route = glasso(S, lam, options=EngineOptions(solver="bcd"))
    finally:
        set_route("general", "iterative")
    assert np.array_equal(r_un.Theta, r_route.Theta)


def test_single_lane_buckets_fuse_bitwise():
    """Buckets of ONE block each (every size unique) stress the
    min-batch-2 rule on both arms: a fused megabatch of singletons must
    equal the unfused one-bucket dispatches."""
    S = planted_general_blocks([4, 5, 6, 7], seed=4)
    r_un = glasso(S, 0.3, options=EngineOptions(solver="bcd"))
    r_f = glasso(S, 0.3, options=EngineOptions(solver="bcd", fused=True))
    assert np.array_equal(r_un.Theta, r_f.Theta)


def test_fused_counters_and_dispatch_collapse():
    """One launch per occupied bin per wave: solver.fused.dispatches equals
    the number of occupied bins, blocks_packed counts every general block,
    and the dispatch stage is attributed on the result."""
    sizes = MIXED_SIZES
    S = planted_general_blocks(sizes, seed=5)
    bins_occupied = {fused_bin(s) for s in sizes}
    reset("solver.fused.")
    reset("engine.dispatch.")
    r = glasso(S, 0.3, options=EngineOptions(solver="bcd", fused=True))
    assert count("solver.fused.dispatches") == len(bins_occupied)
    assert count("solver.fused.blocks_packed") == len(sizes)
    assert count("engine.dispatch.count") >= len(bins_occupied)
    assert count("engine.dispatch.us") > 0
    assert r.dispatch_seconds > 0.0
    assert "dispatch_us" in r.stages_us


def test_fused_options_and_registry_surface():
    assert "fused" in ROUTES
    for s in (1, 8, 9, 64):
        b = fused_bin(s)
        assert b in FUSED_BINS and b >= s
    assert fused_bin(65) is None
    with pytest.raises(ValueError, match="fused must be"):
        EngineOptions(fused="yes")
    # fused=True demands the fused_stack capability ("pg" lacks it)
    from repro.engine.api import Engine

    with pytest.raises(ValueError, match="fused_stack"):
        Engine(options=EngineOptions(solver="pg", fused=True))


def test_bucket_glasso_pallas_interpret_matches_ref():
    """The Pallas kernel (interpret mode off-TPU) and the vmapped jnp
    reference agree bitwise lane for lane on a warm/cold mixed stack."""
    from repro.kernels.bucket_glasso import fused_bcd_ref_stack
    from repro.kernels.bucket_glasso.bucket_glasso import fused_bcd_pallas

    rng = np.random.default_rng(6)
    N, b = 3, 8
    A = rng.standard_normal((N, b, b)) * (rng.random((N, b, b)) < 0.4)
    S = A @ A.transpose(0, 2, 1) / b + np.eye(b)[None]
    lams = np.full(N, 0.3)
    scales = np.abs(S - np.eye(b)[None] * np.diagonal(
        S, axis1=1, axis2=2
    )[:, None, :] * np.eye(b)[None]).mean(axis=(1, 2)) + 1e-12
    W0 = S + lams[:, None, None] * np.eye(b)[None]
    T0 = np.broadcast_to(np.eye(b), (N, b, b)).copy()
    args = tuple(jnp.asarray(x) for x in (S, lams, scales, W0, T0))
    t_ref, sw_ref = fused_bcd_ref_stack(*args)
    t_pl, sw_pl = fused_bcd_pallas(
        args[0], args[1].reshape(N, 1), args[2].reshape(N, 1),
        args[3], args[4], interpret=True,
    )
    assert np.array_equal(np.asarray(t_ref), np.asarray(t_pl))
    assert np.array_equal(
        np.asarray(sw_ref), np.asarray(sw_pl).reshape(N)
    )
    # and the reference really solves the problem: KKT spot check
    from repro.core.solvers.kkt import kkt_residual

    for i in range(N):
        res = float(kkt_residual(jnp.asarray(S[i]), t_ref[i], 0.3))
        assert res < 1e-4


def test_fused_from_serving_routes_unchanged():
    """A "fused"-routed structure reaching the serving batcher falls through
    to its iterative group — same bits as the offline solve."""
    from repro.launch.serve_glasso import GlassoServer

    S = planted_general_blocks([4, 6, 5], seed=7)
    lam = 0.3
    opts = EngineOptions(solver="bcd", output="dense")
    offline = glasso(S, lam, options=opts)
    set_route("general", "fused")
    try:
        with GlassoServer(options=opts) as server:
            served = server.submit(S, lam).result(timeout=300)
    finally:
        set_route("general", "iterative")
    assert np.array_equal(np.asarray(offline.Theta), np.asarray(served.Theta))

"""Covariance substrate: estimators + synthetic generators."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.covariance import (
    impute_missing,
    lambda_interval_for_k,
    microarray_like,
    paper_synthetic,
    sample_correlation,
    sample_covariance,
    streaming_covariance,
)
from repro.core import thresholded_components


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 50), p=st.integers(1, 20), seed=st.integers(0, 1000))
def test_covariance_matches_numpy(n, p, seed):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    S = np.asarray(sample_covariance(jnp.asarray(X)))
    np.testing.assert_allclose(S, np.cov(X, rowvar=False, bias=True), atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(4, 200),
    p=st.integers(1, 16),
    chunk=st.integers(3, 64),
    seed=st.integers(0, 1000),
)
def test_streaming_matches_direct(n, p, chunk, seed):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    a = np.asarray(streaming_covariance(jnp.asarray(X), chunk=chunk))
    b = np.asarray(sample_covariance(jnp.asarray(X)))
    np.testing.assert_allclose(a, b, atol=1e-8)


def test_correlation_properties():
    rng = np.random.default_rng(0)
    X = rng.standard_normal((40, 8)) * rng.uniform(0.1, 10.0, size=(1, 8))
    R = np.asarray(sample_correlation(jnp.asarray(X)))
    np.testing.assert_allclose(np.diag(R), 1.0, atol=1e-10)
    assert np.abs(R).max() <= 1.0 + 1e-9
    # paper Section 4.2: correlation input => all nodes isolated at lambda >= 1
    _, stats = thresholded_components(R, 1.0)
    assert stats.n_isolated == 8


def test_imputation():
    X = np.array([[1.0, np.nan], [3.0, 4.0], [np.nan, 8.0]])
    Xi = np.asarray(impute_missing(jnp.asarray(X)))
    np.testing.assert_allclose(Xi[2, 0], 2.0)
    np.testing.assert_allclose(Xi[0, 1], 6.0)
    assert not np.isnan(Xi).any()


def test_paper_synthetic_calibration():
    """sigma is calibrated so 1.25 * max off-block |noise| == 1 (Section 4.1)."""
    K, p1 = 3, 8
    S = paper_synthetic(K, p1, seed=0)
    block_id = np.repeat(np.arange(K), p1)
    off = block_id[:, None] != block_id[None, :]
    np.testing.assert_allclose(np.abs(S[off]).max(), 0.8, atol=1e-12)
    lam_min, lam_max = lambda_interval_for_k(S, K)
    assert lam_min >= 0.8 - 1e-9  # off-block edges all below lambda_min
    lam_mid = 0.5 * (lam_min + lam_max)
    _, stats = thresholded_components(S, lam_mid)
    assert stats.n_components == K
    assert stats.max_comp == p1


def test_microarray_like_profile():
    X = microarray_like(60, 300, seed=0)
    assert X.shape == (60, 300)
    R = np.asarray(sample_correlation(jnp.asarray(X)))
    # moderate lambda splits into many components with a non-trivial largest
    _, stats = thresholded_components(R, 0.5)
    assert stats.n_components > 10
    assert stats.max_comp >= 4

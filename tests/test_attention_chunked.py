"""Chunked (XLA-flash) attention must match the materialized reference."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import _sdpa, _sdpa_chunked, causal_mask


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("Sq,Skv,chunk", [(64, 64, 16), (48, 80, 16), (33, 33, 8)])
def test_chunked_matches_dense(Sq, Skv, chunk, causal):
    if causal and Sq != Skv:
        pytest.skip("causal aligned only")
    rng = np.random.default_rng(0)
    B, H, Hkv, d = 2, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, H, Sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Skv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Skv, d)), jnp.float32)
    scale = d**-0.5
    mask = causal_mask(Sq, Skv) if causal else None
    ref = _sdpa(q, k, v, scale=scale, mask=mask)
    out = _sdpa_chunked(q, k, v, scale=scale, causal=causal, q_chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    sq=st.integers(4, 70),
    chunk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 50),
)
def test_chunked_property(sq, chunk, seed):
    rng = np.random.default_rng(seed)
    B, H, d = 1, 2, 8
    q = jnp.asarray(rng.standard_normal((B, H, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, sq, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, sq, d)), jnp.float32)
    ref = _sdpa(q, k, v, scale=0.3, mask=causal_mask(sq, sq))
    out = _sdpa_chunked(q, k, v, scale=0.3, causal=True, q_chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

"""Distributed CC + zero-communication bucket solve (single-device mesh here;
the 256/512-device semantics are exercised by launch/dryrun.py in its own
process with faked devices)."""

import jax
import jax.numpy as jnp
import numpy as np

from conftest import lambda_between_edges, random_covariance
from repro.core.components import components_from_covariance_host, partitions_equal
from repro.core.distributed import distributed_bucket_solve, distributed_components
from repro.core.solvers import glasso_bcd


def _mesh1():
    from repro.core.jax_compat import make_mesh

    return make_mesh((1,), ("data",))


def test_distributed_components_matches_host():
    rng = np.random.default_rng(0)
    S = random_covariance(rng, 24)
    lam = lambda_between_edges(S, 0.6)
    mesh = _mesh1()
    labels = np.asarray(distributed_components(jnp.asarray(S), lam, mesh))
    ref = components_from_covariance_host(S, lam)
    assert partitions_equal(labels, ref)


def test_distributed_components_padding():
    """p not divisible by the axis size exercises the pad path."""
    rng = np.random.default_rng(1)
    S = random_covariance(rng, 7)
    lam = lambda_between_edges(S, 0.4)
    mesh = _mesh1()
    labels = np.asarray(distributed_components(jnp.asarray(S), lam, mesh))
    assert partitions_equal(labels, components_from_covariance_host(S, lam))


def test_distributed_bucket_solve_matches_vmap():
    rng = np.random.default_rng(2)
    blocks = np.stack([random_covariance(rng, 4) for _ in range(3)])
    lam = 0.25
    mesh = _mesh1()
    out = np.asarray(
        distributed_bucket_solve(blocks, lam, glasso_bcd, mesh, tol=1e-9)
    )
    ref = np.asarray(
        jax.vmap(lambda Sb: glasso_bcd(Sb, lam, tol=1e-9))(jnp.asarray(blocks))
    )
    np.testing.assert_allclose(out, ref, atol=1e-9)
    assert out.shape == (3, 4, 4)

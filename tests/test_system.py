"""End-to-end behaviour of the paper's system: data -> covariance -> screen ->
schedule -> batched block solves -> assembled Theta, validated against the
unscreened baseline and the KKT conditions."""

import jax.numpy as jnp
import numpy as np

from repro.core import glasso, glasso_path, kkt_residual, lambda_for_max_component
from repro.core.components import component_lists
from repro.covariance import (
    microarray_like,
    paper_synthetic,
    lambda_interval_for_k,
    sample_correlation,
)


def test_end_to_end_paper_synthetic():
    K, p1 = 4, 8
    S = paper_synthetic(K, p1, seed=3)
    lam_min, lam_max = lambda_interval_for_k(S, K)
    lam = 0.5 * (lam_min + lam_max)
    res = glasso(S, lam, solver="bcd", tol=1e-9)
    assert res.screen.n_components == K
    assert res.block_sizes == [p1] * K
    kkt = float(kkt_residual(jnp.asarray(S), jnp.asarray(res.Theta), lam, zero_tol=1e-9))
    assert kkt < 1e-5
    base = glasso(S, lam, solver="bcd", screen=False, tol=1e-9)
    np.testing.assert_allclose(res.Theta, base.Theta, atol=1e-5)


def test_end_to_end_microarray_pipeline():
    X = microarray_like(50, 160, seed=1)
    R = np.asarray(sample_correlation(jnp.asarray(X)))
    lam = lambda_for_max_component(R, 32)  # capacity-bounded split (conseq. 5)
    res = glasso(R, lam, solver="admm", p_max=32, tol=1e-8)
    assert res.screen.max_comp <= 32
    # every solved component is PD and satisfies KKT blockwise
    for comp in component_lists(res.labels):
        if len(comp) == 1:
            continue
        blk_S = R[np.ix_(comp, comp)]
        blk_T = res.Theta[np.ix_(comp, comp)]
        assert np.all(np.linalg.eigvalsh(blk_T) > 0)
        kkt = float(
            kkt_residual(jnp.asarray(blk_S), jnp.asarray(blk_T), lam, zero_tol=1e-9)
        )
        assert kkt < 1e-4


def test_lambda_path_merges_monotonically():
    S = paper_synthetic(3, 6, seed=5)
    lam_min, lam_max = lambda_interval_for_k(S, 3)
    lams = [lam_max * 1.2, 0.5 * (lam_min + lam_max), lam_min * 0.7]
    results = glasso_path(S, lams, solver="bcd", tol=1e-8)
    ncomps = [r.screen.n_components for r in results]
    assert ncomps[0] >= ncomps[1] >= ncomps[2]
    assert ncomps[1] == 3

"""Minimal offline stand-in for the ``hypothesis`` API surface this suite uses.

The real library is listed in requirements-dev.txt and is preferred whenever
it is importable; this shim only exists so the tier-1 suite still collects and
runs in hermetic containers with no network access.  It implements exactly the
subset the tests consume:

    from hypothesis import given, settings, strategies as st
    st.integers(lo, hi), st.floats(lo, hi), st.sampled_from(seq),
    st.lists(elem, min_size=..., max_size=...)

Examples are drawn deterministically (seeded by the test's qualified name), so
a run is reproducible; example 0 is the "minimal" corner of every strategy,
which is where most of hypothesis's shrunk counterexamples live anyway
(empty-ish lists, lower bounds, density 0.0).  No shrinking is attempted — on
failure the falsifying kwargs are attached to the exception.
"""

from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

IS_SHIM = True


class _Strategy:
    def __init__(self, minimal, draw):
        self._minimal = minimal
        self._draw = draw

    def minimal(self):
        return self._minimal()

    def draw(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(
        lambda: min_value,
        lambda rng: int(rng.integers(min_value, max_value + 1)),
    )


def floats(min_value, max_value):
    return _Strategy(
        lambda: float(min_value),
        lambda rng: float(rng.uniform(min_value, max_value)),
    )


def booleans():
    return _Strategy(
        lambda: False,
        lambda rng: bool(rng.integers(2)),
    )


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(
        lambda: seq[0],
        lambda rng: seq[int(rng.integers(len(seq)))],
    )


def lists(elements, *, min_size=0, max_size=10):
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(size)]

    return _Strategy(lambda: [elements.minimal() for _ in range(min_size)], draw)


_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    """Decorator-factory; only max_examples is honoured (deadline et al. are
    timing/shrinking knobs with no meaning here)."""

    def apply(fn):
        fn._shim_max_examples = max_examples
        return fn

    return apply


def given(**strategy_kwargs):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **fixture_kwargs):
            max_examples = getattr(
                wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES
            )
            seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode()
            ) & 0xFFFFFFFF
            rng = np.random.default_rng(seed)
            for k in range(max_examples):
                if k == 0:
                    drawn = {n: s.minimal() for n, s in strategy_kwargs.items()}
                else:
                    drawn = {n: s.draw(rng) for n, s in strategy_kwargs.items()}
                try:
                    fn(*args, **drawn, **fixture_kwargs)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"falsifying example (shim, #{k}): {drawn!r}"
                    ) from e

        # hide the strategy parameters from pytest's fixture resolver
        sig = inspect.signature(fn)
        params = [
            p for n, p in sig.parameters.items() if n not in strategy_kwargs
        ]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper

    return deco


def install(sys_modules):
    """Register this shim as ``hypothesis`` + ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists"):
        setattr(st, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.IS_SHIM = True
    st.IS_SHIM = True
    sys_modules["hypothesis"] = mod
    sys_modules["hypothesis.strategies"] = st

"""Theorem 2: vertex partitions are nested along the lambda path (components
only merge as lambda decreases) — for both the thresholded covariance graph
(by construction) and the estimated concentration graph (via Theorem 1)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import lambda_between_edges, random_covariance
from repro.core import glasso_path, is_refinement, thresholded_components


@settings(max_examples=20, deadline=None)
@given(p=st.integers(4, 20), seed=st.integers(0, 10_000))
def test_thresholded_partitions_nested(p, seed):
    rng = np.random.default_rng(seed)
    S = random_covariance(rng, p)
    lams = sorted(
        (lambda_between_edges(S, q) for q in (0.1, 0.3, 0.5, 0.7, 0.9)), reverse=True
    )
    labels = [thresholded_components(S, lam)[0] for lam in lams]
    for fine, coarse in zip(labels[:-1], labels[1:]):
        assert is_refinement(fine, coarse)


def test_estimated_partitions_nested_via_solve():
    rng = np.random.default_rng(11)
    S = random_covariance(rng, 10)
    lams = sorted(
        (lambda_between_edges(S, q) for q in (0.3, 0.55, 0.8)), reverse=True
    )
    results = glasso_path(S, lams, solver="admm", tol=1e-8)
    parts = []
    for res in results:
        A = np.abs(res.Theta) > 0
        np.fill_diagonal(A, False)
        from repro.core.components import connected_components_host

        parts.append(connected_components_host(A))
    for fine, coarse in zip(parts[:-1], parts[1:]):
        assert is_refinement(fine, coarse)


def test_path_warm_start_matches_cold():
    rng = np.random.default_rng(5)
    S = random_covariance(rng, 8)
    lams = [lambda_between_edges(S, q) for q in (0.8, 0.5, 0.3)]
    warm = glasso_path(S, lams, solver="bcd", warm_start=True, tol=1e-9)
    cold = glasso_path(S, lams, solver="bcd", warm_start=False, tol=1e-9)
    for rw, rc in zip(warm, cold):
        np.testing.assert_allclose(rw.Theta, rc.Theta, atol=1e-5)

"""Joint multi-class graphical lasso: exact hybrid screening + solver stack.

The property core: the hybrid-thresholded union partition must equal the
brute-force joint solution's union-support partition (the K-class
Theorem 1, Tang et al. arXiv:1503.02128) on small (K <= 3, p <= 40)
problems across BOTH penalty regimes; exact per-class ties
|S^(k)_ij| == lam1 are exercised with the dyadic-integer trick from
test_stream (integer X, power-of-two row count — every covariance entry is
exact in f64 under any summation order, so lam1 can be an attained
off-diagonal value and all implementations agree bit-for-bit); the union
partition is identical through all four registered cc backends and through
the out-of-core streamed screen.  The solver side: lam2 = 0 decouples into
K independent ``glasso`` runs, the joint prox kernel matches its jnp
reference in Pallas interpret mode, the joint-forest fast path is verified
and falls back (never corrupts), and the joint KKT verifier accepts ADMM
output while rejecting perturbations.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import lambda_between_edges
from repro.core import glasso
from repro.core.components import component_lists, partitions_equal
from repro.core.instrument import count, reset
from repro.joint import (
    joint_glasso,
    joint_kkt_residual,
    joint_stream_screen,
    joint_thresholded_components,
    joint_union_adjacency,
)
from repro.joint.screen import pair_excess
from repro.stream.unionfind import StreamingUnionFind

BACKENDS = ("host", "jax", "pallas", "shard_map")
PENALTIES = ("group", "fused")
CFG = {"tile": 32, "chunk": 16, "pair_batch": 3}


def _class_covs(rng, K, p, n=32):
    """K moderately-correlated class covariances over shared variables."""
    base = rng.standard_normal((n, p)) * (0.3 + rng.random(p))
    out = []
    for _ in range(K):
        X = base + 0.7 * rng.standard_normal((n, p))
        Xc = X - X.mean(axis=0)
        out.append(Xc.T @ Xc / n)
    return out


def _dense_S(X):
    Xc = X - X.mean(axis=0)
    return Xc.T @ Xc / X.shape[0]


def _integer_Xs(rng, K, n, p):
    assert n & (n - 1) == 0
    return [
        rng.integers(-4, 5, size=(n, p)).astype(np.float64) for _ in range(K)
    ]


def _support_partition(Theta, p, tol=1e-7):
    """Union-support partition of a (K, p, p) solution stack."""
    adj = (np.abs(Theta) > tol).any(axis=0)
    np.fill_diagonal(adj, False)
    iu, ju = np.nonzero(np.triu(adj, 1))
    uf = StreamingUnionFind(p)
    uf.union_edges(iu, ju)
    return uf.labels()


def _lam_pair(Ss, q):
    """(lam1, lam2) at a quantile midpoint of the class-max |S_ij|."""
    M = np.max(np.abs(np.stack(Ss)), axis=0)
    lam1 = lambda_between_edges(M, q)
    return lam1, 0.4 * lam1


# ---------------------------------------------------------------------------
# screen == brute-force joint support partition (the K-class Theorem 1)
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(
    K=st.sampled_from([2, 3]),
    p=st.sampled_from([12, 20]),
    seed=st.integers(0, 10_000),
    q=st.floats(0.55, 0.9),
    penalty=st.sampled_from(PENALTIES),
)
def test_screened_partition_equals_bruteforce_support(K, p, seed, q, penalty):
    rng = np.random.default_rng(seed)
    Ss = _class_covs(rng, K, p)
    lam1, lam2 = _lam_pair(Ss, q)
    labels, stats = joint_thresholded_components(
        Ss, lam1, lam2, penalty=penalty
    )
    brute = joint_glasso(
        Ss, lam1, lam2, penalty=penalty, screen=False, route=False, tol=1e-10
    )
    support_labels = _support_partition(brute.Theta, p)
    assert partitions_equal(labels, support_labels)
    # and the screened solve reproduces the unscreened Theta exactly
    screened = joint_glasso(Ss, lam1, lam2, penalty=penalty, tol=1e-10)
    assert np.abs(screened.Theta - brute.Theta).max() < 1e-6
    assert partitions_equal(screened.labels, labels)


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    penalty=st.sampled_from(PENALTIES),
)
def test_exact_per_class_ties_are_not_edges(seed, penalty):
    """lam1 set to an attained |S^(k)_ij|: the tie is NOT an edge (strict
    rule), every backend and the streamed screen agree bit-for-bit, and the
    screened solve still equals the unscreened one (the tie lambda is a
    boundary of the screen, not of the optimization)."""
    rng = np.random.default_rng(seed)
    K, n, p = 3, 16, 30
    Xs = _integer_Xs(rng, K, n, p)
    Ss = [_dense_S(X) for X in Xs]
    vals = np.abs(Ss[0][np.triu_indices(p, 1)])
    vals = np.sort(vals[vals > 0])
    lam1 = float(vals[vals.size // 2])  # an exact dyadic off-diagonal value
    lam2 = 0.25  # dyadic
    assert (np.abs(Ss[0][np.triu_indices(p, 1)]) == lam1).any()
    labels, stats = joint_thresholded_components(Ss, lam1, lam2, penalty=penalty)
    # independent oracle: evaluate the rule pairwise from the definition
    iu, ju = np.triu_indices(p, 1)
    svec = np.stack([S[iu, ju] for S in Ss])
    edge = pair_excess(svec, lam1, lam2, penalty=penalty) > 0.0
    uf = StreamingUnionFind(p)
    uf.union_edges(iu[edge], ju[edge])
    assert partitions_equal(labels, uf.labels())
    assert stats.n_edges == int(edge.sum())
    # every cc backend and the streamed screen produce the same partition
    for backend in BACKENDS:
        lab_b, _ = joint_thresholded_components(
            Ss, lam1, lam2, penalty=penalty, backend=backend,
            **({"block": 8} if backend == "pallas" else {}),
        )
        assert partitions_equal(labels, lab_b), backend
    sc = joint_stream_screen(Xs, lam1, lam2, penalty=penalty, config=CFG)
    assert partitions_equal(labels, sc.labels)
    assert sc.stats.n_edges == stats.n_edges
    # screened == unscreened Theta at the tie lambda (acceptance: "ties
    # included" on the brute-force grid)
    screened = joint_glasso(Ss, lam1, lam2, penalty=penalty, tol=1e-9)
    brute = joint_glasso(
        Ss, lam1, lam2, penalty=penalty, screen=False, route=False, tol=1e-9
    )
    assert np.abs(screened.Theta - brute.Theta).max() < 1e-6


def test_lam2_zero_reduces_to_union_of_per_class_screens(rng):
    Ss = _class_covs(rng, 3, 24)
    lam1, _ = _lam_pair(Ss, 0.7)
    labels, stats = joint_thresholded_components(Ss, lam1, 0.0, penalty="group")
    adj = np.zeros((24, 24), dtype=bool)
    for S in Ss:
        A = np.abs(S) > lam1
        np.fill_diagonal(A, False)
        adj |= A
    iu, ju = np.nonzero(np.triu(adj, 1))
    uf = StreamingUnionFind(24)
    uf.union_edges(iu, ju)
    assert partitions_equal(labels, uf.labels())
    lab_f, _ = joint_thresholded_components(Ss, lam1, 0.0, penalty="fused")
    assert partitions_equal(labels, lab_f)


# ---------------------------------------------------------------------------
# lam2 = 0 decouples into K independent glasso solves
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    penalty=st.sampled_from(PENALTIES),
)
def test_lam2_zero_matches_independent_glasso(seed, penalty):
    rng = np.random.default_rng(seed)
    K, p = 3, 18
    Ss = _class_covs(rng, K, p)
    lam1, _ = _lam_pair(Ss, 0.6)
    res = joint_glasso(Ss, lam1, 0.0, penalty=penalty, tol=1e-9)
    for k in range(K):
        direct = glasso(Ss[k], lam1, solver="admm", tol=1e-9)
        assert np.abs(res.Theta[k] - direct.Theta).max() < 1e-6


# ---------------------------------------------------------------------------
# streamed screen == dense screen, end to end
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    q=st.floats(0.5, 0.9),
    penalty=st.sampled_from(PENALTIES),
)
def test_streamed_joint_matches_dense(seed, q, penalty):
    rng = np.random.default_rng(seed)
    K, n, p = 3, 40, 45  # p not a multiple of tile=32
    Xs = [
        rng.standard_normal((n, p)) * (0.1 + rng.random(p)) for _ in range(K)
    ]
    Ss = [_dense_S(X) for X in Xs]
    lam1, lam2 = _lam_pair(Ss, q)
    d = joint_glasso(Ss, lam1, lam2, penalty=penalty, tol=1e-9)
    s = joint_glasso(
        Xs=Xs, lam1=lam1, lam2=lam2, penalty=penalty, from_data=True,
        stream=CFG, tol=1e-9,
    )
    assert partitions_equal(d.labels, s.labels)
    assert d.route_mix == s.route_mix
    assert np.abs(d.Theta - s.Theta).max() < 1e-6
    assert s.screen.tiles_total > 0
    assert s.screen.candidate_pairs >= s.screen.n_edges


# ---------------------------------------------------------------------------
# routing ladder: joint forest fast path + fallback safety
# ---------------------------------------------------------------------------


def _shared_tree_problem(p=16, K=3):
    """Identical class blocks: a planted tree + singletons — all fast path."""
    Ss = [np.eye(p) * 2.0 for _ in range(K)]
    for k in range(K):
        for i, j, v in [(0, 1, 0.9), (1, 2, -0.8), (2, 3, 0.7), (3, 4, 0.75),
                        (6, 7, 0.85)]:
            Ss[k][i, j] = Ss[k][j, i] = v
    return Ss


@pytest.mark.parametrize("penalty", PENALTIES)
def test_joint_forest_fast_path_exact(penalty):
    Ss = _shared_tree_problem()
    reset("router")
    reset("joint")
    res = joint_glasso(Ss, 0.4, 0.12, penalty=penalty, tol=1e-9)
    assert res.route_mix.get("joint_forest", 0) >= 2  # tree + pair
    assert res.fallbacks == 0
    assert count("joint.closed_form_blocks") >= 2
    ref = joint_glasso(Ss, 0.4, 0.12, penalty=penalty, route=False, tol=1e-10)
    assert res.route_mix != ref.route_mix  # unrouted stays joint_general
    assert np.abs(res.Theta - ref.Theta).max() < 1e-6
    # all classes share one solution on identical blocks
    assert np.abs(res.Theta[0] - res.Theta[-1]).max() == 0.0


def test_near_identical_blocks_fall_back_not_corrupt():
    """Blocks equal to 1e-6 (past the classifier's 1e-12 identity gate but
    planted to LOOK shared): the classifier must refuse the fast path, or —
    if forced through set_route — verification must repair it."""
    Ss = _shared_tree_problem()
    Ss[1] = Ss[1].copy()
    Ss[1][0, 1] = Ss[1][1, 0] = 0.9 + 1e-6  # not identical anymore
    reset("router")
    res = joint_glasso(Ss, 0.4, 0.12, penalty="group", tol=1e-9)
    # the perturbed component must NOT be classified joint_forest
    assert res.route_mix.get("joint_general", 0) >= 1
    ref = joint_glasso(Ss, 0.4, 0.12, penalty="group", route=False, tol=1e-10)
    assert np.abs(res.Theta - ref.Theta).max() < 1e-6


def test_verify_tail_passes_and_repairs(rng):
    """On well-scaled problems the ADMM tail clears the opt-in exact joint
    KKT gate with zero fallbacks; a starved iteration budget trips the gate
    and the counted fallback re-dispatch still lands on the right answer."""
    K, p = 3, 14
    Ss = _class_covs(rng, K, p)
    lam1, lam2 = _lam_pair(Ss, 0.55)
    reset("joint")
    res = joint_glasso(
        Ss, lam1, lam2, penalty="group", verify_tail=True, tol=1e-9
    )
    assert res.fallbacks == 0
    ref = joint_glasso(
        Ss, lam1, lam2, penalty="group", screen=False, route=False, tol=1e-10
    )
    assert np.abs(res.Theta - ref.Theta).max() < 1e-6
    if res.route_mix.get("joint_general", 0):
        reset("joint")
        starved = joint_glasso(
            Ss, lam1, lam2, penalty="group", verify_tail=True, tol=1e-9,
            max_iter=3,
        )
        assert starved.fallbacks > 0
        assert count("joint.fallbacks") == starved.fallbacks
        # the 10x-budget warm re-dispatch repaired the starved blocks
        assert np.abs(starved.Theta - ref.Theta).max() < 1e-5


def test_joint_kkt_verifier_accepts_and_rejects(rng):
    from repro.joint import joint_admm

    import jax.numpy as jnp

    K, b = 3, 10
    Ss = np.stack(_class_covs(rng, K, b, n=24))
    for penalty in PENALTIES:
        for lam2 in (0.0, 0.1):
            Th = np.asarray(
                joint_admm(jnp.asarray(Ss), 0.15, lam2, penalty=penalty, tol=1e-10)
            )
            res = joint_kkt_residual(Ss, Th, 0.15, lam2, penalty=penalty)
            assert res < 1e-7, (penalty, lam2, res)
            bad = Th.copy()
            bad[0, 0, 1] += 0.03
            bad[0, 1, 0] += 0.03
            assert joint_kkt_residual(Ss, bad, 0.15, lam2, penalty=penalty) > 1e-3


# ---------------------------------------------------------------------------
# joint prox kernel: pallas (interpret) == jnp reference
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    K=st.sampled_from([1, 2, 4]),
    penalty=st.sampled_from(PENALTIES),
)
def test_joint_prox_pallas_matches_ref(seed, K, penalty):
    import jax.numpy as jnp

    from repro.kernels.joint_prox import joint_prox_pallas, joint_prox_ref

    rng = np.random.default_rng(seed)
    b = 16
    th, u, zo = (
        jnp.asarray(rng.standard_normal((K, b, b))) for _ in range(3)
    )
    t1, t2 = 0.3 * rng.random() + 0.01, 0.3 * rng.random()
    zn_p, un_p, acc = joint_prox_pallas(
        th, u, zo, jnp.asarray([[t1, t2]]), penalty=penalty, row_tile=8,
        interpret=True,
    )
    zn_r, un_r, rp2, rd2 = joint_prox_ref(th, u, zo, t1, t2, penalty=penalty)
    np.testing.assert_allclose(np.asarray(zn_p), np.asarray(zn_r), atol=1e-12)
    np.testing.assert_allclose(np.asarray(un_p), np.asarray(un_r), atol=1e-12)
    np.testing.assert_allclose(float(acc[0, 0]), float(rp2), rtol=1e-9)
    np.testing.assert_allclose(float(acc[0, 1]), float(rd2), rtol=1e-9)


def test_fused_prox_is_optimal(rng):
    """Directional-derivative optimality of the sort-free TV prox, ties
    included (the convex objective has no descent direction at the prox)."""
    import jax.numpy as jnp

    from repro.kernels.joint_prox import fused_prox

    def obj(z, a, t1, t2):
        K = len(z)
        pen = sum(
            abs(z[i] - z[j]) for i in range(K) for j in range(i + 1, K)
        )
        return 0.5 * np.sum((z - a) ** 2) + t1 * np.sum(np.abs(z)) + t2 * pen

    for _ in range(40):
        K = int(rng.integers(1, 7))
        a = rng.standard_normal(K)
        if K >= 2 and rng.random() < 0.5:
            a[int(rng.integers(0, K))] = a[int(rng.integers(0, K))]
        t1 = float(rng.random() * 0.5)
        t2 = float(rng.random() * 0.5)
        z = np.asarray(fused_prox(jnp.asarray(a)[:, None], t1, t2))[:, 0]
        f0 = obj(z, a, t1, t2)
        for _ in range(25):
            d = rng.standard_normal(K)
            d /= np.linalg.norm(d)
            assert obj(z + 1e-6 * d, a, t1, t2) >= f0 - 1e-12


# ---------------------------------------------------------------------------
# api validation + counters
# ---------------------------------------------------------------------------


def test_joint_glasso_input_validation():
    with pytest.raises(ValueError, match="needs"):
        joint_glasso(lam1=0.5)
    with pytest.raises(ValueError, match="not both"):
        joint_glasso([np.eye(3)], 0.5, Xs=[np.zeros((4, 3))])
    with pytest.raises(ValueError, match="unknown joint penalty"):
        joint_glasso([np.eye(3)], 0.5, penalty="nope")
    with pytest.raises(ValueError, match="share one shape"):
        joint_glasso([np.eye(3), np.eye(4)], 0.5)


def test_union_adjacency_strictness():
    """The hybrid conditions are strict: exact equality is not an edge."""
    S1 = np.eye(2)
    S1[0, 1] = S1[1, 0] = 0.5
    # group, lam2 = 0: |s| == lam1 exactly -> no edge; above -> edge
    assert not joint_union_adjacency([S1, S1], 0.5, 0.0, penalty="group").any()
    assert joint_union_adjacency([S1, S1], 0.499, 0.0, penalty="group").any()
    # fused: K = 2, s = (0.5, 0.5); subset m=2: |1.0| <= 2*lam1 binds at 0.5
    assert not joint_union_adjacency([S1, S1], 0.5, 0.0, penalty="fused").any()
    # group with lam2: soft(0.5, 0.3) = 0.2 per class; sqrt(2)*0.2 vs lam2
    lam2_tie = float(np.sqrt(2) * 0.2)
    adj = joint_union_adjacency([S1, S1], 0.3, lam2_tie + 1e-12, penalty="group")
    assert not adj.any()
    adj = joint_union_adjacency([S1, S1], 0.3, lam2_tie - 1e-9, penalty="group")
    assert adj.any()


def test_result_surface(rng):
    Ss = _shared_tree_problem()
    res = joint_glasso(Ss, 0.4, 0.1, penalty="group", tol=1e-8)
    assert res.K == 3
    assert res.Theta.shape == (3, 16, 16)
    assert res.support.shape == (16, 16)
    assert res.class_support(0).dtype == bool
    assert res.screen is not None and res.screen.n_components >= 2
    assert res.block_sizes == sorted(
        (len(c) for c in component_lists(res.labels) if len(c) > 1),
        reverse=True,
    )


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

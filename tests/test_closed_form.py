"""Closed-form routing ladder correctness: tree/chordal closed forms match
the ADMM oracle on random instances (property tests), exact ties
|S_ij| == lam are handled, adversarial supports fall back to the iterative
tail, and the instrument counters prove every structure class is exercised."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import glasso
from repro.core.instrument import count, route_mix_counts, reset
from repro.core.solvers import glasso_admm
from repro.core.solvers.closed_form import (
    glasso_chordal_host,
    glasso_forest,
    kkt_residual_host,
)
from repro.engine.structure import classify_component


def _tree_edges(rng, b):
    """Random recursive tree on b vertices."""
    return [(i, int(rng.integers(0, i))) for i in range(1, b)]


def _ktree_edges(rng, b, k):
    """Random k-tree (maximal chordal with treewidth k): seed clique of
    k+1 vertices, each later vertex attaches to a random existing k-clique."""
    k = min(k, b - 1)
    cliques = [list(range(k + 1))]
    edges = [(i, j) for i in range(k + 1) for j in range(i)]
    for v in range(k + 1, b):
        base = cliques[int(rng.integers(0, len(cliques)))]
        sub = [base[i] for i in rng.permutation(len(base))[:k]]
        edges.extend((v, u) for u in sub)
        cliques.append(sub + [v])
    return edges


def _covariance_with_support(rng, b, edges, lam, *, offdiag=0.35):
    """S whose strict thresholded support at lam is EXACTLY ``edges``:
    edge entries above lam, non-edges below, diagonally dominant (keeps the
    soft-thresholded matrix PD, the regime where glasso == thresholding)."""
    S = np.zeros((b, b))
    on = set((min(i, j), max(i, j)) for i, j in edges)
    for i in range(b):
        for j in range(i):
            mag = (
                lam + offdiag * rng.uniform(0.4, 1.0)
                if (j, i) in on
                else lam * rng.uniform(0.0, 0.8)
            )
            S[i, j] = S[j, i] = mag * (1 if rng.random() < 0.5 else -1)
    np.fill_diagonal(S, 1.0 + np.abs(S).sum(axis=1))
    return S


# ------------------------------------------------------------ forest


@settings(max_examples=10, deadline=None)
@given(b=st.integers(3, 12), seed=st.integers(0, 10_000))
def test_forest_closed_form_matches_admm(b, seed):
    rng = np.random.default_rng(seed)
    lam = 0.2
    S = _covariance_with_support(rng, b, _tree_edges(rng, b), lam)
    assert classify_component(S, np.arange(b), lam) == "tree"
    T_cf = np.asarray(glasso_forest(jnp.asarray(S), lam))
    T_admm = np.asarray(glasso_admm(jnp.asarray(S), lam, tol=1e-10))
    scale = np.abs(S).max()
    np.testing.assert_allclose(T_cf, T_admm, atol=5e-6 * scale)
    assert kkt_residual_host(S, lam, T_cf) < 1e-8 * scale


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_pair_closed_form_matches_admm(seed):
    rng = np.random.default_rng(seed)
    lam = 0.3
    S = _covariance_with_support(rng, 2, [(0, 1)], lam)
    T_cf = np.asarray(glasso_forest(jnp.asarray(S), lam))
    T_admm = np.asarray(glasso_admm(jnp.asarray(S), lam, tol=1e-10))
    np.testing.assert_allclose(T_cf, T_admm, atol=1e-6)


# ------------------------------------------------------------ chordal


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(4, 12), k=st.integers(1, 3), seed=st.integers(0, 10_000)
)
def test_chordal_closed_form_matches_admm(b, k, seed):
    rng = np.random.default_rng(seed)
    lam = 0.2
    S = _covariance_with_support(rng, b, _ktree_edges(rng, b, k), lam, offdiag=0.2)
    cls = classify_component(S, np.arange(b), lam)
    assert cls in ("tree", "chordal")  # k=1 k-trees are trees
    T_cf = glasso_chordal_host(S, lam)
    scale = np.abs(S).max()
    # the host KKT check must mirror the canonical jax one (eq. (11)-(12))
    from repro.core.solvers.kkt import kkt_residual

    res_host = kkt_residual_host(S, lam, T_cf)
    if np.isfinite(res_host):
        res_jax = float(
            kkt_residual(jnp.asarray(S), jnp.asarray(T_cf), lam, zero_tol=1e-12)
        )
        assert abs(res_host - res_jax) <= 1e-10 * max(1.0, res_host)
    if kkt_residual_host(S, lam, T_cf) < 1e-6 * scale:
        T_admm = np.asarray(glasso_admm(jnp.asarray(S), lam, tol=1e-10))
        np.testing.assert_allclose(T_cf, T_admm, atol=5e-6 * scale)
    # verification failure is allowed (router falls back); equivalence of the
    # ROUTED result is asserted end-to-end below either way
    res = glasso(S, lam, tol=1e-9)
    ref = glasso(S, lam, route=False, solver="admm", tol=1e-10)
    np.testing.assert_allclose(res.Theta, ref.Theta, atol=5e-6 * scale)


# ------------------------------------------------------------ ties


def test_tie_entries_are_not_edges_in_closed_form():
    """|S_ij| == lam exactly: the strict support drops the entry, the soft
    threshold zeroes it — closed form and iterative must agree."""
    rng = np.random.default_rng(1)
    lam = 0.25
    S = _covariance_with_support(rng, 5, _tree_edges(rng, 5), lam)
    S[0, 3] = S[3, 0] = lam   # exact tie on a non-edge
    S[1, 4] = S[4, 1] = -lam  # negative tie
    assert classify_component(S, np.arange(5), lam) == "tree"
    T_cf = np.asarray(glasso_forest(jnp.asarray(S), lam))
    T_admm = np.asarray(glasso_admm(jnp.asarray(S), lam, tol=1e-10))
    np.testing.assert_allclose(T_cf, T_admm, atol=5e-6 * np.abs(S).max())
    assert T_cf[0, 3] == 0.0 and T_cf[1, 4] == 0.0


# ------------------------------------------------------------ fallback


def test_adversarial_tree_falls_back_to_iterative():
    """Strong path edges make the non-edge dual constraint fail: the
    thresholded support is a tree but the glasso solution is denser, so the
    closed form is NOT optimal — the router must detect it (KKT check) and
    repair via the iterative tail, landing on the admm answer anyway."""
    S = np.array(
        [
            [1.0, 0.9, 0.05],
            [0.9, 1.0, 0.9],
            [0.05, 0.9, 1.0],
        ]
    )
    lam = 0.1
    assert classify_component(S, np.arange(3), lam) == "tree"
    T_cf = np.asarray(glasso_forest(jnp.asarray(S), lam))
    assert kkt_residual_host(S, lam, T_cf) > 1e-3  # closed form rejected
    reset("router")
    res = glasso(S, lam, tol=1e-9)
    assert count("router.fallback.tree") == 1
    ref = glasso(S, lam, route=False, solver="admm", tol=1e-10)
    np.testing.assert_allclose(res.Theta, ref.Theta, atol=1e-5)


# ------------------------------------------------------------ full ladder


def _mixed_structure_covariance():
    """2 singletons + pair + tree(4) + chordal(4) + chordless 5-cycle
    (general — note a COMPLETE block would classify chordal)."""
    p = 17
    S = np.eye(p) * 2.0

    def setv(i, j, v):
        S[i, j] = S[j, i] = v

    setv(2, 3, 0.8)
    setv(4, 5, 0.7), setv(5, 6, -0.6), setv(5, 7, 0.5)
    for a, b in [(8, 9), (9, 10), (10, 11), (11, 8), (8, 10)]:
        setv(a, b, 0.45 * (1 if (a + b) % 2 else -1))
    cyc = [12, 13, 14, 15, 16]
    for k in range(5):
        setv(cyc[k], cyc[(k + 1) % 5], 0.5)
    return S, 0.3


@pytest.mark.parametrize("solver", ["bcd", "admm"])
def test_every_structure_class_routes_and_matches(solver):
    """Acceptance: one solve exercises every ladder rung (counters prove it)
    and the routed result equals the route=False iterative result."""
    S, lam = _mixed_structure_covariance()
    reset("router")
    res = glasso(S, lam, solver=solver, tol=1e-9)
    mix = route_mix_counts()
    for cls in ("singleton", "pair", "tree", "chordal", "general"):
        assert mix.get(cls, 0) > 0, f"class {cls} not exercised"
    assert res.route_mix == {
        "singleton": 2,
        "pair": 1,
        "tree": 1,
        "chordal": 1,
        "general": 1,
    }
    assert 0.0 < res.noniterative_fraction < 1.0
    ref = glasso(S, lam, solver=solver, route=False, tol=1e-9)
    np.testing.assert_allclose(res.Theta, ref.Theta, atol=1e-5)


def test_route_mix_on_path():
    """A descending path re-classifies per lambda: structures only densify,
    and every step's routed result matches its unrouted twin."""
    from repro.core import glasso_path

    S, _ = _mixed_structure_covariance()
    lams = [0.6, 0.45, 0.3]
    path = glasso_path(S, lams, tol=1e-9)
    for r in path:
        ref = glasso(S, r.lam, route=False, solver="admm", tol=1e-10)
        np.testing.assert_allclose(r.Theta, ref.Theta, atol=1e-5)
        assert sum(r.route_mix.values()) == r.screen.n_components


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

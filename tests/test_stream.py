"""Streamed-vs-dense equivalence for the out-of-core screening subsystem.

The streaming screener must reproduce the dense Theorem-1 pipeline EXACTLY:
same partitions (all four dense cc backends, ties |S_ij| == lam included),
same edge weights, same materialized covariance sub-blocks, same glasso
solutions — while never building a (p, p) array.  Exact-tie cases use
integer-valued X with a power-of-two row count, so every covariance entry is
a dyadic rational computed exactly in f64 by ANY summation order: dense and
tiled arithmetic agree bit-for-bit and lam can be set to an off-diagonal
value itself.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import lambda_between_edges
from repro.core.components import component_lists, partitions_equal
from repro.core.screening import (
    count_edges,
    screen_stats_from_labels,
    thresholded_components,
)
from repro.stream import DataSession, StreamConfig, stream_screen

BACKENDS = ("host", "jax", "pallas", "shard_map")
CFG = {"tile": 32, "chunk": 16, "pair_batch": 3}  # 32 does not divide the ps below


def _data(rng, n, p, hetero=False):
    scales = 0.1 + rng.random(p) if not hetero else np.where(
        np.arange(p) < p // 3, 1.0, 0.03
    )
    return rng.standard_normal((n, p)) * scales


def _dense_S(X):
    Xc = X - X.mean(axis=0)
    return Xc.T @ Xc / X.shape[0]


def _integer_data(rng, n, p):
    """Integer X with power-of-two n: S entries are exact dyadic rationals
    identical under any tiling of the accumulation."""
    assert n & (n - 1) == 0
    return rng.integers(-4, 5, size=(n, p)).astype(np.float64)


@settings(max_examples=6, deadline=None)
@given(
    p=st.sampled_from([21, 50, 70]),   # never a multiple of tile=32
    n=st.sampled_from([16, 40]),
    seed=st.integers(0, 10_000),
    q=st.floats(0.3, 0.95),
)
def test_streamed_partition_matches_all_dense_backends(p, n, seed, q):
    rng = np.random.default_rng(seed)
    X = _data(rng, n, p)
    S = _dense_S(X)
    lam = lambda_between_edges(S, q)
    lam_lo = lambda_between_edges(S, q * 0.5)
    sc = stream_screen(X, [lam, lam_lo], config=CFG)
    for backend in BACKENDS:
        labels, stats = thresholded_components(S, lam, backend=backend, block=8)
        assert partitions_equal(sc.labels[0], labels), backend
        assert sc.stats[0].n_edges == stats.n_edges
    labels_lo, stats_lo = thresholded_components(S, lam_lo)
    assert partitions_equal(sc.labels[1], labels_lo)
    assert sc.stats[1].n_edges == stats_lo.n_edges


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_streamed_ties_are_not_edges(seed):
    rng = np.random.default_rng(seed)
    X = _integer_data(rng, 16, 40)
    S = _dense_S(X)
    iu, ju = np.triu_indices(40, 1)
    vals = np.abs(S[iu, ju])
    lam = float(np.median(vals[vals > 0]))  # an exact |S_ij|: a true tie
    assert (vals == lam).any()
    sc = stream_screen(X, [lam], config=CFG)
    labels, stats = thresholded_components(S, lam)
    assert partitions_equal(sc.labels[0], labels)
    assert sc.stats[0].n_edges == stats.n_edges == int((vals > lam).sum())


def test_streamed_edge_weights_match_dense(rng):
    X = _data(rng, 32, 50)
    S = _dense_S(X)
    lam = lambda_between_edges(S, 0.4)
    sc = stream_screen(X, [lam], config=CFG)
    gi, gj, w = sc.edges
    iu, ju = np.triu_indices(50, 1)
    dense_w = np.abs(S[iu, ju])
    keep = dense_w > lam
    assert gi.size == int(keep.sum())
    # same weight multiset, descending
    assert np.allclose(np.sort(w), np.sort(dense_w[keep]), atol=1e-12)
    assert np.all(np.diff(w) <= 0)
    assert np.allclose(np.abs(S[gi, gj]), w, atol=1e-12)


def test_materialized_blocks_and_diag_match_dense(rng):
    X = _data(rng, 32, 70)
    S = _dense_S(X)
    lam = lambda_between_edges(S, 0.5)
    sc = stream_screen(X, [lam], config=CFG)
    assert np.allclose(sc.S.diag_at(np.arange(70)), np.diag(S), atol=1e-12)
    for comp in component_lists(sc.labels[0]):
        assert np.allclose(
            sc.S.gather_block(comp), S[np.ix_(comp, comp)], atol=1e-12
        )


def test_cross_component_gather_raises(rng):
    X = _data(rng, 32, 40, hetero=True)
    S = _dense_S(X)
    lam = lambda_between_edges(S, 0.8)
    sc = stream_screen(X, [lam], config=CFG)
    comps = [c for c in component_lists(sc.labels[0]) if len(c) > 1]
    if len(comps) < 2:
        pytest.skip("partition has < 2 nontrivial components")
    mixed = np.array([comps[0][0], comps[1][0]])
    with pytest.raises(ValueError, match="across components"):
        sc.S.gather_block(mixed)


def test_tile_skip_prunes_and_stays_exact(rng):
    X = _data(rng, 48, 96, hetero=True)
    S = _dense_S(X)
    lam = lambda_between_edges(S, 0.9)
    sc = stream_screen(X, [lam], config=CFG)
    assert sc.tiles_skipped > 0, "heterogeneous scales must prune tiles"
    assert sc.tiles_skipped < sc.tiles_total
    labels, stats = thresholded_components(S, lam)
    assert partitions_equal(sc.labels[0], labels)
    assert sc.stats[0].n_edges == stats.n_edges
    assert sc.stats[0].tiles_skipped == sc.tiles_skipped
    # the memory watermark is accounted (the p-scaled claim is gated by
    # benchmarks/bench_stream.py's peak-RSS measurement at p=8k/16k)
    assert sc.stats[0].bytes_peak > 0


def test_streamed_glasso_path_equals_dense(rng):
    from repro.core import glasso_path

    X = _data(rng, 40, 60)
    S = _dense_S(X)
    lams = [lambda_between_edges(S, q) for q in (0.9, 0.7, 0.5)]
    dense = glasso_path(S, lams, tol=1e-8)
    streamed = glasso_path(
        X=X, lambdas=lams, from_data=True, tol=1e-8, stream=CFG
    )
    for d, s in zip(dense, streamed):
        assert partitions_equal(d.labels, s.labels)
        assert d.block_sizes == s.block_sizes
        assert d.route_mix == s.route_mix
        assert np.abs(d.Theta - s.Theta).max() < 1e-6
        assert s.screen.tiles_total > 0


def test_streamed_glasso_single_equals_dense(rng):
    from repro.core import glasso

    X = _data(rng, 40, 50)
    S = _dense_S(X)
    lam = lambda_between_edges(S, 0.6)
    d = glasso(S, lam, tol=1e-8)
    s = glasso(X=X, lam=lam, from_data=True, tol=1e-8, stream=CFG)
    assert partitions_equal(d.labels, s.labels)
    assert np.abs(d.Theta - s.Theta).max() < 1e-6


def test_glasso_input_validation():
    from repro.core import glasso, glasso_path

    with pytest.raises(ValueError, match="needs"):
        glasso(lam=0.5)
    with pytest.raises(ValueError, match="not both"):
        glasso(np.eye(3), 0.5, X=np.zeros((4, 3)))
    with pytest.raises(ValueError, match="needs"):
        glasso_path(X=np.zeros((4, 3)), from_data=True)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), small=st.booleans())
def test_session_append_matches_scratch(seed, small):
    rng = np.random.default_rng(seed)
    X = _data(rng, 32, 48, hetero=True)
    lam = lambda_between_edges(_dense_S(X), 0.6)
    ses = DataSession(X, lam, config=StreamConfig(**CFG))
    scale = 0.02 if small else 1.0
    Y = rng.standard_normal((3, 48)) * scale
    up = ses.append_rows(Y)
    S2 = _dense_S(np.vstack([X, Y]))
    labels2, stats2 = thresholded_components(S2, lam)
    assert partitions_equal(up.labels, labels2)
    assert up.stats.n_edges == stats2.n_edges
    assert up.tiles_rescreened + up.tiles_revalidated == len(ses.tiles)
    # blocks re-materialize exactly from the updated data
    for comp in component_lists(up.labels):
        assert np.allclose(
            up.S.gather_block(comp), S2[np.ix_(comp, comp)], atol=1e-12
        )


def test_session_small_update_revalidates_tiles(rng):
    X = _data(rng, 48, 96, hetero=True)
    lam = lambda_between_edges(_dense_S(X), 0.6)
    ses = DataSession(X, lam, config=StreamConfig(**CFG))
    Y = 0.01 * rng.standard_normal((2, 96)) * np.where(np.arange(96) < 32, 1.0, 0.03)
    up = ses.append_rows(Y)
    assert up.tiles_revalidated > 0, "a tiny perturbation must keep most tiles"
    S2 = _dense_S(ses.X)
    labels2, _ = thresholded_components(S2, lam)
    assert partitions_equal(up.labels, labels2)
    # stacked updates: certificates shrank but must stay sound
    up2 = ses.append_rows(0.01 * rng.standard_normal((1, 96)))
    labels3, _ = thresholded_components(_dense_S(ses.X), lam)
    assert partitions_equal(up2.labels, labels3)


def test_session_merges_components(rng):
    X = _data(rng, 32, 48, hetero=True)
    lam = lambda_between_edges(_dense_S(X), 0.7)
    ses = DataSession(X, lam, config=StreamConfig(**CFG))
    k0 = ses.stats.n_components
    # rows strongly correlating two columns in different tiles force a merge
    Y = np.zeros((8, 48))
    Y[:, 5] = 8.0 * np.arange(8)
    Y[:, 40] = 8.0 * np.arange(8)
    up = ses.append_rows(Y)
    S2 = _dense_S(ses.X)
    labels2, _ = thresholded_components(S2, lam)
    assert partitions_equal(up.labels, labels2)
    assert up.labels[5] == up.labels[40], "planted correlation must merge"
    assert up.stats.n_components < k0 or up.components_touched > 0


# ---------------------------------------------------------------------------
# screen_stats_from_labels: no dense mask, streamed count reuse
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    p=st.sampled_from([5, 33, 64, 101]),
    seed=st.integers(0, 10_000),
    q=st.floats(0.1, 0.9),
)
def test_count_edges_matches_dense_mask(p, seed, q):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((p, p))
    S = A + A.T
    lam = float(np.quantile(np.abs(S), q))
    off = ~np.eye(p, dtype=bool)
    expected = int((np.abs(S)[off] > lam).sum() // 2)
    assert count_edges(S, lam, row_chunk=17) == expected
    assert count_edges(S, lam) == expected


def test_screen_stats_reuses_provided_edge_count(rng):
    labels = np.zeros(6, dtype=np.int64)

    class Boom:
        """Dense S stand-in that fails if stats touch it."""
        gather_block = None  # truthy attr: routes around the dense count

        def __getattr__(self, name):
            raise AssertionError("stats must not touch S when n_edges given")

    stats = screen_stats_from_labels(Boom(), 0.5, labels, seconds=0.0, n_edges=7)
    assert stats.n_edges == 7
    assert stats.n_components == 1

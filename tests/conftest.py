"""Shared test config.

x64 is enabled globally: the paper pillar's optimality/Theorem-1 checks need
f64 KKT residuals.  LM-substrate tests pass explicit f32/bf16 dtypes, so they
are unaffected.  NOTE: no XLA_FLAGS device-count override here by design —
tests and benches must see the single real CPU device; only launch/dryrun.py
fakes 512 devices (and does so before importing jax).
"""

import sys

try:  # prefer the real library (requirements-dev.txt); shim only offline
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_shim

    _hypothesis_shim.install(sys.modules)

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_covariance(rng, p: int, n: int | None = None) -> np.ndarray:
    """A generic dense sample covariance with no planted structure."""
    n = n or max(2 * p, 8)
    X = rng.standard_normal((n, p)) @ (
        np.eye(p) + 0.3 * rng.standard_normal((p, p))
    )
    return np.cov(X, rowvar=False, bias=True)


def lambda_between_edges(S: np.ndarray, q: float) -> float:
    """A lambda at quantile q of the off-diagonal |S| values, nudged to the
    midpoint between two consecutive distinct values so the strict-inequality
    threshold (eq. 4) is unambiguous."""
    p = S.shape[0]
    iu = np.triu_indices(p, 1)
    vals = np.unique(np.abs(S[iu]))
    if vals.size == 1:
        return float(vals[0] * 0.5)
    k = int(np.clip(q * (vals.size - 1), 0, vals.size - 2))
    return float(0.5 * (vals[k] + vals[k + 1]))

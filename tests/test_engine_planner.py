"""Incremental path planner invariants: one union-find pass per path, plan
diffs reuse unchanged buckets, snapshot labels match direct screening, and
the mild single-block padding rule."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import lambda_between_edges, random_covariance
from repro.core import glasso_path, thresholded_components
from repro.core.blocks import build_plan, bucket_size, plan_bucket_size
from repro.core.components import partitions_equal
from repro.core.instrument import count, counts, reset
from repro.core.partition import component_size_distribution, labels_at_thresholds
from repro.engine.planner import plan_path


def _lambda_grid(S, n):
    qs = np.linspace(0.15, 0.9, n)
    return sorted({lambda_between_edges(S, q) for q in qs}, reverse=True)


# ------------------------------------------------------------ snapshots


@settings(max_examples=10, deadline=None)
@given(p=st.integers(4, 24), seed=st.integers(0, 10_000))
def test_labels_at_thresholds_matches_direct_screening(p, seed):
    rng = np.random.default_rng(seed)
    S = random_covariance(rng, p)
    lams = _lambda_grid(S, 7)
    snapshots = labels_at_thresholds(S, lams)
    for lam, labels in zip(lams, snapshots):
        direct, _ = thresholded_components(S, lam)
        assert partitions_equal(labels, direct)


def test_labels_at_thresholds_input_order_preserved():
    rng = np.random.default_rng(0)
    S = random_covariance(rng, 10)
    lams = _lambda_grid(S, 5)
    shuffled = [lams[2], lams[0], lams[4], lams[1], lams[3]]
    a = labels_at_thresholds(S, lams)
    b = labels_at_thresholds(S, shuffled)
    for lam_pos, lam in enumerate(shuffled):
        np.testing.assert_array_equal(b[lam_pos], a[lams.index(lam)])


# ------------------------------------------------------------ one pass


def test_path_plans_with_exactly_one_unionfind_pass():
    """Acceptance: a 20-lambda glasso_path performs ONE union-find pass."""
    rng = np.random.default_rng(7)
    S = random_covariance(rng, 16)
    lams = _lambda_grid(S, 20)
    reset()
    results = glasso_path(S, lams, solver="bcd", tol=1e-7)
    assert len(results) == len(lams)
    assert count("partition.unionfind_passes") == 1
    assert counts("planner").get("planner.plans_built") == len(lams)
    # screening stats are still populated per lambda from the snapshots
    n_edges = [r.screen.n_edges for r in results]
    assert n_edges == sorted(n_edges)  # descending lambda -> growing edge set
    ncomp = [r.screen.n_components for r in results]
    assert ncomp == sorted(ncomp, reverse=True)


def test_component_size_distribution_single_pass():
    """Satellite: the docstring's 'once over the sorted edges' is now true."""
    rng = np.random.default_rng(1)
    S = random_covariance(rng, 14)
    lams = _lambda_grid(S, 6)
    reset()
    dist = component_size_distribution(S, lams)
    assert count("partition.unionfind_passes") == 1
    for lam, d in zip(lams, dist):
        labels, stats = thresholded_components(S, lam)
        assert d["n_components"] == stats.n_components
        assert d["max_comp"] == stats.max_comp
        assert int((d["sizes"] * d["counts"]).sum()) == 14


# ------------------------------------------------------------ plan diff


def test_plan_diff_reuses_unchanged_buckets():
    """Two well-separated blocks: raising the within-block threshold splits
    one block while the other's bucket must be carried over by identity."""
    rng = np.random.default_rng(5)
    A = random_covariance(rng, 6)
    B = random_covariance(rng, 6)
    S = np.zeros((12, 12))
    S[:6, :6], S[6:, 6:] = A, B
    # couple block A internally stronger than B so a middle lambda splits B
    iu = np.triu_indices(12, 1)
    offmax = np.abs(S[iu]).max()
    lams = [offmax * 0.9, offmax * 0.5]  # both below max: blocks form, nested
    path = plan_path(S, lams)
    assert len(path.steps) == 2
    step0, step1 = path.steps
    if step1.reused_keys:
        reused_buckets = [b for b in step1.plan.buckets if step1.is_reused(b)]
        prev = {id(b) for b in step0.plan.buckets}
        for b in reused_buckets:
            assert id(b) in prev  # the very same Bucket object: no re-pad


def test_plan_diff_full_reuse_on_identical_lambdas_interval():
    """Consecutive lambdas between the same two edge values have identical
    partitions -> every bucket reused."""
    rng = np.random.default_rng(8)
    S = random_covariance(rng, 10)
    iu = np.triu_indices(10, 1)
    vals = np.unique(np.abs(S[iu]))
    k = len(vals) // 2
    lam_hi = vals[k] + (vals[k + 1] - vals[k]) * 0.7
    lam_lo = vals[k] + (vals[k + 1] - vals[k]) * 0.3
    path = plan_path(S, [lam_hi, lam_lo])
    step1 = path.steps[1]
    assert partitions_equal(path.steps[0].labels, step1.labels)
    assert len(step1.reused_keys) == len(step1.plan.buckets)
    assert counts("planner")  # counters exist


# ------------------------------------------------------------ padding rule


def test_single_block_bucket_mild_padding():
    # multi-block buckets stay pow2
    assert plan_bucket_size(1025) == 2048
    # single-block buckets get next-multiple-of-128, capped by pow2
    assert plan_bucket_size(1025, single_block=True) == 1152
    assert plan_bucket_size(300, single_block=True) == 384
    assert plan_bucket_size(400, single_block=True) == 512  # 512 is both
    # at or below 128, pow2 is already mild
    assert plan_bucket_size(100, single_block=True) == bucket_size(100)
    assert plan_bucket_size(5, single_block=True) == 8


def test_build_plan_screen_off_uses_mild_padding():
    """The screen=False baseline pads the full p x p problem: one component
    of 300 must land in a 384 bucket, not 512."""
    rng = np.random.default_rng(2)
    p = 300
    S = np.eye(p) + 0.5  # fully coupled: one component
    labels = np.zeros(p, dtype=np.int64)
    plan = build_plan(S, 0.1, labels)
    assert len(plan.buckets) == 1
    assert plan.buckets[0].size == 384
    assert plan.buckets[0].blocks.shape == (1, 384, 384)
    del rng


def test_build_plan_multi_block_buckets_still_pow2():
    rng = np.random.default_rng(4)
    S = random_covariance(rng, 20)
    lam = lambda_between_edges(S, 0.8)
    labels, _ = thresholded_components(S, lam)
    plan = build_plan(S, lam, labels)
    for b in plan.buckets:
        if len(b.comps) > 1:
            for c in b.comps:
                assert bucket_size(len(c)) == b.size


def test_mild_padding_solution_unchanged():
    """Padding size must not affect the solution (Theorem-1 corollary)."""
    import jax.numpy as jnp

    from repro.core.solvers import glasso_bcd
    from repro.core.blocks import pad_block

    rng = np.random.default_rng(9)
    Sb = random_covariance(rng, 6)
    lam = 0.3
    a = np.asarray(glasso_bcd(jnp.asarray(pad_block(Sb, 8)), lam, tol=1e-10))
    b = np.asarray(glasso_bcd(jnp.asarray(pad_block(Sb, 11)), lam, tol=1e-10))
    np.testing.assert_allclose(a[:6, :6], b[:6, :6], atol=1e-7)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

"""Property tests for Theorem 1: the vertex partition of the thresholded
sample covariance graph equals the partition of the glasso solution's
concentration graph — for ANY PSD input and ANY lambda > 0."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import lambda_between_edges, random_covariance
from repro.core import (
    glasso_admm,
    kkt_residual,
    partitions_equal,
    thresholded_components,
)
from repro.core.components import connected_components_host
from repro.covariance import lambda_interval_for_k, paper_synthetic


def concentration_partition(Theta: np.ndarray, zero_tol: float = 0.0) -> np.ndarray:
    A = np.abs(Theta) > zero_tol
    np.fill_diagonal(A, False)
    return connected_components_host(A)


def solve_full(S: np.ndarray, lam: float) -> np.ndarray:
    # ADMM's Z-iterate is exactly sparse (soft-threshold zeros), so the
    # support needs no fragile epsilon.
    Theta = np.asarray(glasso_admm(jnp.asarray(S), lam, tol=1e-9, max_iter=4000))
    res = float(kkt_residual(jnp.asarray(S), jnp.asarray(Theta), lam, zero_tol=1e-12))
    assert res < 1e-5, f"oracle solve failed to converge (kkt={res})"
    return Theta


@settings(max_examples=15, deadline=None)
@given(
    p=st.integers(4, 14),
    seed=st.integers(0, 10_000),
    q=st.floats(0.2, 0.95),
)
def test_theorem1_random_covariance(p, seed, q):
    rng = np.random.default_rng(seed)
    S = random_covariance(rng, p)
    lam = lambda_between_edges(S, q)
    labels_thresh, _ = thresholded_components(S, lam)
    Theta = solve_full(S, lam)
    labels_conc = concentration_partition(Theta)
    assert partitions_equal(labels_thresh, labels_conc)


@pytest.mark.parametrize("K,p1", [(2, 5), (3, 6), (4, 4)])
def test_theorem1_paper_synthetic(K, p1):
    S = paper_synthetic(K, p1, seed=1)
    lam_min, lam_max = lambda_interval_for_k(S, K)
    # lambda_II backs off 2% from the knife edge: at lambda exactly 1 ulp
    # below the critical |S_ij| the true cross-entries are O(ulp) — exact in
    # theory (Thm 1) but below any solver's resolution.
    lam_II = lam_max - 0.02 * (lam_max - lam_min)
    for lam in (0.5 * (lam_min + lam_max), lam_II):  # lambda_I and lambda_II
        labels_thresh, stats = thresholded_components(S, lam)
        assert stats.n_components == K
        Theta = solve_full(S, lam)
        assert partitions_equal(labels_thresh, concentration_partition(Theta))


def test_theorem1_remark1_edges_may_differ():
    """Remark 1: within a component the *edge sets* need not coincide — the
    thresholded graph can have an edge where Theta is zero.  Exhibit one."""
    rng = np.random.default_rng(7)
    found = False
    for seed in range(40):
        rng = np.random.default_rng(seed)
        S = random_covariance(rng, 8)
        lam = lambda_between_edges(S, 0.3)
        labels, stats = thresholded_components(S, lam)
        Theta = solve_full(S, lam)
        A_thresh = np.abs(S) > lam
        np.fill_diagonal(A_thresh, False)
        A_conc = np.abs(Theta) > 0
        np.fill_diagonal(A_conc, False)
        assert partitions_equal(labels, concentration_partition(Theta))
        if not np.array_equal(A_thresh, A_conc):
            found = True
            break
    assert found, "never saw differing edge sets (suspicious)"


def test_isolated_nodes_closed_form():
    """Witten-Friedman special case: isolated nodes get Theta_ii=1/(S_ii+lam)."""
    rng = np.random.default_rng(3)
    S = random_covariance(rng, 6)
    lam = float(np.abs(S - np.diag(np.diag(S))).max() * 1.01)  # all isolated
    labels, stats = thresholded_components(S, lam)
    assert stats.n_components == 6 and stats.n_isolated == 6
    Theta = solve_full(S, lam)
    np.testing.assert_allclose(Theta, np.diag(1.0 / (np.diag(S) + lam)), rtol=1e-6)

"""Connected-component implementations agree with library oracles."""

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
import scipy.sparse.csgraph as csgraph
from hypothesis import given, settings, strategies as st

from repro.core import (
    canonicalize_labels,
    connected_components_host,
    connected_components_labelprop,
    partitions_equal,
    threshold_adjacency,
)


def random_adjacency(rng, p, density):
    A = rng.random((p, p)) < density
    A = np.triu(A, 1)
    return A | A.T


@settings(max_examples=40, deadline=None)
@given(
    p=st.integers(2, 60),
    density=st.floats(0.0, 0.3),
    seed=st.integers(0, 10_000),
)
def test_unionfind_matches_scipy(p, density, seed):
    rng = np.random.default_rng(seed)
    A = random_adjacency(rng, p, density)
    ours = connected_components_host(A)
    _, ref = csgraph.connected_components(sp.csr_matrix(A), directed=False)
    assert partitions_equal(ours, ref)


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(2, 40),
    density=st.floats(0.0, 0.4),
    seed=st.integers(0, 10_000),
)
def test_labelprop_matches_unionfind(p, density, seed):
    rng = np.random.default_rng(seed)
    A = random_adjacency(rng, p, density)
    # encode adjacency as a "covariance": edge weight 1.0, threshold 0.5
    S = A.astype(np.float64)
    labels_jax = np.asarray(connected_components_labelprop(jnp.asarray(S), 0.5))
    labels_host = connected_components_host(A)
    assert partitions_equal(labels_jax, labels_host)
    # label-prop labels are already canonical (min vertex index of component)
    np.testing.assert_array_equal(labels_jax, canonicalize_labels(labels_jax))


def test_threshold_strictness():
    """eq. (4) is a strict inequality: |S_ij| == lambda is NOT an edge."""
    S = np.array([[1.0, 0.5], [0.5, 1.0]])
    assert not threshold_adjacency(S, 0.5).any()
    assert threshold_adjacency(S, 0.49999).sum() == 2


def test_networkx_oracle_on_path_graph():
    import networkx as nx

    p = 30
    G = nx.random_geometric_graph(p, 0.2, seed=4)
    A = nx.to_numpy_array(G) > 0
    ours = connected_components_host(A)
    ref = np.empty(p, dtype=int)
    for i, comp in enumerate(nx.connected_components(G)):
        for v in comp:
            ref[v] = i
    assert partitions_equal(ours, ref)

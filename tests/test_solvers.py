"""Solver correctness: KKT optimality, cross-solver agreement, batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import lambda_between_edges, random_covariance
from repro.core import SOLVERS, glasso_bcd, kkt_residual
from repro.core.solvers.kkt import glasso_objective


@pytest.mark.parametrize("solver", sorted(SOLVERS))
@settings(max_examples=10, deadline=None)
@given(p=st.integers(2, 12), seed=st.integers(0, 1000), q=st.floats(0.2, 0.9))
def test_kkt_optimality(solver, p, seed, q):
    rng = np.random.default_rng(seed)
    S = random_covariance(rng, p)
    lam = lambda_between_edges(S, q)
    Theta = SOLVERS[solver](jnp.asarray(S), lam, tol=1e-9)
    res = float(kkt_residual(jnp.asarray(S), Theta, lam, zero_tol=1e-8))
    scale = float(np.abs(S).max())
    assert res < 2e-4 * max(scale, 1.0), f"{solver} kkt residual {res}"


@settings(max_examples=8, deadline=None)
@given(p=st.integers(2, 10), seed=st.integers(0, 1000))
def test_solvers_agree(p, seed):
    rng = np.random.default_rng(seed)
    S = random_covariance(rng, p)
    lam = lambda_between_edges(S, 0.5)
    thetas = {
        name: np.asarray(fn(jnp.asarray(S), lam, tol=1e-9))
        for name, fn in SOLVERS.items()
    }
    objs = {
        name: float(glasso_objective(jnp.asarray(S), jnp.asarray(T), lam))
        for name, T in thetas.items()
    }
    best = min(objs.values())
    for name, obj in objs.items():
        assert obj - best < 1e-4 * max(abs(best), 1.0), (name, objs)
    np.testing.assert_allclose(thetas["bcd"], thetas["admm"], atol=5e-4)
    np.testing.assert_allclose(thetas["pg"], thetas["admm"], atol=5e-4)


def test_node_screen_equivalence():
    """eq. (10): the node-screen shortcut must not change the solution."""
    rng = np.random.default_rng(2)
    S = random_covariance(rng, 8)
    lam = lambda_between_edges(S, 0.85)  # sparse regime, screening active
    a = np.asarray(glasso_bcd(jnp.asarray(S), lam, node_screen=True, tol=1e-9))
    b = np.asarray(glasso_bcd(jnp.asarray(S), lam, node_screen=False, tol=1e-9))
    np.testing.assert_allclose(a, b, atol=1e-8)


def test_size_one_block():
    S = jnp.asarray([[2.5]])
    for name, fn in SOLVERS.items():
        Theta = np.asarray(fn(S, 0.3))
        np.testing.assert_allclose(Theta, [[1.0 / 2.8]], rtol=1e-6, err_msg=name)


def test_vmap_batching_matches_loop():
    rng = np.random.default_rng(9)
    blocks = np.stack([random_covariance(rng, 6) for _ in range(5)])
    lam = 0.2
    batched = np.asarray(
        jax.vmap(lambda Sb: glasso_bcd(Sb, lam, tol=1e-9))(jnp.asarray(blocks))
    )
    single = np.stack(
        [np.asarray(glasso_bcd(jnp.asarray(b), lam, tol=1e-9)) for b in blocks]
    )
    np.testing.assert_allclose(batched, single, atol=1e-7)


def test_warm_start_path_speedup_and_correctness():
    rng = np.random.default_rng(4)
    S = random_covariance(rng, 10)
    lam_hi = lambda_between_edges(S, 0.8)
    lam_lo = lambda_between_edges(S, 0.5)
    Theta_hi = glasso_bcd(jnp.asarray(S), lam_hi, tol=1e-10)
    W_hi = jnp.linalg.inv(Theta_hi)
    warm = np.asarray(glasso_bcd(jnp.asarray(S), lam_lo, W0=W_hi, tol=1e-10))
    cold = np.asarray(glasso_bcd(jnp.asarray(S), lam_lo, tol=1e-10))
    np.testing.assert_allclose(warm, cold, atol=1e-6)


def test_admm_warm_start_cuts_iterations():
    """The W0 warm start must genuinely seed ADMM (Z0 = W0^{-1},
    U0 = (W0 - S)/rho): an exact W0 is a fixed point, a nearby one converges
    in a fraction of the cold iterations — this is what makes executor
    repairs and route fallbacks cheap for solver="admm"."""
    from repro.core.solvers import WARM_START_SOLVERS
    from repro.core.solvers.admm import glasso_admm_info

    assert "admm" in WARM_START_SOLVERS
    rng = np.random.default_rng(11)
    S = jnp.asarray(random_covariance(rng, 14))
    lam = lambda_between_edges(np.asarray(S), 0.5)
    Theta_cold, it_cold = glasso_admm_info(S, lam, tol=1e-9)
    # exact warm start: fixed point, converges immediately
    W0 = jnp.linalg.inv(Theta_cold)
    Theta_warm, it_warm = glasso_admm_info(S, lam, tol=1e-9, W0=W0)
    assert int(it_warm) < int(it_cold) / 4, (int(it_warm), int(it_cold))
    np.testing.assert_allclose(
        np.asarray(Theta_warm), np.asarray(Theta_cold), atol=1e-7
    )
    # nearby warm start (neighboring lambda's solution) still cuts iterations
    lam_hi = lambda_between_edges(np.asarray(S), 0.6)
    Theta_hi, _ = glasso_admm_info(S, lam_hi, tol=1e-9)
    _, it_near = glasso_admm_info(S, lam, tol=1e-9, W0=jnp.linalg.inv(Theta_hi))
    assert int(it_near) < int(it_cold), (int(it_near), int(it_cold))
    # degenerate W0 falls back to the cold start, not garbage
    Theta_bad, it_bad = glasso_admm_info(S, lam, tol=1e-9, W0=jnp.zeros_like(S))
    assert int(it_bad) == int(it_cold)
    np.testing.assert_allclose(
        np.asarray(Theta_bad), np.asarray(Theta_cold), atol=1e-12
    )
    # Theta0 alongside W0 (the executor repair path: no inv(W0) re-inversion)
    Theta_t0, it_t0 = glasso_admm_info(
        S, lam, tol=1e-9, W0=W0, Theta0=Theta_cold
    )
    assert int(it_t0) <= int(it_warm)
    np.testing.assert_allclose(
        np.asarray(Theta_t0), np.asarray(Theta_cold), atol=1e-7
    )


def test_objective_at_solution_beats_perturbations():
    rng = np.random.default_rng(6)
    S = random_covariance(rng, 7)
    lam = lambda_between_edges(S, 0.5)
    Theta = np.asarray(glasso_bcd(jnp.asarray(S), lam, tol=1e-10))
    obj = float(glasso_objective(jnp.asarray(S), jnp.asarray(Theta), lam))
    for seed in range(5):
        d = np.random.default_rng(seed).standard_normal(Theta.shape) * 1e-3
        d = 0.5 * (d + d.T)
        pert = float(glasso_objective(jnp.asarray(S), jnp.asarray(Theta + d), lam))
        assert pert >= obj - 1e-10

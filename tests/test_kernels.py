"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs pure-jnp
oracle, across shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.covgram.ops import covgram
from repro.kernels.covgram.ref import covgram_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.prox_l1.ops import prox_step
from repro.kernels.prox_l1.ref import prox_step_ref
from repro.kernels.threshold_cc.ops import connected_components_kernel, labelprop_step
from repro.kernels.threshold_cc.ref import labelprop_step_ref
from repro.kernels.tree_glasso.ref import glasso_forest_ref
from repro.kernels.tree_glasso.tree_glasso import glasso_forest_pallas


# ---------------------------------------------------------------- covgram
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "n,p,bn,bp",
    [(64, 32, 16, 8), (100, 17, 32, 8), (33, 64, 8, 16), (256, 96, 64, 32)],
)
def test_covgram_shapes(n, p, bn, bp, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, p)), dtype)
    out = covgram(x, block_n=bn, block_p=bp)
    ref = covgram_ref(x)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(4, 80), p=st.integers(2, 40), seed=st.integers(0, 100))
def test_covgram_property(n, p, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(covgram(x, block_n=16, block_p=8)),
        np.asarray(covgram_ref(x)),
        atol=1e-4, rtol=1e-4,
    )


# --------------------------------------------------------- covgram_screen
@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(6, 60),
    p=st.integers(5, 50),
    seed=st.integers(0, 100),
    q=st.floats(0.2, 0.9),
)
def test_covgram_screen_pallas_matches_ref(n, p, seed, q):
    """The fused threshold+edge-emit kernel (interpret mode) and the numpy
    oracle emit the same edge set, counts, and tile stats."""
    from repro.kernels.covgram_screen import (
        compact_edges,
        covgram_screen_tiles,
        pad_for_screen,
    )

    bn, bp = 16, 16
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n, p))
    mu = X.mean(axis=0)
    Xc = X - mu
    S = Xc.T @ Xc / n
    iu, ju = np.triu_indices(p, 1)
    lam = float(np.quantile(np.abs(S[iu, ju]), q)) if p > 1 else 0.1
    x_pad, mu_pad = pad_for_screen(X, mu, block_n=bn, block_p=bp)
    nt = x_pad.shape[1] // bp
    ti, tj = np.triu_indices(nt)
    outs = {}
    for backend in ("ref", "pallas"):
        vals, counts_, stats = covgram_screen_tiles(
            x_pad, mu_pad, ti, tj, lam,
            n_true=n, p_true=p, block_p=bp, block_n=bn, backend=backend,
        )
        gi, gj, w = compact_edges(vals, ti, tj, block_p=bp)
        outs[backend] = (set(zip(gi.tolist(), gj.tolist())), counts_, stats)
    dense = set(zip(*(a.tolist() for a in (iu[np.abs(S[iu, ju]) > lam],
                                           ju[np.abs(S[iu, ju]) > lam]))))
    assert outs["ref"][0] == dense
    assert outs["pallas"][0] == dense
    np.testing.assert_array_equal(outs["ref"][1], outs["pallas"][1])
    np.testing.assert_allclose(
        outs["ref"][2], outs["pallas"][2], atol=1e-5, rtol=1e-4
    )


# ----------------------------------------------------------- threshold_cc
@settings(max_examples=15, deadline=None)
@given(p=st.integers(2, 70), seed=st.integers(0, 100), lam=st.floats(0.0, 2.0))
def test_labelprop_step_matches_ref(p, seed, lam):
    rng = np.random.default_rng(seed)
    S = rng.standard_normal((p, p))
    S = S + S.T
    labels = jnp.asarray(rng.integers(0, p, size=p), jnp.int32)
    out = labelprop_step(jnp.asarray(S, jnp.float32), labels, lam, block=16)
    ref = labelprop_step_ref(jnp.asarray(S, jnp.float32), labels, lam)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=10, deadline=None)
@given(p=st.integers(2, 50), seed=st.integers(0, 100), density=st.floats(0.01, 0.3))
def test_cc_kernel_matches_host(p, seed, density):
    from repro.core.components import components_from_covariance_host, partitions_equal

    rng = np.random.default_rng(seed)
    A = rng.random((p, p)) < density
    A = np.triu(A, 1)
    S = (A | A.T).astype(np.float32)
    labels = np.asarray(connected_components_kernel(jnp.asarray(S), 0.5, block=16))
    assert partitions_equal(labels, components_from_covariance_host(S, 0.5))


# ---------------------------------------------------------------- prox_l1
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("B,b,blk", [(1, 8, 8), (3, 20, 8), (5, 64, 32), (2, 100, 64)])
def test_prox_shapes(B, b, blk, dtype):
    rng = np.random.default_rng(1)
    theta = jnp.asarray(rng.standard_normal((B, b, b)), dtype)
    grad = jnp.asarray(rng.standard_normal((B, b, b)), dtype)
    out = prox_step(theta, grad, 0.1, 0.5, block=blk)
    ref = prox_step_ref(theta, grad, 0.1, 0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(2, 40),
    t=st.floats(1e-4, 2.0),
    lam=st.floats(0.0, 2.0),
    seed=st.integers(0, 100),
)
def test_prox_property(b, t, lam, seed):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.standard_normal((2, b, b)), jnp.float32)
    grad = jnp.asarray(rng.standard_normal((2, b, b)), jnp.float32)
    out = prox_step(theta, grad, t, lam, block=16)
    ref = prox_step_ref(theta, grad, t, lam)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    # prox output is exactly sparse where |theta - t g| <= t lam
    z = np.asarray(theta) - t * np.asarray(grad)
    assert np.all(np.asarray(out)[np.abs(z) <= t * lam] == 0.0)


# --------------------------------------------------------- flash_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "B,Hq,Hkv,Sq,Skv,d",
    [
        (1, 4, 4, 64, 64, 16),    # MHA square
        (2, 8, 2, 32, 32, 8),     # GQA 4:1
        (1, 4, 1, 40, 72, 16),    # MQA, ragged + cross lengths
    ],
)
def test_flash_attention_matches_ref(B, Hq, Hkv, Sq, Skv, d, causal, dtype):
    if causal and Sq != Skv:
        pytest.skip("causal requires aligned self-attention lengths here")
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, Hq, Sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Skv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Skv, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@settings(max_examples=8, deadline=None)
@given(
    sq=st.integers(2, 48),
    d=st.sampled_from([4, 8, 16]),
    group=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 100),
)
def test_flash_attention_property(sq, d, group, seed):
    rng = np.random.default_rng(seed)
    Hkv = 2
    q = jnp.asarray(rng.standard_normal((1, Hkv * group, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, Hkv, sq, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, Hkv, sq, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------- tree_glasso
@pytest.mark.parametrize("B,b", [(1, 8), (7, 8), (3, 16), (2, 32)])
def test_tree_glasso_kernel_matches_ref(B, b):
    """Pallas forest closed form (interpret mode) == jnp reference, with
    per-block lambdas (the serving mixed-lambda batch layout)."""
    rng = np.random.default_rng(0)
    blocks = rng.standard_normal((B, b, b))
    blocks = 0.5 * (blocks + blocks.transpose(0, 2, 1))
    blocks += (np.abs(blocks).sum(axis=2).max(axis=1)[:, None, None]) * np.eye(b)
    lams = rng.uniform(0.1, 0.6, size=B)
    out = glasso_forest_pallas(
        jnp.asarray(blocks), jnp.asarray(lams)[:, None], interpret=True
    )
    ref = jax.vmap(glasso_forest_ref)(jnp.asarray(blocks), jnp.asarray(lams))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(2, 12), seed=st.integers(0, 500))
def test_tree_glasso_kernel_property(b, seed):
    """Padded shapes: ops-level zero padding must not change the sliced
    result (zero padding adds no |S_ij| > lam edges)."""
    from repro.kernels.tree_glasso.ops import glasso_forest_stack

    rng = np.random.default_rng(seed)
    S = rng.standard_normal((b, b))
    S = 0.5 * (S + S.T)
    np.fill_diagonal(S, 1.0 + np.abs(S).sum(axis=1))
    lam = float(rng.uniform(0.05, 0.5))
    out = glasso_forest_stack(jnp.asarray(S)[None], jnp.asarray([lam]))[0]
    ref = glasso_forest_ref(jnp.asarray(S), lam)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-12)

"""Validate the trip-count-weighted HLO analyzer against ground truth."""

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    compiled = _compile(lambda x, y: x @ y, a, b)
    out = analyze_hlo(compiled.as_text())
    expect = 2 * 128 * 256 * 64
    assert abs(out["flops"] - expect) / expect < 0.05, out["flops"]


def test_scan_weighting_matches_unrolled():
    """flops(scan of 8 matmuls) must equal flops(unrolled 8 matmuls)."""
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(8):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    f_scan = analyze_hlo(_compile(scanned, x, ws).as_text())["flops"]
    f_unroll = analyze_hlo(_compile(unrolled, x, ws).as_text())["flops"]
    # XLA's own module-level count is ~8x off here; ours must agree within 10%
    assert abs(f_scan - f_unroll) / f_unroll < 0.10, (f_scan, f_unroll)
    expect_dots = 8 * 2 * 64 * 128 * 128
    assert f_scan > expect_dots * 0.95


def test_nested_scan_weighting():
    def inner(x, w):
        return x @ w, None

    def outer(x, ws):
        def obody(c, _):
            return jax.lax.scan(inner, c, ws)[0], None
        return jax.lax.scan(obody, x, None, length=4)[0]

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)
    f = analyze_hlo(_compile(outer, x, ws).as_text())["flops"]
    expect = 4 * 3 * 2 * 32 * 64 * 64
    assert f > expect * 0.9, (f, expect)
    assert f < expect * 1.5, (f, expect)


def test_matches_cost_analysis_on_scanfree_graph():
    def fn(x, w1, w2):
        return jax.nn.relu(x @ w1) @ w2

    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w1 = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    w2 = jax.ShapeDtypeStruct((1024, 256), jnp.float32)
    compiled = _compile(fn, x, w1, w2)
    ours = analyze_hlo(compiled.as_text())["flops"]
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax <= 0.4.x returns [dict]
        ca = ca[0]
    xla = ca["flops"]
    assert abs(ours - xla) / xla < 0.05, (ours, xla)


def test_collective_weighting_in_loop():
    """A psum inside a scan must count once per iteration."""
    from repro.core.jax_compat import make_mesh, shard_map

    mesh = make_mesh((1,), ("d",))

    @jax.jit
    def fn(x):
        def body(c, _):
            s = shard_map(
                lambda v: jax.lax.psum(v, "d"), mesh=mesh,
                in_specs=jax.sharding.PartitionSpec(), out_specs=jax.sharding.PartitionSpec(),
            )(c)
            return s, None
        return jax.lax.scan(body, x, None, length=5)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = fn.lower(x).compile()
    out = analyze_hlo(compiled.as_text())
    coll = out["collective"]
    if coll["total"] > 0:  # single-device psum may fold away entirely
        assert coll.get("all-reduce_count", 0) >= 5

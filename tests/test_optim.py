"""Optimizer unit tests: AdamW/Adafactor step math, convergence on a convex
problem, schedule shape, state sharding mirror."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adafactor, adamw, cosine_with_warmup


def _quadratic(params):
    w = params["w"]
    return jnp.sum((w - 3.0) ** 2)


@pytest.mark.parametrize("make_opt", [lambda: adamw(1e-1, weight_decay=0.0),
                                      lambda: adafactor(5e-1)])
def test_converges_on_convex(make_opt):
    opt = make_opt()
    params = {"w": jnp.zeros((4, 4))}
    state = opt.init(params)

    @jax.jit
    def step(params, state, i):
        g = jax.grad(_quadratic)(params)
        return opt.update(g, state, params, i)

    for i in range(400):
        params, state = step(params, state, jnp.asarray(i))
    assert float(_quadratic(params)) < 1e-2  # optimum is 0 at w == 3


def test_adamw_weight_decay_decoupled():
    opt = adamw(1e-2, weight_decay=0.5, grad_clip=0.0)
    params = {"w": jnp.ones((3,))}
    state = opt.init(params)
    zero_g = {"w": jnp.zeros((3,))}
    new_params, _ = opt.update(zero_g, state, params, jnp.asarray(0))
    # pure decay: w <- w - lr*wd*w
    np.testing.assert_allclose(np.asarray(new_params["w"]), 1.0 - 1e-2 * 0.5, rtol=1e-6)


def test_grad_clip():
    opt = adamw(1e-3, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((2,))}
    state = opt.init(params)
    big = {"w": jnp.full((2,), 1e6)}
    p1, s1 = opt.update(big, state, params, jnp.asarray(0))
    assert np.all(np.isfinite(np.asarray(p1["w"])))


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((32,))}
    state = opt.init(params)
    assert state["w"]["vr"].shape == (64,)
    assert state["w"]["vc"].shape == (32,)
    assert state["b"]["v"].shape == (32,)
    n_state = sum(np.prod(leaf.shape) for leaf in jax.tree.leaves(state))
    n_adam = 2 * sum(np.prod(leaf.shape) for leaf in jax.tree.leaves(params))
    assert n_state < 0.1 * n_adam


def test_cosine_schedule_shape():
    lr = cosine_with_warmup(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(0)) < float(lr(9)) <= 1.0
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-5)
    assert float(lr(99)) < 0.2
    assert float(lr(99)) >= 0.1 * 0.99

"""Per-arch smoke tests: REDUCED config of the same family, one forward /
train step on CPU, asserting output shapes + no NaNs.  The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, get_arch, list_archs
from repro.data.specs import make_batch
from repro.models.transformer import padded_vocab
from repro.models.zoo import active_params, build_model

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_forward_and_grad(arch):
    cfg = get_arch(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params, specs = model.init(jax.random.key(0))
    # specs tree mirrors params tree
    assert jax.tree.structure(jax.tree.map(lambda _: 0, params)) == jax.tree.structure(
        jax.tree.map(lambda _: 0, specs, is_leaf=lambda x: isinstance(x, tuple))
    )
    batch = make_batch(cfg, SMOKE_SHAPE, seed=1)
    loss, metrics = model.train_loss(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0.0
    # one grad step must be finite everywhere
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), arch


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_prefill_decode_consistency(arch):
    """logits(prefill S tokens) == logits(prefill S-1 tokens, then decode the
    S-th) — the cache paths must match the parallel path exactly."""
    cfg = get_arch(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    S = 8
    shape = ShapeConfig("c", seq_len=S, global_batch=2, kind="prefill")
    batch = make_batch(cfg, shape, seed=2)

    full_logits, _ = model.prefill(params, batch)

    # prefill on the first S-1 tokens, pad caches to S, decode token S-1
    batch_m1 = dict(batch)
    batch_m1["tokens"] = batch["tokens"][:, :-1]
    _, caches = model.prefill(params, batch_m1)
    from repro.train.serving import pad_caches

    # model-visible sequence length includes the frontend prefix
    # (enc-dec frames feed the encoder, not decoder positions)
    offset_len = cfg.frontend_len if cfg.frontend and not cfg.encoder_decoder else 0
    caches = pad_caches(
        cfg, caches, batch_m1["tokens"].shape[1] + offset_len,
        to_len=batch["tokens"].shape[1] + offset_len,
    )
    pos = jnp.asarray(batch["tokens"].shape[1] - 1 + offset_len, jnp.int32)
    dec_logits, _ = model.decode_step(
        params, batch["tokens"][:, -1:], caches, pos
    )
    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(full_logits), atol=2e-4, rtol=2e-3
    )


def test_param_accounting_full_configs():
    """Full-config param counts are in the right ballpark (abstract only)."""
    expect = {
        "qwen2-72b": (60e9, 90e9),
        "granite-3-8b": (7e9, 10e9),
        "internlm2-20b": (17e9, 26e9),
        "qwen2.5-3b": (2.5e9, 4e9),
        "internvl2-26b": (18e9, 27e9),   # LM backbone only (ViT is stubbed)
        "deepseek-v2-lite-16b": (12e9, 18e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "zamba2-1.2b": (0.8e9, 1.9e9),
        "rwkv6-7b": (6e9, 9e9),
        "seamless-m4t-medium": (0.5e9, 1.5e9),
    }
    from repro.models.zoo import count_params_abstract

    for arch in list_archs():
        cfg = get_arch(arch)
        n = count_params_abstract(cfg)
        lo, hi = expect[cfg.name]
        assert lo < n < hi, f"{cfg.name}: {n/1e9:.2f}B params out of [{lo/1e9},{hi/1e9}]"
        a = active_params(cfg)
        assert a <= n
        if cfg.moe:
            assert a < 0.6 * n, f"{cfg.name}: MoE should have <60% active"


def test_vocab_padding_multiple_of_256():
    for arch in list_archs():
        cfg = get_arch(arch)
        pv = padded_vocab(cfg)
        assert pv % 256 == 0 and pv >= cfg.vocab and pv - cfg.vocab < 256

"""Bucketing/padding invariants, scheduling bounds, capacity rule."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import lambda_between_edges, random_covariance
from repro.core import glasso, lambda_for_max_component, merge_profile
from repro.core.blocks import bucket_size, build_plan, pad_block
from repro.core.schedule import check_capacity, default_cost, lpt_assign
from repro.core.solvers import glasso_bcd
from repro.core.screening import thresholded_components


def test_padding_invariance():
    """Corollary of Theorem 1: padding a block with identity coordinates does
    not perturb the block's solution, and padded coords solve to 1/(1+lam)."""
    rng = np.random.default_rng(0)
    Sb = random_covariance(rng, 5)
    lam = 0.3
    direct = np.asarray(glasso_bcd(jnp.asarray(Sb), lam, tol=1e-10))
    padded = np.asarray(
        glasso_bcd(jnp.asarray(pad_block(Sb, 8)), lam, tol=1e-10)
    )
    np.testing.assert_allclose(padded[:5, :5], direct, atol=1e-8)
    np.testing.assert_allclose(
        padded[5:, 5:], np.eye(3) / (1.0 + lam), atol=1e-8
    )
    assert np.abs(padded[:5, 5:]).max() == 0.0


@settings(max_examples=10, deadline=None)
@given(p=st.integers(4, 16), seed=st.integers(0, 1000), q=st.floats(0.3, 0.9))
def test_screen_equals_noscreen(p, seed, q):
    """The headline experiment: glasso with screening == without, exactly the
    same Theta (up to solver tolerance)."""
    rng = np.random.default_rng(seed)
    S = random_covariance(rng, p)
    lam = lambda_between_edges(S, q)
    a = glasso(S, lam, solver="bcd", screen=True, tol=1e-9)
    b = glasso(S, lam, solver="bcd", screen=False, tol=1e-9)
    np.testing.assert_allclose(a.Theta, b.Theta, atol=2e-5)


def test_plan_partitions_vertices():
    rng = np.random.default_rng(1)
    S = random_covariance(rng, 20)
    lam = lambda_between_edges(S, 0.8)
    labels, _ = thresholded_components(S, lam)
    plan = build_plan(S, lam, labels)
    seen = list(plan.isolated)
    for b in plan.buckets:
        assert b.blocks.shape[0] == len(b.comps)
        assert b.blocks.shape[1] == b.size
        for c in b.comps:
            assert bucket_size(len(c)) == b.size
            seen.extend(c.tolist())
    assert sorted(seen) == list(range(20))


def test_bucket_sizes_powers_of_two():
    assert [bucket_size(b) for b in (2, 3, 4, 5, 9, 17)] == [2, 4, 4, 8, 16, 32]


@settings(max_examples=30, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 200), min_size=1, max_size=60),
    workers=st.integers(1, 16),
)
def test_lpt_bounds(sizes, workers):
    a = lpt_assign(sizes, workers)
    costs = [default_cost(s) for s in sizes]
    assert a.worker_of.shape == (len(sizes),)
    assert set(a.worker_of.tolist()) <= set(range(workers))
    np.testing.assert_allclose(a.loads.sum(), sum(costs), rtol=1e-9)
    # LPT makespan <= mean load + max job (classic greedy bound)
    assert a.makespan <= sum(costs) / workers + max(costs) + 1e-9


def test_capacity_check():
    check_capacity([3, 5], 5)
    with pytest.raises(ValueError, match="exceeds worker capacity"):
        check_capacity([3, 6], 5)


@settings(max_examples=15, deadline=None)
@given(p=st.integers(4, 25), seed=st.integers(0, 1000), p_max=st.integers(1, 10))
def test_lambda_for_max_component(p, seed, p_max):
    """Consequence 5: at the returned lambda the max component fits; for any
    strictly smaller threshold at the next edge value it would not."""
    rng = np.random.default_rng(seed)
    S = random_covariance(rng, p)
    lam = lambda_for_max_component(S, p_max)
    _, stats = thresholded_components(S, lam)
    assert stats.max_comp <= p_max
    if lam > 0.0:
        _, stats2 = thresholded_components(S, lam * (1 - 1e-12) - 1e-15)
        assert stats2.max_comp > p_max


@settings(max_examples=10, deadline=None)
@given(p=st.integers(3, 20), seed=st.integers(0, 1000))
def test_merge_profile_matches_direct_cc(p, seed):
    rng = np.random.default_rng(seed)
    S = random_covariance(rng, p)
    prof = merge_profile(S)
    vals = prof["value"][1:]  # finite edge values, descending
    for k in range(min(5, vals.size)):
        # lambda just below vals[k] includes edges of weight vals[k]
        lam = vals[k] - 1e-12 if k == vals.size - 1 else 0.5 * (vals[k] + vals[k + 1])
        _, stats = thresholded_components(S, lam)
        assert stats.n_components == prof["n_components"][k + 1]
        assert stats.max_comp == prof["max_comp"][k + 1]

"""Genuinely multi-device shard_map semantics for the paper pillar, run in a
subprocess with 8 faked host devices (the main pytest process must keep the
single real device — see conftest)."""

import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from repro.core.components import components_from_covariance_host, partitions_equal
    from repro.core.distributed import distributed_bucket_solve, distributed_components
    from repro.core.solvers import glasso_bcd
    from repro.covariance import paper_synthetic, lambda_interval_for_k

    assert jax.device_count() == 8
    from repro.core.jax_compat import make_mesh

    mesh = make_mesh((8,), ("data",))

    # 8-way row-sharded CC on a structured problem
    S = paper_synthetic(K=4, p1=10, seed=0)
    lam = 0.5 * sum(lambda_interval_for_k(S, 4))
    labels = np.asarray(distributed_components(jnp.asarray(S), lam, mesh))
    ref = components_from_covariance_host(S, lam)
    assert partitions_equal(labels, ref), "distributed CC mismatch"

    # 8-way sharded bucket solve, n not divisible by 8 (pad path)
    rng = np.random.default_rng(0)
    blocks = []
    for i in range(5):
        X = rng.standard_normal((24, 6))
        blocks.append(np.cov(X, rowvar=False, bias=True))
    blocks = np.stack(blocks)
    out = np.asarray(distributed_bucket_solve(blocks, 0.2, glasso_bcd, mesh, tol=1e-9))
    ref = np.stack([
        np.asarray(glasso_bcd(jnp.asarray(b), 0.2, tol=1e-9)) for b in blocks
    ])
    np.testing.assert_allclose(out, ref, atol=1e-8)
    print("MULTIDEVICE_OK")
    """
)


def test_core_pillar_on_8_devices():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
        timeout=600,
    )
    assert "MULTIDEVICE_OK" in proc.stdout, proc.stderr[-2000:]

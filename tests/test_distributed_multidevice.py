"""Genuinely multi-device shard_map semantics for the paper pillar, run in a
subprocess with 8 faked host devices (the main pytest process must keep the
single real device — see conftest).

ONE module-scoped fixture runs ONE subprocess for every scenario: the 8-way
emulation pays a fixed price per process (backend init, and one compile per
shard_map program shape), so giving each scenario its own subprocess would
multiply exactly the costs that dominate this file's ~8 minutes.  Inside the
script the mesh is built once (``local_device_mesh`` caches per process) and
every sharded-solver scenario reuses the b=100 -> bp=128 compiled shape.
Each scenario prints an ``<NAME>_OK`` marker; the per-scenario tests below
assert their marker, so a failure still reports WHICH scenario broke.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from repro.core.components import components_from_covariance_host, partitions_equal
    from repro.core.distributed import distributed_bucket_solve, distributed_components
    from repro.core.instrument import counts, reset
    from repro.core.solvers import glasso_bcd
    from repro.core.solvers.admm import glasso_admm
    from repro.core.solvers.sharded import glasso_sharded
    from repro.covariance import paper_synthetic, lambda_interval_for_k

    assert jax.device_count() == 8
    from repro.core.jax_compat import local_device_mesh

    mesh = local_device_mesh("data")  # cached: every scenario shares it

    # --- 8-way row-sharded CC on a structured problem --------------------
    S = paper_synthetic(K=4, p1=10, seed=0)
    lam = 0.5 * sum(lambda_interval_for_k(S, 4))
    labels = np.asarray(distributed_components(jnp.asarray(S), lam, mesh))
    ref = components_from_covariance_host(S, lam)
    assert partitions_equal(labels, ref), "distributed CC mismatch"
    print("CC_OK")

    # --- 8-way sharded bucket solve, n not divisible by 8 (pad path) -----
    rng = np.random.default_rng(0)
    blocks = []
    for i in range(5):
        X = rng.standard_normal((24, 6))
        blocks.append(np.cov(X, rowvar=False, bias=True))
    blocks = np.stack(blocks)
    out = np.asarray(distributed_bucket_solve(blocks, 0.2, glasso_bcd, mesh, tol=1e-9))
    ref = np.stack([
        np.asarray(glasso_bcd(jnp.asarray(b), 0.2, tol=1e-9)) for b in blocks
    ])
    np.testing.assert_allclose(out, ref, atol=1e-8)
    print("BUCKET_OK")

    # --- sharded oversize solver vs the single-device ADMM oracle --------
    # b=100 on 8 shards pads to 128 (non-divisible path); three seeds share
    # the one compiled shape.
    for seed in (1, 2, 3):
        Sg = np.asarray(paper_synthetic(K=2, p1=50, seed=seed))[:100, :100]
        Sg = 0.5 * (Sg + Sg.T)
        lam_g = 0.15
        res = glasso_sharded(Sg, lam_g)
        ref_g = np.asarray(glasso_admm(jnp.asarray(Sg), lam_g, tol=1e-9))
        scale = max(1.0, res.s_max)
        assert res.kkt_residual <= 1e-6 * scale, (seed, res.kkt_residual)
        assert np.abs(res.Theta - ref_g).max() < 1e-6, (
            seed, np.abs(res.Theta - ref_g).max()
        )
        assert (
            (np.abs(res.Theta) > 1e-9) == (np.abs(ref_g) > 1e-9)
        ).all(), f"support mismatch at seed {seed}"
        assert res.padded == 128 and res.n_shards == 8
    print("SHARDED_MATCH_OK")

    # --- exact |S_ij| == lam ties are NOT edges (strict eq. (4)) ---------
    rng = np.random.default_rng(0)
    b = 24
    A = np.round(rng.standard_normal((b, 2 * b)) * 4) / 4
    St = (A @ A.T) / (2 * b)
    St = np.round(St * 64) / 64          # dyadic: exactly representable
    np.fill_diagonal(St, np.abs(St).sum(axis=1) + 1.0)
    lam_t = 0.25
    St[0, 1] = St[1, 0] = 0.25           # planted exact ties
    St[2, 3] = St[3, 2] = -0.25
    res_t = glasso_sharded(St, lam_t)
    ref_t = np.asarray(glasso_admm(jnp.asarray(St), lam_t, tol=1e-9))
    assert res_t.Theta[0, 1] == 0.0 and res_t.Theta[2, 3] == 0.0
    assert ref_t[0, 1] == 0.0 and ref_t[2, 3] == 0.0
    assert np.abs(res_t.Theta - ref_t).max() < 1e-7
    print("SHARDED_TIES_OK")

    # --- warm start: Theta0 from a solved iterate cuts the iterations ----
    Sg = np.asarray(paper_synthetic(K=2, p1=50, seed=1))[:100, :100]
    Sg = 0.5 * (Sg + Sg.T)
    cold = glasso_sharded(Sg, 0.15)
    warm = glasso_sharded(Sg, 0.15, Theta0=cold.Theta)
    assert warm.iters < cold.iters / 2, (warm.iters, cold.iters)
    assert np.abs(warm.Theta - cold.Theta).max() < 1e-6
    print("SHARDED_WARM_OK")

    # --- engine end-to-end: oversize route == single-device route --------
    reset("")
    from repro.core.glasso import glasso

    Se = np.asarray(paper_synthetic(K=2, p1=50, seed=4))[:100, :100]
    Se = 0.5 * (Se + Se.T)
    lam_e = 0.15
    base = glasso(Se, lam_e, solver="admm", tol=1e-9)
    over = glasso(Se, lam_e, solver="admm", tol=1e-9, oversize_threshold=60)
    assert "oversize" in over.route_mix, over.route_mix
    assert over.oversize["dispatched"] >= 1
    assert over.oversize["fallbacks"] == 0
    c = counts("solver.oversize.")
    assert c["solver.oversize.dispatched"] >= 1
    assert c["solver.oversize.cg_iters"] > 0
    assert c.get("solver.oversize.fallbacks", 0) == 0
    assert np.abs(over.Theta - base.Theta).max() < 1e-6
    print("ENGINE_OVERSIZE_OK")
    print("MULTIDEVICE_OK")
    """
)

MARKERS = (
    "CC_OK",
    "BUCKET_OK",
    "SHARDED_MATCH_OK",
    "SHARDED_TIES_OK",
    "SHARDED_WARM_OK",
    "ENGINE_OVERSIZE_OK",
)


@pytest.fixture(scope="module")
def multidevice_run():
    """One subprocess for the whole module (see module docstring)."""
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env={
            "PYTHONPATH": SRC,
            "PATH": "/usr/bin:/bin",
            # skip accelerator probing: the 8-device emulation is host-only,
            # and a TPU-probe timeout would eat a minute of this shard
            "JAX_PLATFORMS": "cpu",
        },
        timeout=600,
    )
    return proc


def test_core_pillar_on_8_devices(multidevice_run):
    assert "MULTIDEVICE_OK" in multidevice_run.stdout, multidevice_run.stderr[-2000:]


@pytest.mark.parametrize("marker", MARKERS)
def test_scenario(multidevice_run, marker):
    assert marker in multidevice_run.stdout, (
        f"scenario {marker} did not pass:\n{multidevice_run.stdout}\n"
        f"{multidevice_run.stderr[-2000:]}"
    )

"""Observability tests: the span tracer, the labeled metrics registry, the
instrument shim's back-compat contract, the unified ``result.stages()``
view, and trace isolation under concurrent serving traffic.

The registry is process-global, so registry tests use a ``testobs.``
namespace (and unique tenants in the serving test) to stay independent of
whatever counters other tests have already bumped.
"""

import json
import math
import threading

import numpy as np
import pytest

from repro.core import instrument
from repro.obs.metrics import LATENCY_BUCKETS_S, REGISTRY, MetricsRegistry
from repro.obs.trace import (
    Trace,
    activate,
    context_token,
    current_trace,
    span,
    trace_request,
)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_span_noop_without_context():
    with span("orphan") as sp:
        assert sp is None
    assert current_trace() is None


def test_trace_request_nests_and_finishes():
    with trace_request("req", tenant="t") as tr:
        assert current_trace() is tr
        with span("outer", k=1):
            with span("inner"):
                pass
        with span("outer"):
            pass
    assert current_trace() is None
    assert tr.root.t1 is not None  # finished
    names = [s.name for s in tr.spans]
    assert names == ["req", "outer", "inner", "outer"]
    # nesting: inner's parent is the first outer, outers parent the root
    by_id = {s.span_id: s for s in tr.spans}
    inner = tr.spans[2]
    assert by_id[inner.parent_id].name == "outer"
    assert by_id[by_id[inner.parent_id].parent_id].name == "req"
    # stage view sums DIRECT children per name (two "outer" spans)
    stages = tr.stage_seconds()
    assert set(stages) == {"outer"}
    assert stages["outer"] <= tr.wall_seconds + 1e-9


def test_trace_request_degrades_under_active_trace():
    """Serving owns the root: a nested trace_request must not fork a second
    trace — it records a child span on the active one."""
    with trace_request("serve.request") as outer:
        with trace_request("engine.run") as inner:
            assert inner is outer
    assert [s.name for s in outer.spans] == ["serve.request", "engine.run"]


def test_cross_thread_handoff_explicit():
    """contextvars do not follow threads; the token handoff does."""
    recorded = {}

    def worker(token):
        # a fresh thread sees no ambient context...
        assert current_trace() is None
        with activate(token):
            with span("worker.stage") as sp:
                recorded["thread"] = sp.thread
        assert current_trace() is None

    with trace_request("req") as tr:
        t = threading.Thread(target=worker, args=(context_token(),), name="wk")
        t.start()
        t.join()
    assert [s.name for s in tr.spans] == ["req", "worker.stage"]
    assert recorded["thread"] == "wk"


def test_finish_closes_open_descendants():
    tr = Trace("root")
    child = tr.begin("child", parent_id=tr.root_id)
    tr.finish()
    assert tr.spans[child].t1 is not None
    assert tr.spans[child].t1 <= tr.root.t1 + 1e-12


def test_chrome_export_valid(tmp_path):
    with trace_request("req", tenant="t") as tr:
        with span("a", route="iterative"):
            with span("b"):
                pass
    path = tmp_path / "trace.json"
    text = tr.to_chrome_json(str(path))
    assert path.read_text() == text
    doc = json.loads(text)
    events = doc["traceEvents"]
    complete = [e for e in events if e["ph"] == "X"]
    meta = [e for e in events if e["ph"] == "M"]
    assert len(complete) == 3 and meta, "3 spans + thread_name metadata"
    for e in complete:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["pid"] == 1
    assert any(e["args"].get("route") == "iterative" for e in complete)
    # to_dict round-trips the same span count
    assert len(tr.to_dict()["spans"]) == 3


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_labels():
    reg = MetricsRegistry()
    reg.inc("testobs.reqs", tenant="a")
    reg.inc("testobs.reqs", 2, tenant="a")
    reg.inc("testobs.reqs", tenant="b")
    reg.set_gauge("testobs.depth", 7, queue="q0")
    assert reg.value("testobs.reqs", tenant="a") == 3
    assert reg.value("testobs.reqs", tenant="b") == 1
    assert reg.value("testobs.reqs", tenant="c") == 0
    assert reg.value("testobs.depth", queue="q0") == 7
    with pytest.raises(TypeError):
        reg.inc("testobs.depth")  # registered as gauge


def test_registry_histogram_quantile_and_merge():
    reg = MetricsRegistry()
    for v in (0.001, 0.002, 0.004, 0.008, 0.2):
        reg.observe("testobs.lat", v, tenant="a", slo="i")
    reg.observe("testobs.lat", 0.5, tenant="b", slo="i")
    # rank 0.5*5 = 2.5 lands on the 3rd sample (0.004); the estimate is
    # that bucket's upper bound — within one 1.5x ratio above the sample
    p50 = reg.quantile("testobs.lat", 0.5, tenant="a")
    assert 0.004 <= p50 <= 0.004 * 1.5
    # label-superset merge: slo="i" pools both tenants
    tot = reg.histogram_totals("testobs.lat", slo="i")
    assert tot["count"] == 6
    assert math.isclose(tot["sum"], 0.715)
    p99 = reg.quantile("testobs.lat", 0.99, slo="i")
    assert 0.5 <= p99 <= 0.5 * 1.5
    # empty selections are NaN, not 0 (0 would read as "fast")
    assert math.isnan(reg.quantile("testobs.lat", 0.5, tenant="zzz"))
    assert math.isnan(reg.quantile("testobs.nope", 0.5))


def test_registry_reset_by_prefix():
    reg = MetricsRegistry()
    reg.bump_flat("testobs.flat", 5)
    reg.bump_flat("other.flat", 5)
    reg.observe("testobs.lat", 0.01)
    reg.reset("testobs")
    assert reg.flat_value("testobs.flat") == 0
    assert reg.flat_value("other.flat") == 5
    assert math.isnan(reg.quantile("testobs.lat", 0.5))


def test_render_prometheus_exposition():
    reg = MetricsRegistry()
    reg.bump_flat("testobs.dotted.counter", 3)
    reg.inc("testobs.reqs", 2, tenant="a")
    reg.observe("testobs.lat", 0.01, slo="i")
    text = reg.render_prometheus()
    assert "# TYPE testobs_reqs counter" in text
    assert 'testobs_reqs{tenant="a"} 2' in text
    assert "# TYPE testobs_lat histogram" in text
    assert 'testobs_lat_count{slo="i"} 1' in text
    assert 'le="+Inf"' in text
    assert "testobs_dotted_counter 3" in text
    # cumulative bucket counts: the +Inf bucket equals the series count
    inf_line = [
        ln for ln in text.splitlines()
        if ln.startswith("testobs_lat_bucket") and 'le="+Inf"' in ln
    ]
    assert inf_line and inf_line[0].endswith(" 1")


def test_latency_buckets_cover_serving_range():
    assert LATENCY_BUCKETS_S[0] == pytest.approx(1e-4)
    assert LATENCY_BUCKETS_S[-1] > 600  # ten minutes fits below +Inf
    ratios = [
        b / a for a, b in zip(LATENCY_BUCKETS_S, LATENCY_BUCKETS_S[1:])
    ]
    assert all(r == pytest.approx(1.5) for r in ratios)


# ---------------------------------------------------------------------------
# instrument shim back-compat + the dispatch-us truncation fix
# ---------------------------------------------------------------------------


def test_timed_dispatch_accumulates_sub_microsecond(monkeypatch):
    """Regression: 10 dispatches of 0.3 us each must read back as 3 us.
    The old per-call int() truncation recorded 0 forever."""
    instrument.reset("engine.dispatch")
    ticks = iter(np.arange(1, 100) * 0.15e-6)
    monkeypatch.setattr(instrument, "_clock", lambda: float(next(ticks)))
    for _ in range(10):
        out, dt = instrument.timed_dispatch(lambda: "ok")
        assert out == "ok"
        assert dt == pytest.approx(0.15e-6)
    assert instrument.count("engine.dispatch.count") == 10
    us = instrument.count("engine.dispatch.us")
    assert isinstance(us, int)
    assert us == 2  # round(10 * 0.15) — truncation would have read 0


def test_instrument_shim_int_reads_and_peaks():
    instrument.reset("testobs")
    instrument.bump("testobs.n")
    instrument.bump("testobs.n", 4)
    instrument.bump("testobs.frac", 0.4)
    instrument.bump("testobs.frac", 0.4)
    instrument.set_peak("testobs.peak", 10)
    instrument.set_peak("testobs.peak", 7)  # watermark keeps the max
    assert instrument.count("testobs.n") == 5
    assert isinstance(instrument.count("testobs.n"), int)
    assert instrument.count("testobs.frac") == 1  # round(0.8)
    assert instrument.counts("testobs.")["testobs.peak"] == 10
    assert instrument.tail_counts("testobs.")["n"] == 5
    instrument.reset("testobs")
    assert instrument.counts("testobs.") == {}


def test_instrument_reset_clears_labeled_families():
    """bench_serve's reset("serve") must zero the request histogram too —
    otherwise warmup latencies leak into the measured quantiles."""
    REGISTRY.observe("serve.request_seconds", 0.123, tenant="testobs-reset")
    assert (
        REGISTRY.histogram_totals(
            "serve.request_seconds", tenant="testobs-reset"
        )["count"]
        == 1
    )
    instrument.reset("serve")
    assert math.isnan(
        REGISTRY.quantile("serve.request_seconds", 0.5, tenant="testobs-reset")
    )


# ---------------------------------------------------------------------------
# engine integration: stages() view, trace attachment, trace=False
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_result():
    from repro.covariance import lambda_interval_for_k, paper_synthetic
    from repro.engine.api import Engine

    S = paper_synthetic(3, 6, seed=3)
    lo, hi = lambda_interval_for_k(S, 3)
    return Engine().run(S, float(0.5 * (lo + hi)))


def test_result_stages_unified_view(small_result):
    r = small_result
    stages = r.stages()
    assert list(stages) == ["screen", "solve", "dispatch", "assemble"]
    # the legacy attributes are views over the same dict
    assert r.screen_seconds == stages["screen"]
    assert r.solve_seconds == stages["solve"]
    assert r.dispatch_seconds == stages["dispatch"]
    assert r.assemble_seconds == stages["assemble"]
    assert r.stages_us == {
        f"{k}_us": int(v * 1e6) for k, v in stages.items()
    }
    # mutating the returned copy must not corrupt the result
    stages["solve"] = -1.0
    assert r.solve_seconds >= 0.0


def test_engine_attaches_trace(small_result):
    tr = small_result.trace
    assert tr is not None and tr.name == "engine.run"
    names = {s.name for s in tr.spans}
    assert {"engine.screen", "engine.plan", "engine.solve"} <= names
    child_sum = sum(sp.seconds for sp in tr.children(tr.root_id))
    assert child_sum <= tr.wall_seconds + 1e-6


def test_trace_false_is_span_free():
    from repro.covariance import lambda_interval_for_k, paper_synthetic
    from repro.engine.api import Engine
    from repro.engine.options import EngineOptions

    S = paper_synthetic(3, 6, seed=4)
    lo, hi = lambda_interval_for_k(S, 3)
    r = Engine(options=EngineOptions(trace=False)).run(S, float(0.5 * (lo + hi)))
    assert r.trace is None


def test_engine_options_trace_validation():
    from repro.engine.options import EngineOptions

    assert EngineOptions(trace="jax").trace == "jax"
    with pytest.raises(ValueError, match="trace"):
        EngineOptions(trace="chrome")


def test_select_path_roots_a_trace():
    from repro.covariance import lambda_interval_for_k, paper_synthetic
    from repro.select import select_path

    S = paper_synthetic(2, 5, seed=5)
    lo, hi = lambda_interval_for_k(S, 2)
    sel = select_path(S, grid=[float(hi), float(0.5 * (lo + hi))], n=100)
    tr = sel.result.trace
    assert tr is not None and tr.name == "select.path"
    names = {s.name for s in tr.spans}
    assert {"select.grid", "select.score", "engine.path"} <= names


# ---------------------------------------------------------------------------
# serving: concurrent requests keep disjoint, reconciling span trees
# ---------------------------------------------------------------------------


def test_server_concurrent_trace_isolation():
    """N client threads against ONE server: every result carries its own
    trace, attributed to its own tenant, with every span inside its own
    root window — no cross-request leakage through the shared batcher."""
    from repro.covariance import lambda_interval_for_k, paper_synthetic
    from repro.engine.options import EngineOptions
    from repro.launch.control_plane import DenseSpec, RequestMeta
    from repro.launch.serve_glasso import GlassoServer

    n_threads = 4
    cases = []
    for i in range(n_threads):
        S = paper_synthetic(3, 6, seed=30 + i)
        lo, hi = lambda_interval_for_k(S, 3)
        cases.append((S, float(0.5 * (lo + hi))))

    results: dict[int, object] = {}
    errors: list[BaseException] = []
    opts = EngineOptions(solver="bcd", solver_opts={"tol": 1e-7})
    with GlassoServer(options=opts, max_delay=0.002) as server:
        def client(i):
            try:
                S, lam = cases[i]
                meta = RequestMeta(
                    tenant=f"obs-iso-{i}",
                    slo="interactive" if i % 2 == 0 else "batch",
                )
                results[i] = server.submit(DenseSpec(S, lam), meta=meta).result(
                    timeout=300
                )
            except BaseException as e:  # noqa: BLE001 - surfaced below
                errors.append(e)

        threads = [
            threading.Thread(target=client, args=(i,)) for i in range(n_threads)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
    assert not errors, errors

    traces = [results[i].trace for i in range(n_threads)]
    assert all(tr is not None for tr in traces)
    assert len({id(tr) for tr in traces}) == n_threads, "traces were shared"
    for i, tr in enumerate(traces):
        assert tr.root.attrs["tenant"] == f"obs-iso-{i}"
        assert tr.root.attrs["kind"] == "dense"
        assert tr.root.t1 is not None, "request trace never finished"
        for sp in tr.spans:
            assert sp.t0 >= tr.root.t0 - 1e-9, f"{sp.name} precedes the root"
            assert sp.t1 <= tr.root.t1 + 1e-9, f"{sp.name} outlives the root"
        child_sum = sum(sp.seconds for sp in tr.children(tr.root_id))
        assert child_sum <= tr.wall_seconds + 1e-6
        # each request's latency landed in its own labeled series
        assert (
            REGISTRY.histogram_totals(
                "serve.request_seconds", tenant=f"obs-iso-{i}"
            )["count"]
            == 1
        )
    # after the batch resolves, no context may leak into the caller thread
    assert current_trace() is None


def test_server_metrics_surface():
    from repro.covariance import lambda_interval_for_k, paper_synthetic
    from repro.engine.options import EngineOptions
    from repro.launch.control_plane import DenseSpec, RequestMeta
    from repro.launch.serve_glasso import GlassoServer

    S = paper_synthetic(2, 5, seed=40)
    lo, hi = lambda_interval_for_k(S, 2)
    opts = EngineOptions(solver="bcd", solver_opts={"tol": 1e-7})
    with GlassoServer(options=opts) as server:
        fut = server.submit(
            DenseSpec(S, float(0.5 * (lo + hi))),
            meta=RequestMeta(tenant="obs-metrics"),
        )
        res = fut.result(timeout=300)
        text = server.metrics()
    # the future carries the trace too (callers without the result object)
    assert fut.trace is res.trace is not None
    assert 'tenant="obs-metrics"' in text
    assert "serve_request_seconds_bucket" in text
    assert "# TYPE serve_request_seconds histogram" in text
    q = REGISTRY.quantile(
        "serve.request_seconds", 0.99, tenant="obs-metrics"
    )
    assert not math.isnan(q) and q > 0

"""Fault tolerance: checkpoint roundtrip, atomicity, bit-exact restart,
elastic re-shard, preemption save, optimizer + data-pipeline determinism."""


import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.checkpoint.io import latest_step


def small_state(seed=0):
    k = jax.random.key(seed)
    return {
        "step": jnp.asarray(7, jnp.int32),
        "params": {"w": jax.random.normal(k, (8, 16)), "b": jnp.zeros((16,))},
        "opt": {"m": jnp.ones((8, 16)) * 0.5},
    }


def test_roundtrip(tmp_path):
    state = small_state()
    save_checkpoint(tmp_path, 7, state)
    restored, step = restore_checkpoint(tmp_path, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, every=1, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, small_state(s))
    assert latest_step(tmp_path) == 4
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2 and kept[-1] == "step_000000004"


def test_atomicity_partial_save_invisible(tmp_path):
    """A torn checkpoint directory without the LATEST pointer swap must be
    ignored by restore."""
    state = small_state()
    save_checkpoint(tmp_path, 1, state)
    # simulate a crash mid-save of step 2: directory exists, no pointer swap
    torn = tmp_path / "step_000000002"
    torn.mkdir()
    (torn / "manifest.json").write_text("{not json")
    restored, step = restore_checkpoint(tmp_path, state)
    assert step == 1


def test_bit_exact_restart(tmp_path):
    """Train 12 steps; separately train 6, checkpoint, restart, train 6 more.
    Final params must be bit-exact equal (deterministic data + optimizer)."""
    from repro.launch.train import run_training

    common = dict(arch="qwen2_5_3b", batch=4, seq=32, reduced=True,
                  ckpt_every=6, log=lambda *a, **k: None)
    state_a, losses_a, _ = run_training(steps=12, ckpt_dir=None, **common)

    d1 = tmp_path / "run"
    state_b1, _, _ = run_training(steps=6, ckpt_dir=str(d1), **common)
    state_b2, losses_b, _ = run_training(steps=12, ckpt_dir=str(d1), resume=True, **common)

    assert int(state_a.step) == int(state_b2.step) == 12
    for a, b in zip(jax.tree.leaves(state_a.params), jax.tree.leaves(state_b2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_new_sharding(tmp_path):
    """Restore with shardings for a different (here: trivial) mesh — the
    elastic path: saved layout does not constrain the restore layout."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    state = small_state()
    save_checkpoint(tmp_path, 3, state, mesh_shape=(16, 16))
    from repro.core.jax_compat import make_mesh

    mesh = make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, step = restore_checkpoint(tmp_path, state, shardings=sh)
    assert step == 3
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf, jax.Array)


def test_data_pipeline_determinism():
    from repro.configs.base import ShapeConfig, get_arch
    from repro.data.synthetic_lm import SyntheticLM

    cfg = get_arch("granite_3_8b").reduced()
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    a = SyntheticLM(cfg, shape, seed=3).batch_at(17)
    b = SyntheticLM(cfg, shape, seed=3).batch_at(17)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    c = SyntheticLM(cfg, shape, seed=4).batch_at(17)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_grad_compression_roundtrip():
    from repro.optim.grad_compress import compress_tree, dequantize_int8, quantize_int8

    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((64, 64)) * 0.01, jnp.float32)
    q, scale = quantize_int8(g)
    deq = dequantize_int8(q, scale)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.5 + 1e-9

    # error feedback drives the *accumulated* bias to zero over steps
    grads = {"w": g}
    err = None
    acc_true = jnp.zeros_like(g)
    acc_comp = jnp.zeros_like(g)
    for _ in range(50):
        deq_tree, err = compress_tree(grads, err)
        acc_true += g
        acc_comp += deq_tree["w"]
    resid = float(jnp.max(jnp.abs(acc_comp - acc_true)))
    assert resid <= float(scale) * 1.5, resid

"""Model selection (``repro.select``): grid chokepoint, warm homotopy
exactness, per-component criteria, and the serving ``PathSpec`` contract.

The warm-start exactness test is the PR's property pillar: homotopy Thetas
must match cold single-lambda solves within ``route_check_tol`` across all
registered cc backends — including dyadic ``|S_ij| == lam`` ties (the strict
eq.-(4) threshold excludes the tied edge) and a merge event mid-grid.
"""

import numpy as np
import pytest

from conftest import lambda_between_edges, random_covariance
from repro.core import glasso, glasso_path
from repro.core.instrument import reset, tail_counts
from repro.engine.options import EngineOptions
from repro.engine.registry import available_cc_backends
from repro.select import (
    CovSource,
    Selection,
    SelectionReport,
    ebic_score,
    gaussian_loglik,
    homotopy_path,
    kfold_cv,
    lambda_grid,
    lambda_max,
    lambda_max_from_data,
    loglik_terms,
    normalize_lambda_grid,
    select_path,
    stars,
)

TIGHT = EngineOptions(solver_opts={"tol": 1e-9})


# -- grid normalization: the one chokepoint ------------------------------


def test_normalize_sorts_descending_and_dedupes():
    assert normalize_lambda_grid([0.1, 0.5, 0.3, 0.5, 0.1]) == [0.5, 0.3, 0.1]


@pytest.mark.parametrize("bad", [[], [0.5, 0.0], [0.5, -1.0], [np.nan], [np.inf]])
def test_normalize_rejects_degenerate_grids(bad):
    with pytest.raises(ValueError):
        normalize_lambda_grid(bad)


def test_glasso_path_normalizes_at_every_entry_point(rng):
    """Unsorted/duplicated grids give the same results as the canonical
    grid through both the screened planner and the screen=False baseline."""
    S = random_covariance(rng, 10)
    lams = [lambda_between_edges(S, q) for q in (0.3, 0.6, 0.8)]
    messy = [lams[0], lams[2], lams[1], lams[0]]  # unsorted + duplicate
    for screen in (True, False):
        a = glasso_path(S, messy, screen=screen, options=TIGHT)
        b = glasso_path(
            S, sorted(lams, reverse=True), screen=screen, options=TIGHT
        )
        assert [r.lam for r in a] == sorted(lams, reverse=True)
        for ra, rb in zip(a, b):
            np.testing.assert_allclose(ra.Theta, rb.Theta, atol=1e-7)
    with pytest.raises(ValueError):
        glasso_path(S, [0.5, -0.1])
    with pytest.raises(ValueError):
        glasso_path(S, [0.5, 0.0], screen=False)


def test_from_data_path_normalizes_grid(rng):
    X = rng.standard_normal((60, 12))
    res = glasso_path(X=X, lambdas=[0.2, 0.5, 0.2], from_data=True)
    assert [r.lam for r in res] == [0.5, 0.2]
    with pytest.raises(ValueError):
        glasso_path(X=X, lambdas=[0.5, 0.0], from_data=True)


# -- lambda_max / auto grid ----------------------------------------------


def test_lambda_max_matches_brute_force(rng):
    S = random_covariance(rng, 17)
    off = np.abs(S - np.diag(np.diag(S)))
    assert lambda_max(S) == pytest.approx(off.max(), abs=0.0)
    assert lambda_max(np.eye(1)) == 0.0


def test_lambda_max_from_data_matches_dense(rng):
    X = rng.standard_normal((40, 23))
    S = np.cov(X, rowvar=False, bias=True)
    reset("select.grid.")
    got = lambda_max_from_data(X, config={"tile": 8, "chunk": 16})
    assert got == pytest.approx(lambda_max(S), rel=1e-12)
    c = tail_counts("select.grid.")
    assert c.get("tiles_scanned", 0) >= 1
    n_tiles = -(-23 // 8)
    assert (
        c.get("tiles_scanned", 0) + c.get("tiles_pruned", 0)
        == n_tiles * (n_tiles + 1) // 2
    )


def test_lambda_grid_anchored_and_descending(rng):
    S = random_covariance(rng, 9)
    grid = lambda_grid(S, n_points=7)
    assert len(grid) == 7
    assert grid[0] == pytest.approx(lambda_max(S))
    assert grid[-1] == pytest.approx(0.1 * lambda_max(S))
    assert grid == sorted(grid, reverse=True)
    lin = lambda_grid(S, n_points=5, scale="linear", lam_min_ratio=0.5)
    assert np.allclose(np.diff(lin), np.diff(lin)[0])
    with pytest.raises(ValueError):
        lambda_grid(S, X=np.zeros((3, 3)))
    with pytest.raises(ValueError):
        lambda_grid(S, scale="sqrt")


# -- warm-start exactness: the homotopy property pillar ------------------


def _dyadic_merging_covariance():
    """PD covariance with exactly-representable edge weights and a known
    merge sequence: two 4-cliques at |S_ij| = 0.5, joined by one 0.25
    cross edge.  Grid points AT 0.5 and 0.25 are strict-threshold ties."""
    S = np.eye(8)
    for block in (range(0, 4), range(4, 8)):
        for i in block:
            for j in block:
                if i != j:
                    S[i, j] = 0.5
    S[3, 4] = S[4, 3] = 0.25
    assert np.linalg.eigvalsh(S).min() > 0
    return S


@pytest.mark.parametrize("backend", available_cc_backends())
def test_homotopy_matches_cold_solves_with_ties_and_merge(backend):
    S = _dyadic_merging_covariance()
    # 0.5: tie on every clique edge -> all singletons; 0.375: two cliques;
    # 0.25: tie on the cross edge -> still two; 0.125: merged into one.
    lams = [0.5, 0.375, 0.25, 0.125]
    opts = EngineOptions(cc_backend=backend, solver_opts={"tol": 1e-9})
    path = homotopy_path(S, lambdas=lams, options=opts)
    comp_counts = []
    for r, lam in zip(path, lams):
        cold = glasso(S, lam, options=opts)
        np.testing.assert_array_equal(r.labels, cold.labels)
        np.testing.assert_allclose(
            r.Theta, cold.Theta, atol=10 * opts.route_check_tol
        )
        comp_counts.append(int(r.screen.n_components))
    assert comp_counts == [8, 2, 2, 1]  # ties excluded, merge mid-grid


def test_homotopy_matches_cold_on_generic_covariance(rng):
    S = random_covariance(rng, 14)
    lams = [lambda_between_edges(S, q) for q in (0.85, 0.6, 0.4, 0.2)]
    path = homotopy_path(S, lambdas=lams, options=TIGHT)
    for r in path:
        cold = glasso(S, r.lam, options=TIGHT)
        np.testing.assert_allclose(r.Theta, cold.Theta, atol=1e-5)


# -- warm accounting ------------------------------------------------------


def test_warm_counters_classify_reused_merged_cold():
    S = _dyadic_merging_covariance()
    lams = [0.375, 0.3, 0.125]  # cliques, unchanged cliques, merged
    # route=False -> every bucket is solver-bound, so every one is counted
    opts = EngineOptions(route=False, solver_opts={"tol": 1e-8})
    reset("select.warm.")
    homotopy_path(S, lambdas=lams, options=opts)
    warm = tail_counts("select.warm.")
    # buckets, not components: the two same-shape cliques share one bucket
    assert warm.get("cold", 0) >= 1     # first grid point's clique bucket
    assert warm.get("reused", 0) >= 1   # unchanged clique bucket at 0.3
    assert warm.get("merged", 0) >= 1   # the 0.125 merge
    reset("select.warm.")
    homotopy_path(S, lambdas=lams, options=opts, warm_start=False)
    warm = tail_counts("select.warm.")
    assert set(warm) <= {"cold"} and warm.get("cold", 0) >= 3


# -- criteria -------------------------------------------------------------


def test_loglik_and_ebic_match_manual_dense(rng):
    S = random_covariance(rng, 12)
    lam = lambda_between_edges(S, 0.5)
    res = glasso(S, lam, options=TIGHT)
    src = CovSource(S=S)
    ld, tr = loglik_terms(res, src)
    sign, manual_ld = np.linalg.slogdet(res.Theta)
    assert sign > 0
    assert ld == pytest.approx(manual_ld, rel=1e-10)
    assert tr == pytest.approx(float(np.sum(S * res.Theta)), rel=1e-10)
    n, gamma = 80, 0.5
    E = res.support_edges().shape[0]
    manual = -n * (manual_ld - np.sum(S * res.Theta)) + E * (
        np.log(n) + 4 * gamma * np.log(S.shape[0])
    )
    assert ebic_score(res, src, n, gamma=gamma) == pytest.approx(manual)
    assert gaussian_loglik(res, src, n) == pytest.approx(0.5 * n * (ld - tr))
    with pytest.raises(ValueError):
        ebic_score(res, src, 0)


def test_criteria_agree_dense_vs_sparse_output(rng):
    S = random_covariance(rng, 12)
    lam = lambda_between_edges(S, 0.5)
    dense = glasso(S, lam, options=TIGHT.replace(output="dense"))
    sparse = glasso(S, lam, options=TIGHT.replace(output="sparse"))
    src = CovSource(S=S)
    ld_d, tr_d = loglik_terms(dense, src)
    ld_s, tr_s = loglik_terms(sparse, src)
    assert ld_s == pytest.approx(ld_d, rel=1e-8)
    assert tr_s == pytest.approx(tr_d, rel=1e-8)


def test_cov_source_from_data_matches_covariance(rng):
    X = rng.standard_normal((50, 10))
    S = np.cov(X, rowvar=False, bias=True)
    src = CovSource(X=X)
    idx = np.array([1, 4, 7])
    np.testing.assert_allclose(src.block(idx), S[np.ix_(idx, idx)], atol=1e-12)
    np.testing.assert_allclose(src.diag(idx), np.diag(S)[idx], atol=1e-12)
    assert src.p == 10


# -- select_path + SelectionReport ---------------------------------------


def test_select_path_ebic_report_shape(rng):
    S = random_covariance(rng, 12)
    sel = select_path(S, grid=5, criterion="ebic", n=100, options=TIGHT)
    assert isinstance(sel, Selection)
    rep = sel.report
    assert isinstance(rep, SelectionReport)
    assert rep.criterion == "ebic"
    assert len(rep.lambdas) == len(rep.scores) == 5
    assert len(rep.support_sizes) == len(rep.n_components) == 5
    assert len(rep.route_mixes) == len(rep.stages_us) == 5
    assert rep.lambdas == sorted(rep.lambdas, reverse=True)
    assert 0 <= rep.selected_index < 5
    assert rep.selected_lam == rep.lambdas[rep.selected_index]
    assert sel.result is sel.path[rep.selected_index]
    assert rep.scores[rep.selected_index] == min(rep.scores)
    assert rep.detail == {"gamma": 0.5, "n": 100}
    assert 0.0 <= rep.warm_fraction <= 1.0
    for st in rep.stages_us:
        assert set(st) == {"screen_us", "solve_us", "dispatch_us", "assemble_us"}
        assert all(v >= 0 for v in st.values())


def test_select_path_validates_inputs(rng):
    S = random_covariance(rng, 8)
    with pytest.raises(ValueError):
        select_path(S, X=np.zeros((4, 8)))
    with pytest.raises(ValueError):
        select_path(S, criterion="aic", n=10)
    with pytest.raises(ValueError):
        select_path(S, criterion="ebic")  # covariance input without n=
    with pytest.raises(ValueError):
        select_path(S, criterion="cv", n=10)  # cv resamples rows
    with pytest.raises(ValueError):
        select_path(S, grid={"auto": 5, "extra": 1}, n=10)
    with pytest.raises(TypeError):
        select_path(S, n=10, criterion_opts={"bogus": 1})


def test_select_path_cv_and_stars_from_data(rng):
    X = rng.standard_normal((60, 10))
    grid = [0.6, 0.4, 0.25]
    cv = select_path(X=X, grid=grid, criterion="cv", criterion_opts={"k": 3})
    assert len(cv.report.scores) == 3
    assert cv.report.scores[cv.report.selected_index] == max(cv.report.scores)
    assert cv.report.detail["k"] == 3
    st = select_path(
        X=X, grid=grid, criterion="stars", criterion_opts={"n_subsamples": 4}
    )
    assert len(st.report.scores) == 3
    assert all(0.0 <= d <= 0.5 + 1e-12 for d in st.report.scores)
    mono = st.report.detail["monotone"]
    assert all(a <= b + 1e-12 for a, b in zip(mono, mono[1:]))


def test_kfold_cv_and_stars_direct(rng):
    X = rng.standard_normal((45, 8))
    out = kfold_cv(X, [0.5, 0.3], k=3, seed=1)
    assert len(out["scores"]) == 2 and out["k"] == 3
    out2 = stars(X, [0.5, 0.3], n_subsamples=3, seed=1)
    assert len(out2["scores"]) == 2 and out2["n_subsamples"] == 3
    with pytest.raises(ValueError):
        kfold_cv(X, [0.5], k=1)


# -- serving: PathSpec through the control plane -------------------------


def test_pathspec_validation(rng):
    from repro.launch.control_plane import PathSpec

    S = random_covariance(rng, 6)
    with pytest.raises(ValueError):
        PathSpec(S=S, X=np.zeros((3, 6)))
    with pytest.raises(ValueError):
        PathSpec()
    with pytest.raises(ValueError):
        PathSpec(S=S, criterion="bic")
    with pytest.raises(ValueError):
        PathSpec(S=S, criterion="cv")  # resampling criteria need X
    assert PathSpec(S=S).p == 6
    assert PathSpec(X=np.zeros((4, 9))).p == 9


def test_pathspec_cache_key(rng):
    from repro.launch.control_plane import PathSpec, spec_cache_key

    S = random_covariance(rng, 6)
    k1 = spec_cache_key(PathSpec(S=S, grid={"auto": 5}), "sparse")
    k2 = spec_cache_key(PathSpec(S=S, grid={"auto": 5}), "sparse")
    assert k1 == k2 and k1[0] == "path"
    # different grid / criterion / gamma / output -> different keys
    assert spec_cache_key(PathSpec(S=S, grid=[0.5, 0.2]), "sparse") != k1
    assert spec_cache_key(PathSpec(S=S, grid={"auto": 5}, gamma=1.0), "sparse") != k1
    assert spec_cache_key(PathSpec(S=S, grid={"auto": 5}), "dense") != k1
    # custom stream config -> uncacheable
    assert spec_cache_key(
        PathSpec(X=np.zeros((4, 6)), grid=[0.5], stream={"tile": 4}), "sparse"
    ) is None


def test_pathspec_defaults_to_batch_slo(rng):
    from repro.launch.control_plane import DenseSpec, PathSpec
    from repro.launch.serve_glasso import GlassoServer

    S = random_covariance(rng, 6)
    assert GlassoServer._fold_output(None, None, spec=PathSpec(S=S)).slo == "batch"
    assert (
        GlassoServer._fold_output(None, None, spec=DenseSpec(S=S, lam=0.5)).slo
        == "interactive"
    )


def test_submit_pathspec_bitwise_equals_offline(rng):
    from repro.launch.control_plane import PathSpec
    from repro.launch.serve_glasso import GlassoServer, serve_stats

    S = random_covariance(rng, 14)
    grid = [lambda_between_edges(S, q) for q in (0.8, 0.5, 0.3)]
    opts = EngineOptions(output="sparse", solver_opts={"tol": 1e-8})
    offline = select_path(S, grid=grid, criterion="ebic", n=120, options=opts)
    spec = PathSpec(S=S, grid=grid, criterion="ebic", n=120)
    with GlassoServer(options=opts, result_cache=4) as server:
        served = server.submit(spec).result(timeout=300)
        again = server.submit(spec).result(timeout=300)
    assert served.report.scores == offline.report.scores
    assert served.report.selected_index == offline.report.selected_index
    assert served.report.lambdas == offline.report.lambdas
    np.testing.assert_array_equal(
        served.result.support_edges(), offline.result.support_edges()
    )
    for (ca, ba), (cb, bb) in zip(
        served.result.Theta.blocks(), offline.result.Theta.blocks()
    ):
        np.testing.assert_array_equal(ca, cb)
        np.testing.assert_array_equal(ba, bb)  # bitwise, not approx
    assert again is served  # second submit is a cache hit
    st = serve_stats()
    # the hit short-circuits before kind dispatch, so exactly one admission
    assert st.get("serve.path_requests", 0) >= 1
    assert st.get("serve.cache.hits", 0) >= 1

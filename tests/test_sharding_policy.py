"""Sharding resolver unit tests: divisibility fallbacks, FSDP axes, cache
layout chains, activation constraints — on both production mesh shapes
(structural only; no 512-device runtime needed because PartitionSpec
resolution is pure)."""

from jax.sharding import PartitionSpec as P


class FakeMesh:
    """Duck-typed mesh: the resolver only reads axis_names and shape."""

    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)


def policy(multi=False, **kw):
    from repro.distributed.sharding import ShardingPolicy

    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16} if multi else {"data": 16, "model": 16})
    return ShardingPolicy(mesh, **kw)


def test_param_tensor_and_fsdp_axes():
    pol = policy()
    # (embed, heads): embed -> data (FSDP), heads -> model
    assert pol.param_pspec(("embed", "heads"), (8192, 8192)) == P("data", "model")
    # vocab -> model
    assert pol.param_pspec(("vocab", "embed"), (152064, 8192)) == P("model", "data")


def test_divisibility_fallback_replicates():
    pol = policy()
    # kv dim 8*128=1024 divisible; but 2 kv heads * 64 = 128 not divisible by 16 -> still divisible!
    # use a genuinely indivisible dim:
    assert pol.param_pspec(("embed", "kv"), (4096, 129)) == P("data", None)
    # layers axis never sharded
    assert pol.param_pspec(("layers", "embed", "mlp"), (48, 4096, 12800)) == P(None, "data", "model")


def test_multi_pod_fsdp_spans_pod_and_data():
    pol = policy(multi=True)
    spec = pol.param_pspec(("embed", "mlp"), (8192, 29568))
    assert spec == P(("pod", "data"), "model")


def test_no_axis_reuse_within_one_spec():
    pol = policy()
    # both dims want "model": only the first gets it
    spec = pol.param_pspec(("vocab", "heads"), (256, 256))
    assert spec == P("model", None)


def test_fsdp_off():
    pol = policy(fsdp=False)
    assert pol.param_pspec(("embed", "heads"), (8192, 8192)) == P(None, "model")


def test_batch_pspec_and_replicated_mode():
    pol = policy()
    assert pol.batch_pspec((256, 4096)) == P("data", None)
    assert pol.batch_pspec((7, 4096)) == P(None, None)  # indivisible batch
    pol_r = policy(batch_replicated=True)
    assert pol_r.batch_pspec((256, 4096)) == P(None, None)


def test_cache_pspec_chains():
    pol = policy()
    # (L,B,Hkv,S,hd): B -> data, H=8 indivisible by 16 -> S takes model
    spec = pol.cache_pspec("k", (80, 128, 8, 32768, 128))
    assert spec == P(None, "data", None, "model", None)
    # divisible kv heads: H -> model, S -> leftover dp? data consumed by B
    spec = pol.cache_pspec("k", (38, 128, 32, 32768, 64))
    assert spec == P(None, "data", "model", None, None)
    # long_500k: B=1 unshardable -> S absorbs axes
    spec = pol.cache_pspec("k", (6, 1, 32, 524288, 64))
    assert spec == P(None, None, "model", "data", None)
    # MLA latents
    spec = pol.cache_pspec("c", (27, 128, 32768, 512))
    assert spec == P(None, "data", "model", None)


def test_act_pspec_seq_shard_lever():
    pol = policy()
    assert pol.act_pspec(("batch", "seq", "embed"), (16, 4096, 8192)) == P("data", None, None)
    from repro.distributed.sharding import ShardingPolicy

    pol2 = policy()
    pol2.seq_shard = True
    assert pol2.act_pspec(("batch", "seq", "embed"), (16, 4096, 8192)) == P("data", "model", None)
    # vocab-sharded logits
    assert pol.act_pspec(("batch", "seq", "vocab"), (16, 4096, 152064)) == P("data", None, "model")

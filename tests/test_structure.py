"""Structure classifier invariants: exact class per known graph, MCS/PEO
chordality agreement with networkx, clique-tree identities, and the strict
tie convention |S_ij| == lam -> not an edge."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.engine.structure import (
    STRUCTURES,
    classify_adjacency,
    classify_component,
    clique_tree,
    component_adjacency,
    mcs_elimination_order,
    peo_or_none,
)


def _adj(b, edges):
    A = np.zeros((b, b), dtype=bool)
    for i, j in edges:
        A[i, j] = A[j, i] = True
    return A


def _random_connected(rng, b, extra_edges):
    """Random connected graph: random recursive tree + extra random edges."""
    edges = [(i, int(rng.integers(0, i))) for i in range(1, b)]
    for _ in range(extra_edges):
        i, j = rng.integers(0, b, size=2)
        if i != j:
            edges.append((int(min(i, j)), int(max(i, j))))
    return _adj(b, edges)


# ------------------------------------------------------------ known graphs


def test_known_classes():
    assert classify_adjacency(_adj(1, [])) == "singleton"
    assert classify_adjacency(_adj(2, [(0, 1)])) == "pair"
    # path and star are trees
    assert classify_adjacency(_adj(4, [(0, 1), (1, 2), (2, 3)])) == "tree"
    assert classify_adjacency(_adj(4, [(0, 1), (0, 2), (0, 3)])) == "tree"
    # triangle and chorded 4-cycle are chordal (cyclic, so not trees)
    assert classify_adjacency(_adj(3, [(0, 1), (1, 2), (0, 2)])) == "chordal"
    assert (
        classify_adjacency(_adj(4, [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)]))
        == "chordal"
    )
    # chordless 4- and 5-cycles are the smallest non-chordal graphs
    assert classify_adjacency(_adj(4, [(0, 1), (1, 2), (2, 3), (3, 0)])) == "general"
    assert (
        classify_adjacency(_adj(5, [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)]))
        == "general"
    )
    # complete graph is chordal (one clique)
    K5 = ~np.eye(5, dtype=bool)
    assert classify_adjacency(K5) == "chordal"


def test_structures_tuple_is_the_ladder():
    assert STRUCTURES == (
        "singleton", "pair", "tree", "chordal", "general", "oversize"
    )
    # "oversize" is planner-assigned (size threshold), never by the classifier
    from repro.engine.registry import route_for

    assert route_for("oversize") == "sharded"


# ------------------------------------------------------------ vs networkx


@settings(max_examples=25, deadline=None)
@given(b=st.integers(3, 14), extra=st.integers(0, 12), seed=st.integers(0, 10_000))
def test_chordality_matches_networkx(b, extra, seed):
    nx = pytest.importorskip("networkx")
    rng = np.random.default_rng(seed)
    A = _random_connected(rng, b, extra)
    G = nx.from_numpy_array(A)
    cls = classify_adjacency(A)
    if nx.is_chordal(G):
        assert cls in ("pair", "tree", "chordal")
    else:
        assert cls == "general"
    # tree <=> acyclic (connected)
    assert (cls == "tree") == (int(A.sum()) // 2 == b - 1 and b > 2)


# ------------------------------------------------------------ clique tree


@settings(max_examples=25, deadline=None)
@given(b=st.integers(3, 14), extra=st.integers(0, 12), seed=st.integers(0, 10_000))
def test_clique_tree_identities(b, extra, seed):
    """On any connected chordal graph: cliques cover all edges, every clique
    is complete, and the junction-tree identity sum|C| - sum|Sep| = b."""
    rng = np.random.default_rng(seed)
    A = _random_connected(rng, b, extra)
    order = peo_or_none(A)
    if order is None:
        return  # not chordal, nothing to check
    cliques, seps = clique_tree(A, order)
    assert len(seps) == len(cliques) - 1
    covered = np.zeros_like(A)
    for C in cliques:
        sub = A[np.ix_(C, C)]
        assert sub[~np.eye(len(C), dtype=bool)].all(), "clique not complete"
        covered[np.ix_(C, C)] = True
    assert covered[A].all(), "some edge not covered by a clique"
    assert sum(len(C) for C in cliques) - sum(len(s) for s in seps) == b
    assert all(len(s) > 0 for s in seps), "connected graph, empty separator"


def test_mcs_order_is_permutation():
    rng = np.random.default_rng(3)
    A = _random_connected(rng, 9, 5)
    order = mcs_elimination_order(A)
    assert sorted(order.tolist()) == list(range(9))


# ------------------------------------------------------------ ties


def test_tie_is_not_an_edge():
    """|S_ij| == lam exactly: strict eq. (4) thresholding, so the pair is
    disconnected — the classifier must agree with the screening backends."""
    lam = 0.25
    S = np.eye(3)
    S[0, 1] = S[1, 0] = lam        # tie: NOT an edge
    S[1, 2] = S[2, 1] = 2 * lam    # edge
    A = component_adjacency(S, np.arange(3), lam)
    assert not A[0, 1] and A[1, 2]
    # component {1, 2} is a pair; vertex 0 is a singleton
    assert classify_component(S, np.array([1, 2]), lam) == "pair"
    assert classify_component(S, np.array([0]), lam) == "singleton"


def test_classify_component_matches_adjacency():
    rng = np.random.default_rng(0)
    S = np.eye(6) + rng.uniform(-0.4, 0.4, (6, 6))
    S = 0.5 * (S + S.T)
    comp = np.arange(6)
    lam = 0.15
    assert classify_component(S, comp, lam) == classify_adjacency(
        component_adjacency(S, comp, lam)
    )


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

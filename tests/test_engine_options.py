"""EngineOptions: one typed configuration object across glasso /
glasso_path / joint_glasso / Engine / JointEngine / GlassoServer, with the
legacy-kwarg deprecation layer behind a single normalization chokepoint."""

import warnings

import numpy as np
import pytest

from repro.core import glasso, glasso_path
from repro.covariance import lambda_interval_for_k, paper_synthetic
from repro.engine import EngineOptions, normalize_options
from repro.joint import joint_glasso


def _case(seed=0):
    S = paper_synthetic(3, 8, seed=seed)
    lam_min, lam_max = lambda_interval_for_k(S, 3)
    return S, float(0.5 * (lam_min + lam_max))


def test_options_equivalent_to_legacy_kwargs_bitwise():
    S, lam = _case()
    with pytest.warns(DeprecationWarning, match="glasso"):
        r_legacy = glasso(S, lam, solver="bcd", route=False, tol=1e-9)
    r_opts = glasso(
        S, lam,
        options=EngineOptions(
            solver="bcd", route=False, solver_opts={"tol": 1e-9}
        ),
    )
    np.testing.assert_array_equal(r_legacy.Theta, r_opts.Theta)
    np.testing.assert_array_equal(r_legacy.labels, r_opts.labels)
    assert r_legacy.routed == r_opts.routed


def test_options_path_and_no_warning():
    S, _ = _case(seed=1)
    lam_min, lam_max = lambda_interval_for_k(S, 3)
    lams = [0.9 * lam_max, 0.5 * (lam_min + lam_max)]
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        path = glasso_path(
            S, lams, options=EngineOptions(solver_opts={"tol": 1e-8})
        )
    assert len(path) == 2
    assert path[0].lam > path[1].lam


def test_options_and_kwargs_together_rejected():
    S, lam = _case()
    with pytest.raises(TypeError, match="not both"):
        glasso(S, lam, options=EngineOptions(), tol=1e-8)
    with pytest.raises(TypeError, match="EngineOptions"):
        glasso(S, lam, options={"solver": "bcd"})


def test_joint_options_equivalence():
    Ss = [np.eye(8) + 0.6 * (1 - np.eye(8)) * (0.9 ** k) for k in range(2)]
    with pytest.warns(DeprecationWarning, match="joint_glasso"):
        r_legacy = joint_glasso(Ss, 0.4, 0.1, penalty="group", tol=1e-8)
    r_opts = joint_glasso(
        Ss, 0.4, 0.1, penalty="group",
        options=EngineOptions(solver_opts={"tol": 1e-8}),
    )
    np.testing.assert_array_equal(r_legacy.Theta, r_opts.Theta)
    assert r_opts.solver == r_legacy.solver


def test_internal_constructors_normalize_silently():
    """Engine/JointEngine/GlassoServer accept the same legacy kwargs WITHOUT
    warning — only the public wrappers are the deprecation surface."""
    from repro.engine.api import Engine
    from repro.launch.serve_glasso import GlassoServer

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        Engine(solver="bcd", tol=1e-8)
        GlassoServer(solver="bcd", tol=1e-8, route=False)


def test_options_validation_and_replace():
    with pytest.raises(ValueError, match="output"):
        EngineOptions(output="csv")
    base = EngineOptions(solver="bcd", solver_opts={"tol": 1e-8})
    # replace(): known fields swap, unknown keys merge into solver_opts
    r = base.replace(route=False, max_iter=50)
    assert r.route is False and r.solver == "bcd"
    assert r.solver_opts == {"tol": 1e-8, "max_iter": 50}
    assert base.solver_opts == {"tol": 1e-8}  # frozen original untouched
    # normalize_options splits engine keys from free-form solver opts
    opts = normalize_options(None, {"route": False, "tol": 1e-7})
    assert opts.route is False and opts.solver_opts == {"tol": 1e-7}
    assert normalize_options(None, {}) == EngineOptions()


def test_unknown_solver_opt_still_rejected_downstream():
    S, lam = _case()
    with pytest.raises(TypeError, match="option"):
        glasso(S, lam, options=EngineOptions(solver_opts={"bogus": 1}))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

"""Serving x routing ladder: all-fast-path requests are solved at admission
(the dispatch queue is skipped entirely), mixed batches coalesce per
(size, route), and every served Theta matches a direct engine solve."""

import numpy as np
import pytest

from repro.core import glasso
from repro.core.instrument import count, reset
from repro.covariance import lambda_interval_for_k, paper_synthetic
from repro.launch.serve_glasso import GlassoRequest, GlassoServer


def _tree_request(seed, p=12, lam=0.3):
    """Tridiagonal S: one path-graph component -> pure closed-form plan."""
    rng = np.random.default_rng(seed)
    S = np.eye(p) * 2.0
    for i in range(p - 1):
        v = rng.uniform(0.5, 0.8) * (1 if rng.random() < 0.5 else -1)
        S[i, i + 1] = S[i + 1, i] = v
    return S, lam


def _dense_request(seed):
    S = paper_synthetic(3, 8, seed=seed)
    lam_min, lam_max = lambda_interval_for_k(S, 3)
    return S, float(0.4 * lam_min + 0.6 * lam_max)


def test_fast_path_requests_skip_the_queue():
    reqs = [_tree_request(seed=i) for i in range(4)]
    reset("serve")
    with GlassoServer(solver="bcd", max_delay=0.25, tol=1e-8) as server:
        futures = [server.submit(S, lam) for S, lam in reqs]
        results = [f.result(timeout=300) for f in futures]
    assert count("serve.fastpath_requests") == len(reqs)
    assert count("serve.batches") == 0  # nothing ever reached the batcher
    assert count("serve.fastpath_blocks") >= len(reqs)
    for (S, lam), res in zip(reqs, results):
        direct = glasso(S, lam, solver="bcd", tol=1e-8)
        np.testing.assert_allclose(res.Theta, direct.Theta, atol=1e-6)
        assert res.route_mix.get("tree", 0) == 1


def test_mixed_admission_splits_fast_and_queued():
    tree_S, tree_lam = _tree_request(seed=11)
    dense_S, dense_lam = _dense_request(seed=200)
    reset("serve")
    with GlassoServer(solver="bcd", max_delay=0.05, tol=1e-8) as server:
        f_tree = server.submit(tree_S, tree_lam)
        f_dense = server.submit(dense_S, dense_lam)
        r_tree = f_tree.result(timeout=300)
        r_dense = f_dense.result(timeout=300)
    assert count("serve.fastpath_requests") == 1
    assert count("serve.requests") == 2
    np.testing.assert_allclose(
        r_tree.Theta, glasso(tree_S, tree_lam, tol=1e-8).Theta, atol=1e-6
    )
    np.testing.assert_allclose(
        r_dense.Theta, glasso(dense_S, dense_lam, tol=1e-8).Theta, atol=1e-6
    )


def test_fast_path_disabled_still_correct():
    S, lam = _tree_request(seed=3)
    reset("serve")
    with GlassoServer(solver="bcd", fast_path=False, tol=1e-8) as server:
        res = server.submit(S, lam).result(timeout=300)
    assert count("serve.fastpath_requests") == 0
    assert count("serve.batches") >= 1  # went through the batcher
    assert count("serve.fastpath_blocks") >= 1  # ...but still routed fast
    np.testing.assert_allclose(res.Theta, glasso(S, lam, tol=1e-8).Theta, atol=1e-6)


def test_batch_coalesces_per_size_and_route():
    """A synchronous mixed batch: tree requests share one closed-form
    dispatch; dense requests share the iterative dispatch; results match
    unrouted direct solves."""
    reqs = [GlassoRequest(*_tree_request(seed=i, p=8)) for i in range(3)]
    reqs += [GlassoRequest(*_dense_request(seed=i)) for i in range(2)]
    server = GlassoServer(solver="bcd", tol=1e-8)
    reset("serve")
    server.solve_batch(reqs)
    # >= 3: the three tree requests are certainly fast-path; a planted
    # "dense" block may legitimately classify chordal at its lambda too
    assert count("serve.fastpath_blocks") >= 3
    for req in reqs:
        res = req.future.result(timeout=0)
        ref = glasso(req.S, req.lam, route=False, solver="bcd", tol=1e-8)
        np.testing.assert_allclose(res.Theta, ref.Theta, atol=1e-6)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

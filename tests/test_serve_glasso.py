"""Batched serving endpoint: >= 8 concurrent requests solved correctly, with
same-size buckets coalesced ACROSS requests into shared compiled-solver
dispatches (asserted via the engine's compiled-function cache hit counters
and the serve.* coalescing counters)."""

import numpy as np
import pytest

from repro.core import glasso
from repro.core.instrument import count, counts, reset
from repro.covariance import lambda_interval_for_k, paper_synthetic
from repro.engine.executor import compiled_cache_stats
from repro.launch.serve_glasso import GlassoRequest, GlassoServer

N_REQUESTS = 8


def _requests():
    reqs = []
    for i in range(N_REQUESTS):
        # same (K, p1) structure for every client -> same padded bucket size,
        # different matrices and lambdas -> coalescing is across requests
        S = paper_synthetic(3, 8, seed=100 + i)
        lam_min, lam_max = lambda_interval_for_k(S, 3)
        reqs.append((S, float(0.4 * lam_min + 0.6 * lam_max)))
    return reqs


def test_concurrent_requests_solved_and_coalesced():
    reqs = _requests()
    reset("serve")
    hits_before = compiled_cache_stats()["hits"]

    with GlassoServer(solver="bcd", max_delay=0.25, tol=1e-8) as server:
        futures = [server.submit(S, lam) for S, lam in reqs]
        results = [f.result(timeout=300) for f in futures]

    assert len(results) == N_REQUESTS
    assert count("serve.requests") == N_REQUESTS
    # every request's Theta matches a direct (unbatched) engine solve
    for (S, lam), res in zip(reqs, results):
        direct = glasso(S, lam, solver="bcd", tol=1e-8)
        np.testing.assert_allclose(res.Theta, direct.Theta, atol=1e-6)
        assert res.lam == lam
    # coalescing: all requests produce 8-sized buckets; far fewer dispatches
    # than requests means buckets traveled together...
    assert count("serve.dispatches") < N_REQUESTS
    # ...and at least one dispatch mixed blocks from several requests
    assert count("serve.coalesced_blocks") > 0
    # the direct glasso() calls above reuse the SAME compiled executables the
    # server populated/used: process-global cache, hits must have grown
    assert compiled_cache_stats()["hits"] > hits_before


def test_batch_solve_is_one_dispatch_per_size():
    """Synchronous coalescing core: 8 requests x 3 blocks of size 8 each must
    collapse into exactly ONE compiled dispatch of 24 stacked blocks."""
    reqs = [GlassoRequest(S=S, lam=lam) for S, lam in _requests()]
    # route=False: this test pins the COALESCING mechanics (one dispatch per
    # padded size); with routing on, a block whose subgraph happens to be
    # chordal/tree at this lambda legitimately leaves the iterative group —
    # covered by test_serve_routes.py
    server = GlassoServer(solver="bcd", tol=1e-8, route=False)
    reset("serve")
    server.solve_batch(reqs)
    assert count("serve.dispatches") == 1
    assert count("serve.coalesced_blocks") == 3 * N_REQUESTS
    for req in reqs:
        res = req.future.result(timeout=0)
        assert res.screen.n_components == 3
        assert sorted(res.block_sizes) == [8, 8, 8]


def test_repeat_batches_hit_compiled_cache():
    """Steady-state serving: a second batch of the same shape family compiles
    nothing — every dispatch is a cache hit."""
    server = GlassoServer(solver="bcd", tol=1e-8, route=False)
    server.solve_batch([GlassoRequest(S=S, lam=lam) for S, lam in _requests()])
    stats0 = compiled_cache_stats()
    server.solve_batch([GlassoRequest(S=S, lam=lam) for S, lam in _requests()])
    stats1 = compiled_cache_stats()
    assert stats1["misses"] == stats0["misses"]  # no new compiles
    assert stats1["hits"] > stats0["hits"]


def test_server_propagates_per_request_stats():
    S = paper_synthetic(2, 6, seed=5)
    lam_min, lam_max = lambda_interval_for_k(S, 2)
    with GlassoServer(solver="bcd", tol=1e-8) as server:
        res = server.submit(S, 0.5 * (lam_min + lam_max)).result(timeout=300)
    assert res.screen is not None
    assert res.screen.n_components == 2
    assert res.solver == "bcd"
    assert counts("serve")  # counters populated


def test_submit_data_matches_dense_submit(rng):
    """The data-matrix admission path (streamed screening, materialized
    blocks) must resolve to the same solution as submitting the dense S."""
    from conftest import lambda_between_edges

    X = rng.standard_normal((40, 60)) * (0.1 + rng.random(60))
    Xc = X - X.mean(axis=0)
    S = Xc.T @ Xc / X.shape[0]
    lam = lambda_between_edges(S, 0.6)
    reset("serve")
    with GlassoServer(solver="bcd", tol=1e-8) as server:
        rd = server.submit_data(
            X, lam, stream={"tile": 32, "chunk": 16}
        ).result(timeout=300)
        rs = server.submit(S, lam).result(timeout=300)
    np.testing.assert_allclose(rd.Theta, rs.Theta, atol=1e-6)
    assert count("serve.data_requests") == 1
    assert count("serve.requests") == 2
    assert rd.screen.tiles_total > 0  # streamed provenance rode along


def test_append_rows_incremental_session(rng):
    """append_rows re-screens incrementally and matches a from-scratch dense
    solve of the grown dataset; unknown sessions are an error."""
    from conftest import lambda_between_edges

    p = 64
    scales = np.where(np.arange(p) < 24, 1.0, 0.05)
    X = rng.standard_normal((40, p)) * scales
    Xc = X - X.mean(axis=0)
    S = Xc.T @ Xc / X.shape[0]
    lam = lambda_between_edges(S, 0.8)
    reset("serve")
    reset("stream")
    with GlassoServer(solver="bcd", tol=1e-8) as server:
        server.submit_data(
            X, lam, session="s0", stream={"tile": 32, "chunk": 16}
        ).result(timeout=300)
        Y = 0.02 * rng.standard_normal((3, p)) * scales
        res = server.append_rows("s0", Y).result(timeout=300)
        with pytest.raises(KeyError, match="unknown data session"):
            server.append_rows("nope", Y)
    X2 = np.vstack([X, Y])
    Xc2 = X2 - X2.mean(axis=0)
    S2 = Xc2.T @ Xc2 / X2.shape[0]
    direct = glasso(S2, lam, solver="bcd", tol=1e-8)
    np.testing.assert_allclose(res.Theta, direct.Theta, atol=1e-5)
    assert count("serve.session_updates") == 1
    # the tiny perturbation must leave certificates standing somewhere
    assert count("stream.tiles_revalidated") > 0
    # counters surface through serve_stats (streamed + serving in one view)
    from repro.launch.serve_glasso import serve_stats

    st = serve_stats()
    assert "stream.tiles_revalidated" in st and "serve.session_updates" in st


def test_submit_joint_matches_direct(rng):
    """submit_joint resolves to the same result as a direct joint_glasso,
    via the admission fast path for all-closed-form plans and via the
    batcher queue otherwise."""
    from repro.joint import joint_glasso

    p = 16
    Ss = [np.eye(p) * 2.0 for _ in range(3)]
    for k in range(3):
        for i, j, v in [(0, 1, 0.9), (1, 2, -0.8), (2, 3, 0.7)]:
            Ss[k][i, j] = Ss[k][j, i] = v
    reset("joint")
    reset("serve")
    with GlassoServer(solver="bcd", tol=1e-8) as server:
        res = server.submit_joint(Ss, 0.4, 0.1, penalty="fused").result(
            timeout=300
        )
    direct = joint_glasso(Ss, 0.4, 0.1, penalty="fused", tol=1e-8)
    np.testing.assert_allclose(res.Theta, direct.Theta, atol=1e-6)
    assert count("joint.requests") == 1
    assert count("joint.fastpath_requests") == 1  # identical-block forest plan
    # queued path: class-specific blocks force the joint ADMM route
    Ss2 = [np.array(S) for S in Ss]
    blk = rng.standard_normal((24, 5))
    for k in range(3):
        Ss2[k][np.ix_(range(6, 11), range(6, 11))] = (
            blk.T @ blk / 24 + (2 + 0.3 * k) * np.eye(5) + 0.6 * (1 - np.eye(5))
        )
    with GlassoServer(solver="bcd", tol=1e-8) as server:
        res2 = server.submit_joint(Ss2, 0.4, 0.1, penalty="group").result(
            timeout=300
        )
    direct2 = joint_glasso(Ss2, 0.4, 0.1, penalty="group", tol=1e-8)
    np.testing.assert_allclose(res2.Theta, direct2.Theta, atol=1e-6)
    assert res2.route_mix.get("joint_general", 0) >= 1
    # joint.* counters surface through serve_stats
    from repro.launch.serve_glasso import serve_stats

    st = serve_stats()
    assert "joint.requests" in st and "joint.dispatches" in st


def test_stop_fails_inflight_data_and_joint_requests(rng):
    """Shutdown with queued data-session and joint requests: every future
    must fail cleanly through _fail_pending instead of hanging its client
    (previously only plain-submit shutdown was covered)."""
    p = 32
    X = rng.standard_normal((40, p)) * (0.1 + rng.random(p))
    Ss = [np.eye(8) + 0.6 * (1 - np.eye(8)) * (0.9 ** k) for k in range(2)]
    # fast_path off and batcher never started: requests stay in the queue
    server = GlassoServer(solver="bcd", tol=1e-8, fast_path=False)
    f_data = server.submit_data(
        X, 0.05, session="s-stop", stream={"tile": 16, "chunk": 8}
    )
    f_joint = server.submit_joint(Ss, 0.3, 0.05, penalty="group")
    assert not f_data.done() and not f_joint.done()
    server.stop()
    for fut in (f_data, f_joint):
        with pytest.raises(RuntimeError, match="GlassoServer stopped"):
            fut.result(timeout=5)
    # post-stop admissions of every kind fail fast, never park
    with pytest.raises(RuntimeError, match="GlassoServer stopped"):
        server.submit(np.eye(4), 0.5).result(timeout=5)
    with pytest.raises(RuntimeError, match="GlassoServer stopped"):
        server.submit_data(X, 0.05).result(timeout=5)
    with pytest.raises(RuntimeError, match="GlassoServer stopped"):
        server.submit_joint(Ss, 0.3, 0.05).result(timeout=5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

"""Backend-equivalence property tests for the engine screening registry.

All four registered backends must induce the IDENTICAL vertex partition (up
to label canonicalization, which the registry already applies) for any S and
lambda — including ties |S_ij| == lambda, which eq. (4)'s strict inequality
excludes from the edge set.

Entries are quantized to multiples of 1/64 (exactly representable in float32)
so backends that compute the mask in float32 (the Pallas kernel) cannot
disagree with the float64 host path through rounding.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.components import partitions_equal
from repro.engine import available_cc_backends, label_components

BACKENDS = ("host", "jax", "pallas", "shard_map")


def quantized_covariance(rng, p, density):
    """Symmetric matrix with off-diagonal magnitudes on the 1/64 grid."""
    A = (rng.integers(0, 65, size=(p, p)) / 64.0) * (rng.random((p, p)) < density)
    A = np.triu(A, 1) * np.where(rng.random((p, p)) < 0.5, -1.0, 1.0)
    S = A + A.T
    np.fill_diagonal(S, 1.0)
    return S


def test_all_four_backends_registered():
    assert set(BACKENDS) <= set(available_cc_backends())


def test_unknown_backend_is_an_error():
    with pytest.raises(ValueError, match="unknown cc backend"):
        label_components(np.eye(3), 0.1, backend="no-such-backend")


@settings(max_examples=8, deadline=None)
@given(
    p=st.sampled_from([4, 7, 12, 16]),
    density=st.floats(0.05, 0.6),
    seed=st.integers(0, 10_000),
    lam64=st.integers(0, 63),
)
def test_backends_equivalent(p, density, seed, lam64):
    rng = np.random.default_rng(seed)
    S = quantized_covariance(rng, p, density)
    # lam on the same 1/64 grid: with probability ~density several |S_ij|
    # tie with lam exactly — the strict-inequality edge of eq. (4)
    lam = lam64 / 64.0
    ref = label_components(S, lam, backend="host")
    for backend in BACKENDS[1:]:
        labels = label_components(S, lam, backend=backend, block=8)
        assert partitions_equal(labels, ref), (
            f"backend {backend} disagrees with host at lam={lam} (p={p})"
        )


def test_tie_at_lambda_is_not_an_edge_all_backends():
    """|S_01| == lambda exactly: 0-1 must NOT merge; |S_12| > lambda must."""
    S = np.eye(4)
    S[0, 1] = S[1, 0] = 0.5
    S[1, 2] = S[2, 1] = 0.75
    for backend in BACKENDS:
        labels = label_components(S, 0.5, backend=backend, block=8)
        assert labels[0] != labels[1], backend
        assert labels[1] == labels[2], backend
        assert labels[3] not in (labels[0], labels[1]), backend


def test_labels_are_canonical():
    """Registry contract: label == smallest vertex index of the component."""
    rng = np.random.default_rng(3)
    S = quantized_covariance(rng, 13, 0.3)
    for backend in BACKENDS:
        labels = label_components(S, 0.25, backend=backend, block=8)
        for lab in np.unique(labels):
            members = np.nonzero(labels == lab)[0]
            assert lab == members.min(), backend

"""Serving control plane: typed specs, tenant quotas, bounded queues,
deadlines, and the result cache (launch.control_plane + the unified
``GlassoServer.submit(spec, meta=...)`` chokepoint)."""

import threading
import time

import numpy as np
import pytest

from repro.core.instrument import count, reset
from repro.covariance import lambda_interval_for_k, paper_synthetic
from repro.launch.control_plane import (
    AdmissionQueue,
    DataSpec,
    DeadlineExceeded,
    DenseSpec,
    JointSpec,
    Overload,
    Quota,
    RequestMeta,
    ResultCache,
    TokenBucket,
    spec_cache_key,
)
from repro.launch.serve_glasso import GlassoServer


def _dense_case(seed=0):
    S = paper_synthetic(3, 8, seed=seed)
    lam_min, lam_max = lambda_interval_for_k(S, 3)
    return S, float(0.5 * (lam_min + lam_max))


# ---------------------------------------------------------------------------
# primitives in isolation
# ---------------------------------------------------------------------------


def test_token_bucket_burst_and_refill():
    now = [0.0]
    b = TokenBucket(Quota(rate=2.0, burst=3.0), clock=lambda: now[0])
    # burst: 3 immediate admissions, then dry
    assert all(b.try_acquire() for _ in range(3))
    assert not b.try_acquire()
    # refill at `rate` per second, capped at burst
    now[0] = 1.0
    assert b.try_acquire() and b.try_acquire()
    assert not b.try_acquire()
    now[0] = 100.0
    assert b.tokens == pytest.approx(3.0)


def test_admission_queue_bounded_and_priority():
    q = AdmissionQueue(maxsize=3)
    assert q.try_put("b1", slo="batch")
    assert q.try_put("i1", slo="interactive")
    assert q.try_put("b2", slo="batch")
    assert not q.try_put("i2", slo="interactive")  # full, even for priority
    # strict two-class priority: interactive first, FIFO within a class
    assert [q.get(timeout=1) for _ in range(3)] == ["i1", "b1", "b2"]
    import queue as _q

    with pytest.raises(_q.Empty):
        q.get(timeout=0.01)
    # maxsize=0 is unbounded (the legacy default)
    q0 = AdmissionQueue(maxsize=0)
    assert all(q0.try_put(i) for i in range(100))
    assert len(q0) == 100


def test_result_cache_lru_eviction():
    c = ResultCache(maxsize=2)
    c.put(("a",), 1)
    c.put(("b",), 2)
    assert c.get(("a",)) == 1          # touch "a": "b" becomes LRU
    c.put(("c",), 3)
    assert c.get(("b",)) is None       # evicted
    assert c.get(("a",)) == 1 and c.get(("c",)) == 3
    assert c.get(None) is None         # uncacheable key: always a miss
    c.put(None, 9)
    assert len(c) == 2


def test_spec_cache_keys():
    S, lam = _dense_case()
    k1 = spec_cache_key(DenseSpec(S, lam), "dense")
    k2 = spec_cache_key(DenseSpec(S.copy(), lam), "dense")
    assert k1 == k2                    # content-addressed, not identity
    assert k1 != spec_cache_key(DenseSpec(S, lam * 0.9), "dense")
    assert k1 != spec_cache_key(DenseSpec(S, lam), "sparse")
    X = np.ones((6, 4))
    assert spec_cache_key(DataSpec(X, 0.1), "dense") is not None
    # sessions mutate and custom stream configs may re-tile: uncacheable
    assert spec_cache_key(DataSpec(X, 0.1, session="s"), "dense") is None
    assert spec_cache_key(DataSpec(X, 0.1, stream={"tile": 2}), "dense") is None
    kj = spec_cache_key(JointSpec(Ss=[S, S], lam1=lam, lam2=0.1), "dense")
    assert kj is not None
    assert kj != spec_cache_key(
        JointSpec(Ss=[S, S], lam1=lam, lam2=0.1, penalty="fused"), "dense"
    )


def test_meta_and_spec_validation():
    with pytest.raises(ValueError, match="slo"):
        RequestMeta(slo="realtime")
    with pytest.raises(ValueError, match="deadline"):
        RequestMeta(deadline=0.0)
    with pytest.raises(ValueError, match="exactly one"):
        JointSpec(lam1=0.1)
    with pytest.raises(ValueError, match="exactly one"):
        JointSpec(Ss=[np.eye(2)], Xs=[np.ones((3, 2))], lam1=0.1)
    with pytest.raises(ValueError):
        Quota(rate=0.0, burst=1.0)


def test_lpt_priorities_place_urgent_first():
    from repro.core.schedule import lpt_assign

    sizes = [4, 4, 4, 4]
    base = lpt_assign(sizes, 2)
    uniform = lpt_assign(sizes, 2, priorities=[1, 1, 1, 1])
    # uniform priorities preserve plain LPT exactly (stable tie-break)
    np.testing.assert_array_equal(base.worker_of, uniform.worker_of)
    # the single urgent equal-cost item is placed first -> worker 0
    urgent = lpt_assign(sizes, 2, priorities=[0, 0, 1, 0])
    assert urgent.worker_of[2] == 0
    with pytest.raises(ValueError, match="priorities"):
        lpt_assign(sizes, 2, priorities=[1, 2])


# ---------------------------------------------------------------------------
# unified submit: equivalence with the legacy verbs (byte-identical)
# ---------------------------------------------------------------------------


def test_spec_submit_matches_legacy_dense(rng):
    S, lam = _dense_case(seed=3)
    with GlassoServer(solver="bcd", tol=1e-8) as server:
        r_spec = server.submit(DenseSpec(S, lam)).result(timeout=300)
        with pytest.warns(DeprecationWarning, match="submit"):
            r_legacy = server.submit(S, lam).result(timeout=300)
    np.testing.assert_array_equal(r_spec.Theta, r_legacy.Theta)
    np.testing.assert_array_equal(r_spec.labels, r_legacy.labels)
    assert r_spec.solver == r_legacy.solver


def test_spec_submit_matches_legacy_data(rng):
    p = 24
    X = rng.standard_normal((40, p)) * (0.1 + rng.random(p))
    lam = 0.08
    with GlassoServer(solver="bcd", tol=1e-8) as server:
        r_spec = server.submit(
            DataSpec(X, lam, stream={"tile": 8, "chunk": 16})
        ).result(timeout=300)
        with pytest.warns(DeprecationWarning, match="submit_data"):
            r_legacy = server.submit_data(
                X, lam, stream={"tile": 8, "chunk": 16}
            ).result(timeout=300)
    np.testing.assert_array_equal(r_spec.Theta, r_legacy.Theta)
    np.testing.assert_array_equal(r_spec.labels, r_legacy.labels)


def test_spec_submit_matches_legacy_joint():
    Ss = [np.eye(8) + 0.6 * (1 - np.eye(8)) * (0.9 ** k) for k in range(2)]
    with GlassoServer(solver="bcd", tol=1e-8) as server:
        r_spec = server.submit(
            JointSpec(Ss=Ss, lam1=0.4, lam2=0.1, penalty="group")
        ).result(timeout=300)
        with pytest.warns(DeprecationWarning, match="submit_joint"):
            r_legacy = server.submit_joint(Ss, 0.4, 0.1, penalty="group").result(
                timeout=300
            )
    np.testing.assert_array_equal(r_spec.Theta, r_legacy.Theta)
    assert r_spec.penalty == r_legacy.penalty == "group"


def test_spec_plus_positional_lam_rejected():
    S, lam = _dense_case()
    with GlassoServer(solver="bcd", tol=1e-8) as server:
        with pytest.raises(TypeError, match="spec"):
            server.submit(DenseSpec(S, lam), lam)
        with pytest.raises(TypeError, match="output"):
            server.submit(
                DenseSpec(S, lam), output="dense",
                meta=RequestMeta(output="sparse"),
            )


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_queue_full_raises_typed_overload():
    """A full bounded queue rejects SYNCHRONOUSLY with Overload — the
    client never receives a future that would hang out its timeout."""
    S, lam = _dense_case()
    reset("serve")
    # batcher never started and fast path off: everything parks in the queue
    server = GlassoServer(solver="bcd", tol=1e-8, fast_path=False, max_queue=2)
    f1 = server.submit(DenseSpec(S, lam))
    f2 = server.submit(DenseSpec(S, lam))
    with pytest.raises(Overload) as exc:
        server.submit(DenseSpec(S, lam))
    assert exc.value.reason == "queue"
    assert count("serve.rejected.queue") == 1
    assert not f1.done() and not f2.done()
    server.stop()  # drains both with the standard shutdown error
    for f in (f1, f2):
        with pytest.raises(RuntimeError, match="GlassoServer stopped"):
            f.result(timeout=5)


def test_tenant_quota_isolates_noisy_tenant():
    """The noisy tenant exhausts ITS bucket; the quiet tenant (unmetered
    default) is untouched — per-tenant isolation, not global throttling."""
    S, lam = _dense_case()
    reset("serve")
    quotas = {"noisy": Quota(rate=1e-6, burst=2.0)}
    with GlassoServer(solver="bcd", tol=1e-8, quotas=quotas) as server:
        noisy_ok = [
            server.submit(DenseSpec(S, lam), meta=RequestMeta(tenant="noisy"))
            for _ in range(2)
        ]
        with pytest.raises(Overload) as exc:
            server.submit(DenseSpec(S, lam), meta=RequestMeta(tenant="noisy"))
        assert exc.value.reason == "quota" and exc.value.tenant == "noisy"
        # the quiet tenant admits freely AFTER the noisy rejection
        quiet = [
            server.submit(DenseSpec(S, lam), meta=RequestMeta(tenant="quiet"))
            for _ in range(4)
        ]
        for f in noisy_ok + quiet:
            assert f.result(timeout=300).Theta is not None
    assert count("serve.rejected.quota") == 1
    assert count("serve.requests") == 6


def test_expired_deadline_never_reaches_solve_batch():
    S, lam = _dense_case()
    reset("serve")
    server = GlassoServer(solver="bcd", tol=1e-8, fast_path=False)
    seen = []
    orig = server.solve_batch
    server.solve_batch = lambda reqs: (seen.extend(reqs), orig(reqs))[1]
    # queued while the batcher is down; expires before it ever starts
    fut = server.submit(
        DenseSpec(S, lam), meta=RequestMeta(slo="batch", deadline=0.02)
    )
    time.sleep(0.1)
    server.start()
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=30)
    server.stop()
    assert seen == []  # dropped at the drain, pre-dispatch
    assert count("serve.rejected.deadline") == 1


def test_result_cache_hit_skips_planner():
    S, lam = _dense_case(seed=7)
    reset("serve")
    with GlassoServer(solver="bcd", tol=1e-8, result_cache=8) as server:
        r1 = server.submit(DenseSpec(S, lam)).result(timeout=300)
        r2 = server.submit(DenseSpec(S.copy(), lam)).result(timeout=300)
    assert r2 is r1                       # the FINISHED result, verbatim
    assert count("serve.cache.hits") == 1
    assert count("serve.cache.misses") == 1
    assert count("serve.requests") == 2   # hits still count as admissions


def test_interactive_slo_keeps_fast_path_batch_slo_queues():
    """Same all-closed-form request: interactive solves at admission,
    batch-SLO always takes the queue (and the batcher)."""
    S, lam = _dense_case()
    lam_hi = float(np.abs(S - np.diag(np.diag(S))).max() * 1.01)  # singletons
    reset("serve")
    with GlassoServer(solver="bcd", tol=1e-8, max_delay=0.01) as server:
        fi = server.submit(DenseSpec(S, lam_hi))  # default slo=interactive
        assert fi.done()                          # solved synchronously
        fb = server.submit(
            DenseSpec(S, lam_hi), meta=RequestMeta(slo="batch")
        )
        rb = fb.result(timeout=300)
    assert count("serve.fastpath_requests") == 1
    np.testing.assert_array_equal(fi.result().Theta, rb.Theta)


def test_concurrent_stop_submit_never_hangs():
    """Hammer the shutdown race: submissions racing stop() either solve or
    fail fast with the standard shutdown error — no future is ever left
    parked in a drained queue."""
    S, lam = _dense_case()
    futures, errors = [], []
    lock = threading.Lock()
    server = GlassoServer(
        solver="bcd", tol=1e-8, route=False, fast_path=False, max_delay=0.001
    ).start()

    def client(seed):
        for _ in range(25):
            try:
                f = server.submit(DenseSpec(S, lam))
                with lock:
                    futures.append(f)
            except Exception as e:  # pragma: no cover - no Overload expected
                with lock:
                    errors.append(e)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    server.stop()
    for t in threads:
        t.join(timeout=30)
    assert not errors
    # every future RESOLVES within the timeout: solved, or failed cleanly
    outcomes = {"ok": 0, "stopped": 0}
    for f in futures:
        try:
            f.result(timeout=30)
            outcomes["ok"] += 1
        except RuntimeError as e:
            assert "GlassoServer stopped" in str(e)
            outcomes["stopped"] += 1
    assert sum(outcomes.values()) == len(futures) == 100


if __name__ == "__main__":
    pytest.main([__file__, "-q"])

"""Process-wide counters for planner/executor observability.

The engine's acceptance invariants are stated as counter facts — "one
union-find pass per ``glasso_path`` call", "this serving batch hit the
compiled-solver cache N times" — so the counters live in one tiny module that
every layer (core, engine, launch) can bump without import cycles.  Thread
safe: the serving endpoint bumps from worker threads.

Since the observability PR this module is a thin back-compat shim over
``repro.obs.metrics.REGISTRY``: the flat dotted counter namespace is one
store inside the labeled registry, so ``render_prometheus()`` exposes
every counter here alongside the labeled serving histograms.  The shim
preserves the original contract bitwise — every name, the
``counts``/``tail_counts`` views, watermark semantics, and the int-typed
read surface.  Internally values may accumulate as floats (the
``engine.dispatch.us`` fix: ``int(dt * 1e6)`` per call dropped sub-µs
enqueues to 0, undercounting fused-wave dispatch overhead); reads round
once at the surface instead of truncating per event.
"""

from __future__ import annotations

import time

from repro.obs import trace as _trace
from repro.obs.metrics import REGISTRY as _REGISTRY

# Monotonic clock hook — tests monkeypatch this to a fake clock to pin
# the float-accumulation contract of timed_dispatch.
_clock = time.perf_counter


def _as_int(v: float) -> int:
    return v if isinstance(v, int) else int(round(v))


def bump(name: str, n: int = 1) -> None:
    _REGISTRY.bump_flat(name, n)


def timed_dispatch(call, *args, **kwargs):
    """Run one bucket-dispatch chokepoint; returns ``(result, seconds)``.

    Bumps ``engine.dispatch.count`` and ``engine.dispatch.us`` — the
    process-wide ledger of how many solver launches the engines issued and
    how much HOST time they spent issuing them.  For async device routes
    (closed-form, iterative, fused, repairs) that is pure enqueue overhead
    — the cost the wave packer exists to collapse; for host-executed routes
    (chordal, sharded) the dispatch IS the solve, so their entries measure
    the blocking host call.  Wrapped at every chokepoint: the single-class
    executor, the joint engine, the sharded per-block loop, the chordal
    host solve, and the serving batcher.

    The µs ledger accumulates in FLOAT and rounds only at the read
    surface (``count``/``counts``), so sub-microsecond enqueues aggregate
    instead of truncating to zero.  When a request trace is active each
    dispatch also records an ``engine.dispatch`` span, which is how every
    chokepoint shows up in Chrome-trace exports for free."""
    with _trace.span(
        "engine.dispatch", call=getattr(call, "__name__", str(call))
    ):
        t0 = _clock()
        out = call(*args, **kwargs)
        dt = _clock() - t0
    _REGISTRY.bump_flat("engine.dispatch.count", 1)
    _REGISTRY.bump_flat("engine.dispatch.us", dt * 1e6)
    return out, dt


def set_peak(name: str, value: int) -> None:
    """Raise a high-watermark counter to ``value`` if it is larger.

    Watermarks (e.g. ``stream.bytes_peak``) share the counter namespace so
    they appear in ``counts()``/``serve_stats()`` like any other counter, but
    they record a maximum, not a sum."""
    _REGISTRY.set_peak_flat(name, int(value))


def count(name: str) -> int:
    return _as_int(_REGISTRY.flat_value(name))


def counts(prefix: str = "") -> dict[str, int]:
    return {k: _as_int(v) for k, v in _REGISTRY.flat_items(prefix).items()}


def tail_counts(prefix: str) -> dict[str, int]:
    """Counters under ``prefix``, keyed by the remainder of the name —
    e.g. ``tail_counts("router.route.")`` -> {"singleton": 812, "tree": 37}.
    The router/benchmark convenience view of the per-route counters."""
    return {
        k[len(prefix):]: _as_int(v)
        for k, v in _REGISTRY.flat_items(prefix).items()
    }


def route_mix_counts() -> dict[str, int]:
    """Blocks routed per structure class since the last reset — the
    acceptance view: every ladder rung exercised shows up here."""
    return tail_counts("router.route.")


def reset(prefix: str = "") -> None:
    """Reset all counters with the given prefix ('' resets everything).
    Labeled registry families under the same dotted prefix (e.g. the
    ``serve.request_seconds`` histogram) reset with it, so benchmark
    warmup resets clear both surfaces at once."""
    _REGISTRY.reset(prefix)

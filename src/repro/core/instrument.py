"""Process-wide counters for planner/executor observability.

The engine's acceptance invariants are stated as counter facts — "one
union-find pass per ``glasso_path`` call", "this serving batch hit the
compiled-solver cache N times" — so the counters live in one tiny module that
every layer (core, engine, launch) can bump without import cycles.  Thread
safe: the serving endpoint bumps from worker threads.
"""

from __future__ import annotations

import threading
import time
from collections import Counter

_LOCK = threading.Lock()
_COUNTS: Counter[str] = Counter()


def bump(name: str, n: int = 1) -> None:
    with _LOCK:
        _COUNTS[name] += n


def timed_dispatch(call, *args, **kwargs):
    """Run one bucket-dispatch chokepoint; returns ``(result, seconds)``.

    Bumps ``engine.dispatch.count`` and ``engine.dispatch.us`` — the
    process-wide ledger of how many solver launches the engines issued and
    how much HOST time they spent issuing them.  For async device routes
    (closed-form, iterative, fused, repairs) that is pure enqueue overhead
    — the cost the wave packer exists to collapse; for host-executed routes
    (chordal, sharded) the dispatch IS the solve, so their entries measure
    the blocking host call.  Wrapped at every chokepoint: the single-class
    executor, the joint engine, the sharded per-block loop, the chordal
    host solve, and the serving batcher."""
    t0 = time.perf_counter()
    out = call(*args, **kwargs)
    dt = time.perf_counter() - t0
    with _LOCK:
        _COUNTS["engine.dispatch.count"] += 1
        _COUNTS["engine.dispatch.us"] += int(dt * 1e6)
    return out, dt


def set_peak(name: str, value: int) -> None:
    """Raise a high-watermark counter to ``value`` if it is larger.

    Watermarks (e.g. ``stream.bytes_peak``) share the counter namespace so
    they appear in ``counts()``/``serve_stats()`` like any other counter, but
    they record a maximum, not a sum."""
    with _LOCK:
        if value > _COUNTS[name]:
            _COUNTS[name] = int(value)


def count(name: str) -> int:
    with _LOCK:
        return _COUNTS[name]


def counts(prefix: str = "") -> dict[str, int]:
    with _LOCK:
        return {k: v for k, v in _COUNTS.items() if k.startswith(prefix)}


def tail_counts(prefix: str) -> dict[str, int]:
    """Counters under ``prefix``, keyed by the remainder of the name —
    e.g. ``tail_counts("router.route.")`` -> {"singleton": 812, "tree": 37}.
    The router/benchmark convenience view of the per-route counters."""
    with _LOCK:
        return {
            k[len(prefix):]: v for k, v in _COUNTS.items() if k.startswith(prefix)
        }


def route_mix_counts() -> dict[str, int]:
    """Blocks routed per structure class since the last reset — the
    acceptance view: every ladder rung exercised shows up here."""
    return tail_counts("router.route.")


def reset(prefix: str = "") -> None:
    """Reset all counters with the given prefix ('' resets everything)."""
    with _LOCK:
        for k in [k for k in _COUNTS if k.startswith(prefix)]:
            del _COUNTS[k]

"""Paper core: exact covariance thresholding into connected components
(Mazumder & Hastie 2011) wrapped around batched JAX graphical-lasso solvers.
"""

from repro.core.components import (
    canonicalize_labels,
    components_from_covariance_host,
    connected_components_host,
    connected_components_labelprop,
    is_refinement,
    partitions_equal,
    threshold_adjacency,
)
from repro.core.glasso import EngineOptions, GlassoResult, glasso, glasso_path
from repro.core.partition import (
    component_size_distribution,
    labels_at_thresholds,
    lambda_for_max_component,
    merge_profile,
)
from repro.core.screening import thresholded_components
from repro.core.solvers import SOLVERS, glasso_admm, glasso_bcd, glasso_pg, kkt_residual
from repro.core.sparse import (
    AUTO_SPARSE_P,
    JointSparseTheta,
    SparseTheta,
    result_nbytes,
)

__all__ = [
    "glasso",
    "glasso_path",
    "GlassoResult",
    "EngineOptions",
    "thresholded_components",
    "threshold_adjacency",
    "connected_components_host",
    "connected_components_labelprop",
    "components_from_covariance_host",
    "canonicalize_labels",
    "partitions_equal",
    "is_refinement",
    "merge_profile",
    "labels_at_thresholds",
    "lambda_for_max_component",
    "component_size_distribution",
    "SOLVERS",
    "glasso_bcd",
    "glasso_pg",
    "glasso_admm",
    "kkt_residual",
    "AUTO_SPARSE_P",
    "SparseTheta",
    "JointSparseTheta",
    "result_nbytes",
]

"""Scheduling components across workers (devices / hosts / pods).

The paper's footnote 4: "Distributing these operations depend upon the number
of processors available, their capacities ... it is often desirable to club
smaller components into a single machine."  We make that concrete:

  * cost model: solving a size-b block costs ~ b^3 (Section 3: O(p^J), J=3),
  * LPT (longest-processing-time) greedy bin packing — 4/3-approximate
    makespan, ideal for the heavy-tailed component-size distributions Figure 1
    shows,
  * capacity check against a per-worker p_max (consequence 5 of Theorem 1):
    if any component exceeds p_max the scheduler reports the smallest feasible
    lambda instead of an assignment,
  * elastic rebalance = re-run on the surviving worker set; assignments are
    pure functions of (sizes, n_workers) so recovery is deterministic.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass

import numpy as np


def default_cost(b: int) -> float:
    return float(b) ** 3


@dataclass
class Assignment:
    worker_of: np.ndarray          # component index -> worker id
    loads: np.ndarray              # per-worker total cost
    makespan: float
    balance: float                 # makespan / mean load (1.0 = perfect)


def lpt_assign(
    sizes, n_workers: int, *, cost=default_cost, priorities=None
) -> Assignment:
    """LPT greedy assignment; ``priorities`` (higher = more urgent, same
    length as ``sizes``) makes the placement priority-aware: urgent items
    are placed FIRST — they land on the least-loaded workers and sit at the
    front of each worker's dispatch order — with LPT's cost-descending
    order intact within a priority level, so the makespan bound is
    unchanged for uniform priorities."""
    sizes = np.asarray(sizes)
    if priorities is None:
        order = np.argsort(-sizes, kind="stable")
    else:
        priorities = np.asarray(priorities, dtype=float)
        if priorities.shape != sizes.shape:
            raise ValueError(
                f"priorities shape {priorities.shape} != sizes {sizes.shape}"
            )
        # lexsort: last key is primary — priority desc, then cost desc
        order = np.lexsort((-sizes.astype(float), -priorities))
    loads = [(0.0, w) for w in range(n_workers)]
    heapq.heapify(loads)
    worker_of = np.zeros(sizes.size, dtype=np.int64)
    for idx in order:
        load, w = heapq.heappop(loads)
        worker_of[idx] = w
        heapq.heappush(loads, (load + cost(int(sizes[idx])), w))
    per = np.zeros(n_workers)
    for idx, w in enumerate(worker_of):
        per[w] += cost(int(sizes[idx]))
    makespan = float(per.max()) if n_workers else 0.0
    mean = float(per.mean()) if n_workers else 0.0
    return Assignment(
        worker_of=worker_of,
        loads=per,
        makespan=makespan,
        balance=makespan / mean if mean > 0 else 1.0,
    )


def feasible_lambda(S: np.ndarray, p_max: int) -> float:
    """Smallest lambda at which every component fits a p_max-capacity worker
    (consequence 5). Thin wrapper so schedulers can self-serve."""
    from repro.core.partition import lambda_for_max_component

    return lambda_for_max_component(S, p_max)


def check_capacity(sizes, p_max: int | None) -> None:
    if p_max is None:
        return
    sizes = np.asarray(sizes)
    if sizes.size and sizes.max() > p_max:
        raise ValueError(
            f"component of size {int(sizes.max())} exceeds worker capacity "
            f"p_max={p_max}; increase lambda (see schedule.feasible_lambda)"
        )

"""Sparse-native result representation: Theta without the (p, p) wall.

Theorem 1 makes the glasso solution block-diagonal over the screened
components, so everything the solve produces is already sparse: per-bucket
padded solution stacks plus a closed-form diagonal for isolated vertices.
``SparseTheta`` keeps exactly that — ZERO-COPY views into the executor's
padded stacks, a (p,) component index map, and the isolated values — and
serves global views (COO/CSR/dense) only on demand.  Peak result memory is
O(nnz + sum b_i^2) instead of O(p^2), which is what lets the from-data path
(PR 3) solve at p >= 1e5 end-to-end.

Layout (DESIGN.md Section 13):

    _stacks            list of (n_i, size_i, size_i) padded solution stacks,
                       one per plan bucket — the executor's own output
                       arrays, not copies
    _comps / _loc      flat component list + (stack, row) locator per comp
    _comp_id           (p,) vertex -> flat component index, -1 if isolated
    _pos_in            (p,) vertex -> row within its block (or its position
                       in ``isolated`` when isolated)
    isolated(_values)  vertex ids with |comp| = 1 and their closed-form
                       Theta_ii = 1/(S_ii + lam)

``gather_block`` intentionally differs from the covariance materializer's:
a result IS defined across components (exact zeros there, by Theorem 1), so
cross-component gathers return the block-diagonal restriction instead of
raising — which is precisely what the path warm start needs when components
merge (the old Theta restricted to a merged component is block-diagonal
over its old sub-components).
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "AUTO_SPARSE_P",
    "DENSIFY_MAX_P",
    "SparseTheta",
    "JointSparseTheta",
    "resolve_output",
    "result_nbytes",
]

#: ``output="auto"`` returns a SparseTheta above this p (and a dense array
#: at or below it).  8192^2 float64 = 512 MB — the last size where a dense
#: result is still a reasonable default.
AUTO_SPARSE_P = 8192

#: ``toarray()`` refuses above this p unless forced — densifying a result
#: the pipeline went out of its way never to allocate should be loud.
DENSIFY_MAX_P = 8192


def resolve_output(output, p: int) -> str:
    """Normalize an ``output=`` argument to "dense" or "sparse".

    None and "auto" pick by problem size (> ``AUTO_SPARSE_P`` -> sparse)."""
    if output is None:
        output = "auto"
    if output == "auto":
        return "sparse" if int(p) > AUTO_SPARSE_P else "dense"
    if output not in ("dense", "sparse"):
        raise ValueError(f"output must be 'dense', 'sparse' or 'auto', got {output!r}")
    return output


def result_nbytes(Theta) -> int:
    """Resident bytes of a result Theta — ndarray ``.nbytes`` attribute or a
    sparse result's ``.nbytes()`` method, whichever the object carries."""
    nb = Theta.nbytes
    return int(nb() if callable(nb) else nb)


def _build_index(p: int, comps: list[np.ndarray], isolated: np.ndarray):
    """(p,) vertex -> flat component id (-1 if isolated) and row-within-block
    (position within ``isolated`` for isolated vertices)."""
    comp_id = np.full(p, -1, dtype=np.int64)
    pos_in = np.zeros(p, dtype=np.int64)
    for j, c in enumerate(comps):
        comp_id[c] = j
        pos_in[c] = np.arange(c.size)
    if isolated.size:
        pos_in[isolated] = np.arange(isolated.size)
    return comp_id, pos_in


class SparseTheta:
    """Block-sparse precision matrix: padded stacks + component index map.

    Construct via ``core.blocks.assemble_sparse`` (single-class) — not by
    hand.  Behaves like a matrix where it matters (``shape``, ``diagonal``,
    ``gather_block``/``diag_at``) and converts on demand (``to_coo``,
    ``to_csr``, ``toarray``); ``np.asarray`` on an oversize result raises
    rather than reintroducing the O(p^2) allocation."""

    def __init__(
        self, p: int, dtype, stacks: list[np.ndarray], comps: list[np.ndarray],
        loc: list[tuple[int, int]], comp_id: np.ndarray, pos_in: np.ndarray,
        isolated: np.ndarray, isolated_values: np.ndarray,
        *, densify_max: int = DENSIFY_MAX_P,
    ):
        self.p = int(p)
        self.dtype = np.dtype(dtype)
        self._stacks = stacks
        self._comps = comps
        self._loc = loc
        self._comp_id = comp_id
        self._pos_in = pos_in
        self.isolated = isolated
        self.isolated_values = isolated_values
        self.densify_max = int(densify_max)

    # -- matrix-like surface ----------------------------------------------

    @property
    def shape(self) -> tuple[int, int]:
        return (self.p, self.p)

    @property
    def n_components(self) -> int:
        return len(self._comps) + int(self.isolated.size)

    def component_block(self, j: int) -> np.ndarray:
        """The (b, b) solution block of flat component ``j`` — a VIEW into
        the padded stack, no copy."""
        s, r = self._loc[j]
        b = self._comps[j].size
        return self._stacks[s][r, :b, :b]

    def blocks(self):
        """Yield (vertex array, (b, b) block view) per non-singleton
        component."""
        for j, c in enumerate(self._comps):
            yield c, self.component_block(j)

    def diagonal(self) -> np.ndarray:
        d = np.zeros(self.p, dtype=self.dtype)
        if self.isolated.size:
            d[self.isolated] = self.isolated_values
        for c, blk in self.blocks():
            d[c] = np.diagonal(blk)
        return d

    @property
    def nnz(self) -> int:
        """Stored nonzeros (isolated diagonal + block entries != 0) —
        matches ``np.count_nonzero`` of the densified matrix exactly."""
        n = int(np.count_nonzero(self.isolated_values))
        for _, blk in self.blocks():
            n += int(np.count_nonzero(blk))
        return n

    def logdet(self) -> float:
        """log det(Theta), summed per component (Theorem 1: the matrix is
        block-diagonal over them, so the determinant factors) — per-block
        ``slogdet`` plus the isolated log(theta_ii) terms, never a global
        dense factorization.  -inf when any block is not PD (a result from
        the solvers never is).  The selection criteria (``repro.select``)
        score every path result through exactly this decomposition."""
        total = 0.0
        if self.isolated.size:
            vals = np.asarray(self.isolated_values, dtype=np.float64)
            if np.any(vals <= 0):
                return float("-inf")
            total += float(np.sum(np.log(vals)))
        for _, blk in self.blocks():
            sign, val = np.linalg.slogdet(np.asarray(blk))
            if sign <= 0:
                return float("-inf")
            total += float(val)
        return total

    def nbytes(self) -> int:
        """Resident bytes: padded stacks + index maps + isolated values.
        The stacks are shared with the executor's output, so this is the
        result's whole footprint, not an increment over it."""
        return int(
            sum(s.nbytes for s in self._stacks)
            + self._comp_id.nbytes + self._pos_in.nbytes
            + self.isolated.nbytes + self.isolated_values.nbytes
        )

    # -- gather protocol (result side) -------------------------------------

    def gather_block(self, idx: np.ndarray) -> np.ndarray:
        """Theta[np.ix_(idx, idx)] as a dense (|idx|, |idx|) array.

        Unlike the covariance materializer, CROSS-component index sets are
        fine: entries between distinct components are exact zeros (Theorem
        1), so the gather returns the block-diagonal restriction — the warm
        start's merged-component W is built through exactly this."""
        idx = np.asarray(idx)
        out = np.zeros((idx.size, idx.size), dtype=self.dtype)
        cid = self._comp_id[idx]
        iso = np.where(cid < 0)[0]
        if iso.size:
            out[iso, iso] = self.isolated_values[self._pos_in[idx[iso]]]
        for j in np.unique(cid[cid >= 0]):
            sel = np.where(cid == j)[0]
            pos = self._pos_in[idx[sel]]
            out[np.ix_(sel, sel)] = self.component_block(int(j))[np.ix_(pos, pos)]
        return out

    def diag_at(self, idx) -> np.ndarray:
        return self.diagonal()[idx]

    # -- global views -------------------------------------------------------

    def to_coo(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(rows, cols, values) of every stored nonzero — identical entry
        set to ``np.nonzero`` of the densified matrix."""
        rows, cols, vals = [], [], []
        nz = np.nonzero(self.isolated_values)[0]
        if nz.size:
            rows.append(self.isolated[nz])
            cols.append(self.isolated[nz])
            vals.append(self.isolated_values[nz])
        for c, blk in self.blocks():
            ri, ci = np.nonzero(blk)
            rows.append(c[ri])
            cols.append(c[ci])
            vals.append(blk[ri, ci])
        if not rows:
            z = np.zeros(0, dtype=np.int64)
            return z, z, np.zeros(0, dtype=self.dtype)
        return np.concatenate(rows), np.concatenate(cols), np.concatenate(vals)

    def to_csr(self):
        """scipy.sparse CSR view of the full matrix (built on demand)."""
        from scipy import sparse as sp

        r, c, v = self.to_coo()
        return sp.coo_matrix((v, (r, c)), shape=self.shape, dtype=self.dtype).tocsr()

    def toarray(self, *, force: bool = False) -> np.ndarray:
        """Densify.  Refuses above ``densify_max`` unless ``force=True`` —
        the caller is about to allocate the very buffer the sparse path
        exists to avoid, and should have to say so."""
        if self.p > self.densify_max and not force:
            raise ValueError(
                f"refusing to densify a ({self.p}, {self.p}) sparse result "
                f"(> densify_max={self.densify_max}); use toarray(force=True), "
                "to_csr(), or blocks()"
            )
        out = np.zeros((self.p, self.p), dtype=self.dtype)
        if self.isolated.size:
            out[self.isolated, self.isolated] = self.isolated_values
        for c, blk in self.blocks():
            out[np.ix_(c, c)] = blk
        return out

    def __array__(self, dtype=None, copy=None):
        arr = self.toarray()
        return arr if dtype is None else arr.astype(dtype, copy=False)

    # -- support ------------------------------------------------------------

    def support_edges(self) -> np.ndarray:
        """(E, 2) array of off-diagonal upper-triangular support edges —
        the edge-list form serving payloads carry at any p."""
        edges = []
        for c, blk in self.blocks():
            ri, ci = np.nonzero(blk)
            keep = ri < ci
            if keep.any():
                edges.append(np.stack([c[ri[keep]], c[ci[keep]]], axis=1))
        if not edges:
            return np.zeros((0, 2), dtype=np.int64)
        e = np.concatenate(edges).astype(np.int64)
        return e[np.lexsort((e[:, 1], e[:, 0]))]

    def support(self):
        """Adjacency of the estimated concentration graph: dense bool up to
        ``densify_max``, scipy bool CSR above it."""
        if self.p <= self.densify_max:
            A = np.zeros((self.p, self.p), dtype=bool)
            e = self.support_edges()
            A[e[:, 0], e[:, 1]] = True
            A[e[:, 1], e[:, 0]] = True
            return A
        from scipy import sparse as sp

        e = self.support_edges()
        data = np.ones(2 * len(e), dtype=bool)
        r = np.concatenate([e[:, 0], e[:, 1]])
        c = np.concatenate([e[:, 1], e[:, 0]])
        return sp.coo_matrix((data, (r, c)), shape=self.shape, dtype=bool).tocsr()

    def __repr__(self) -> str:
        return (
            f"SparseTheta(p={self.p}, components={self.n_components}, "
            f"nnz={self.nnz}, dtype={self.dtype.name})"
        )


class JointSparseTheta:
    """K-class block-sparse result: (n_i, K, size_i, size_i) stacks sharing
    one component index across classes (the union-graph partition).

    ``shape`` is (K, p, p) and ``result[k]`` is a zero-copy single-class
    ``SparseTheta`` over per-class stack views, so everything downstream of
    a single-class result (KKT, support, COO dumps) reuses unchanged."""

    def __init__(
        self, K: int, p: int, dtype, stacks: list[np.ndarray],
        comps: list[np.ndarray], loc: list[tuple[int, int]],
        comp_id: np.ndarray, pos_in: np.ndarray,
        isolated: np.ndarray, isolated_values: np.ndarray,
        *, densify_max: int = DENSIFY_MAX_P,
    ):
        self.K = int(K)
        self.p = int(p)
        self.dtype = np.dtype(dtype)
        self._stacks = stacks              # per bucket: (n, K, size, size)
        self._comps = comps
        self._loc = loc
        self._comp_id = comp_id
        self._pos_in = pos_in
        self.isolated = isolated
        self.isolated_values = isolated_values   # (K, n_isolated)
        self.densify_max = int(densify_max)
        self._views: dict[int, SparseTheta] = {}

    @property
    def shape(self) -> tuple[int, int, int]:
        return (self.K, self.p, self.p)

    @property
    def n_components(self) -> int:
        return len(self._comps) + int(self.isolated.size)

    def class_view(self, k: int) -> SparseTheta:
        k = int(k)
        if not 0 <= k < self.K:
            raise IndexError(f"class index {k} out of range for K={self.K}")
        if k not in self._views:
            self._views[k] = SparseTheta(
                self.p, self.dtype, [s[:, k] for s in self._stacks],
                self._comps, self._loc, self._comp_id, self._pos_in,
                self.isolated, self.isolated_values[k],
                densify_max=self.densify_max,
            )
        return self._views[k]

    def __getitem__(self, k: int) -> SparseTheta:
        return self.class_view(k)

    def blocks(self):
        """Yield (vertex array, (K, b, b) block view) per union component."""
        for j, c in enumerate(self._comps):
            s, r = self._loc[j]
            yield c, self._stacks[s][r, :, : c.size, : c.size]

    @property
    def nnz(self) -> int:
        return sum(self.class_view(k).nnz for k in range(self.K))

    def nbytes(self) -> int:
        return int(
            sum(s.nbytes for s in self._stacks)
            + self._comp_id.nbytes + self._pos_in.nbytes
            + self.isolated.nbytes + self.isolated_values.nbytes
        )

    def toarray(self, *, force: bool = False) -> np.ndarray:
        if self.p > self.densify_max and not force:
            raise ValueError(
                f"refusing to densify a ({self.K}, {self.p}, {self.p}) sparse "
                f"result (> densify_max={self.densify_max}); use "
                "toarray(force=True) or class_view(k)"
            )
        return np.stack(
            [self.class_view(k).toarray(force=force) for k in range(self.K)]
        )

    def __array__(self, dtype=None, copy=None):
        arr = self.toarray()
        return arr if dtype is None else arr.astype(dtype, copy=False)

    def support_edges(self) -> np.ndarray:
        """Union support edges: an (i, j) pair present in ANY class."""
        es = [self.class_view(k).support_edges() for k in range(self.K)]
        e = np.unique(np.concatenate(es), axis=0)
        return e[np.lexsort((e[:, 1], e[:, 0]))] if len(e) else e

    def support(self):
        """Union concentration-graph adjacency across classes (dense bool up
        to ``densify_max``, scipy bool CSR above)."""
        if self.p <= self.densify_max:
            A = np.zeros((self.p, self.p), dtype=bool)
            e = self.support_edges()
            if len(e):
                A[e[:, 0], e[:, 1]] = True
                A[e[:, 1], e[:, 0]] = True
            return A
        from scipy import sparse as sp

        e = self.support_edges()
        data = np.ones(2 * len(e), dtype=bool)
        r = np.concatenate([e[:, 0], e[:, 1]])
        c = np.concatenate([e[:, 1], e[:, 0]])
        return sp.coo_matrix((data, (r, c)), shape=(self.p, self.p), dtype=bool).tocsr()

    def __repr__(self) -> str:
        return (
            f"JointSparseTheta(K={self.K}, p={self.p}, "
            f"components={self.n_components}, dtype={self.dtype.name})"
        )

"""Connected components of the thresholded sample covariance graph.

Three implementations with one contract (labels[i] = component id, canonical =
smallest vertex index in the component):

``connected_components_host``       numpy union-find with path compression —
                                    the orchestration-time path (plays the role
                                    of MATLAB ``graphconncomp`` in the paper).
``connected_components_labelprop``  pure-JAX min-label propagation + pointer
                                    jumping, O(log p) rounds of masked min
                                    reduces — the TPU-native adaptation of
                                    Tarjan/Gazit (DESIGN.md Section 3).  Works
                                    directly from S and lambda so the p x p
                                    adjacency never needs to be materialized by
                                    the caller.
``connected_components_distributed``  shard_map row-sharded variant of the
                                    label-prop iteration for pod-scale p
                                    (see repro/core/distributed.py).

Plus partition utilities used by the Theorem-1/2 tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Host union-find
# ---------------------------------------------------------------------------


def _find(parent: np.ndarray, i: int) -> int:
    root = i
    while parent[root] != root:
        root = parent[root]
    while parent[i] != root:  # path compression
        parent[i], i = root, parent[i]
    return root


def connected_components_host(adj: np.ndarray) -> np.ndarray:
    """Union-find over a boolean adjacency matrix. Returns canonical labels."""
    from repro.core.instrument import bump

    bump("partition.unionfind_passes")
    adj = np.asarray(adj)
    p = adj.shape[0]
    parent = np.arange(p)
    ii, jj = np.nonzero(np.triu(adj, 1))
    for a, b in zip(ii.tolist(), jj.tolist()):
        ra, rb = _find(parent, a), _find(parent, b)
        if ra != rb:
            # union by smaller root index keeps labels canonical-ish; final
            # pass below canonicalizes regardless.
            if ra < rb:
                parent[rb] = ra
            else:
                parent[ra] = rb
    return np.array([_find(parent, i) for i in range(p)])


def threshold_adjacency(S: np.ndarray, lam: float) -> np.ndarray:
    """E_ij = 1[|S_ij| > lambda, i != j]  (paper eq. (4), strict inequality)."""
    A = np.abs(np.asarray(S)) > lam
    np.fill_diagonal(A, False)
    return A


def components_from_covariance_host(S: np.ndarray, lam: float) -> np.ndarray:
    return connected_components_host(threshold_adjacency(S, lam))


# ---------------------------------------------------------------------------
# JAX label propagation
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_rounds",))
def connected_components_labelprop(
    S: jax.Array, lam: jax.Array, *, max_rounds: int | None = None
) -> jax.Array:
    """Min-label propagation with pointer jumping, fused with thresholding.

    Each round:
      1. hook:  l_i <- min(l_i, min_{j : |S_ij|>lam} l_j)   (masked min-reduce)
      2. jump:  l <- l[l]                                    (pointer doubling)
    Labels are always vertex indices of a member of one's own component, so the
    jump step is well-defined.  Converges in O(log p) rounds; the while_loop
    exits at the first fixed point.  The hook step is the op the
    ``threshold_cc`` Pallas kernel tiles on TPU.
    """
    p = S.shape[0]
    mask = (jnp.abs(S) > lam) & ~jnp.eye(p, dtype=bool)
    init = jnp.arange(p, dtype=jnp.int32)
    big = jnp.int32(p)

    def round_(labels):
        neigh = jnp.where(mask, labels[None, :], big)
        labels = jnp.minimum(labels, jnp.min(neigh, axis=1))
        labels = labels[labels]
        labels = labels[labels]
        return labels

    def cond(carry):
        labels, prev, it = carry
        limit = max_rounds if max_rounds is not None else p + 2
        return jnp.logical_and(jnp.any(labels != prev), it < limit)

    def body(carry):
        labels, _, it = carry
        return round_(labels), labels, it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (round_(init), init, jnp.int32(0)))
    return labels


# ---------------------------------------------------------------------------
# Partition utilities
# ---------------------------------------------------------------------------


def canonicalize_labels(labels: np.ndarray) -> np.ndarray:
    """Relabel so each component's id is its smallest vertex index.

    Vectorized (one ``np.unique`` + a grouped min) — the engine path planner
    canonicalizes a snapshot per lambda, so this is on the planning hot path.
    """
    labels = np.asarray(labels)
    p = labels.shape[0]
    if p == 0:
        return labels.copy()
    _, inverse = np.unique(labels, return_inverse=True)
    mins = np.full(inverse.max() + 1, p, dtype=np.int64)
    np.minimum.at(mins, inverse, np.arange(p, dtype=np.int64))
    return mins[inverse].astype(labels.dtype, copy=False)


def partitions_equal(a: np.ndarray, b: np.ndarray) -> bool:
    """Theorem-1 equality: same vertex partition up to label permutation."""
    return bool(np.array_equal(canonicalize_labels(a), canonicalize_labels(b)))


def is_refinement(fine: np.ndarray, coarse: np.ndarray) -> bool:
    """Theorem-2 nestedness: every class of ``fine`` lies inside one class of
    ``coarse`` (fine = larger lambda, coarse = smaller lambda)."""
    fine = canonicalize_labels(fine)
    coarse = np.asarray(coarse)
    for lab in np.unique(fine):
        members = coarse[fine == lab]
        if not np.all(members == members[0]):
            return False
    return True


def component_lists(labels: np.ndarray) -> list[np.ndarray]:
    """Members per component, largest first (scheduling order).

    Vectorized: one argsort + one split instead of a per-component scan — the
    planner calls this at every lambda of a path."""
    labels = canonicalize_labels(labels)
    order = np.argsort(labels, kind="stable")  # stable: members stay ascending
    _, starts = np.unique(labels[order], return_index=True)
    comps = np.split(order, starts[1:])
    return sorted(comps, key=lambda c: -len(c))

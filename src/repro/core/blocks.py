"""Component extraction, size-bucketing/padding, and solution scatter-back.

TPU/JAX want few compiled shapes and batched work.  Components arrive in many
ragged sizes; we pad each to a bucket size (powers of two by default) and
stack same-bucket blocks so one vmapped solver call handles the whole bucket.

Padding correctness is itself a corollary of Theorem 1: the padded input
S_pad = blkdiag(S_comp, I_pad) has zero off-block entries <= lam, so its
glasso solution is exactly blkdiag(Theta_comp, (1/(1+lam)) I_pad) — the
padded coordinates never contaminate the component's solution.  (This is
property-tested in tests/test_blocks.py.)

Isolated nodes (|comp| = 1) are closed-form: Theta_ii = 1/(S_ii + lam), from
the diagonal KKT W_ii = S_ii + lam — the Witten-Friedman special case the
paper generalizes.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.instrument import set_peak


def bucket_size(b: int, *, min_bucket: int = 2) -> int:
    """Next power of two >= b (>= min_bucket)."""
    size = min_bucket
    while size < b:
        size *= 2
    return size


def plan_bucket_size(b: int, *, single_block: bool = False, min_bucket: int = 2) -> int:
    """Padded size for a component of size b inside a plan.

    Buckets holding several blocks stay at the next power of two (few compiled
    shapes, shared across lambdas).  A bucket holding a SINGLE block — always
    the case for the largest component, and for the full p x p problem when
    screening is off — gets mild next-multiple-of-128 padding instead: pow2
    would pad 1025 -> 2048, an 8x FLOPs blowup at b^3 cost, where 1025 -> 1152
    costs 1.4x.  128 keeps TPU lane/MXU alignment; below 128 pow2 is already
    mild, so the rule only changes sizes > 256.
    """
    p2 = bucket_size(b, min_bucket=min_bucket)
    if not single_block or b <= 128:
        return p2
    return min(p2, ((b + 127) // 128) * 128)


#: working-set multiplier for a SINGLE-DEVICE iterative solve of one (b, b)
#: block: S, the solver pair (Z/U or Theta/W), the eigh/Cholesky workspace
#: and the result — the memory model behind the oversize threshold
SINGLE_DEVICE_BUFFERS = 8


def oversize_threshold(budget_mb: float, dtype=np.float64) -> int:
    """Largest block size a single device's memory budget can solve.

    Components LARGER than this are classed "oversize" by the planner and
    routed to the mesh-spanning sharded solver.  The model is
    ``SINGLE_DEVICE_BUFFERS`` resident (b, b) buffers:

        b_max = sqrt(budget_bytes / (SINGLE_DEVICE_BUFFERS * itemsize))
    """
    budget_bytes = float(budget_mb) * 2**20
    itemsize = np.dtype(dtype).itemsize
    return max(1, int(np.sqrt(budget_bytes / (SINGLE_DEVICE_BUFFERS * itemsize))))


def group_components(
    comps: list[np.ndarray], classify=None
) -> tuple[np.ndarray, dict[tuple[int, str], list[np.ndarray]]]:
    """Split components into (isolated vertices, {(padded size, structure):
    members}).

    ``classify`` maps a component's vertex array to its structure class
    (``repro.engine.structure``); None tags everything "general" — the
    pre-router behavior.  Buckets are homogeneous in BOTH padded size and
    structure, so the executor can route a whole bucket down one ladder rung.

    Grouping is by power-of-two size; groups that end up with exactly one
    block are then re-padded to their mild single-block size (see
    ``plan_bucket_size``).  Sizes cannot collide across same-structure
    groups: the mild size stays within (pow2/2, pow2].
    """
    isolated = np.array(
        sorted(int(c[0]) for c in comps if len(c) == 1), dtype=np.int64
    )
    by_p2: dict[tuple[int, str], list[np.ndarray]] = {}
    for c in comps:
        if len(c) == 1:
            continue
        structure = classify(c) if classify is not None else "general"
        by_p2.setdefault((bucket_size(len(c)), structure), []).append(c)
    by_key: dict[tuple[int, str], list[np.ndarray]] = {}
    for (_, structure), members in by_p2.items():
        size = plan_bucket_size(len(members[0]), single_block=len(members) == 1)
        by_key.setdefault((size, structure), []).extend(members)
    return isolated, dict(sorted(by_key.items()))


def pad_block(S_block: np.ndarray, size: int) -> np.ndarray:
    b = S_block.shape[0]
    out = np.eye(size, dtype=S_block.dtype)
    out[:b, :b] = S_block
    return out


def gather_submatrix(S, idx: np.ndarray, *, dtype=None) -> np.ndarray:
    """S[np.ix_(idx, idx)] through the covariance gather protocol.

    Dense arrays index directly; objects exposing ``gather_block`` (the
    streaming screener's ``MaterializedCovariance``) serve the same entries
    from per-component blocks — the planner/executor/classifier never learn
    which input modality produced S."""
    if hasattr(S, "gather_block"):
        blk = S.gather_block(idx)
    else:
        blk = np.asarray(S)[np.ix_(idx, idx)]
    return blk if dtype is None else blk.astype(dtype, copy=False)


def gather_diag(S, idx) -> np.ndarray:
    """S[idx, idx] (diagonal gather) through the same protocol."""
    if hasattr(S, "diag_at"):
        return S.diag_at(idx)
    return np.asarray(S)[idx, idx]


def gather_submatrix_rows(S, rows: np.ndarray, cols: np.ndarray, *, dtype=None) -> np.ndarray:
    """S[np.ix_(rows, cols)] through the gather protocol (both index sets
    inside ONE component).  The rectangular sibling of ``gather_submatrix``:
    the sharded oversize route fetches a giant block one row-chunk at a time
    (``stream.materialize.shard_gather``), so no stage ever holds the whole
    (b, b) block on the host."""
    if hasattr(S, "gather_block_rows"):
        blk = S.gather_block_rows(rows, cols)
    else:
        blk = np.asarray(S)[np.ix_(rows, cols)]
    return blk if dtype is None else blk.astype(dtype, copy=False)


@dataclass
class Bucket:
    size: int                                  # padded block size
    comps: list[np.ndarray]                    # member-vertex arrays
    blocks: np.ndarray | None                  # (n_blocks, size, size) padded S;
                                               # None for "oversize" buckets —
                                               # the sharded route gathers
                                               # straight into device shards,
                                               # never a host stack
    structure: str = "general"                 # routing ladder class


@dataclass
class Plan:
    p: int
    lam: float
    labels: np.ndarray
    isolated: np.ndarray                       # vertex ids with |comp| = 1
    buckets: list[Bucket] = field(default_factory=list)

    @property
    def n_components(self) -> int:
        return len(self.isolated) + sum(len(b.comps) for b in self.buckets)

    @property
    def max_comp(self) -> int:
        mx = 1 if len(self.isolated) else 0
        for b in self.buckets:
            mx = max(mx, max(len(c) for c in b.comps))
        return mx

    def block_bytes(self) -> int:
        """Bytes held by the plan's padded input stacks (oversize buckets
        carry none — their blocks stream straight to device shards)."""
        return int(
            sum(b.blocks.nbytes for b in self.buckets if b.blocks is not None)
        )


def make_bucket(
    S: np.ndarray,
    size: int,
    members: list[np.ndarray],
    *,
    dtype=np.float64,
    structure: str = "general",
) -> Bucket:
    """Pad and stack one size-group of components (the ONLY place padded
    bucket stacks are constructed — build_plan and the engine planner both
    delegate here, so the padding convention cannot desynchronize).

    "oversize" buckets carry NO host block stack: their blocks exceed the
    single-device budget by definition, so the executor's sharded route
    gathers each one row-chunk by row-chunk straight into device shards
    (``stream.materialize.shard_gather``) — a padded host copy here would
    reintroduce exactly the allocation the route exists to avoid."""
    if structure == "oversize":
        return Bucket(size=size, comps=members, blocks=None, structure=structure)
    blocks = np.stack(
        [pad_block(gather_submatrix(S, c, dtype=dtype), size) for c in members]
    )
    return Bucket(size=size, comps=members, blocks=blocks, structure=structure)


def build_plan(
    S: np.ndarray, lam: float, labels: np.ndarray, *, dtype=np.float64, classify=None
) -> Plan:
    """Group components into padded same-(size, structure) buckets.

    ``classify`` tags each component with its routing-ladder structure class
    (see ``group_components``); None keeps every bucket "general"."""
    from repro.core.components import component_lists

    comps = component_lists(labels)
    isolated, by_key = group_components(comps, classify=classify)
    buckets = [
        make_bucket(S, size, members, dtype=dtype, structure=structure)
        for (size, structure), members in by_key.items()
    ]
    return Plan(p=S.shape[0], lam=float(lam), labels=labels, isolated=isolated, buckets=buckets)


def solve_bucket(
    blocks: jax.Array, lam: float, solver, *, W0=None, **solver_opts
) -> jax.Array:
    """vmap the block solver across one bucket's stacked padded blocks.

    W0, if given, is a per-block stack of warm-start covariance iterates and
    is mapped alongside the blocks."""
    if W0 is not None:
        return jax.vmap(lambda Sb, w0: solver(Sb, lam, W0=w0, **solver_opts))(
            blocks, W0
        )
    return jax.vmap(lambda Sb: solver(Sb, lam, **solver_opts))(blocks)


def assemble_dense(
    plan: Plan, bucket_solutions: list[np.ndarray], S: np.ndarray, *,
    out: np.ndarray | None = None,
) -> np.ndarray:
    """Scatter per-component solutions back into the global dense Theta.

    Buckets whose members all share one size scatter with a single fancy-
    index assignment per bucket — on large-lambda plans (thousands of tiny
    components) the per-component python loop was a measurable slice of the
    whole solve stage.

    ``out``, when given, must be a ZERO-INITIALIZED (p, p) buffer to
    assemble into — the joint assembler hands per-class views of one
    (K, p, p) allocation so the dense stack is written exactly once
    (a stack-of-K-results copy at p=2400 costs more than the scatter)."""
    p = plan.p
    if out is not None:
        Theta = out
    else:
        dtype = (
            np.asarray(bucket_solutions[0]).dtype
            if bucket_solutions
            else cov_dtype(S)
        )
        Theta = np.zeros((p, p), dtype=dtype)
        set_peak("result.bytes_peak", Theta.nbytes)
    if len(plan.isolated):
        Theta[plan.isolated, plan.isolated] = 1.0 / (
            gather_diag(S, plan.isolated) + plan.lam
        )
    for bucket, sols in zip(plan.buckets, bucket_solutions):
        sols = np.asarray(sols)
        by_b: dict[int, list[int]] = {}
        for i, comp in enumerate(bucket.comps):
            by_b.setdefault(len(comp), []).append(i)
        for b, idxs in by_b.items():
            if len(idxs) == 1:
                comp = bucket.comps[idxs[0]]
                Theta[np.ix_(comp, comp)] = sols[idxs[0]][:b, :b]
            else:
                rows = np.stack([bucket.comps[i] for i in idxs])   # (n, b)
                Theta[rows[:, :, None], rows[:, None, :]] = sols[idxs][:, :b, :b]
    return Theta


def cov_dtype(S) -> np.dtype:
    """The numpy dtype of a covariance operand — dense array or gather-
    protocol object (``MaterializedCovariance`` carries ``.dtype``)."""
    if hasattr(S, "gather_block"):
        return np.dtype(S.dtype)
    return np.asarray(S).dtype


def assemble_sparse(plan: Plan, bucket_solutions: list[np.ndarray], S):
    """Assemble per-bucket solutions into a ``SparseTheta`` with ZERO (p, p)
    allocation: the bucket solution stacks become the result's block storage
    as-is (no copy), and only the (p,) index maps + isolated closed-form
    diagonal are built on top.

    The dense and sparse assemblers consume identical inputs, so a dense
    ``assemble_dense`` of the same ``bucket_solutions`` densifies to the
    numerically IDENTICAL matrix — the equivalence ``bench_sparse`` and the
    property tests hard-assert."""
    from repro.core.sparse import SparseTheta, _build_index

    stacks = [np.asarray(sols) for sols in bucket_solutions]
    dtype = stacks[0].dtype if stacks else cov_dtype(S)
    comps: list[np.ndarray] = []
    loc: list[tuple[int, int]] = []
    for s, bucket in enumerate(plan.buckets):
        for r, comp in enumerate(bucket.comps):
            comps.append(np.asarray(comp, dtype=np.int64))
            loc.append((s, r))
    isolated = np.asarray(plan.isolated, dtype=np.int64)
    if isolated.size:
        iso_vals = (
            1.0 / (gather_diag(S, isolated) + plan.lam)
        ).astype(dtype, copy=False)
    else:
        iso_vals = np.zeros(0, dtype=dtype)
    comp_id, pos_in = _build_index(plan.p, comps, isolated)
    Theta = SparseTheta(
        plan.p, dtype, stacks, comps, loc, comp_id, pos_in, isolated, iso_vals
    )
    set_peak("result.bytes_peak", Theta.nbytes())
    return Theta

"""Component extraction, size-bucketing/padding, and solution scatter-back.

TPU/JAX want few compiled shapes and batched work.  Components arrive in many
ragged sizes; we pad each to a bucket size (powers of two by default) and
stack same-bucket blocks so one vmapped solver call handles the whole bucket.

Padding correctness is itself a corollary of Theorem 1: the padded input
S_pad = blkdiag(S_comp, I_pad) has zero off-block entries <= lam, so its
glasso solution is exactly blkdiag(Theta_comp, (1/(1+lam)) I_pad) — the
padded coordinates never contaminate the component's solution.  (This is
property-tested in tests/test_blocks.py.)

Isolated nodes (|comp| = 1) are closed-form: Theta_ii = 1/(S_ii + lam), from
the diagonal KKT W_ii = S_ii + lam — the Witten-Friedman special case the
paper generalizes.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


def bucket_size(b: int, *, min_bucket: int = 2) -> int:
    """Next power of two >= b (>= min_bucket)."""
    size = min_bucket
    while size < b:
        size *= 2
    return size


def pad_block(S_block: np.ndarray, size: int) -> np.ndarray:
    b = S_block.shape[0]
    out = np.eye(size, dtype=S_block.dtype)
    out[:b, :b] = S_block
    return out


@dataclass
class Bucket:
    size: int                                  # padded block size
    comps: list[np.ndarray]                    # member-vertex arrays
    blocks: np.ndarray                         # (n_blocks, size, size) padded S

@dataclass
class Plan:
    p: int
    lam: float
    labels: np.ndarray
    isolated: np.ndarray                       # vertex ids with |comp| = 1
    buckets: list[Bucket] = field(default_factory=list)

    @property
    def n_components(self) -> int:
        return len(self.isolated) + sum(len(b.comps) for b in self.buckets)

    @property
    def max_comp(self) -> int:
        mx = 1 if len(self.isolated) else 0
        for b in self.buckets:
            mx = max(mx, max(len(c) for c in b.comps))
        return mx


def build_plan(
    S: np.ndarray, lam: float, labels: np.ndarray, *, dtype=np.float64
) -> Plan:
    """Group components into padded same-size buckets."""
    from repro.core.components import component_lists

    comps = component_lists(labels)
    isolated = np.array(sorted(int(c[0]) for c in comps if len(c) == 1), dtype=np.int64)
    by_size: dict[int, list[np.ndarray]] = {}
    for c in comps:
        if len(c) == 1:
            continue
        by_size.setdefault(bucket_size(len(c)), []).append(c)
    buckets = []
    for size in sorted(by_size):
        members = by_size[size]
        blocks = np.stack(
            [pad_block(np.asarray(S, dtype)[np.ix_(c, c)], size) for c in members]
        )
        buckets.append(Bucket(size=size, comps=members, blocks=blocks))
    return Plan(p=S.shape[0], lam=float(lam), labels=labels, isolated=isolated, buckets=buckets)


def solve_bucket(
    blocks: jax.Array, lam: float, solver, *, W0=None, **solver_opts
) -> jax.Array:
    """vmap the block solver across one bucket's stacked padded blocks.

    W0, if given, is a per-block stack of warm-start covariance iterates and
    is mapped alongside the blocks."""
    if W0 is not None:
        return jax.vmap(lambda Sb, w0: solver(Sb, lam, W0=w0, **solver_opts))(
            blocks, W0
        )
    return jax.vmap(lambda Sb: solver(Sb, lam, **solver_opts))(blocks)


def assemble_dense(
    plan: Plan, bucket_solutions: list[np.ndarray], S: np.ndarray
) -> np.ndarray:
    """Scatter per-component solutions back into the global dense Theta."""
    p = plan.p
    Theta = np.zeros((p, p), dtype=np.asarray(bucket_solutions[0]).dtype if bucket_solutions else np.float64)
    Sd = np.asarray(S)
    if len(plan.isolated):
        Theta[plan.isolated, plan.isolated] = 1.0 / (
            Sd[plan.isolated, plan.isolated] + plan.lam
        )
    for bucket, sols in zip(plan.buckets, bucket_solutions):
        sols = np.asarray(sols)
        for comp, sol in zip(bucket.comps, sols):
            b = len(comp)
            Theta[np.ix_(comp, comp)] = sol[:b, :b]
    return Theta

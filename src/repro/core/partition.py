"""Edge-sorted merge profile of the thresholded covariance graph.

The components change *only* at the distinct values of |S_ij| (paper
Section 4.2), so one pass of incremental union-find over edges sorted by
decreasing |S_ij| yields, for every threshold, the number of components and the
maximal component size.  This powers:

  * Figure-1 style component-size profiles across lambda,
  * ``lambda_for_max_component`` — consequence 5 of Theorem 1: the smallest
    lambda whose maximal component fits a per-machine capacity p_max,
  * the lambda_I / lambda_II calibration of the synthetic experiments.

Cost: O(p^2 log p) for the sort + O(p^2 alpha(p)) for the unions — negligible
next to one glasso solve (paper Section 3).
"""

from __future__ import annotations

import numpy as np


def merge_profile(S: np.ndarray, *, max_edges: int | None = None) -> dict:
    """Incremental-union merge profile.

    Returns dict of arrays, one row per *distinct* edge value v (descending):
      value          v
      n_components   #components of the graph with edges {|S_ij| > lambda}
      max_comp       maximal component size
    valid for lambda in [next smaller v, v).  Row 0 is the fictitious
    lambda >= max|S_ij| regime (all isolated): value=+inf boundary handled by
    callers via lambda >= value[1].
    """
    S = np.asarray(S)
    p = S.shape[0]
    iu, ju = np.triu_indices(p, 1)
    w = np.abs(S[iu, ju])
    order = np.argsort(-w, kind="stable")
    if max_edges is not None:
        order = order[:max_edges]
    iu, ju, w = iu[order], ju[order], w[order]

    parent = np.arange(p)
    size = np.ones(p, dtype=np.int64)

    def find(i):
        root = i
        while parent[root] != root:
            root = parent[root]
        while parent[i] != root:
            parent[i], i = root, parent[i]
        return root

    values = [np.inf]
    n_components = [p]
    max_comp = [1]
    ncomp, mx = p, 1
    k = 0
    m = w.size
    while k < m:
        v = w[k]
        # insert every edge with this exact value
        while k < m and w[k] == v:
            ra, rb = find(iu[k]), find(ju[k])
            if ra != rb:
                if size[ra] < size[rb]:
                    ra, rb = rb, ra
                parent[rb] = ra
                size[ra] += size[rb]
                ncomp -= 1
                mx = max(mx, int(size[ra]))
            k += 1
        values.append(float(v))
        n_components.append(ncomp)
        max_comp.append(mx)
    return {
        "value": np.asarray(values),
        "n_components": np.asarray(n_components),
        "max_comp": np.asarray(max_comp),
    }


def lambda_for_max_component(S: np.ndarray, p_max: int) -> float:
    """Smallest lambda such that the maximal thresholded component has size
    <= p_max (paper consequence 5; also the Figure-1 x-axis lower bound).

    The graph at lambda = value[k] *excludes* edges of weight value[k] (strict
    inequality in eq. (4)), i.e. it has the profile of row k-1... rows are
    arranged so row k describes lambda in [value[k+1], value[k]).  We return
    the infimum feasible lambda: the largest edge value v whose insertion
    pushes max_comp beyond p_max (at lambda = v that edge is excluded, so the
    constraint still holds).
    """
    prof = merge_profile(S)
    vals, mx = prof["value"], prof["max_comp"]
    bad = np.nonzero(mx > p_max)[0]
    if bad.size == 0:
        return 0.0
    return float(vals[bad[0]])


def component_size_distribution(S: np.ndarray, lambdas: np.ndarray) -> list[dict]:
    """Figure-1 data: for each lambda, the histogram of component sizes.

    Re-runs union-find once over the sorted edges, snapshotting at each
    requested lambda (descending order internally)."""
    from repro.core.components import components_from_covariance_host

    out = []
    for lam in np.asarray(lambdas):
        labels = components_from_covariance_host(S, float(lam))
        _, counts = np.unique(labels, return_counts=True)
        sizes, freq = np.unique(counts, return_counts=True)
        out.append(
            {
                "lambda": float(lam),
                "sizes": sizes,
                "counts": freq,
                "n_components": int(counts.size),
                "max_comp": int(counts.max()),
            }
        )
    return out

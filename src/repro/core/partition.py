"""Edge-sorted merge profile of the thresholded covariance graph.

The components change *only* at the distinct values of |S_ij| (paper
Section 4.2), so one pass of incremental union-find over edges sorted by
decreasing |S_ij| yields, for every threshold, the number of components and the
maximal component size.  This powers:

  * Figure-1 style component-size profiles across lambda,
  * ``lambda_for_max_component`` — consequence 5 of Theorem 1: the smallest
    lambda whose maximal component fits a per-machine capacity p_max,
  * the lambda_I / lambda_II calibration of the synthetic experiments.

Cost: O(p^2 log p) for the sort + O(p^2 alpha(p)) for the unions — negligible
next to one glasso solve (paper Section 3).
"""

from __future__ import annotations

import numpy as np

from repro.core.instrument import bump


def _sorted_edges(S: np.ndarray, *, lam_min: float | None = None):
    """Upper-triangle edges of |S| sorted by decreasing weight.

    ``lam_min`` drops edges with |S_ij| <= lam_min BEFORE the sort: a path
    planner whose grid is bounded below by lam_min never inserts them (strict
    threshold, eq. (4)), and on sparse problems the argsort shrinks from
    p^2/2 entries to the surviving-edge count — the difference between the
    planner being cheaper or dearer than per-lambda re-screens."""
    S = np.asarray(S)
    p = S.shape[0]
    iu, ju = np.triu_indices(p, 1)
    w = np.abs(S[iu, ju])
    if lam_min is not None:
        keep = w > lam_min
        iu, ju, w = iu[keep], ju[keep], w[keep]
    order = np.argsort(-w, kind="stable")
    return iu[order], ju[order], w[order]


def merge_profile(S: np.ndarray, *, max_edges: int | None = None) -> dict:
    """Incremental-union merge profile.

    Returns dict of arrays, one row per *distinct* edge value v (descending):
      value          v
      n_components   #components of the graph with edges {|S_ij| > lambda}
      max_comp       maximal component size
    valid for lambda in [next smaller v, v).  Row 0 is the fictitious
    lambda >= max|S_ij| regime (all isolated): value=+inf boundary handled by
    callers via lambda >= value[1].
    """
    bump("partition.unionfind_passes")
    S = np.asarray(S)
    p = S.shape[0]
    iu, ju, w = _sorted_edges(S)
    if max_edges is not None:
        iu, ju, w = iu[:max_edges], ju[:max_edges], w[:max_edges]

    parent = np.arange(p)
    size = np.ones(p, dtype=np.int64)

    def find(i):
        root = i
        while parent[root] != root:
            root = parent[root]
        while parent[i] != root:
            parent[i], i = root, parent[i]
        return root

    values = [np.inf]
    n_components = [p]
    max_comp = [1]
    ncomp, mx = p, 1
    k = 0
    m = w.size
    while k < m:
        v = w[k]
        # insert every edge with this exact value
        while k < m and w[k] == v:
            ra, rb = find(iu[k]), find(ju[k])
            if ra != rb:
                if size[ra] < size[rb]:
                    ra, rb = rb, ra
                parent[rb] = ra
                size[ra] += size[rb]
                ncomp -= 1
                mx = max(mx, int(size[ra]))
            k += 1
        values.append(float(v))
        n_components.append(ncomp)
        max_comp.append(mx)
    return {
        "value": np.asarray(values),
        "n_components": np.asarray(n_components),
        "max_comp": np.asarray(max_comp),
    }


def lambda_for_max_component(S: np.ndarray, p_max: int) -> float:
    """Smallest lambda such that the maximal thresholded component has size
    <= p_max (paper consequence 5; also the Figure-1 x-axis lower bound).

    The graph at lambda = value[k] *excludes* edges of weight value[k] (strict
    inequality in eq. (4)), i.e. it has the profile of row k-1... rows are
    arranged so row k describes lambda in [value[k+1], value[k]).  We return
    the infimum feasible lambda: the largest edge value v whose insertion
    pushes max_comp beyond p_max (at lambda = v that edge is excluded, so the
    constraint still holds).
    """
    prof = merge_profile(S)
    vals, mx = prof["value"], prof["max_comp"]
    bad = np.nonzero(mx > p_max)[0]
    if bad.size == 0:
        return 0.0
    return float(vals[bad[0]])


def labels_at_thresholds(S: np.ndarray, lambdas, *, edges=None) -> list[np.ndarray]:
    """Canonical component labels at every requested lambda from ONE
    incremental union-find pass over the edge-sorted |S_ij| (Theorem 2: the
    partitions are nested, so one descending sweep visits them all).

    Returns one (p,) canonical label array per lambda, aligned with the INPUT
    order of ``lambdas`` (internally processed descending).  Each snapshot
    costs O(p) on top of the shared O(p^2 log p) sort — this is the engine
    path-planner's only partition pass, counted in
    ``instrument.count("partition.unionfind_passes")``.
    """
    S = np.asarray(S)
    edges = _sorted_edges(S) if edges is None else edges
    return labels_at_thresholds_from_edges(S.shape[0], lambdas, edges)


def labels_at_thresholds_from_edges(
    p: int, lambdas, edges
) -> list[np.ndarray]:
    """The snapshot pass of ``labels_at_thresholds`` on a pre-sorted edge
    list (iu, ju, w descending), without a dense S — the entry point the
    streaming screener shares: its compacted edges (all |S_ij| above the
    grid minimum, which bounds every requested lambda from below) produce
    the same nested partitions as a dense edge sort."""
    from repro.core.components import canonicalize_labels

    bump("partition.unionfind_passes")
    iu, ju, w = edges

    parent = np.arange(p)

    def find(i):
        root = i
        while parent[root] != root:
            root = parent[root]
        while parent[i] != root:
            parent[i], i = root, parent[i]
        return root

    lams = np.asarray(list(lambdas), dtype=np.float64).ravel()
    out: list[np.ndarray | None] = [None] * lams.size
    k, m = 0, w.size
    for pos in np.argsort(-lams, kind="stable"):
        lam = lams[pos]
        while k < m and w[k] > lam:  # strict: eq. (4)
            ra, rb = find(int(iu[k])), find(int(ju[k]))
            if ra != rb:
                parent[rb if ra < rb else ra] = min(ra, rb)
            k += 1
        roots = np.fromiter((find(i) for i in range(p)), np.int64, p)
        out[pos] = canonicalize_labels(roots)
    return out  # type: ignore[return-value]


def component_size_distribution(S: np.ndarray, lambdas: np.ndarray) -> list[dict]:
    """Figure-1 data: for each lambda, the histogram of component sizes.

    Runs union-find ONCE over the sorted edges via ``labels_at_thresholds``,
    snapshotting at each requested lambda (descending order internally)."""
    out = []
    for lam, labels in zip(np.asarray(lambdas), labels_at_thresholds(S, lambdas)):
        _, counts = np.unique(labels, return_counts=True)
        sizes, freq = np.unique(counts, return_counts=True)
        out.append(
            {
                "lambda": float(lam),
                "sizes": sizes,
                "counts": freq,
                "n_components": int(counts.size),
                "max_comp": int(counts.max()),
            }
        )
    return out

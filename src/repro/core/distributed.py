"""Distributed screening + block solving over a device mesh.

Two stages, mirroring the paper's consequence 2-4:

1. ``distributed_components``  — the only stage that communicates.  The
   adjacency mask (fused from S and lambda) is *row-sharded* across the mesh's
   data axis; each label-propagation round does a device-local masked
   min-reduce over owned rows followed by one all-gather of the p-vector of
   labels (p * 4 bytes — negligible next to the p^2/d mask scan, matching the
   paper's Section-3 claim that partitioning cost is dominated by solving).

2. ``distributed_bucket_solve`` — ZERO-communication batched solves: Theorem 1
   guarantees the subproblems are independent, so same-size padded blocks are
   sharded across devices and solved with a vmapped block solver inside
   shard_map with no collective at all.  This is the paper's "split across
   machines" made literal on a pod.

Both functions are mesh-agnostic: they take any mesh and the name of the axis
to shard over (launch/mesh.py builds the production meshes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.jax_compat import shard_map


def distributed_components(
    S: jax.Array, lam, mesh, *, axis: str = "data", max_rounds: int | None = None
) -> jax.Array:
    """Row-sharded min-label propagation. Returns labels (p,), replicated."""
    p = S.shape[0]
    n_shard = np.prod([mesh.shape[a] for a in ([axis] if isinstance(axis, str) else axis)])
    if p % n_shard != 0:
        pad = int(n_shard - p % n_shard)
        # padded vertices carry no edges -> isolated, labels >= p, harmless
        S = jnp.pad(S, ((0, pad), (0, pad)))
    pp = S.shape[0]
    spec_rows = P(axis, None)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec_rows, P()), out_specs=P()
    )
    def run(S_rows, lam_arr):
        rows = S_rows.shape[0]
        axis_idx = jax.lax.axis_index(axis)
        row0 = axis_idx * rows
        ii = row0 + jnp.arange(rows)
        jj = jnp.arange(pp)
        mask = (jnp.abs(S_rows) > lam_arr) & (ii[:, None] != jj[None, :])
        big = jnp.int32(pp)

        def round_(labels):
            neigh = jnp.where(mask, labels[None, :], big)
            owned = jax.lax.dynamic_slice(labels, (row0,), (rows,))
            local = jnp.minimum(owned, jnp.min(neigh, axis=1))
            labels = jax.lax.all_gather(local, axis, tiled=True)
            labels = labels[labels]
            labels = labels[labels]
            return labels

        init = jnp.arange(pp, dtype=jnp.int32)

        def cond(c):
            labels, prev, it = c
            limit = max_rounds if max_rounds is not None else pp + 2
            return jnp.logical_and(jnp.any(labels != prev), it < limit)

        def body(c):
            labels, _, it = c
            return round_(labels), labels, it + 1

        labels, _, _ = jax.lax.while_loop(
            cond, body, (round_(init), init, jnp.int32(0))
        )
        return labels

    labels = run(S, jnp.asarray(lam, S.dtype))
    return labels[:p]


def distributed_bucket_solve(
    blocks: np.ndarray | jax.Array,
    lam: float,
    solver,
    mesh,
    *,
    axis: str = "data",
    **solver_opts,
):
    """Shard a (n, b, b) stack of padded same-size blocks across ``axis`` and
    solve with vmap(solver) per device.  No collectives — independence is
    exactly what Theorem 1 bought us.

    n is padded up to a multiple of the axis size with identity blocks (whose
    solution is (1/(1+lam)) I); callers slice the first n results.
    """
    blocks = jnp.asarray(blocks)
    n, b, _ = blocks.shape
    n_shard = int(np.prod([mesh.shape[a] for a in ([axis] if isinstance(axis, str) else axis)]))
    pad = (-n) % n_shard
    if pad:
        blocks = jnp.concatenate(
            [blocks, jnp.broadcast_to(jnp.eye(b, dtype=blocks.dtype), (pad, b, b))]
        )

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(axis, None, None),), out_specs=P(axis, None, None)
    )
    def run(local):
        return jax.vmap(lambda Sb: solver(Sb, lam, **solver_opts))(local)

    out = run(blocks)
    return out[:n]


def put_sharded_blocks(blocks: np.ndarray, mesh, *, axis: str = "data"):
    """Device_put a block stack with first-axis sharding (for benchmarks that
    want the transfer outside the timed region)."""
    return jax.device_put(
        jnp.asarray(blocks), NamedSharding(mesh, P(axis, None, None))
    )


# ---------------------------------------------------------------------------
# Row-sharded matrix primitives (the sharded oversize solver's vocabulary)
# ---------------------------------------------------------------------------
#
# All three helpers run INSIDE a shard_map body: operands are the local
# (rows_local, p) shard of a row-sharded square matrix, and — crucially for
# the oversize memory model — none of them ever materializes a full (p, p)
# operand on any one device.  Peak per-device scratch is one extra shard.


def ring_matmul(a_rows: jax.Array, b_rows: jax.Array, *, axis: str, n_shards: int):
    """C = A @ B with A, B, C all row-sharded over ``axis``.

    Classic 1-D ring algorithm: at step k each device multiplies its local
    column slab A[:, rows-of-shard-s] (s = my_index + k) by the B shard
    currently in its ring buffer, then passes the buffer along the ring.
    n_shards steps of (rl, rl) @ (rl, p) work — the same b^3 / d FLOPs as the
    gathered product, but the only extra buffer is one (rl, p) shard instead
    of the full (p, p) all-gather."""
    if n_shards == 1:
        return a_rows @ b_rows
    rl = a_rows.shape[0]
    idx = jax.lax.axis_index(axis)
    perm = [(j, (j - 1) % n_shards) for j in range(n_shards)]

    def step(k, carry):
        acc, b_cur = carry
        s = jax.lax.rem((idx + k).astype(jnp.int32), jnp.int32(n_shards))
        col0 = (s * rl).astype(jnp.int32)
        a_cols = jax.lax.dynamic_slice(a_rows, (jnp.int32(0), col0), (rl, rl))
        acc = acc + a_cols @ b_cur
        b_cur = jax.lax.ppermute(b_cur, axis, perm)
        return acc, b_cur

    acc0 = jnp.zeros_like(b_rows)
    acc, _ = jax.lax.fori_loop(0, n_shards, step, (acc0, b_rows))
    return acc


def transpose_rowsharded(a_rows: jax.Array, *, axis: str, n_shards: int):
    """(A^T) row-sharded from A row-sharded, via one all_to_all.

    Device i sends its column block j to device j and receives every
    device's column block i — i.e. the full column slab A[:, cols_i] —
    whose transpose is exactly the rows of A^T this device owns.  Per-device
    traffic and scratch are one shard, never the full matrix."""
    if n_shards == 1:
        return a_rows.T
    col_slab = jax.lax.all_to_all(
        a_rows, axis, split_axis=1, concat_axis=0, tiled=True
    )  # (p, rows_local) — global rows arrive in shard order, already aligned
    return col_slab.T


def matvec_rowsharded(a_rows: jax.Array, v: jax.Array, *, axis: str, n_shards: int):
    """(A @ v) replicated, from A row-sharded and v replicated."""
    if n_shards == 1:
        return a_rows @ v
    return jax.lax.all_gather(a_rows @ v, axis, tiled=True)


def device_memory_budget_mb() -> float | None:
    """Per-device accelerator memory in MB, or None when the backend does
    not report it (CPU).  The planner's ``oversize_budget_mb="auto"`` hook."""
    try:
        stats = jax.local_devices()[0].memory_stats()
    except (RuntimeError, AttributeError, TypeError):
        return None
    if not stats or "bytes_limit" not in stats:
        return None
    return float(stats["bytes_limit"]) / 2**20

"""Version-compat shims for the handful of jax APIs that moved after 0.4.x.

The container pins jax 0.4.37 while some call sites were written against the
newer surface; everything engine-side goes through these helpers so the
distributed screening/solving backends stay first-class on either version:

    shard_map(...)   jax.shard_map (>=0.6, ``check_vma``) vs
                     jax.experimental.shard_map.shard_map (0.4.x, ``check_rep``)
    make_mesh(...)   ``axis_types`` keyword only exists on newer jax
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs):
    """Unreplicated-output-check disabled in both dialects (the label-prop
    while_loop trips the 0.4.x replication checker)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def make_mesh(axis_shapes, axis_names, *, auto_axes: bool = True):
    """jax.make_mesh with axis_types=Auto where supported."""
    if auto_axes and hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            axis_shapes,
            axis_names,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
        )
    return jax.make_mesh(axis_shapes, axis_names)


_LOCAL_MESHES: dict[tuple, object] = {}


def local_device_mesh(axis: str = "data"):
    """1-D mesh over every local device (the engine's default placement).

    Cached per (axis, device count): the device set is fixed for a process
    lifetime, and re-building the mesh per call both wastes time (tests that
    emulate 8 host devices re-init it hundreds of times) and defeats any
    compiled-function cache keyed on mesh identity."""
    key = (axis, jax.device_count())
    mesh = _LOCAL_MESHES.get(key)
    if mesh is None:
        mesh = make_mesh((jax.device_count(),), (axis,))
        _LOCAL_MESHES[key] = mesh
    return mesh

"""Closed-form glasso solvers for structured thresholded supports.

The routing ladder (DESIGN.md Section 9) sends each component to the
cheapest solver its structure admits:

    singleton   Theta_ii = 1/(S_ii + lam)                (diagonal KKT)
    pair        analytic 2x2: W = [[s11+lam, soft(s12,lam)],
                                   [soft(s12,lam), s22+lam]], Theta = W^{-1}
                — the single-edge case of the forest formula
    tree        Fattahi-Sojoudi closed form (kernels/tree_glasso): O(|E|)
    chordal     clique-tree inverse of the maximum-determinant completion
                (Fattahi, Zhang & Sojoudi, arXiv:1711.09131):
                    Theta = sum_cliques [A_C^{-1}]^0 - sum_seps [A_S^{-1}]^0
                with A the soft-thresholded matrix restricted to the chordal
                support.  Equivalent to a zero-fill sparse Cholesky solve
                under the perfect elimination ordering; cost is
                sum |C|^3 over maximal cliques instead of iterating O(b^3).
    general     the iterative tail (bcd / pg / admm)

Closed forms satisfy the edge KKT exactly BY CONSTRUCTION; the non-edge dual
constraint |W_ij - S_ij| <= lam can fail on adversarial matrices (glasso ==
thresholding needs the papers' sign-consistency conditions), so every fast
path is verified — ``kkt_ok_stack`` / ``kkt_residual_host`` — and failures
fall back to the iterative solver.  Routing therefore never changes the
answer, only the cost.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.tree_glasso.ops import glasso_forest, glasso_forest_stack

__all__ = [
    "glasso_forest",
    "glasso_forest_stack",
    "glasso_chordal_host",
    "soft_threshold_host",
    "kkt_ok_stack",
    "kkt_residual_host",
]


# ---------------------------------------------------------------------------
# Chordal: clique-tree inverse of the max-det completion (host, per block)
# ---------------------------------------------------------------------------


def soft_threshold_host(S: np.ndarray, lam: float) -> np.ndarray:
    """A = soft(S, lam) off-diagonal (strict support |S_ij| > lam),
    S_ii + lam on the diagonal — the matrix whose completion glasso inverts."""
    S = np.asarray(S)
    absS = np.abs(S)
    A = np.where(absS > lam, np.sign(S) * (absS - lam), 0.0)
    np.fill_diagonal(A, np.diag(S) + lam)
    return A


def glasso_chordal_host(
    S_blk: np.ndarray, lam: float, *, adj: np.ndarray | None = None
) -> np.ndarray:
    """Closed-form glasso candidate for one block with chordal support.

    Sums zero-padded clique inverses and subtracts separator inverses of the
    soft-thresholded matrix — the junction-tree formula for the inverse of
    the maximum-determinant positive-definite completion.  The caller (the
    executor's router) verifies the KKT residual and falls back on failure.
    """
    from repro.engine.structure import clique_tree, component_adjacency, peo_or_none

    S_blk = np.asarray(S_blk, dtype=np.float64 if S_blk.dtype.kind != "f" else S_blk.dtype)
    b = S_blk.shape[0]
    if adj is None:
        adj = component_adjacency(S_blk, np.arange(b), lam)
    order = peo_or_none(adj)
    if order is None:
        raise ValueError("glasso_chordal_host called on a non-chordal support")
    cliques, separators = clique_tree(adj, order)
    A = soft_threshold_host(S_blk, lam)
    Theta = np.zeros_like(A)
    for C in cliques:
        Theta[np.ix_(C, C)] += np.linalg.inv(A[np.ix_(C, C)])
    for sep in separators:
        Theta[np.ix_(sep, sep)] -= np.linalg.inv(A[np.ix_(sep, sep)])
    return Theta


# ---------------------------------------------------------------------------
# KKT verification (the router's safety net)
# ---------------------------------------------------------------------------


#: closed-form candidates are EXACTLY sparse off their support, so the zero
#: classification can be much tighter than the iterative solvers' default
_ZERO_TOL = 1e-12


def _kkt_residual_one(S: jax.Array, lam: jax.Array, Theta: jax.Array) -> jax.Array:
    """Worst KKT violation of a candidate Theta — delegates to the canonical
    ``core.solvers.kkt.kkt_residual`` (paper eq. (11)-(12)) so the router's
    safety net cannot drift from the optimality definition the tests use.
    NaN/Inf-safe: a degenerate candidate yields NaN/inf, which compares
    False against any tolerance, so the router falls back; the explicit PD
    guard catches indefinite candidates whose inverse is still finite."""
    from repro.core.solvers.kkt import kkt_residual

    res = kkt_residual(S, Theta, lam, zero_tol=_ZERO_TOL)
    pd = jnp.linalg.slogdet(Theta)[0] > 0
    return jnp.where(pd, res, jnp.inf)


def kkt_ok_stack(
    blocks: jax.Array, lams: jax.Array, thetas: jax.Array, *, tol: float
) -> jax.Array:
    """Per-block bool: candidate solutions within ``tol`` (scaled by max|S|)
    of KKT optimality.  One batched O(b^3) inverse — cheap next to the
    hundreds of iterations it certifies skipping."""
    res = jax.vmap(_kkt_residual_one)(blocks, lams, thetas)
    scale = jnp.maximum(
        jnp.max(jnp.abs(blocks), axis=(1, 2)), jnp.ones((), blocks.dtype)
    )
    return res <= tol * scale


def kkt_residual_host(S: np.ndarray, lam: float, Theta: np.ndarray) -> float:
    """Host twin of ``_kkt_residual_one`` for the chordal (numpy) path.

    Pure numpy so the chordal per-block host loop pays no jax dispatch; the
    formula MUST mirror ``core.solvers.kkt.kkt_residual`` (eq. (11)-(12)) —
    tests/test_closed_form.py cross-checks the two on every chordal
    property-test instance."""
    S = np.asarray(S, dtype=np.float64)
    Theta = np.asarray(Theta, dtype=np.float64)
    sign, _ = np.linalg.slogdet(Theta)
    if not np.isfinite(Theta).all() or sign <= 0:
        return float("inf")
    W = np.linalg.inv(Theta)
    eye = np.eye(S.shape[0], dtype=bool)
    is_zero = np.abs(Theta) <= _ZERO_TOL
    v_zero = np.where(
        is_zero & ~eye, np.maximum(np.abs(S - W) - lam, 0.0), 0.0
    ).max()
    v_act = np.where(
        ~is_zero & ~eye, np.abs(W - S - lam * np.sign(Theta)), 0.0
    ).max()
    v_diag = np.abs(np.diag(W) - np.diag(S) - lam).max()
    return float(max(v_zero, v_act, v_diag))

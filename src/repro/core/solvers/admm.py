"""ADMM for the graphical lasso [Boyd et al. 2011, Section 6.5].

    Theta-update:  rho*Theta - Theta^{-1} = rho*(Z - U) - S
                   -> eigendecompose the RHS, theta_i = (d_i + sqrt(d_i^2 + 4 rho)) / (2 rho)
    Z-update:      Z = soft(Theta + U, lam/rho)      (diagonal penalized too —
                   criterion (1) includes i = j, hence W_ii = S_ii + lam)
    U-update:      U += Theta - Z

Per-iteration cost is one (b, b) eigh — O(b^3), same class as one GLASSO
sweep.  Most robust solver on ill-conditioned blocks; the tests use it with a
tight tolerance as the cross-check oracle.  Returns Z (the sparse iterate), so
the support is exactly sparse — important for Theorem-1 pattern checks.

rho is adapted online (Boyd Section 3.4.1: x2 when the primal residual runs
10x ahead of the dual, /2 in the opposite case, with the scaled dual variable
U rescaled accordingly) — fixed rho=1 stalls far from the optimum on
ill-conditioned blocks well inside the default iteration budget.

WARM STARTS: ``W0`` (a covariance iterate, W ~= Theta*^{-1} — the executor's
path/repair currency) seeds BOTH halves of the splitting:

    Z0 = W0^{-1}                 the primal candidate
    U0 = (W0 - S) / rho          the scaled dual — from the Theta-update
                                 optimality rho*Theta - Theta^{-1} = rho*(Z-U)-S
                                 at the fixed point Theta = Z

Seeding Z alone is nearly worthless: ADMM then spends as many iterations
rebuilding U from zero as a cold start spends on everything (the dual IS the
memory of the splitting).  With both seeded, an exact W0 is a fixed point —
the KKT conditions (11)/(12) make soft(Z0 + U0, lam/rho) return Z0 exactly —
and a near-solution W0 (path step, executor repair, serving re-solve)
converges in a handful of sweeps.  A singular/non-finite W0 falls back to the
cold start inside the jit.

Callers that HOLD the Theta-side iterate (executor repairs hold the rejected
candidate, the path warm start holds the previous padded solution) pass it
as ``Theta0`` alongside W0: Z0 then comes straight from Theta0 and the
``inv(W0)`` above is skipped — they already paid one O(b^3) inversion to
build W0 from it, and inverting back would waste a second one (plus
precision on ill-conditioned blocks).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _soft(x, t):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


@functools.partial(jax.jit, static_argnames=("max_iter",))
def glasso_admm_info(
    S: jax.Array,
    lam: jax.Array,
    *,
    rho: float = 1.0,
    max_iter: int = 2000,
    tol: float = 1e-7,
    W0: jax.Array | None = None,
    Theta0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """ADMM returning (Theta, iterations) — the iteration count backs the
    warm-start acceptance tests and the executor's repair accounting."""
    b = S.shape[0]
    dtype = S.dtype
    lam = jnp.asarray(lam, dtype)
    rho0 = jnp.asarray(rho, dtype)

    def theta_update(Z, U, rho):
        rhs = rho * (Z - U) - S
        d, Q = jnp.linalg.eigh(rhs)
        theta_d = (d + jnp.sqrt(d * d + 4.0 * rho)) / (2.0 * rho)
        return (Q * theta_d[None, :]) @ Q.T

    def body(carry):
        Z, U, rho, _, _, it = carry
        Theta = theta_update(Z, U, rho)
        Z_new = _soft(Theta + U, lam / rho)
        U_new = U + Theta - Z_new
        r_prim = jnp.linalg.norm(Theta - Z_new)
        r_dual = rho * jnp.linalg.norm(Z_new - Z)
        # adaptive rho; U is the SCALED dual, so it rescales inversely
        factor = jnp.where(
            r_prim > 10.0 * r_dual,
            jnp.asarray(2.0, dtype),
            jnp.where(r_dual > 10.0 * r_prim, jnp.asarray(0.5, dtype), jnp.asarray(1.0, dtype)),
        )
        return Z_new, U_new / factor, rho * factor, r_prim, r_dual, it + 1

    def cond(carry):
        _, _, _, r_prim, r_dual, it = carry
        eps = tol * b
        return jnp.logical_and(
            jnp.logical_or(r_prim > eps, r_dual > eps), it < max_iter
        )

    cold_Z = jnp.where(
        jnp.eye(b, dtype=bool), 1.0 / (jnp.diag(S) + lam), jnp.zeros_like(S)
    )
    if W0 is None:
        Z0, U0 = cold_Z, jnp.zeros_like(S)
    else:
        Z0c = Theta0 if Theta0 is not None else jnp.linalg.inv(W0)
        Z0c = 0.5 * (Z0c + Z0c.T)
        usable = jnp.all(jnp.isfinite(Z0c)) & jnp.all(jnp.isfinite(W0))
        Z0 = jnp.where(usable, Z0c, cold_Z)
        U0 = jnp.where(usable, (W0 - S) / rho0, jnp.zeros_like(S))
    init = (
        Z0,
        U0,
        rho0,
        jnp.asarray(jnp.inf, dtype),
        jnp.asarray(jnp.inf, dtype),
        jnp.int32(0),
    )
    Z, U, _, _, _, it = jax.lax.while_loop(cond, body, init)
    return 0.5 * (Z + Z.T), it


def glasso_admm(
    S: jax.Array,
    lam: jax.Array,
    *,
    rho: float = 1.0,
    max_iter: int = 2000,
    tol: float = 1e-7,
    W0: jax.Array | None = None,
    Theta0: jax.Array | None = None,
) -> jax.Array:
    """Single-block solver contract ``solve(S, lam, **opts) -> Theta``."""
    Theta, _ = glasso_admm_info(
        S, lam, rho=rho, max_iter=max_iter, tol=tol, W0=W0, Theta0=Theta0
    )
    return Theta

"""ADMM for the graphical lasso [Boyd et al. 2011, Section 6.5].

    Theta-update:  rho*Theta - Theta^{-1} = rho*(Z - U) - S
                   -> eigendecompose the RHS, theta_i = (d_i + sqrt(d_i^2 + 4 rho)) / (2 rho)
    Z-update:      Z = soft(Theta + U, lam/rho)      (diagonal penalized too —
                   criterion (1) includes i = j, hence W_ii = S_ii + lam)
    U-update:      U += Theta - Z

Per-iteration cost is one (b, b) eigh — O(b^3), same class as one GLASSO
sweep.  Most robust solver on ill-conditioned blocks; the tests use it with a
tight tolerance as the cross-check oracle.  Returns Z (the sparse iterate), so
the support is exactly sparse — important for Theorem-1 pattern checks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _soft(x, t):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


@functools.partial(jax.jit, static_argnames=("max_iter",))
def glasso_admm(
    S: jax.Array,
    lam: jax.Array,
    *,
    rho: float = 1.0,
    max_iter: int = 500,
    tol: float = 1e-7,
    W0: jax.Array | None = None,  # accepted for API parity; unused
) -> jax.Array:
    b = S.shape[0]
    dtype = S.dtype
    lam = jnp.asarray(lam, dtype)
    rho = jnp.asarray(rho, dtype)
    eye = jnp.eye(b, dtype=dtype)

    def theta_update(Z, U):
        rhs = rho * (Z - U) - S
        d, Q = jnp.linalg.eigh(rhs)
        theta_d = (d + jnp.sqrt(d * d + 4.0 * rho)) / (2.0 * rho)
        return (Q * theta_d[None, :]) @ Q.T

    def body(carry):
        Z, U, _, _, it = carry
        Theta = theta_update(Z, U)
        Z_new = _soft(Theta + U, lam / rho)
        U_new = U + Theta - Z_new
        r_prim = jnp.linalg.norm(Theta - Z_new)
        r_dual = rho * jnp.linalg.norm(Z_new - Z)
        return Z_new, U_new, r_prim, r_dual, it + 1

    def cond(carry):
        _, _, r_prim, r_dual, it = carry
        eps = tol * b
        return jnp.logical_and(
            jnp.logical_or(r_prim > eps, r_dual > eps), it < max_iter
        )

    Z0 = jnp.where(jnp.eye(b, dtype=bool), 1.0 / (jnp.diag(S) + lam), jnp.zeros_like(S))
    init = (Z0, jnp.zeros_like(S), jnp.asarray(jnp.inf, dtype), jnp.asarray(jnp.inf, dtype), jnp.int32(0))
    Z, U, _, _, _ = jax.lax.while_loop(cond, body, init)
    del eye, W0
    return 0.5 * (Z + Z.T)

"""Mesh-spanning graphical lasso for oversize components.

Every other solver in this package runs one block on one device, holding the
(b, b) iterate (and, for ADMM, an O(b^3) eigh workspace) in a single HBM.
For moderate rho the paper's largest component stays near size p, so the
single-device cap on b IS the system's scale cap.  This solver removes it:

* the (b, b) iterates stay ROW-SHARDED across the mesh for the whole solve —
  no stage ever materializes a full (b, b) array on one device (matmuls are
  the ring-algorithm ``core.distributed.ring_matmul``, transposes one-shard
  ``transpose_rowsharded`` all_to_alls, spectral estimates distributed
  matvec power iterations);

* the outer loop is the SAME ADMM as the single-device oracle (Boyd 6.5,
  adaptive rho 3.4.1) — but the O(b^3) eigh of its Theta-update

      Theta = (M + sqrt(M^2 + 4 rho I)) / (2 rho),   M = rho (Z - U) - S

  is replaced by inner MATRIX ITERATIONS built from distributed matvecs:
  a warm-vector power iteration bounds ||M||_2, and a coupled Newton-Schulz
  square-root iteration (Higham 1997: Y <- Y T, Zc <- T Zc with
  T = (3 I - Zc Y) / 2 on the spectrally-scaled argument) computes the sqrt
  with ring matmuls only.  The inner tolerance is tied to the outer primal
  residual (inexact ADMM with vanishing errors), so early outer iterations
  are cheap and late ones exact.  Unlike a proximal-gradient linearization
  of the Theta-step — which stalls: the tiny trust-region step keeps the
  primal residual artificially small and drives the adaptive rho into the
  floor — this keeps the oracle's iteration count (~1x) while making every
  FLOP a shardable GEMM;

* the Z/U prox tail (soft-threshold + dual update + both residual
  reductions) is fused into one HBM pass by ``kernels/shard_prox`` (jnp
  reference off-TPU — the tree_glasso trade-off);

* the returned Z (exactly sparse, like the dense ADMM's) is KKT-verified IN
  PLACE against the sharded S: a warm-started column-wise block-CG solves
  Z W = I (the "distributed matvec/CG inner solve" proper — CG also detects
  a non-PD candidate via negative curvature and reports residual = inf),
  then eq. (11)-(12) reduce shard-locally with one pmax.  The executor
  compares the returned residual to ``route_check_tol`` and falls back to
  the single-device iterative tail on failure, so the sharded route obeys
  the same "changes cost, never the answer" contract as every PR-2 route.

Theta-update PD holds by construction (theta_i = (d_i + sqrt(d_i^2 +
4 rho)) / (2 rho) > 0), so there is no line search and no PD safeguard in
the hot loop; the only defensive state is a spectral-scale boost that
doubles when a Newton-Schulz pass fails to contract (non-finite or err
growth), reverting that outer step.

Counters:  solver.oversize.dispatched / .cg_iters (inner matrix-iteration
steps: Newton-Schulz + verification CG), plus the
``solver.oversize.device_bytes_peak`` watermark — the accounting model is
_BUFFERS_PER_DEVICE row-shards of (b_pad/d, b_pad) (DESIGN.md Section 11).
"""

from __future__ import annotations

import functools
import threading
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import (
    matvec_rowsharded,
    ring_matmul,
    transpose_rowsharded,
)
from repro.core.instrument import bump, set_peak
from repro.core.jax_compat import local_device_mesh, shard_map
from repro.kernels.shard_prox import fused_prox_residual

#: exact-sparsity zero classification for the returned Z (same as closed_form)
_ZERO_TOL = 1e-12

#: per-device resident f64 row-shards during a solve: S, the ADMM pair
#: (Z, U), the Theta-update working set (M, M^2 + 4 rho I, Y, Zc, T) and the
#: prox outputs — the memory-model constant behind the bytes watermark
_BUFFERS_PER_DEVICE = 12

_CACHE_LOCK = threading.Lock()
_COMPILED: dict[tuple, Any] = {}


def mesh_axis_size(mesh, axis: str = "data") -> int:
    return int(np.prod([mesh.shape[a] for a in ([axis] if isinstance(axis, str) else axis)]))


def sharded_pad_size(b: int, n_shards: int) -> int:
    """Padded size for the sharded solver: the smallest multiple of
    8 * n_shards >= b, so every device owns an equal, sublane-aligned row
    shard.  Identity padding is exact (Theorem-1 corollary, see blocks.py)."""
    unit = 8 * n_shards
    return max(unit, -(-b // unit) * unit)


@dataclass
class ShardedSolve:
    """One oversize solve: the dense (b, b) Theta plus the verification and
    accounting facts the executor / benchmarks consume."""

    Theta: np.ndarray
    iters: int                 # outer ADMM iterations
    inner_iters: int           # Newton-Schulz + verification-CG steps
    retries: int               # outer steps reverted by the NS safeguard
    kkt_residual: float        # distributed eq.-(11)/(12) residual of Theta
    s_max: float               # max |S| over the padded block (KKT scale)
    rho: float                 # final (adapted) ADMM penalty
    b: int
    padded: int
    n_shards: int
    device_bytes: int          # accounting-model per-device peak


def _build_sharded(
    bp: int,
    d: int,
    axis: str,
    dtype,
    max_iter: int,
    ns_max: int,
    cg_max: int,
    pow_steps: int,
    warm: bool,
    mesh,
):
    """Compile the shard_map solve for one (padded size, mesh) family."""
    rl = bp // d
    spec = P(axis, None)
    in_specs = (spec, P()) + ((spec,) if warm else ())

    def run(S_rows, scalars, *warm_args):
        lam = scalars[0]
        rho0 = scalars[1]
        tol = scalars[2]
        idx = jax.lax.axis_index(axis)
        gi = idx * rl + jnp.arange(rl)
        eye_loc = gi[:, None] == jnp.arange(bp)[None, :]
        eyef = eye_loc.astype(S_rows.dtype)
        mm = functools.partial(ring_matmul, axis=axis, n_shards=d)
        tr = functools.partial(transpose_rowsharded, axis=axis, n_shards=d)
        mv = functools.partial(matvec_rowsharded, axis=axis, n_shards=d)

        def psum(x):
            return jax.lax.psum(x, axis) if d > 1 else x

        def pmax(x):
            return jax.lax.pmax(x, axis) if d > 1 else x

        def power_norm(A_rows, v):
            """(||A||_2 estimate, refreshed vector) for symmetric A."""

            def body(_, v):
                u = mv(A_rows, v)
                return u / (jnp.linalg.norm(u) + 1e-30)

            v = jax.lax.fori_loop(0, pow_steps, body, v)
            u = mv(A_rows, v)
            return jnp.abs(v @ u), u / (jnp.linalg.norm(u) + 1e-30)

        def sqrt_ns(A_rows, c, ns_tol):
            """sqrt(A) via the coupled Newton-Schulz iteration on A / c.

            Requires spectrum(A / c) in (0, 3); the caller scales c from the
            power-iteration bound with margin.  Returns (sqrt, steps, ok)."""
            Y0 = A_rows / c
            Zc0 = eyef

            def cond(carry):
                _, _, err, prev_err, k = carry
                return (err > ns_tol) & (k < ns_max) & (err <= prev_err * 4.0)

            def body(carry):
                Y, Zc, err, _, k = carry
                T = 0.5 * (3.0 * eyef - mm(Zc, Y))
                err_new = pmax(jnp.max(jnp.abs(T - eyef)))
                return mm(Y, T), mm(T, Zc), err_new, err, k + 1

            init = (
                Y0, Zc0, jnp.asarray(jnp.inf, S_rows.dtype),
                jnp.asarray(jnp.inf, S_rows.dtype), jnp.int32(0),
            )
            Y, _, err, _, k = jax.lax.while_loop(cond, body, init)
            ok = (err <= ns_tol) & jnp.all(jnp.isfinite(Y))
            return jnp.sqrt(c) * Y, k, ok

        def cg_inverse(A_rows, W_init, cg_tol):
            """Column-wise block-CG on A W = I; returns (W, iters, neg)."""
            R = eyef - mm(A_rows, W_init)
            rs = psum(jnp.sum(R * R, axis=0))
            tol2 = cg_tol * cg_tol

            def cond(c):
                _, _, _, rs, it, neg = c
                return jnp.any(rs > tol2) & (it < cg_max) & ~neg

            def body(c):
                W, R, Pc, rs, it, neg = c
                AP = mm(A_rows, Pc)
                pAp = psum(jnp.sum(Pc * AP, axis=0))
                active = rs > tol2
                neg = neg | jnp.any(active & (pAp <= 0.0))
                alpha = jnp.where(
                    active & (pAp > 0.0), rs / jnp.where(pAp > 0.0, pAp, 1.0), 0.0
                )
                W = W + Pc * alpha[None, :]
                Rn = R - AP * alpha[None, :]
                rsn = psum(jnp.sum(Rn * Rn, axis=0))
                beta = jnp.where(active, rsn / jnp.where(rs > 0.0, rs, 1.0), 0.0)
                Pc = Rn + Pc * beta[None, :]
                return W, Rn, Pc, rsn, it + 1, neg

            W, _, _, _, it, neg = jax.lax.while_loop(
                cond, body, (W_init, R, R, rs, jnp.int32(0), jnp.bool_(False))
            )
            return W, it, neg

        kkt_rel = scalars[3]  # relative KKT target (inf = single attempt)
        diag_own = jnp.sum(jnp.where(eye_loc, S_rows, 0.0), axis=1)
        if warm:
            # At the ADMM fixed point U* = (Theta*^{-1} - S) / rho (the
            # Theta-update optimality rho Theta - Theta^{-1} = rho (Z - U) - S
            # at Theta = Z): seeding BOTH Z and U from Theta0 makes an exact
            # warm start a fixed point — Z alone leaves the dual to be
            # rebuilt from zero, which costs as many iterations as a cold
            # start.  One CG inverse buys that dual.  Same argument as the
            # dense ``glasso_admm`` W0 warm start.
            (theta0_rows,) = warm_args
            diag_t0 = jnp.sum(jnp.where(eye_loc, theta0_rows, 0.0), axis=1)
            Wt0 = jnp.where(eye_loc, (1.0 / diag_t0)[:, None], 0.0)
            Wt, _, neg0 = cg_inverse(theta0_rows, Wt0, jnp.asarray(1e-8, S_rows.dtype))
            usable = ~neg0 & jnp.all(jnp.isfinite(Wt))
            cold = jnp.where(eye_loc, (1.0 / (diag_own + lam))[:, None], 0.0)
            Z0 = jnp.where(usable, theta0_rows, cold)
            U0 = jnp.where(usable, (Wt - S_rows) / rho0, jnp.zeros_like(S_rows))
        else:
            Z0 = jnp.where(eye_loc, (1.0 / (diag_own + lam))[:, None], 0.0)
            U0 = jnp.zeros_like(S_rows)
        v0 = jnp.ones((bp,), S_rows.dtype) / jnp.sqrt(jnp.asarray(bp, S_rows.dtype))

        def admm_cond(c):
            _, _, _, _, _, rp, rd, it, _, retries, eps = c
            return ((rp > eps) | (rd > eps)) & (it < max_iter) & (retries < 30)

        def admm_body(c):
            Z, U, v, rho, boost, rp, rd, it, inner, retries, eps = c
            M = rho * (Z - U) - S_rows
            m, vn = power_norm(M, v)
            cscale = boost * (m * m + 4.0 * rho)
            ns_tol = jnp.clip(1e-3 * rp / bp, 1e-11, 1e-2)
            A = mm(M, M) + 4.0 * rho * eyef
            R_sqrt, ns_k, ns_ok = sqrt_ns(A, cscale, ns_tol)
            Theta = (M + R_sqrt) / (2.0 * rho)
            Zn, Un, rp2_l, rd2_l = fused_prox_residual(Theta, U, Z, lam / rho)
            rp_n = jnp.sqrt(psum(rp2_l))
            rd_n = rho * jnp.sqrt(psum(rd2_l))
            factor = jnp.where(
                rp_n > 10.0 * rd_n,
                jnp.asarray(2.0, S_rows.dtype),
                jnp.where(
                    rd_n > 10.0 * rp_n,
                    jnp.asarray(0.5, S_rows.dtype),
                    jnp.asarray(1.0, S_rows.dtype),
                ),
            )
            ok = ns_ok & jnp.isfinite(rp_n) & jnp.isfinite(rd_n)
            return (
                jnp.where(ok, Zn, Z),
                jnp.where(ok, Un / factor, U),
                vn,
                jnp.where(ok, rho * factor, rho),
                jnp.where(ok, boost, 2.0 * boost),
                jnp.where(ok, rp_n, rp),
                jnp.where(ok, rd_n, rd),
                it + 1,
                inner + ns_k,
                retries + jnp.where(ok, 0, 1).astype(jnp.int32),
                eps,
            )

        def kkt_of(Zf, W_warm, inner_tol):
            """Distributed eq.-(11)/(12) residual of a symmetrized iterate."""
            Wz, cg_k, neg = cg_inverse(Zf, W_warm, inner_tol)
            Wz = 0.5 * (Wz + tr(Wz))
            zero = jnp.abs(Zf) <= _ZERO_TOL
            off = ~eye_loc
            v_zero = jnp.max(
                jnp.where(
                    zero & off, jnp.maximum(jnp.abs(S_rows - Wz) - lam, 0.0), 0.0
                )
            )
            v_act = jnp.max(
                jnp.where(~zero & off, jnp.abs(Wz - S_rows - lam * jnp.sign(Zf)), 0.0)
            )
            v_diag = jnp.max(jnp.where(eye_loc, jnp.abs(Wz - S_rows - lam), 0.0))
            res = pmax(jnp.maximum(jnp.maximum(v_zero, v_act), v_diag))
            return jnp.where(neg, jnp.asarray(jnp.inf, S_rows.dtype), res), Wz, cg_k

        s_max = pmax(jnp.max(jnp.abs(S_rows)))
        kkt_target = kkt_rel * jnp.maximum(s_max, 1.0)

        # ADMM-until-verified: each attempt runs the ADMM loop to its eps,
        # then VERIFIES the KKT residual in place; a miss tightens eps 20x
        # and continues warm (same Z/U/rho — no restart).  The stopping rule
        # the caller actually cares about is the KKT acceptance, and the
        # mapping eps -> KKT residual is problem-dependent — iterating on
        # eps makes the acceptance self-fulfilling within the max_iter
        # budget instead of a post-hoc coin flip.
        def attempt_cond(c):
            st, _, res, _, att = c
            it, retries = st[7], st[9]
            # att == 0 forces the first attempt even with no KKT target
            # (kkt_target = inf, where `res > inf` is already False)
            return (
                ((res > kkt_target) | (att == 0))
                & (it < max_iter)
                & (retries < 30)
                & (att < 4)
            )

        def attempt_body(c):
            st, W_warm, _, eps, att = c
            st = jax.lax.while_loop(
                admm_cond, admm_body, st[:10] + (eps,)
            )
            (Z, U, v, rho, boost, rp, rd, it, inner, retries, _) = st
            Zf = 0.5 * (Z + tr(Z))
            res, Wz, cg_k = kkt_of(Zf, W_warm, jnp.minimum(1e-8, tol))
            st_out = (
                Zf, U, v, rho, boost, rp, rd, it, inner + cg_k, retries,
            )
            return st_out + (eps,), Wz, res, 0.05 * eps, att + 1

        W_init = jnp.where(eye_loc, (1.0 / (diag_own + lam))[:, None], 0.0)
        init_state = (
            Z0,
            U0,
            v0,
            rho0,
            jnp.asarray(1.5, S_rows.dtype),
            jnp.asarray(jnp.inf, S_rows.dtype),
            jnp.asarray(jnp.inf, S_rows.dtype),
            jnp.int32(0),
            jnp.int32(0),
            jnp.int32(0),
            tol * bp,
        )
        (st, _, res, _, _) = jax.lax.while_loop(
            attempt_cond,
            attempt_body,
            (init_state, W_init, jnp.asarray(jnp.inf, S_rows.dtype), tol * bp,
             jnp.int32(0)),
        )
        Zf, _, _, rho, _, _, _, it, inner, retries, _ = st
        stats = jnp.stack(
            [
                it.astype(S_rows.dtype),
                inner.astype(S_rows.dtype),
                res,
                s_max,
                rho,
                retries.astype(S_rows.dtype),
            ]
        )
        return Zf, stats

    return jax.jit(
        shard_map(run, mesh=mesh, in_specs=in_specs, out_specs=(spec, P()))
    )


def compiled_sharded_solver(
    bp: int,
    d: int,
    *,
    axis: str,
    dtype,
    max_iter: int,
    ns_max: int,
    cg_max: int,
    pow_steps: int,
    warm: bool,
    mesh,
):
    key = (
        bp,
        d,
        axis,
        jnp.dtype(dtype).name,
        max_iter,
        ns_max,
        cg_max,
        pow_steps,
        warm,
        id(mesh),
    )
    with _CACHE_LOCK:
        fn = _COMPILED.get(key)
        if fn is None:
            fn = _build_sharded(
                bp, d, axis, dtype, max_iter, ns_max, cg_max, pow_steps, warm,
                mesh,
            )
            _COMPILED[key] = fn
        return fn


def pad_rowsharded(S: np.ndarray, mesh, *, axis: str = "data", dtype=None):
    """Identity-pad a host (b, b) block to the sharded pad size and place it
    row-sharded on the mesh.  Dense-host convenience — the streamed oversize
    path uses ``stream.materialize.shard_gather`` instead, which never holds
    the full block on the host."""
    d = mesh_axis_size(mesh, axis)
    b = S.shape[0]
    bp = sharded_pad_size(b, d)
    np_dtype = np.dtype(jnp.dtype(dtype or S.dtype).name)
    S_pad = np.eye(bp, dtype=np_dtype)
    S_pad[:b, :b] = S
    return jax.device_put(S_pad, NamedSharding(mesh, P(axis, None)))


def glasso_sharded(
    S,
    lam: float,
    *,
    mesh=None,
    axis: str = "data",
    b: int | None = None,
    rho: float = 1.0,
    max_iter: int = 6000,
    tol: float = 1e-9,
    kkt_target: float | None = None,
    ns_max: int = 60,
    cg_max: int | None = None,
    pow_steps: int = 10,
    dtype=None,
    Theta0: np.ndarray | None = None,
) -> ShardedSolve:
    """Solve one oversize block across the mesh; see the module docstring.

    ``S`` is either a host (b, b) array (padded + sharded here) or an
    already row-sharded padded (bp, bp) jax array (then ``b`` gives the true
    block size — the shard-direct streaming gather's calling convention).
    ``Theta0`` warm-starts Z (a previous solution on the same support, e.g.
    a path step or serving session).  ``kkt_target`` is the caller's
    RELATIVE acceptance tolerance (the executor's ``route_check_tol``):
    after the ADMM loop reaches ``tol``, the in-place KKT residual is
    checked against ``kkt_target * max(1, max|S|)`` and a miss tightens the
    stopping eps 20x and continues warm (up to 4 attempts within
    ``max_iter``) — the eps -> KKT mapping is problem-dependent, so the
    solver iterates on the acceptance criterion itself rather than leaving
    it a post-hoc coin flip.  Returns a ``ShardedSolve``; ``Theta`` is the
    host (b, b) solution and ``kkt_residual`` the distributed
    eq.-(11)/(12) verification the caller compares to its acceptance
    tolerance."""
    if mesh is None:
        mesh = local_device_mesh(axis)
    d = mesh_axis_size(mesh, axis)
    if isinstance(S, jax.Array):
        bp = S.shape[0]
        if b is None:
            raise ValueError("pre-sharded S needs the true block size (b=...)")
        if bp != sharded_pad_size(b, d):
            raise ValueError(
                f"pre-sharded S is {bp}x{bp}; expected padded size "
                f"{sharded_pad_size(b, d)} for b={b} on {d} shards"
            )
        S_sh = S
        dt = jnp.dtype(S.dtype) if dtype is None else jnp.dtype(dtype)
    else:
        S = np.asarray(S)
        b = S.shape[0]
        dt = jnp.dtype(dtype or jnp.float64)
        S_sh = pad_rowsharded(S, mesh, axis=axis, dtype=dt)
        bp = S_sh.shape[0]
    if cg_max is None:
        cg_max = bp
    warm = Theta0 is not None
    fn = compiled_sharded_solver(
        bp, d, axis=axis, dtype=dt, max_iter=int(max_iter), ns_max=int(ns_max),
        cg_max=int(cg_max), pow_steps=int(pow_steps), warm=warm, mesh=mesh,
    )
    scalars = jnp.asarray(
        [lam, rho, tol, np.inf if kkt_target is None else float(kkt_target)], dt
    )
    if warm:
        T_pad = np.eye(bp, dtype=np.dtype(dt.name)) / (1.0 + float(lam))
        T_pad[:b, :b] = np.asarray(Theta0)
        theta_sh = jax.device_put(T_pad, NamedSharding(mesh, P(axis, None)))
        Z, stats = fn(S_sh, scalars, theta_sh)
    else:
        Z, stats = fn(S_sh, scalars)
    stats = np.asarray(stats)
    itemsize = jnp.dtype(dt).itemsize
    device_bytes = _BUFFERS_PER_DEVICE * (bp // d) * bp * itemsize
    bump("solver.oversize.dispatched")
    bump("solver.oversize.cg_iters", int(stats[1]))
    set_peak("solver.oversize.device_bytes_peak", device_bytes)
    Theta = np.asarray(Z)[:b, :b]
    return ShardedSolve(
        Theta=Theta,
        iters=int(stats[0]),
        inner_iters=int(stats[1]),
        retries=int(stats[5]),
        kkt_residual=float(stats[2]),
        s_max=float(stats[3]),
        rho=float(stats[4]),
        b=int(b),
        padded=int(bp),
        n_shards=int(d),
        device_bytes=int(device_bytes),
    )

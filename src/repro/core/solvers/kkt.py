"""KKT optimality check for the graphical lasso (paper eq. (11)-(12)).

    W = Theta^{-1}
    |S_ij - W_ij| <= lam            where Theta_ij  = 0          (11)
    W_ij = S_ij + lam*sign(Theta_ij) where Theta_ij != 0          (12)
    W_ii = S_ii + lam

``kkt_residual`` returns the worst violation across all three groups — the
ground-truth optimality measure the tests and the Theorem-1 property check use
(solver-independent, so it also cross-validates BCD vs PG vs ADMM).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def kkt_residual(S: jax.Array, Theta: jax.Array, lam, *, zero_tol: float = 1e-9):
    lam = jnp.asarray(lam, S.dtype)
    W = jnp.linalg.inv(Theta)
    eyeb = jnp.eye(S.shape[0], dtype=bool)
    is_zero = jnp.abs(Theta) <= zero_tol

    # (11): inactive entries
    v_zero = jnp.where(
        is_zero & ~eyeb, jnp.maximum(jnp.abs(S - W) - lam, 0.0), 0.0
    ).max()
    # (12): active entries
    v_act = jnp.where(
        ~is_zero & ~eyeb, jnp.abs(W - S - lam * jnp.sign(Theta)), 0.0
    ).max()
    # diagonal
    v_diag = jnp.abs(jnp.diag(W) - jnp.diag(S) - lam).max()
    return jnp.maximum(jnp.maximum(v_zero, v_act), v_diag)


@jax.jit
def glasso_objective(S: jax.Array, Theta: jax.Array, lam) -> jax.Array:
    """-logdet(Theta) + tr(S Theta) + lam * ||Theta||_1 (diagonal included)."""
    sign, logdet = jnp.linalg.slogdet(Theta)
    obj = -logdet + jnp.sum(S * Theta) + jnp.asarray(lam, S.dtype) * jnp.sum(jnp.abs(Theta))
    return jnp.where(sign > 0, obj, jnp.inf)

"""KKT optimality check for the graphical lasso (paper eq. (11)-(12)).

    W = Theta^{-1}
    |S_ij - W_ij| <= lam            where Theta_ij  = 0          (11)
    W_ij = S_ij + lam*sign(Theta_ij) where Theta_ij != 0          (12)
    W_ii = S_ii + lam

``kkt_residual`` returns the worst violation across all three groups — the
ground-truth optimality measure the tests and the Theorem-1 property check use
(solver-independent, so it also cross-validates BCD vs PG vs ADMM).

``kkt_residual_sparse`` is the block-sparse twin: per-component residuals
against gathered S blocks, never a global (p, p) product — verifying a
sparse-native result costs O(sum b_i^3) like the solve itself.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def kkt_residual(S: jax.Array, Theta: jax.Array, lam, *, zero_tol: float = 1e-9):
    lam = jnp.asarray(lam, S.dtype)
    W = jnp.linalg.inv(Theta)
    eyeb = jnp.eye(S.shape[0], dtype=bool)
    is_zero = jnp.abs(Theta) <= zero_tol

    # (11): inactive entries
    v_zero = jnp.where(
        is_zero & ~eyeb, jnp.maximum(jnp.abs(S - W) - lam, 0.0), 0.0
    ).max()
    # (12): active entries
    v_act = jnp.where(
        ~is_zero & ~eyeb, jnp.abs(W - S - lam * jnp.sign(Theta)), 0.0
    ).max()
    # diagonal
    v_diag = jnp.abs(jnp.diag(W) - jnp.diag(S) - lam).max()
    return jnp.maximum(jnp.maximum(v_zero, v_act), v_diag)


def kkt_residual_sparse(S, Theta, lam: float) -> float:
    """Worst KKT violation of a block-sparse result, block by block.

    ``Theta`` is a ``repro.core.sparse.SparseTheta``; ``S`` is anything the
    covariance gather protocol accepts (dense array or a materialized
    streamed covariance).  Per non-singleton component: gather S[C, C] and
    take the canonical host residual (eq. (11)-(12)); isolated vertices
    check their closed form W_ii = 1/Theta_ii = S_ii + lam exactly.

    Cross-component entries need no arithmetic AT ALL: Theorem 1's screen
    guarantees |S_ij| <= lam there, and the block-diagonal Theta gives
    W_ij = 0, so condition (11) holds by construction — which is why this
    verifier never allocates a (p, p) buffer (the ``result.bytes_peak``
    watermark records the largest per-block working set instead)."""
    import numpy as np

    from repro.core.blocks import gather_diag, gather_submatrix
    from repro.core.instrument import set_peak
    from repro.core.solvers.closed_form import kkt_residual_host

    worst = 0.0
    for c, blk in Theta.blocks():
        Sb = gather_submatrix(S, c, dtype=np.float64)
        # working set: S block, Theta block, W = inv(Theta) block
        set_peak("result.bytes_peak", int(3 * Sb.nbytes))
        worst = max(
            worst, kkt_residual_host(Sb, float(lam), np.asarray(blk))
        )
    iso = Theta.isolated
    if iso.size:
        d = np.asarray(gather_diag(S, iso), dtype=np.float64)
        vals = np.asarray(Theta.isolated_values, dtype=np.float64)
        worst = max(worst, float(np.abs(1.0 / vals - d - float(lam)).max()))
    return float(worst)


@jax.jit
def glasso_objective(S: jax.Array, Theta: jax.Array, lam) -> jax.Array:
    """-logdet(Theta) + tr(S Theta) + lam * ||Theta||_1 (diagonal included)."""
    sign, logdet = jnp.linalg.slogdet(Theta)
    obj = -logdet + jnp.sum(S * Theta) + jnp.asarray(lam, S.dtype) * jnp.sum(jnp.abs(Theta))
    return jnp.where(sign > 0, obj, jnp.inf)

"""GLASSO block coordinate descent [Friedman, Hastie, Tibshirani 2007].

Maintains W ~= Theta^{-1}.  One sweep updates every row/column j:

    beta_j = argmin_beta  1/2 beta' W11 beta - beta' s12 + lam ||beta||_1   (9)
    w12    = W11 beta_j

with the inner lasso solved by cyclic coordinate descent.  On convergence the
precision matrix is recovered column-wise:

    theta_22 = 1 / (w22 - w12' beta),    theta_12 = -beta * theta_22

KKT sanity (paper eq. (11)-(12)): W_ii = S_ii + lam exactly, and
|S_ij - W_ij| <= lam wherever Theta_ij = 0.

Node screening (paper eq. (10)): ||s12||_inf <= lam  =>  beta_j = 0.  The
paper observes this check is an immediate consequence of the block updates yet
was *missing* from GLASSO 1.4 — we make it explicit: the inner CD loop is
skipped entirely for screened columns (a lax.cond on the hot path).

Everything is expressed with masked full-matrix ops (no row/col deletion), so
the solver jits once per block size and vmaps across a bucket of same-size
components — that batching is what feeds the MXU well on TPU (DESIGN.md
Section 3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _soft(x, t):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def _lasso_cd(W, s12, lam, beta0, j, *, n_cd: int, tol) -> jax.Array:
    """Cyclic coordinate descent for (9) on column j.

    beta is a length-b vector with beta[j] pinned to 0.  Coordinate update:
        beta_k <- soft(s12_k - sum_{l != k} W_kl beta_l, lam) / W_kk
    Runs until the sweep-wise max update < tol or n_cd sweeps.
    """
    b = W.shape[0]
    kk = jnp.arange(b)

    def sweep(beta):
        def coord(k, carry):
            beta, delta = carry
            r = s12[k] - (W[k, :] @ beta - W[k, k] * beta[k])
            new = _soft(r, lam) / W[k, k]
            new = jnp.where(k == j, 0.0, new)
            delta = jnp.maximum(delta, jnp.abs(new - beta[k]))
            return beta.at[k].set(new), delta

        beta, delta = jax.lax.fori_loop(0, b, coord, (beta, jnp.zeros((), W.dtype)))
        return beta, delta

    def cond(c):
        _, delta, it = c
        return jnp.logical_and(delta > tol, it < n_cd)

    def body(c):
        beta, _, it = c
        beta, delta = sweep(beta)
        return beta, delta, it + 1

    beta0 = beta0.at[j].set(0.0)
    beta, delta = sweep(beta0)
    beta, _, _ = jax.lax.while_loop(cond, body, (beta, delta, jnp.int32(1)))
    del kk
    return beta


@functools.partial(
    jax.jit, static_argnames=("max_sweeps", "n_cd", "node_screen")
)
def glasso_bcd(
    S: jax.Array,
    lam: jax.Array,
    *,
    max_sweeps: int = 100,
    n_cd: int = 100,
    tol: float = 1e-6,
    node_screen: bool = True,
    W0: jax.Array | None = None,
    Theta0: jax.Array | None = None,
) -> jax.Array:
    """Solve the graphical lasso on one (b, b) block. Returns Theta.

    W0 warm-starts the covariance iterate (lambda-path reuse, Theorem 2);
    default is the cold start W = S + lam*I.  Theta0 additionally seeds the
    inner-lasso coefficients: column j of (9) relates to the precision column
    via theta_12 = -beta * theta_22, so beta_j = -Theta0[:, j] / Theta0[j, j]
    (diagonal pinned to 0).  Without it every column's coordinate descent —
    the dominant cost — restarts from beta = 0 no matter how good W0 is.
    """
    b = S.shape[0]
    dtype = S.dtype
    lam = jnp.asarray(lam, dtype)
    eye = jnp.eye(b, dtype=dtype)
    W_init = (S + lam * eye) if W0 is None else W0
    # Diagonal KKT is exact at the solution; enforce from the start.
    W_init = jnp.where(jnp.eye(b, dtype=bool), jnp.diag(S) + lam, W_init)
    if Theta0 is None:
        B_init = jnp.zeros((b, b), dtype)
    else:
        d = jnp.diagonal(Theta0)
        d = jnp.where(d > 0, d, jnp.ones((), dtype))  # PD => d > 0; belt+braces
        B_init = jnp.where(jnp.eye(b, dtype=bool), 0.0, -(Theta0 / d[None, :]))
    scale = jnp.mean(jnp.abs(S - jnp.diag(jnp.diag(S)))) + jnp.asarray(1e-12, dtype)

    cd_tol = jnp.asarray(tol, dtype) * scale

    def column_update(j, W, B):
        s12 = S[:, j].at[j].set(0.0)
        screened = jnp.max(jnp.abs(s12)) <= lam

        def solve_col(operand):
            W, beta0 = operand
            beta = _lasso_cd(W, s12, lam, beta0, j, n_cd=n_cd, tol=cd_tol)
            return beta

        def zero_col(operand):
            _, beta0 = operand
            return jnp.zeros_like(beta0)

        if node_screen:
            beta = jax.lax.cond(screened, zero_col, solve_col, (W, B[:, j]))
        else:
            beta = solve_col((W, B[:, j]))
        w12 = (W @ beta).at[j].set(0.0)
        W = W.at[:, j].set(w12.at[j].set(W[j, j]))
        W = W.at[j, :].set(w12.at[j].set(W[j, j]))
        return W, B.at[:, j].set(beta)

    def sweep(carry):
        W, B, _, it = carry
        W_old = W

        def body(j, wb):
            W, B = wb
            return column_update(j, W, B)

        W, B = jax.lax.fori_loop(0, b, body, (W, B))
        delta = jnp.max(jnp.abs(W - W_old))
        return W, B, delta, it + 1

    def cond(carry):
        _, _, delta, it = carry
        return jnp.logical_and(delta > tol * scale, it < max_sweeps)

    W, B, delta, _ = sweep((W_init, B_init, jnp.asarray(jnp.inf, dtype), jnp.int32(0)))
    W, B, _, _ = jax.lax.while_loop(cond, sweep, (W, B, delta, jnp.int32(1)))

    # Recover Theta column-wise from the final (W, B).
    def theta_col(j):
        beta = B[:, j]
        w12 = W[:, j].at[j].set(0.0)
        t22 = 1.0 / (W[j, j] - w12 @ beta)
        col = -beta * t22
        return col.at[j].set(t22)

    Theta = jax.vmap(theta_col, out_axes=1)(jnp.arange(b))
    return 0.5 * (Theta + Theta.T)

"""The Solver protocol: capability-tagged specs behind one registry.

Before this module the solver layer's contracts lived in three parallel
ad-hoc structures — a name->fn dict (``SOLVERS``), a warm-start name set
(``WARM_START_SOLVERS``), and special-cased closed forms — and a new solver
meant editing every consumer (executor, engine, serving) by hand.  A
``SolverSpec`` states the contract ONCE:

    fn              solve(S, lam, **opts) -> Theta for single-device specs
                    (jit/vmap-friendly: same-size blocks batch onto the MXU);
                    sharded specs take ``glasso_sharded``'s mesh-spanning
                    signature instead
    batched         the executor may vmap it over a padded bucket stack
    warm_startable  genuinely consumes a W0 covariance warm start (the
                    executor skips building W0 stacks otherwise)
    sharded         spans the device mesh; dispatched per-block down the
                    executor's oversize route, never vmapped
    iterative       eligible as the routing ladder's tail (closed forms are
                    exact only on certified structure classes, so they are
                    reachable through routes, not as user-picked solvers)

``engine.registry`` re-exports the registration surface next to the
screening-backend and route registries, so all three extension points live
in one place; ``core.solvers`` keeps the legacy ``SOLVERS`` /
``WARM_START_SOLVERS`` names as views derived from the specs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

_SPECS: dict[str, "SolverSpec"] = {}


@dataclass(frozen=True)
class SolverSpec:
    """One solver's contract; see the module docstring for the fields."""

    name: str
    fn: Callable
    batched: bool = True
    warm_startable: bool = False
    sharded: bool = False
    iterative: bool = True
    description: str = ""
    # extra per-solver facts (e.g. which kwarg carries the warm start)
    meta: dict = field(default_factory=dict)


def register_solver(spec: SolverSpec) -> SolverSpec:
    """Register (or replace) a solver spec; returns it for chaining."""
    if spec.sharded and spec.batched:
        raise ValueError(
            f"solver {spec.name!r}: sharded solvers span the mesh and cannot "
            "also be vmapped over a bucket stack (batched=True)"
        )
    _SPECS[spec.name] = spec
    return spec


def solver_spec(name: str) -> SolverSpec:
    try:
        return _SPECS[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; available: {available_solvers()}"
        ) from None


def available_solvers(**caps: bool) -> tuple[str, ...]:
    """Registered solver names, optionally filtered by capability flags,
    e.g. ``available_solvers(batched=True, warm_startable=True)``."""
    names = []
    for name, spec in sorted(_SPECS.items()):
        if all(getattr(spec, cap) == want for cap, want in caps.items()):
            names.append(name)
    return tuple(names)


def block_solvers() -> dict[str, Callable]:
    """name -> fn for the user-pickable single-device block solvers (the
    legacy ``SOLVERS`` view: batched, iterative, not sharded)."""
    return {
        name: spec.fn
        for name, spec in sorted(_SPECS.items())
        if spec.batched and spec.iterative and not spec.sharded
    }


def warm_start_solvers() -> frozenset[str]:
    """The legacy ``WARM_START_SOLVERS`` view."""
    return frozenset(n for n, s in _SPECS.items() if s.warm_startable)

"""Graphical-lasso block solvers behind the Solver protocol.

The paper is solver-agnostic (its contribution wraps *any* solver); every
solver here is registered as a capability-tagged ``SolverSpec``
(``protocol.py``, re-exported through ``engine.registry``) and the executor
consults the spec — batched? warm-startable? sharded? — instead of
hard-coded name sets.  The single-device contract is
``solve(S, lam, **opts) -> Theta`` on a (b, b) block, jit- and vmap-friendly
so same-size component buckets batch onto the MXU:

``bcd``      GLASSO block coordinate descent [Friedman et al. 2007] — the
             paper-faithful baseline.  Row/column sweeps with an inner cyclic
             coordinate-descent lasso; includes the eq.-(10) node-screening
             check the paper points out GLASSO 1.4 was missing.  Consumes a
             W0 covariance warm start plus a Theta0 seed for the inner-lasso
             coefficients (path reuse: beta_j = -Theta0[:, j] / Theta0[j, j]).
``pg``       G-ISTA-style proximal gradient — the first-order stand-in for
             SMACS [Lu 2010] (same O(p^3)-per-iteration complexity class;
             DESIGN.md Section 3 records the adaptation).  Warm-starts from
             Theta0, not W0.
``admm``     ADMM [Boyd et al. 2011] — eigh-based, the most robust on
             ill-conditioned blocks; the cross-check oracle in tests.
             Consumes W0: Z0 = W0^{-1}, U0 = (W0 - S)/rho (see admm.py).
``sharded``  mesh-spanning ADMM for OVERSIZE blocks (``sharded.py``): the
             (b, b) iterate stays row-sharded, the eigh is replaced by
             matmul-only Newton-Schulz + CG inner iterations.  Different
             calling convention (mesh kwargs, ShardedSolve result) — reached
             through the executor's "sharded" route, never vmapped.
"""

from collections.abc import Mapping as _Mapping, Set as _Set

from repro.core.solvers.admm import glasso_admm, glasso_admm_info
from repro.core.solvers.bcd import glasso_bcd
from repro.core.solvers.closed_form import (
    glasso_chordal_host,
    glasso_forest,
    glasso_forest_stack,
)
from repro.core.solvers.kkt import kkt_residual
from repro.core.solvers.pg import glasso_pg
from repro.core.solvers.protocol import (
    SolverSpec,
    available_solvers,
    block_solvers,
    register_solver,
    solver_spec,
    warm_start_solvers,
)
from repro.core.solvers.sharded import ShardedSolve, glasso_sharded

register_solver(
    SolverSpec(
        name="bcd",
        fn=glasso_bcd,
        batched=True,
        warm_startable=True,
        description="GLASSO block coordinate descent (paper baseline)",
        # consumes the Theta-side seed alongside W0: Theta0 seeds the inner
        # lasso coefficients (B), which is where the sweep time actually goes.
        # fused_stack: kernels.bucket_glasso replays this solver's exact
        # arithmetic over a packed megabatch, so the executor's wave packer
        # may fuse its small buckets (DESIGN.md Section 16)
        meta={"theta_warm": True, "fused_stack": True},
    )
)
register_solver(
    SolverSpec(
        name="fused_bcd",
        fn=glasso_bcd,
        batched=True,
        warm_startable=True,
        description="bcd with the wave packer forced on: small iterative "
                    "buckets fuse into one bucket_glasso launch per bin per "
                    "wave; oversize-bin blocks dispatch as plain bcd",
        # force_fused: picking this solver opts the executor into fusion even
        # under EngineOptions(fused="auto"); identical bits to "bcd" —
        # max_fused_size is the largest bin the packer may pad into
        meta={
            "theta_warm": True,
            "fused_stack": True,
            "force_fused": True,
            "max_fused_size": 64,
        },
    )
)
register_solver(
    SolverSpec(
        name="pg",
        fn=glasso_pg,
        batched=True,
        warm_startable=False,  # accepts W0 for parity; warm-starts via Theta0
        description="G-ISTA proximal gradient (SMACS stand-in)",
    )
)
register_solver(
    SolverSpec(
        name="admm",
        fn=glasso_admm,
        batched=True,
        warm_startable=True,
        description="ADMM (eigh Theta-update); the test oracle",
        # consumes the Theta-side seed alongside W0: callers holding the
        # Theta iterate (repairs, path reuse) skip admm's inv(W0)
        meta={"theta_warm": True},
    )
)
register_solver(
    SolverSpec(
        name="sharded",
        fn=glasso_sharded,
        batched=False,
        warm_startable=True,
        sharded=True,
        description="mesh-spanning ADMM for oversize blocks (no eigh)",
        meta={"warm_kwarg": "Theta0"},
    )
)

class _BlockSolversView(_Mapping):
    """LIVE name -> fn view of the registry's user-pickable block solvers.

    A plain ``dict`` snapshot taken at import time would make
    ``register_solver`` a dead extension point — a solver registered later
    would never be visible to the executor/serving admission checks that
    consult ``SOLVERS``.  This view re-derives from the specs on every
    access, so registration works at any time."""

    def __getitem__(self, name):
        return block_solvers()[name]

    def __iter__(self):
        return iter(block_solvers())

    def __len__(self):
        return len(block_solvers())


class _WarmStartView(_Set):
    """LIVE view of batched solvers that genuinely consume a W0 warm start
    (same rationale as ``_BlockSolversView``; the sharded solver's Theta0
    warm start rides its own dispatch path)."""

    def _names(self):
        return available_solvers(batched=True, warm_startable=True)

    def __contains__(self, name):
        return name in self._names()

    def __iter__(self):
        return iter(self._names())

    def __len__(self):
        return len(self._names())


#: user-pickable single-device block solvers (live registry view)
SOLVERS = _BlockSolversView()

# Closed-form direct solvers are NOT in SOLVERS: they are exact only on the
# structure classes the planner certifies, so they are reachable through the
# routing ladder (engine.registry.route_for), never as a user-picked solver
# for arbitrary blocks.
CLOSED_FORM_SOLVERS = {
    "forest": glasso_forest,
    "chordal": glasso_chordal_host,
}

#: batched solvers whose W0 covariance warm start is genuinely consumed
#: (live view; the engine skips building W0 stacks for the others)
WARM_START_SOLVERS = _WarmStartView()

__all__ = [
    "glasso_bcd",
    "glasso_pg",
    "glasso_admm",
    "glasso_admm_info",
    "glasso_forest",
    "glasso_forest_stack",
    "glasso_chordal_host",
    "glasso_sharded",
    "ShardedSolve",
    "kkt_residual",
    "SolverSpec",
    "register_solver",
    "solver_spec",
    "available_solvers",
    "block_solvers",
    "warm_start_solvers",
    "SOLVERS",
    "CLOSED_FORM_SOLVERS",
    "WARM_START_SOLVERS",
]

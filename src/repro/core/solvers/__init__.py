"""Graphical-lasso block solvers.

The paper is solver-agnostic (its contribution wraps *any* solver); we ship
three with one contract — ``solve(S, lam, **opts) -> Theta`` on a (b, b)
block, jit- and vmap-friendly so same-size component buckets batch onto the
MXU:

``bcd``   GLASSO block coordinate descent [Friedman et al. 2007] — the
          paper-faithful baseline.  Row/column sweeps with an inner cyclic
          coordinate-descent lasso; includes the eq.-(10) node-screening check
          the paper points out GLASSO 1.4 was missing.
``pg``    G-ISTA-style proximal gradient — the first-order stand-in for SMACS
          [Lu 2010] (same O(p^3)-per-iteration complexity class; DESIGN.md
          Section 3 records the adaptation).
``admm``  ADMM [Boyd et al. 2011] — eigh-based, the most robust on
          ill-conditioned blocks; used as the cross-check oracle in tests.
"""

from repro.core.solvers.admm import glasso_admm
from repro.core.solvers.bcd import glasso_bcd
from repro.core.solvers.closed_form import (
    glasso_chordal_host,
    glasso_forest,
    glasso_forest_stack,
)
from repro.core.solvers.kkt import kkt_residual
from repro.core.solvers.pg import glasso_pg

SOLVERS = {
    "bcd": glasso_bcd,
    "pg": glasso_pg,
    "admm": glasso_admm,
}

# Closed-form direct solvers are NOT in SOLVERS: they are exact only on the
# structure classes the planner certifies, so they are reachable through the
# routing ladder (engine.registry.route_for), never as a user-picked solver
# for arbitrary blocks.
CLOSED_FORM_SOLVERS = {
    "forest": glasso_forest,
    "chordal": glasso_chordal_host,
}

# solvers that actually consume a W0 covariance warm start (pg/admm accept
# the kwarg for API parity but discard it — the engine skips building W0
# stacks for them entirely)
WARM_START_SOLVERS = frozenset({"bcd"})

__all__ = [
    "glasso_bcd",
    "glasso_pg",
    "glasso_admm",
    "glasso_forest",
    "glasso_forest_stack",
    "glasso_chordal_host",
    "kkt_residual",
    "SOLVERS",
    "CLOSED_FORM_SOLVERS",
    "WARM_START_SOLVERS",
]

"""G-ISTA-style proximal gradient for the graphical lasso.

First-order stand-in for SMACS [Lu 2010] (same O(b^3)-per-iteration class —
one Cholesky + solve per step; DESIGN.md Section 3 records why the MATLAB
SMACS line search was adapted rather than ported).

    grad f(Theta) = S - Theta^{-1}
    Theta+ = soft(Theta - t * grad, t * lam)        (diagonal penalized too)

with backtracking on t: accept when Theta+ is PD (Cholesky succeeds) and the
quadratic upper bound holds.  Step is re-warmed to eigmin(Theta)^2 via the
Cholesky of the accepted iterate (G-ISTA's safe step).  The batched prox is
the op mirrored by the ``prox_logdet`` Pallas kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _soft(x, t):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def _chol_logdet_inv(Theta):
    """(is_pd, logdet, Theta^{-1}) via one Cholesky."""
    L = jnp.linalg.cholesky(Theta)
    ok = jnp.all(jnp.isfinite(L))
    Ls = jnp.where(ok, L, jnp.eye(Theta.shape[0], dtype=Theta.dtype))
    logdet = 2.0 * jnp.sum(jnp.log(jnp.clip(jnp.diag(Ls), 1e-30, None)))
    inv = jax.scipy.linalg.cho_solve((Ls, True), jnp.eye(Theta.shape[0], dtype=Theta.dtype))
    return ok, logdet, inv


@functools.partial(jax.jit, static_argnames=("max_iter", "ls_iter"))
def glasso_pg(
    S: jax.Array,
    lam: jax.Array,
    *,
    max_iter: int = 1000,
    ls_iter: int = 30,
    tol: float = 1e-7,
    W0: jax.Array | None = None,  # API parity; PG warm-starts from Theta0
    Theta0: jax.Array | None = None,
) -> jax.Array:
    b = S.shape[0]
    dtype = S.dtype
    lam = jnp.asarray(lam, dtype)
    eyeb = jnp.eye(b, dtype=bool)

    if Theta0 is None:
        Theta = jnp.where(eyeb, 1.0 / (jnp.diag(S) + lam), jnp.zeros_like(S))
    else:
        Theta = Theta0

    def f_val(logdet, Theta):
        return -logdet + jnp.sum(S * Theta)

    def step(carry):
        Theta, t, _, it = carry
        ok, logdet, inv = _chol_logdet_inv(Theta)
        grad = S - inv
        fcur = f_val(logdet, Theta)

        def ls_body(c):
            t, _, _, k = c
            cand = _soft(Theta - t * grad, t * lam)
            okc, logdetc, _ = _chol_logdet_inv(cand)
            diff = cand - Theta
            quad = fcur + jnp.sum(grad * diff) + jnp.sum(diff * diff) / (2.0 * t)
            good = jnp.logical_and(okc, f_val(logdetc, cand) <= quad + 1e-12)
            return t * 0.5, cand, good, k + 1

        def ls_cond(c):
            t, _, good, k = c
            return jnp.logical_and(~good, k < ls_iter)

        t0 = t
        tl, cand, good, _ = jax.lax.while_loop(
            ls_cond, ls_body, ls_body((t0 * 2.0, Theta, False, jnp.int32(-1)))
        )
        new = jnp.where(good, cand, Theta)
        delta = jnp.max(jnp.abs(new - Theta))
        # G-ISTA safe step for the next iterate: eigmin(Theta+)^2 ~ kept via
        # doubling the accepted step (cheap Barzilai-style re-warm).
        return new, jnp.clip(tl * 4.0, 1e-12, 1e6), delta, it + 1

    def cond(carry):
        _, _, delta, it = carry
        return jnp.logical_and(delta > tol, it < max_iter)

    t_init = jnp.asarray(1.0, dtype) / (jnp.linalg.norm(S) + 1.0)
    Theta, _, _, _ = jax.lax.while_loop(
        cond, step, (Theta, t_init, jnp.asarray(jnp.inf, dtype), jnp.int32(0))
    )
    del W0
    return 0.5 * (Theta + Theta.T)

"""Public graphical-lasso API: screening wrapper + lambda-path driver.

``glasso(S, lam)``        solve (1) — with exact covariance-thresholding
                          screening (Theorem 1) on by default, or screen=False
                          for the paper's "without screening" baseline column.
``glasso_path(S, lams)``  descending-lambda path exploiting Theorem 2:
                          components only merge as lambda decreases, so each
                          block is warm-started from the block-diagonal of the
                          previous solution restricted to its vertices.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import blocks as blocks_mod
from repro.core import schedule as schedule_mod
from repro.core.screening import ScreenStats, thresholded_components
from repro.core.solvers import SOLVERS


@dataclass
class GlassoResult:
    lam: float
    Theta: np.ndarray
    labels: np.ndarray
    screen: ScreenStats | None
    solve_seconds: float
    solver: str
    block_sizes: list[int] = field(default_factory=list)

    @property
    def support(self) -> np.ndarray:
        """Estimated concentration-graph adjacency (eq. (2))."""
        A = np.abs(self.Theta) > 0
        np.fill_diagonal(A, False)
        return A


def _solve_plan(
    S, plan: blocks_mod.Plan, lam, solver_fn, dtype, warm_W: np.ndarray | None, solver_opts
) -> np.ndarray:
    sols = []
    for bucket in plan.buckets:
        stacked = jnp.asarray(bucket.blocks, dtype)
        opts = dict(solver_opts)
        if warm_W is not None:
            W0 = np.stack(
                [
                    blocks_mod.pad_block(
                        warm_W[np.ix_(c, c)].astype(np.asarray(bucket.blocks).dtype),
                        bucket.size,
                    )
                    for c in bucket.comps
                ]
            )
            # pad_block puts 1.0 on padded diagonal; W padding wants 1 + lam.
            for k, c in enumerate(bucket.comps):
                b = len(c)
                idx = np.arange(b, bucket.size)
                W0[k, idx, idx] = 1.0 + lam
            opts["W0"] = jnp.asarray(W0, dtype)
        out = blocks_mod.solve_bucket(stacked, float(lam), solver_fn, **opts)
        sols.append(np.asarray(out))
    return blocks_mod.assemble_dense(plan, sols, S)


def glasso(
    S: np.ndarray,
    lam: float,
    *,
    solver: str = "bcd",
    screen: bool = True,
    p_max: int | None = None,
    dtype=jnp.float64,
    cc_backend: str = "host",
    warm_W: np.ndarray | None = None,
    **solver_opts,
) -> GlassoResult:
    S = np.asarray(S)
    p = S.shape[0]
    solver_fn = SOLVERS[solver]

    screen_stats = None
    if screen:
        labels, screen_stats = thresholded_components(S, lam, backend=cc_backend)
    else:
        labels = np.zeros(p, dtype=np.int64)  # one global component

    plan = blocks_mod.build_plan(S, lam, labels)
    schedule_mod.check_capacity(
        [len(c) for b in plan.buckets for c in b.comps] or [1], p_max
    )

    t0 = time.perf_counter()
    Theta = _solve_plan(S, plan, lam, solver_fn, dtype, warm_W, solver_opts)
    solve_seconds = time.perf_counter() - t0

    return GlassoResult(
        lam=float(lam),
        Theta=Theta,
        labels=labels,
        screen=screen_stats,
        solve_seconds=solve_seconds,
        solver=solver,
        block_sizes=sorted(
            (len(c) for b in plan.buckets for c in b.comps), reverse=True
        ),
    )


def glasso_path(
    S: np.ndarray,
    lambdas,
    *,
    solver: str = "bcd",
    warm_start: bool = True,
    dtype=jnp.float64,
    **solver_opts,
) -> list[GlassoResult]:
    """Solve along a descending lambda path.

    Theorem 2 guarantees the vertex partitions are nested (components only
    merge), so the previous Theta/W restricted to a new component's vertices
    is block-diagonal over its old sub-components — a valid PD warm start.
    """
    lambdas = sorted((float(l) for l in np.asarray(lambdas).ravel()), reverse=True)
    results: list[GlassoResult] = []
    warm_W = None
    for lam in lambdas:
        res = glasso(S, lam, solver=solver, dtype=dtype, warm_W=warm_W, **solver_opts)
        results.append(res)
        if warm_start:
            # W = Theta^{-1} blockwise; store densely for the next lambda.
            warm_W = np.zeros_like(res.Theta)
            from repro.core.components import component_lists

            for comp in component_lists(res.labels):
                blk = res.Theta[np.ix_(comp, comp)]
                warm_W[np.ix_(comp, comp)] = np.linalg.inv(blk)
    return results

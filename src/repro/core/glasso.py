"""Public graphical-lasso API: thin wrappers over the Plan->Execute engine.

``glasso(S, lam)``        solve (1) — with exact covariance-thresholding
                          screening (Theorem 1) on by default, or screen=False
                          for the paper's "without screening" baseline column.
``glasso_path(S, lams)``  descending-lambda path exploiting Theorem 2: the
                          engine plans the whole grid from ONE union-find pass,
                          diffs consecutive plans so unchanged buckets skip
                          re-padding, and warm-starts every block from the
                          previous solution.

ENGINE CONFIGURATION travels as one typed value: ``options=EngineOptions(
solver=..., route=..., output=..., tol=...)`` (``repro.engine.EngineOptions``).
The historical kwarg spelling — ``glasso(S, lam, route=False, tol=1e-9)`` —
still works through a deprecation layer (one normalization chokepoint,
``engine.options.normalize_options``) and raises a ``DeprecationWarning``;
per-call arguments (``screen``, ``p_max``, ``warm_W``, ``warm_start``,
``stream``) are not engine configuration and are not deprecated.

The engine itself (``repro.engine``) is the extension surface: new screening
backends register with ``@register_cc_backend``; the executor's compiled
solver cache is shared process-wide (lambda paths, benchmarks, and the
``launch/serve_glasso.py`` endpoint all reuse the same executables).
"""

from __future__ import annotations

import numpy as np

from repro.core.screening import ScreenStats  # noqa: F401  (re-export, API compat)
from repro.engine.api import Engine, GlassoResult
from repro.engine.options import EngineOptions, normalize_options

__all__ = ["GlassoResult", "EngineOptions", "glasso", "glasso_path"]


def glasso(
    S: np.ndarray | None = None,
    lam: float | None = None,
    *,
    X: np.ndarray | None = None,
    from_data: bool = False,
    stream=None,
    screen: bool = True,
    p_max: int | None = None,
    warm_W: np.ndarray | None = None,
    options: EngineOptions | None = None,
    **engine_kwargs,
) -> GlassoResult:
    """``options.route=False`` disables the structure-routed solver ladder
    (every block takes the iterative solver — the pre-router baseline; used
    by the equivalence gates and the route-mix benchmark).

    ``options.oversize_threshold`` (block-size cap) or
    ``options.oversize_budget_mb`` (per-device memory budget; ``"auto"`` asks
    the backend) enable the SHARDED route: components too large for one
    device solve across the whole mesh (row-sharded iterate, no eigh —
    DESIGN.md Section 11), with ``GlassoResult.oversize`` counting
    dispatches/inner iterations/fallbacks.

    ``glasso(X=X, lam=lam, from_data=True)`` solves from the (n, p) DATA
    matrix instead of a covariance: screening runs out-of-core through
    ``repro.stream`` (the dense (p, p) S is never materialized — only the
    per-component blocks the solvers consume), exactness unchanged; an
    oversize component then streams from X STRAIGHT into device shards.
    ``stream`` passes a ``repro.stream.StreamConfig`` (or kwargs dict) for
    this call, overriding ``options.stream``; ``screen``/``cc_backend`` do
    not apply on this path (the streamed screen IS the screening stage).

    ``options.output`` picks the result representation: "dense" is the
    historical (p, p) array, "sparse" returns a
    ``repro.core.sparse.SparseTheta`` assembled with zero (p, p) allocation,
    and "auto" (default) switches to sparse above ``AUTO_SPARSE_P`` — see
    DESIGN.md Section 13."""
    opts = normalize_options(options, engine_kwargs, warn=True, context="glasso")
    engine = Engine(options=opts)
    data = X if X is not None else (S if from_data else None)
    if from_data or X is not None:
        if data is None:
            raise ValueError("from_data=True needs the data matrix (X=...)")
        if X is not None and S is not None:
            raise ValueError("pass either S or X=, not both")
        if lam is None:
            raise ValueError("glasso needs lam")
        return engine.run_from_data(
            data, lam, stream=stream, p_max=p_max, warm_W=warm_W
        )
    if S is None or lam is None:
        raise ValueError("glasso needs (S, lam) — or X=/from_data=True")
    return engine.run(S, lam, screen=screen, p_max=p_max, warm_W=warm_W)


def glasso_path(
    S: np.ndarray | None = None,
    lambdas=None,
    *,
    X: np.ndarray | None = None,
    from_data: bool = False,
    stream=None,
    warm_start: bool = True,
    screen: bool = True,
    p_max: int | None = None,
    options: EngineOptions | None = None,
    **engine_kwargs,
) -> list[GlassoResult]:
    """Solve along a descending lambda path (one planning pass, warm starts).

    Theorem 2 guarantees the vertex partitions are nested (components only
    merge), so the previous Theta/W restricted to a new component's vertices
    is block-diagonal over its old sub-components — a valid PD warm start.
    ``options.cc_backend`` is accepted for API symmetry with ``glasso``; path
    planning always uses the host edge-sorted union-find (it IS the
    incremental planner), which produces the identical partition.
    ``screen=False`` is the paper's unscreened baseline column: no planner,
    one dense solve per lambda.

    ``glasso_path(X=X, lambdas=lams, from_data=True)`` plans the whole grid
    from the data matrix via the out-of-core streaming screener: ONE tiled
    pass over X (edges above the grid minimum determine every partition,
    Theorem 2), materialized per-component blocks, the same diffed plans and
    warm starts — and never a (p, p) allocation in the screening stage.
    """
    opts = normalize_options(
        options, engine_kwargs, warn=True, context="glasso_path"
    )
    engine = Engine(options=opts)
    data = X if X is not None else (S if from_data else None)
    if from_data or X is not None:
        if data is None:
            raise ValueError("from_data=True needs the data matrix (X=...)")
        if X is not None and S is not None:
            raise ValueError("pass either S or X=, not both")
        if lambdas is None:
            raise ValueError("glasso_path needs lambdas")
        return engine.run_path_from_data(
            data, lambdas, stream=stream, warm_start=warm_start, p_max=p_max
        )
    if S is None or lambdas is None:
        raise ValueError("glasso_path needs (S, lambdas) — or X=/from_data=True")
    if not screen:
        from repro.select.grid import normalize_lambda_grid  # lazy: avoid cycle

        lams = normalize_lambda_grid(lambdas)
        return [engine.run(S, lam, screen=False, p_max=p_max) for lam in lams]
    return engine.run_path(S, lambdas, warm_start=warm_start, p_max=p_max)

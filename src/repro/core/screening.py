"""The paper's screening rule as a standalone, solver-agnostic layer.

``thresholded_components(S, lam)`` is the entire Theorem-1 wrapper interface:
threshold |S| at lambda (strict, off-diagonal — eq. (4)), take connected
components, and the returned vertex partition is *exactly* the partition of
the glasso solution's concentration graph.  Everything downstream (bucketing,
scheduling, solving) consumes only this partition.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


@dataclass
class ScreenStats:
    lam: float
    n_components: int
    max_comp: int
    n_isolated: int
    n_edges: int
    seconds: float      # the paper's "graph partition" column


def thresholded_components(
    S: np.ndarray, lam: float, *, backend: str = "host"
) -> tuple[np.ndarray, ScreenStats]:
    """Labels of the thresholded sample covariance graph + timing stats.

    backend="host"  numpy union-find (orchestration path)
    backend="jax"   min-label-propagation on device (used by the distributed
                    path; identical partition, property-tested)
    """
    t0 = time.perf_counter()
    if backend == "host":
        from repro.core.components import components_from_covariance_host

        labels = components_from_covariance_host(S, lam)
    elif backend == "jax":
        import jax.numpy as jnp

        from repro.core.components import canonicalize_labels, connected_components_labelprop

        labels = canonicalize_labels(
            np.asarray(connected_components_labelprop(jnp.asarray(S), lam))
        )
    else:
        raise ValueError(f"unknown backend {backend!r}")
    dt = time.perf_counter() - t0

    Sd = np.asarray(S)
    p = Sd.shape[0]
    off = ~np.eye(p, dtype=bool)
    n_edges = int((np.abs(Sd)[off] > lam).sum() // 2)
    _, counts = np.unique(labels, return_counts=True)
    stats = ScreenStats(
        lam=float(lam),
        n_components=int(counts.size),
        max_comp=int(counts.max()),
        n_isolated=int((counts == 1).sum()),
        n_edges=n_edges,
        seconds=dt,
    )
    return labels, stats

"""The paper's screening rule as a standalone, solver-agnostic layer.

``thresholded_components(S, lam)`` is the entire Theorem-1 wrapper interface:
threshold |S| at lambda (strict, off-diagonal — eq. (4)), take connected
components, and the returned vertex partition is *exactly* the partition of
the glasso solution's concentration graph.  Everything downstream (bucketing,
scheduling, solving) consumes only this partition.

The streaming screener (``repro.stream``) produces the same ScreenStats from
X without a dense S; its extra counters (tiles scheduled/skipped, edges
emitted, peak bytes) ride along in the optional stream fields.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


@dataclass
class ScreenStats:
    lam: float
    n_components: int
    max_comp: int
    n_isolated: int
    n_edges: int
    seconds: float      # the paper's "graph partition" column
    # streaming-screener provenance (zero for dense screens):
    tiles_total: int = 0     # upper-triangular tile pairs in the schedule
    tiles_skipped: int = 0   # pairs the Cauchy-Schwarz bound pruned
    edges_emitted: int = 0   # compacted edges streamed (|S_ij| > grid min)
    bytes_peak: int = 0      # screening-stage high-watermark (bytes)


def thresholded_components(
    S: np.ndarray, lam: float, *, backend: str = "host", **backend_opts
) -> tuple[np.ndarray, ScreenStats]:
    """Labels of the thresholded sample covariance graph + timing stats.

    ``backend`` names any registered engine screening backend
    (``repro.engine.registry``); the four built-ins are

    backend="host"       numpy union-find (orchestration path)
    backend="jax"        min-label-propagation on device
    backend="pallas"     fused threshold+hook TPU kernel (interpret off-TPU)
    backend="shard_map"  row-sharded label propagation over the local mesh

    All produce the identical canonical partition (property-tested, including
    ties |S_ij| == lambda — strict inequality, eq. (4)).
    """
    from repro.engine.registry import label_components  # lazy: import cycle

    t0 = time.perf_counter()
    labels = label_components(S, lam, backend=backend, **backend_opts)
    dt = time.perf_counter() - t0
    return labels, screen_stats_from_labels(S, lam, labels, seconds=dt)


def count_edges(S: np.ndarray, lam: float, *, row_chunk: int = 2048) -> int:
    """Strict upper-triangle edge count of |S| > lam, chunked over row
    blocks so the only temporaries are (row_chunk, p) — no dense p x p
    boolean mask, no p^2 fancy-index copy (the orchestration host runs this
    at the same p the screening backends stream)."""
    if hasattr(S, "gather_block"):
        raise TypeError(
            "count_edges needs a dense S; streamed covariances carry their "
            "edge counts (pass n_edges= to screen_stats_from_labels)"
        )
    Sd = np.asarray(S)
    p = Sd.shape[0]
    cols = np.arange(p)
    n_edges = 0
    for r0 in range(0, p, row_chunk):
        blk = Sd[r0 : r0 + row_chunk]
        upper = cols[None, :] > np.arange(r0, r0 + blk.shape[0])[:, None]
        n_edges += int(((np.abs(blk) > lam) & upper).sum())
    return n_edges


def screen_stats_from_labels(
    S: np.ndarray,
    lam: float,
    labels: np.ndarray,
    *,
    seconds: float,
    n_edges: int | None = None,
) -> ScreenStats:
    """``n_edges``, when the caller already knows it (streamed edge counts,
    the planner's sorted-edge searchsorted), skips touching S entirely —
    required for materialized (block-only) covariances, cheaper everywhere."""
    if n_edges is None:
        n_edges = count_edges(S, lam)
    _, counts = np.unique(labels, return_counts=True)
    return ScreenStats(
        lam=float(lam),
        n_components=int(counts.size),
        max_comp=int(counts.max()),
        n_isolated=int((counts == 1).sum()),
        n_edges=int(n_edges),
        seconds=seconds,
    )

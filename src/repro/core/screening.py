"""The paper's screening rule as a standalone, solver-agnostic layer.

``thresholded_components(S, lam)`` is the entire Theorem-1 wrapper interface:
threshold |S| at lambda (strict, off-diagonal — eq. (4)), take connected
components, and the returned vertex partition is *exactly* the partition of
the glasso solution's concentration graph.  Everything downstream (bucketing,
scheduling, solving) consumes only this partition.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


@dataclass
class ScreenStats:
    lam: float
    n_components: int
    max_comp: int
    n_isolated: int
    n_edges: int
    seconds: float      # the paper's "graph partition" column


def thresholded_components(
    S: np.ndarray, lam: float, *, backend: str = "host", **backend_opts
) -> tuple[np.ndarray, ScreenStats]:
    """Labels of the thresholded sample covariance graph + timing stats.

    ``backend`` names any registered engine screening backend
    (``repro.engine.registry``); the four built-ins are

    backend="host"       numpy union-find (orchestration path)
    backend="jax"        min-label-propagation on device
    backend="pallas"     fused threshold+hook TPU kernel (interpret off-TPU)
    backend="shard_map"  row-sharded label propagation over the local mesh

    All produce the identical canonical partition (property-tested, including
    ties |S_ij| == lambda — strict inequality, eq. (4)).
    """
    from repro.engine.registry import label_components  # lazy: import cycle

    t0 = time.perf_counter()
    labels = label_components(S, lam, backend=backend, **backend_opts)
    dt = time.perf_counter() - t0
    return labels, screen_stats_from_labels(S, lam, labels, seconds=dt)


def screen_stats_from_labels(
    S: np.ndarray, lam: float, labels: np.ndarray, *, seconds: float
) -> ScreenStats:
    Sd = np.asarray(S)
    p = Sd.shape[0]
    off = ~np.eye(p, dtype=bool)
    n_edges = int((np.abs(Sd)[off] > lam).sum() // 2)
    _, counts = np.unique(labels, return_counts=True)
    return ScreenStats(
        lam=float(lam),
        n_components=int(counts.size),
        max_comp=int(counts.max()),
        n_isolated=int((counts == 1).sum()),
        n_edges=n_edges,
        seconds=seconds,
    )

"""Train state: f32 master params + optimizer state + step counter."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    step: jax.Array
    params: dict
    opt_state: dict


def init_state(model, optimizer: Optimizer, key) -> tuple[TrainState, dict]:
    params, specs = model.init(key)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return (
        TrainState(
            step=jnp.zeros((), jnp.int32),
            params=master,
            opt_state=optimizer.init(master),
        ),
        specs,
    )


def state_specs(specs: dict, optimizer_name: str = "adamw") -> dict:
    """Logical-axis specs for the whole TrainState (mirrors params for m/v)."""
    if optimizer_name == "adamw":
        opt = {"m": specs, "v": specs}
    else:  # adafactor factored dims handled leaf-wise at resolve time
        opt = {"m": specs, "v": specs}
    return {"step": (), "params": specs, "opt_state": opt}

"""The jit-compiled step functions the launcher and the dry-run lower.

train_step: gradient-accumulation scan over microbatches (bf16 compute, f32
grad accumulators), remat policy on the layer scan, then one optimizer
update on the f32 master params.  Activation sharding constraints are
applied at the microbatch boundary; everything else is left to SPMD
propagation from the param/batch shardings.

serve steps: prefill and decode_step wrappers with donated caches (decode
updates its KV cache in place — no per-token cache copy)."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.train.state import TrainState

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def cast_params(params, dtype):
    return jax.tree.map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params,
    )


def make_train_step(
    model,
    optimizer,
    *,
    microbatches: int = 1,
    remat: str = "full",
    sharding_policy=None,
) -> Callable:
    cfg: ArchConfig = model.cfg
    policy = REMAT_POLICIES[remat]
    compute_dtype = jnp.dtype(cfg.dtype)

    def _constrain_micro(tree, *, stacked: bool):
        """Re-pin the batch axis after the microbatch reshape — SPMD loses
        the data sharding across the (n, B/n, ...) reshape and would
        otherwise replicate the whole microbatch on every device."""
        if sharding_policy is None:
            return tree
        from jax.sharding import NamedSharding, PartitionSpec as P

        def leaf(x):
            spec = sharding_policy.batch_pspec(x.shape[1:] if stacked else x.shape)
            parts = (None, *spec) if stacked else tuple(spec)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(sharding_policy.mesh, P(*parts))
            )

        return jax.tree.map(leaf, tree)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        n = microbatches

        def to_micro(x):
            return x.reshape(n, x.shape[0] // n, *x.shape[1:])

        micro = _constrain_micro(jax.tree.map(to_micro, batch), stacked=True)
        params_c = cast_params(state.params, compute_dtype)

        def loss_fn(p, mb):
            mb = _constrain_micro(mb, stacked=False)
            loss, metrics = model.train_loss(p, mb, remat_policy=policy)
            return loss, metrics

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def body(carry, mb):
            gacc, lacc = carry
            (loss, _), grads = grad_fn(params_c, mb)
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads
            )
            return (gacc, lacc + loss), None

        gzero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params_c
        )
        (gsum, lsum), _ = jax.lax.scan(body, (gzero, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / n, gsum)

        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params, state.step
        )
        new_state = TrainState(
            step=state.step + 1, params=new_params, opt_state=new_opt
        )
        return new_state, {"loss": lsum / n}

    return train_step


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, token, caches, pos):
        return model.decode_step(params, token, caches, pos)

    return decode_step

"""Serving helpers: cache padding (prefill -> decode handoff) and a batched
greedy-decode driver used by the serving example."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _pad_axis(a, axis, to_len):
    cur = a.shape[axis]
    if cur >= to_len:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, to_len - cur)
    return jnp.pad(a, pad)


def pad_caches(cfg: ArchConfig, caches, cur_len: int, *, to_len: int):
    """Grow prefill caches to a decode-capacity length along their seq axis.

    Family layout (leading axis is the scan-stacked layer axis):
      gqa self-attn  k/v: (L, B, Hkv, S, hd)  -> seq axis 3
      MLA            c: (L, B, S, lora), kr: (L, B, S, rope) -> seq axis 2
      zamba2         mamba states seq-free; shared attn k/v: (A, B, Hkv, S, hd)
      rwkv           states seq-free
      encdec         self like gqa; cross is static (encoder length)
    """
    if cfg.ssm:
        return caches  # state caches are seq-free
    if cfg.hybrid:
        return {
            "mamba": caches["mamba"],
            "attn": jax.tree.map(lambda a: _pad_axis(a, 3, to_len), caches["attn"]),
        }
    if cfg.encoder_decoder:
        return {
            "self": jax.tree.map(lambda a: _pad_axis(a, 3, to_len), caches["self"]),
            "cross": caches["cross"],
        }
    if cfg.mla:
        return jax.tree.map(lambda a: _pad_axis(a, 2, to_len), caches)
    return jax.tree.map(lambda a: _pad_axis(a, 3, to_len), caches)


def greedy_generate(model, params, batch, *, max_new_tokens: int):
    """Prefill + greedy decode loop (example driver; jits the decode step)."""
    cfg = model.cfg
    logits, caches = model.prefill(params, batch)
    prompt_len = batch["tokens"].shape[1]
    total = prompt_len + max_new_tokens
    caches = pad_caches(cfg, caches, prompt_len, to_len=total)

    step = jax.jit(model.decode_step)
    tokens = []
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    offset = cfg.frontend_len if cfg.frontend else 0
    for i in range(max_new_tokens):
        tokens.append(tok)
        logits, caches = step(params, tok, caches, jnp.asarray(prompt_len + i + offset, jnp.int32))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(tokens, axis=1)

"""Training/serving substrate: train state, steps, serving helpers."""

"""AdamW with decoupled weight decay.

State (m, v) is kept in f32 and mirrors the (f32 master) param tree, so
under FSDP the optimizer state inherits the parameters' sharding — each
device updates only its parameter shard (ZeRO-style, by construction)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable   # (grads, state, params, step) -> (new_params, new_state)


def adamw(
    lr: float | Callable = 3e-4,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip:
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
            )
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t

        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)

        def upd(p, m_, v_):
            mh = m_ / c1
            vh = v_ / c2
            return (p - lr_t * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)).astype(
                p.dtype
            )

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v}

    return Optimizer(init=init, update=update)

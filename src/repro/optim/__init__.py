"""Hand-rolled optimizers (no optax in this container — and the substrate
rule is: build everything)."""

from repro.optim.adafactor import adafactor
from repro.optim.adamw import adamw
from repro.optim.schedule import cosine_with_warmup

OPTIMIZERS = {"adamw": adamw, "adafactor": adafactor}

__all__ = ["adamw", "adafactor", "cosine_with_warmup", "OPTIMIZERS"]

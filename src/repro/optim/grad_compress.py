"""int8 gradient compression with error feedback.

Used by the shard_map data-parallel gradient exchange: quantize each leaf to
int8 with a per-leaf f32 scale, psum the int32 accumulators, dequantize —
4x less all-reduce traffic than f32 (2x vs bf16), at the cost of one extra
abs-max pass.  Error feedback (residual carried into the next step) keeps
the compression from biasing convergence [Seide et al. 2014; 1-bit SGD
lineage].

This is one of the §Perf levers for collective-bound cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str, *, error: jax.Array | None = None):
    """Quantized psum over a mesh axis (call inside shard_map).

    Returns (mean-reduced value, new error-feedback residual)."""
    if error is not None:
        x = x + error
    q, scale = quantize_int8(x)
    deq_local = dequantize_int8(q, scale)
    new_error = x - deq_local
    total = jax.lax.psum(q.astype(jnp.int32) * scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total / n, new_error


def compress_tree(grads, errors=None):
    """Leaf-wise quantize->dequantize with error feedback (local simulation
    path used in tests and in the accumulation loop)."""
    if errors is None:
        errors = jax.tree.map(jnp.zeros_like, grads)
    qs = jax.tree.map(lambda g, e: quantize_int8(g + e), grads, errors)
    deq = jax.tree.map(lambda qe: dequantize_int8(*qe), qs, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda g, e, d: g + e - d, grads, errors, deq)
    return deq, new_err

"""Adafactor [Shazeer & Stern 2018] — factored second moment: O(n+m) state
for an (n, m) matrix instead of Adam's O(nm).  At qwen2-72b scale this cuts
optimizer HBM by ~2x vs AdamW (the m buffer disappears, v factors are
negligible) — one of the levers the memory-bound hillclimb can pull."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


def adafactor(
    lr: float = 1e-2,
    *,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    decay_rate: float = 0.8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(per_leaf, params, is_leaf=lambda x: hasattr(x, "shape"))

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-decay_rate)

        def per_leaf(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                )
                upd = g / jnp.maximum(denom, eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                upd = g / jnp.sqrt(v)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            new_p = (p - lr * upd - lr * weight_decay * p).astype(p.dtype)
            return new_p, new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        out = [per_leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_state = tdef.unflatten([o[1] for o in out])
        return new_params, new_state

    return Optimizer(init=init, update=update)

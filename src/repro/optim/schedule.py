"""LR schedules."""

import jax.numpy as jnp


def cosine_with_warmup(peak: float, *, warmup: int = 100, total: int = 10_000, floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * (step + 1) / warmup
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr

"""Model stacks for all assigned families.

One init + three entry points per family, built from a config:

  init_params(key, cfg)                          -> (params, specs)
  forward_train(params, cfg, batch)              -> (loss, aux)
  prefill(params, cfg, batch)                    -> (last_logits, caches)
  decode_step(params, cfg, token, caches, pos)   -> (logits, caches)

Layers are scan-stacked (compact HLO, one compiled layer body) with an
optional remat policy applied to the scan body by the caller (train.step).
Families: "decoder" (dense/moe/vlm, GQA or MLA), "encdec" (seamless),
"hybrid" (zamba2: mamba segments + shared attention block), "rwkv".

Vocab is padded to a multiple of 256 so the "vocab" axis shards on any mesh
(Megatron-style padding; padded rows never receive probability mass from
real tokens and are sliced off nowhere — the loss simply never selects
them).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.context import constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    dense_apply,
    dense_init,
    embedding_apply,
    embedding_init,
    rmsnorm_apply,
    rmsnorm_init,
    rope_angles,
)

VOCAB_PAD = 256


def padded_vocab(cfg: ArchConfig) -> int:
    return ((cfg.vocab + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


def _is_spec_leaf(x):
    return isinstance(x, tuple) and all(isinstance(t, (str, type(None))) for t in x)


def stacked_init(init_fn, key, n):
    """vmap an init over n layer keys; prepend the 'layers' logical axis."""
    box = {}

    def params_only(k):
        p, s = init_fn(k)
        box["specs"] = s
        return p

    params = jax.vmap(params_only)(jax.random.split(key, n))
    specs = jax.tree.map(
        lambda ax: ("layers",) + ax, box["specs"], is_leaf=_is_spec_leaf
    )
    return params, specs


# ======================================================= layer definitions
def _decoder_layer_init(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln1"], s["ln1"] = rmsnorm_init(cfg.d_model, dtype)
    p["ln2"], s["ln2"] = rmsnorm_init(cfg.d_model, dtype)
    if cfg.mla:
        p["attn"], s["attn"] = attn.mla_init(
            k1, cfg.d_model, cfg.n_heads, dtype,
            kv_lora=cfg.kv_lora_rank, qk_nope_dim=cfg.qk_nope_dim,
            qk_rope_dim=cfg.qk_rope_dim, v_dim=cfg.v_head_dim,
        )
    else:
        p["attn"], s["attn"] = attn.gqa_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, dtype, bias=cfg.qkv_bias,
        )
    if cfg.moe:
        p["mlp"], s["mlp"] = moe_mod.moe_init(
            k2, cfg.d_model, n_experts=cfg.n_experts, d_ff_expert=cfg.d_ff_expert,
            top_k=cfg.top_k, n_shared=cfg.n_shared_experts,
            d_ff_shared=cfg.d_ff_expert, dtype=dtype,
        )
    else:
        from repro.models.layers import swiglu_init

        p["mlp"], s["mlp"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p, s


def _decoder_layer_apply(
    p, x, cfg: ArchConfig, *, cos, sin, mode, cache=None, pos=None, dropless=True
):
    h = rmsnorm_apply(p["ln1"], x, eps=cfg.norm_eps)
    if cfg.mla:
        h, new_cache = attn.mla_apply(
            p["attn"], h, n_heads=cfg.n_heads, kv_lora=cfg.kv_lora_rank,
            qk_nope_dim=cfg.qk_nope_dim, qk_rope_dim=cfg.qk_rope_dim,
            v_dim=cfg.v_head_dim, cos=cos, sin=sin, mode=mode,
            cache=cache, pos=pos,
        )
    else:
        h, new_cache = attn.gqa_apply(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, cos=cos, sin=sin, mode=mode,
            cache=cache, pos=pos,
        )
    x = x + h
    h2 = rmsnorm_apply(p["ln2"], x, eps=cfg.norm_eps)
    if cfg.moe:
        ff, aux = moe_mod.moe_apply(
            p["mlp"], h2, n_experts=cfg.n_experts, top_k=cfg.top_k,
            dropless=dropless,
        )
    else:
        from repro.models.layers import swiglu_apply

        ff, aux = swiglu_apply(p["mlp"], h2), jnp.zeros((), jnp.float32)
    return x + ff, new_cache, aux


def _shared_attn_block_init(key, cfg: ArchConfig):
    """Zamba2's shared transformer block (one copy of weights, applied after
    every ``attn_every`` mamba layers)."""
    from repro.models.layers import swiglu_init

    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    p["ln1"], s["ln1"] = rmsnorm_init(cfg.d_model, dtype)
    p["ln2"], s["ln2"] = rmsnorm_init(cfg.d_model, dtype)
    p["attn"], s["attn"] = attn.gqa_init(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
    )
    p["mlp"], s["mlp"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p, s


def _mamba_layer_init(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    p, s = {}, {}
    p["ln"], s["ln"] = rmsnorm_init(cfg.d_model, dtype)
    p["mix"], s["mix"] = ssm_mod.mamba2_init(
        k1, cfg.d_model, d_inner=2 * cfg.d_model, d_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim, conv_width=cfg.ssm_conv_width, dtype=dtype,
    )
    return p, s


def _encdec_dec_layer_init(key, cfg: ArchConfig):
    from repro.models.layers import swiglu_init

    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln1"], s["ln1"] = rmsnorm_init(cfg.d_model, dtype)
    p["ln_x"], s["ln_x"] = rmsnorm_init(cfg.d_model, dtype)
    p["ln2"], s["ln2"] = rmsnorm_init(cfg.d_model, dtype)
    p["self"], s["self"] = attn.gqa_init(
        k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
    )
    p["cross"], s["cross"] = attn.gqa_init(
        k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dtype
    )
    p["mlp"], s["mlp"] = swiglu_init(k3, cfg.d_model, cfg.d_ff, dtype)
    return p, s


# ============================================================ whole models
def init_params(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["embed"], s["embed"] = embedding_init(ks[0], padded_vocab(cfg), cfg.d_model, dtype)
    p["final_norm"], s["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    p["lm_head"], s["lm_head"] = dense_init(
        ks[1], cfg.d_model, padded_vocab(cfg), "embed", "vocab", dtype
    )
    if cfg.frontend:
        p["frontend_proj"], s["frontend_proj"] = dense_init(
            ks[2], cfg.d_model, cfg.d_model, "embed", "embed_out", dtype
        )

    if cfg.ssm:  # rwkv
        def one(k):
            return ssm_mod.rwkv6_init(
                k, cfg.d_model, head_dim=cfg.ssm_head_dim, d_ff=cfg.d_ff,
                lora_rank=cfg.ssm_lora_rank, dtype=dtype,
            )

        p["layers"], s["layers"] = stacked_init(one, ks[3], cfg.n_layers)
    elif cfg.hybrid:  # zamba2
        p["mamba"], s["mamba"] = stacked_init(
            lambda k: _mamba_layer_init(k, cfg), ks[3], cfg.n_layers
        )
        p["shared_attn"], s["shared_attn"] = _shared_attn_block_init(ks[4], cfg)
    elif cfg.encoder_decoder:
        p["enc_layers"], s["enc_layers"] = stacked_init(
            lambda k: _decoder_layer_init(k, cfg), ks[3], cfg.n_enc_layers
        )
        p["dec_layers"], s["dec_layers"] = stacked_init(
            lambda k: _encdec_dec_layer_init(k, cfg), ks[4], cfg.n_layers
        )
        p["enc_norm"], s["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
    else:
        p["layers"], s["layers"] = stacked_init(
            lambda k: _decoder_layer_init(k, cfg), ks[3], cfg.n_layers
        )
    return p, s


def _rope_cache(cfg: ArchConfig, positions):
    if cfg.mla:
        return rope_angles(positions, cfg.qk_rope_dim, cfg.rope_theta)
    return rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta)


def _segments(n_layers: int, every: int) -> list[int]:
    """Hybrid segmentation: [every, every, ..., remainder]."""
    sizes, left = [], n_layers
    while left > 0:
        sizes.append(min(every, left))
        left -= every
    return sizes


def _tree_slice(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def _scan_layers(layer_fn, params_stacked, x, *, remat_policy=None, ys=None):
    """Scan a layer body over stacked params (+ optional per-layer inputs),
    collecting per-layer outputs."""
    body = layer_fn
    if remat_policy is not None:
        body = jax.checkpoint(layer_fn, policy=remat_policy)

    def scan_body(carry, xs):
        return body(carry, xs)

    return jax.lax.scan(scan_body, x, (params_stacked, ys) if ys is not None else params_stacked)


# ------------------------------------------------------------ entry points
def _embed_sequence(p, cfg, batch):
    """Token embeddings (+ projected frontend stub embeddings prepended)."""
    # constrain BEFORE any frontend concat: sharding must be pinned on the
    # one-hot-matmul output itself, or SPMD replicates the (B, S, V/tp)
    # one-hot across the batch axis (observed: 24 GB/device on internvl2).
    x = constrain(embedding_apply(p["embed"], batch["tokens"]), ("batch", "seq", "embed"))
    if cfg.frontend and "frontend" in batch:
        fe = dense_apply(p["frontend_proj"], batch["frontend"].astype(x.dtype))
        fe = constrain(fe, ("batch", "seq", "embed"))
        x = jnp.concatenate([fe, x], axis=1)
    return constrain(x, ("batch", "seq", "embed"))


def _run_decoder_stack(
    p, cfg, x, *, mode, caches=None, pos=None, remat_policy=None, dropless=True
):
    B, S, _ = x.shape
    if mode == "decode":
        positions = jnp.asarray(pos, jnp.int32).reshape(1)
    else:
        positions = jnp.arange(S)
    cos, sin = _rope_cache(cfg, positions)

    if mode == "decode":
        def body(carry, xs):
            layer_p, layer_cache = xs
            y, new_cache, _ = _decoder_layer_apply(
                layer_p, carry, cfg, cos=cos, sin=sin, mode="decode",
                cache=layer_cache, pos=pos,
            )
            return constrain(y, ("batch", "seq", "embed")), new_cache

        x, new_caches = jax.lax.scan(body, x, (p["layers"], caches))
        return x, new_caches, jnp.zeros((), jnp.float32)

    def body(carry, layer_p):
        y, cache, aux = _decoder_layer_apply(
            layer_p, carry, cfg, cos=cos, sin=sin, mode=mode, dropless=dropless
        )
        return constrain(y, ("batch", "seq", "embed")), (cache, aux)

    if remat_policy is not None:
        body = jax.checkpoint(body, policy=remat_policy)
    x, (caches_out, auxs) = jax.lax.scan(body, x, p["layers"])
    return x, caches_out, jnp.sum(auxs)


def _run_rwkv_stack(p, cfg, x, *, mode, caches=None, remat_policy=None):
    if mode == "decode":
        def body(carry, xs):
            layer_p, layer_cache = xs
            y, new_cache = ssm_mod.rwkv6_apply(
                layer_p, carry, head_dim=cfg.ssm_head_dim, d_ff=cfg.d_ff,
                cache=layer_cache,
            )
            return constrain(y, ("batch", "seq", "embed")), new_cache

        x, new_caches = jax.lax.scan(body, x, (p["layers"], caches))
        return x, new_caches, jnp.zeros((), jnp.float32)

    def body(carry, layer_p):
        y, cache = ssm_mod.rwkv6_apply(
            layer_p, carry, head_dim=cfg.ssm_head_dim, d_ff=cfg.d_ff
        )
        return constrain(y, ("batch", "seq", "embed")), cache

    if remat_policy is not None:
        body = jax.checkpoint(body, policy=remat_policy)
    x, caches_out = jax.lax.scan(body, x, p["layers"])
    return x, caches_out, jnp.zeros((), jnp.float32)


def _shared_attn_apply(p, x, cfg, *, cos, sin, mode, cache=None, pos=None):
    h = rmsnorm_apply(p["ln1"], x, eps=cfg.norm_eps)
    h, new_cache = attn.gqa_apply(
        p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, cos=cos, sin=sin, mode=mode,
        cache=cache, pos=pos,
    )
    x = x + h
    from repro.models.layers import swiglu_apply

    h2 = rmsnorm_apply(p["ln2"], x, eps=cfg.norm_eps)
    return x + swiglu_apply(p["mlp"], h2), new_cache


def _run_hybrid_stack(p, cfg, x, *, mode, caches=None, pos=None, remat_policy=None):
    """Zamba2: segments of mamba layers, shared attn block between them."""
    B, S, _ = x.shape
    sizes = _segments(cfg.n_layers, cfg.attn_every)
    n_attn = len(sizes) - 1  # shared attn after every segment except the last
    if mode == "decode":
        positions = jnp.asarray(pos, jnp.int32).reshape(1)
    else:
        positions = jnp.arange(S)
    cos, sin = _rope_cache(cfg, positions)

    def mamba_body(carry, xs):
        if mode == "decode":
            layer_p, layer_cache = xs
        else:
            layer_p, layer_cache = xs, None
        h = rmsnorm_apply(layer_p["ln"], carry, eps=cfg.norm_eps)
        h, new_cache = ssm_mod.mamba2_apply(
            layer_p["mix"], h, d_inner=2 * cfg.d_model, d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, conv_width=cfg.ssm_conv_width,
            chunk=cfg.ssm_chunk, cache=layer_cache,
        )
        return constrain(carry + h, ("batch", "seq", "embed")), new_cache

    body = mamba_body if remat_policy is None else jax.checkpoint(mamba_body, policy=remat_policy)

    mamba_caches_out, attn_caches_out = [], []
    lo = 0
    for seg_idx, size in enumerate(sizes):
        seg_params = _tree_slice(p["mamba"], lo, lo + size)
        if mode == "decode":
            seg_caches = _tree_slice(caches["mamba"], lo, lo + size)
            x, seg_caches_new = jax.lax.scan(body, x, (seg_params, seg_caches))
        else:
            x, seg_caches_new = jax.lax.scan(body, x, seg_params)
        mamba_caches_out.append(seg_caches_new)
        lo += size
        if seg_idx < n_attn:
            if mode == "decode":
                a_cache = jax.tree.map(lambda a: a[seg_idx], caches["attn"])
                x, a_new = _shared_attn_apply(
                    p["shared_attn"], x, cfg, cos=cos, sin=sin, mode="decode",
                    cache=a_cache, pos=pos,
                )
            else:
                x, a_new = _shared_attn_apply(
                    p["shared_attn"], x, cfg, cos=cos, sin=sin, mode=mode
                )
            attn_caches_out.append(a_new)

    caches_out = {
        "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *mamba_caches_out),
        "attn": jax.tree.map(lambda *xs: jnp.stack(xs, 0), *attn_caches_out)
        if attn_caches_out
        else {},
    }
    return x, caches_out, jnp.zeros((), jnp.float32)


def _run_encdec(p, cfg, batch, *, mode, caches=None, pos=None, remat_policy=None):
    """Seamless: encoder over stub frames, decoder with self+cross attention."""
    dtype = jnp.dtype(cfg.dtype)

    def enc_body(carry, layer_p):
        y, _, aux = _decoder_layer_apply(
            layer_p, carry, cfg, cos=cos_e, sin=sin_e, mode="full"
        )
        return constrain(y, ("batch", "seq", "embed")), aux

    def dec_body(carry, xs):
        if mode == "decode":
            layer_p, (self_cache, cross_cache) = xs
        else:
            layer_p, (self_cache, cross_cache) = xs, (None, None)
        x = carry
        h = rmsnorm_apply(layer_p["ln1"], x, eps=cfg.norm_eps)
        h, self_new = attn.gqa_apply(
            layer_p["self"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, cos=cos_d, sin=sin_d,
            mode="decode" if mode == "decode" else "causal",
            cache=self_cache, pos=pos,
        )
        x = x + h
        hx = rmsnorm_apply(layer_p["ln_x"], x, eps=cfg.norm_eps)
        if mode == "decode":
            hx, cross_new = attn.gqa_apply(
                layer_p["cross"], hx, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, mode="cross_decode", cache=cross_cache,
            )
        else:
            hx, _ = attn.gqa_apply(
                layer_p["cross"], hx, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, mode="cross", x_kv=enc_out,
            )
            # prefill builds the static cross cache from encoder memory
            from repro.models.attention import _split_heads
            from repro.models.layers import dense_apply as _da

            cross_new = {
                "k": _split_heads(_da(layer_p["cross"]["wk"], enc_out), cfg.n_kv_heads, cfg.resolved_head_dim),
                "v": _split_heads(_da(layer_p["cross"]["wv"], enc_out), cfg.n_kv_heads, cfg.resolved_head_dim),
            }
        x = x + hx
        from repro.models.layers import swiglu_apply

        h2 = rmsnorm_apply(layer_p["ln2"], x, eps=cfg.norm_eps)
        out = constrain(x + swiglu_apply(layer_p["mlp"], h2), ("batch", "seq", "embed"))
        return out, (self_new, cross_new)

    if mode == "decode":
        # encoder already consumed; caches carry self+cross
        positions = jnp.asarray(pos, jnp.int32).reshape(1)
        cos_d, sin_d = _rope_cache(cfg, positions)
        cos_e = sin_e = None
        x = embedding_apply(p["embed"], batch["tokens"])
        x, caches_new = jax.lax.scan(
            dec_body, x, (p["dec_layers"], (caches["self"], caches["cross"]))
        )
        return x, {"self": caches_new[0], "cross": caches_new[1]}, jnp.zeros((), jnp.float32)

    frames = batch["frames"].astype(dtype)
    fe = dense_apply(p["frontend_proj"], frames) if "frontend_proj" in p else frames
    cos_e, sin_e = _rope_cache(cfg, jnp.arange(fe.shape[1]))
    enc_body_ = enc_body if remat_policy is None else jax.checkpoint(enc_body, policy=remat_policy)
    enc_out, _ = jax.lax.scan(enc_body_, fe, p["enc_layers"])
    enc_out = rmsnorm_apply(p["enc_norm"], enc_out, eps=cfg.norm_eps)

    x = embedding_apply(p["embed"], batch["tokens"])
    cos_d, sin_d = _rope_cache(cfg, jnp.arange(x.shape[1]))
    dec_body_ = dec_body if remat_policy is None else jax.checkpoint(dec_body, policy=remat_policy)
    x, caches_new = jax.lax.scan(dec_body_, x, p["dec_layers"])
    return x, {"self": caches_new[0], "cross": caches_new[1]}, jnp.zeros((), jnp.float32)


def backbone_apply(
    p, cfg: ArchConfig, batch, *, mode, caches=None, pos=None,
    remat_policy=None, dropless=True,
):
    """Dispatch to the family stack. Returns (hidden, caches, aux)."""
    if cfg.encoder_decoder:
        return _run_encdec(p, cfg, batch, mode=mode, caches=caches, pos=pos, remat_policy=remat_policy)
    x = _embed_sequence(p, cfg, batch)
    if cfg.ssm:
        return _run_rwkv_stack(p, cfg, x, mode=mode, caches=caches, remat_policy=remat_policy)
    if cfg.hybrid:
        return _run_hybrid_stack(p, cfg, x, mode=mode, caches=caches, pos=pos, remat_policy=remat_policy)
    return _run_decoder_stack(
        p, cfg, x, mode=mode, caches=caches, pos=pos,
        remat_policy=remat_policy, dropless=dropless,
    )


def token_loss(logits, targets):
    """Mean next-token cross-entropy in f32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - ll)


def forward_train(p, cfg: ArchConfig, batch, *, remat_policy=None, aux_weight=0.01):
    x, _, aux = backbone_apply(
        p, cfg, batch, mode="causal", remat_policy=remat_policy, dropless=False
    )
    x = rmsnorm_apply(p["final_norm"], x, eps=cfg.norm_eps)
    if cfg.frontend and "frontend" in batch:
        x = x[:, batch["frontend"].shape[1]:]  # loss on the text span only
    logits = constrain(dense_apply(p["lm_head"], x), ("batch", "seq", "vocab"))
    loss = token_loss(logits, batch["targets"])
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


def prefill(p, cfg: ArchConfig, batch):
    x, caches, _ = backbone_apply(p, cfg, batch, mode="causal")
    x = rmsnorm_apply(p["final_norm"], x[:, -1:], eps=cfg.norm_eps)
    logits = constrain(dense_apply(p["lm_head"], x)[:, 0], ("batch", "vocab"))
    return logits, caches


def decode_step(p, cfg: ArchConfig, token, caches, pos):
    """token: (B, 1) int32; pos: scalar int32 write index."""
    batch = {"tokens": token}
    x, caches, _ = backbone_apply(p, cfg, batch, mode="decode", caches=caches, pos=pos)
    x = rmsnorm_apply(p["final_norm"], x, eps=cfg.norm_eps)
    logits = constrain(dense_apply(p["lm_head"], x)[:, 0], ("batch", "vocab"))
    return logits, caches

"""LM substrate for the assigned architectures.

Functional style: every module is an (init, apply) pair over plain nested
dicts.  ``init`` returns (params, specs) where ``specs`` mirrors the params
tree with tuples of *logical* axis names ("embed", "heads", "mlp", "vocab",
"expert", ...); repro.distributed.sharding maps logical axes onto mesh axes
with divisibility fallbacks.  Models are built from configs by zoo.build.
"""

from repro.models.zoo import build_model

__all__ = ["build_model"]

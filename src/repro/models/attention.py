"""Attention modules: GQA (with QKV-bias variant) and MLA (DeepSeek-V2-style
latent KV compression), each with prefill/decode KV-cache paths.

Cache contract (decode): caches are preallocated at full seq_len; a decode
step writes the new token's KV at position ``pos`` in place
(dynamic_update_slice — donation-friendly) and attends over kpos <= pos.
"Decode with a KV cache of seq_len" therefore costs O(S) reads and zero
reallocation, which is what the decode_32k / long_500k dry-run cells lower.

MLA decode uses the absorbed formulation: q is projected into the latent
space (q @ W_uk per head) so attention runs directly against the cached
c_kv latents — the cache stays (S, kv_lora + rope_dim) per token instead of
(S, 2 * H * hd): a 10-20x cache shrink, which is the whole point of MLA.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_apply, dense_init, rope_apply


def _sdpa(q, k, v, *, scale, mask) -> jax.Array:
    """q: (B,H,Sq,d), k/v: (B,Hkv,Skv,d) with GQA broadcast; mask: (Sq,Skv)
    or (B,1,Sq,Skv) boolean (True = attend).

    f32 accumulation comes from preferred_element_type on the dots — NOT
    from casting k/v: materializing an f32 copy of a 32k-token KV cache costs
    more HBM traffic than the attention math itself (seen in the decode
    dry-run as a whole-cache convert per layer)."""
    B, H, Sq, d = q.shape
    Hkv = k.shape[1]
    group = H // Hkv
    qg = q.reshape(B, Hkv, group, Sq, d)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg, k, preferred_element_type=jnp.float32
    )
    s = s * scale
    if mask is not None:
        m = mask if mask.ndim == 4 else mask[None, None]
        s = jnp.where(m[:, :, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p, v, preferred_element_type=jnp.float32
    )
    return o.reshape(B, H, Sq, v.shape[-1]).astype(q.dtype)  # v dim may differ (MLA)


def _dus_seq(cache_arr, new_vals, pos, axis: int):
    """dynamic_update_slice along one axis at traced index pos (int32)."""
    idx = [jnp.zeros((), jnp.int32)] * cache_arr.ndim
    idx[axis] = jnp.asarray(pos, jnp.int32)
    return jax.lax.dynamic_update_slice(
        cache_arr, new_vals.astype(cache_arr.dtype), tuple(idx)
    )


def causal_mask(Sq: int, Skv: int, offset: int = 0) -> jax.Array:
    """True where query may attend: kpos <= qpos + offset."""
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Skv)[None, :]
    return kpos <= qpos


# q-chunking threshold: above this query length the S^2 score matrix stops
# fitting HBM (34 GB/layer for a 72B at 32k), so attention runs as a scan
# over q blocks — the XLA-native flash formulation.  The Pallas kernel
# (kernels/flash_attention) replaces this on real TPU via use_flash.
ATTN_CHUNK_THRESHOLD = 4096
ATTN_Q_CHUNK = 1024


def _sdpa_chunked(q, k, v, *, scale, causal, q_chunk=ATTN_Q_CHUNK) -> jax.Array:
    """Same contract as _sdpa but scanned over q chunks: transient score
    buffers are (B, H, q_chunk, Skv) instead of (B, H, Sq, Skv)."""
    B, H, Sq, d = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = H // Hkv
    pad = (-Sq) % q_chunk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else q
    nq = qp.shape[2] // q_chunk
    qg = qp.reshape(B, Hkv, group, nq, q_chunk, d).transpose(3, 0, 1, 2, 4, 5)

    kpos = jnp.arange(Skv)

    def body(_, inp):
        idx, qc = inp                                   # qc: (B,Hkv,g,qc,d)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qc, k, preferred_element_type=jnp.float32)
        s = s * scale
        if causal:
            qpos = idx * q_chunk + jnp.arange(q_chunk)
            s = jnp.where(kpos[None, None, None, None, :] <= qpos[None, None, None, :, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v, preferred_element_type=jnp.float32)
        return None, o.astype(q.dtype)

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qg))
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, H, nq * q_chunk, v.shape[-1])
    return out[:, :, :Sq]


# =========================================================== GQA attention
def gqa_init(key, d_model, n_heads, n_kv_heads, head_dim, dtype, *, bias=False):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(kq, d_model, n_heads * head_dim, "embed", "heads", dtype, bias=bias)
    p["wk"], s["wk"] = dense_init(kk, d_model, n_kv_heads * head_dim, "embed", "kv", dtype, bias=bias)
    p["wv"], s["wv"] = dense_init(kv, d_model, n_kv_heads * head_dim, "embed", "kv", dtype, bias=bias)
    p["wo"], s["wo"] = dense_init(ko, n_heads * head_dim, d_model, "heads", "embed", dtype)
    return p, s


def _split_heads(x, n, d):
    B, S, _ = x.shape
    return x.reshape(B, S, n, d).transpose(0, 2, 1, 3)


def _merge_heads(x):
    B, H, S, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * d)


def gqa_apply(
    p, x, *, n_heads, n_kv_heads, head_dim,
    cos=None, sin=None, mode="causal",
    x_kv=None, cache=None, pos=None, use_flash=False,
):
    """mode: 'causal' | 'full' | 'cross' | 'decode'.

    decode: x is (B, 1, D); cache = {'k','v'} preallocated (B,Hkv,S,hd);
    pos is the write index (scalar int32).  Returns (out, new_cache)."""
    B, Sq, _ = x.shape
    scale = head_dim**-0.5
    q = _split_heads(dense_apply(p["wq"], x), n_heads, head_dim)
    src = x if x_kv is None else x_kv
    k = _split_heads(dense_apply(p["wk"], src), n_kv_heads, head_dim)
    v = _split_heads(dense_apply(p["wv"], src), n_kv_heads, head_dim)

    if cos is not None and mode != "cross":
        q = rope_apply(q, cos, sin)
        k = rope_apply(k, cos, sin)

    new_cache = None
    if mode == "decode":
        ck = _dus_seq(cache["k"], k, pos, 2)
        cv = _dus_seq(cache["v"], v, pos, 2)
        new_cache = {"k": ck, "v": cv}
        Skv = ck.shape[2]
        mask = (jnp.arange(Skv)[None, :] <= pos)[None, None, :, :]  # (1,1,1,Skv)
        o = _sdpa(q, ck, cv, scale=scale, mask=jnp.broadcast_to(mask, (B, 1, 1, Skv)))
    elif mode == "cross_decode":
        # decoder cross-attention during decode: static encoder memory cache
        o = _sdpa(q, cache["k"], cache["v"], scale=scale, mask=None)
        new_cache = cache
    else:
        if use_flash:
            from repro.kernels.flash_attention.ops import flash_attention

            o = flash_attention(q, k, v, causal=(mode == "causal"), scale=scale)
        elif Sq > ATTN_CHUNK_THRESHOLD:
            # strict: at S=4096 the dense score tile is ~0.5 GB transient and
            # cheaper in traffic than the chunk scan (+16% bytes measured);
            # the capacity blocker only appears at longer context.
            o = _sdpa_chunked(q, k, v, scale=scale, causal=(mode == "causal"))
        else:
            mask = causal_mask(Sq, k.shape[2]) if mode == "causal" else None
            o = _sdpa(q, k, v, scale=scale, mask=mask)
        if mode != "cross":
            new_cache = {"k": k, "v": v}  # prefill cache
    return dense_apply(p["wo"], _merge_heads(o)), new_cache


# =========================================================== MLA attention
def mla_init(
    key, d_model, n_heads, dtype, *,
    kv_lora, qk_nope_dim=128, qk_rope_dim=64, v_dim=128,
):
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    qk_dim = qk_nope_dim + qk_rope_dim
    p["wq"], s["wq"] = dense_init(ks[0], d_model, n_heads * qk_dim, "embed", "heads", dtype)
    p["wdkv"], s["wdkv"] = dense_init(ks[1], d_model, kv_lora, "embed", "lora", dtype)
    p["wkrope"], s["wkrope"] = dense_init(ks[2], d_model, qk_rope_dim, "embed", "lora", dtype)
    p["wuk"], s["wuk"] = dense_init(ks[3], kv_lora, n_heads * qk_nope_dim, "lora", "heads", dtype)
    p["wuv"], s["wuv"] = dense_init(ks[4], kv_lora, n_heads * v_dim, "lora", "heads", dtype)
    p["wo"], s["wo"] = dense_init(ks[5], n_heads * v_dim, d_model, "heads", "embed", dtype)
    return p, s


def mla_apply(
    p, x, *, n_heads, kv_lora, qk_nope_dim=128, qk_rope_dim=64, v_dim=128,
    cos=None, sin=None, mode="causal", cache=None, pos=None,
):
    """MLA with latent cache {c: (B,S,kv_lora), kr: (B,S,rope_dim)}."""
    B, Sq, _ = x.shape
    qk_dim = qk_nope_dim + qk_rope_dim
    scale = qk_dim**-0.5

    q = dense_apply(p["wq"], x).reshape(B, Sq, n_heads, qk_dim).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :qk_nope_dim], q[..., qk_nope_dim:]
    q_rope = rope_apply(q_rope, cos, sin)

    c_new = dense_apply(p["wdkv"], x)                       # (B,Sq,lora)
    kr_new = dense_apply(p["wkrope"], x)                    # (B,Sq,rope)
    kr_new = rope_apply(kr_new[:, None], cos, sin)[:, 0]    # single shared rope head

    wuk = p["wuk"]["w"].reshape(kv_lora, n_heads, qk_nope_dim)
    wuv = p["wuv"]["w"].reshape(kv_lora, n_heads, v_dim)

    if mode == "decode":
        c = _dus_seq(cache["c"], c_new, pos, 1)
        kr = _dus_seq(cache["kr"], kr_new, pos, 1)
        new_cache = {"c": c, "kr": kr}
        # absorbed path: q_nope -> latent space, score against c directly.
        # No whole-cache casts — f32 accumulate via preferred_element_type.
        q_lat = jnp.einsum("bhqd,lhd->bhql", q_nope, wuk, preferred_element_type=jnp.float32)
        s_lat = jnp.einsum("bhql,bkl->bhqk", q_lat.astype(c.dtype), c, preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bhqd,bkd->bhqk", q_rope, kr, preferred_element_type=jnp.float32)
        s_all = (s_lat + s_rope) * scale
        Skv = c.shape[1]
        mask = (jnp.arange(Skv)[None, None, None, :] <= pos)
        s_all = jnp.where(mask, s_all, -1e30)
        prob = jax.nn.softmax(s_all, axis=-1)
        o_lat = jnp.einsum("bhqk,bkl->bhql", prob.astype(c.dtype), c, preferred_element_type=jnp.float32)
        o = jnp.einsum("bhql,lhd->bhqd", o_lat.astype(wuv.dtype), wuv, preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        new_cache = {"c": c_new, "kr": kr_new}
        k_nope = jnp.einsum("bkl,lhd->bhkd", c_new, wuk)    # expand per head
        vfull = jnp.einsum("bkl,lhd->bhkd", c_new, wuv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(kr_new[:, None], (B, n_heads, Sq, qk_rope_dim))],
            axis=-1,
        )
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        if Sq > ATTN_CHUNK_THRESHOLD:
            o = _sdpa_chunked(qfull, k, vfull, scale=scale, causal=(mode == "causal"))
        else:
            mask = causal_mask(Sq, Sq) if mode == "causal" else None
            o = _sdpa(qfull, k, vfull, scale=scale, mask=mask)

    out = o.transpose(0, 2, 1, 3).reshape(B, Sq, n_heads * v_dim)
    return dense_apply(p["wo"], out), new_cache

"""Mixture-of-Experts FFN with top-k routing and fixed-capacity one-hot
dispatch (GShard/Switch pattern).

The dispatch/combine einsums are the SPMD-friendly formulation: the
(tokens, experts, capacity) tensors shard tokens on the data axes and
experts on the tensor axis, so XLA partitions the dispatch into the
canonical all-to-all + batched expert GEMMs with *static* shapes (no
data-dependent shapes on the hot path — the straggler-free property
DESIGN.md Section 5 relies on).  Capacity overflow drops tokens
deterministically (standard fixed-capacity semantics); the aux load-balance
loss keeps overflow rare.

Supports shared (always-on) experts alongside routed ones (DeepSeek-V2
style), and expert widths != shared widths (Qwen3-MoE style).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import swiglu_apply, swiglu_init, truncnorm_init


def moe_init(
    key, d_model, *, n_experts, d_ff_expert, top_k, n_shared=0, d_ff_shared=0, dtype
):
    ks = jax.random.split(key, 5)
    p, s = {}, {}
    p["router"] = {"w": truncnorm_init(ks[0], (d_model, n_experts), jnp.float32, d_model**-0.5)}
    s["router"] = {"w": ("embed", "expert")}
    # stacked expert SwiGLU weights: (E, d, f) / (E, f, d)
    p["wi"] = truncnorm_init(ks[1], (n_experts, d_model, d_ff_expert), dtype, d_model**-0.5)
    p["wg"] = truncnorm_init(ks[2], (n_experts, d_model, d_ff_expert), dtype, d_model**-0.5)
    p["wo"] = truncnorm_init(ks[3], (n_experts, d_ff_expert, d_model), dtype, d_ff_expert**-0.5)
    s["wi"] = ("expert", "embed", "mlp")
    s["wg"] = ("expert", "embed", "mlp")
    s["wo"] = ("expert", "mlp", "embed")
    if n_shared:
        p["shared"], s["shared"] = swiglu_init(ks[4], d_model, n_shared * d_ff_shared, dtype)
    return p, s


def moe_apply(
    p, x, *, n_experts, top_k, capacity_factor=1.25, dropless=False,
    chunk: int = 1024,
):
    """x: (B, S, D) -> (out, aux_loss).

    CHUNKED dispatch: the GShard one-hot dispatch einsum costs
    2*T*E*C*d flops with C ~ cf*T*k/E, i.e. QUADRATIC in the number of
    tokens dispatched together.  Dispatching a whole 131k-token microbatch
    at once made the dispatch ~170x the expert-FFN cost (observed in the
    dry-run: MoE prefill compute 100x the dense archs').  Tokens are
    therefore routed in chunks of ``chunk``: the dispatch tensors get a
    leading chunk axis (nc, Tc, E, C) and every einsum carries it — total
    dispatch cost becomes 2*cf*k*T*chunk*d, linear in T, ~0.5x the FFN
    flops at chunk=1024 for the assigned MoE shapes.

    dropless=True (serving): capacity = Tc per chunk — exact, no token ever
    dropped, and prefill/decode stay bit-consistent.  Training uses the
    fixed-capacity regime (cf=1.25) with deterministic overflow drops.
    """
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    Tc = min(chunk, T)
    pad = (-T) % Tc
    if pad:
        xt = jnp.concatenate([xt, jnp.zeros((pad, D), xt.dtype)])
    nc = xt.shape[0] // Tc
    xc = xt.reshape(nc, Tc, D)

    logits = (xc.astype(jnp.float32)) @ p["router"]["w"]           # (n,Tc,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)              # (n,Tc,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    if dropless:
        C = Tc
    else:
        C = int(min(Tc, max(1, (Tc * top_k * capacity_factor) // n_experts)))

    # position of each (token, choice) in its expert's per-chunk buffer
    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)  # (n,Tc,k,E)
    flat = onehot.reshape(nc, Tc * top_k, n_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(nc, Tc, top_k, n_experts)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                 # (n,Tc,k)
    keep = pos < C

    disp = (
        jax.nn.one_hot(gate_idx, n_experts, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=x.dtype)[..., :C][..., None, :]
    )                                                              # (n,Tc,k,E,C)
    comb = disp * gate_vals[..., None, None].astype(x.dtype)
    disp = jnp.sum(disp, axis=2)                                   # (n,Tc,E,C)
    comb = jnp.sum(comb, axis=2)

    xe = jnp.einsum("ntec,ntd->necd", disp, xc)                    # (n,E,C,D)
    h = jnp.einsum("necd,edf->necf", xe, p["wg"])
    h = jax.nn.silu(h) * jnp.einsum("necd,edf->necf", xe, p["wi"])
    ye = jnp.einsum("necf,efd->necd", h, p["wo"])                  # (n,E,C,D)
    yt = jnp.einsum("ntec,necd->ntd", comb, ye)                    # (n,Tc,D)

    out = yt.reshape(nc * Tc, D)[:T].reshape(B, S, D)
    if "shared" in p:
        out = out + swiglu_apply(p["shared"], x)

    # Switch-style load-balance aux loss (over real tokens only)
    probs_flat = probs.reshape(nc * Tc, n_experts)[:T]
    idx_flat = gate_idx.reshape(nc * Tc, top_k)[:T]
    me = jnp.mean(probs_flat, axis=0)                              # (E,)
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx_flat, n_experts, dtype=jnp.float32), axis=1),
        axis=0,
    )
    aux = n_experts * jnp.sum(me * frac)
    return out, aux

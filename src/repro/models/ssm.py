"""State-space sequence mixers: Mamba2 (SSD) and RWKV6 (Finch).

Mamba2 uses the chunked SSD formulation (Mamba-2 paper, Sec. 6): within a
chunk of length L the recurrence

    h_t = a_t h_{t-1} + B_t (dt_t x_t)',   y_t = C_t' h_t + D x_t

is evaluated as masked matmuls — M[t,i] = exp(La_t - La_i) for t >= i (all
exponents <= 0, so no overflow path exists), y_intra = (M * C B') @ xb —
while chunk-to-chunk states are carried by a lax.scan.  This keeps the MXU
fed (L x L and L x N contractions) instead of serializing 4k steps, and the
HLO stays compact (one scan over T/L chunks).

RWKV6's data-dependent per-channel decay makes the safe matmul factorization
overflow-prone (exponents of both signs), so the baseline WKV6 runs as a
lax.scan over time, vectorized over (B, H, dk, dv) — exact, compact HLO.
A chunked variant is a recorded candidate in EXPERIMENTS.md §Perf.

Both expose one-step decode paths with O(1) state caches — the reason these
families run the long_500k cell that full-attention archs skip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_apply, dense_init, rmsnorm_apply, truncnorm_init


# =============================================================== Mamba2/SSD
def mamba2_init(key, d_model, *, d_inner, d_state, head_dim, conv_width, dtype):
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    # fused in-projection: [z | x | B | C | dt]
    proj_out = 2 * d_inner + 2 * d_state + n_heads
    p["in_proj"], s["in_proj"] = dense_init(ks[0], d_model, proj_out, "embed", "heads", dtype)
    conv_ch = d_inner + 2 * d_state
    p["conv_w"] = truncnorm_init(ks[1], (conv_width, conv_ch), dtype, conv_width**-0.5)
    s["conv_w"] = ("conv", "heads")
    p["conv_b"] = jnp.zeros((conv_ch,), dtype)
    s["conv_b"] = ("heads",)
    p["A_log"] = jnp.zeros((n_heads,), jnp.float32) + jnp.log(jnp.arange(1, n_heads + 1, dtype=jnp.float32))
    s["A_log"] = ("ssm",)
    p["D"] = jnp.ones((n_heads,), jnp.float32)
    s["D"] = ("ssm",)
    p["dt_bias"] = jnp.zeros((n_heads,), jnp.float32)
    s["dt_bias"] = ("ssm",)
    p["norm"] = {"scale": jnp.ones((d_inner,), dtype)}
    s["norm"] = {"scale": ("heads",)}
    p["out_proj"], s["out_proj"] = dense_init(ks[2], d_inner, d_model, "heads", "embed", dtype)
    return p, s


def _causal_conv(x, w, b, *, state=None):
    """Depthwise causal conv over seq.  x: (B,T,C), w: (W,C).  state: (B,W-1,C)
    carries the last W-1 inputs for decode; returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # (B, T+W-1, C) -> windows
    T = x.shape[1]
    y = sum(xp[:, i : i + T] * w[i][None, None] for i in range(W))
    new_state = xp[:, -(W - 1) :] if W > 1 else None
    return y + b[None, None], new_state


def _ssd_chunked(xb, loga, Bm, Cm, h0, *, chunk):
    """xb: (B,T,H,P) inputs (dt*x); loga: (B,T,H) per-step log decay (<=0);
    Bm, Cm: (B,T,N); h0: (B,H,N,P).  Returns (y: (B,T,H,P), h_final)."""
    Bsz, T, H, Pd = xb.shape
    N = Bm.shape[-1]
    L = min(chunk, T)
    nc = T // L
    xb = xb.reshape(Bsz, nc, L, H, Pd)
    loga = loga.reshape(Bsz, nc, L, H).astype(jnp.float32)
    Bm = Bm.reshape(Bsz, nc, L, N)
    Cm = Cm.reshape(Bsz, nc, L, N)

    La = jnp.cumsum(loga, axis=2)                      # inclusive (B,nc,L,H)
    # intra-chunk: M[t,i] = exp(La_t - La_i), t >= i  (exponents <= 0).
    # Mask BEFORE exp: exp(+big) under a where still poisons the backward
    # pass with 0 * inf = NaN cotangents.
    diff = La[:, :, :, None, :] - La[:, :, None, :, :]  # (B,nc,L,L,H)
    tri = jnp.tril(jnp.ones((L, L), bool))
    M = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -jnp.inf))
    CB = jnp.einsum("bcln,bcmn->bclm", Cm.astype(jnp.float32), Bm.astype(jnp.float32))
    # The (B,nc,L,L,H) masked-decay matrix is the largest intra-chunk buffer;
    # combine with CB in f32 (exp/cumsum precision) then drop to the compute
    # dtype for the contraction — halves its HBM traffic in bf16 runs
    # (§Perf iteration A3).
    MCB = (M * CB[..., None]).astype(xb.dtype)
    y_intra = jnp.einsum(
        "bclmh,bcmhp->bclhp", MCB, xb, preferred_element_type=jnp.float32
    )

    # per-chunk state contribution (independent of h): sum_i exp(La_L - La_i) B_i xb_i
    decay_out = jnp.exp(La[:, :, -1:, :] - La)          # (B,nc,L,H) <= 1
    S_chunk = jnp.einsum("bclh,bcln,bclhp->bchnp", decay_out, Bm.astype(jnp.float32), xb.astype(jnp.float32))
    a_chunk = jnp.exp(La[:, :, -1, :])                  # (B,nc,H) total chunk decay

    def scan_body(h, per_chunk):
        a_c, S_c = per_chunk                            # (B,H), (B,H,N,P)
        h_next = a_c[..., None, None] * h + S_c
        return h_next, h

    (h_final, h_prevs) = jax.lax.scan(
        scan_body,
        h0.astype(jnp.float32),
        (a_chunk.transpose(1, 0, 2), S_chunk.transpose(1, 0, 2, 3, 4)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)          # (B,nc,H,N,P)

    # inter-chunk: y_t += exp(La_t) C_t' h_prev(chunk)
    y_inter = jnp.einsum(
        "bclh,bcln,bchnp->bclhp", jnp.exp(La), Cm.astype(jnp.float32), h_prevs
    )
    y = (y_intra + y_inter).reshape(Bsz, T, H, Pd)
    return y, h_final


def mamba2_apply(
    p, x, *, d_inner, d_state, head_dim, conv_width, chunk=128, cache=None
):
    """Full-sequence when cache is None (returns final state as cache);
    single-step decode when cache = {'conv': (B,W-1,C), 'ssm': (B,H,N,P)}."""
    B, T, D = x.shape
    H = d_inner // head_dim
    zxbcdt = dense_apply(p["in_proj"], x)
    z, xs, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state], axis=-1
    )
    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_state = None if cache is None else cache["conv"]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], state=conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])  # (B,T,H)
    A = -jnp.exp(p["A_log"])                                                  # (H,) < 0
    loga = dt * A[None, None]                                                 # <= 0
    xh = xs.reshape(B, T, H, head_dim)
    # keep the discretized input in the compute dtype: decay math (loga,
    # cumsums) stays f32, but the big intra-chunk contraction operands drop
    # to bf16 in production — state accumulation is still f32 via
    # preferred_element_type (§Perf iteration A3).
    xb = (xh * dt[..., None]).astype(x.dtype)

    h0 = (
        jnp.zeros((B, H, d_state, head_dim), jnp.float32)
        if cache is None
        else cache["ssm"].astype(jnp.float32)
    )
    if cache is None:
        y, h_final = _ssd_chunked(xb, loga, Bm, Cm, h0, chunk=chunk)
    else:  # decode: exact one-step recurrence
        a = jnp.exp(loga[:, 0])                                               # (B,H)
        h_final = a[..., None, None] * h0 + jnp.einsum(
            "bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32), xb[:, 0].astype(jnp.float32)
        )
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), h_final)[:, None]

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, T, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm_apply(p["norm"], y)
    out = dense_apply(p["out_proj"], y)
    new_cache = {"conv": new_conv, "ssm": h_final.astype(jnp.float32)}
    return out, new_cache


# ================================================================== RWKV6
def rwkv6_init(key, d_model, *, head_dim, d_ff, lora_rank, dtype):
    from repro.models.layers import layernorm_init

    ks = jax.random.split(key, 12)
    p, s = {}, {}
    p["ln1"], s["ln1"] = layernorm_init(d_model, dtype)
    p["ln2"], s["ln2"] = layernorm_init(d_model, dtype)
    for i, name in enumerate(("r", "k", "v", "g", "w")):
        p[f"mu_{name}"] = jnp.full((d_model,), 0.5, dtype)
        s[f"mu_{name}"] = ("embed",)
    p["wr"], s["wr"] = dense_init(ks[0], d_model, d_model, "embed", "heads", dtype)
    p["wk"], s["wk"] = dense_init(ks[1], d_model, d_model, "embed", "heads", dtype)
    p["wv"], s["wv"] = dense_init(ks[2], d_model, d_model, "embed", "heads", dtype)
    p["wg"], s["wg"] = dense_init(ks[3], d_model, d_model, "embed", "heads", dtype)
    p["w_lora_a"], s["w_lora_a"] = dense_init(ks[4], d_model, lora_rank, "embed", "lora", dtype)
    p["w_lora_b"], s["w_lora_b"] = dense_init(ks[5], lora_rank, d_model, "lora", "heads", dtype)
    p["w_base"] = jnp.full((d_model,), -6.0, jnp.float32)
    s["w_base"] = ("heads",)
    p["u"] = truncnorm_init(ks[6], (d_model,), jnp.float32, 0.5)
    s["u"] = ("heads",)
    p["ln_x"] = {"scale": jnp.ones((d_model,), dtype)}
    s["ln_x"] = {"scale": ("heads",)}
    p["wo"], s["wo"] = dense_init(ks[7], d_model, d_model, "heads", "embed", dtype)
    # channel mix
    p["cm_mu_r"] = jnp.full((d_model,), 0.5, dtype)
    s["cm_mu_r"] = ("embed",)
    p["cm_mu_k"] = jnp.full((d_model,), 0.5, dtype)
    s["cm_mu_k"] = ("embed",)
    p["cm_wr"], s["cm_wr"] = dense_init(ks[8], d_model, d_model, "embed", "heads", dtype)
    p["cm_wk"], s["cm_wk"] = dense_init(ks[9], d_model, d_ff, "embed", "mlp", dtype)
    p["cm_wv"], s["cm_wv"] = dense_init(ks[10], d_ff, d_model, "mlp", "embed", dtype)
    return p, s


def _token_shift(x, mu, shift_state):
    """lerp(x_t, x_{t-1}, mu); shift_state: (B,1,D) previous last token."""
    prev = jnp.concatenate([shift_state.astype(x.dtype), x[:, :-1]], axis=1)
    return x + (prev - x) * mu[None, None]


def _wkv6_scan(r, k, v, w, u, s0):
    """Exact WKV6:  S_t = diag(w_t) S_{t-1} + k_t v_t';
                    y_t = r_t' (S_{t-1} + diag(u) k_t v_t').
    r,k,v,w: (B,T,H,dk); u: (H,dk); s0: (B,H,dk,dv).  Scan over T."""

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                        # (B,H,dk) each
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B,H,dk,dv)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[None, :, :, None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    rs, ks_, vs, ws = (t.transpose(1, 0, 2, 3) for t in (r, k, v, w))
    S_final, ys = jax.lax.scan(step, s0, (rs, ks_, vs, ws))
    return ys.transpose(1, 0, 2, 3), S_final            # (B,T,H,dv)


def rwkv6_apply(p, x, *, head_dim, d_ff, cache=None):
    """Time-mix (WKV6) + channel-mix, pre-LN block with internal residuals:
    x = x + tm(LN1(x)); out = x + cm(LN2(x)).  cache carries {'shift_tm',
    'shift_cm','wkv'} for decode; full-sequence mode returns final state."""
    from repro.models.layers import layernorm_apply

    B, T, D = x.shape
    H = D // head_dim
    if cache is None:
        shift_tm = jnp.zeros((B, 1, D), x.dtype)
        shift_cm = jnp.zeros((B, 1, D), x.dtype)
        s0 = jnp.zeros((B, H, head_dim, head_dim), jnp.float32)
    else:
        shift_tm, shift_cm, s0 = cache["shift_tm"], cache["shift_cm"], cache["wkv"]

    # ---- time mix
    xa = layernorm_apply(p["ln1"], x)
    xr = _token_shift(xa, p["mu_r"], shift_tm)
    xk = _token_shift(xa, p["mu_k"], shift_tm)
    xv = _token_shift(xa, p["mu_v"], shift_tm)
    xg = _token_shift(xa, p["mu_g"], shift_tm)
    xw = _token_shift(xa, p["mu_w"], shift_tm)
    r = dense_apply(p["wr"], xr).reshape(B, T, H, head_dim).astype(jnp.float32)
    k = dense_apply(p["wk"], xk).reshape(B, T, H, head_dim).astype(jnp.float32)
    v = dense_apply(p["wv"], xv).reshape(B, T, H, head_dim).astype(jnp.float32)
    g = dense_apply(p["wg"], xg)
    # data-dependent decay (Finch): w_t = exp(-exp(w_base + lora(x_w)))
    ww = p["w_base"][None, None] + dense_apply(
        p["w_lora_b"], jnp.tanh(dense_apply(p["w_lora_a"], xw))
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(jnp.clip(ww, -12.0, 3.0))).reshape(B, T, H, head_dim)
    u = p["u"].reshape(H, head_dim)

    y, s_final = _wkv6_scan(r, k, v, w, u, s0)
    y = y.reshape(B, T, D)
    # per-head group norm
    yh = y.reshape(B, T, H, head_dim)
    yh = yh * jax.lax.rsqrt(jnp.mean(jnp.square(yh), axis=-1, keepdims=True) + 1e-6)
    y = (yh.reshape(B, T, D) * p["ln_x"]["scale"].astype(jnp.float32)[None, None]).astype(x.dtype)
    tm_out = dense_apply(p["wo"], y * jax.nn.silu(g))
    h = x + tm_out

    # ---- channel mix
    hb = layernorm_apply(p["ln2"], h)
    hr = _token_shift(hb, p["cm_mu_r"], shift_cm)
    hk = _token_shift(hb, p["cm_mu_k"], shift_cm)
    rr = jax.nn.sigmoid(dense_apply(p["cm_wr"], hr))
    kk = jnp.square(jax.nn.relu(dense_apply(p["cm_wk"], hk)))
    cm_out = rr * dense_apply(p["cm_wv"], kk)
    out = h + cm_out

    new_cache = {
        "shift_tm": xa[:, -1:],
        "shift_cm": hb[:, -1:],
        "wkv": s_final,
    }
    return out, new_cache

"""Shared layers: norms, dense projections, SwiGLU MLP, RoPE, embeddings.

Every init returns (params, specs): specs mirror the param tree with tuples
of logical axis names consumed by repro.distributed.sharding.  Axis-name
vocabulary (resolution rules live in one place, sharding.py):

  "vocab"    embedding rows / lm-head cols        -> tensor axis
  "embed"    d_model                              -> fsdp axis (weights)
  "mlp"      ffn hidden                           -> tensor axis
  "heads"    q heads * head_dim (fused)           -> tensor axis
  "kv"       kv heads * head_dim (fused)          -> tensor axis
  "expert"   MoE expert count                     -> tensor axis (EP)
  "lora"     MLA latent dims                      -> replicated
  "conv"/"state"/"ssm"  SSM internals             -> see sharding.py
  "layers"   scan-stacked leading axis            -> never sharded
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def truncnorm_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def dense_init(key, in_dim, out_dim, in_ax, out_ax, dtype, *, bias=False, scale=None):
    scale = scale if scale is not None else (in_dim**-0.5)
    p = {"w": truncnorm_init(key, (in_dim, out_dim), dtype, scale)}
    s = {"w": (in_ax, out_ax)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        s["b"] = (out_ax,)
    return p, s


def dense_apply(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype)}, {"scale": ("embed",)}


def rmsnorm_apply(p, x, *, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim, dtype):
    return (
        {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
        {"scale": ("embed",), "bias": ("embed",)},
    )


def layernorm_apply(p, x, *, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(
        x.dtype
    )


def embedding_init(key, vocab, dim, dtype):
    return (
        {"table": truncnorm_init(key, (vocab, dim), dtype, 1.0)},
        {"table": ("vocab", "embed")},
    )


def embedding_apply(p, ids, *, iota_threshold: int = 8192):
    """Embedding lookup.

    Large vocabularies use the one-hot-matmul form: with the table sharded
    on the vocab axis, a gather forces the SPMD partitioner into an
    "involuntary full rematerialization" (replicate-the-table), and its
    transpose is a scatter.  one_hot @ table is a plain dot — it partitions
    cleanly along the vocab axis and its grad is another dot.  (Same trick
    as MaxText's use_iota_embed.)
    """
    table = p["table"]
    if table.shape[0] >= iota_threshold:
        onehot = jax.nn.one_hot(ids, table.shape[0], dtype=table.dtype)
        return onehot @ table
    return jnp.take(table, ids, axis=0)


# ------------------------------------------------------------------ SwiGLU
def swiglu_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    wi, si = dense_init(k1, d_model, d_ff, "embed", "mlp", dtype)
    wg, sg = dense_init(k2, d_model, d_ff, "embed", "mlp", dtype)
    wo, so = dense_init(k3, d_ff, d_model, "mlp", "embed", dtype)
    return {"wi": wi, "wg": wg, "wo": wo}, {"wi": si, "wg": sg, "wo": so}


def swiglu_apply(p, x):
    h = jax.nn.silu(dense_apply(p["wg"], x)) * dense_apply(p["wi"], x)
    return dense_apply(p["wo"], h)


# -------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions: (..., S) int -> (cos, sin) of shape (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope_apply(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, S, d); cos/sin: (B, S, d/2) or (S, d/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos_, sin_ = cos[None, None], sin[None, None]
    else:
        cos_, sin_ = cos[:, None], sin[:, None]
    out1 = x1 * cos_ - x2 * sin_
    out2 = x2 * cos_ + x1 * sin_
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)

"""Model builder + parameter accounting."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable          # (key) -> (params, specs)
    train_loss: Callable    # (params, batch, remat_policy=None) -> (loss, metrics)
    prefill: Callable       # (params, batch) -> (last_logits, caches)
    decode_step: Callable   # (params, token, caches, pos) -> (logits, caches)


def build_model(cfg: ArchConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: tfm.init_params(key, cfg),
        train_loss=lambda p, batch, remat_policy=None: tfm.forward_train(
            p, cfg, batch, remat_policy=remat_policy
        ),
        prefill=lambda p, batch: tfm.prefill(p, cfg, batch),
        decode_step=lambda p, token, caches, pos: tfm.decode_step(
            p, cfg, token, caches, pos
        ),
    )


def count_params(params) -> int:
    return int(sum(np.prod(leaf.shape) for leaf in jax.tree.leaves(params)))


def count_params_abstract(cfg: ArchConfig) -> int:
    shapes = jax.eval_shape(
        lambda k: tfm.init_params(k, cfg)[0], jax.random.key(0)
    )
    return int(sum(np.prod(leaf.shape) for leaf in jax.tree.leaves(shapes)))


def active_params(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: shared + top_k of routed experts) —
    the N in MODEL_FLOPS = 6*N*D."""
    total = count_params_abstract(cfg)
    if not cfg.moe:
        return total
    # routed expert params per layer
    per_expert = 3 * cfg.d_model * cfg.d_ff_expert
    routed = cfg.n_layers * cfg.n_experts * per_expert
    active_routed = cfg.n_layers * cfg.top_k * per_expert
    return total - routed + active_routed

"""Host-side edge accumulation for the streaming screener.

Collects the compacted (i, j, |S_ij|) triples each tile batch emits into
growing flat arrays (the O(#edges) term of the memory model) and, when a
serving session asks for it, a per-tile-pair record of the bounds needed to
re-validate the tile after a rank-k data update without recomputing it:

    min_above   smallest edge weight in the tile  (> lam by construction)
    max_below   largest off-diagonal |S_ij| <= lam (kernel ``stats[:, 1]``)

A tile whose [max_below + delta, min_above - delta] interval still brackets
lam after an update provably kept its edge SET (weights may be stale, the
partition at lam is not) — see ``stream.session``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class TileRecord:
    """Per-tile-pair screening outcome retained for session re-validation."""

    skipped: bool
    n_edges: int = 0
    min_above: float = np.inf
    max_below: float = 0.0
    # local edge arrays (global vertex ids + |S_ij|); kept only by sessions
    gi: np.ndarray | None = None
    gj: np.ndarray | None = None
    w: np.ndarray | None = None


def bin_edges_to_records(
    i_idx, j_idx, gi: np.ndarray, gj: np.ndarray, w: np.ndarray,
    stats: np.ndarray, *, tile: int,
) -> dict[tuple[int, int], TileRecord]:
    """Bin one computed batch's compacted edges back to per-tile-pair
    records — THE record constructor (the screen accumulator and the session
    re-screen both build certificates here, so the min_above/max_below
    conventions cannot drift apart)."""
    tile_of = gi // tile * np.int64(2**20) + gj // tile
    out: dict[tuple[int, int], TileRecord] = {}
    for t, (ti, tj) in enumerate(zip(i_idx, j_idx)):
        key = np.int64(ti) * np.int64(2**20) + np.int64(tj)
        sel = tile_of == key
        rec = TileRecord(
            skipped=False,
            n_edges=int(sel.sum()),
            max_below=float(stats[t, 1]),
            gi=gi[sel],
            gj=gj[sel],
            w=w[sel],
        )
        rec.min_above = float(rec.w.min()) if rec.n_edges else np.inf
        out[(int(ti), int(tj))] = rec
    return out


@dataclass
class EdgeAccumulator:
    """Growing edge store + optional per-tile records."""

    keep_tiles: bool = False
    chunks_i: list = field(default_factory=list)
    chunks_j: list = field(default_factory=list)
    chunks_w: list = field(default_factory=list)
    tiles: dict = field(default_factory=dict)  # (ti, tj) -> TileRecord
    n_edges: int = 0

    def add_skipped(self, pairs) -> None:
        if self.keep_tiles:
            for ti, tj in pairs:
                self.tiles[(int(ti), int(tj))] = TileRecord(skipped=True)

    def add_batch(
        self,
        i_idx: np.ndarray,
        j_idx: np.ndarray,
        gi: np.ndarray,
        gj: np.ndarray,
        w: np.ndarray,
        stats: np.ndarray,
        *,
        tile: int,
    ) -> None:
        """Absorb one computed batch: global edge triples + kernel stats."""
        if gi.size:
            self.chunks_i.append(gi)
            self.chunks_j.append(gj)
            self.chunks_w.append(w)
            self.n_edges += int(gi.size)
        if not self.keep_tiles:
            return
        self.tiles.update(
            bin_edges_to_records(i_idx, j_idx, gi, gj, w, stats, tile=tile)
        )

    def edges(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Concatenated (i, j, w), unsorted."""
        if not self.chunks_i:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy(), np.zeros(0, dtype=np.float64)
        return (
            np.concatenate(self.chunks_i),
            np.concatenate(self.chunks_j),
            np.concatenate(self.chunks_w),
        )

    def bytes_held(self) -> int:
        return sum(
            a.nbytes
            for chunks in (self.chunks_i, self.chunks_j, self.chunks_w)
            for a in chunks
        )

"""Configuration for the out-of-core streaming screener.

One knob object threads through the whole subsystem (tiler, driver, path
adapter, serving sessions).  The memory model it controls (DESIGN.md
Section 10):

    peak screening bytes  ~=  pair_batch * tile^2 * itemsize   (in-flight tiles)
                            + 3 * 8 * #edges                   (compacted edges)
                            + O(p)                             (moments, labels)

so ``memory_budget_mb`` simply solves for ``pair_batch``.  The dense (p, p)
covariance never exists; ``stream.bytes_peak`` (instrument watermark) records
what actually did.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class StreamConfig:
    """Knobs for one streaming screen.

    tile           column-tile width (the covgram_screen block_p); need not
                   divide p — the last tile is padded and masked.
    chunk          row-chunk height streamed per Gram accumulation step
                   (covgram_screen block_n on TPU; the numpy path reduces
                   whole columns at once and only uses it for the moments
                   pass).
    pair_batch     tile PAIRS computed in flight per kernel/oracle call; the
                   dominant peak-memory term.  Derived from
                   ``memory_budget_mb`` when that is set.
    memory_budget_mb  optional cap on the in-flight tile batch; overrides
                   pair_batch.
    backend        covgram_screen dispatch: "auto" (pallas on TPU, numpy
                   oracle elsewhere), "pallas", or "ref".
    skip_slack     relative inflation of the Cauchy-Schwarz tile-skip bound
                   sqrt(max_I S_ii * max_J S_jj) <= lam: floating-point Gram
                   accumulation can overshoot the exact bound by a few ulps,
                   so the skip test uses bound * (1 + skip_slack) <= lam.
                   Ties |S_ij| == lam are not edges (strict eq. (4)), so a
                   tile whose inflated bound equals lam is still computed,
                   never mis-skipped.
    """

    tile: int = 512
    chunk: int = 512
    pair_batch: int = 64
    memory_budget_mb: float | None = None
    backend: str = "auto"
    skip_slack: float = 1e-6

    def resolved_pair_batch(self, itemsize: int) -> int:
        if self.memory_budget_mb is None:
            return max(1, int(self.pair_batch))
        budget = self.memory_budget_mb * 2**20
        per_pair = self.tile * self.tile * itemsize
        return max(1, int(budget // max(per_pair, 1)))


def as_config(config) -> StreamConfig:
    """None -> defaults; dict -> kwargs; StreamConfig passes through."""
    if config is None:
        return StreamConfig()
    if isinstance(config, StreamConfig):
        return config
    if isinstance(config, dict):
        return StreamConfig(**config)
    raise TypeError(f"expected StreamConfig, dict, or None; got {type(config)!r}")

"""Incremental re-screening for growing datasets (rank-k covariance updates).

A ``DataSession`` pins one evolving (X, lambda) problem.  Appending k rows Y
perturbs every covariance entry, but bounded-ly:  with G = X'X, n' = n + k,

    S' - S = G (1/n' - 1/n) + Y'Y/n' + (mu mu' - mu~ mu~')

so  |S'_ij - S_ij| <= delta_IJ  per column-tile pair, where delta_IJ is
assembled from per-tile maxima of the uncentered column norms sqrt(G_ii),
the update's column norms, and the mean shift — all O(p) statistics.  A tile
pair whose previous screen left the certificate interval

    [max |S_ij| <= lam  (max_below),  min edge weight  (min_above)]

still clear of lambda after widening by delta provably kept its EDGE SET
(weights moved, no entry crossed the strict eq.-(4) threshold), so the
partition needs nothing from it; only pairs whose certificate broke are
recomputed (``stream.tiles_rescreened`` vs ``stream.tiles_revalidated``).
Skipped pairs re-validate even more cheaply against the fresh Cauchy-Schwarz
norm bound.  Certificates SHRINK by delta on every kept update, so stacked
appends stay conservative.

The union-find is rebuilt from the per-tile edge sets (merges AND splits are
handled — an edge can disappear), components touched by recomputed tiles are
reported for plan invalidation, and the per-component blocks are
re-materialized exactly from the updated X — stale weights never reach a
solver.  Sessions are single-lambda by construction (the serving admission
path is per-request anyway); the full-grid path planner re-screens instead.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core.instrument import bump
from repro.core.screening import ScreenStats
from repro.obs.trace import span
from repro.kernels.covgram_screen import (
    compact_edges,
    covgram_screen_tiles,
    pad_for_screen,
)
from repro.stream.accumulate import bin_edges_to_records
from repro.stream.config import as_config
from repro.stream.materialize import MaterializedCovariance, materialize_components
from repro.stream.screen import stream_screen
from repro.stream.tiler import column_moments, pair_skippable, tile_maxima
from repro.stream.unionfind import StreamingUnionFind


@dataclass
class SessionUpdate:
    """What one ``append_rows`` changed."""

    labels: np.ndarray
    stats: ScreenStats
    S: MaterializedCovariance
    tiles_rescreened: int
    tiles_revalidated: int
    components_touched: int


class DataSession:
    """Streaming screen state for one evolving dataset at one lambda."""

    def __init__(
        self, X: np.ndarray, lam: float, *, config=None,
        oversize: int | None = None,
    ):
        self.lam = float(lam)
        self.config = as_config(config)
        self.X = np.asarray(X)
        # single-device block cap: components past it materialize DEFERRED
        # (sharded route gathers them chunk-wise at solve time)
        self.oversize = oversize
        # append_rows mutates X/moments/tiles/labels as one transaction;
        # concurrent appends (serving exposes sessions to many clients)
        # must serialize or certificates detach from the moments they
        # were computed against
        self._lock = threading.Lock()
        bump("stream.sessions")
        sc = stream_screen(
            self.X, [self.lam], config=self.config, keep_tile_stats=True,
            oversize=oversize,
        )
        self.moments = sc.moments
        self.tiles = sc.tiles            # (ti, tj) -> TileRecord
        self.labels = sc.labels[0]
        self.stats = sc.stats[0]
        self.S = sc.S

    # -- delta bound -------------------------------------------------------

    def _tile_deltas(self, Y: np.ndarray, new_moments) -> np.ndarray:
        """Conservative per-tile-pair bound on |S'_ij - S_ij| (module doc)."""
        tile = self.config.tile
        old, new = self.moments, new_moments
        n, k = old.n, Y.shape[0]
        n2 = n + k
        g_old = tile_maxima(old.gram_norms, tile)
        y_norm = tile_maxima(
            np.sqrt((Y.astype(np.float64) ** 2).sum(axis=0)), tile
        )
        mu_old = tile_maxima(np.abs(old.mu), tile)
        mu_new = tile_maxima(np.abs(new.mu), tile)
        dmu = tile_maxima(np.abs(new.mu - old.mu), tile)
        nt = g_old.shape[0]
        ti, tj = np.triu_indices(nt)
        delta = (
            g_old[ti] * g_old[tj] * (1.0 / n - 1.0 / n2)
            + y_norm[ti] * y_norm[tj] / n2
            + dmu[ti] * mu_old[tj]
            + mu_new[ti] * dmu[tj]
        ) * (1.0 + self.config.skip_slack)
        out = np.zeros((nt, nt))
        out[ti, tj] = delta
        return out

    # -- the incremental re-screen ----------------------------------------

    def append_rows(self, Y: np.ndarray) -> SessionUpdate:
        """Absorb k new data rows; re-screen only the tiles whose
        certificate the perturbation bound cannot clear.  Thread-safe:
        concurrent appends serialize on the session lock."""
        with self._lock, span(
            "session.append_rows", k=int(np.atleast_2d(np.asarray(Y)).shape[0])
        ):
            return self._append_rows_locked(Y)

    def _append_rows_locked(self, Y: np.ndarray) -> SessionUpdate:
        t0 = time.perf_counter()
        Y = np.atleast_2d(np.asarray(Y))
        if Y.shape[1] != self.X.shape[1]:
            raise ValueError(
                f"appended rows have p={Y.shape[1]}, session has "
                f"p={self.X.shape[1]}"
            )
        cfg = self.config
        lam, tile = self.lam, cfg.tile
        X2 = np.concatenate([self.X, Y], axis=0)
        new_moments = column_moments(X2, chunk=cfg.chunk)
        deltas = self._tile_deltas(Y, new_moments)
        norms_max = tile_maxima(new_moments.norms, tile)

        invalid: list[tuple[int, int]] = []
        for (ti, tj), rec in self.tiles.items():
            if rec.skipped:
                # fresh Cauchy-Schwarz bound (the schedule's predicate):
                # still provably edge-free?
                if pair_skippable(
                    norms_max, ti, tj, lam, slack=cfg.skip_slack
                ):
                    continue
                invalid.append((ti, tj))
            else:
                d = deltas[ti, tj]
                if rec.min_above - d > lam and rec.max_below + d <= lam:
                    # certificate holds: edge set unchanged; shrink it so
                    # stacked updates stay conservative
                    rec.min_above -= d
                    rec.max_below += d
                    continue
                invalid.append((ti, tj))

        touched_vertices: set[int] = set()
        for key in invalid:
            rec = self.tiles[key]
            if rec.gi is not None and rec.gi.size:
                touched_vertices.update(rec.gi.tolist())
                touched_vertices.update(rec.gj.tolist())

        n2, p = X2.shape
        if invalid:
            x_pad, mu_pad = pad_for_screen(
                X2, new_moments.mu, block_n=cfg.chunk, block_p=tile
            )
            batch = cfg.resolved_pair_batch(
                4 if cfg.backend == "pallas" else x_pad.dtype.itemsize
            )
            inv_i = np.array([t for t, _ in invalid], dtype=np.int32)
            inv_j = np.array([t for _, t in invalid], dtype=np.int32)
            for b0 in range(0, inv_i.size, batch):
                bi, bj = inv_i[b0 : b0 + batch], inv_j[b0 : b0 + batch]
                vals, _, stats = covgram_screen_tiles(
                    x_pad, mu_pad, bi, bj, lam,
                    n_true=n2, p_true=p, block_p=tile, block_n=cfg.chunk,
                    backend=cfg.backend,
                )
                gi, gj, w = compact_edges(vals, bi, bj, block_p=tile)
                fresh = bin_edges_to_records(
                    bi, bj, gi, gj, w, stats, tile=tile
                )
                self.tiles.update(fresh)
                for rec in fresh.values():
                    if rec.gi.size:
                        touched_vertices.update(rec.gi.tolist())
                        touched_vertices.update(rec.gj.tolist())

        bump("stream.tiles_rescreened", len(invalid))
        n_valid = len(self.tiles) - len(invalid)
        bump("stream.tiles_revalidated", n_valid)

        # rebuild the partition from the per-tile edge sets (splits included)
        uf = StreamingUnionFind(p)
        n_edges = 0
        for rec in self.tiles.values():
            if rec.gi is not None and rec.gi.size:
                uf.union_edges(rec.gi, rec.gj)
                n_edges += int(rec.gi.size)
        labels = uf.labels()

        old_labels = self.labels
        touched_roots = {int(labels[v]) for v in touched_vertices} | {
            int(old_labels[v]) for v in touched_vertices
        }
        components_touched = len(touched_roots)
        bump("stream.session_components_touched", components_touched)

        S = materialize_components(
            X2, new_moments.mu, new_moments.diag, labels,
            oversize=self.oversize,
        )
        _, counts = np.unique(labels, return_counts=True)
        stats = ScreenStats(
            lam=lam,
            n_components=int(counts.size),
            max_comp=int(counts.max()),
            n_isolated=int((counts == 1).sum()),
            n_edges=n_edges,
            seconds=time.perf_counter() - t0,
            tiles_total=len(self.tiles),
            tiles_skipped=sum(1 for r in self.tiles.values() if r.skipped),
            edges_emitted=n_edges,
            bytes_peak=self.stats.bytes_peak,
        )

        self.X, self.moments = X2, new_moments
        self.labels, self.stats, self.S = labels, stats, S
        return SessionUpdate(
            labels=labels,
            stats=stats,
            S=S,
            tiles_rescreened=len(invalid),
            tiles_revalidated=n_valid,
            components_touched=components_touched,
        )

"""Incremental host union-find over unsorted streamed edge batches.

Unlike ``core.partition.labels_at_thresholds`` (one pass over PRE-SORTED
edges — which the screen driver uses, since it retains the full weighted
edge list anyway), this structure absorbs unsorted batches as they arrive
with no sort and no weights.  That is the session layer's shape of the
problem: after a rank-k data update the surviving per-tile edge SETS are
known but a global sorted sweep would be wasted work for a single-lambda
partition, so ``stream.session`` rebuilds through here (merges AND splits
— the rebuild starts from fresh parents).
"""

from __future__ import annotations

import numpy as np


class StreamingUnionFind:
    """Union-find over p vertices with batched edge absorption."""

    def __init__(self, p: int):
        self.p = int(p)
        self.parent = np.arange(self.p)
        self.n_components = self.p

    def _find(self, i: int) -> int:
        parent = self.parent
        root = i
        while parent[root] != root:
            root = parent[root]
        while parent[i] != root:  # path compression
            parent[i], i = root, parent[i]
        return root

    def union_edges(self, gi: np.ndarray, gj: np.ndarray) -> int:
        """Absorb one batch of edges; returns the number of merges."""
        merges = 0
        for a, b in zip(gi.tolist(), gj.tolist()):
            ra, rb = self._find(a), self._find(b)
            if ra != rb:
                # union toward the smaller root keeps labels canonical-ish;
                # labels() canonicalizes regardless
                if ra < rb:
                    self.parent[rb] = ra
                else:
                    self.parent[ra] = rb
                merges += 1
        self.n_components -= merges
        return merges

    def labels(self) -> np.ndarray:
        """Canonical labels (labels[i] == smallest vertex in i's component)."""
        from repro.core.components import canonicalize_labels

        roots = np.fromiter(
            (self._find(i) for i in range(self.p)), np.int64, self.p
        )
        return canonicalize_labels(roots)

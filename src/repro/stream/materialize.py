"""Component materializer: the only covariance entries the solvers ever see.

Theorem 1 reduces the glasso solve to independent blocks over the screened
components, and Theorem 2 nests every partition of a descending lambda grid
inside the partition at the grid minimum — so the union of all covariance
entries any plan on the grid can request is exactly the per-component
sub-blocks S[C, C] of that COARSEST partition.  ``materialize_components``
gathers them straight from X (centered column gather + one small Gram per
component, the same arithmetic as the dense estimator), and
``MaterializedCovariance`` serves them through the gather protocol
(``gather_block`` / ``gather_block_rows`` / ``diag_at``) that
``core.blocks`` and ``engine.structure`` dispatch on — the planner,
executor, classifier, and assembler consume materialized blocks UNCHANGED,
never a (p, p) array.

OVERSIZE components (larger than the planner's single-device threshold) are
DEFERRED: no host block is built at all — only the component's index set is
recorded, and ``shard_gather`` later streams the block straight from X into
row shards on the device mesh, one (b/d, b) chunk at a time.  The full
(b, b) host copy of a giant component never exists anywhere on the host;
host peak for it is one row chunk plus the O(n * b) centered column gather.

Memory: sum of materialized block sizes squared (what the solve stage holds
anyway) plus an O(n * max_comp) gather scratch per component.
"""

from __future__ import annotations

import numpy as np

from repro.core.instrument import bump, set_peak


class MaterializedCovariance:
    """Per-component covariance blocks + diagonal, masquerading as S.

    Supports exactly the access patterns the Plan->Execute pipeline uses:
    ``shape``, ``gather_block(idx)`` for same-component index sets (bucket
    padding, structure classification), ``gather_block_rows(rows, cols)``
    (the sharded route's chunked fetch), and ``diag_at(idx)``
    (isolated-vertex assembly).  Cross-component off-block entries do not
    exist — by Theorem 1 they are never needed; asking for them is a bug and
    raises.  DEFERRED (oversize) components keep no host block: their
    entries are recomputed from the retained (X, mu) restriction on demand,
    which the sharded gather does row-chunk by row-chunk.
    """

    def __init__(
        self, p: int, diag: np.ndarray, blocks: dict[int, np.ndarray],
        root_of: np.ndarray, pos_in: np.ndarray,
        deferred: dict[int, np.ndarray] | None = None,
    ):
        self.p = int(p)
        self._diag = diag
        self._blocks = blocks          # component root -> (b, b) block
        self._root_of = root_of        # vertex -> component root
        self._pos_in = pos_in          # vertex -> row within its block
        # component root -> centered X[:, comp] columns (n, b)
        self._deferred = deferred or {}
        self.dtype = diag.dtype

    @property
    def shape(self) -> tuple[int, int]:
        return (self.p, self.p)

    def _common_root(self, idx: np.ndarray) -> int:
        roots = self._root_of[idx]
        root = int(roots[0])
        if not (roots == root).all():
            raise ValueError(
                "gather called across components — Theorem 1 says no stage "
                "should ever need those entries"
            )
        return root

    def _deferred_rows(self, root: int, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
        """S[rows, cols] of a deferred component, from the retained centered
        columns: one (len(rows), len(cols)) Gram chunk, exact diagonal."""
        Xc = self._deferred[root]
        pos = self._pos_in
        out = (Xc[:, pos[rows]].T @ Xc[:, pos[cols]]) / Xc.shape[0]
        same = rows[:, None] == cols[None, :]
        if same.any():
            ri, ci = np.nonzero(same)
            out[ri, ci] = self._diag[rows[ri]]
        return out.astype(self.dtype, copy=False)

    def gather_block(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx)
        root = self._common_root(idx)
        blk = self._blocks.get(root)
        if blk is None and root in self._deferred:
            bump("stream.deferred_gathers")
            return self._deferred_rows(root, idx, idx)
        if blk is None:  # all-isolated gather (diagonal only)
            out = np.zeros((idx.size, idx.size), dtype=self.dtype)
            np.fill_diagonal(out, self._diag[idx])
            return out
        pos = self._pos_in[idx]
        return blk[np.ix_(pos, pos)]

    def gather_block_rows(self, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        root = self._common_root(np.concatenate([rows, cols]))
        blk = self._blocks.get(root)
        if blk is None and root in self._deferred:
            bump("stream.deferred_gathers")
            return self._deferred_rows(root, rows, cols)
        if blk is None:
            out = np.zeros((rows.size, cols.size), dtype=self.dtype)
            same = rows[:, None] == cols[None, :]
            ri, ci = np.nonzero(same)
            out[ri, ci] = self._diag[rows[ri]]
            return out
        return blk[np.ix_(self._pos_in[rows], self._pos_in[cols])]

    def diag_at(self, idx) -> np.ndarray:
        return self._diag[idx]

    def nbytes(self) -> int:
        return (
            self._diag.nbytes
            + self._root_of.nbytes
            + self._pos_in.nbytes
            + sum(b.nbytes for b in self._blocks.values())
            + sum(Xc.nbytes for Xc in self._deferred.values())
        )


def materialize_components(
    X: np.ndarray,
    mu: np.ndarray,
    diag: np.ndarray,
    labels: np.ndarray,
    *,
    dtype=np.float64,
    oversize: int | None = None,
) -> MaterializedCovariance:
    """Gather S[C, C] for every non-singleton component of ``labels``.

    Blocks are computed as (X[:, C] - mu[C])'(X[:, C] - mu[C]) / n — the
    dense estimator's arithmetic restricted to C, so streamed and dense
    pipelines solve numerically identical subproblems (bit-identical on
    exactly-representable data).  The (p,) ``diag`` comes from the moments
    pass; block diagonals are overwritten with it so isolated-vertex
    assembly and block solves see one consistent S_ii.

    Components larger than ``oversize`` are DEFERRED: only their centered
    column restriction (n x b, the gather scratch that exists transiently
    anyway) is retained, and the (b, b) block is never formed on the host —
    ``shard_gather`` later streams it chunk-wise into device shards."""
    from repro.core.components import component_lists

    X = np.asarray(X)
    n, p = X.shape
    root_of = np.asarray(labels, dtype=np.int64)
    pos_in = np.zeros(p, dtype=np.int64)
    blocks: dict[int, np.ndarray] = {}
    deferred: dict[int, np.ndarray] = {}
    for comp in component_lists(labels):
        pos_in[comp] = np.arange(comp.size)
        if comp.size == 1:
            continue
        Xc = X[:, comp].astype(dtype, copy=False) - mu[comp].astype(dtype)
        if oversize is not None and comp.size > oversize:
            deferred[int(root_of[comp[0]])] = Xc
            bump("stream.deferred_components")
            continue
        B = (Xc.T @ Xc) / n
        B = 0.5 * (B + B.T)
        np.fill_diagonal(B, diag[comp].astype(dtype))
        blocks[int(root_of[comp[0]])] = B
    mat = MaterializedCovariance(
        p, diag.astype(dtype), blocks, root_of, pos_in, deferred
    )
    set_peak("stream.bytes_peak", mat.nbytes())
    return mat


def shard_gather(S, comp: np.ndarray, mesh, *, axis: str = "data", dtype=None):
    """Gather S[comp, comp] STRAIGHT into row shards on the mesh.

    The sharded oversize route's loader: for each device d owning padded
    rows [d*rl, (d+1)*rl), fetch just that (rl, b) row chunk through the
    gather protocol (``blocks.gather_submatrix_rows`` — dense slices, a
    materialized block's row view, or a deferred streamed component's
    on-the-fly Gram chunk), identity-pad it to (rl, bp), and place it on its
    device; the shards assemble into one row-sharded (bp, bp) jax array via
    ``make_array_from_single_device_arrays``.  Host peak is ONE row chunk —
    the full (b, b) block never exists on the host, which is what lets a
    giant component stream from X into the mesh within budget."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.blocks import gather_submatrix_rows
    from repro.core.solvers.sharded import mesh_axis_size, sharded_pad_size

    comp = np.asarray(comp)
    b = comp.size
    d = mesh_axis_size(mesh, axis)
    bp = sharded_pad_size(b, d)
    rl = bp // d
    np_dtype = np.dtype("float64" if dtype is None else np.dtype(dtype).name)
    sharding = NamedSharding(mesh, P(axis, None))
    devices = list(mesh.devices.flatten())
    shards = []
    for k, dev in enumerate(devices):
        lo, hi = k * rl, (k + 1) * rl
        chunk = np.zeros((rl, bp), dtype=np_dtype)
        n_true = max(0, min(hi, b) - lo)
        if n_true:
            chunk[:n_true, :b] = gather_submatrix_rows(
                S, comp[lo : lo + n_true], comp, dtype=np_dtype
            )
        pad_rows = np.arange(max(lo, b), hi)  # identity rows past the block
        chunk[pad_rows - lo, pad_rows] = 1.0
        shards.append(jax.device_put(chunk, dev))
        bump("stream.shard_chunks")
    return jax.make_array_from_single_device_arrays(
        (bp, bp), sharding, shards
    )

"""Component materializer: the only covariance entries the solvers ever see.

Theorem 1 reduces the glasso solve to independent blocks over the screened
components, and Theorem 2 nests every partition of a descending lambda grid
inside the partition at the grid minimum — so the union of all covariance
entries any plan on the grid can request is exactly the per-component
sub-blocks S[C, C] of that COARSEST partition.  ``materialize_components``
gathers them straight from X (centered column gather + one small Gram per
component, the same arithmetic as the dense estimator), and
``MaterializedCovariance`` serves them through the two-method gather
protocol (``gather_block`` / ``diag_at``) that ``core.blocks`` and
``engine.structure`` dispatch on — the planner, executor, classifier, and
assembler consume materialized blocks UNCHANGED, never a (p, p) array.

Memory: sum of block sizes squared (what the solve stage holds anyway) plus
an O(n * max_comp) gather scratch per component.
"""

from __future__ import annotations

import numpy as np

from repro.core.instrument import set_peak


class MaterializedCovariance:
    """Per-component covariance blocks + diagonal, masquerading as S.

    Supports exactly the access patterns the Plan->Execute pipeline uses:
    ``shape``, ``gather_block(idx)`` for same-component index sets (bucket
    padding, structure classification), and ``diag_at(idx)`` (isolated-vertex
    assembly).  Cross-component off-block entries do not exist — by
    Theorem 1 they are never needed; asking for them is a bug and raises.
    """

    def __init__(
        self, p: int, diag: np.ndarray, blocks: dict[int, np.ndarray],
        root_of: np.ndarray, pos_in: np.ndarray,
    ):
        self.p = int(p)
        self._diag = diag
        self._blocks = blocks          # component root -> (b, b) block
        self._root_of = root_of        # vertex -> component root
        self._pos_in = pos_in          # vertex -> row within its block
        self.dtype = diag.dtype

    @property
    def shape(self) -> tuple[int, int]:
        return (self.p, self.p)

    def gather_block(self, idx: np.ndarray) -> np.ndarray:
        idx = np.asarray(idx)
        roots = self._root_of[idx]
        root = int(roots[0])
        if not (roots == root).all():
            raise ValueError(
                "gather_block called across components — Theorem 1 says no "
                "stage should ever need those entries"
            )
        blk = self._blocks.get(root)
        if blk is None:  # all-isolated gather (diagonal only)
            out = np.zeros((idx.size, idx.size), dtype=self.dtype)
            np.fill_diagonal(out, self._diag[idx])
            return out
        pos = self._pos_in[idx]
        return blk[np.ix_(pos, pos)]

    def diag_at(self, idx) -> np.ndarray:
        return self._diag[idx]

    def nbytes(self) -> int:
        return self._diag.nbytes + sum(b.nbytes for b in self._blocks.values())


def materialize_components(
    X: np.ndarray,
    mu: np.ndarray,
    diag: np.ndarray,
    labels: np.ndarray,
    *,
    dtype=np.float64,
) -> MaterializedCovariance:
    """Gather S[C, C] for every non-singleton component of ``labels``.

    Blocks are computed as (X[:, C] - mu[C])'(X[:, C] - mu[C]) / n — the
    dense estimator's arithmetic restricted to C, so streamed and dense
    pipelines solve numerically identical subproblems (bit-identical on
    exactly-representable data).  The (p,) ``diag`` comes from the moments
    pass; block diagonals are overwritten with it so isolated-vertex
    assembly and block solves see one consistent S_ii."""
    from repro.core.components import component_lists

    X = np.asarray(X)
    n, p = X.shape
    root_of = np.asarray(labels, dtype=np.int64)
    pos_in = np.zeros(p, dtype=np.int64)
    blocks: dict[int, np.ndarray] = {}
    for comp in component_lists(labels):
        pos_in[comp] = np.arange(comp.size)
        if comp.size == 1:
            continue
        Xc = X[:, comp].astype(dtype, copy=False) - mu[comp].astype(dtype)
        B = (Xc.T @ Xc) / n
        B = 0.5 * (B + B.T)
        np.fill_diagonal(B, diag[comp].astype(dtype))
        blocks[int(root_of[comp[0]])] = B
    mat = MaterializedCovariance(
        p, diag.astype(dtype), blocks, root_of, pos_in
    )
    set_peak("stream.bytes_peak", mat.nbytes())
    return mat

"""Path adapter: a whole descending-lambda plan straight from X.

``plan_path_streaming`` is the streamed twin of ``engine.planner.plan_path``:
one ``stream_screen`` call replaces the dense sort+union-find pass, then
every lambda's plan is built by the SAME ``build_plan_incremental`` — with
``S`` being the materialized per-component blocks — so PR-1's nested-lambda
diffing (bucket reuse by (padded size, structure, membership) key, counted
in ``planner.buckets_reused``) and PR-2's structure routing work unchanged
against streamed edge weights.  The executor consumes the resulting
``PathPlan`` exactly as a dense one.
"""

from __future__ import annotations

import numpy as np

from repro.engine.planner import (
    PathPlan,
    PathStep,
    build_plan_incremental,
    component_lifetimes,
)
from repro.stream.screen import StreamScreen, stream_screen


def plan_path_from_screen(
    sc: StreamScreen,
    *,
    dtype=np.float64,
    classify_structures: bool = True,
    oversize: int | None = None,
) -> PathPlan:
    """Build the per-lambda plans over an existing streamed screen."""
    if sc.S is None:
        raise ValueError(
            "plan_path_from_screen needs a materialized screen "
            "(stream_screen(..., materialize=True))"
        )
    life = component_lifetimes(sc.labels)
    path = PathPlan(p=sc.p, lambdas=list(sc.lambdas))
    prev_plan = None
    for lam, labels, stats in zip(sc.lambdas, sc.labels, sc.stats):
        plan, reused = build_plan_incremental(
            sc.S, lam, labels, prev=prev_plan, dtype=dtype,
            classify_structures=classify_structures, oversize=oversize,
            lifetime_of=life,
        )
        path.steps.append(
            PathStep(
                lam=lam, labels=labels, plan=plan, screen=stats,
                reused_keys=reused,
            )
        )
        prev_plan = plan
    return path


def plan_path_streaming(
    X: np.ndarray,
    lambdas,
    *,
    config=None,
    dtype=np.float64,
    classify_structures: bool = True,
    oversize: int | None = None,
) -> tuple[PathPlan, StreamScreen]:
    """Screen X out-of-core at every lambda and plan the whole path.

    Returns (path, screen) — the screen carries the streamed edges, moments,
    and counters for callers that want them (serving sessions, benchmarks).
    ``oversize`` (single-device block cap) defers giant components to the
    sharded route: no host block, shard-direct gather at solve time.
    """
    sc = stream_screen(X, lambdas, config=config, oversize=oversize)
    return (
        plan_path_from_screen(
            sc, dtype=dtype, classify_structures=classify_structures,
            oversize=oversize,
        ),
        sc,
    )

"""The out-of-core screening driver: Theorem-1 partitions straight from X.

``stream_screen(X, lambdas)`` computes, without ever materializing the
(p, p) covariance:

  1. MOMENTS   one chunked pass over X -> mu, S_ii, column norms (tiler);
  2. SCHEDULE  upper-triangular column-tile pairs, minus every pair the
               Cauchy-Schwarz bound  max_I sqrt(S_ii) * max_J sqrt(S_jj)
               <= min(lambdas)  proves edge-free (``stream.tiles_skipped``);
  3. STREAM    surviving pairs flow in bounded batches through the fused
               covgram_screen kernel (Pallas on TPU, numpy oracle off-TPU);
               each batch compacts to (i, j, |S_ij|) triples in the edge
               accumulator;
  4. SNAPSHOT  the retained edges, sorted once, replay the planner's nested
               Theorem-2 sweep (``labels_at_thresholds_from_edges``) — one
               incremental union-find pass labeling every requested lambda,
               the coarsest (grid-minimum) partition included;
  5. MATERIALIZE  per-component covariance sub-blocks of the coarsest
               partition are gathered from X — the only entries any plan on
               the grid can request.

Peak memory is  O(p * tile + #edges)  (in-flight tile batch + edge store +
O(p) moments/labels), recorded live in the ``stream.bytes_peak`` watermark;
the exactness story is unchanged — the emitted partition is property-tested
identical to ``thresholded_components`` on a dense S, ties included.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.instrument import bump, set_peak
from repro.core.partition import labels_at_thresholds_from_edges
from repro.core.screening import ScreenStats
from repro.kernels.covgram_screen import (
    compact_edges,
    covgram_screen_tiles,
    pad_for_screen,
)
from repro.stream.accumulate import EdgeAccumulator
from repro.stream.config import StreamConfig, as_config
from repro.stream.materialize import MaterializedCovariance, materialize_components
from repro.stream.tiler import (
    Moments,
    column_moments,
    tile_maxima,
    tile_pair_schedule,
)


@dataclass
class StreamScreen:
    """Everything downstream stages need, and nothing dense."""

    p: int
    n: int
    lambdas: list[float]                    # descending
    labels: list[np.ndarray]                # per lambda, canonical
    stats: list[ScreenStats]                # per lambda
    edges: tuple                            # (i, j, w) sorted by w descending
    S: MaterializedCovariance | None
    moments: Moments
    config: StreamConfig
    seconds: float
    tiles: dict = field(default_factory=dict)   # (ti, tj) -> TileRecord
    tiles_total: int = 0
    tiles_skipped: int = 0


def stream_screen(
    X: np.ndarray,
    lambdas,
    *,
    config=None,
    keep_tile_stats: bool = False,
    materialize: bool = True,
    oversize: int | None = None,
) -> StreamScreen:
    """Screen (X, every lambda) out-of-core; see the module docstring.

    ``oversize`` is the planner's single-device block-size cap: components
    larger than it are materialized DEFERRED (no host block — the sharded
    solve route streams them chunk-wise into device shards via
    ``materialize.shard_gather``)."""
    from repro.select.grid import normalize_lambda_grid  # lazy: select imports engine

    cfg = as_config(config)
    t0 = time.perf_counter()
    X = np.asarray(X)
    n, p = X.shape
    lams = normalize_lambda_grid(lambdas)
    lam_min = lams[-1]

    moments = column_moments(X, chunk=cfg.chunk)
    norms_max = tile_maxima(moments.norms, cfg.tile)
    ti, tj, keep = tile_pair_schedule(
        norms_max, lam_min, slack=cfg.skip_slack
    )
    bump("stream.tiles_total", int(ti.size))
    bump("stream.tiles_skipped", int((~keep).sum()))

    acc = EdgeAccumulator(keep_tiles=keep_tile_stats)
    acc.add_skipped(zip(ti[~keep], tj[~keep]))

    x_pad, mu_pad = pad_for_screen(X, moments.mu, block_n=cfg.chunk, block_p=cfg.tile)
    itemsize = 4 if cfg.backend == "pallas" else x_pad.dtype.itemsize
    batch = cfg.resolved_pair_batch(itemsize)
    i_keep = ti[keep].astype(np.int32)
    j_keep = tj[keep].astype(np.int32)
    base_bytes = x_pad.nbytes + 4 * p * 8  # padded X + moments vectors
    local_peak = base_bytes
    for b0 in range(0, i_keep.size, batch):
        bi = i_keep[b0 : b0 + batch]
        bj = j_keep[b0 : b0 + batch]
        vals, _, stats = covgram_screen_tiles(
            x_pad,
            mu_pad,
            bi,
            bj,
            lam_min,
            n_true=n,
            p_true=p,
            block_p=cfg.tile,
            block_n=cfg.chunk,
            backend=cfg.backend,
        )
        gi, gj, w = compact_edges(vals, bi, bj, block_p=cfg.tile)
        acc.add_batch(bi, bj, gi, gj, w, stats, tile=cfg.tile)
        local_peak = max(local_peak, base_bytes + vals.nbytes + acc.bytes_held())
        set_peak("stream.bytes_peak", local_peak)
    bump("stream.edges_emitted", acc.n_edges)

    ei, ej, ew = acc.edges()
    order = np.argsort(-ew, kind="stable")
    edges = (ei[order], ej[order], ew[order])
    labels = labels_at_thresholds_from_edges(p, lams, edges)

    seconds = time.perf_counter() - t0
    per_lam = seconds / max(len(lams), 1)
    stats_list = []
    for lam, lab in zip(lams, labels):
        _, counts = np.unique(lab, return_counts=True)
        stats_list.append(
            ScreenStats(
                lam=lam,
                n_components=int(counts.size),
                max_comp=int(counts.max()),
                n_isolated=int((counts == 1).sum()),
                # edges sorted descending; strict |S_ij| > lam (eq. (4))
                n_edges=int(np.searchsorted(-edges[2], -lam, side="left")),
                seconds=per_lam,
                tiles_total=int(ti.size),
                tiles_skipped=int((~keep).sum()),
                edges_emitted=acc.n_edges,
                bytes_peak=0,  # filled below once materialization lands
            )
        )

    S = None
    if materialize:
        # the coarsest partition is the grid-minimum snapshot of the same
        # Theorem-2 sweep (lams is descending, so labels[-1]); every finer
        # plan gathers sub-blocks of these blocks.  Merging edges into a
        # live union-find DURING the stream would duplicate the sweep's
        # O(#edges) work per call — that incremental structure is the
        # session layer's tool, where edge sets arrive per-tile
        # (stream.session / stream.unionfind).
        S = materialize_components(
            X, moments.mu, moments.diag, labels[-1], oversize=oversize
        )
        local_peak = max(local_peak, base_bytes + acc.bytes_held() + S.nbytes())
        set_peak("stream.bytes_peak", local_peak)
    for st in stats_list:
        st.bytes_peak = local_peak
    seconds = time.perf_counter() - t0
    return StreamScreen(
        p=p,
        n=n,
        lambdas=lams,
        labels=labels,
        stats=stats_list,
        edges=edges,
        S=S,
        moments=moments,
        config=cfg,
        seconds=seconds,
        tiles=acc.tiles,
        tiles_total=int(ti.size),
        tiles_skipped=int((~keep).sum()),
    )

"""Tiled moment/Gram accumulation and the tile-pair schedule.

Two consumers share the row-chunked accumulation idiom defined here:

* the streaming screener's MOMENTS PASS (``column_moments``): one numpy
  sweep over row chunks of X yields the column means, the centered diagonal
  S_ii, and the uncentered column norms — O(p) state, chunk-at-a-time
  upcast, never an (n, p) copy;
* ``covariance.estimators.sample_covariance`` for low-precision inputs
  (``centered_gram_chunked``): the jnp twin, a ``lax.scan`` over row chunks
  that upcasts INSIDE the scan body so the promised "upcast tile-by-tile"
  is what actually happens — the full-precision (n, p) copy never exists.

The tile-pair schedule implements the screener's early skip.  By
Cauchy-Schwarz, |S_ij| <= sqrt(S_ii * S_jj), so a pair of column tiles
(I, J) with  max_I sqrt(S_ii) * max_J sqrt(S_jj) * (1 + slack) <= lam  can
contain no edge of eq. (4) at any lambda >= lam and is never computed — the
paper's large-lambda regime turns most of the p^2/(2*tile^2) pairs into
zero-cost skips (``stream.tiles_skipped``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Moments:
    """O(p) sufficient statistics of one pass over X."""

    n: int
    mu: np.ndarray        # column means, f64
    diag: np.ndarray      # centered S_ii = sum((x_i - mu_i)^2) / n
    sqsum: np.ndarray     # uncentered sum x_i^2 — sqrt(G_ii) feeds the
                          # session layer's rank-k perturbation bounds

    @property
    def norms(self) -> np.ndarray:
        """sqrt(S_ii) — the per-column Cauchy-Schwarz factors."""
        return np.sqrt(np.maximum(self.diag, 0.0))

    @property
    def gram_norms(self) -> np.ndarray:
        """sqrt(G_ii) = uncentered column 2-norms (session delta bounds)."""
        return np.sqrt(np.maximum(self.sqsum, 0.0))


def column_moments(X: np.ndarray, *, chunk: int = 4096) -> Moments:
    """Two chunked passes (mean, then centered square) in f64 accumulation.

    The second pass centers each chunk against the final mean, so ``diag``
    matches a dense  diag((X-mu)'(X-mu))/n  estimator to f64 roundoff (and
    exactly, on exactly-representable data)."""
    X = np.asarray(X)
    n, p = X.shape
    colsum = np.zeros(p, dtype=np.float64)
    sqsum = np.zeros(p, dtype=np.float64)
    for r0 in range(0, n, chunk):
        c = X[r0 : r0 + chunk].astype(np.float64, copy=False)
        colsum += c.sum(axis=0)
        sqsum += (c * c).sum(axis=0)
    mu = colsum / n
    css = np.zeros(p, dtype=np.float64)
    for r0 in range(0, n, chunk):
        c = X[r0 : r0 + chunk].astype(np.float64, copy=False) - mu
        css += (c * c).sum(axis=0)
    return Moments(n=n, mu=mu, diag=css / n, sqsum=sqsum)


def tile_maxima(values: np.ndarray, tile: int) -> np.ndarray:
    """Per-column-tile maximum of a (p,) vector (last tile may be short)."""
    p = values.shape[0]
    nt = -(-p // tile)
    out = np.empty(nt, dtype=np.float64)
    for t in range(nt):
        out[t] = values[t * tile : (t + 1) * tile].max(initial=0.0)
    return out


def pair_skippable(
    norms_max: np.ndarray, ti, tj, lam: float, *, slack: float
) -> np.ndarray:
    """THE skip predicate (one definition site — the screen schedule and the
    session re-validation must never drift apart):  a tile pair holds no
    strict eq.-(4) edge at any lambda >= lam iff
    norms_max[ti] * norms_max[tj] * (1 + slack) <= lam."""
    return norms_max[ti] * norms_max[tj] * (1.0 + slack) <= lam


def tile_pair_schedule(
    norms_max: np.ndarray, lam_min: float, *, slack: float
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Upper-triangular tile pairs with the Cauchy-Schwarz skip applied.

    Returns (ti, tj, keep) over ALL pairs, ti <= tj: ``keep`` marks pairs
    that must be computed; ~keep pairs are provably edge-free for every
    lambda on a grid whose smallest value is lam_min (``pair_skippable``)."""
    nt = norms_max.shape[0]
    ti, tj = np.triu_indices(nt)
    keep = ~pair_skippable(norms_max, ti, tj, lam_min, slack=slack)
    return ti, tj, keep


# ---------------------------------------------------------------------------
# jnp twin shared with covariance.estimators
# ---------------------------------------------------------------------------


def centered_gram_chunked(X, mu, acc_dtype, *, chunk: int = 1024):
    """S_raw = (X - mu)'(X - mu) accumulated over row chunks, upcasting each
    chunk to ``acc_dtype`` inside the scan body (jnp; jit-safe).

    X: (n, p) any dtype; mu: (p,) in acc_dtype.  Rows pad with zeros and a
    validity mask zeroes the padded rows' centered contribution exactly
    (padding with cast(mu) would NOT be exact for bf16 — mu need not
    round-trip the input dtype).  Callers divide by the true n; returns the
    (p, p) accumulator (no normalization)."""
    import jax
    import jax.numpy as jnp

    n, p = X.shape
    pad = (-n) % chunk
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    valid = jnp.pad(jnp.ones((n,), acc_dtype), (0, pad))
    chunks = Xp.reshape(-1, chunk, p)
    masks = valid.reshape(-1, chunk)

    def body(gram, xc_mask):
        xc, m = xc_mask
        c = (xc.astype(acc_dtype) - mu) * m[:, None]
        return gram + c.T @ c, None

    gram, _ = jax.lax.scan(
        body, jnp.zeros((p, p), acc_dtype), (chunks, masks)
    )
    return gram

"""Out-of-core streaming screener: Theorem-1 partitions straight from X.

The dense pipeline starts from a (p, p) covariance; this package starts from
the (n, p) data matrix and never materializes S — tiles of the centered Gram
stream through the fused ``kernels/covgram_screen`` kernel, compacted edges
feed an incremental union-find, and only the per-component sub-blocks the
solvers actually consume are gathered (DESIGN.md Section 10).

    stream_screen          screen (X, lambda grid) out-of-core
    plan_path_streaming    whole-path planning from X (engine-compatible)
    DataSession            incremental re-screen for appended data rows
    StreamConfig           tile/batch/memory-budget knobs
"""

from repro.stream.config import StreamConfig, as_config
from repro.stream.materialize import (
    MaterializedCovariance,
    materialize_components,
    shard_gather,
)
from repro.stream.path import plan_path_from_screen, plan_path_streaming
from repro.stream.screen import StreamScreen, stream_screen
from repro.stream.session import DataSession, SessionUpdate

__all__ = [
    "StreamConfig",
    "as_config",
    "MaterializedCovariance",
    "materialize_components",
    "shard_gather",
    "plan_path_from_screen",
    "plan_path_streaming",
    "StreamScreen",
    "stream_screen",
    "DataSession",
    "SessionUpdate",
]

"""Sharding-aware checkpointing with atomic manifests and elastic restore.

Layout per step:
    <dir>/step_000123/
        manifest.json        tree structure, leaf shapes/dtypes, mesh shape,
                             save timestamp, framework version
        shard_00000.npz      flat leaf arrays (this host's shards)
    <dir>/LATEST             atomic pointer file (rename-swapped)

Fault-tolerance contract (DESIGN.md Section 5):
  * atomicity — a checkpoint becomes visible only when the LATEST pointer is
    renamed over, after every shard file is fsync'd; a process killed
    mid-save can never leave a half-readable "latest" checkpoint;
  * elasticity — restore() takes the *current* device layout and re-shards:
    leaves are saved unsharded per-host here (single-host container); on a
    real pod each host writes its local shards and restore re-stitches via
    jax.make_array_from_single_device_arrays — the manifest records the
    saved mesh so any new mesh can reshard;
  * determinism — combined with the counter-based data pipeline, a restore
    reproduces the exact training trajectory (tested bit-exact in
    tests/test_fault_tolerance.py).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(directory, step: int, tree, *, mesh_shape=None) -> Path:
    directory = Path(directory)
    tag = f"step_{step:09d}"
    tmp = directory / f".tmp_{tag}_{os.getpid()}"
    final = directory / tag
    tmp.mkdir(parents=True, exist_ok=True)

    names, leaves, _ = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
    with open(tmp / "shard_00000.npz", "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": step,
        "names": names,
        "shapes": [list(np.shape(leaf)) for leaf in leaves],
        "dtypes": [str(np.asarray(leaf).dtype) for leaf in leaves],
        "mesh_shape": list(mesh_shape) if mesh_shape else None,
        # manifest wants a real epoch timestamp (when was this written),
        # not a duration — the one legitimate wall-clock read in src/
        "time": time.time(),  # noqa: TID251
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    # atomic LATEST pointer
    ptr_tmp = directory / f".LATEST_{os.getpid()}"
    ptr_tmp.write_text(tag)
    os.replace(ptr_tmp, directory / "LATEST")
    return final


def latest_step(directory) -> int | None:
    ptr = Path(directory) / "LATEST"
    if not ptr.exists():
        return None
    tag = ptr.read_text().strip()
    if not (Path(directory) / tag / "manifest.json").exists():
        return None
    return int(tag.split("_")[1])


def restore_checkpoint(directory, tree_like, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``tree_like``.  ``shardings``, if given,
    is a matching tree of NamedShardings for the *current* mesh — this is the
    elastic path: the saved arrays are device_put with the new layout
    regardless of the mesh they were saved under."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    tag = f"step_{step:09d}"
    data = np.load(directory / tag / "shard_00000.npz")
    names, leaves, treedef = _flatten_with_paths(tree_like)
    restored = []
    for i, (name, like) in enumerate(zip(names, leaves)):
        arr = data[f"leaf_{i}"]
        arr = arr.astype(like.dtype) if hasattr(like, "dtype") else arr
        restored.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, step


class CheckpointManager:
    """Periodic + preemption-triggered saves with a bounded retention set
    and async (thread-offloaded) writes."""

    def __init__(self, directory, *, every: int = 100, keep: int = 3,
                 async_save: bool = True):
        self.directory = Path(directory)
        self.every = every
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None
        self.directory.mkdir(parents=True, exist_ok=True)

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every == 0

    def save(self, step: int, tree, *, blocking: bool = False):
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def _do():
            save_checkpoint(self.directory, step, host_tree)
            self._gc()

        self.wait()
        if self.async_save and not blocking:
            self._pending = threading.Thread(target=_do, daemon=True)
            self._pending.start()
        else:
            _do()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, tree_like, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, tree_like, shardings=shardings)

    def _gc(self):
        tags = sorted(
            (p for p in self.directory.glob("step_*") if p.is_dir()),
            key=lambda p: p.name,
        )
        for p in tags[: -self.keep]:
            shutil.rmtree(p, ignore_errors=True)

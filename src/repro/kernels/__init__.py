"""Pallas TPU kernels for the framework's compute hot-spots.

Layout per kernel: <name>/<name>.py (pl.pallas_call + BlockSpec tiling),
<name>/ops.py (jit'd public wrapper, interpret=True on CPU), <name>/ref.py
(pure-jnp oracle used by the allclose test sweeps).

Kernels:
  covgram          tiled centered Gram matrix  S = (X-mu)'(X-mu)/n — the
                   O(n p^2) covariance front-end (paper Section 3)
  covgram_screen   fused Gram-tile + threshold + edge-emit for the
                   out-of-core streaming screener (compacted edge lists and
                   per-tile |S_ij| bounds instead of dense tiles)
  threshold_cc     fused |S|>lambda masking + one min-label-propagation hook
                   step — the TPU adaptation of the paper's graph-partition
                   stage (the p x p adjacency never materializes in HBM)
  prox_l1          fused proximal-gradient step soft(Theta - t*G, t*lam) for
                   the batched first-order glasso solvers
  flash_attention  blockwise online-softmax attention (causal + GQA) for the
                   LM pillar's train/prefill steps
"""

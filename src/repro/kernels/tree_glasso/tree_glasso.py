"""Pallas kernel: batched closed-form forest glasso over a bucket stack.

One program per padded block — the whole (b, b) tile lives in VMEM, the math
is elementwise soft-thresholding plus a single row reduction (VPU work, no
MXU), so the kernel is memory-bound and fuses what would otherwise be ~10
separate HBM round-trips (mask, soft, denominators, two divisions, row sum,
diagonal scatter) into one read and one write of the stack.

    grid (B,)   in: S (B, b, b), lam (B, 1)   out: Theta (B, b, b)

lam is a PER-BLOCK vector block — the serving path coalesces blocks with
different lambdas into one stack, and a lambda path never recompiles.  Tree
buckets are small by nature (large components are rarely acyclic), so the
one-tile-per-program layout holds comfortably within VMEM; the ops wrapper
falls back to the jnp reference above a size cap.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(s_ref, lam_ref, o_ref):
    s = s_ref[0]
    lam = lam_ref[0, 0]
    b = s.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    eye = rows == cols
    abss = jnp.abs(s)
    mask = (abss > lam) & ~eye
    a = jnp.where(mask, jnp.sign(s) * (abss - lam), 0.0)
    d = jnp.sum(jnp.where(eye, s, 0.0), axis=1) + lam  # diag(S) + lam, (b,)
    den = jnp.where(mask, d[:, None] * d[None, :] - a * a, 1.0)
    theta_off = jnp.where(mask, -a / den, 0.0)
    contrib = jnp.where(mask, (a * a) / (d[:, None] * den), 0.0)
    theta_diag = 1.0 / d + jnp.sum(contrib, axis=1)
    o_ref[0] = theta_off + jnp.where(eye, theta_diag[:, None], 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def glasso_forest_pallas(
    blocks: jax.Array, lams: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """blocks: (B, b, b) with b a multiple of 8; lams: (B, 1)."""
    B, b, _ = blocks.shape
    return pl.pallas_call(
        _kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, b, b), lambda n: (n, 0, 0)),
            pl.BlockSpec((1, 1), lambda n: (n, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, b), lambda n: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, b, b), blocks.dtype),
        interpret=interpret,
    )(blocks, lams)

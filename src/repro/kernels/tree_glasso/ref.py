"""Reference (pure jnp) closed-form glasso on an acyclic thresholded support.

Fattahi & Sojoudi (arXiv:1708.09479): when the support of the soft-thresholded
covariance is a forest, the glasso optimum is explicit.  With the full-L1
convention this repo uses (diagonal penalized, so W_ii = S_ii + lam) define

    d_i  = S_ii + lam
    a_ij = soft(S_ij, lam)            on edges |S_ij| > lam (strict, eq. (4))
    D_ij = d_i d_j - a_ij^2

and the optimum is

    Theta_ij = -a_ij / D_ij                          (i, j) an edge
    Theta_ii = 1/d_i + sum_{j ~ i} a_ij^2 / (d_i D_ij)
    Theta_ij = 0                                     otherwise.

This is exactly the junction-tree inverse of the max-det completion
specialized to cliques = edges, separators = vertices with multiplicity
deg - 1 — O(|E|) work versus hundreds of O(b^3) iterative sweeps.  The 2x2
"pair" class is the single-edge special case, and padded bucket coordinates
(identity-padded S, no edges) come out as 1/(1 + lam) on the diagonal —
precisely the padded glasso solution, so the formula applies verbatim to the
planner's padded block stacks.

Exactness requires the thresholded/solution supports to coincide (the
closed-form KKT holds on edges by construction; non-edge dual feasibility
can fail on adversarial matrices) — the executor verifies the KKT residual
and falls back to the iterative ladder tail, so routing is always safe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def glasso_forest_ref(S: jax.Array, lam, *, eps: float = 0.0) -> jax.Array:
    """Closed-form glasso for one (b, b) block with forest support.

    Same contract as the iterative solvers: ``solve(S, lam) -> Theta``,
    jit- and vmap-friendly.  ``eps`` is unused (accepted for option parity).
    """
    del eps
    b = S.shape[0]
    lam = jnp.asarray(lam, S.dtype)
    eye = jnp.eye(b, dtype=bool)
    absS = jnp.abs(S)
    mask = (absS > lam) & ~eye
    a = jnp.where(mask, jnp.sign(S) * (absS - lam), 0.0)
    d = jnp.diag(S) + lam
    den = jnp.where(mask, d[:, None] * d[None, :] - a * a, 1.0)
    theta_off = jnp.where(mask, -a / den, 0.0)
    contrib = jnp.where(mask, (a * a) / (d[:, None] * den), 0.0)
    theta_diag = 1.0 / d + jnp.sum(contrib, axis=1)
    return theta_off + jnp.where(eye, theta_diag[:, None], 0.0)

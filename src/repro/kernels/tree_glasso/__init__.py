from repro.kernels.tree_glasso.ops import glasso_forest, glasso_forest_stack
from repro.kernels.tree_glasso.ref import glasso_forest_ref

__all__ = ["glasso_forest", "glasso_forest_stack", "glasso_forest_ref"]

"""Public wrappers for the forest closed form (padding + backend dispatch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.tree_glasso.ref import glasso_forest_ref
from repro.kernels.tree_glasso.tree_glasso import glasso_forest_pallas

#: above this padded size, skip the one-tile-per-program Pallas path (tree
#: buckets this large are vanishingly rare; the jnp reference vmaps fine)
_PALLAS_SIZE_CAP = 1024


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def glasso_forest_stack(blocks: jax.Array, lams: jax.Array) -> jax.Array:
    """Batched closed-form forest glasso over a (B, b, b) bucket stack.

    ``lams`` is per-block, shape (B,) — mixed-lambda serving batches share
    one executable.  On TPU this is the Pallas kernel (zero-padded up to a
    sublane multiple; zero padding adds no edges since |0| > lam is false,
    and the padded diagonal is discarded by the slice).  Off-TPU the fused
    jnp reference wins: interpret-mode emulation costs 2-6x on exactly the
    many-small-dispatch pattern this fast path exists to accelerate."""
    B, b, _ = blocks.shape
    if not _is_tpu() or b > _PALLAS_SIZE_CAP:
        return jax.vmap(glasso_forest_ref)(blocks, lams)
    pad = (-b) % 8
    bp = jnp.pad(blocks, ((0, 0), (0, pad), (0, pad)))
    out = glasso_forest_pallas(bp, lams.reshape(B, 1).astype(blocks.dtype))
    return out[:, :b, :b]


@jax.jit
def glasso_forest(S: jax.Array, lam, *, W0=None, tol=None) -> jax.Array:
    """Single-block contract ``solve(S, lam) -> Theta`` (solver-registry
    compatible; W0/tol accepted for parity and ignored — the solve is
    direct)."""
    del W0, tol
    lam = jnp.asarray(lam, S.dtype)
    return glasso_forest_stack(S[None], lam[None])[0]

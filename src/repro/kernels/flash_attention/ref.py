"""Pure-jnp oracle: materialized-scores attention with causal + GQA."""

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("causal", "scale"))
def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, d)
    k: jax.Array,  # (B, Hkv, Skv, d)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / (d**0.5)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Skv)[None, :]
        s = jnp.where(kpos <= qpos, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)

"""Blockwise online-softmax attention (FlashAttention) for TPU, with causal
masking and GQA head grouping.

Grid (BH, nq, nk): q-row blocks revisit their output tile across the
innermost k axis; running (m, l, acc) statistics live in VMEM scratch that
persists across k iterations (TPU sequential-grid semantics).  The (Sq, Skv)
score matrix never exists — per step only a (bq, bk) f32 tile does, so the
working set is O(bq*(bk + d)) VMEM instead of O(S^2) HBM: the standard
IO-aware reformulation, which on TPU also keeps the MXU fed with
(bq, d) @ (d, bk) and (bq, bk) @ (bk, d) contractions.

GQA is folded into the BlockSpec index maps: the kv BlockSpecs map q-head
bh -> kv-head bh // group, so no head replication ever materializes.

Causal blocks strictly above the diagonal are skipped wholesale with
@pl.when (the mask only nibbles the diagonal blocks) — ~2x fewer grid steps
at long context.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, nk: int, bq: int, bk: int, scale: float, causal: bool, kv_len: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # Skip blocks entirely above the diagonal (the mask only nibbles the
        # diagonal blocks) — ~2x fewer grid steps at long context.
        pl.when(ik * bk <= iq * bq + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ik == nk - 1)
    def _finish():
        lse = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / lse).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret", "kv_len"),
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, d)
    k: jax.Array,  # (BHkv, Skv_padded, d)
    v: jax.Array,
    *,
    kv_len: int,
    causal: bool,
    scale: float,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, d = q.shape
    BHkv, Skv, _ = k.shape
    group = BH // BHkv
    nq, nk = Sq // block_q, Skv // block_k

    return pl.pallas_call(
        functools.partial(
            _kernel, nk=nk, bq=block_q, bk=block_k,
            scale=scale, causal=causal, kv_len=kv_len,
        ),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh // group, ik, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, iq, ik: (bh // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

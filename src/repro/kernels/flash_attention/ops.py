"""Public flash-attention wrapper: (B, H, S, d) layout, padding, GQA checks,
backend dispatch (interpret kernel body on CPU)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "block_q", "block_k")
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, d)
    k: jax.Array,  # (B, Hkv, Skv, d)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    B, Hq, Sq, d = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0, "GQA requires Hq to be a multiple of Hkv"
    scale = float(scale if scale is not None else 1.0 / (d**0.5))

    bq = min(block_q, max(8, Sq))
    bk = min(block_k, max(8, Skv))
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk

    qf = q.reshape(B * Hq, Sq, d)
    kf = k.reshape(B * Hkv, Skv, d)
    vf = v.reshape(B * Hkv, Skv, d)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kf = jnp.pad(kf, ((0, 0), (0, pad_k), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad_k), (0, 0)))

    out = flash_attention_pallas(
        qf, kf, vf,
        kv_len=Skv, causal=causal, scale=scale,
        block_q=bq, block_k=bk, interpret=not _is_tpu(),
    )
    return out[:, :Sq, :].reshape(B, Hq, Sq, d)

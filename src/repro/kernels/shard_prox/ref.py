"""Reference (pure jnp) fused prox step for the sharded oversize solver.

One linearized-ADMM iteration ends with four elementwise passes over the
local (rows_local, b) shard of the iterate:

    A      = X_new + U                       (prox argument)
    Z_new  = soft(A, lam / rho)              (diagonal penalized too — the
                                              full-L1 convention of eq. (1))
    U_new  = A - Z_new                       (scaled-dual update, algebraically
                                              identical to U + X_new - Z_new)
    rp2    = sum((X_new - Z_new)^2)          (local primal-residual partial)
    rd2    = sum((Z_new - Z_old)^2)          (local dual-residual partial,
                                              scaled by rho at the call site)

The Pallas kernel fuses all four into one read and one write of the shard;
this module is the semantics — the off-TPU dispatch target and the
pallas-vs-ref test oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_prox_ref(
    x_new: jax.Array, u: jax.Array, z_old: jax.Array, t
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns (Z_new, U_new, rp2_partial, rd2_partial) for one shard."""
    t = jnp.asarray(t, x_new.dtype)
    a = x_new + u
    z_new = jnp.sign(a) * jnp.maximum(jnp.abs(a) - t, 0.0)
    u_new = a - z_new
    rp2 = jnp.sum((x_new - z_new) ** 2)
    rd2 = jnp.sum((z_new - z_old) ** 2)
    return z_new, u_new, rp2, rd2

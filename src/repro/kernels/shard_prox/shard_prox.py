"""Pallas kernel: fused soft-threshold + residual reduction for one shard.

The sharded oversize solver's hot elementwise tail.  Without fusion the
prox step costs ~7 HBM round-trips of the (rows_local, b) shard (add, abs,
sign, subtract, two squared-difference reductions, dual update); the kernel
does one read of (X_new, U, Z_old) and one write of (Z_new, U_new) per row
tile, accumulating both residual partials in a (1, 2) scalar block that
every grid step maps to the same output tile (TPU grids are sequential, so
the accumulation is race-free — same pattern as the covgram_screen bounds).

    grid (n_row_tiles,)
    in:  X_new (rl, b), U (rl, b), Z_old (rl, b), t (1, 1)
    out: Z_new (rl, b), U_new (rl, b), acc (1, 2) = [rp2, rd2]

t = lam / rho is a TRACED scalar block: adaptive-rho steps never recompile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, u_ref, z_ref, t_ref, zn_ref, un_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    a = x + u_ref[...]
    t = t_ref[0, 0]
    zn = jnp.sign(a) * jnp.maximum(jnp.abs(a) - t, 0.0)
    zn_ref[...] = zn
    un_ref[...] = a - zn
    dp = x - zn
    dd = zn - z_ref[...]
    acc_ref[0, 0] += jnp.sum(dp * dp)
    acc_ref[0, 1] += jnp.sum(dd * dd)


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def fused_prox_pallas(
    x_new: jax.Array,
    u: jax.Array,
    z_old: jax.Array,
    t: jax.Array,
    *,
    row_tile: int = 0,
    interpret: bool = False,
):
    """x_new/u/z_old: (rl, b) with rl a multiple of row_tile and b a multiple
    of 8; t: (1, 1).  Returns (Z_new, U_new, acc (1, 2))."""
    rl, b = x_new.shape
    tr = row_tile or rl
    grid = (rl // tr,)
    shard = pl.BlockSpec((tr, b), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[shard, shard, shard, pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=[shard, shard, pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((rl, b), x_new.dtype),
            jax.ShapeDtypeStruct((rl, b), x_new.dtype),
            jax.ShapeDtypeStruct((1, 2), x_new.dtype),
        ],
        interpret=interpret,
    )(x_new, u, z_old, t.reshape(1, 1).astype(x_new.dtype))

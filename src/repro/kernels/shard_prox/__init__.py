from repro.kernels.shard_prox.ops import fused_prox_residual
from repro.kernels.shard_prox.ref import fused_prox_ref
from repro.kernels.shard_prox.shard_prox import fused_prox_pallas

__all__ = ["fused_prox_residual", "fused_prox_ref", "fused_prox_pallas"]

"""Dispatch wrapper for the fused sharded prox step (padding + backend).

Called on the LOCAL shard inside the sharded solver's shard_map body: on TPU
the Pallas kernel fuses the whole prox tail into one HBM pass (rows padded to
a sublane multiple, columns to a lane multiple; zero padding soft-thresholds
to zero and contributes nothing to either residual partial, so the padded
coordinates are exact no-ops); off TPU the jnp reference wins — interpret
mode would emulate the fusion at 2-6x the cost, the same trade-off recorded
for ``tree_glasso`` and ``covgram_screen``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.shard_prox.ref import fused_prox_ref
from repro.kernels.shard_prox.shard_prox import fused_prox_pallas


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_prox_residual(
    x_new: jax.Array, u: jax.Array, z_old: jax.Array, t
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """(Z_new, U_new, rp2_partial, rd2_partial) for one (rl, b) shard."""
    if not _is_tpu():
        return fused_prox_ref(x_new, u, z_old, t)
    rl, b = x_new.shape
    pad_r = (-rl) % 8
    pad_c = (-b) % 128
    if pad_r or pad_c:
        padder = lambda m: jnp.pad(m, ((0, pad_r), (0, pad_c)))
        x_new, u, z_old = padder(x_new), padder(u), padder(z_old)
    zn, un, acc = fused_prox_pallas(x_new, u, z_old, jnp.asarray(t))
    if pad_r or pad_c:
        zn, un = zn[:rl, :b], un[:rl, :b]
    return zn, un, acc[0, 0], acc[0, 1]

"""jnp reference for the fused bucket BCD — ``glasso_bcd`` per packed lane.

``fused_bcd_single`` is ``core.solvers.bcd.glasso_bcd`` with two deltas that
make it PACKABLE across bucket boundaries without changing any lane's bits:

* **Warm inputs are mandatory.**  Every lane carries a (W0, Theta0) pair, so
  one compiled signature covers a megabatch that mixes warm and cold source
  buckets.  Cold lanes pass W0 = S + lam*I (bitwise-identical to the cold
  init: the diagonal is reset from S either way and lam*0 adds nothing
  off-diagonal) and Theta0 = I (B_init off-diagonal becomes -0.0 where the
  cold path had +0.0 — equal under ``==``, the repo's bitwise gate).

* **The convergence scale is an input.**  ``glasso_bcd`` derives its sweep
  and CD tolerances from ``mean|S - diag S| + 1e-12`` of ITS OWN padded
  block.  Re-padding a (s, s) lane into a (bin, bin) slot keeps every other
  quantity exact (padded columns are screened no-ops, the cross region stays
  exactly zero, extra zeros drop out of max-reductions) but changes the mean
  denominator from s^2 to bin^2 — so the packer precomputes the scale at the
  SOURCE shape (``engine.waves.bucket_scales``) and each lane solves against
  the tolerance its unfused dispatch would have used.

Everything else — inner ``_lasso_cd``, column update, sweep loop, Theta
recovery — is imported from / verbatim to ``bcd.py``; tests/test_fused.py
pins the lane-for-lane ``==``-equality against per-bucket ``glasso_bcd``.

The second return is the per-lane SWEEP COUNT: under ``vmap`` the while_loop
is select-masked (converged lanes freeze, so packing cannot change results)
but every lane still pays the slowest lane's sweeps in compute — the count
is what lets the executor report ``solver.fused.lockstep_sweeps_saved``, the
work the Pallas kernel's genuine per-block early exit avoids.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.solvers.bcd import _lasso_cd


def fused_bcd_single(
    S: jax.Array,
    lam: jax.Array,
    scale: jax.Array,
    W0: jax.Array,
    Theta0: jax.Array,
    *,
    max_sweeps: int = 100,
    n_cd: int = 100,
    tol: float = 1e-6,
    node_screen: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One packed lane: ``glasso_bcd`` with injected warm pair + scale.

    Returns (Theta, sweeps).  ``S`` may be a source block re-padded into a
    larger bin (identity diagonal, zero off-diagonal): padded columns are
    eq.-(10)-screened exactly and the [:s, :s] slice of the result equals
    the unfused solve of the (s, s) block bit for bit (up to zero signs).
    """
    b = S.shape[0]
    dtype = S.dtype
    lam = jnp.asarray(lam, dtype)
    # Diagonal KKT is exact at the solution; enforce from the start.
    W_init = jnp.where(jnp.eye(b, dtype=bool), jnp.diag(S) + lam, W0)
    d = jnp.diagonal(Theta0)
    d = jnp.where(d > 0, d, jnp.ones((), dtype))  # PD => d > 0; belt+braces
    B_init = jnp.where(jnp.eye(b, dtype=bool), 0.0, -(Theta0 / d[None, :]))
    cd_tol = jnp.asarray(tol, dtype) * scale

    def column_update(j, W, B):
        s12 = S[:, j].at[j].set(0.0)
        screened = jnp.max(jnp.abs(s12)) <= lam

        def solve_col(operand):
            W, beta0 = operand
            beta = _lasso_cd(W, s12, lam, beta0, j, n_cd=n_cd, tol=cd_tol)
            return beta

        def zero_col(operand):
            _, beta0 = operand
            return jnp.zeros_like(beta0)

        if node_screen:
            beta = jax.lax.cond(screened, zero_col, solve_col, (W, B[:, j]))
        else:
            beta = solve_col((W, B[:, j]))
        w12 = (W @ beta).at[j].set(0.0)
        W = W.at[:, j].set(w12.at[j].set(W[j, j]))
        W = W.at[j, :].set(w12.at[j].set(W[j, j]))
        return W, B.at[:, j].set(beta)

    def sweep(carry):
        W, B, _, it = carry
        W_old = W

        def body(j, wb):
            W, B = wb
            return column_update(j, W, B)

        W, B = jax.lax.fori_loop(0, b, body, (W, B))
        delta = jnp.max(jnp.abs(W - W_old))
        return W, B, delta, it + 1

    def cond(carry):
        _, _, delta, it = carry
        return jnp.logical_and(delta > tol * scale, it < max_sweeps)

    W, B, delta, _ = sweep((W_init, B_init, jnp.asarray(jnp.inf, dtype), jnp.int32(0)))
    W, B, _, sweeps = jax.lax.while_loop(cond, sweep, (W, B, delta, jnp.int32(1)))

    # Recover Theta column-wise from the final (W, B).
    def theta_col(j):
        beta = B[:, j]
        w12 = W[:, j].at[j].set(0.0)
        t22 = 1.0 / (W[j, j] - w12 @ beta)
        col = -beta * t22
        return col.at[j].set(t22)

    Theta = jax.vmap(theta_col, out_axes=1)(jnp.arange(b))
    return 0.5 * (Theta + Theta.T), sweeps


@functools.partial(
    jax.jit, static_argnames=("max_sweeps", "n_cd", "tol", "node_screen")
)
def fused_bcd_ref_stack(
    blocks: jax.Array,
    lams: jax.Array,
    scales: jax.Array,
    W0: jax.Array,
    T0: jax.Array,
    *,
    max_sweeps: int = 100,
    n_cd: int = 100,
    tol: float = 1e-6,
    node_screen: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """vmapped reference over a packed (N, bin, bin) megabatch.

    Returns (Theta (N, bin, bin), sweeps (N,) int32).  Under vmap the sweep
    while_loop runs to the batch max with converged lanes select-frozen, so
    per-lane results are independent of what the lane is packed with — the
    property the wave packer's bitwise gate rests on."""
    fn = functools.partial(
        fused_bcd_single,
        max_sweeps=max_sweeps, n_cd=n_cd, tol=tol, node_screen=node_screen,
    )
    return jax.vmap(fn)(blocks, lams, scales, W0, T0)

"""Public wrapper for the fused bucket BCD (backend dispatch).

``fused_bcd_stack`` is what the executor's wave packer calls: one launch per
(bin, dtype, opts) megabatch.  On TPU it is the Pallas kernel — grid
programs run sequentially per TensorCore, so each block's sweep loop exits
the moment IT converges.  Off-TPU the vmapped jnp reference runs instead
(same bits lane-for-lane; the lockstep compute waste is SIMD-inherent there
and only the dispatch saving remains — which on CPU is the dominant cost of
the many-tiny-buckets tail anyway, see bench_fused).
"""

from __future__ import annotations

import jax

from repro.kernels.bucket_glasso.bucket_glasso import fused_bcd_pallas
from repro.kernels.bucket_glasso.ref import fused_bcd_ref_stack

#: above this padded bin, skip the one-tile-per-program Pallas path (the
#: wave packer never bins past 64; anything larger is a direct caller)
_PALLAS_SIZE_CAP = 256


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_bcd_stack(
    blocks: jax.Array,
    lams: jax.Array,
    scales: jax.Array,
    W0: jax.Array,
    T0: jax.Array,
    *,
    max_sweeps: int = 100,
    n_cd: int = 100,
    tol: float = 1e-6,
    node_screen: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Solve a packed (N, bin, bin) megabatch; returns (Theta, sweeps).

    ``lams``/``scales`` are per-lane (N,) — lanes from different buckets
    (and, over the serving path, different lambdas) share one executable.
    Every lane carries its (W0, T0) warm pair; cold lanes are synthesized by
    the packer (``engine.waves``)."""
    N, b, _ = blocks.shape
    opts = dict(
        max_sweeps=max_sweeps, n_cd=n_cd, tol=tol, node_screen=node_screen
    )
    if not _is_tpu() or b > _PALLAS_SIZE_CAP:
        return fused_bcd_ref_stack(blocks, lams, scales, W0, T0, **opts)
    theta, sweeps = fused_bcd_pallas(
        blocks,
        lams.reshape(N, 1).astype(blocks.dtype),
        scales.reshape(N, 1).astype(blocks.dtype),
        W0,
        T0,
        **opts,
    )
    return theta, sweeps.reshape(N)

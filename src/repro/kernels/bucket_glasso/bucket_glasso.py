"""Pallas kernel: full BCD glasso solve inside the kernel, one program per
packed lane.

    grid (N,)   in:  S (N, b, b), lam (N, 1), scale (N, 1),
                     W0 (N, b, b), T0 (N, b, b)
                out: Theta (N, b, b), sweeps (N, 1) int32

Unlike the vmapped reference — where ``lax.while_loop`` is select-masked and
every lane pays the batch-max sweep count in compute — grid programs on a
TensorCore execute one after another, so the per-program sweep loop is a REAL
early exit: a block converged after 3 sweeps costs 3 sweeps, full stop.
That is the lockstep saving ``solver.fused.lockstep_sweeps_saved`` measures
(the megabatch's sum over lanes of ``max(sweeps) - sweeps_i``).

The whole working set per program is five (b, b) tiles (S, W, B, W_old and
the output) — at the bin cap b = 64 in f64 that is ~160 KiB, comfortably
within VMEM.  The body reuses ``ref.fused_bcd_single`` verbatim: the solve
is lax control flow (fori/while/cond) over jnp ops on VMEM-resident values,
which Pallas lowers directly; off-TPU the ops wrapper never reaches this
kernel (interpret mode is exercised by the parity tests only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bucket_glasso.ref import fused_bcd_single


def _make_kernel(*, max_sweeps: int, n_cd: int, tol: float, node_screen: bool):
    def kernel(s_ref, lam_ref, scale_ref, w0_ref, t0_ref, o_ref, sweeps_ref):
        theta, sweeps = fused_bcd_single(
            s_ref[0],
            lam_ref[0, 0],
            scale_ref[0, 0],
            w0_ref[0],
            t0_ref[0],
            max_sweeps=max_sweeps,
            n_cd=n_cd,
            tol=tol,
            node_screen=node_screen,
        )
        o_ref[0] = theta
        sweeps_ref[0, 0] = sweeps

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("max_sweeps", "n_cd", "tol", "node_screen", "interpret"),
)
def fused_bcd_pallas(
    blocks: jax.Array,
    lams: jax.Array,
    scales: jax.Array,
    W0: jax.Array,
    T0: jax.Array,
    *,
    max_sweeps: int = 100,
    n_cd: int = 100,
    tol: float = 1e-6,
    node_screen: bool = True,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """blocks/W0/T0: (N, b, b) with b a multiple of 8; lams/scales: (N, 1)."""
    N, b, _ = blocks.shape
    mat = pl.BlockSpec((1, b, b), lambda n: (n, 0, 0))
    scalar = pl.BlockSpec((1, 1), lambda n: (n, 0))
    return pl.pallas_call(
        _make_kernel(
            max_sweeps=max_sweeps, n_cd=n_cd, tol=tol, node_screen=node_screen
        ),
        grid=(N,),
        in_specs=[mat, scalar, scalar, mat, mat],
        out_specs=[mat, scalar],
        out_shape=[
            jax.ShapeDtypeStruct((N, b, b), blocks.dtype),
            jax.ShapeDtypeStruct((N, 1), jnp.int32),
        ],
        interpret=interpret,
    )(blocks, lams, scales, W0, T0)

"""Fused in-kernel small-block BCD over a packed bucket stack.

The kernel family behind the executor's wave packer (DESIGN.md Section 16):
same-dtype iterative small buckets are re-packed across bucket boundaries
into size-binned megabatches and solved with ONE launch per bin per wave —
outer BCD sweeps, inner lasso CD, eq.-(10) node screening and per-block
convergence all run inside the kernel, so a converged block exits early
instead of sweeping in lockstep with the slowest block of its dispatch.
"""

from repro.kernels.bucket_glasso.ops import fused_bcd_stack
from repro.kernels.bucket_glasso.ref import fused_bcd_ref_stack, fused_bcd_single

__all__ = ["fused_bcd_stack", "fused_bcd_ref_stack", "fused_bcd_single"]

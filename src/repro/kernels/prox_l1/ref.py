"""Pure-jnp oracle for the fused prox step."""

import jax
import jax.numpy as jnp


@jax.jit
def prox_step_ref(theta: jax.Array, grad: jax.Array, t, lam) -> jax.Array:
    z = theta - t * grad
    thr = t * lam
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - thr, 0.0)

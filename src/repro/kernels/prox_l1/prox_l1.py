"""Fused proximal-gradient step for batched first-order glasso solvers:

    out = soft_threshold(Theta - t * G,  t * lam)

where G = S - Theta^{-1} is the smooth gradient (computed outside — the
inverse wants a Cholesky, not a Pallas kernel).  Fusing the AXPY with the
shrinkage halves HBM traffic versus materializing the gradient step: the
step is memory-bound (arithmetic intensity < 1 flop/byte), so on TPU this is
a straight 2x on the dominant roofline term of the inner loop.

Grid (nb, ni, nj) tiles a (B, b, b) stack of blocks — the bucket layout
repro.core.blocks produces — so one launch advances every same-size
component in the bucket.  t and lam arrive as (1, 1) blocks: no recompile
along a lambda path or a backtracking line search.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(theta_ref, grad_ref, t_ref, lam_ref, o_ref):
    t = t_ref[0, 0]
    lam = lam_ref[0, 0]
    z = theta_ref[...] - t * grad_ref[...]
    thr = t * lam
    o_ref[...] = jnp.sign(z) * jnp.maximum(jnp.abs(z) - thr, 0.0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def prox_step_pallas(
    theta: jax.Array,
    grad: jax.Array,
    t: jax.Array,
    lam: jax.Array,
    *,
    block: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """theta, grad: (B, b, b) with b a block multiple; t, lam: (1, 1)."""
    B, b, _ = theta.shape
    nt = b // block
    return pl.pallas_call(
        _kernel,
        grid=(B, nt, nt),
        in_specs=[
            pl.BlockSpec((1, block, block), lambda n, i, j: (n, i, j)),
            pl.BlockSpec((1, block, block), lambda n, i, j: (n, i, j)),
            pl.BlockSpec((1, 1), lambda n, i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda n, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, block), lambda n, i, j: (n, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, b, b), theta.dtype),
        interpret=interpret,
    )(theta, grad, t, lam)

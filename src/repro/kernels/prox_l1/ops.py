"""Public wrapper for the fused prox step (padding + backend dispatch)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.prox_l1.prox_l1 import prox_step_pallas


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block",))
def prox_step(theta: jax.Array, grad: jax.Array, t, lam, *, block: int = 256):
    """soft(theta - t*grad, t*lam) over a (B, b, b) stack (or a single (b, b)
    block, auto-promoted)."""
    single = theta.ndim == 2
    if single:
        theta, grad = theta[None], grad[None]
    B, b, _ = theta.shape
    blk = min(block, max(8, b))
    pad = (-b) % blk
    tp = jnp.pad(theta, ((0, 0), (0, pad), (0, pad)))
    gp = jnp.pad(grad, ((0, 0), (0, pad), (0, pad)))
    t_arr = jnp.asarray(t, theta.dtype).reshape(1, 1)
    lam_arr = jnp.asarray(lam, theta.dtype).reshape(1, 1)
    out = prox_step_pallas(tp, gp, t_arr, lam_arr, block=blk, interpret=not _is_tpu())
    out = out[:, :b, :b]
    return out[0] if single else out

from repro.kernels.prox_l1.ops import prox_step

__all__ = ["prox_step"]

"""Public wrapper for the covgram_screen kernel family: backend dispatch,
padding convention, and edge compaction.

Dispatch follows the ``tree_glasso`` precedent: on TPU the fused Pallas
kernel computes the requested tile pairs; off-TPU the numpy oracle wins
(interpret-mode emulation costs per-grid-step overhead on exactly the
many-tile pattern the kernel accelerates, and the numpy path keeps the input
dtype — f64 tiles match a dense f64 estimator exactly on representable
data).  ``backend="pallas"`` forces the kernel (interpret mode off-TPU) for
the equivalence tests.

``compact_edges`` turns a batch of thresholded tiles into the compacted
(i, j, |S_ij|) edge arrays the streaming screener accumulates: an entry of
``vals`` is nonzero iff it is an eq.-(4) edge (|S_ij| > lam >= 0 implies
S_ij != 0 in the same arithmetic), so compaction is one ``np.nonzero`` over
the in-flight batch — the dense (p, p) matrix never exists.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.covgram_screen.covgram_screen import covgram_screen_pallas
from repro.kernels.covgram_screen.ref import covgram_screen_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_for_screen(
    x: np.ndarray, mu: np.ndarray, *, block_n: int, block_p: int
) -> tuple[np.ndarray, np.ndarray]:
    """Pad rows to a block_n multiple with copies of mu (centered
    contribution exactly zero) and columns to a block_p multiple with zeros
    (mu padded with zeros, so padded columns contribute exact zeros).

    mu is cast to x's dtype FIRST and the cast copy is what both the padding
    and the returned mean use: the padded rows then center to exactly zero in
    every backend (an f64 mu against f32-padded rows would not — the cast
    does not round-trip), at the cost of the mean carrying x's precision."""
    n, p = x.shape
    mu = np.asarray(mu, dtype=x.dtype)
    pad_n = (-n) % block_n
    pad_p = (-p) % block_p
    if pad_n:
        x = np.concatenate([x, np.broadcast_to(mu, (pad_n, p)).astype(x.dtype)])
    if pad_p:
        x = np.pad(x, ((0, 0), (0, pad_p)))
        mu = np.pad(mu, (0, pad_p))
    return x, mu


def covgram_screen_tiles(
    x_pad,
    mu_pad,
    i_idx: np.ndarray,
    j_idx: np.ndarray,
    lam: float,
    *,
    n_true: int,
    p_true: int,
    block_p: int,
    block_n: int = 512,
    backend: str = "auto",
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Compute + threshold the requested tile pairs of the centered Gram.

    x_pad/mu_pad follow ``pad_for_screen``'s convention.  Returns host
    arrays (vals (B, bp, bp), counts (B,), stats (B, 2)) — see the kernel
    docstring for the stats layout."""
    if backend == "auto":
        backend = "pallas" if _is_tpu() else "ref"
    i_idx = np.asarray(i_idx, np.int32)
    j_idx = np.asarray(j_idx, np.int32)
    if backend == "ref":
        vals, counts, stats = covgram_screen_ref(
            np.asarray(x_pad),
            np.asarray(mu_pad),
            i_idx,
            j_idx,
            float(lam),
            n_true=n_true,
            p_true=p_true,
            block_p=block_p,
        )
        return vals, counts[:, 0], stats
    if backend != "pallas":
        raise ValueError(f"unknown covgram_screen backend {backend!r}")
    vals, counts, stats = covgram_screen_pallas(
        jnp.asarray(x_pad, jnp.float32),
        jnp.asarray(mu_pad, jnp.float32),
        jnp.asarray(i_idx),
        jnp.asarray(j_idx),
        jnp.asarray(float(lam), jnp.float32).reshape(1, 1),
        n_true=n_true,
        p_true=p_true,
        block_n=block_n,
        block_p=block_p,
        interpret=not _is_tpu(),
    )
    return np.asarray(vals), np.asarray(counts)[:, 0], np.asarray(stats)


def compact_edges(
    vals: np.ndarray, i_idx: np.ndarray, j_idx: np.ndarray, *, block_p: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Compact a batch of thresholded tiles into global (i, j, |S_ij|) edge
    arrays, upper triangle only (diagonal tile pairs emit both orientations;
    off-diagonal pairs are scheduled with tile_i < tile_j)."""
    gi, gj, v = compact_edges_signed(vals, i_idx, j_idx, block_p=block_p)
    return gi, gj, np.abs(v)


def compact_edges_signed(
    vals: np.ndarray, i_idx: np.ndarray, j_idx: np.ndarray, *, block_p: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``compact_edges`` keeping the SIGNED covariance values.

    The joint hybrid screen needs signs: the fused-penalty subset condition
    bounds |sum_A S_k,ij| across classes, which |S_ij| alone cannot
    evaluate.  The single-class screen keeps using the absolute view."""
    t, ri, ci = np.nonzero(vals)
    gi = i_idx[t].astype(np.int64) * block_p + ri
    gj = j_idx[t].astype(np.int64) * block_p + ci
    keep = gi < gj
    v = vals[t[keep], ri[keep], ci[keep]].astype(np.float64)
    return gi[keep], gj[keep], v


def covgram_screen_tiles_stacked(
    xs_pad,
    mus_pad,
    i_idx_per_class,
    j_idx_per_class,
    lam: float,
    *,
    n_trues,
    p_true: int,
    block_p: int,
    block_n: int = 512,
    backend: str = "auto",
    pair_batch: int = 64,
) -> list[tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """K-stacked screen: one fused gram+threshold+compact pass PER CLASS.

    The joint screener's entry point: each class streams its OWN kept-tile
    schedule (the Cauchy-Schwarz certificates are per class — a tile proven
    edge-free for class k cannot contribute a |S_k,ij| > lam1 candidate, so
    skipping it per class is exact) in bounded ``pair_batch`` flights
    through the same kernel/oracle the single-class screener uses, and the
    compacted SIGNED per-class edges come back stacked for the hybrid-rule
    evaluation.  Per-class row counts n_k (and their padding) legitimately
    differ, which is why this is a schedule-stacked wrapper rather than one
    K-batched kernel launch."""
    out = []
    for x_pad, mu_pad, bi, bj, n_true in zip(
        xs_pad, mus_pad, i_idx_per_class, j_idx_per_class, n_trues
    ):
        bi = np.asarray(bi, np.int32)
        bj = np.asarray(bj, np.int32)
        gi_parts, gj_parts, v_parts = [], [], []
        for b0 in range(0, bi.size, max(1, int(pair_batch))):
            sl = slice(b0, b0 + max(1, int(pair_batch)))
            vals, _, _ = covgram_screen_tiles(
                x_pad,
                mu_pad,
                bi[sl],
                bj[sl],
                lam,
                n_true=int(n_true),
                p_true=p_true,
                block_p=block_p,
                block_n=block_n,
                backend=backend,
            )
            gi, gj, v = compact_edges_signed(
                vals, bi[sl], bj[sl], block_p=block_p
            )
            gi_parts.append(gi)
            gj_parts.append(gj)
            v_parts.append(v)
        def cat(parts, dt):
            return np.concatenate(parts) if parts else np.empty(0, dt)

        out.append(
            (
                cat(gi_parts, np.int64),
                cat(gj_parts, np.int64),
                cat(v_parts, np.float64),
            )
        )
    return out

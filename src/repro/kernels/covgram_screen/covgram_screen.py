"""Fused centered-Gram + threshold + edge-emit Pallas kernel.

The out-of-core screening variant of ``kernels/covgram``: instead of writing
the dense (p, p) covariance, the kernel computes ONE (block_p, block_p) tile
S_IJ = (X_I - mu_I)'(X_J - mu_J) / n per requested tile PAIR, thresholds it
at lambda in VMEM, and emits

  * ``vals``   the tile with sub-threshold entries zeroed (the compaction
               source: an entry survives iff it is an edge of eq. (4)),
  * ``count``  the number of surviving entries (diagonal excluded),
  * ``stats``  [max off-diagonal |S_ij| in the tile,
                max off-diagonal |S_ij| <= lambda] — the bounds the streaming
               session layer needs to re-validate a tile after a rank-k data
               update without recomputing it.

The dense S never exists in HBM: only the in-flight batch of tile pairs
(``npairs`` x block_p^2 f32) plus the O(#edges) compacted output survive the
call.  Tile pairs arrive as scalar-prefetched index lists (i_idx, j_idx), so
the driver's Cauchy-Schwarz tile-skip (sqrt(S_ii,max * S_jj,max) <= lambda)
simply omits a pair from the grid — skipped tiles cost zero FLOPs and zero
HBM traffic.

Grid (npairs, nk): k streams (block_n, block_p) row-chunks of the SAME padded
X at two column offsets (rank-block_n MXU updates accumulated in an f32 VMEM
scratch, exactly the covgram schedule); the threshold/emit epilogue runs at
k == nk-1.  lam rides in a (1, 1) block so a lambda sweep never recompiles;
the true n and p are static (one compile per dataset shape family).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    i_idx_ref,
    j_idx_ref,
    x_i_ref,
    x_j_ref,
    mu_i_ref,
    mu_j_ref,
    lam_ref,
    vals_ref,
    cnt_ref,
    stat_ref,
    acc_ref,
    *,
    nk: int,
    n_true: int,
    p_true: int,
    block_p: int,
):
    t = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = x_i_ref[...].astype(jnp.float32) - mu_i_ref[...].astype(jnp.float32)
    b = x_j_ref[...].astype(jnp.float32) - mu_j_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _emit():
        S = acc_ref[...] / n_true
        rows = i_idx_ref[t] * block_p + jax.lax.broadcasted_iota(
            jnp.int32, (block_p, block_p), 0
        )
        cols = j_idx_ref[t] * block_p + jax.lax.broadcasted_iota(
            jnp.int32, (block_p, block_p), 1
        )
        valid = (rows < p_true) & (cols < p_true) & (rows != cols)
        absS = jnp.abs(S)
        lam = lam_ref[0, 0]
        mask = valid & (absS > lam)  # strict: eq. (4), ties are NOT edges
        vals_ref[0] = jnp.where(mask, S, 0.0)
        cnt_ref[0, 0] = jnp.sum(mask.astype(jnp.int32)).astype(jnp.int32)
        stat_ref[0, 0] = jnp.max(jnp.where(valid, absS, 0.0))
        stat_ref[0, 1] = jnp.max(jnp.where(valid & ~mask, absS, 0.0))


@functools.partial(
    jax.jit,
    static_argnames=("n_true", "p_true", "block_n", "block_p", "interpret"),
)
def covgram_screen_pallas(
    x: jax.Array,
    mu: jax.Array,
    i_idx: jax.Array,
    j_idx: jax.Array,
    lam: jax.Array,
    *,
    n_true: int,
    p_true: int,
    block_n: int = 512,
    block_p: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (N, P) pre-padded (rows with copies of mu — zero centered
    contribution — to a block_n multiple; columns with zeros to a block_p
    multiple); mu: (P,) zero-padded; i_idx/j_idx: (npairs,) int32 tile
    indices; lam: (1, 1) f32.

    Returns (vals (npairs, block_p, block_p) f32 thresholded tiles,
    counts (npairs, 1) int32, stats (npairs, 2) f32 [tile max |S_ij|,
    max |S_ij| <= lam])."""
    N, P = x.shape
    nk = N // block_n
    npairs = i_idx.shape[0]
    mu2 = mu.reshape(1, P)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(npairs, nk),
        in_specs=[
            pl.BlockSpec((block_n, block_p), lambda t, k, ii, jj: (k, ii[t])),
            pl.BlockSpec((block_n, block_p), lambda t, k, ii, jj: (k, jj[t])),
            pl.BlockSpec((1, block_p), lambda t, k, ii, jj: (0, ii[t])),
            pl.BlockSpec((1, block_p), lambda t, k, ii, jj: (0, jj[t])),
            pl.BlockSpec((1, 1), lambda t, k, ii, jj: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_p, block_p), lambda t, k, ii, jj: (t, 0, 0)),
            pl.BlockSpec((1, 1), lambda t, k, ii, jj: (t, 0)),
            pl.BlockSpec((1, 2), lambda t, k, ii, jj: (t, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((block_p, block_p), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _kernel, nk=nk, n_true=n_true, p_true=p_true, block_p=block_p
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((npairs, block_p, block_p), jnp.float32),
            jax.ShapeDtypeStruct((npairs, 1), jnp.int32),
            jax.ShapeDtypeStruct((npairs, 2), jnp.float32),
        ],
        interpret=interpret,
    )(i_idx, j_idx, x, x, mu2, mu2, lam)

from repro.kernels.covgram_screen.ops import (
    compact_edges,
    covgram_screen_tiles,
    pad_for_screen,
)

__all__ = ["covgram_screen_tiles", "compact_edges", "pad_for_screen"]

from repro.kernels.covgram_screen.ops import (
    compact_edges,
    compact_edges_signed,
    covgram_screen_tiles,
    covgram_screen_tiles_stacked,
    pad_for_screen,
)

__all__ = [
    "covgram_screen_tiles",
    "covgram_screen_tiles_stacked",
    "compact_edges",
    "compact_edges_signed",
    "pad_for_screen",
]

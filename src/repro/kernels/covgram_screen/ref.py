"""Numpy oracle for the covgram_screen kernel.

Matches the kernel's contract bit-for-bit in spirit (same centered-product
arithmetic, same strict threshold) but computes in the INPUT dtype — under
f64 inputs the emitted tile values agree exactly with a dense
``(X-mu)'(X-mu)/n`` estimator on exactly-representable data, which is what
the streamed-vs-dense tie property tests rely on.  This is also the off-TPU
dispatch target: interpret-mode Pallas pays per-grid-step emulation overhead
on precisely the many-small-tile pattern this kernel exists for (same
trade-off as ``kernels/tree_glasso``)."""

from __future__ import annotations

import numpy as np


def covgram_screen_ref(
    x: np.ndarray,
    mu: np.ndarray,
    i_idx: np.ndarray,
    j_idx: np.ndarray,
    lam: float,
    *,
    n_true: int,
    p_true: int,
    block_p: int,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Same (vals, counts, stats) contract as ``covgram_screen_pallas``, in
    x's dtype.  x: (N, P) padded, mu: (P,)."""
    npairs = len(i_idx)
    dt = x.dtype
    vals = np.zeros((npairs, block_p, block_p), dtype=dt)
    counts = np.zeros((npairs, 1), dtype=np.int32)
    stats = np.zeros((npairs, 2), dtype=dt)
    iota = np.arange(block_p)
    for t, (ti, tj) in enumerate(zip(i_idx, j_idx)):
        a = x[:, ti * block_p : (ti + 1) * block_p] - mu[
            ti * block_p : (ti + 1) * block_p
        ]
        b = x[:, tj * block_p : (tj + 1) * block_p] - mu[
            tj * block_p : (tj + 1) * block_p
        ]
        S = (a.T @ b) / n_true
        rows = ti * block_p + iota[:, None]
        cols = tj * block_p + iota[None, :]
        valid = (rows < p_true) & (cols < p_true) & (rows != cols)
        absS = np.abs(S)
        mask = valid & (absS > lam)
        vals[t] = np.where(mask, S, 0.0)
        counts[t, 0] = int(mask.sum())
        stats[t, 0] = np.where(valid, absS, 0.0).max(initial=0.0)
        stats[t, 1] = np.where(valid & ~mask, absS, 0.0).max(initial=0.0)
    return vals, counts, stats

"""Pure-jnp oracle for the covgram kernel."""

import jax
import jax.numpy as jnp


@jax.jit
def covgram_ref(x: jax.Array) -> jax.Array:
    """S = (X - mu)'(X - mu) / n in f32, matching ops.covgram's contract."""
    x = x.astype(jnp.float32)
    n = x.shape[0]
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    return (xc.T @ xc) / n

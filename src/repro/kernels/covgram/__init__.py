from repro.kernels.covgram.ops import covgram

__all__ = ["covgram"]

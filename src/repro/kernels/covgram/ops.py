"""Public wrapper for the covgram kernel: padding + mean handling + backend
dispatch (interpret=True off-TPU so the kernel body is validated on CPU)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.covgram.covgram import covgram_pallas


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_n", "block_p"))
def covgram(
    x: jax.Array, *, block_n: int = 512, block_p: int = 256
) -> jax.Array:
    """Centered Gram matrix S = (X - mu)'(X - mu)/n for (n, p) X, f32 out.

    Rows are padded to a block_n multiple with copies of mu (centered
    contribution exactly zero) and columns to a block_p multiple with zeros;
    the divisor stays the true n.
    """
    n, p = x.shape
    bn = min(block_n, max(8, n))
    bp = min(block_p, max(8, p))
    mu = jnp.mean(x.astype(jnp.float32), axis=0)
    pad_n = (-n) % bn
    pad_p = (-p) % bp
    xp = x.astype(jnp.float32)
    if pad_n:
        xp = jnp.concatenate([xp, jnp.broadcast_to(mu, (pad_n, p))], axis=0)
    if pad_p:
        xp = jnp.pad(xp, ((0, 0), (0, pad_p)))
    mup = jnp.pad(mu, (0, pad_p))
    out = covgram_pallas(
        xp, mup, block_n=bn, block_p=bp, interpret=not _is_tpu()
    )
    # kernel divides by padded row count; rescale to the true n
    out = out * ((n + pad_n) / n)
    return out[:p, :p]

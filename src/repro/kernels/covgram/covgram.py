"""Tiled centered-Gram Pallas kernel:  S = (X - mu)'(X - mu) / n.

Grid (ni, nj, nk): (i, j) tile the p x p output, k streams row-chunks of X
from HBM through VMEM.  Both operand tiles are (bn, bp) slabs of the SAME
array X at different column offsets — arithmetic intensity is that of a
rank-bn update per grid step, hitting the MXU with (bp, bn) @ (bn, bp)
contractions accumulated in an f32 VMEM scratch that persists across the
innermost k axis (TPU sequential-grid semantics).

Centering is fused: mu tiles ride along in VMEM so the (n, p) matrix is read
exactly once and the centered copy never exists in HBM.  bf16 inputs upcast
to f32 at the tile level (MXU-native mixed precision).

VMEM budget per step: 2 * bn * bp * in_bytes + bp * bp * 4 (acc) + 2 * bp * 4.
Defaults bn=512, bp=256 (f32): 2*512*256*4 = 1.0 MiB operands + 256 KiB acc —
comfortably inside the ~16 MiB/core VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_i_ref, x_j_ref, mu_i_ref, mu_j_ref, o_ref, acc_ref, *, nk: int, n: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = x_i_ref[...].astype(jnp.float32) - mu_i_ref[...].astype(jnp.float32)
    b = x_j_ref[...].astype(jnp.float32) - mu_j_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / n).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_p", "interpret")
)
def covgram_pallas(
    x: jax.Array,
    mu: jax.Array,
    *,
    block_n: int = 512,
    block_p: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x: (n, p) pre-padded to multiples of (block_n, block_p) with rows equal
    to mu (zero centered contribution); mu: (p,).  Returns (p, p) f32 Gram
    divided by the *unpadded* row count — callers pass n via mu padding
    convention, see ops.covgram."""
    n, p = x.shape
    nk, ni, nj = n // block_n, p // block_p, p // block_p
    mu2 = mu.reshape(1, p)

    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, n=n),
        grid=(ni, nj, nk),
        in_specs=[
            pl.BlockSpec((block_n, block_p), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_n, block_p), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_p), lambda i, j, k: (0, i)),
            pl.BlockSpec((1, block_p), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_p, block_p), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_p, block_p), jnp.float32)],
        interpret=interpret,
    )(x, x, mu2, mu2)

"""Pure-jnp oracle for one threshold + min-label hook step."""

import jax
import jax.numpy as jnp


@jax.jit
def labelprop_step_ref(S: jax.Array, labels: jax.Array, lam) -> jax.Array:
    p = S.shape[0]
    mask = (jnp.abs(S) > lam) & ~jnp.eye(p, dtype=bool)
    big = jnp.int32(2**30)
    neigh = jnp.where(mask, labels[None, :].astype(jnp.int32), big)
    return jnp.minimum(labels.astype(jnp.int32), jnp.min(neigh, axis=1))

"""Fused threshold + min-label-propagation "hook" step.

One round of the paper's graph-partition stage, adapted for TPU (DESIGN.md
Section 3):

    new_label_i = min(label_i, min_{j != i, |S_ij| > lam} label_j)

Grid (ni, nj): i tiles the rows (and the output vector), j streams column
tiles.  The |S|>lam adjacency is formed tile-locally inside VMEM and consumed
immediately by the masked min-reduce — the p x p boolean matrix never exists
in HBM, which is the whole point: the screening stage stays O(p^2) streamed
reads with O(p) state, "orders of magnitude" cheaper than the solve stage
(paper Section 3), even at p ~ 10^5.

Labels are int32 and the min-reduce runs on the VPU; the row-tile accumulator
persists across the j axis (sequential innermost grid).  lam arrives as a
(1, 1) array block so a lambda path never recompiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(s_ref, lab_j_ref, lab_i_ref, lam_ref, o_ref, acc_ref, *, nj, block, p):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = lab_i_ref[...]

    lam = lam_ref[0, 0]
    rows = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    cols = j * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    mask = (jnp.abs(s_ref[...]) > lam) & (rows != cols) & (cols < p)
    big = jnp.int32(2**30)
    neigh = jnp.where(mask, lab_j_ref[...], big)  # lab_j broadcast over rows
    acc_ref[...] = jnp.minimum(acc_ref[...], jnp.min(neigh, axis=1, keepdims=True))

    @pl.when(j == nj - 1)
    def _finish():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("true_p", "block", "interpret"))
def labelprop_step_pallas(
    S: jax.Array,
    labels: jax.Array,
    lam: jax.Array,
    *,
    true_p: int,
    block: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """One hook step. S: (P, P) padded square, labels: (P,) int32, lam: (1,1).
    P must be a block multiple (ops.labelprop_step pads); columns >= true_p
    are masked out of the min-reduce."""
    P = S.shape[0]
    nt = P // block
    lab_row = labels.reshape(P, 1)
    lab_col = labels.reshape(1, P)

    out = pl.pallas_call(
        functools.partial(_kernel, nj=nt, block=block, p=true_p),
        grid=(nt, nt),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j: (i, j)),
            pl.BlockSpec((1, block), lambda i, j: (0, j)),
            pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block, 1), jnp.int32)],
        interpret=interpret,
    )(S, lab_col, lab_row, lam)
    return out[:, 0]

from repro.kernels.threshold_cc.ops import connected_components_kernel, labelprop_step

__all__ = ["connected_components_kernel", "labelprop_step"]

from repro.kernels.threshold_cc.ops import labelprop_step

__all__ = ["labelprop_step"]

"""Public wrapper: padding + backend dispatch + a full CC driver that loops
the Pallas hook step with pointer jumping to a fixed point."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.threshold_cc.threshold_cc import labelprop_step_pallas


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block",))
def labelprop_step(
    S: jax.Array, labels: jax.Array, lam, *, block: int = 256
) -> jax.Array:
    """One fused threshold+hook step: new_l_i = min(l_i, min over thresholded
    neighbours j of l_j).  Pads to a block multiple; padded vertices isolate."""
    p = S.shape[0]
    b = min(block, max(8, p))
    pad = (-p) % b
    Sp = jnp.pad(S.astype(jnp.float32), ((0, pad), (0, pad)))
    lp = jnp.pad(labels.astype(jnp.int32), (0, pad), constant_values=2**30 - 1)
    lam_arr = jnp.asarray(lam, jnp.float32).reshape(1, 1)
    out = labelprop_step_pallas(
        Sp, lp, lam_arr, true_p=p, block=b, interpret=not _is_tpu()
    )
    return out[:p]


@functools.partial(jax.jit, static_argnames=("block",))
def connected_components_kernel(
    S: jax.Array, lam, *, block: int = 256
) -> jax.Array:
    """Full CC labels via the Pallas hook step + host-free pointer jumping.
    Same contract as repro.core.components.connected_components_labelprop."""
    p = S.shape[0]
    init = jnp.arange(p, dtype=jnp.int32)

    def round_(labels):
        labels = labelprop_step(S, labels, lam, block=block)
        labels = labels[labels]
        labels = labels[labels]
        return labels

    def cond(c):
        labels, prev, it = c
        return jnp.logical_and(jnp.any(labels != prev), it < p + 2)

    def body(c):
        labels, _, it = c
        return round_(labels), labels, it + 1

    labels, _, _ = jax.lax.while_loop(cond, body, (round_(init), init, jnp.int32(0)))
    return labels

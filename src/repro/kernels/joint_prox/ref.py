"""Reference (pure jnp) fused prox step for the joint multi-class ADMM.

One joint-ADMM iteration ends with the Z-update: at every matrix entry
(i, j) the K class values are proximal-mapped JOINTLY under the composite
penalty lam1 * l1 + lam2 * P2, where P2 couples the classes:

    group  P2 = sqrt(sum_k theta_k^2)            (off-diagonal entries)
    fused  P2 = sum_{k<k'} |theta_k - theta_k'|  (off-diagonal entries)

Both composite proxes have EXACT closed forms built from two monotone
coordinate-wise-compatible pieces, so no inner iteration is needed:

    group  prox_{t1 l1 + t2 l2}   = group-shrink  o  soft(., t1)
           (sparse-group-lasso order: l1 first, then v * (1 - t2/||v||)_+)
    fused  prox_{t1 l1 + t2 TV_K} = soft(., t1)  o  prox_{t2 TV_K}
           (soft-thresholding is monotone, so the TV subgradient chosen at
           the TV prox stays valid after shrinkage — the Friedman et al.
           2007 fused-lasso argument, which only needs monotonicity)

with TV_K the complete-graph total variation over the K classes.  Its prox
is computed WITHOUT a data-dependent sort primitive (the same code must run
inside the Pallas kernel): stable ranks from K^2 pairwise comparisons, the
rank-r order statistics via one-hot contractions, the stationarity shift
b_r = a_(r) - t(2r - K + 1), and the isotonic regression of b via the exact
minimax formula  y_r = max_{j<=r} min_{l>=r} mean(b_j..b_l)  (pool-adjacent-
violators in closed form; K is small and static, so the K^3 broadcast is a
handful of VPU ops).  Tied inputs produce tied outputs (the prox of a
permutation-symmetric function maps equal coordinates to equal values), so
the arbitrary stable tie-break in the rank is sound.

Diagonal entries take only the l1 piece: the cross-class penalty is
OFF-DIAGONAL by construction (coupling the diagonals would break the
per-class diagonal KKT W_ii = S_ii + lam1 that padding and isolated-vertex
assembly rely on).

The residual reductions ride along exactly like ``shard_prox``:
rp2 = sum((Theta - Z_new)^2), rd2 = sum((Z_new - Z_old)^2), both over all K
classes — the Pallas kernel fuses prox + both reductions into one HBM pass;
this module is the semantics, the off-TPU dispatch target, and the
pallas-vs-ref test oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

PENALTIES = ("group", "fused")


def _soft(x, t):
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


def group_prox(a: jnp.ndarray, t1, t2) -> jnp.ndarray:
    """prox of t1*||.||_1 + t2*||.||_2 along axis 0 of a (K, ...) array."""
    v = _soft(a, t1)
    nrm = jnp.sqrt(jnp.sum(v * v, axis=0, keepdims=True))
    scale = jnp.where(
        nrm > 0.0, jnp.maximum(1.0 - t2 / jnp.where(nrm > 0.0, nrm, 1.0), 0.0), 0.0
    )
    return v * scale


def tv_complete_prox(a: jnp.ndarray, t) -> jnp.ndarray:
    """prox of t * sum_{k<k'} |x_k - x_k'| along axis 0 of a (K, ...) array.

    Sort-free formulation (see module docstring): ranks via pairwise
    comparisons, order statistics via one-hot sums, minimax isotonic fit,
    rank-gather back.  All loops are over the STATIC class axis K."""
    K = a.shape[0]
    if K == 1:
        return a
    t = jnp.asarray(t, a.dtype)
    tail = (1,) * (a.ndim - 1)
    pos = jnp.arange(K).reshape((K,) + tail)
    # stable rank: #(strictly smaller) + #(equal with smaller class index)
    ai = a[:, None]
    aj = a[None, :]
    pi = pos[:, None]
    pj = pos[None, :]
    less = (aj < ai) | ((aj == ai) & (pj < pi))
    rank = jnp.sum(less.astype(a.dtype), axis=1)  # (K, ...), values 0..K-1
    # order statistics a_(r) via one-hot contraction
    r_ids = jnp.arange(K, dtype=a.dtype).reshape((K,) + (1,) * a.ndim)
    onehot = (rank[None] == r_ids).astype(a.dtype)  # (Kr, K, ...)
    asort = jnp.sum(onehot * a[None], axis=1)  # (K, ...) ascending
    # stationarity shift for strictly ordered coordinates
    shift = t * (2.0 * jnp.arange(K, dtype=a.dtype) - (K - 1)).reshape((K,) + tail)
    b = asort - shift
    # prefix sums P[r] = sum of the first r shifted values; a static python
    # loop instead of cumsum so the identical code lowers inside Pallas
    parts = [jnp.zeros(a.shape[1:], a.dtype)]
    for r in range(K):
        parts.append(parts[-1] + b[r])
    prefix = jnp.stack(parts)  # (K+1, ...)
    # segment means M[j, l] = mean(b_j..b_l); only j <= l is ever read below
    num = prefix[None, 1:] - prefix[:-1, None]  # (j, l, ...)
    length = (
        jnp.arange(K, dtype=a.dtype)[None, :] - jnp.arange(K, dtype=a.dtype)[:, None]
        + 1.0
    )
    length = jnp.maximum(length, 1.0).reshape((K, K) + tail)
    M = num / length
    # isotonic fit via minimax: y_r = max_{j<=r} min_{l>=r} M[j, l]
    ys = []
    for r in range(K):
        inner = jnp.min(M[:, r:], axis=1)  # min over l >= r, for every j
        ys.append(jnp.max(inner[: r + 1], axis=0))
    ysort = jnp.stack(ys)  # (K, ...) nondecreasing
    # gather back by rank
    return jnp.sum(onehot * ysort[:, None], axis=0)


def fused_prox(a: jnp.ndarray, t1, t2) -> jnp.ndarray:
    """prox of t1*||.||_1 + t2*TV_complete along axis 0 of a (K, ...) array."""
    return _soft(tv_complete_prox(a, t2), t1)


def joint_prox_entries(a: jnp.ndarray, t1, t2, *, penalty: str) -> jnp.ndarray:
    """Off-diagonal joint prox along the class axis (axis 0)."""
    if penalty == "group":
        return group_prox(a, t1, t2)
    if penalty == "fused":
        return fused_prox(a, t1, t2)
    raise ValueError(f"unknown joint penalty {penalty!r}; available: {PENALTIES}")


def joint_prox_ref(
    theta: jnp.ndarray,
    u: jnp.ndarray,
    z_old: jnp.ndarray,
    t1,
    t2,
    *,
    penalty: str,
):
    """(Z_new, U_new, rp2, rd2) for one (K, b, b) block.

    Diagonal entries take soft(., t1) only (lam2 is off-diagonal); both
    residual partials sum over all K classes."""
    t1 = jnp.asarray(t1, theta.dtype)
    t2 = jnp.asarray(t2, theta.dtype)
    a = theta + u
    z_off = joint_prox_entries(a, t1, t2, penalty=penalty)
    eye = jnp.eye(theta.shape[-1], dtype=bool)
    z_new = jnp.where(eye[None], _soft(a, t1), z_off)
    u_new = a - z_new
    rp2 = jnp.sum((theta - z_new) ** 2)
    rd2 = jnp.sum((z_new - z_old) ** 2)
    return z_new, u_new, rp2, rd2

from repro.kernels.joint_prox.joint_prox import joint_prox_pallas
from repro.kernels.joint_prox.ops import joint_prox_step
from repro.kernels.joint_prox.ref import (
    PENALTIES,
    fused_prox,
    group_prox,
    joint_prox_entries,
    joint_prox_ref,
    tv_complete_prox,
)

__all__ = [
    "PENALTIES",
    "joint_prox_step",
    "joint_prox_ref",
    "joint_prox_pallas",
    "joint_prox_entries",
    "group_prox",
    "fused_prox",
    "tv_complete_prox",
]

"""Dispatch wrapper for the fused joint prox step (padding + backend).

Called inside the joint ADMM's Z-update: on TPU the Pallas kernel fuses the
K-way coupled prox and both residual reductions into one HBM pass (rows and
columns padded to sublane/lane multiples; a zero-padded entry proxes to zero
in every penalty — group and fused proxes both fix the origin — and
contributes nothing to either residual partial, so padding is an exact
no-op).  Off TPU the jnp reference wins — interpret mode would emulate the
fusion at 2-6x the cost, the same trade-off recorded for ``tree_glasso``,
``covgram_screen`` and ``shard_prox``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.joint_prox.joint_prox import joint_prox_pallas
from repro.kernels.joint_prox.ref import (  # noqa: F401  (re-export surface)
    PENALTIES,
    fused_prox,
    group_prox,
    joint_prox_entries,
    joint_prox_ref,
    tv_complete_prox,
)


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def joint_prox_step(
    theta: jax.Array,
    u: jax.Array,
    z_old: jax.Array,
    t1,
    t2,
    *,
    penalty: str,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """(Z_new, U_new, rp2, rd2) for one (K, b, b) iterate block."""
    if not _is_tpu():
        return joint_prox_ref(theta, u, z_old, t1, t2, penalty=penalty)
    K, b, _ = theta.shape
    pad = (-b) % 128
    if pad:
        def padder(m):
            return jnp.pad(m, ((0, 0), (0, pad), (0, pad)))

        theta, u, z_old = padder(theta), padder(u), padder(z_old)
    t = jnp.stack([jnp.asarray(t1), jnp.asarray(t2)]).reshape(1, 2)
    zn, un, acc = joint_prox_pallas(theta, u, z_old, t, penalty=penalty)
    if pad:
        zn, un = zn[:, :b, :b], un[:, :b, :b]
    return zn, un, acc[0, 0], acc[0, 1]

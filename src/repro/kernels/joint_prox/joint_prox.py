"""Pallas kernel: fused K-way joint prox + residual reduction per row tile.

The joint ADMM's hot elementwise tail.  Unfused, the Z-update costs many HBM
round-trips of the (K, b, b) iterate (add, the K-way coupled prox with its
rank/order-statistic broadcasts, the dual update, two squared-difference
reductions); the kernel does one read of (Theta, U, Z_old) and one write of
(Z_new, U_new) per row tile, accumulating both residual partials in a (1, 2)
scalar block that every grid step maps to the same output tile (TPU grids
are sequential, so the accumulation is race-free — the ``shard_prox`` /
``covgram_screen`` pattern).

    grid (b // row_tile,)
    in:  Theta (K, rt, b), U (K, rt, b), Z_old (K, rt, b), t (1, 2)
    out: Z_new (K, rt, b), U_new (K, rt, b), acc (1, 2) = [rp2, rd2]

t = [lam1/rho, lam2/rho] is a TRACED scalar block: adaptive-rho steps never
recompile.  The class axis K rides as the leading block dimension (the
tiling constraint binds the trailing (rt, b) dims); the prox math is the
SAME sort-free code as the jnp reference (``ref.joint_prox_entries``) — K is
static, so the rank/one-hot broadcasts unroll into K^2 VPU ops.  The
diagonal (lam1-only) entries are detected in-kernel from the row-tile
offset via iota, so no mask input is streamed.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.joint_prox.ref import _soft, joint_prox_entries


def _kernel(penalty, theta_ref, u_ref, z_ref, t_ref, zn_ref, un_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    theta = theta_ref[...]
    a = theta + u_ref[...]
    t1 = t_ref[0, 0]
    t2 = t_ref[0, 1]
    _, rt, b = a.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (rt, b), 0) + i * rt
    cols = jax.lax.broadcasted_iota(jnp.int32, (rt, b), 1)
    diag = (rows == cols)[None]
    zn = jnp.where(
        diag,
        _soft(a, t1),
        joint_prox_entries(a, t1, t2, penalty=penalty),
    )
    zn_ref[...] = zn
    un_ref[...] = a - zn
    dp = theta - zn
    dd = zn - z_ref[...]
    acc_ref[0, 0] += jnp.sum(dp * dp)
    acc_ref[0, 1] += jnp.sum(dd * dd)


@functools.partial(
    jax.jit, static_argnames=("penalty", "row_tile", "interpret")
)
def joint_prox_pallas(
    theta: jax.Array,
    u: jax.Array,
    z_old: jax.Array,
    t: jax.Array,
    *,
    penalty: str,
    row_tile: int = 0,
    interpret: bool = False,
):
    """theta/u/z_old: (K, b, b) with b a multiple of row_tile (and ideally of
    the lane width); t: (1, 2) = [[t1, t2]].  Returns (Z_new, U_new,
    acc (1, 2))."""
    K, b, _ = theta.shape
    rt = row_tile or b
    grid = (b // rt,)
    blk = pl.BlockSpec((K, rt, b), lambda i: (0, i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, penalty),
        grid=grid,
        in_specs=[blk, blk, blk, pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_specs=[blk, blk, pl.BlockSpec((1, 2), lambda i: (0, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((K, b, b), theta.dtype),
            jax.ShapeDtypeStruct((K, b, b), theta.dtype),
            jax.ShapeDtypeStruct((1, 2), theta.dtype),
        ],
        interpret=interpret,
    )(theta, u, z_old, t.reshape(1, 2).astype(theta.dtype))

"""Qwen3-30B-A3B: MoE decoder, 128 routed experts top-8, GQA kv=4
[hf:Qwen/Qwen3-30B-A3B].  48L d_model=2048 32H d_ff(expert)=768
vocab=151936."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab=151936,
    head_dim=128,
    moe=True,
    n_experts=128,
    top_k=8,
    d_ff_expert=768,
    n_shared_experts=0,
)

"""Architecture configs (one module per assigned arch) + shape registry."""

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig, get_arch, list_archs

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "get_arch", "list_archs"]

"""SeamlessM4T-medium: encoder-decoder, speech frontend stubbed
[arXiv:2308.11596].  12L enc + 12L dec, d_model=1024 16H (kv=16) d_ff=4096
vocab=256206.  The encoder consumes precomputed frame embeddings
(input_specs() stub); shapes' seq_len applies to the decoder stream."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    encoder_decoder=True,
    n_enc_layers=12,
    enc_len=4096,
    frontend="frames",
)

"""Zamba2-1.2B: Mamba2 backbone + shared full-attention block
[arXiv:2411.15242].  38 mamba layers, d_model=2048, shared attn 32H (MHA
kv=32) + shared MLP d_ff=8192, ssm_state=64, vocab=32000.  Hybrid =>
sub-quadratic => runs the long_500k cell."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    hybrid=True,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
    sub_quadratic=True,
)

"""Config dataclasses + the arch/shape registries.

Every assigned architecture gets one module (src/repro/configs/<id>.py)
exporting CONFIG with the exact assigned dimensions; ``reduced()`` shrinks
any config to a CPU-smoke-test size of the same family.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    # --- MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    moe_every: int = 1             # every k-th layer is MoE
    # --- MLA
    mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- SSM / hybrid
    ssm: bool = False              # rwkv-style attention-free
    hybrid: bool = False           # mamba backbone + shared attention
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    ssm_lora_rank: int = 64
    attn_every: int = 6            # hybrid: shared attn after every k ssm layers
    # --- encoder-decoder
    encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_len: int = 4096            # stub frontend frames fed to the encoder
    # --- modality stub frontend
    frontend: str | None = None    # None | "patch" | "frames"
    frontend_len: int = 256        # embeddings prepended to the token stream
    # --- numerics
    dtype: str = "bfloat16"
    sub_quadratic: bool = False    # may run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        r = {
            "n_layers": min(self.n_layers, 2),
            "d_model": 64,
            "n_heads": 4,
            "n_kv_heads": min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            "head_dim": 16,
            "d_ff": 128,
            "vocab": 256,
        }
        if self.moe:
            r.update(n_experts=4, top_k=2, d_ff_expert=32,
                     n_shared_experts=min(self.n_shared_experts, 1))
        if self.mla:
            r.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        if self.ssm or self.hybrid:
            r.update(ssm_state=8, ssm_head_dim=8, ssm_chunk=8, ssm_lora_rank=8,
                     attn_every=2)
            if self.hybrid:
                r.update(n_layers=4)  # 2 segments -> shared attn exercised
            if self.ssm:
                r.update(d_model=64, n_heads=8, head_dim=8)  # rwkv: H = D/hd
        if self.encoder_decoder:
            r.update(n_enc_layers=2, enc_len=16)
        if self.frontend:
            r.update(frontend_len=4)
        return replace(self, **r)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "internvl2_26b",
    "granite_3_8b",
    "internlm2_20b",
    "qwen2_72b",
    "qwen2_5_3b",
    "deepseek_v2_lite_16b",
    "qwen3_moe_30b_a3b",
    "zamba2_1_2b",
    "rwkv6_7b",
    "seamless_m4t_medium",
]


def get_arch(name: str) -> ArchConfig:
    key = name.replace("-", "_").replace(".", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def cell_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a live dry-run cell; reason if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (full-attention arch)"
    return True, ""

"""DeepSeek-V2-Lite (16B): MLA attention (kv_lora=512) + fine-grained MoE
with 2 shared + 64 routed experts, top-6 [arXiv:2405.04434].
27L d_model=2048 16H d_ff(expert)=1408 vocab=102400.
(The assignment line reads "MoE 64e top-6"; the full V2 has 160 routed
experts — Lite has 64, which is what we build.  See DESIGN.md.)"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    moe=True,
    n_experts=64,
    top_k=6,
    d_ff_expert=1408,
    n_shared_experts=2,
    mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)

"""InternVL2-26B: InternViT frontend (stubbed) + InternLM2-20B-class LM
backbone [arXiv:2404.16821].  48L d_model=6144 48H GQA(kv=8) d_ff=16384
vocab=92553.  The ViT is a modality stub: input_specs() supplies precomputed
patch embeddings prepended to the token stream."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    frontend="patch",
    frontend_len=256,
)

"""RWKV6-7B (Finch): attention-free, data-dependent decay
[arXiv:2404.05892].  32L d_model=4096 d_ff=14336 vocab=65536.
SSM => O(1) decode state => runs the long_500k cell."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,         # d_model / ssm_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    ssm=True,
    ssm_head_dim=64,
    ssm_lora_rank=64,
    sub_quadratic=True,
)

"""Sample covariance / correlation estimators.

All estimators accept an (n, p) data matrix and return a (p, p) symmetric PSD
matrix.  Accumulation is always float32-or-wider regardless of the input dtype
(bf16 inputs are upcast tile-by-tile) — the screening rule compares |S_ij| with
lambda, so covariance entries must be trustworthy to much better than the
lambda grid spacing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _center(X: jax.Array, dtype) -> jax.Array:
    X = X.astype(dtype)
    return X - jnp.mean(X, axis=0, keepdims=True)


def _mean_chunked(X: jax.Array, acc, *, chunk: int) -> jax.Array:
    """Column means accumulated over row chunks, each chunk upcast in the
    scan body — the (n, p) full-precision copy never exists."""
    n, p = X.shape
    pad = (-n) % chunk
    Xp = jnp.pad(X, ((0, pad), (0, 0)))
    chunks = Xp.reshape(-1, chunk, p)

    def body(s, xc):
        return s + xc.astype(acc).sum(axis=0), None

    s, _ = jax.lax.scan(body, jnp.zeros((p,), acc), chunks)
    return s / n


@functools.partial(jax.jit, static_argnames=("ddof", "chunk"))
def sample_covariance(
    X: jax.Array, *, ddof: int = 0, chunk: int = 1024
) -> jax.Array:
    """S = (X - mean)' (X - mean) / (n - ddof).

    The paper's experiments use the maximum-likelihood normalization (ddof=0);
    the estimator is exposed for both conventions.

    bf16/f16 inputs really are upcast tile-by-tile: the mean and the Gram
    accumulate over ``chunk``-row slabs through ``stream.tiler``'s shared
    scan (each slab upcast inside the scan body), so the f32 copy of X never
    materializes — f32/f64 inputs keep the direct one-shot product.
    """
    from repro.stream.tiler import centered_gram_chunked

    n = X.shape[0]
    denom = max(n - ddof, 1)
    if X.dtype in (jnp.bfloat16, jnp.float16):
        acc = jnp.float32
        mu = _mean_chunked(X, acc, chunk=chunk)
        S = centered_gram_chunked(X, mu, acc, chunk=chunk) / denom
    else:
        acc = X.dtype
        Xc = _center(X, acc)
        S = (Xc.T @ Xc) / jnp.asarray(denom, acc)
    return 0.5 * (S + S.T)


@functools.partial(jax.jit, static_argnames=("ddof",))
def sample_correlation(X: jax.Array, *, ddof: int = 0) -> jax.Array:
    """Correlation matrix — what the paper uses for the microarray examples.

    With a correlation input every |S_ij| <= 1 (i != j), so all nodes isolate
    at lambda >= 1 (paper Section 4.2).  ``ddof`` is exposed for convention
    parity with ``sample_covariance`` (the normalization cancels in exact
    arithmetic — S/(d d') is scale-free — so this is API symmetry, not a
    numerically different estimator).
    """
    S = sample_covariance(X, ddof=ddof)
    d = jnp.sqrt(jnp.clip(jnp.diag(S), 1e-12, None))
    R = S / jnp.outer(d, d)
    R = jnp.where(jnp.eye(S.shape[0], dtype=bool), 1.0, R)
    return 0.5 * (R + R.T)


def streaming_covariance(X: jax.Array, *, chunk: int = 4096) -> jax.Array:
    """Covariance via a scan over row-chunks of X.

    For n far larger than memory allows at once, accumulate the Gram matrix and
    the mean in one pass:  S = (X'X - n * mu mu') / n.  The chunked Gram is the
    shape the ``covgram`` Pallas kernel tiles on TPU (HBM->VMEM streaming over
    the n axis).
    """
    n, p = X.shape
    acc = jnp.float32 if X.dtype in (jnp.bfloat16, jnp.float16) else X.dtype
    pad = (-n) % chunk
    Xp = jnp.pad(X.astype(acc), ((0, pad), (0, 0)))
    valid = jnp.pad(jnp.ones((n,), acc), (0, pad))
    Xp = Xp * valid[:, None]
    chunks = Xp.reshape(-1, chunk, p)

    def body(carry, xc):
        gram, ssum = carry
        return (gram + xc.T @ xc, ssum + xc.sum(axis=0)), None

    (gram, ssum), _ = jax.lax.scan(
        body, (jnp.zeros((p, p), acc), jnp.zeros((p,), acc)), chunks
    )
    mu = ssum / n
    S = gram / n - jnp.outer(mu, mu)
    return 0.5 * (S + S.T)


@jax.jit
def impute_missing(X: jax.Array) -> jax.Array:
    """Mean-impute NaNs per feature (paper Section 4.2: examples (B), (C) have
    few missing values, imputed by the mean of the observed expressions)."""
    mask = jnp.isnan(X)
    cnt = jnp.maximum(jnp.sum(~mask, axis=0), 1)
    mu = jnp.where(mask, 0.0, X).sum(axis=0) / cnt
    return jnp.where(mask, mu[None, :], X)

"""Synthetic problem generators reproducing the paper's experimental setups.

``paper_synthetic``   — Section 4.1: block-of-ones signal + calibrated sigma*UU'
                        noise, so that thresholding at an interval of lambdas
                        recovers exactly K components.
``microarray_like``   — Section 4.2 analog: a latent-factor expression matrix
                        with power-law-sized gene modules, giving the rich
                        component-merge profile of Figure 1 (the real
                        Alon / Brown-lab / NKI arrays are not redistributable;
                        the generator matches their (n, p) regimes).
"""

from __future__ import annotations

import numpy as np


def paper_synthetic(K: int, p1: int, *, seed: int = 0) -> np.ndarray:
    """Build S = blkdiag(1, ..., 1) + sigma * U U'  (paper Section 4.1).

    Each of the K signal blocks is the p1 x p1 all-ones matrix.  U has i.i.d.
    standard Gaussian entries and sigma is calibrated so that 1.25x the largest
    absolute off-block-diagonal entry of sigma*UU' equals the smallest nonzero
    entry of the signal (= 1).

    Returns the p x p matrix S with p = K * p1 (float64).
    """
    rng = np.random.default_rng(seed)
    p = K * p1
    S_tilde = np.zeros((p, p))
    for b in range(K):
        sl = slice(b * p1, (b + 1) * p1)
        S_tilde[sl, sl] = 1.0
    U = rng.standard_normal((p, p))
    noise = U @ U.T
    block_id = np.repeat(np.arange(K), p1)
    off_block = block_id[:, None] != block_id[None, :]
    max_off = np.abs(noise[off_block]).max()
    sigma = 1.0 / (1.25 * max_off)
    return S_tilde + sigma * noise


def lambda_interval_for_k(S: np.ndarray, K: int) -> tuple[float, float]:
    """[lambda_min, lambda_max] such that thresholding S at any lambda inside
    gives exactly K connected components (paper Section 4.1 defines
    lambda_I = midpoint, lambda_II = lambda_max of this interval).

    Uses the exact edge-sorted merge profile: components change only at the
    distinct values of |S_ij| (paper Section 4.2).
    """
    from repro.core.partition import merge_profile

    prof = merge_profile(S)
    # prof rows: (edge_value v, n_components, max_comp_size) valid for
    # lambda in [next smaller v, v).
    vals = prof["value"]
    ncomp = prof["n_components"]
    hit = np.nonzero(ncomp == K)[0]
    if hit.size == 0:
        raise ValueError(f"no lambda gives exactly {K} components")
    lo_idx, hi_idx = hit[0], hit[-1]
    # Row k's component structure holds for lambda in [v_{k+1}, v_k) — open at
    # the top because eq. (4) thresholds *strictly*.  The returned closed
    # interval therefore tops out just below v_{lo}.
    lam_max = float(np.nextafter(vals[lo_idx], 0.0))
    lam_min = float(vals[hi_idx + 1]) if hi_idx + 1 < vals.size else 0.0
    return lam_min, lam_max


def microarray_like(
    n: int,
    p: int,
    *,
    n_modules: int = 40,
    min_module: int = 4,
    alpha: float = 1.6,
    noise: float = 0.6,
    seed: int = 0,
) -> np.ndarray:
    """Latent-factor expression matrix X (n x p) whose correlation matrix has a
    power-law module-size structure.

    Genes are partitioned into modules with sizes ~ Zipf(alpha) (clipped), each
    module driven by one latent factor with per-gene loading in [0.4, 1]; the
    remaining genes are pure noise (isolated at moderate lambda).  This
    reproduces the qualitative Figure-1 behaviour: decreasing lambda merges
    modules into growing components while isolated nodes dominate at large
    lambda.
    """
    rng = np.random.default_rng(seed)
    sizes = np.clip(rng.zipf(alpha, size=n_modules), min_module, max(p // 8, min_module))
    # Keep total module genes <= 70% of p; the rest are background noise genes.
    budget = int(0.7 * p)
    keep, tot = [], 0
    for s in sizes:
        if tot + int(s) > budget:
            break
        keep.append(int(s))
        tot += int(s)
    X = rng.standard_normal((n, p)) * noise
    g = 0
    for s in keep:
        z = rng.standard_normal((n, 1))
        load = rng.uniform(0.4, 1.0, size=(1, s)) * rng.choice([-1.0, 1.0], size=(1, s))
        X[:, g : g + s] += z @ load
        g += s
    # Shuffle columns so component structure is not contiguous (exercises the
    # permutation story in Theorem 1).
    perm = rng.permutation(p)
    return X[:, perm]

"""Synthetic problem generators reproducing the paper's experimental setups.

``paper_synthetic``   — Section 4.1: block-of-ones signal + calibrated sigma*UU'
                        noise, so that thresholding at an interval of lambdas
                        recovers exactly K components.
``microarray_like``   — Section 4.2 analog: a latent-factor expression matrix
                        with power-law-sized gene modules, giving the rich
                        component-merge profile of Figure 1 (the real
                        Alon / Brown-lab / NKI arrays are not redistributable;
                        the generator matches their (n, p) regimes).
``structured_synthetic`` — planted-support covariance for the routing-ladder
                        bench: components whose thresholded subgraphs are
                        trees / chordal k-trees / chordless cycles in chosen
                        proportions, with edge magnitudes spread across a
                        lambda interval so a descending path progressively
                        reveals (then merges) the planted structures.
"""

from __future__ import annotations

import numpy as np


def paper_synthetic(K: int, p1: int, *, seed: int = 0) -> np.ndarray:
    """Build S = blkdiag(1, ..., 1) + sigma * U U'  (paper Section 4.1).

    Each of the K signal blocks is the p1 x p1 all-ones matrix.  U has i.i.d.
    standard Gaussian entries and sigma is calibrated so that 1.25x the largest
    absolute off-block-diagonal entry of sigma*UU' equals the smallest nonzero
    entry of the signal (= 1).

    Returns the p x p matrix S with p = K * p1 (float64).
    """
    rng = np.random.default_rng(seed)
    p = K * p1
    S_tilde = np.zeros((p, p))
    for b in range(K):
        sl = slice(b * p1, (b + 1) * p1)
        S_tilde[sl, sl] = 1.0
    U = rng.standard_normal((p, p))
    noise = U @ U.T
    block_id = np.repeat(np.arange(K), p1)
    off_block = block_id[:, None] != block_id[None, :]
    max_off = np.abs(noise[off_block]).max()
    sigma = 1.0 / (1.25 * max_off)
    return S_tilde + sigma * noise


def lambda_interval_for_k(S: np.ndarray, K: int) -> tuple[float, float]:
    """[lambda_min, lambda_max] such that thresholding S at any lambda inside
    gives exactly K connected components (paper Section 4.1 defines
    lambda_I = midpoint, lambda_II = lambda_max of this interval).

    Uses the exact edge-sorted merge profile: components change only at the
    distinct values of |S_ij| (paper Section 4.2).
    """
    from repro.core.partition import merge_profile

    prof = merge_profile(S)
    # prof rows: (edge_value v, n_components, max_comp_size) valid for
    # lambda in [next smaller v, v).
    vals = prof["value"]
    ncomp = prof["n_components"]
    hit = np.nonzero(ncomp == K)[0]
    if hit.size == 0:
        raise ValueError(f"no lambda gives exactly {K} components")
    lo_idx, hi_idx = hit[0], hit[-1]
    # Row k's component structure holds for lambda in [v_{k+1}, v_k) — open at
    # the top because eq. (4) thresholds *strictly*.  The returned closed
    # interval therefore tops out just below v_{lo}.
    lam_max = float(np.nextafter(vals[lo_idx], 0.0))
    lam_min = float(vals[hi_idx + 1]) if hi_idx + 1 < vals.size else 0.0
    return lam_min, lam_max


def _planted_edges(rng, kind: str, p1: int) -> list[tuple[int, int]]:
    """Within-block support of one planted component: tree / 2-tree / cycle."""
    if kind == "tree":
        return [(i, int(rng.integers(0, i))) for i in range(1, p1)]
    if kind == "chordal":
        edges = [(1, 0), (2, 0), (2, 1)]
        for v in range(3, p1):
            a = int(rng.integers(0, v))
            b = int(rng.integers(0, v))
            while b == a:
                b = int(rng.integers(0, v))
            edges += [(v, a), (v, b)]
        return edges
    return [(i, (i + 1) % p1) for i in range(p1)]  # chordless cycle


def structured_synthetic(
    K: int,
    p1: int,
    *,
    tree_frac: float = 0.6,
    chordal_frac: float = 0.25,
    lam_lo: float = 0.3,
    lam_hi: float = 0.8,
    noise: float = 0.9,
    seed: int = 0,
    classes: int | None = None,
    shared_fraction: float = 1.0,
) -> np.ndarray:
    """Covariance with K planted p1-vertex components of known structure.

    Component i's within-block support is a random recursive tree (first
    ``tree_frac`` of blocks), a chordal 2-tree (next ``chordal_frac``), or a
    chordless cycle (the rest — the smallest non-chordal shape, so the
    iterative ladder tail stays exercised).  Edge magnitudes are uniform in
    [lam_lo, lam_hi] and off-block noise stays below ``noise * lam_lo``, so
    any lambda in (noise * lam_lo, lam_hi) screens into (pieces of) the
    planted blocks; descending through the interval both densifies each
    block's subgraph and merges pieces — the full structure-classification
    story on one path.  Diagonals are set diagonally dominant, keeping the
    soft-thresholded matrix PD (the closed-form regime of the ladder bench).

    Returns the p x p matrix S with p = K * p1 (float64), columns shuffled.

    MULTI-CLASS (``classes=k``): returns a (classes, p, p) stack for the
    JOINT workload (``repro.joint``).  The first ``round(shared_fraction *
    K)`` planted blocks are IDENTICAL across classes (same support, same
    edge values — the joint routing ladder's exact closed-form regime); the
    rest are re-drawn per class (same structure kind, class-specific
    support and values — the joint ADMM regime).  Off-block noise is drawn
    per class but stays below ``noise * lam_lo`` everywhere, which keeps
    the hybrid screen clean for BOTH penalties: the fused subset bound is
    weakest at |A| = K where it degenerates to the per-class lam1
    threshold, and the group condition is vacuous once every class is
    below lam1.  Diagonals use the CLASS-MAX absolute row sum, so shared
    blocks stay bit-identical while every class remains diagonally
    dominant; one column permutation is shared by all classes (the classes
    observe the same variables)."""
    if classes is not None:
        return _structured_synthetic_classes(
            K, p1, int(classes), shared_fraction,
            tree_frac=tree_frac, chordal_frac=chordal_frac,
            lam_lo=lam_lo, lam_hi=lam_hi, noise=noise, seed=seed,
        )
    rng = np.random.default_rng(seed)
    p = K * p1
    S = np.zeros((p, p))
    n_tree = int(round(tree_frac * K))
    n_chordal = int(round(chordal_frac * K))
    for blk in range(K):
        base = blk * p1
        if blk < n_tree:
            edges = [(i, int(rng.integers(0, i))) for i in range(1, p1)]
        elif blk < n_tree + n_chordal:
            # 2-tree: triangle seed, then each vertex joins a random edge
            edges = [(1, 0), (2, 0), (2, 1)]
            for v in range(3, p1):
                a = int(rng.integers(0, v))
                b = int(rng.integers(0, v))
                while b == a:
                    b = int(rng.integers(0, v))
                edges += [(v, a), (v, b)]
        else:
            edges = [(i, (i + 1) % p1) for i in range(p1)]  # chordless cycle
        for i, j in edges:
            v = rng.uniform(lam_lo, lam_hi) * (1 if rng.random() < 0.5 else -1)
            S[base + i, base + j] = S[base + j, base + i] = v
    # off-block noise strictly below the screening range
    mask = S == 0
    np.fill_diagonal(mask, False)
    tri = np.triu(mask, 1)
    vals = rng.uniform(0, noise * lam_lo, size=int(tri.sum()))
    signs = rng.choice([-1.0, 1.0], size=vals.size)
    S[tri] = vals * signs
    S = np.triu(S, 1)
    S = S + S.T
    np.fill_diagonal(S, 1.0 + np.abs(S).sum(axis=1))
    perm = rng.permutation(p)
    return S[np.ix_(perm, perm)]


def _structured_synthetic_classes(
    K: int,
    p1: int,
    n_classes: int,
    shared_fraction: float,
    *,
    tree_frac: float,
    chordal_frac: float,
    lam_lo: float,
    lam_hi: float,
    noise: float,
    seed: int,
) -> np.ndarray:
    """The multi-class branch of ``structured_synthetic`` (separate RNG
    stream so the single-class generator stays bit-identical to its
    committed benchmark baselines)."""
    rng = np.random.default_rng(seed)
    p = K * p1
    n_tree = int(round(tree_frac * K))
    n_chordal = int(round(chordal_frac * K))
    n_shared = int(round(np.clip(shared_fraction, 0.0, 1.0) * K))
    kinds = [
        "tree" if b < n_tree else
        "chordal" if b < n_tree + n_chordal else "cycle"
        for b in range(K)
    ]
    stacks = np.zeros((n_classes, p, p))

    def fill(S, base, edges, gen):
        for i, j in edges:
            v = gen.uniform(lam_lo, lam_hi) * (1 if gen.random() < 0.5 else -1)
            S[base + i, base + j] = S[base + j, base + i] = v

    for blk in range(K):
        base = blk * p1
        if blk < n_shared:
            edges = _planted_edges(rng, kinds[blk], p1)
            vals = [
                (i, j,
                 rng.uniform(lam_lo, lam_hi) * (1 if rng.random() < 0.5 else -1))
                for i, j in edges
            ]
            for k in range(n_classes):
                for i, j, v in vals:
                    stacks[k, base + i, base + j] = v
                    stacks[k, base + j, base + i] = v
        else:
            for k in range(n_classes):
                fill(stacks[k], base, _planted_edges(rng, kinds[blk], p1), rng)
    # off-block noise, strictly below the screening range, per class
    block_id = np.repeat(np.arange(K), p1)
    off_block = np.triu(block_id[:, None] != block_id[None, :], 1)
    n_off = int(off_block.sum())
    for k in range(n_classes):
        vals = rng.uniform(0, noise * lam_lo, size=n_off)
        signs = rng.choice([-1.0, 1.0], size=n_off)
        stacks[k][off_block] = vals * signs
        stacks[k] = np.triu(stacks[k], 1)
        stacks[k] = stacks[k] + stacks[k].T
    # class-max row sums keep shared blocks identical AND every class
    # diagonally dominant
    diag = 1.0 + np.abs(stacks).sum(axis=2).max(axis=0)
    for k in range(n_classes):
        np.fill_diagonal(stacks[k], diag)
    perm = rng.permutation(p)
    return stacks[:, perm][:, :, perm]


def microarray_like(
    n: int,
    p: int,
    *,
    n_modules: int = 40,
    min_module: int = 4,
    alpha: float = 1.6,
    noise: float = 0.6,
    seed: int = 0,
) -> np.ndarray:
    """Latent-factor expression matrix X (n x p) whose correlation matrix has a
    power-law module-size structure.

    Genes are partitioned into modules with sizes ~ Zipf(alpha) (clipped), each
    module driven by one latent factor with per-gene loading in [0.4, 1]; the
    remaining genes are pure noise (isolated at moderate lambda).  This
    reproduces the qualitative Figure-1 behaviour: decreasing lambda merges
    modules into growing components while isolated nodes dominate at large
    lambda.
    """
    rng = np.random.default_rng(seed)
    sizes = np.clip(rng.zipf(alpha, size=n_modules), min_module, max(p // 8, min_module))
    # Keep total module genes <= 70% of p; the rest are background noise genes.
    budget = int(0.7 * p)
    keep, tot = [], 0
    for s in sizes:
        if tot + int(s) > budget:
            break
        keep.append(int(s))
        tot += int(s)
    X = rng.standard_normal((n, p)) * noise
    g = 0
    for s in keep:
        z = rng.standard_normal((n, 1))
        load = rng.uniform(0.4, 1.0, size=(1, s)) * rng.choice([-1.0, 1.0], size=(1, s))
        X[:, g : g + s] += z @ load
        g += s
    # Shuffle columns so component structure is not contiguous (exercises the
    # permutation story in Theorem 1).
    perm = rng.permutation(p)
    return X[:, perm]

"""Covariance substrate: estimators, missing-data handling, synthetic generators.

This is the O(n·p^2) front-end of the paper's pipeline (Section 3: "the cost for
creating the sample covariance matrix S is O(n p^2)").  The hot Gram computation
has a Pallas kernel twin in ``repro.kernels.covgram``.
"""

from repro.covariance.estimators import (
    impute_missing,
    sample_correlation,
    sample_covariance,
    streaming_covariance,
)
from repro.covariance.synthetic import (
    lambda_interval_for_k,
    microarray_like,
    paper_synthetic,
    structured_synthetic,
)

__all__ = [
    "sample_covariance",
    "sample_correlation",
    "streaming_covariance",
    "impute_missing",
    "paper_synthetic",
    "microarray_like",
    "structured_synthetic",
    "lambda_interval_for_k",
]

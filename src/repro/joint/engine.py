"""The joint Plan->Execute engine: K-class screen -> plan -> route -> solve.

Mirrors ``repro.engine`` on the class axis:

* **Compiled cache gains K.**  Joint executables live in the SAME
  process-global compiled cache as the single-class solvers
  (``engine.executor.compiled_cached``), keyed ("__joint__", solver, size,
  K, dtype, penalty, warm, opts) — a serving mix of single-class and joint
  requests shares one cache, one lock, one hit/miss telemetry.  lam1/lam2
  are TRACED per-block vectors, so coalesced batches with mixed penalty
  strengths never recompile.

* **Async wave.**  Every bucket is dispatched (jitted vmap over the
  (n, K, size, size) stack) before anything blocks; chronologically the
  same submit-then-sync shape as ``BucketExecutor.solve_plan``.

* **Routing ladder.**  "singleton" assembles closed-form (per class
  1/(S_ii + lam1); lam2 never touches the diagonal).  IDENTICAL class
  blocks reduce the joint problem on the component exactly to ONE
  single-class problem at an effective lambda, so they fan out by union
  shape like the single-class ladder: "joint_forest" (batched forest
  closed form), "joint_chordal" (host clique-tree direct solve),
  "joint_shared" (one single-class iterative solve — 1/K of the coupled
  work).  The reduction,

      fused  lam_eff = lam1            (the symmetric optimum zeroes every
                                        difference; y = 0 is admissible)
      group  lam_eff = lam1 + lam2/sqrt(K)   off-diagonal (the group
                                        subgradient at a symmetric point is
                                        forced to sign/sqrt(K)); the
                                        DIAGONAL keeps lam1, folded in by
                                        shifting the input diagonal by
                                        lam1 - lam_eff before the solve

  is solved once and replicated across classes.  The candidate is accepted
  only on per-class sufficiency: canonical KKT against EVERY class's own
  (shifted) block at lam_eff — for a symmetric candidate that per-class
  certificate implies joint optimality (DESIGN.md Section 12), so
  near-identical misroutes can only fall back, never corrupt.
  "joint_general" (class-specific blocks) takes the K-coupled joint ADMM.

* **Verified, with fallback.**  Every CONDITIONAL route — the shared
  forest/chordal/single-class candidates, whose optimality rests on the
  identical-block reduction — is per-class KKT-certified, and rejections
  re-dispatch to the joint ADMM warm-started from the rejected candidate
  (``joint.fallbacks`` + per-class ``router.fallback.*``).  The joint ADMM
  tail itself is TRUSTED on convergence, the same contract as the
  single-class executor's bcd/pg/admm tail: an absolute W-space KKT gate at
  tol*max|S| is unreachable for iterative solves on badly-scaled blocks
  (dW ~ W dTheta W amplifies a Theta-space residual by ||W||^2 ~ max|S|^2),
  so gating the tail would misfire exactly where the solver is fine.
  ``verify_tail=True`` opts in to the exact host joint-KKT check of every
  tail block (``repro.joint.kkt``; failures re-dispatch with a 10x
  iteration budget, counted as above) — the property tests run with it on
  well-scaled problems.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.instrument import bump, timed_dispatch
from repro.obs.trace import span, trace_request
from repro.core.solvers.closed_form import kkt_ok_stack
from repro.core.solvers.protocol import solver_spec
from repro.core.sparse import resolve_output
from repro.engine.executor import compiled_cached
from repro.engine.options import EngineOptions, normalize_options
from repro.joint.blocks import (
    JointPlan,
    assemble_joint,
    assemble_joint_sparse,
    build_joint_plan,
)
from repro.joint.kkt import joint_kkt_residual
from repro.joint.screen import (
    JointScreenStats,
    _check_penalty,
    joint_thresholded_components,
)
from repro.kernels.tree_glasso.ops import glasso_forest_stack


def joint_effective_lambda(lam1, lam2, K: int, *, penalty: str):
    """Effective single-class lambda of an identical-block joint component."""
    if penalty == "group":
        return lam1 + lam2 / np.sqrt(float(K))
    return lam1 + 0.0 * lam2


def compiled_joint_solver(
    solver: str, size: int, K: int, dtype, penalty: str, *,
    warm: bool = False, opts_key: tuple = (),
):
    """Fetch-or-build the jitted batched joint solver for one (size, K)
    bucket family.  Signature: fn(blocks (n, K, size, size), lam1s (n,),
    lam2s (n,)[, W0, Theta0])."""
    key = (
        "__joint__", solver, int(size), int(K), jnp.dtype(dtype).name,
        penalty, bool(warm), opts_key,
    )

    def build():
        solver_fn = solver_spec(solver).fn
        opts = dict(opts_key)
        if warm:

            def run(blocks, lam1s, lam2s, W0, T0):
                return jax.vmap(
                    lambda Sb, l1, l2, w0, t0: solver_fn(
                        Sb, l1, l2, penalty=penalty, W0=w0, Theta0=t0, **opts
                    )
                )(blocks, lam1s, lam2s, W0, T0)

        else:

            def run(blocks, lam1s, lam2s):
                return jax.vmap(
                    lambda Sb, l1, l2: solver_fn(
                        Sb, l1, l2, penalty=penalty, **opts
                    )
                )(blocks, lam1s, lam2s)

        return jax.jit(run)

    return compiled_cached(key, build)


def compiled_joint_symmetric(
    size: int, K: int, dtype, penalty: str, *, tol: float,
    inner: str = "forest", opts_key: tuple = (),
):
    """Fetch-or-build the batched shared-component solver + per-class
    verifier.

    Returned callable: fn(blocks (n, K, size, size), lam1s (n,), lam2s (n,))
    -> (thetas (n, K, size, size), ok (n,)).  ONE single-class solve of the
    class-mean (diag-shifted) block at lam_eff — the forest closed form for
    ``inner="forest"``, else the named single-class iterative solver (the
    "iterative single-class" path: 1/K of the coupled work) — replicated
    across K; ok certifies the canonical KKT residual of the SAME candidate
    against every class's own shifted block, which for a symmetric
    candidate implies JOINT optimality (module docstring)."""
    key = (
        "__joint_symmetric__", inner, int(size), int(K),
        jnp.dtype(dtype).name, penalty, float(tol), opts_key,
    )

    def build():
        if inner == "forest":
            solve = glasso_forest_stack
        else:
            solver_fn = solver_spec(inner).fn
            opts = dict(opts_key)

            def solve(eff, lam_eff):
                return jax.vmap(
                    lambda Sb, lm: solver_fn(Sb, lm, **opts)
                )(eff, lam_eff)

        def run(blocks, lam1s, lam2s):
            n = blocks.shape[0]
            lam_eff = joint_effective_lambda(lam1s, lam2s, K, penalty=penalty)
            shift = lam1s - lam_eff  # 0 for fused
            eye = jnp.eye(size, dtype=blocks.dtype)
            adjusted = blocks + shift[:, None, None, None] * eye
            eff = jnp.mean(adjusted, axis=1)
            theta = solve(eff, lam_eff)
            flat = adjusted.reshape(n * K, size, size)
            flat_theta = jnp.broadcast_to(
                theta[:, None], (n, K, size, size)
            ).reshape(n * K, size, size)
            ok = kkt_ok_stack(
                flat, jnp.repeat(lam_eff, K), flat_theta, tol=tol
            ).reshape(n, K).all(axis=1)
            return (
                jnp.broadcast_to(theta[:, None], (n, K, size, size)),
                ok,
            )

        return jax.jit(run)

    return compiled_cached(key, build)


def solve_joint_chordal_bucket(
    bucket, plan, *, tol: float
) -> tuple[np.ndarray, np.ndarray]:
    """Host clique-tree direct solve of one identical-block chordal bucket.

    Per block: the class-mean (diag-shifted) sub-block solves ONCE through
    the single-class chordal machinery at lam_eff; the candidate replicates
    across classes and must pass the canonical host KKT against EVERY
    class's own shifted block.  Returns (padded (n, K, size, size) stack,
    per-block ok) — failures join the caller's joint-ADMM fallback."""
    from repro.core.solvers.closed_form import (
        glasso_chordal_host,
        kkt_residual_host,
    )

    n = len(bucket.comps)
    K = plan.K
    lam_eff = float(
        joint_effective_lambda(plan.lam1, plan.lam2, K, penalty=plan.penalty)
    )
    shift = plan.lam1 - lam_eff
    out = np.empty_like(np.asarray(bucket.blocks))
    ok = np.zeros(n, dtype=bool)
    for i, comp in enumerate(bucket.comps):
        b = len(comp)
        cls_blocks = np.asarray(bucket.blocks[i][:, :b, :b], dtype=np.float64)
        cls_blocks = cls_blocks + shift * np.eye(b)
        eff = cls_blocks.mean(axis=0)
        padded = np.broadcast_to(
            np.eye(bucket.size, dtype=out.dtype) / (1.0 + plan.lam1),
            (K, bucket.size, bucket.size),
        ).copy()
        try:
            theta = glasso_chordal_host(eff, lam_eff)
            res = max(
                kkt_residual_host(cls_blocks[k], lam_eff, theta)
                for k in range(K)
            )
            scale = max(1.0, float(np.abs(cls_blocks).max()))
            ok[i] = res <= tol * scale
            padded[:, :b, :b] = theta
        except (ValueError, np.linalg.LinAlgError):
            ok[i] = False
        out[i] = padded
    return out, ok


class JointEngine:
    """Reusable K-class pipeline: fixed (solver, dtype, cc_backend, route).

    The penalty and (lam1, lam2) are per-call — they are request data, like
    lambda on the single-class path."""

    def __init__(
        self,
        *,
        options: EngineOptions | None = None,
        **legacy_engine_kwargs,
    ):
        """Configured by one ``EngineOptions`` (``options=``); the historical
        kwargs (``solver=``, ``route=``, ``verify_tail=``, solver opts)
        normalize through the shared chokepoint without warning — the public
        ``joint_glasso`` wrapper owns the deprecation signal."""
        opts = normalize_options(
            options, legacy_engine_kwargs, context="JointEngine"
        )
        self.options = opts
        solver = opts.resolved_solver("joint_admm")
        spec = solver_spec(solver)
        if not spec.meta.get("joint"):
            raise ValueError(
                f"solver {solver!r} is not a joint solver (spec.meta['joint'])"
            )
        self.output = opts.output
        self.last_assemble_seconds = 0.0
        self.solver = solver
        self.dtype = opts.resolved_dtype()
        self.np_dtype = np.dtype(jnp.dtype(self.dtype).name)
        self.cc_backend = opts.cc_backend
        self.route = opts.route
        self.route_check_tol = opts.route_check_tol
        self.verify_tail = opts.verify_tail
        self.stream = opts.stream
        solver_opts = dict(opts.solver_opts)
        self.solver_opts = solver_opts
        self._opts_key = tuple(sorted(solver_opts.items()))
        # the "joint_shared" rung's single-class solver (identical blocks,
        # general union shape): bcd — the same solver the per-class
        # baseline would pay K times — fed the subset of the joint solver's
        # options it understands (tol travels; admm-specific knobs do not)
        self.effective_solver = "bcd"
        import inspect

        from repro.core.solvers import SOLVERS

        eff_accept = set(
            inspect.signature(SOLVERS[self.effective_solver]).parameters
        )
        self._effective_opts_key = tuple(
            sorted(
                (k, v) for k, v in solver_opts.items() if k in eff_accept
            )
        )

    def _trace_ctx(self, name: str, **attrs):
        """Root a request trace — or join the ambient one (the serving
        batcher owns the root for submitted joint work).  Mirrors
        ``Engine._trace_ctx``; ``EngineOptions(trace=False)`` keeps the
        joint engine span-free."""
        from contextlib import nullcontext

        if not self.options.trace:
            return nullcontext()
        return trace_request(name, **attrs)

    # -- stages ------------------------------------------------------------

    def screen(
        self, Ss, lam1: float, lam2: float, *, penalty: str
    ) -> tuple[np.ndarray, JointScreenStats]:
        with span("engine.screen", backend=self.cc_backend, kind="joint"):
            return joint_thresholded_components(
                Ss, lam1, lam2, penalty=penalty, backend=self.cc_backend
            )

    def plan(
        self, Ss, lam1: float, lam2: float, labels, *, penalty: str,
        classify: bool | None = None,
    ) -> JointPlan:
        if classify is None:
            classify = self.route
        with span("engine.plan", kind="joint"):
            return build_joint_plan(
                Ss, lam1, lam2, labels, penalty=penalty, dtype=self.np_dtype,
                classify_structures=classify,
            )

    # -- solve -------------------------------------------------------------

    def run(
        self,
        Ss,
        lam1: float,
        lam2: float = 0.0,
        *,
        penalty: str = "group",
        screen: bool = True,
        labels: np.ndarray | None = None,
        screen_stats: JointScreenStats | None = None,
        output: str | None = None,
    ):
        """One joint solve; see ``repro.joint.api.joint_glasso`` for the
        user-facing wrapper and result object."""
        from repro.joint.api import _joint_result

        _check_penalty(penalty)
        Ss = [S if hasattr(S, "gather_block") else np.asarray(S) for S in Ss]
        if len({S.shape for S in Ss}) != 1:
            raise ValueError("all class covariances must share one shape")
        p = Ss[0].shape[0]
        with self._trace_ctx(
            "engine.joint", lam1=float(lam1), lam2=float(lam2),
            K=len(Ss), p=int(p),
        ):
            screened = True
            if labels is not None:
                labels = np.asarray(labels)
            elif any(hasattr(S, "gather_block") for S in Ss):
                raise ValueError(
                    "materialized covariances cannot be re-screened densely; "
                    "pass the streamed labels (see JointEngine.run_from_data)"
                )
            elif screen:
                labels, screen_stats = self.screen(
                    Ss, lam1, lam2, penalty=penalty
                )
            else:
                labels = np.zeros(p, dtype=np.int64)
                screen_stats = None
                screened = False
            plan = self.plan(
                Ss, lam1, lam2, labels, penalty=penalty,
                classify=self.route and screened,
            )
            out_mode = resolve_output(
                self.output if output is None else output, p
            )
            t0 = time.perf_counter()
            with span("engine.solve", kind="joint"):
                Theta, fallbacks = self.solve_plan(plan, Ss, output=out_mode)
            seconds = time.perf_counter() - t0
            return _joint_result(
                plan, labels, screen_stats, Theta, seconds, self.solver,
                routed=self.route, fallbacks=fallbacks,
                assemble_seconds=self.last_assemble_seconds,
            )

    def run_from_data(
        self,
        Xs,
        lam1: float,
        lam2: float = 0.0,
        *,
        penalty: str = "group",
        stream=None,
        output: str | None = None,
    ):
        """One joint solve screened straight from the per-class (n_k, p)
        data matrices — no class's dense S ever exists (``repro.joint.
        stream``)."""
        from repro.joint.stream import joint_stream_screen

        if stream is None:
            stream = self.stream
        with self._trace_ctx(
            "engine.joint", lam1=float(lam1), lam2=float(lam2), K=len(Xs),
            source="data",
        ):
            with span("engine.screen", backend="stream", kind="joint"):
                sc = joint_stream_screen(
                    Xs, lam1, lam2, penalty=penalty, config=stream
                )
            return self.run(
                sc.S, lam1, lam2, penalty=penalty,
                labels=sc.labels, screen_stats=sc.stats, output=output,
            )

    def solve_plan(
        self, plan: JointPlan, Ss, *, output: str = "dense"
    ) -> tuple[np.ndarray, int]:
        """Dispatch all buckets async, verify, repair, assemble.

        Returns (Theta, fallbacks for THIS solve) — Theta is the dense
        (K, p, p) stack, or a ``JointSparseTheta`` over the bucket solution
        stacks when ``output="sparse"`` (no (K, p, p) allocation)."""
        from repro.engine.registry import route_for

        if self.route and len(plan.isolated):
            bump("router.route.singleton", int(len(plan.isolated)))
        pending = []  # (bucket, out, ok)
        for bucket in plan.buckets:
            n = len(bucket.comps)
            route = route_for(bucket.structure) if self.route else "iterative"
            if self.route:
                bump(f"router.route.{bucket.structure}", n)
            if route == "chordal" and bucket.structure == "joint_chordal":
                # host direct solve: no device round-trip for the candidate
                # (the padded class stack is only re-read on fallback, from
                # the host copy the bucket already holds)
                (out, ok), _ = timed_dispatch(
                    solve_joint_chordal_bucket,
                    bucket, plan, tol=self.route_check_tol,
                )
                bump("joint.dispatches")
                bump("joint.closed_form_blocks", n)
                pending.append([bucket, out, ok])
                continue
            stacked = jnp.asarray(bucket.blocks, self.dtype)
            lam1s = jnp.full((n,), plan.lam1, self.dtype)
            lam2s = jnp.full((n,), plan.lam2, self.dtype)
            if route == "closed_form" and bucket.structure == "joint_forest":
                fn = compiled_joint_symmetric(
                    bucket.size, plan.K, self.dtype, plan.penalty,
                    tol=self.route_check_tol, inner="forest",
                )
                (out, ok), _ = timed_dispatch(fn, stacked, lam1s, lam2s)
                bump("joint.dispatches")
                bump("joint.closed_form_blocks", n)
            elif bucket.structure == "joint_shared" and self.route:
                # identical blocks, general union shape: ONE single-class
                # iterative solve at lam_eff instead of the K-coupled ADMM
                fn = compiled_joint_symmetric(
                    bucket.size, plan.K, self.dtype, plan.penalty,
                    tol=self.route_check_tol, inner=self.effective_solver,
                    opts_key=self._effective_opts_key,
                )
                (out, ok), _ = timed_dispatch(fn, stacked, lam1s, lam2s)
                bump("joint.dispatches")
                bump("joint.shared_blocks", n)
            else:
                fn = compiled_joint_solver(
                    self.solver, bucket.size, plan.K, self.dtype,
                    plan.penalty, opts_key=self._opts_key,
                )
                out, _ = timed_dispatch(fn, stacked, lam1s, lam2s)
                ok = None
                bump("joint.dispatches")
            pending.append([bucket, out, ok])

        # single synchronization point for the primary wave
        with span("engine.barrier"):
            jax.block_until_ready(
                [p[1] for p in pending if isinstance(p[1], jax.Array)]
            )
        # verify every bucket, DISPATCH all repairs, only then block once
        # more — repairs form their own async wave instead of serializing
        # (the single-class executor's repair shape)
        fallbacks = 0
        solutions = []
        repairs = []  # (solutions index, row idx, in-flight re-solve)
        for bucket, out, ok in pending:
            out = np.asarray(out)
            if ok is not None:  # conditional-route candidates: verdicts
                idx = np.flatnonzero(~np.asarray(ok))
            elif self.verify_tail:  # opt-in: exact host joint-KKT verdicts
                bad = [
                    i
                    for i in range(out.shape[0])
                    if not self._admm_ok(bucket.blocks[i], out[i], plan)
                ]
                idx = np.asarray(bad, dtype=np.int64)
            else:  # the iterative tail is trusted on convergence
                idx = np.empty(0, dtype=np.int64)
            if idx.size:
                fallbacks += int(idx.size)
                bump("joint.fallbacks", int(idx.size))
                bump(f"router.fallback.{bucket.structure}", int(idx.size))
                fixed = self._dispatch_fallback(
                    bucket, plan, np.asarray(bucket.blocks)[idx],
                    np.full(idx.size, plan.lam1), np.full(idx.size, plan.lam2),
                    out[idx],
                )
                out = np.array(out)
                repairs.append((len(solutions), idx, fixed))
            solutions.append(out)
        if repairs:
            jax.block_until_ready([r[2] for r in repairs])
            for pos, idx, fixed in repairs:
                solutions[pos][idx] = np.asarray(fixed)
        t0 = time.perf_counter()
        with span("engine.assemble", output=output):
            if output == "sparse":
                Theta = assemble_joint_sparse(plan, solutions, Ss)
            else:
                Theta = assemble_joint(plan, solutions, Ss)
        self.last_assemble_seconds = time.perf_counter() - t0
        bump("engine.assemble_us", int(self.last_assemble_seconds * 1e6))
        return Theta, fallbacks

    def _admm_ok(self, S_stack: np.ndarray, theta: np.ndarray, plan) -> bool:
        scale = max(1.0, float(np.abs(S_stack).max()))
        res = joint_kkt_residual(
            S_stack, theta, plan.lam1, plan.lam2, penalty=plan.penalty
        )
        return res <= self.route_check_tol * scale

    def _dispatch_fallback(
        self, bucket, plan, blocks, lam1s, lam2s, candidates
    ):
        """Re-dispatch rejected candidates to the joint ADMM, warm-started
        from the rejected candidate (its per-class inverse is the W seed,
        the candidate itself the Theta seed), with a 10x iteration budget
        and 10x tighter inner tolerance — the joint analog of
        ``executor.dispatch_repair``.  With lam2 = 0 this IS K independent
        single-class re-solves (the prox decouples), i.e. the iterative
        single-class fallback."""
        opts = dict(self._opts_key)
        # 10x the configured budget, floored at a full default budget — a
        # starved caller's repair must not inherit the starvation
        opts["max_iter"] = max(10 * int(opts.get("max_iter", 2000)), 5000)
        opts["tol"] = min(float(opts.get("tol", 1e-7)), 1e-7) / 10.0
        sub = jnp.asarray(blocks, self.dtype)
        cand = jnp.asarray(candidates, self.dtype)
        W0 = jnp.linalg.inv(cand)
        finite = jnp.all(jnp.isfinite(W0), axis=(1, 2, 3), keepdims=True)
        eye = jnp.eye(bucket.size, dtype=self.dtype)
        cold_W = sub + jnp.asarray(lam1s, self.dtype)[:, None, None, None] * eye
        diag = jnp.diagonal(sub, axis1=2, axis2=3)
        cold_T = jnp.where(
            jnp.eye(bucket.size, dtype=bool),
            (1.0 / (diag + jnp.asarray(lam1s, self.dtype)[:, None, None]))[
                ..., None
            ]
            * jnp.eye(bucket.size, dtype=self.dtype),
            0.0,
        )
        W0 = jnp.where(finite, W0, cold_W)
        T0 = jnp.where(finite, cand, cold_T)
        fn = compiled_joint_solver(
            self.solver, bucket.size, plan.K, self.dtype, plan.penalty,
            warm=True, opts_key=tuple(sorted(opts.items())),
        )
        bump("joint.dispatches")
        out, _ = timed_dispatch(
            fn,
            sub, jnp.asarray(lam1s, self.dtype), jnp.asarray(lam2s, self.dtype),
            W0, T0,
        )
        return out

"""Joint-plan construction: K-stacked buckets over the union partition.

The single-class planner buckets same-(padded size, structure) components so
one vmapped solver call covers a whole bucket; the joint planner reuses that
machinery (``blocks.group_components`` with the union-graph classifier from
``repro.joint.screen``) but every bucket carries a (n_blocks, K, size, size)
stack — the K class blocks of each component, gathered per class through the
covariance gather protocol, so dense stacks and per-class materialized
streamed covariances plan identically.

Bucket identity gains K: the joint executor's compiled-cache keys are
(size, K, penalty, ...), so a serving mix of different class counts shares
executables per (size, K) family exactly like the single-class cache shares
per size.

Padding is per class with the identity, and is exact for the joint problem
by the same Theorem-1 corollary as the single-class case: a padded
coordinate has zero off-diagonal entries in EVERY class, so no hybrid
condition can make it an edge (both (G) and (F) of ``screen.py`` hold
trivially at s = 0), and its joint solution is 1/(1 + lam1) on each class
diagonal — exactly what ``assemble_joint`` discards.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import blocks as blocks_mod
from repro.core.components import component_lists
from repro.core.instrument import bump, set_peak
from repro.joint.screen import classify_joint_component


@dataclass
class JointBucket:
    size: int                      # padded per-class block size
    comps: list[np.ndarray]        # member-vertex arrays
    blocks: np.ndarray             # (n_blocks, K, size, size) padded stacks
    structure: str = "joint_general"


@dataclass
class JointPlan:
    p: int
    K: int
    lam1: float
    lam2: float
    penalty: str
    labels: np.ndarray
    isolated: np.ndarray           # vertex ids with |comp| = 1
    buckets: list[JointBucket] = field(default_factory=list)

    @property
    def n_components(self) -> int:
        return len(self.isolated) + sum(len(b.comps) for b in self.buckets)


def make_joint_bucket(
    Ss, size: int, members: list[np.ndarray], *, dtype=np.float64,
    structure: str = "joint_general",
) -> JointBucket:
    """Pad and stack one size-group of union components across all classes."""
    stacks = []
    for c in members:
        stacks.append(
            np.stack(
                [
                    blocks_mod.pad_block(
                        blocks_mod.gather_submatrix(S, c, dtype=dtype), size
                    )
                    for S in Ss
                ]
            )
        )
    return JointBucket(
        size=size, comps=members, blocks=np.stack(stacks), structure=structure
    )


def build_joint_plan(
    Ss,
    lam1: float,
    lam2: float,
    labels: np.ndarray,
    *,
    penalty: str,
    dtype=np.float64,
    classify_structures: bool = True,
) -> JointPlan:
    """Group union components into padded same-(size, K, structure) buckets.

    ``classify_structures=False`` tags every bucket "joint_general" — the
    unrouted baseline (every block takes the joint ADMM), required when
    ``labels`` does not come from a real hybrid screen (screen=False forces
    one global pseudo-component, which is not a union component)."""
    bump("planner.plans_built")
    comps = component_lists(labels)
    classify = (
        (lambda c: classify_joint_component(Ss, c, lam1, lam2, penalty=penalty))
        if classify_structures
        else None
    )
    isolated, by_key = blocks_mod.group_components(comps, classify=classify)
    buckets = []
    for (size, structure), members in by_key.items():
        bump("planner.buckets_padded")
        buckets.append(
            make_joint_bucket(
                Ss, size, members, dtype=dtype,
                structure=structure if classify is not None else "joint_general",
            )
        )
    p = Ss[0].shape[0]
    return JointPlan(
        p=p,
        K=len(Ss),
        lam1=float(lam1),
        lam2=float(lam2),
        penalty=penalty,
        labels=np.asarray(labels),
        isolated=isolated,
        buckets=buckets,
    )


def assemble_joint(
    plan: JointPlan, bucket_solutions: list[np.ndarray], Ss
) -> np.ndarray:
    """Scatter per-component joint solutions into the dense (K, p, p) Theta.

    Delegates per class to the single-class ``assemble_dense`` (batched
    fancy-index scatter, isolated vertices closed-form at 1/(S_ii + lam1) —
    lam2 never touches the diagonal, so the single-class formula IS the
    joint one), writing per-class views of ONE (K, p, p) allocation — the
    dense stack is touched exactly once."""
    dtype = (
        np.asarray(bucket_solutions[0]).dtype
        if bucket_solutions
        else blocks_mod.cov_dtype(Ss[0])
    )
    out = np.zeros((plan.K, plan.p, plan.p), dtype=dtype)
    set_peak("result.bytes_peak", out.nbytes)
    shim = blocks_mod.Plan(
        p=plan.p,
        lam=plan.lam1,
        labels=plan.labels,
        isolated=plan.isolated,
        buckets=[
            blocks_mod.Bucket(
                size=b.size, comps=b.comps, blocks=None, structure=b.structure
            )
            for b in plan.buckets
        ],
    )
    for k in range(plan.K):
        sols_k = [np.asarray(sols)[:, k] for sols in bucket_solutions]
        blocks_mod.assemble_dense(shim, sols_k, Ss[k], out=out[k])
    return out


def assemble_joint_sparse(
    plan: JointPlan, bucket_solutions: list[np.ndarray], Ss
):
    """Assemble per-component joint solutions into a ``JointSparseTheta``
    with ZERO (K, p, p) allocation — the joint sibling of ``core.blocks.
    assemble_sparse``: the (n, K, size, size) bucket stacks become the block
    storage as-is, one shared component index serves every class, and
    isolated vertices keep their per-class closed form 1/(S_ii + lam1)."""
    from repro.core.sparse import JointSparseTheta, _build_index

    stacks = [np.asarray(sols) for sols in bucket_solutions]
    dtype = stacks[0].dtype if stacks else blocks_mod.cov_dtype(Ss[0])
    comps: list[np.ndarray] = []
    loc: list[tuple[int, int]] = []
    for s, bucket in enumerate(plan.buckets):
        for r, comp in enumerate(bucket.comps):
            comps.append(np.asarray(comp, dtype=np.int64))
            loc.append((s, r))
    isolated = np.asarray(plan.isolated, dtype=np.int64)
    if isolated.size:
        iso_vals = np.stack(
            [
                (1.0 / (blocks_mod.gather_diag(S, isolated) + plan.lam1)).astype(
                    dtype, copy=False
                )
                for S in Ss
            ]
        )
    else:
        iso_vals = np.zeros((plan.K, 0), dtype=dtype)
    comp_id, pos_in = _build_index(plan.p, comps, isolated)
    Theta = JointSparseTheta(
        plan.K, plan.p, dtype, stacks, comps, loc, comp_id, pos_in,
        isolated, iso_vals,
    )
    set_peak("result.bytes_peak", Theta.nbytes())
    return Theta

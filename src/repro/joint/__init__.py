"""Joint multi-class graphical lasso: exact hybrid thresholding + a fused/
group-penalty solver stack over the existing Plan->Execute machinery.

    from repro.joint import joint_glasso
    res = joint_glasso([S_1, S_2, S_3], lam1=0.4, lam2=0.1, penalty="group")
    res.Theta        # (K, p, p)
    res.route_mix    # {"singleton": ..., "joint_forest": ..., ...}

Modules: ``screen`` (the Tang et al. exact hybrid rule + union-graph
classifier), ``stream`` (the out-of-core per-class screen), ``admm`` (the
group/fused joint ADMM over the ``kernels/joint_prox`` fused prox),
``kkt`` (exact joint-KKT verification), ``blocks``/``engine`` (K-stacked
planning + the routed executor on the shared compiled cache), ``api``
(``joint_glasso``).  Serving admission lives in
``launch.serve_glasso.GlassoServer.submit_joint``.
"""

from repro.core.solvers.protocol import SolverSpec, register_solver
from repro.joint.admm import joint_admm, joint_admm_info
from repro.joint.api import JointGlassoResult, joint_glasso
from repro.joint.engine import JointEngine
from repro.joint.kkt import joint_kkt_ok, joint_kkt_residual
from repro.joint.screen import (
    JointScreenStats,
    joint_thresholded_components,
    joint_union_adjacency,
)
from repro.joint.stream import JointStreamScreen, joint_stream_screen

# The joint solver joins the capability-tagged registry: batched=False keeps
# it out of the single-class SOLVERS view (its contract is a (K, b, b)
# stack), meta["joint"] is what JointEngine requires, and theta_warm lets
# repairs/fallbacks hand back the Theta seed they already hold.
register_solver(
    SolverSpec(
        name="joint_admm",
        fn=joint_admm,
        batched=False,
        warm_startable=True,
        description="group/fused joint ADMM over the K-class stack",
        meta={"joint": True, "theta_warm": True},
    )
)

__all__ = [
    "joint_glasso",
    "JointGlassoResult",
    "JointEngine",
    "joint_admm",
    "joint_admm_info",
    "joint_kkt_residual",
    "joint_kkt_ok",
    "joint_thresholded_components",
    "joint_union_adjacency",
    "JointScreenStats",
    "joint_stream_screen",
    "JointStreamScreen",
]

"""Exact hybrid covariance thresholding for the joint graphical lasso.

Tang, Yang, Peng & Xu (arXiv:1503.02128) generalize the source paper's
Theorem 1 to K classes estimated JOINTLY under

    min_{Theta_1..Theta_K}  sum_k [ -logdet Theta_k + tr(S_k Theta_k)
                                    + lam1 ||Theta_k||_1 ]
                            + lam2 * P2({Theta_k})                      (J)

    P2 group:  sum_{i != j} sqrt(sum_k Theta_k,ij^2)
    P2 fused:  sum_{i != j} sum_{k<k'} |Theta_k,ij - Theta_k',ij|

(lam1 penalizes every entry including the diagonal — the single-class
convention of this repo, so lam2 = 0 decouples (J) into K independent
``glasso`` problems exactly; lam2 couples OFF-DIAGONAL entries only).

The screen is per-PAIR but HYBRID across classes: whether (i, j) can carry
an edge in ANY class depends on the whole vector s = (S_1,ij .. S_K,ij).
Writing the zero-subgradient feasibility of (J) at Theta_ij,: = 0:

    group:  exists z in [-1,1]^K, ||c||_2 <= 1 with s_k = lam1 z_k + lam2 c_k
            <=>  sum_k soft(|s_k|, lam1)^2 <= lam2^2                    (G)

    fused:  exists z in [-1,1]^K and antisymmetric y_kk' in [-1,1] with
            s_k = lam1 z_k + lam2 sum_k' y_kk'
            <=>  for every nonempty A subset {1..K}:
                 |sum_{k in A} s_k| <= |A| lam1 + |A|(K-|A|) lam2       (F)
            (max-flow / polymatroid duality: within-A y's cancel in the
            subset sum, each boundary pair contributes at most lam2)

(F) looks exponential but is not: for fixed |A| = m the extreme subset sums
are the m largest and m smallest of s, so sorting s once reduces the check
to K prefix-sum comparisons per pair — ``fused_subset_excess``.  Both
conditions are STRICT-inequality screens like eq. (4): a tie (equality)
is NOT an edge.  With lam2 = 0 both reduce to "any |s_k| > lam1" — the
union of the per-class Theorem-1 screens.

The union graph over all pairs whose condition FAILS partitions the
vertices; Tang et al. prove the joint solution's union support graph
induces EXACTLY this partition, so the joint problem decomposes into
independent per-component joint problems — the K-class Theorem 1.
``joint_thresholded_components`` emits the canonical labels through any
registered cc backend (the union adjacency is fed to ``registry.
label_components`` as a 0/1 matrix thresholded at 1/2), so host/jax/
pallas/shard_map all serve the joint screen unchanged.

This module also owns the union-graph STRUCTURE CLASSIFIER for the joint
routing ladder (``classify_joint_component``); see ``repro.joint.engine``
for how "joint_forest" buckets reach the batched closed-form fast path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.instrument import bump

PENALTIES = ("group", "fused")


def _check_penalty(penalty: str) -> str:
    if penalty not in PENALTIES:
        raise ValueError(f"unknown joint penalty {penalty!r}; available: {PENALTIES}")
    return penalty


def fused_subset_excess(
    vals: np.ndarray, slack: float, lam2: float
) -> np.ndarray:
    """Worst subset-sum violation of the fused feasibility system (F).

    ``vals`` has the class axis FIRST: shape (m, ...).  Returns, per
    trailing position, max over subset sizes mm of

        max( sum of mm largest, -(sum of mm smallest) )
        - ( mm * slack + lam2 * mm * (m - mm) )

    i.e. > 0 iff NO feasible (z, y) exists — for the screen this is the
    edge indicator (slack = lam1); the joint KKT verifier reuses it with
    slack = 0 on tied active groups (``repro.joint.kkt``)."""
    vals = np.asarray(vals, dtype=np.float64)
    m = vals.shape[0]
    srt = np.sort(vals, axis=0)  # ascending
    prefix = np.concatenate(
        [np.zeros((1,) + vals.shape[1:]), np.cumsum(srt, axis=0)], axis=0
    )
    total = prefix[m]
    excess = np.full(vals.shape[1:], -np.inf)
    for mm in range(1, m + 1):
        top = total - prefix[m - mm]      # sum of mm largest
        bot = prefix[mm]                  # sum of mm smallest
        bound = mm * slack + lam2 * mm * (m - mm)
        excess = np.maximum(excess, np.maximum(top, -bot) - bound)
    return excess


def pair_excess(
    vals: np.ndarray, lam1: float, lam2: float, *, penalty: str
) -> np.ndarray:
    """Hybrid-rule violation per pair; > 0 is an edge (strict, ties are not).

    ``vals`` carries the K class values along axis 0 (any trailing shape:
    a (K, p, p) dense stack, or (K, E) candidate columns on the streamed
    path)."""
    _check_penalty(penalty)
    vals = np.asarray(vals, dtype=np.float64)
    if penalty == "group":
        soft = np.maximum(np.abs(vals) - lam1, 0.0)
        return np.einsum("k...,k...->...", soft, soft) - lam2 * lam2
    return fused_subset_excess(vals, lam1, lam2)


def joint_union_adjacency(
    Ss: np.ndarray | list, lam1: float, lam2: float, *, penalty: str
) -> np.ndarray:
    """Boolean union adjacency of the hybrid-thresholded K-class graph."""
    stack = np.stack([np.asarray(S, dtype=np.float64) for S in Ss])
    adj = pair_excess(stack, lam1, lam2, penalty=penalty) > 0.0
    np.fill_diagonal(adj, False)
    return adj & adj.T  # symmetric by construction; belt and braces


@dataclass
class JointScreenStats:
    """Per-screen statistics, the K-class analog of ``ScreenStats``."""

    lam1: float
    lam2: float
    penalty: str
    K: int
    n_components: int
    max_comp: int
    n_isolated: int
    n_edges: int                 # union-graph edges (hybrid rule)
    seconds: float
    # streaming provenance (zero for dense screens):
    candidate_pairs: int = 0     # pairs with |S_k,ij| > lam1 in >= 1 class
    tiles_total: int = 0         # per-class tile pairs scheduled, summed
    tiles_skipped: int = 0       # per-class Cauchy-Schwarz prunes, summed


def _stats_from_labels(
    labels: np.ndarray,
    n_edges: int,
    lam1: float,
    lam2: float,
    penalty: str,
    K: int,
    seconds: float,
) -> JointScreenStats:
    _, counts = np.unique(labels, return_counts=True)
    return JointScreenStats(
        lam1=float(lam1),
        lam2=float(lam2),
        penalty=penalty,
        K=int(K),
        n_components=int(counts.size),
        max_comp=int(counts.max()),
        n_isolated=int((counts == 1).sum()),
        n_edges=int(n_edges),
        seconds=seconds,
    )


def joint_thresholded_components(
    Ss,
    lam1: float,
    lam2: float,
    *,
    penalty: str = "group",
    backend: str = "host",
    **backend_opts,
) -> tuple[np.ndarray, JointScreenStats]:
    """Canonical labels of the hybrid-thresholded union graph + stats.

    ``backend`` names any registered cc backend (host/jax/pallas/shard_map
    or user-registered): the union adjacency is handed to it as a 0/1
    matrix with lam = 1/2, so every backend computes the identical joint
    partition it already computes for the single-class screen."""
    from repro.engine.registry import label_components

    t0 = time.perf_counter()
    bump("joint.screens")
    adj = joint_union_adjacency(Ss, lam1, lam2, penalty=penalty)
    labels = label_components(adj.astype(np.float64), 0.5, backend=backend, **backend_opts)
    n_edges = int(np.triu(adj, 1).sum())
    return labels, _stats_from_labels(
        labels, n_edges, lam1, lam2, penalty, len(Ss), time.perf_counter() - t0
    )


# ---------------------------------------------------------------------------
# Union-graph structure classification (the joint routing ladder's planner
# stage)
# ---------------------------------------------------------------------------

#: joint structure classes.  "singleton" shares the single-class assemble
#: route.  IDENTICAL class blocks reduce the joint problem to ONE
#: single-class problem at an effective lambda (see ``repro.joint.engine``),
#: so they fan out by the union subgraph's shape exactly like the
#: single-class ladder: "joint_forest" (pair/tree -> batched forest closed
#: form), "joint_chordal" (chordal -> host clique-tree direct solve),
#: "joint_shared" (general -> ONE single-class iterative solve instead of a
#: K-coupled one).  Everything else takes the joint ADMM through
#: "joint_general".
JOINT_STRUCTURES = (
    "singleton", "joint_forest", "joint_chordal", "joint_shared",
    "joint_general",
)


def joint_component_adjacency(
    Ss, comp: np.ndarray, lam1: float, lam2: float, *, penalty: str
) -> np.ndarray:
    """Union adjacency of one component's hybrid-thresholded subgraph.

    Goes through the gather protocol (``blocks.gather_submatrix``) per
    class, so materialized streamed covariances classify identically to
    dense stacks."""
    from repro.core.blocks import gather_submatrix

    comp = np.asarray(comp)
    stack = np.stack(
        [gather_submatrix(S, comp, dtype=np.float64) for S in Ss]
    )
    adj = pair_excess(stack, lam1, lam2, penalty=penalty) > 0.0
    np.fill_diagonal(adj, False)
    return adj


def classify_joint_component(
    Ss, comp: np.ndarray, lam1: float, lam2: float, *, penalty: str
) -> str:
    """Structure class of one union component for the joint routing ladder.

    The shared classes require IDENTICAL class blocks (to machine
    precision) — then the joint problem on the component reduces to a
    single-class problem at an effective lambda (see ``repro.joint.engine``)
    and the union subgraph's shape picks the single-class machinery:
    pair/tree -> "joint_forest", chordal -> "joint_chordal", general ->
    "joint_shared".  The identity test is a routing heuristic, not a
    correctness gate: every shared-path candidate is per-class KKT-verified
    against its OWN class block, so a near-identical misclassification
    falls back to the joint ADMM instead of corrupting the answer."""
    from repro.core.blocks import gather_submatrix
    from repro.engine.structure import classify_adjacency

    comp = np.asarray(comp)
    if comp.size == 1:
        bump("structure.classified.singleton")
        return "singleton"
    blocks = [gather_submatrix(S, comp, dtype=np.float64) for S in Ss]
    scale = max(1.0, float(np.abs(blocks[0]).max()))
    identical = all(
        np.allclose(blocks[0], blk, rtol=0.0, atol=1e-12 * scale)
        for blk in blocks[1:]
    )
    cls = "joint_general"
    if identical:
        stack = np.stack(blocks)
        adj = pair_excess(stack, lam1, lam2, penalty=penalty) > 0.0
        np.fill_diagonal(adj, False)
        shape = classify_adjacency(adj)
        if shape in ("pair", "tree"):
            cls = "joint_forest"
        elif shape == "chordal":
            cls = "joint_chordal"
        else:
            cls = "joint_shared"
    bump(f"structure.classified.{cls}")
    return cls

"""Joint-KKT verification for the K-class graphical lasso.

The single-class router verifies fast-path candidates against the canonical
``kkt_residual`` (paper eq. (11)-(12)).  The joint stationarity condition
per off-diagonal entry (i, j) couples the classes through the cross-penalty
subgradient:

    W_k,ij - S_k,ij = lam1 z_k + lam2 c_k,   z_k in d|theta_k|,
                                             c  in dP2(theta_ij,:)

so "residual" means: how far is r = (W_k,ij - S_k,ij)_k from the SET of
admissible right-hand sides.  That distance has closed form for both
penalties:

  group   theta != 0: c = theta/||theta|| is a singleton — per-class check
          with the forced c_k (zero coordinates get the usual lam1 slack);
          theta == 0: shrink each r_k by lam1, then the leftover vector must
          fit in the lam2 ball: max(||soft(|r|, lam1)||_2 - lam2, 0).

  fused   cross-class y_kk' are forced to sign(theta_k - theta_k') wherever
          the values differ and free in [-1, 1] on TIES, so after removing
          the forced contributions the feasibility WITHIN each tied group is
          exactly the subset-sum system of the hybrid screen
          (``screen.fused_subset_excess``) — with per-coordinate slack lam1
          on all-zero groups (z free) and slack 0 on active groups (z
          forced to the common sign).

With lam2 = 0 both reduce to the canonical per-class condition, and the
verifier literally delegates to ``kkt_residual_host`` per class — the
joint verifier cannot drift from the single-class optimality definition.

This is the safety net behind the joint routing ladder: closed-form
"joint_forest" candidates are accepted only on sufficiency (see
``repro.joint.engine``), and joint-ADMM outputs whose residual exceeds the
tolerance are re-dispatched (``joint.fallbacks``).
"""

from __future__ import annotations

import numpy as np

from repro.joint.screen import _check_penalty, fused_subset_excess

#: joint-ADMM candidates are exactly sparse off-support (the prox output),
#: so the zero classification can be tight — same rationale as closed_form
_ZERO_TOL = 1e-9
_TIE_TOL = 1e-8


def _fused_entry_violation(
    theta: np.ndarray, r: np.ndarray, lam1: float, lam2: float,
    zero_tol: float, tie_tol: float,
) -> float:
    """Worst fused-stationarity violation for one entry's K-vectors."""
    K = theta.size
    order = np.argsort(theta, kind="stable")
    ts, rs = theta[order], r[order]
    scale = max(1.0, float(np.abs(ts).max()))
    # tie groups: consecutive sorted values within tie_tol * scale
    bounds = [0]
    for k in range(1, K):
        if ts[k] - ts[bounds[-1]] > tie_tol * scale:
            bounds.append(k)
    bounds.append(K)
    groups = [slice(bounds[g], bounds[g + 1]) for g in range(len(bounds) - 1)]
    worst = 0.0
    for g, sl in enumerate(groups):
        m = sl.stop - sl.start
        n_lower = sl.start
        n_higher = K - sl.stop
        d = rs[sl] - lam2 * (n_lower - n_higher)
        if np.all(np.abs(ts[sl]) <= zero_tol):
            slack = lam1
        else:
            d = d - lam1 * np.sign(ts[sl])
            slack = 0.0
        worst = max(worst, float(fused_subset_excess(d, slack, lam2)))
    return worst


def joint_kkt_residual(
    Ss,
    Thetas,
    lam1: float,
    lam2: float,
    *,
    penalty: str = "group",
    zero_tol: float = _ZERO_TOL,
    tie_tol: float = _TIE_TOL,
) -> float:
    """Worst joint-KKT violation of a candidate (K, b, b) Theta stack.

    Host numpy (the verifier runs per block after the solve, like the
    chordal route's host check).  NaN/indefinite candidates return inf so
    callers' ``residual <= tol`` comparisons fail safely."""
    _check_penalty(penalty)
    S = np.stack([np.asarray(s, dtype=np.float64) for s in Ss])
    T = np.stack([np.asarray(t, dtype=np.float64) for t in Thetas])
    K, b, _ = S.shape
    if not np.isfinite(T).all():
        return float("inf")
    if lam2 == 0.0:
        # exact reduction: the canonical per-class residual IS the joint one
        from repro.core.solvers.closed_form import kkt_residual_host

        return max(kkt_residual_host(S[k], lam1, T[k]) for k in range(K))
    W = np.empty_like(T)
    for k in range(K):
        sign, _ = np.linalg.slogdet(T[k])
        if sign <= 0:
            return float("inf")
        W[k] = np.linalg.inv(T[k])
    r = W - S
    # diagonal: per-class W_ii = S_ii + lam1 (lam2 is off-diagonal only)
    diag = np.abs(np.diagonal(r, axis1=1, axis2=2) - lam1)
    worst = float(diag.max())
    iu, ju = np.triu_indices(b, 1)
    if penalty == "group":
        tvec = T[:, iu, ju]                      # (K, E)
        rvec = r[:, iu, ju]
        nrm = np.sqrt(np.sum(tvec * tvec, axis=0))
        active_vec = nrm > zero_tol
        # theta == 0 entirely: leftover after lam1 shrink must fit lam2 ball
        soft = np.maximum(np.abs(rvec) - lam1, 0.0)
        v_zero = np.maximum(
            np.sqrt(np.sum(soft * soft, axis=0)) - lam2, 0.0
        )
        # theta != 0: c_k = theta_k/||theta|| is forced (zero coords incl.)
        safe = np.where(active_vec, nrm, 1.0)
        forced = lam2 * tvec / safe
        act_coord = np.abs(tvec) > zero_tol
        v_act = np.where(
            act_coord,
            np.abs(rvec - lam1 * np.sign(tvec) - forced),
            np.maximum(np.abs(rvec - forced) - lam1, 0.0),
        ).max(axis=0)
        per_pair = np.where(active_vec, v_act, v_zero)
        return max(worst, float(per_pair.max()) if per_pair.size else 0.0)
    for i, j in zip(iu, ju):
        worst = max(
            worst,
            _fused_entry_violation(
                T[:, i, j], r[:, i, j], lam1, lam2, zero_tol, tie_tol
            ),
        )
    return worst


def joint_kkt_residual_sparse(
    Ss,
    Theta,
    lam1: float,
    lam2: float,
    *,
    penalty: str = "group",
    zero_tol: float = _ZERO_TOL,
    tie_tol: float = _TIE_TOL,
) -> float:
    """Worst joint-KKT violation of a block-sparse K-class result.

    ``Theta`` is a ``repro.core.sparse.JointSparseTheta``; per union
    component the per-class S blocks are gathered and the dense per-block
    verifier runs unchanged — never a (K, p, p) buffer.  Cross-component
    entries are certified by the hybrid screen (both the (G) and (F)
    conditions hold at theta = 0 there), mirroring the single-class
    Theorem-1 argument; isolated vertices check their per-class closed form
    W_ii = S_ii + lam1 exactly (lam2 never touches the diagonal)."""
    from repro.core.blocks import gather_diag, gather_submatrix
    from repro.core.instrument import set_peak

    _check_penalty(penalty)
    worst = 0.0
    for c, blk in Theta.blocks():
        Sb = np.stack(
            [gather_submatrix(S, c, dtype=np.float64) for S in Ss]
        )
        # working set: per-class S, Theta, and W = inv(Theta) blocks
        set_peak("result.bytes_peak", int(3 * Sb.nbytes))
        worst = max(
            worst,
            joint_kkt_residual(
                Sb, np.asarray(blk), lam1, lam2, penalty=penalty,
                zero_tol=zero_tol, tie_tol=tie_tol,
            ),
        )
    iso = Theta.isolated
    if iso.size:
        for k, S in enumerate(Ss):
            d = np.asarray(gather_diag(S, iso), dtype=np.float64)
            vals = np.asarray(Theta.isolated_values[k], dtype=np.float64)
            worst = max(
                worst, float(np.abs(1.0 / vals - d - float(lam1)).max())
            )
    return float(worst)


def joint_kkt_ok(
    Ss, Thetas, lam1: float, lam2: float, *, penalty: str, tol: float
) -> bool:
    """Acceptance check with the router's usual max|S| scaling."""
    scale = max(1.0, max(float(np.abs(np.asarray(S)).max()) for S in Ss))
    res = joint_kkt_residual(Ss, Thetas, lam1, lam2, penalty=penalty)
    return bool(res <= tol * scale)

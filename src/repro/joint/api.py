"""Public joint graphical-lasso API: thin wrappers over ``JointEngine``.

``joint_glasso([S_1..S_K], lam1, lam2)``   solve the K-class joint problem
    (J) with the exact hybrid covariance thresholding screen (Tang et al.,
    arXiv:1503.02128) on by default — or ``screen=False`` for the
    unscreened baseline arm the equivalence gates compare against.
``joint_glasso(Xs=[X_1..X_K], ..., from_data=True)``   the out-of-core
    path: one streamed screen per class at lam1, exact hybrid completion of
    the candidate pairs, per-class materialized component blocks — no
    class's dense (p, p) covariance ever exists.

``penalty`` picks the cross-class coupling: "group" (l2 over classes per
entry) or "fused" (pairwise l1 between classes).  ``lam2=0`` decouples the
problem exactly into K independent ``glasso`` solves — the acceptance
equivalence used by tests and ``bench_joint --smoke``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.instrument import bump
from repro.core.sparse import JointSparseTheta, result_nbytes
from repro.joint.screen import JointScreenStats

__all__ = ["JointGlassoResult", "joint_glasso"]


@dataclass
class JointGlassoResult:
    lam1: float
    lam2: float
    penalty: str
    Theta: np.ndarray              # (K, p, p) — or a JointSparseTheta when
                                   # output resolved to "sparse"
    labels: np.ndarray             # union-graph partition (canonical)
    screen: JointScreenStats | None
    solve_seconds: float           # dispatch + verify (assembly EXCLUDED)
    solver: str
    block_sizes: list[int] = field(default_factory=list)
    route_mix: dict = field(default_factory=dict)   # joint structure -> #blocks
    routed: bool = True
    fallbacks: int = 0             # verification failures re-dispatched
    assemble_seconds: float = 0.0  # scatter/index-build slice of this solve
    bytes_peak: int = 0            # resident bytes of Theta as assembled
    output: str = "dense"          # the representation actually returned
    trace: object | None = None    # request Trace (repro.obs) when traced

    def stages(self) -> dict[str, float]:
        """Seconds per canonical stage — the same unified view as
        ``GlassoResult.stages()`` (joint solves have no separate dispatch
        ledger: host issue time rides inside ``solve``)."""
        return {
            "screen": (
                float(self.screen.seconds) if self.screen is not None else 0.0
            ),
            "solve": float(self.solve_seconds),
            "dispatch": 0.0,
            "assemble": float(self.assemble_seconds),
        }

    @property
    def K(self) -> int:
        return self.Theta.shape[0]

    @property
    def support(self) -> np.ndarray:
        """Union concentration-graph adjacency (an edge in ANY class).

        Sparse results derive it from per-block nonzeros (dense bool up to
        the densify cap, scipy bool CSR above) — no (p, p) densify."""
        if isinstance(self.Theta, JointSparseTheta):
            return self.Theta.support()
        A = (np.abs(self.Theta) > 0).any(axis=0)
        np.fill_diagonal(A, False)
        return A

    def class_support(self, k: int) -> np.ndarray:
        if isinstance(self.Theta, JointSparseTheta):
            return self.Theta.class_view(k).support()
        A = np.abs(self.Theta[k]) > 0
        np.fill_diagonal(A, False)
        return A

    def support_edges(self) -> np.ndarray:
        """(E, 2) union support edges (upper-triangular, sorted)."""
        if isinstance(self.Theta, JointSparseTheta):
            return self.Theta.support_edges()
        r, c = np.nonzero(np.triu(self.support, k=1))
        return np.stack([r, c], axis=1).astype(np.int64) if r.size else np.zeros(
            (0, 2), dtype=np.int64
        )


def _joint_result(
    plan, labels, screen_stats, Theta, seconds, solver, *,
    routed: bool = True, fallbacks: int = 0, assemble_seconds: float = 0.0,
) -> JointGlassoResult:
    route_mix = {"singleton": len(plan.isolated)} if len(plan.isolated) else {}
    for b in plan.buckets:
        route_mix[b.structure] = route_mix.get(b.structure, 0) + len(b.comps)
    solve_seconds = max(0.0, float(seconds) - float(assemble_seconds))
    bump("engine.solve_us", int(solve_seconds * 1e6))
    from repro.obs.trace import current_trace

    return JointGlassoResult(
        trace=current_trace(),
        lam1=plan.lam1,
        lam2=plan.lam2,
        penalty=plan.penalty,
        Theta=Theta,
        labels=labels,
        screen=screen_stats,
        solve_seconds=solve_seconds,
        solver=solver,
        block_sizes=sorted(
            (len(c) for b in plan.buckets for c in b.comps), reverse=True
        ),
        route_mix=route_mix,
        routed=routed,
        fallbacks=fallbacks,
        assemble_seconds=float(assemble_seconds),
        bytes_peak=result_nbytes(Theta),
        output="sparse" if isinstance(Theta, JointSparseTheta) else "dense",
    )


def joint_glasso(
    Ss=None,
    lam1: float | None = None,
    lam2: float = 0.0,
    *,
    penalty: str = "group",
    Xs=None,
    from_data: bool = False,
    stream=None,
    screen: bool = True,
    options=None,
    **engine_kwargs,
) -> JointGlassoResult:
    """Solve the K-class joint graphical lasso; see the module docstring.

    Engine configuration travels as ``options=EngineOptions(...)`` — the
    same typed object ``glasso`` and the serving control plane accept
    (``options.route=False`` disables the joint routing ladder,
    ``options.cc_backend`` picks the union-graph partition backend,
    ``options.verify_tail=True`` opts in to exact joint-KKT verification of
    the ADMM tail; see ``JointEngine``).  The historical kwarg spelling
    (``route=``, ``verify_tail=``, ``tol=``, ...) still works through the
    shared deprecation layer and raises a ``DeprecationWarning``.

    ``options.output`` picks the result representation: "dense" is the
    (K, p, p) stack, "sparse" a ``JointSparseTheta`` assembled with zero
    (K, p, p) allocation, "auto" (default) switches to sparse above
    ``AUTO_SPARSE_P``."""
    from repro.engine.options import normalize_options
    from repro.joint.engine import JointEngine

    opts = normalize_options(
        options, engine_kwargs, warn=True, context="joint_glasso"
    )
    engine = JointEngine(options=opts)
    if from_data or Xs is not None:
        if Xs is None:
            raise ValueError("from_data=True needs the data matrices (Xs=...)")
        if Ss is not None:
            raise ValueError("pass either Ss or Xs=, not both")
        if lam1 is None:
            raise ValueError("joint_glasso needs lam1")
        return engine.run_from_data(
            Xs, float(lam1), float(lam2), penalty=penalty, stream=stream
        )
    if Ss is None or lam1 is None:
        raise ValueError("joint_glasso needs (Ss, lam1) — or Xs=/from_data=True")
    return engine.run(
        Ss, float(lam1), float(lam2), penalty=penalty, screen=screen
    )

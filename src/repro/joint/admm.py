"""ADMM for the joint multi-class graphical lasso (problem (J) in
``repro.joint.screen``).

The splitting is the single-class one (``core.solvers.admm``) lifted to a
(K, b, b) stack with the Z-update coupled across classes:

    Theta-update:  per class, the SAME eigh-based update as single-class
                   ADMM — rho*Theta_k - Theta_k^{-1} = rho*(Z_k - U_k) - S_k
                   — batched over K with one vmapped eigh;
    Z-update:      the JOINT prox of lam1*l1 + lam2*P2 applied entrywise to
                   the K-vector at every (i, j) — the fused
                   ``kernels/joint_prox`` pass (Pallas on TPU, jnp ref
                   off-TPU), which also returns both residual partials;
                   diagonal entries take the l1 piece only;
    U-update:      U += Theta - Z (inside the same fused pass).

rho is shared across classes (the coupled prox needs one lam/rho) and
adapted online exactly like the single-class solver (Boyd Section 3.4.1);
the stopping criterion scales the single-class eps by sqrt(K) to keep the
per-entry tolerance comparable.  Warm starts mirror ``glasso_admm``: a
(K, b, b) covariance stack W0 seeds Z0 = W0^{-1} (or Theta0 directly when
the caller holds it — the ``theta_warm`` contract) and U0 = (W0 - S)/rho
per class; a non-finite seed falls back to the cold start inside the jit.

Returns Z — exactly sparse off-support (the prox output), which is what the
union-support property tests and the K-class Theorem-1 check need.
Registered as the capability-tagged ``SolverSpec`` "joint_admm"
(``repro.joint.__init__``): batched=False keeps it out of the single-class
``SOLVERS`` view (its contract is (K, b, b), not (b, b)); the joint
executor vmaps it over bucket stacks itself through the shared compiled
cache with K in the key.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.joint_prox.ops import joint_prox_step


@functools.partial(jax.jit, static_argnames=("penalty", "max_iter"))
def joint_admm_info(
    S: jax.Array,
    lam1: jax.Array,
    lam2: jax.Array,
    *,
    penalty: str = "group",
    rho: float = 1.0,
    max_iter: int = 2000,
    tol: float = 1e-7,
    W0: jax.Array | None = None,
    Theta0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Joint ADMM returning (Theta (K, b, b), iterations)."""
    K, b, _ = S.shape
    dtype = S.dtype
    lam1 = jnp.asarray(lam1, dtype)
    lam2 = jnp.asarray(lam2, dtype)
    rho0 = jnp.asarray(rho, dtype)

    def theta_update(Z, U, rho):
        rhs = rho * (Z - U) - S
        d, Q = jnp.linalg.eigh(rhs)  # batched over the class axis
        theta_d = (d + jnp.sqrt(d * d + 4.0 * rho)) / (2.0 * rho)
        return jnp.einsum("kij,kj,klj->kil", Q, theta_d, Q)

    def body(carry):
        Z, U, rho, _, _, it = carry
        Theta = theta_update(Z, U, rho)
        Z_new, U_new, rp2, rd2 = joint_prox_step(
            Theta, U, Z, lam1 / rho, lam2 / rho, penalty=penalty
        )
        r_prim = jnp.sqrt(rp2)
        r_dual = rho * jnp.sqrt(rd2)
        # adaptive rho; U is the SCALED dual, so it rescales inversely
        factor = jnp.where(
            r_prim > 10.0 * r_dual,
            jnp.asarray(2.0, dtype),
            jnp.where(
                r_dual > 10.0 * r_prim,
                jnp.asarray(0.5, dtype),
                jnp.asarray(1.0, dtype),
            ),
        )
        return Z_new, U_new / factor, rho * factor, r_prim, r_dual, it + 1

    def cond(carry):
        _, _, _, r_prim, r_dual, it = carry
        eps = tol * b * jnp.sqrt(jnp.asarray(float(K), dtype))
        return jnp.logical_and(
            jnp.logical_or(r_prim > eps, r_dual > eps), it < max_iter
        )

    eye = jnp.eye(b, dtype=bool)
    diag = jnp.diagonal(S, axis1=1, axis2=2)  # (K, b)
    cold_Z = jnp.where(
        eye[None], (1.0 / (diag + lam1))[:, :, None], jnp.zeros_like(S)
    )
    if W0 is None:
        Z0, U0 = cold_Z, jnp.zeros_like(S)
    else:
        Z0c = Theta0 if Theta0 is not None else jnp.linalg.inv(W0)
        Z0c = 0.5 * (Z0c + jnp.swapaxes(Z0c, -1, -2))
        usable = jnp.all(jnp.isfinite(Z0c)) & jnp.all(jnp.isfinite(W0))
        Z0 = jnp.where(usable, Z0c, cold_Z)
        U0 = jnp.where(usable, (W0 - S) / rho0, jnp.zeros_like(S))
    init = (
        Z0,
        U0,
        rho0,
        jnp.asarray(jnp.inf, dtype),
        jnp.asarray(jnp.inf, dtype),
        jnp.int32(0),
    )
    Z, U, _, _, _, it = jax.lax.while_loop(cond, body, init)
    return 0.5 * (Z + jnp.swapaxes(Z, -1, -2)), it


def joint_admm(
    S: jax.Array,
    lam1: jax.Array,
    lam2: jax.Array,
    *,
    penalty: str = "group",
    rho: float = 1.0,
    max_iter: int = 2000,
    tol: float = 1e-7,
    W0: jax.Array | None = None,
    Theta0: jax.Array | None = None,
) -> jax.Array:
    """Joint-block solver contract ``solve(S (K,b,b), lam1, lam2) -> Theta``."""
    Theta, _ = joint_admm_info(
        S, lam1, lam2, penalty=penalty, rho=rho, max_iter=max_iter, tol=tol,
        W0=W0, Theta0=Theta0,
    )
    return Theta

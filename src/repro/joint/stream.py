"""Out-of-core hybrid screening: joint partitions straight from the Xs.

The dense hybrid rule (``repro.joint.screen``) needs, per pair (i, j), the
whole K-vector of covariances — but it only needs it for pairs that can
possibly be edges, and BOTH penalties share a per-class necessary
condition: if |S_k,ij| <= lam1 for every class, the pair is screened out
(group: every soft-threshold is zero; fused: |sum_A s| <= sum_A |s_k| <=
|A| lam1 bounds every subset).  So the streamed screen is

  1. PER-CLASS STREAM   each class runs the single-class out-of-core
     machinery at lam1 — chunked moments, per-class Cauchy-Schwarz tile
     skip, the fused covgram_screen kernel over its own kept-tile schedule
     (``kernels.covgram_screen.covgram_screen_tiles_stacked``, the
     K-stacked variant) — emitting SIGNED (i, j, S_k,ij) candidates;
  2. COMPLETE           the candidate set is the union over classes; for a
     candidate a class did NOT emit, its exact value is recomputed from
     that class's centered columns (one O(n_k) dot per missing value —
     candidates are few, that is the point of screening);
  3. DECIDE             the exact hybrid rule (``screen.pair_excess``)
     evaluates every candidate's K-vector — identical arithmetic to the
     dense path, so ties |S_k,ij| == lam1 resolve identically;
  4. PARTITION          surviving union edges feed the incremental
     ``stream.unionfind`` (unsorted, unweighted — the joint screen is
     single-threshold, so the sorted Theorem-2 sweep has nothing to
     amortize);
  5. MATERIALIZE        per class, the per-component covariance blocks of
     the union partition (``stream.materialize``) — the gather protocol
     then feeds the joint planner/classifier/executor unchanged.

No class's dense (p, p) covariance ever exists; peak memory is the
in-flight tile batch + the candidate store + K * (component blocks).
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.instrument import bump
from repro.joint.screen import (
    JointScreenStats,
    _check_penalty,
    pair_excess,
)
from repro.kernels.covgram_screen import (
    covgram_screen_tiles_stacked,
    pad_for_screen,
)
from repro.stream.config import StreamConfig, as_config
from repro.stream.materialize import MaterializedCovariance, materialize_components
from repro.stream.tiler import column_moments, tile_maxima, tile_pair_schedule
from repro.stream.unionfind import StreamingUnionFind


@dataclass
class JointStreamScreen:
    """Everything the joint engine needs, and nothing dense."""

    p: int
    K: int
    lam1: float
    lam2: float
    penalty: str
    labels: np.ndarray
    stats: JointScreenStats
    candidates: tuple                 # (i, j, vals (K, E)) — the hybrid inputs
    S: list[MaterializedCovariance] | None
    moments: list
    config: StreamConfig
    seconds: float


def _complete_candidates(
    X: np.ndarray, mu: np.ndarray, keys: np.ndarray, have_keys: np.ndarray,
    have_vals: np.ndarray, p: int,
) -> np.ndarray:
    """Exact per-class values on the candidate set: emitted values are
    scattered in, missing ones recomputed from the centered columns with
    the estimator's own arithmetic (bit-identical on exactly-representable
    data)."""
    vals = np.zeros(keys.size, dtype=np.float64)
    filled = np.zeros(keys.size, dtype=bool)
    if have_keys.size:
        pos = np.searchsorted(keys, have_keys)
        vals[pos] = have_vals
        filled[pos] = True
    missing = np.flatnonzero(~filled)
    if missing.size:
        mi = (keys[missing] // p).astype(np.int64)
        mj = (keys[missing] % p).astype(np.int64)
        cols, inv = np.unique(np.concatenate([mi, mj]), return_inverse=True)
        Xc = X[:, cols].astype(np.float64) - mu[cols]
        pi = inv[: mi.size]
        pj = inv[mi.size :]
        vals[missing] = np.einsum(
            "ne,ne->e", Xc[:, pi], Xc[:, pj]
        ) / X.shape[0]
    return vals


def joint_stream_screen(
    Xs,
    lam1: float,
    lam2: float,
    *,
    penalty: str = "group",
    config=None,
    materialize: bool = True,
) -> JointStreamScreen:
    """Screen (X_1..X_K, lam1, lam2) out-of-core; see the module docstring."""
    _check_penalty(penalty)
    cfg = as_config(config)
    t0 = time.perf_counter()
    Xs = [np.asarray(X) for X in Xs]
    p = Xs[0].shape[1]
    if any(X.shape[1] != p for X in Xs):
        raise ValueError("all classes must share the variable dimension p")
    K = len(Xs)
    lam1 = float(lam1)
    lam2 = float(lam2)
    bump("joint.screens")

    moments = [column_moments(X, chunk=cfg.chunk) for X in Xs]
    xs_pad, mus_pad, schedules_i, schedules_j = [], [], [], []
    tiles_total = tiles_skipped = 0
    for X, mom in zip(Xs, moments):
        norms_max = tile_maxima(mom.norms, cfg.tile)
        ti, tj, keep = tile_pair_schedule(norms_max, lam1, slack=cfg.skip_slack)
        tiles_total += int(ti.size)
        tiles_skipped += int((~keep).sum())
        x_pad, mu_pad = pad_for_screen(
            X, mom.mu, block_n=cfg.chunk, block_p=cfg.tile
        )
        xs_pad.append(x_pad)
        mus_pad.append(mu_pad)
        schedules_i.append(ti[keep].astype(np.int32))
        schedules_j.append(tj[keep].astype(np.int32))
    bump("stream.tiles_total", tiles_total)
    bump("stream.tiles_skipped", tiles_skipped)

    itemsize = (
        4
        if cfg.backend == "pallas"
        else max(x.dtype.itemsize for x in xs_pad)
    )
    per_class = covgram_screen_tiles_stacked(
        xs_pad,
        mus_pad,
        schedules_i,
        schedules_j,
        lam1,
        n_trues=[X.shape[0] for X in Xs],
        p_true=p,
        block_p=cfg.tile,
        block_n=cfg.chunk,
        backend=cfg.backend,
        pair_batch=cfg.resolved_pair_batch(itemsize),
    )
    bump("stream.edges_emitted", sum(v.size for _, _, v in per_class))

    # candidate union + exact completion per class
    key_parts = [gi * p + gj for gi, gj, _ in per_class]
    keys = (
        np.unique(np.concatenate(key_parts))
        if key_parts
        else np.empty(0, np.int64)
    )
    bump("joint.candidate_pairs", int(keys.size))
    vals = np.zeros((K, keys.size), dtype=np.float64)
    for k, ((gi, gj, v), mom) in enumerate(zip(per_class, moments)):
        vals[k] = _complete_candidates(
            Xs[k], mom.mu, keys, gi * p + gj, v, p
        )

    ci = (keys // p).astype(np.int64)
    cj = (keys % p).astype(np.int64)
    edge = pair_excess(vals, lam1, lam2, penalty=penalty) > 0.0
    n_edges = int(edge.sum())
    bump("joint.edges", n_edges)

    uf = StreamingUnionFind(p)
    uf.union_edges(ci[edge], cj[edge])
    labels = uf.labels()

    _, counts = np.unique(labels, return_counts=True)
    stats = JointScreenStats(
        lam1=lam1,
        lam2=lam2,
        penalty=penalty,
        K=K,
        n_components=int(counts.size),
        max_comp=int(counts.max()),
        n_isolated=int((counts == 1).sum()),
        n_edges=n_edges,
        seconds=time.perf_counter() - t0,
        candidate_pairs=int(keys.size),
        tiles_total=tiles_total,
        tiles_skipped=tiles_skipped,
    )

    S = None
    if materialize:
        S = [
            materialize_components(X, mom.mu, mom.diag, labels)
            for X, mom in zip(Xs, moments)
        ]
    stats.seconds = time.perf_counter() - t0
    return JointStreamScreen(
        p=p,
        K=K,
        lam1=lam1,
        lam2=lam2,
        penalty=penalty,
        labels=labels,
        stats=stats,
        candidates=(ci, cj, vals),
        S=S,
        moments=moments,
        config=cfg,
        seconds=stats.seconds,
    )

"""Labeled metrics registry: counters, gauges, log-bucketed histograms.

Two surfaces share one store:

* **Flat dotted counters** — the historical ``core/instrument.py``
  namespace (``serve.requests``, ``engine.dispatch.us``, watermarks).
  ``instrument`` is now a thin shim over this registry, so every
  pre-existing counter name, ``tail_counts`` view, and benchmark gate
  keeps working bitwise.  Values may accumulate as floats internally
  (the dispatch-µs fix); the read surface rounds to int.
* **Labeled families** — ``inc``/``set_gauge``/``observe`` keyed by
  ``(name, sorted label items)``.  Label taxonomy (DESIGN.md §17):
  ``tenant``, ``slo``, ``route``, ``kind``.  Histograms use fixed
  log-spaced latency buckets so the server itself reports p50/p99 per
  tenant/SLO class without client cooperation.

``reset(prefix)`` clears BOTH stores by dotted-name prefix — the serving
benchmark's ``reset("serve")`` between warmup and the measured loop
therefore also zeroes the ``serve.request_seconds`` histogram.

``render_prometheus()`` emits text exposition format (the ``/metrics``
surface); dotted names are sanitized to underscores per the Prometheus
data model.
"""

from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Any, Iterable

__all__ = [
    "MetricsRegistry",
    "REGISTRY",
    "LATENCY_BUCKETS_S",
    "render_prometheus",
]

#: 40 log-spaced bucket upper bounds, 100 µs .. ~1100 s (ratio 1.5), plus
#: +Inf implicitly.  Quantile estimates are therefore exact to a factor
#: of 1.5 anywhere in the serving latency range.
LATENCY_BUCKETS_S: tuple[float, ...] = tuple(1e-4 * 1.5**k for k in range(40))

_LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: dict[str, Any]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Histogram:
    __slots__ = ("buckets", "counts", "total", "sum")

    def __init__(self, buckets: tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)  # last slot = +Inf
        self.total = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        i = bisect_left(self.buckets, value)
        self.counts[i] += 1
        self.total += 1
        self.sum += value


class MetricsRegistry:
    """Thread-safe; one process-global instance (``REGISTRY``) below."""

    def __init__(self):
        self._lock = threading.Lock()
        self._flat: dict[str, float] = {}
        # name -> {"type": ..., "series": {label_key: value|_Histogram},
        #          "buckets": ...}
        self._families: dict[str, dict[str, Any]] = {}

    # -- flat dotted counters (instrument.py backing store) ---------------

    def bump_flat(self, name: str, n: float = 1) -> None:
        with self._lock:
            self._flat[name] = self._flat.get(name, 0) + n

    def set_peak_flat(self, name: str, value: float) -> None:
        with self._lock:
            if value > self._flat.get(name, 0):
                self._flat[name] = value

    def flat_value(self, name: str) -> float:
        with self._lock:
            return self._flat.get(name, 0)

    def flat_items(self, prefix: str = "") -> dict[str, float]:
        with self._lock:
            return {k: v for k, v in self._flat.items() if k.startswith(prefix)}

    # -- labeled families -------------------------------------------------

    def _family(self, name: str, kind: str, buckets=None) -> dict[str, Any]:
        fam = self._families.get(name)
        if fam is None:
            fam = {"type": kind, "series": {}, "buckets": buckets}
            self._families[name] = fam
        elif fam["type"] != kind:
            raise TypeError(
                f"metric {name!r} already registered as {fam['type']}, "
                f"not {kind}"
            )
        return fam

    def inc(self, name: str, n: float = 1, **labels: Any) -> None:
        key = _label_key(labels)
        with self._lock:
            fam = self._family(name, "counter")
            fam["series"][key] = fam["series"].get(key, 0) + n

    def set_gauge(self, name: str, value: float, **labels: Any) -> None:
        key = _label_key(labels)
        with self._lock:
            fam = self._family(name, "gauge")
            fam["series"][key] = value

    def observe(
        self,
        name: str,
        value: float,
        *,
        buckets: tuple[float, ...] = LATENCY_BUCKETS_S,
        **labels: Any,
    ) -> None:
        key = _label_key(labels)
        with self._lock:
            fam = self._family(name, "histogram", buckets)
            hist = fam["series"].get(key)
            if hist is None:
                hist = fam["series"][key] = _Histogram(fam["buckets"])
            hist.observe(value)

    def value(self, name: str, **labels: Any) -> float:
        """Current value of one counter/gauge series (0 if absent)."""
        key = _label_key(labels)
        with self._lock:
            fam = self._families.get(name)
            if fam is None or fam["type"] == "histogram":
                return 0.0
            return float(fam["series"].get(key, 0))

    def histogram_totals(self, name: str, **labels: Any) -> dict[str, float]:
        """Merged count/sum over every series whose labels are a superset
        of ``labels`` (sum-less-precise view of ``quantile``)."""
        want = set(_label_key(labels))
        total, s = 0, 0.0
        with self._lock:
            fam = self._families.get(name)
            if fam is not None and fam["type"] == "histogram":
                for key, hist in fam["series"].items():
                    if want.issubset(set(key)):
                        total += hist.total
                        s += hist.sum
        return {"count": total, "sum": s}

    def quantile(self, name: str, q: float, **labels: Any) -> float:
        """Estimated q-quantile of a histogram, merging every series whose
        labels are a superset of ``labels`` — e.g.
        ``quantile("serve.request_seconds", 0.99, tenant="web",
        slo="interactive")`` merges over ``kind``.  Returns the upper
        bound of the bucket holding the target rank (NaN when empty), so
        estimates are conservative to one bucket ratio (1.5x)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        want = set(_label_key(labels))
        with self._lock:
            fam = self._families.get(name)
            if fam is None or fam["type"] != "histogram":
                return float("nan")
            buckets = fam["buckets"]
            merged = [0] * (len(buckets) + 1)
            total = 0
            for key, hist in fam["series"].items():
                if want.issubset(set(key)):
                    for i, c in enumerate(hist.counts):
                        merged[i] += c
                    total += hist.total
        if total == 0:
            return float("nan")
        rank = q * total
        cum = 0
        for i, c in enumerate(merged):
            cum += c
            if cum >= rank and c:
                return buckets[i] if i < len(buckets) else float("inf")
        return float("inf")

    # -- lifecycle --------------------------------------------------------

    def reset(self, prefix: str = "") -> None:
        with self._lock:
            for k in [k for k in self._flat if k.startswith(prefix)]:
                del self._flat[k]
            for k in [k for k in self._families if k.startswith(prefix)]:
                del self._families[k]

    # -- exposition -------------------------------------------------------

    def render_prometheus(self) -> str:
        """Prometheus text exposition: labeled families first (counters,
        gauges, histograms with ``_bucket``/``_sum``/``_count``), then the
        flat dotted counters as unlabeled counters."""
        with self._lock:
            lines: list[str] = []
            for name in sorted(self._families):
                fam = self._families[name]
                pname = _sanitize(name)
                lines.append(f"# TYPE {pname} {fam['type']}")
                if fam["type"] == "histogram":
                    for key, hist in sorted(fam["series"].items()):
                        cum = 0
                        bounds = [*fam["buckets"], float("inf")]
                        for bound, c in zip(bounds, hist.counts):
                            cum += c
                            le = "+Inf" if bound == float("inf") else _fmt(bound)
                            lines.append(
                                f"{pname}_bucket{{{_labels(key, le=le)}}} {cum}"
                            )
                        lines.append(
                            f"{pname}_sum{{{_labels(key)}}} {_fmt(hist.sum)}"
                        )
                        lines.append(
                            f"{pname}_count{{{_labels(key)}}} {hist.total}"
                        )
                else:
                    for key, v in sorted(fam["series"].items()):
                        label_part = f"{{{_labels(key)}}}" if key else ""
                        lines.append(f"{pname}{label_part} {_fmt(v)}")
            for name in sorted(self._flat):
                pname = _sanitize(name)
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {_fmt(self._flat[name])}")
        return "\n".join(lines) + "\n"


def _sanitize(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return out if not out[:1].isdigit() else "_" + out


def _labels(key: Iterable[tuple[str, str]], **extra: str) -> str:
    pairs = [*key, *sorted(extra.items())]
    return ",".join(f'{k}="{v}"' for k, v in pairs)


def _fmt(v: float) -> str:
    if isinstance(v, float) and v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


#: The process-global registry every layer records into.
REGISTRY = MetricsRegistry()


def render_prometheus() -> str:
    """Module-level convenience: exposition of the global registry."""
    return REGISTRY.render_prometheus()

"""Request-scoped span tracer with explicit cross-thread propagation.

One ``submit(spec)`` fans out across the admission thread, the serving
batcher, the executor's dispatch waves, and (for joint/select work) whole
sub-engines.  The tracer answers "where did THIS request's 80 ms go"
without a benchmark rerun: every stage opens a ``span(...)`` context
manager, the spans nest into a per-request :class:`Trace`, and the trace
travels on the result (``GlassoResult.trace``) and on serve futures.

Propagation rules (DESIGN.md Section 17):

* The ambient context is a ``contextvars.ContextVar`` holding
  ``(trace, active_span_id)``.  ``span()`` is a NO-OP when nothing is
  active — untraced code paths pay one ContextVar read.
* Crossing a thread pool is EXPLICIT: the enqueuing side captures
  ``context_token()`` and the worker wraps its portion in
  ``activate(token)``.  contextvars do not flow into pre-started worker
  threads on their own, and implicit inheritance would mis-attribute
  batcher work to whichever request started the thread.
* ``trace_request()`` starts a new trace ONLY when none is active;
  otherwise it degrades to a plain child span, so a serving-owned
  request trace absorbs the engine's own ``engine.run`` tree instead of
  forking a second root.

All timestamps come from ``time.perf_counter()`` — monotonic, so span
durations never go negative across wall-clock adjustments (the ruff
TID251 gate bans the wall clock in ``src/`` for exactly this reason).
"""

from __future__ import annotations

import contextvars
import json
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Iterator

__all__ = [
    "Span",
    "Trace",
    "trace_request",
    "span",
    "current_trace",
    "context_token",
    "activate",
]

_CURRENT: contextvars.ContextVar[tuple["Trace", int] | None] = (
    contextvars.ContextVar("repro_obs_current", default=None)
)


@dataclass
class Span:
    """One timed stage.  ``t0``/``t1`` are perf_counter instants; ``t1``
    is None while the span is open."""

    name: str
    span_id: int
    parent_id: int | None
    t0: float
    t1: float | None = None
    attrs: dict[str, Any] = field(default_factory=dict)
    thread: str = ""

    @property
    def seconds(self) -> float:
        end = self.t1 if self.t1 is not None else time.perf_counter()
        return max(0.0, end - self.t0)


class Trace:
    """A tree of spans for one request.  Thread-safe: worker threads
    append concurrently under ``activate(token)``."""

    def __init__(self, name: str, **attrs: Any):
        self._lock = threading.Lock()
        self.spans: list[Span] = []
        self._next_id = 0
        self.root_id = self.begin(name, parent_id=None, **attrs)

    # -- recording --------------------------------------------------------

    def begin(self, name: str, *, parent_id: int | None, **attrs: Any) -> int:
        t0 = time.perf_counter()
        with self._lock:
            span_id = self._next_id
            self._next_id += 1
            self.spans.append(
                Span(
                    name=name,
                    span_id=span_id,
                    parent_id=parent_id,
                    t0=t0,
                    attrs=dict(attrs),
                    thread=threading.current_thread().name,
                )
            )
        return span_id

    def end(self, span_id: int) -> None:
        t1 = time.perf_counter()
        with self._lock:
            sp = self.spans[span_id]
            if sp.t1 is None:
                sp.t1 = t1

    def finish(self) -> "Trace":
        """Close the root span (idempotent).  Open descendants are closed
        at the same instant so exports never contain dangling spans."""
        t1 = time.perf_counter()
        with self._lock:
            for sp in self.spans:
                if sp.t1 is None:
                    sp.t1 = t1
        return self

    # -- views ------------------------------------------------------------

    @property
    def root(self) -> Span:
        return self.spans[self.root_id]

    @property
    def name(self) -> str:
        return self.root.name

    @property
    def wall_seconds(self) -> float:
        return self.root.seconds

    def children(self, span_id: int) -> list[Span]:
        with self._lock:
            return [s for s in self.spans if s.parent_id == span_id]

    def stage_seconds(self) -> dict[str, float]:
        """Wall seconds of the root's DIRECT children, summed per span
        name — the "where did the time go" one-liner.  Nested detail
        (per-wave dispatch, per-bucket solves) stays in ``spans``."""
        out: dict[str, float] = {}
        for sp in self.children(self.root_id):
            out[sp.name] = out.get(sp.name, 0.0) + sp.seconds
        return out

    def to_dict(self) -> dict[str, Any]:
        """Compact serializable view (serve_stats / debugging)."""
        with self._lock:
            spans = [
                {
                    "name": s.name,
                    "id": s.span_id,
                    "parent": s.parent_id,
                    "t0_us": round((s.t0 - self.spans[self.root_id].t0) * 1e6, 3),
                    "dur_us": round(s.seconds * 1e6, 3),
                    "thread": s.thread,
                    **({"attrs": s.attrs} if s.attrs else {}),
                }
                for s in self.spans
            ]
        return {
            "name": self.name,
            "wall_seconds": self.wall_seconds,
            "stages": self.stage_seconds(),
            "spans": spans,
        }

    def to_chrome_json(self, path: str | None = None) -> str:
        """Chrome trace-event JSON (open in Perfetto / chrome://tracing).

        Complete ("ph": "X") events with microsecond timestamps relative
        to the root span; one tid per recording thread, named via
        thread_name metadata events."""
        with self._lock:
            spans = list(self.spans)
        t_base = spans[self.root_id].t0
        tids: dict[str, int] = {}
        events: list[dict[str, Any]] = []
        for s in spans:
            tid = tids.setdefault(s.thread, len(tids))
            end = s.t1 if s.t1 is not None else time.perf_counter()
            events.append(
                {
                    "name": s.name,
                    "ph": "X",
                    "ts": (s.t0 - t_base) * 1e6,
                    "dur": max(0.0, end - s.t0) * 1e6,
                    "pid": 1,
                    "tid": tid,
                    "args": dict(s.attrs),
                }
            )
        for thread_name, tid in tids.items():
            events.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 1,
                    "tid": tid,
                    "args": {"name": thread_name},
                }
            )
        text = json.dumps({"traceEvents": events, "displayTimeUnit": "ms"})
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text


# -- ambient context ------------------------------------------------------


def current_trace() -> Trace | None:
    cur = _CURRENT.get()
    return cur[0] if cur is not None else None


def context_token() -> tuple[Trace, int] | None:
    """Snapshot the active (trace, span) for handoff into a worker
    thread; the worker re-attaches with ``activate(token)``."""
    return _CURRENT.get()


@contextmanager
def activate(token: tuple[Trace, int] | None) -> Iterator[Trace | None]:
    """Re-attach a captured context on the current thread.  ``None`` is
    accepted (and deactivates tracing) so call sites can hand off
    unconditionally."""
    reset = _CURRENT.set(token)
    try:
        yield token[0] if token is not None else None
    finally:
        _CURRENT.reset(reset)


@contextmanager
def span(name: str, **attrs: Any) -> Iterator[Span | None]:
    """Open a child span under the ambient context; no-op without one."""
    cur = _CURRENT.get()
    if cur is None:
        yield None
        return
    trace, parent_id = cur
    span_id = trace.begin(name, parent_id=parent_id, **attrs)
    reset = _CURRENT.set((trace, span_id))
    try:
        yield trace.spans[span_id]
    finally:
        _CURRENT.reset(reset)
        trace.end(span_id)


@contextmanager
def trace_request(name: str, **attrs: Any) -> Iterator[Trace]:
    """Root a new trace — or, when one is already active, record this
    request as a child span of it (the serving path owns the root)."""
    cur = _CURRENT.get()
    if cur is not None:
        with span(name, **attrs):
            yield cur[0]
        return
    trace = Trace(name, **attrs)
    reset = _CURRENT.set((trace, trace.root_id))
    try:
        yield trace
    finally:
        _CURRENT.reset(reset)
        trace.finish()

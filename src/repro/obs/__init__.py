"""Observability layer: request-scoped tracing + labeled metrics.

``repro.obs.trace`` — span tracer with explicit cross-thread context
propagation; produces per-request :class:`Trace` trees exportable to
Chrome trace-event JSON (Perfetto) and compact dicts.

``repro.obs.metrics`` — process-global :class:`MetricsRegistry` with
counter/gauge/histogram families (labels: tenant/slo/route/kind),
log-spaced latency buckets, and Prometheus text exposition.  The legacy
``core/instrument.py`` counter namespace is a shim over this registry.
"""

from repro.obs.metrics import (  # noqa: F401
    LATENCY_BUCKETS_S,
    REGISTRY,
    MetricsRegistry,
    render_prometheus,
)
from repro.obs.trace import (  # noqa: F401
    Span,
    Trace,
    activate,
    context_token,
    current_trace,
    span,
    trace_request,
)

__all__ = [
    "Span",
    "Trace",
    "trace_request",
    "span",
    "current_trace",
    "context_token",
    "activate",
    "MetricsRegistry",
    "REGISTRY",
    "LATENCY_BUCKETS_S",
    "render_prometheus",
]

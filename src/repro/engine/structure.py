"""Structure classification of thresholded component subgraphs.

The paper's screening rule hands the executor a bag of independent blocks,
but PR 1 sent every block — singletons, pairs, trees — to a full iterative
solver.  Fattahi & Sojoudi (arXiv:1708.09479) give an exact closed-form
glasso solution when the thresholded support is acyclic, and Fattahi, Zhang
& Sojoudi (arXiv:1711.09131) extend fast recovery to chordal supports via
the maximum-determinant completion; in the large-lambda regime the paper
targets, most components ARE these shapes.  This module is the planner-side
stage that detects them:

    classify_component(S, comp, lam) -> one of STRUCTURES

    "singleton"  |comp| == 1                      -> diagonal formula
    "pair"       |comp| == 2                      -> analytic 2x2
    "tree"       acyclic (|E| == |V| - 1)         -> Fattahi-Sojoudi closed
                                                     form (O(|E|))
    "chordal"    perfect elimination ordering     -> clique-tree direct solve
                 exists (maximum cardinality         (zero-fill sparse
                 search check)                       Cholesky equivalent)
    "general"    everything else                  -> iterative solver ladder
                                                     tail (bcd/pg/admm)

Classification is exact, not heuristic: MCS + the Tarjan-Yannakakis PEO
check decide chordality in O(b^2) for a b-vertex block, negligible next to
even one iterative sweep.  The same adjacency (strict |S_ij| > lam, paper
eq. (4)) feeds both the classifier and the closed-form solvers, so the
routed solver sees exactly the structure it was promised.

Counters (repro.core.instrument):
    structure.classified.<class>   components classified per class
"""

from __future__ import annotations

import numpy as np

from repro.core.instrument import bump

#: the routing ladder's structure classes, fastest solver first.  "oversize"
#: is assigned by the PLANNER (size threshold from the device memory budget,
#: checked before any graph classification — running MCS on a giant
#: component would cost more than it could ever save), never by
#: ``classify_component``; it routes to the mesh-spanning sharded solver.
STRUCTURES = ("singleton", "pair", "tree", "chordal", "general", "oversize")


def component_adjacency(S: np.ndarray, comp: np.ndarray, lam: float) -> np.ndarray:
    """Boolean adjacency of one component's thresholded subgraph.

    Strict inequality (eq. (4)): ties |S_ij| == lam are NOT edges — the same
    convention every screening backend and closed-form solver uses.  Goes
    through the gather protocol (``blocks.gather_submatrix``) so materialized
    streamed covariances classify identically to dense ones."""
    from repro.core.blocks import gather_submatrix

    blk = np.abs(gather_submatrix(S, np.asarray(comp))) > lam
    np.fill_diagonal(blk, False)
    return blk


def mcs_elimination_order(adj: np.ndarray) -> np.ndarray:
    """Maximum cardinality search elimination order.

    Returns ``order`` with ``order[k]`` = vertex eliminated k-th.  Vertices
    are numbered from the back by repeatedly taking an unnumbered vertex
    with the most numbered neighbors (ties -> smallest index, so the order
    is deterministic).  For a chordal graph the result is a perfect
    elimination ordering (Tarjan & Yannakakis 1984)."""
    b = adj.shape[0]
    weight = np.zeros(b, dtype=np.int64)
    numbered = np.zeros(b, dtype=bool)
    order = np.empty(b, dtype=np.int64)
    for k in range(b - 1, -1, -1):
        cand = np.flatnonzero(~numbered)
        v = int(cand[np.argmax(weight[cand])])
        order[k] = v
        numbered[v] = True
        weight[adj[v] & ~numbered] += 1
    return order


def is_perfect_elimination_order(adj: np.ndarray, order: np.ndarray) -> bool:
    """Tarjan-Yannakakis check: for each vertex, its later neighbors must
    all be adjacent to the earliest of them."""
    b = adj.shape[0]
    pos = np.empty(b, dtype=np.int64)
    pos[order] = np.arange(b)
    for i in range(b):
        v = int(order[i])
        later = np.flatnonzero(adj[v] & (pos > i))
        if later.size <= 1:
            continue
        u = int(later[np.argmin(pos[later])])
        rest = later[later != u]
        if not adj[u, rest].all():
            return False
    return True


def peo_or_none(adj: np.ndarray) -> np.ndarray | None:
    """A perfect elimination ordering of ``adj``, or None if not chordal."""
    order = mcs_elimination_order(adj)
    return order if is_perfect_elimination_order(adj, order) else None


def clique_tree(
    adj: np.ndarray, order: np.ndarray
) -> tuple[list[np.ndarray], list[np.ndarray]]:
    """Maximal cliques and clique-tree separators of a chordal graph.

    Given a PEO, candidate cliques are ``{v} + later-neighbors(v)``;
    non-maximal candidates are dropped, then a maximum-weight spanning tree
    of the clique intersection graph (weight = intersection size) realizes
    the running-intersection property, and its edge intersections are the
    separators — WITH multiplicity, which is what the max-det completion
    inverse formula needs (Vandenberghe & Andersen 2015, eq. Theta =
    sum_C [A_C^{-1}] - sum_S [A_S^{-1}]).

    Separators of a connected component are always non-empty; the graph must
    be connected and chordal (caller's responsibility — the planner only
    calls this on components whose PEO check passed)."""
    b = adj.shape[0]
    pos = np.empty(b, dtype=np.int64)
    pos[order] = np.arange(b)
    cand: list[frozenset[int]] = []
    for i in range(b):
        v = int(order[i])
        later = np.flatnonzero(adj[v] & (pos > i))
        cand.append(frozenset([v]) | frozenset(int(u) for u in later))
    # drop duplicates and non-maximal candidates (k <= b sets, each <= b)
    uniq = sorted(set(cand), key=lambda c: (-len(c), sorted(c)))
    cliques_sets: list[frozenset[int]] = []
    for c in uniq:
        if not any(c < kept for kept in cliques_sets):
            cliques_sets.append(c)
    k = len(cliques_sets)
    cliques = [np.array(sorted(c), dtype=np.int64) for c in cliques_sets]
    if k == 1:
        return cliques, []
    # Prim's maximum-weight spanning tree on pairwise intersection sizes
    in_tree = np.zeros(k, dtype=bool)
    in_tree[0] = True
    best_w = np.array([len(cliques_sets[0] & c) for c in cliques_sets])
    best_from = np.zeros(k, dtype=np.int64)
    separators: list[np.ndarray] = []
    for _ in range(k - 1):
        cand_idx = np.flatnonzero(~in_tree)
        j = int(cand_idx[np.argmax(best_w[cand_idx])])
        sep = cliques_sets[j] & cliques_sets[int(best_from[j])]
        separators.append(np.array(sorted(sep), dtype=np.int64))
        in_tree[j] = True
        for m in cand_idx:
            w = len(cliques_sets[int(m)] & cliques_sets[j])
            if w > best_w[m]:
                best_w[m] = w
                best_from[m] = j
    return cliques, separators


def classify_adjacency(adj: np.ndarray) -> str:
    """Classify one CONNECTED component's adjacency into a structure class."""
    b = adj.shape[0]
    if b == 1:
        return "singleton"
    if b == 2:
        return "pair"
    n_edges = int(adj.sum()) // 2
    if n_edges == b - 1:
        return "tree"  # connected + |E| == |V|-1  <=>  acyclic
    if peo_or_none(adj) is not None:
        return "chordal"
    return "general"


def classify_component(S: np.ndarray, comp: np.ndarray, lam: float) -> str:
    """Structure class of one component of the thresholded graph of (S, lam)."""
    comp = np.asarray(comp)
    if comp.size == 1:
        cls = "singleton"
    else:
        cls = classify_adjacency(component_adjacency(S, comp, lam))
    bump(f"structure.classified.{cls}")
    return cls

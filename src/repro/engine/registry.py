"""Backend registries for the screening stage and the solver-routing ladder.

One contract for every implementation of the paper's eq.-(4) partition step:

    backend(S, lam, **opts) -> int labels, shape (p,), CANONICAL
    (labels[i] == smallest vertex index in i's component)

so downstream stages (planner, executor, serving) never care which device or
algorithm produced the partition.  Four backends ship:

    "host"       numpy union-find (orchestration path; the paper's
                 ``graphconncomp`` role)
    "jax"        jitted min-label propagation + pointer jumping (single device)
    "pallas"     the fused threshold+hook Pallas TPU kernel driven to a fixed
                 point (interpret mode off-TPU)
    "shard_map"  row-sharded label propagation across the local device mesh
                 (core/distributed.py), for p too large for one device's HBM

All four provably compute the same partition (strict |S_ij| > lam, Theorem 1);
tests/test_engine_backends.py property-tests the equivalence, including ties
|S_ij| == lam.  Register additional backends (e.g. a GPU ECL-CC port) with
``@register_cc_backend("name")``.

The second registry is the ROUTING LADDER: structure class (assigned per
bucket by the planner via ``engine.structure``) -> executor route:

    "singleton" -> "assemble"     closed-form at scatter time, no dispatch
    "pair"      -> "closed_form"  batched analytic 2x2 (forest kernel)
    "tree"      -> "closed_form"  batched Fattahi-Sojoudi forest kernel
    "chordal"   -> "chordal"      host clique-tree direct solve
    "general"   -> "iterative"    the configured bcd/pg/admm solver
    "oversize"  -> "sharded"      mesh-spanning solve for blocks past the
                                  per-device memory budget (planner class,
                                  assigned by size threshold before any
                                  graph classification)

Every non-iterative route is KKT-verified by the executor and falls back to
"iterative" on failure, so re-routing a class (``set_route``) can change
cost but never correctness.  (The sharded route's fallback solves the block
on ONE device — correct but memory-bound, counted in
``solver.oversize.fallbacks``.)

The third registry is the SOLVER protocol (``core.solvers.protocol``,
re-exported here so all three extension points share one import):
capability-tagged ``SolverSpec``s — batched / warm_startable / sharded —
that the executor consults instead of hard-coded name sets.  Register a new
solver with ``register_solver(SolverSpec(name=..., fn=..., ...))``.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.instrument import bump
from repro.core.solvers.protocol import (  # noqa: F401  (re-export surface)
    SolverSpec,
    available_solvers,
    register_solver,
    solver_spec,
)

CCBackend = Callable[..., np.ndarray]

_REGISTRY: dict[str, CCBackend] = {}


def register_cc_backend(name: str) -> Callable[[CCBackend], CCBackend]:
    """Decorator: register ``fn(S, lam, **opts) -> canonical labels``."""

    def deco(fn: CCBackend) -> CCBackend:
        _REGISTRY[name] = fn
        return fn

    return deco


def available_cc_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_cc_backend(name: str) -> CCBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown cc backend {name!r}; available: {available_cc_backends()}"
        ) from None


def label_components(S, lam: float, *, backend: str = "host", **opts) -> np.ndarray:
    """Screen S at lam through the named backend; returns canonical labels."""
    bump(f"registry.cc.{backend}")
    labels = np.asarray(get_cc_backend(backend)(S, lam, **opts))
    if labels.shape != (np.asarray(S).shape[0],):
        raise AssertionError(
            f"backend {backend!r} broke the contract: labels shape "
            f"{labels.shape} for p={np.asarray(S).shape[0]}"
        )
    return labels


# ---------------------------------------------------------------------------
# Solver-routing ladder (structure class -> executor route)
# ---------------------------------------------------------------------------

#: executor routes, cheapest first; "iterative" is the ladder's tail and the
#: fallback target of every verified fast path ("sharded" blocks fall back
#: to a single-device iterative solve — correct, but memory-bound).
#: "fused" is the iterative tail's megabatched variant: small same-dtype
#: buckets are re-packed across bucket boundaries into size-binned stacks
#: and solved with one ``kernels.bucket_glasso`` launch per bin per wave
#: (DESIGN.md Section 16); buckets too large for a bin, or a solver without
#: the ``fused_stack`` capability, fall through to plain "iterative" — like
#: every ladder rung, re-routing changes cost, never the answer
ROUTES = ("assemble", "closed_form", "chordal", "iterative", "fused", "sharded")

_ROUTE_OF: dict[str, str] = {
    "singleton": "assemble",
    "pair": "closed_form",
    "tree": "closed_form",
    "chordal": "chordal",
    "general": "iterative",
    "oversize": "sharded",
    # joint (K-class) ladder classes — assigned by the union-graph
    # classifier in repro.joint.screen.  Identical-block components reduce
    # to ONE single-class problem at an effective lambda: "closed_form" is
    # the batched joint forest fast path, "chordal" the host clique-tree
    # direct solve, and joint_shared's "iterative" is a SINGLE-class
    # iterative solve (1/K of the coupled work); joint_general's
    # "iterative" is the K-coupled joint ADMM
    "joint_forest": "closed_form",
    "joint_chordal": "chordal",
    "joint_shared": "iterative",
    "joint_general": "iterative",
}


def route_for(structure: str) -> str:
    """Executor route for a bucket's structure class (unknown classes take
    the iterative tail — a forward-compatible default for new classifiers)."""
    return _ROUTE_OF.get(structure, "iterative")


def set_route(structure: str, route: str) -> None:
    """Re-route a structure class (e.g. force "tree" -> "iterative" to
    benchmark the ladder against the PR-1 behavior)."""
    if route not in ROUTES:
        raise ValueError(f"unknown route {route!r}; available: {ROUTES}")
    _ROUTE_OF[structure] = route


def solver_routes() -> dict[str, str]:
    return dict(_ROUTE_OF)


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------


@register_cc_backend("host")
def _host(S, lam, **_opts) -> np.ndarray:
    from repro.core.components import components_from_covariance_host

    return components_from_covariance_host(np.asarray(S), float(lam))


@register_cc_backend("jax")
def _jax(S, lam, **_opts) -> np.ndarray:
    import jax.numpy as jnp

    from repro.core.components import (
        canonicalize_labels,
        connected_components_labelprop,
    )

    labels = connected_components_labelprop(jnp.asarray(S), lam)
    return canonicalize_labels(np.asarray(labels))


@register_cc_backend("pallas")
def _pallas(S, lam, *, block: int = 256, **_opts) -> np.ndarray:
    import jax.numpy as jnp

    from repro.core.components import canonicalize_labels
    from repro.kernels.threshold_cc.ops import connected_components_kernel

    labels = connected_components_kernel(jnp.asarray(S), lam, block=block)
    return canonicalize_labels(np.asarray(labels))


@register_cc_backend("shard_map")
def _shard_map(S, lam, *, mesh=None, axis: str = "data", **_opts) -> np.ndarray:
    import jax.numpy as jnp

    from repro.core.components import canonicalize_labels
    from repro.core.distributed import distributed_components
    from repro.core.jax_compat import local_device_mesh

    if mesh is None:
        mesh = local_device_mesh(axis)
    labels = distributed_components(jnp.asarray(S), lam, mesh, axis=axis)
    return canonicalize_labels(np.asarray(labels))

"""Incremental lambda-path planning: screen -> partition -> bucket, diffed.

The screening stage's output along a descending lambda grid is NESTED
(Theorem 2: components only merge), so planning the whole path needs exactly
ONE union-find pass over the edge-sorted |S_ij| —
``partition.labels_at_thresholds`` — after which each lambda's plan is a
snapshot.  Consecutive plans are then DIFFED at bucket granularity: a bucket
whose (padded size, member components) signature is unchanged keeps its padded
block stack (no re-gather / re-pad) and is marked reusable so the executor can
also recycle its previous solution as a warm start.

Because the whole grid is planned upfront, the planner also knows each
component's LIFETIME — the first and last step at which it exists (Theorem
2: merges only, so lifetimes are intervals).  Buckets group components by
(padded size, structure, lifetime): all members of a bucket appear and
merge together, so a bucket's membership never changes during its life and
it is reused — stack, device residency, warm-start solution — at every
step it survives.  Without the lifetime split, one merge (or one newly
completed component joining) anywhere in a size class evicted the whole
bucket and forced every co-bucketed component back through the host gather
path.

Each component is also CLASSIFIED (``engine.structure``) so buckets are
homogeneous in (padded size, structure class) and the executor can route a
whole bucket down one rung of the solver ladder.  Structure is part of the
bucket identity: the same membership at a smaller lambda can gain edges
(components merge OR densify), so a bucket whose subgraph stopped being a
tree must not inherit the tree route from the previous step.

Counters (repro.core.instrument):
    partition.unionfind_passes   exactly 1 per ``plan_path`` call
    planner.plans_built          one per lambda
    planner.buckets_padded       buckets that had to be (re)padded
    planner.buckets_reused       buckets carried over from the previous lambda
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core import blocks as blocks_mod
from repro.core.components import component_lists
from repro.core.instrument import bump, set_peak
from repro.core.partition import _sorted_edges, labels_at_thresholds
from repro.core.screening import ScreenStats
from repro.engine.structure import classify_component


def bucket_key(bucket: blocks_mod.Bucket) -> tuple:
    """Identity of a bucket across lambdas: padded size + structure class +
    exact membership.

    S is fixed along a path, so equal membership implies bit-identical padded
    blocks — the invariant that makes reuse sound (DESIGN.md, plan-diff).
    The structure class is lambda-dependent (edges appear as lambda drops
    even when membership is unchanged), so it is part of the key: a bucket
    that changed class is re-made rather than re-routed."""
    return (
        bucket.size,
        bucket.structure,
        tuple(np.asarray(c).tobytes() for c in bucket.comps),
    )


def _screen_stats(labels: np.ndarray, lam: float, sorted_w: np.ndarray, seconds: float) -> ScreenStats:
    _, counts = np.unique(labels, return_counts=True)
    # sorted_w is descending; edges are strict |S_ij| > lam (eq. (4))
    n_edges = int(np.searchsorted(-sorted_w, -lam, side="left"))
    return ScreenStats(
        lam=float(lam),
        n_components=int(counts.size),
        max_comp=int(counts.max()),
        n_isolated=int((counts == 1).sum()),
        n_edges=n_edges,
        seconds=seconds,
    )


@dataclass
class PathStep:
    """One lambda's executable plan plus its diff against the previous step."""

    lam: float
    labels: np.ndarray
    plan: blocks_mod.Plan
    screen: ScreenStats
    reused_keys: frozenset = frozenset()  # bucket_key()s carried over

    def is_reused(self, bucket: blocks_mod.Bucket) -> bool:
        return bucket_key(bucket) in self.reused_keys


@dataclass
class PathPlan:
    p: int
    lambdas: list[float] = field(default_factory=list)  # descending
    steps: list[PathStep] = field(default_factory=list)


def _classifier(S, lam: float, oversize: int | None):
    """Structure classifier with the oversize short-circuit.

    The size check runs BEFORE graph classification: an oversize component
    is sharded regardless of its subgraph shape, and running MCS/PEO on a
    near-p component would cost more than any route it could unlock."""
    def classify(c):
        if oversize is not None and len(c) > oversize:
            bump("structure.classified.oversize")
            return "oversize"
        return classify_component(S, c, lam)

    return classify


def component_lifetimes(labels_list) -> dict:
    """Map component membership (``tobytes`` of its sorted vertex array) to
    its (birth step, death step) over a descending-lambda sequence of label
    snapshots.  Nested partitions (Theorem 2) mean a component exists on one
    consecutive run of steps and then merges; one forward pass recording the
    first and last sighting is exact."""
    life: dict = {}
    for t, labels in enumerate(labels_list):
        for c in component_lists(labels):
            b = c.tobytes()
            life[b] = (life[b][0], t) if b in life else (t, t)
    return life


def build_plan_incremental(
    S: np.ndarray,
    lam: float,
    labels: np.ndarray,
    *,
    prev: blocks_mod.Plan | None = None,
    dtype=np.float64,
    classify_structures: bool = True,
    oversize: int | None = None,
    lifetime_of: dict | None = None,
) -> tuple[blocks_mod.Plan, frozenset]:
    """``blocks.build_plan`` with bucket reuse against a previous plan.

    ``lifetime_of`` (``component_lifetimes`` of the full grid) splits each
    (size, structure) group by member (birth, death) interval: every member
    of a bucket appears and merges at the same steps, so bucket membership
    is static for the bucket's whole life and reuse holds at every step of
    it — the path planners pass this; single-solve callers don't and get
    the plain grouping.

    ``classify_structures=False`` skips structure classification and tags
    every bucket "general" — the PR-1 plan shape.  Required when routing is
    off (the classifier's cost and the finer (size, structure) bucket split
    would distort the unrouted baseline) and when ``labels`` does not come
    from real screening (screen=False forces one global pseudo-component,
    which is not connected — the classifier's precondition).

    ``oversize`` is the single-device block-size cap (``blocks.
    oversize_threshold``): larger components are classed "oversize" and
    carry no host block stack — the executor's sharded route gathers them
    straight into device shards.

    Returns (plan, reused bucket keys)."""
    bump("planner.plans_built")
    comps = component_lists(labels)
    classify = _classifier(S, lam, oversize) if classify_structures else None
    isolated, by_key = blocks_mod.group_components(comps, classify=classify)
    prev_by_key = (
        {bucket_key(b): b for b in prev.buckets} if prev is not None else {}
    )
    buckets, reused = [], set()
    for (size, structure), members in by_key.items():
        if lifetime_of is None:
            groups = [members]
        else:
            by_life: dict = {}
            for c in members:
                by_life.setdefault(lifetime_of[np.asarray(c).tobytes()], []).append(c)
            # order members by first vertex: canonical component labels are
            # renumbered after every merge, so label order would shuffle a
            # surviving bucket's membership tuple and break its reuse key
            groups = [
                sorted(by_life[d], key=lambda c: int(np.asarray(c)[0]))
                for d in sorted(by_life)
            ]
        for mem in groups:
            key = (
                size,
                structure,
                tuple(np.asarray(c).tobytes() for c in mem),
            )
            hit = prev_by_key.get(key)
            if hit is not None:
                buckets.append(hit)
                reused.add(key)
                bump("planner.buckets_reused")
            else:
                buckets.append(
                    blocks_mod.make_bucket(
                        S, size, mem, dtype=dtype, structure=structure
                    )
                )
                bump("planner.buckets_padded")
    plan = blocks_mod.Plan(
        p=S.shape[0],
        lam=float(lam),
        labels=labels,
        isolated=isolated,
        buckets=buckets,
    )
    set_peak("plan.bytes_peak", plan.block_bytes())
    return plan, frozenset(reused)


def plan_path(
    S: np.ndarray,
    lambdas,
    *,
    dtype=np.float64,
    classify_structures: bool = True,
    oversize: int | None = None,
) -> PathPlan:
    """Plan a whole descending-lambda path with one partition pass.

    Every requested lambda gets a PathStep whose ScreenStats are derived from
    the snapshot (no per-lambda thresholding or union-find).  The grid is
    canonicalized through THE shared chokepoint (``select.grid``): sorted
    descending, deduped, non-positive values rejected."""
    from repro.select.grid import normalize_lambda_grid  # lazy: select imports engine

    S = np.asarray(S)
    lams = normalize_lambda_grid(lambdas)
    t0 = time.perf_counter()
    # shared by the snapshot pass and edge counting; the grid's smallest
    # lambda bounds every insertion, so sub-threshold edges never sort
    edges = _sorted_edges(S, lam_min=lams[-1])
    labels_list = labels_at_thresholds(S, lams, edges=edges)
    sorted_w = edges[2]
    snap_seconds = (time.perf_counter() - t0) / max(len(lams), 1)

    life = component_lifetimes(labels_list)
    path = PathPlan(p=S.shape[0], lambdas=lams)
    prev_plan = None
    for lam, labels in zip(lams, labels_list):
        t1 = time.perf_counter()
        plan, reused = build_plan_incremental(
            S, lam, labels, prev=prev_plan, dtype=dtype,
            classify_structures=classify_structures, oversize=oversize,
            lifetime_of=life,
        )
        stats = _screen_stats(
            labels, lam, sorted_w, snap_seconds + (time.perf_counter() - t1)
        )
        path.steps.append(
            PathStep(lam=lam, labels=labels, plan=plan, screen=stats, reused_keys=reused)
        )
        prev_plan = plan
    return path

"""One typed options object for the whole engine surface.

Before this module, ``glasso``/``glasso_path``/``joint_glasso`` (and
``Engine``/``JointEngine``/``GlassoServer`` underneath) each re-declared the
same overlapping engine kwargs — ``route``, ``cc_backend``, ``oversize_*``,
``output``, ``stream``, plus the free-form solver opts — and a request could
not carry that configuration as a value (the serving control plane needs to
ship it inside a spec).  ``EngineOptions`` collapses them into one frozen
dataclass accepted everywhere as ``options=``.

The legacy kwargs still work through a SINGLE normalization chokepoint,
``normalize_options``: the public wrappers call it with ``warn=True`` so
kwarg-style configuration raises a ``DeprecationWarning`` (tests pin this),
while internal constructors normalize silently.  Passing both ``options=``
and legacy kwargs is an error — there is exactly one source of truth per
call.

Field split (what belongs here vs. a call site):

* **EngineOptions** — how solves are CONFIGURED: solver choice, dtype,
  screening backend, routing ladder, oversize route, result representation,
  stream defaults, joint tail verification, solver opts (``tol``,
  ``max_iter``, ...).
* **call kwargs** — what is being SOLVED: ``S``/``X``/``lam``/``lambdas``,
  ``screen=False`` baselines, ``p_max``, ``warm_W``/``warm_start``,
  ``penalty``, serving ``session``.  These are not deprecated.

Model-selection knobs (the lambda grid, the criterion and its parameters)
are neither: they describe a QUESTION about the path, not how solves run,
and travel on ``repro.select.select_path(...)`` arguments — or, over the
serving surface, on ``launch.control_plane.PathSpec`` — always alongside
an ``EngineOptions`` that configures the underlying solves.  One
``EngineOptions`` therefore serves every grid point of a selection path
unchanged (which is what lets the homotopy executor reuse compiled
solvers and warm starts across the whole grid).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field, fields, replace
from typing import Any, Mapping

__all__ = ["EngineOptions", "ENGINE_OPTION_KEYS", "normalize_options"]


@dataclass(frozen=True)
class EngineOptions:
    """Engine configuration as a value.

    ``solver=None`` means "the context default" — "bcd" for single-class
    engines, "joint_admm" for the joint engine; ``dtype=None`` resolves to
    ``jnp.float64``.  ``solver_opts`` holds the free-form per-solver knobs
    (``tol``, ``max_iter``, ``rho``, ...) that used to travel as ``**kwargs``.
    """

    solver: str | None = None
    dtype: Any = None
    cc_backend: str = "host"
    route: bool = True
    route_check_tol: float = 1e-6
    oversize_threshold: int | None = None
    oversize_budget_mb: float | str | None = None
    output: str = "auto"
    stream: Any = None             # StreamConfig / kwargs dict default
    verify_tail: bool = False      # joint-only: exact tail KKT verification
    # wave packer (DESIGN.md Section 16): True fuses all small iterative
    # buckets into one bucket_glasso megabatch launch per size bin per wave
    # (requires a solver with the "fused_stack" capability); False never
    # fuses; "auto" fuses when the solver forces it ("fused_bcd") or a
    # structure class is routed to "fused" (registry.set_route)
    fused: bool | str = "auto"
    # observability (DESIGN.md Section 17): True roots a request Trace per
    # run/run_path (spans: screen -> plan -> per-step solve -> dispatch ->
    # assemble) attached as ``GlassoResult.trace``; False makes the engine
    # span-free (the <5%-overhead bench arm); "jax" additionally wraps each
    # dispatch wave in ``jax.profiler.TraceAnnotation`` so device-side
    # profiler timelines correlate with the host span tree
    trace: bool | str = True
    solver_opts: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.output not in ("dense", "sparse", "auto"):
            raise ValueError(
                f"output must be 'dense', 'sparse' or 'auto', got {self.output!r}"
            )
        if self.fused not in (True, False, "auto"):
            raise ValueError(
                f"fused must be True, False or 'auto', got {self.fused!r}"
            )
        if self.trace not in (True, False, "jax"):
            raise ValueError(
                f"trace must be True, False or 'jax', got {self.trace!r}"
            )
        object.__setattr__(self, "solver_opts", dict(self.solver_opts))

    # -- derived views ----------------------------------------------------

    def resolved_solver(self, default: str) -> str:
        return self.solver if self.solver is not None else default

    def resolved_dtype(self):
        if self.dtype is None:
            import jax.numpy as jnp

            return jnp.float64
        return self.dtype

    def np_dtype(self):
        """The numpy dtype mirroring ``resolved_dtype()`` (host-side
        gathers/assembly use numpy; devices use the jax dtype)."""
        import jax.numpy as jnp
        import numpy as np

        return np.dtype(jnp.dtype(self.resolved_dtype()).name)

    def replace(self, **changes) -> "EngineOptions":
        """``dataclasses.replace`` with solver_opts MERGED, not clobbered:
        unknown keys in ``changes`` update solver_opts entry-wise (the same
        absorption rule as the legacy kwargs layer)."""
        known = {f.name for f in fields(self)}
        direct = {k: v for k, v in changes.items() if k in known}
        extra = {k: v for k, v in changes.items() if k not in known}
        if extra:
            merged = dict(self.solver_opts)
            merged.update(extra)
            direct.setdefault("solver_opts", merged)
        return replace(self, **direct)


#: Engine-configuration keys the legacy kwarg layer recognizes; anything
#: else a caller passes is absorbed into ``solver_opts`` (the historical
#: ``**solver_opts`` behavior — validated downstream by the executor).
ENGINE_OPTION_KEYS = frozenset(
    f.name for f in fields(EngineOptions) if f.name != "solver_opts"
)

_DEPRECATION_MSG = (
    "configuring {context} via bare engine kwargs ({keys}) is deprecated; "
    "pass options=EngineOptions(...) instead (repro.engine.EngineOptions)"
)


def normalize_options(
    options: EngineOptions | None,
    kwargs: Mapping[str, Any],
    *,
    warn: bool = False,
    context: str = "the engine",
) -> EngineOptions:
    """THE normalization chokepoint: every options-accepting surface funnels
    its ``options=``/legacy-kwargs pair through here.

    * ``options`` given and ``kwargs`` empty — pass-through (validated).
    * ``kwargs`` only — build an ``EngineOptions``, splitting recognized
      engine keys from free-form solver opts; with ``warn=True`` (the public
      wrappers) this is the deprecation layer and raises a
      ``DeprecationWarning`` naming the legacy keys.
    * both — ``TypeError``: one source of truth per call.
    """
    if options is not None:
        if kwargs:
            raise TypeError(
                f"pass options=EngineOptions(...) OR legacy engine kwargs "
                f"({sorted(kwargs)}), not both"
            )
        if not isinstance(options, EngineOptions):
            raise TypeError(
                f"options must be an EngineOptions, got {type(options).__name__}"
            )
        return options
    if not kwargs:
        return EngineOptions()
    if warn:
        warnings.warn(
            _DEPRECATION_MSG.format(context=context, keys=sorted(kwargs)),
            DeprecationWarning,
            stacklevel=3,
        )
    direct = {k: v for k, v in kwargs.items() if k in ENGINE_OPTION_KEYS}
    solver_opts = {k: v for k, v in kwargs.items() if k not in ENGINE_OPTION_KEYS}
    return EngineOptions(solver_opts=solver_opts, **direct)

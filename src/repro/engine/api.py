"""The Plan->Execute engine: one object owning the whole pipeline

    screen -> partition -> bucket -> place -> solve -> assemble

``Engine.run``       one (S, lam) solve through a registry screening backend,
                     the bucket planner, and the async executor.
``Engine.run_path``  a descending lambda grid with ONE partition pass
                     (planner.plan_path) and bucket-level reuse of padded
                     arrays + warm starts between consecutive lambdas.

``repro.core.glasso.glasso/glasso_path`` are thin wrappers over this module —
the public API is unchanged, the engine is the implementation.  Serving
(``repro.launch.serve_glasso``) drives the same executor/compiled-cache with
cross-request coalescing.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core import schedule as schedule_mod
from repro.core.components import component_lists
from repro.core.screening import ScreenStats, thresholded_components
from repro.engine.executor import BucketExecutor
from repro.engine.planner import build_plan_incremental, plan_path


@dataclass
class GlassoResult:
    lam: float
    Theta: np.ndarray
    labels: np.ndarray
    screen: ScreenStats | None
    solve_seconds: float
    solver: str
    block_sizes: list[int] = field(default_factory=list)
    route_mix: dict = field(default_factory=dict)  # structure class -> #blocks
    routed: bool = True            # was the routing ladder enabled?

    @property
    def support(self) -> np.ndarray:
        """Estimated concentration-graph adjacency (eq. (2))."""
        A = np.abs(self.Theta) > 0
        np.fill_diagonal(A, False)
        return A

    @property
    def noniterative_fraction(self) -> float:
        """Share of this solve's blocks ROUTED to a non-iterative solver
        (the routing-ladder acceptance metric; singletons included).

        0.0 when the solve ran with route=False; honors ``registry.set_route``
        re-routing.  The rare KKT-rejected blocks repaired by the iterative
        tail are NOT subtracted — track those via the ``router.fallback.*``
        counters."""
        from repro.engine.registry import route_for

        if not self.routed:
            return 0.0
        total = sum(self.route_mix.values())
        if not total:
            return 1.0
        iterative = sum(
            n for cls, n in self.route_mix.items() if route_for(cls) == "iterative"
        )
        return 1.0 - iterative / total


def _result(
    plan, labels, screen_stats, Theta, seconds, solver, lam, *, routed: bool = True
) -> GlassoResult:
    route_mix = {"singleton": len(plan.isolated)} if len(plan.isolated) else {}
    for b in plan.buckets:
        route_mix[b.structure] = route_mix.get(b.structure, 0) + len(b.comps)
    return GlassoResult(
        lam=float(lam),
        Theta=Theta,
        labels=labels,
        screen=screen_stats,
        solve_seconds=seconds,
        solver=solver,
        block_sizes=sorted(
            (len(c) for b in plan.buckets for c in b.comps), reverse=True
        ),
        route_mix=route_mix,
        routed=routed,
    )


class Engine:
    """Reusable pipeline instance: fixed (solver, dtype, cc_backend, opts).

    Holds the per-stream executor (and thus the warm-start bucket state); the
    compiled-solver cache underneath is process-global, so engines are cheap
    to construct."""

    def __init__(
        self,
        *,
        solver: str = "bcd",
        dtype=jnp.float64,
        cc_backend: str = "host",
        devices=None,
        route: bool = True,
        route_check_tol: float = 1e-6,
        **solver_opts,
    ):
        from repro.core.solvers import WARM_START_SOLVERS

        self.solver = solver
        self.dtype = dtype
        self.np_dtype = np.dtype(jnp.dtype(dtype).name)  # host-side twin
        self.cc_backend = cc_backend
        self.warm_capable = solver in WARM_START_SOLVERS
        self.executor = BucketExecutor(
            solver=solver,
            dtype=dtype,
            solver_opts=solver_opts,
            devices=devices,
            route=route,
            route_check_tol=route_check_tol,
        )

    # -- stages ------------------------------------------------------------

    def screen(self, S: np.ndarray, lam: float) -> tuple[np.ndarray, ScreenStats]:
        return thresholded_components(S, lam, backend=self.cc_backend)

    # -- single solve ------------------------------------------------------

    def run(
        self,
        S: np.ndarray,
        lam: float,
        *,
        screen: bool = True,
        p_max: int | None = None,
        warm_W: np.ndarray | None = None,
        labels: np.ndarray | None = None,
    ) -> GlassoResult:
        """``labels`` short-circuits the screening stage with a precomputed
        canonical partition (callers that already screened, e.g. to report
        stage timings, should not pay for the partition twice)."""
        S = np.asarray(S)
        p = S.shape[0]
        screened = True
        if labels is not None:
            from repro.core.screening import screen_stats_from_labels

            labels = np.asarray(labels)
            screen_stats = screen_stats_from_labels(S, lam, labels, seconds=0.0)
        elif screen:
            labels, screen_stats = self.screen(S, lam)
        else:
            labels = np.zeros(p, dtype=np.int64)  # one global component
            screen_stats = None
            screened = False
        # classify only when routing can use the tags AND the labels are a
        # real screening partition (the screen=False pseudo-component is not
        # connected, which the classifier requires — the unscreened baseline
        # must stay on the dense iterative path)
        plan, _ = build_plan_incremental(
            S, lam, labels, dtype=self.np_dtype,
            classify_structures=self.executor.route and screened,
        )
        schedule_mod.check_capacity(
            [len(c) for b in plan.buckets for c in b.comps] or [1], p_max
        )
        t0 = time.perf_counter()
        Theta = self.executor.solve_plan(plan, float(lam), S, warm_W=warm_W)
        seconds = time.perf_counter() - t0
        return _result(
            plan, labels, screen_stats, Theta, seconds, self.solver, lam,
            routed=self.executor.route,
        )

    # -- lambda path -------------------------------------------------------

    def run_path(
        self,
        S: np.ndarray,
        lambdas,
        *,
        warm_start: bool = True,
        p_max: int | None = None,
    ) -> list[GlassoResult]:
        """Descending path: one union-find pass, diffed plans, warm starts.

        Theorem 2 guarantees nested partitions, so (a) the planner can
        snapshot every lambda from a single pass, and (b) the previous Theta
        restricted to a merged component is block-diagonal over its old
        sub-components — a valid PD warm start.  Buckets unchanged between
        consecutive lambdas skip re-padding entirely and warm-start from their
        own previous padded solutions on device."""
        from repro.engine.registry import route_for  # local: avoid cycle at import

        S = np.asarray(S)
        path = plan_path(
            S, lambdas, dtype=self.np_dtype,
            classify_structures=self.executor.route,
        )
        results: list[GlassoResult] = []
        prev: GlassoResult | None = None
        for step in path.steps:
            schedule_mod.check_capacity(
                [len(c) for b in step.plan.buckets for c in b.comps] or [1], p_max
            )
            warm_W = None
            if warm_start and prev is not None and self.warm_capable:
                # warm starts only matter for iterative-routed buckets; a
                # closed-form/chordal block is solved directly regardless
                fresh = [
                    b
                    for b in step.plan.buckets
                    if not step.is_reused(b)
                    and (
                        not self.executor.route
                        or route_for(b.structure) == "iterative"
                    )
                ]
                if fresh:
                    # dense warm start only for merged buckets: blockwise
                    # inverse of the previous Theta over its old components
                    warm_W = np.zeros_like(prev.Theta)
                    needed = np.zeros(S.shape[0], dtype=bool)
                    for b in fresh:
                        for c in b.comps:
                            needed[c] = True
                    for comp in component_lists(prev.labels):
                        if not needed[comp].any():
                            continue
                        blk = prev.Theta[np.ix_(comp, comp)]
                        warm_W[np.ix_(comp, comp)] = np.linalg.inv(blk)
            t0 = time.perf_counter()
            Theta = self.executor.solve_plan(
                step.plan,
                step.lam,
                S,
                warm_W=warm_W,
                reused_keys=step.reused_keys if warm_start else frozenset(),
                keep_solutions=warm_start,
            )
            seconds = time.perf_counter() - t0
            res = _result(
                step.plan, step.labels, step.screen, Theta, seconds, self.solver,
                step.lam, routed=self.executor.route,
            )
            results.append(res)
            prev = res
        return results

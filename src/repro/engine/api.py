"""The Plan->Execute engine: one object owning the whole pipeline

    screen -> partition -> bucket -> place -> solve -> assemble

``Engine.run``       one (S, lam) solve through a registry screening backend,
                     the bucket planner, and the async executor.
``Engine.run_path``  a descending lambda grid with ONE partition pass
                     (planner.plan_path) and bucket-level reuse of padded
                     arrays + warm starts between consecutive lambdas.

``repro.core.glasso.glasso/glasso_path`` are thin wrappers over this module —
the public API is unchanged, the engine is the implementation.  Serving
(``repro.launch.serve_glasso``) drives the same executor/compiled-cache with
cross-request coalescing.
"""

from __future__ import annotations

import time
from contextlib import nullcontext

import jax.numpy as jnp
import numpy as np

from repro.core import schedule as schedule_mod
from repro.core.components import component_lists
from repro.core.instrument import bump
from repro.core.screening import ScreenStats, thresholded_components
from repro.core.sparse import SparseTheta, resolve_output, result_nbytes
from repro.engine.executor import BucketExecutor
from repro.engine.options import EngineOptions, normalize_options
from repro.engine.planner import build_plan_incremental, plan_path
from repro.obs.trace import Trace, current_trace, span, trace_request

#: canonical stage order of the ``result.stages()`` view
STAGES = ("screen", "solve", "dispatch", "assemble")


class GlassoResult:
    """One solve's answer + attribution.

    Timing lives in ONE place — the ``stages()`` view (seconds per
    canonical stage: screen / solve / dispatch / assemble) — and the
    historical per-stage attributes (``solve_seconds``,
    ``assemble_seconds``, ``dispatch_seconds``, ``screen_seconds``,
    ``stages_us``) are properties over it.  ``trace`` carries the full
    request :class:`repro.obs.Trace` (span tree, per-wave dispatch
    detail, cross-thread attribution) when the solve ran traced;
    ``trace.to_chrome_json(path)`` exports it for Perfetto."""

    def __init__(
        self,
        lam: float,
        Theta,                     # dense (p, p) — or a SparseTheta when
                                   # output resolved to "sparse"
        labels: np.ndarray,
        screen: ScreenStats | None,
        solve_seconds: float,      # device solve + verify (assembly and
                                   # dispatch-issue overhead EXCLUDED)
        solver: str,
        block_sizes: list[int] | None = None,
        route_mix: dict | None = None,  # structure class -> #blocks
        routed: bool = True,       # was the routing ladder enabled?
        # sharded-route accounting for THIS solve: {dispatched, inner_iters,
        # fallbacks} (empty when no block took the oversize route); the
        # process-wide view is instrument counts("solver.oversize.")
        oversize: dict | None = None,
        assemble_seconds: float = 0.0,  # scatter/index-build slice
        # host seconds spent ISSUING async solver launches — the per-dispatch
        # overhead the wave packer collapses.  Reported as its own stage:
        # before it existed this time was silently folded into solve_seconds,
        # which is how a warm homotopy pass (many small reused buckets, ~6x
        # the dispatch count of a cold pass) showed a LARGER solve stage than
        # cold despite a faster wall clock (the bench_select anomaly)
        dispatch_seconds: float = 0.0,
        bytes_peak: int = 0,       # resident bytes of Theta as assembled
        output: str = "dense",     # the representation actually returned
        trace: Trace | None = None,
    ):
        self.lam = lam
        self.Theta = Theta
        self.labels = labels
        self.screen = screen
        self.solver = solver
        self.block_sizes = list(block_sizes) if block_sizes is not None else []
        self.route_mix = dict(route_mix) if route_mix is not None else {}
        self.routed = routed
        self.oversize = dict(oversize) if oversize is not None else {}
        self.bytes_peak = bytes_peak
        self.output = output
        self.trace = trace
        self._stage_seconds = {
            "screen": float(screen.seconds) if screen is not None else 0.0,
            "solve": float(solve_seconds),
            "dispatch": float(dispatch_seconds),
            "assemble": float(assemble_seconds),
        }

    def __repr__(self) -> str:
        return (
            f"GlassoResult(lam={self.lam!r}, p={len(self.labels)}, "
            f"solver={self.solver!r}, output={self.output!r})"
        )

    # -- unified timing view ------------------------------------------------

    def stages(self) -> dict[str, float]:
        """Seconds per canonical stage for THIS result: ``screen`` /
        ``solve`` / ``dispatch`` / ``assemble`` — the single source the
        legacy ``*_seconds`` properties and ``stages_us`` read from.  The
        attached ``trace`` (when present) holds the same stages as spans
        plus the nested detail no scalar can carry."""
        return dict(self._stage_seconds)

    @property
    def solve_seconds(self) -> float:
        return self._stage_seconds["solve"]

    @property
    def assemble_seconds(self) -> float:
        return self._stage_seconds["assemble"]

    @property
    def dispatch_seconds(self) -> float:
        return self._stage_seconds["dispatch"]

    @property
    def screen_seconds(self) -> float:
        """Screening-stage seconds (0.0 when screening was skipped or the
        labels were precomputed)."""
        return self._stage_seconds["screen"]

    @property
    def stages_us(self) -> dict[str, int]:
        """Per-result stage attribution in microseconds — the same values
        this result bumped into the process-wide ``engine.screen_us`` /
        ``engine.solve_us`` / ``engine.assemble_us`` counters, kept on the
        result so path consumers (``repro.select``, bench_select) can
        report where homotopy saves time per grid point."""
        return {f"{k}_us": int(v * 1e6) for k, v in self._stage_seconds.items()}

    @property
    def support(self) -> np.ndarray:
        """Estimated concentration-graph adjacency (eq. (2)).

        Sparse results derive it from per-block nonzeros — dense bool up to
        the densify cap, scipy bool CSR above it — so calling this on a
        large result does not recreate the O(p^2) allocation."""
        if isinstance(self.Theta, SparseTheta):
            return self.Theta.support()
        A = np.abs(self.Theta) > 0
        np.fill_diagonal(A, False)
        return A

    def support_edges(self) -> np.ndarray:
        """(E, 2) off-diagonal upper-triangular support edges — the payload
        form sparse serving responses carry at any p."""
        if isinstance(self.Theta, SparseTheta):
            return self.Theta.support_edges()
        r, c = np.nonzero(np.triu(self.support, k=1))
        return np.stack([r, c], axis=1).astype(np.int64) if r.size else np.zeros(
            (0, 2), dtype=np.int64
        )

    @property
    def noniterative_fraction(self) -> float:
        """Share of this solve's blocks ROUTED to a non-iterative solver
        (the routing-ladder acceptance metric; singletons included).

        0.0 when the solve ran with route=False; honors ``registry.set_route``
        re-routing.  The rare KKT-rejected blocks repaired by the iterative
        tail are NOT subtracted — track those via the ``router.fallback.*``
        counters."""
        from repro.engine.registry import route_for

        if not self.routed:
            return 0.0
        total = sum(self.route_mix.values())
        if not total:
            return 1.0
        iterative = sum(
            n for cls, n in self.route_mix.items()
            if route_for(cls) in ("iterative", "fused")
        )
        return 1.0 - iterative / total


def resolve_oversize(
    threshold: int | None, budget_mb: float | str | None, np_dtype, *,
    route: bool = True,
) -> int | None:
    """Resolve the single-device block-size cap for the oversize route.

    An explicit ``threshold`` wins; otherwise it is derived from a per-device
    memory budget in MB (``blocks.oversize_threshold``), where ``"auto"``
    asks the backend for its HBM size (``distributed.
    device_memory_budget_mb`` — None on CPU, disabling the route).  Returns
    None when oversize routing is off.  Oversize is a ROUTE, so it requires
    the routing ladder."""
    if threshold is None and budget_mb is None:
        return None
    if not route:
        raise ValueError(
            "oversize_threshold / oversize_budget_mb require route=True "
            "(the oversize class is a routing-ladder rung)"
        )
    if threshold is not None:
        return int(threshold)
    if budget_mb == "auto":
        from repro.core.distributed import device_memory_budget_mb

        budget_mb = device_memory_budget_mb()
        if budget_mb is None:
            return None
    from repro.core.blocks import oversize_threshold as _threshold_from_budget

    return _threshold_from_budget(float(budget_mb), np_dtype)


def _as_cov_operand(S):
    """Dense arrays pass through np.asarray; materialized streamed
    covariances (the gather protocol: ``gather_block``/``diag_at``) are used
    as-is — wrapping them in an object array would defeat the point."""
    return S if hasattr(S, "gather_block") else np.asarray(S)


def blockwise_inverse(
    labels: np.ndarray, Theta: np.ndarray, needed: np.ndarray | None = None
) -> np.ndarray:
    """Dense W = inv(Theta) computed block-by-block over ``labels``'
    components (Theta is block-diagonal over them by Theorem 1).

    ``needed`` (bool mask over vertices) restricts the work to components
    that intersect it.  Shared by the path warm start (merged components:
    the restriction of the old Theta is block-diagonal over its old
    sub-components, hence PD — a valid W iterate) and the serving data
    sessions (rank-k updates warm-start every surviving component).

    A block-sparse ``Theta`` produces a block-sparse W over the SAME
    components (inverses per block, reciprocal isolated diagonal) — no
    (p, p) buffer appears anywhere on the warm-start path; the executor
    gathers merged-component restrictions through ``gather_block``, whose
    cross-component entries are exact zeros."""
    if isinstance(Theta, SparseTheta):
        return _blockwise_inverse_sparse(Theta, needed)
    W = np.zeros_like(Theta)
    for comp in component_lists(labels):
        if needed is not None and not needed[comp].any():
            continue
        W[np.ix_(comp, comp)] = np.linalg.inv(Theta[np.ix_(comp, comp)])
    return W


def _blockwise_inverse_sparse(
    Theta: SparseTheta, needed: np.ndarray | None
) -> SparseTheta:
    """Block-diagonal W = inv(Theta) of a sparse result, as another
    ``SparseTheta`` (one single-row stack per needed component)."""
    from repro.core.sparse import _build_index

    stacks: list[np.ndarray] = []
    comps: list[np.ndarray] = []
    loc: list[tuple[int, int]] = []
    for c, blk in Theta.blocks():
        if needed is not None and not needed[c].any():
            continue
        comps.append(c)
        loc.append((len(stacks), 0))
        stacks.append(np.linalg.inv(blk)[None])
    iso = Theta.isolated
    vals = Theta.isolated_values
    if needed is not None and iso.size:
        keep = needed[iso]
        iso, vals = iso[keep], vals[keep]
    comp_id, pos_in = _build_index(Theta.p, comps, iso)
    return SparseTheta(
        Theta.p, Theta.dtype, stacks, comps, loc, comp_id, pos_in,
        iso, (1.0 / vals).astype(Theta.dtype, copy=False),
        densify_max=Theta.densify_max,
    )


def _result(
    plan, labels, screen_stats, Theta, seconds, solver, lam, *,
    routed: bool = True, oversize: dict | None = None,
    assemble_seconds: float = 0.0, dispatch_seconds: float = 0.0,
) -> GlassoResult:
    route_mix = {"singleton": len(plan.isolated)} if len(plan.isolated) else {}
    for b in plan.buckets:
        route_mix[b.structure] = route_mix.get(b.structure, 0) + len(b.comps)
    solve_seconds = max(
        0.0, float(seconds) - float(assemble_seconds) - float(dispatch_seconds)
    )
    bump("engine.solve_us", int(solve_seconds * 1e6))
    if screen_stats is not None:
        bump("engine.screen_us", int(float(screen_stats.seconds) * 1e6))
    return GlassoResult(
        trace=current_trace(),
        lam=float(lam),
        Theta=Theta,
        labels=labels,
        screen=screen_stats,
        solve_seconds=solve_seconds,
        solver=solver,
        block_sizes=sorted(
            (len(c) for b in plan.buckets for c in b.comps), reverse=True
        ),
        route_mix=route_mix,
        routed=routed,
        oversize=dict(oversize or {}),
        assemble_seconds=float(assemble_seconds),
        dispatch_seconds=float(dispatch_seconds),
        bytes_peak=result_nbytes(Theta),
        output="sparse" if isinstance(Theta, SparseTheta) else "dense",
    )


class Engine:
    """Reusable pipeline instance: fixed (solver, dtype, cc_backend, opts).

    Holds the per-stream executor (and thus the warm-start bucket state); the
    compiled-solver cache underneath is process-global, so engines are cheap
    to construct."""

    def __init__(
        self,
        *,
        options: EngineOptions | None = None,
        devices=None,
        **legacy_engine_kwargs,
    ):
        """``options=EngineOptions(...)`` is the configuration surface; the
        historical kwargs (``solver=``, ``route=``, ``tol=``, ...) still
        work through the shared normalization chokepoint (they warn at the
        PUBLIC wrappers — ``glasso``/``glasso_path`` — not here, so internal
        constructions stay quiet)."""
        from repro.core.solvers import WARM_START_SOLVERS, solver_spec

        opts = normalize_options(options, legacy_engine_kwargs, context="Engine")
        self.options = opts
        self.output = opts.output
        self.solver = opts.resolved_solver("bcd")
        self.dtype = opts.resolved_dtype()
        self.np_dtype = np.dtype(jnp.dtype(self.dtype).name)  # host-side twin
        self.cc_backend = opts.cc_backend
        self.stream = opts.stream   # default StreamConfig for from-data runs
        self.warm_capable = self.solver in WARM_START_SOLVERS
        self.oversize = resolve_oversize(
            opts.oversize_threshold, opts.oversize_budget_mb, self.np_dtype,
            route=opts.route,
        )
        # wave-packer resolution (EngineOptions.fused): True demands the
        # capability, "auto" turns on only for solvers that force it
        # ("fused_bcd") — buckets ROUTED "fused" via registry.set_route fuse
        # in the executor regardless of this flag
        meta = solver_spec(self.solver).meta
        if opts.fused is True and not meta.get("fused_stack"):
            raise ValueError(
                f"fused=True requires a solver with the 'fused_stack' "
                f"capability; {self.solver!r} lacks it"
            )
        fused = (
            bool(meta.get("force_fused")) if opts.fused == "auto"
            else bool(opts.fused)
        )
        self.executor = BucketExecutor(
            solver=self.solver,
            dtype=self.dtype,
            solver_opts=dict(opts.solver_opts),
            devices=devices,
            route=opts.route,
            route_check_tol=opts.route_check_tol,
            fused=fused,
            jax_annotations=opts.trace == "jax",
        )

    def _trace_ctx(self, name: str, **attrs):
        """Root a request trace for this run — or join the ambient one
        (serving owns the root for submitted work).  ``EngineOptions
        (trace=False)`` makes the engine span-free: nothing roots, and
        ``span()`` calls below degrade to no-ops unless an outer layer
        (the server) is tracing."""
        if not self.options.trace:
            return nullcontext()
        return trace_request(name, **attrs)

    # -- stages ------------------------------------------------------------

    def screen(self, S: np.ndarray, lam: float) -> tuple[np.ndarray, ScreenStats]:
        with span("engine.screen", backend=self.cc_backend):
            return thresholded_components(S, lam, backend=self.cc_backend)

    # -- single solve ------------------------------------------------------

    def run(
        self,
        S: np.ndarray,
        lam: float,
        *,
        screen: bool = True,
        p_max: int | None = None,
        warm_W: np.ndarray | None = None,
        labels: np.ndarray | None = None,
        screen_stats: ScreenStats | None = None,
        output: str | None = None,
    ) -> GlassoResult:
        """``labels`` short-circuits the screening stage with a precomputed
        canonical partition (callers that already screened, e.g. to report
        stage timings, should not pay for the partition twice);
        ``screen_stats`` rides along when the caller has them (the streaming
        screener's stats carry tile counters a dense recount would lose).
        ``S`` may be a materialized streamed covariance (gather protocol) —
        then ``labels`` is required, since dense screening needs dense S."""
        S = _as_cov_operand(S)
        p = S.shape[0]
        with self._trace_ctx("engine.run", lam=float(lam), p=int(p)):
            screened = True
            if labels is not None:
                labels = np.asarray(labels)
                if screen_stats is None:
                    from repro.core.screening import screen_stats_from_labels

                    screen_stats = screen_stats_from_labels(
                        S, lam, labels, seconds=0.0
                    )
            elif hasattr(S, "gather_block"):
                raise ValueError(
                    "materialized covariances cannot be re-screened densely; "
                    "pass the streamed labels (see Engine.run_from_data)"
                )
            elif screen:
                labels, screen_stats = self.screen(S, lam)
            else:
                labels = np.zeros(p, dtype=np.int64)  # one global component
                screen_stats = None
                screened = False
            # classify only when routing can use the tags AND the labels are
            # a real screening partition (the screen=False pseudo-component
            # is not connected, which the classifier requires — the
            # unscreened baseline must stay on the dense iterative path)
            with span("engine.plan"):
                plan, _ = build_plan_incremental(
                    S, lam, labels, dtype=self.np_dtype,
                    classify_structures=self.executor.route and screened,
                    oversize=self.oversize if screened else None,
                )
            schedule_mod.check_capacity(
                [len(c) for b in plan.buckets for c in b.comps] or [1], p_max
            )
            out_mode = resolve_output(
                self.output if output is None else output, p
            )
            t0 = time.perf_counter()
            with span("engine.solve", lam=float(lam)):
                Theta = self.executor.solve_plan(
                    plan, float(lam), S, warm_W=warm_W, output=out_mode
                )
            seconds = time.perf_counter() - t0
            return _result(
                plan, labels, screen_stats, Theta, seconds, self.solver, lam,
                routed=self.executor.route,
                oversize=self.executor.last_oversize,
                assemble_seconds=self.executor.last_assemble_seconds,
                dispatch_seconds=self.executor.last_dispatch_seconds,
            )

    # -- lambda path -------------------------------------------------------

    def run_path(
        self,
        S: np.ndarray,
        lambdas,
        *,
        warm_start: bool = True,
        p_max: int | None = None,
        output: str | None = None,
    ) -> list[GlassoResult]:
        """Descending path: one union-find pass, diffed plans, warm starts.

        Theorem 2 guarantees nested partitions, so (a) the planner can
        snapshot every lambda from a single pass, and (b) the previous Theta
        restricted to a merged component is block-diagonal over its old
        sub-components — a valid PD warm start.  Buckets unchanged between
        consecutive lambdas skip re-padding entirely and warm-start from their
        own previous padded solutions on device."""
        S = _as_cov_operand(S)
        lambdas = list(lambdas)
        with self._trace_ctx(
            "engine.path", n_lams=len(lambdas), p=int(S.shape[0])
        ):
            with span("engine.plan"):
                path = plan_path(
                    S, lambdas, dtype=self.np_dtype,
                    classify_structures=self.executor.route,
                    oversize=self.oversize,
                )
            return self._execute_path(
                S, path, warm_start=warm_start, p_max=p_max, output=output
            )

    def _execute_path(
        self, S, path, *, warm_start: bool, p_max: int | None,
        output: str | None = None,
    ) -> list[GlassoResult]:
        """Run an already-planned path (dense or streamed) through the
        executor with bucket-level reuse and warm starts."""
        from repro.engine.registry import route_for  # local: avoid cycle at import

        results: list[GlassoResult] = []
        prev: GlassoResult | None = None
        out_mode = resolve_output(
            self.output if output is None else output, S.shape[0]
        )
        for step in path.steps:
            schedule_mod.check_capacity(
                [len(c) for b in step.plan.buckets for c in b.comps] or [1], p_max
            )
            warm_W = warm_Theta = None
            if warm_start and prev is not None and self.warm_capable:
                # warm starts only matter for iterative-routed buckets; a
                # closed-form/chordal block is solved directly regardless
                fresh = [
                    b
                    for b in step.plan.buckets
                    if not step.is_reused(b)
                    and (
                        not self.executor.route
                        or route_for(b.structure) in ("iterative", "fused")
                    )
                ]
                if fresh:
                    # the previous Theta rides along untouched: merged
                    # buckets gather their block-diagonal restriction from
                    # it (cross-component entries are exact zeros) and the
                    # executor inverts the gathered stacks batched on device
                    # — no dense (p, p) W is ever built on the host.
                    # theta_warm solvers additionally seed their inner
                    # iterates from the same stack.
                    warm_Theta = prev.Theta
            # selection-layer warm accounting (select.warm.*): one count per
            # solver-bound bucket — iterative/sharded routes only; closed-
            # form and chordal blocks are solved directly either way.
            # "reused" = the bucket resumes from its own previous padded
            # solution, "merged" = a fresh iterative bucket starting from
            # the merged-component blockwise inverse, "cold" = no warm
            # source (first grid point, warm_start=False, a solver outside
            # WARM_START_SOLVERS, or a fresh sharded block).
            warmable = warm_start and prev is not None and self.warm_capable
            for b in step.plan.buckets:
                route = (
                    route_for(b.structure) if self.executor.route else "iterative"
                )
                if route not in ("iterative", "fused", "sharded"):
                    continue
                if warmable and step.is_reused(b):
                    bump("select.warm.reused")
                elif warmable and route in ("iterative", "fused"):
                    bump("select.warm.merged")
                else:
                    bump("select.warm.cold")
            t0 = time.perf_counter()
            with span("engine.solve", lam=float(step.lam)):
                Theta = self.executor.solve_plan(
                    step.plan,
                    step.lam,
                    S,
                    warm_W=warm_W,
                    warm_Theta=warm_Theta,
                    reused_keys=step.reused_keys if warm_start else frozenset(),
                    keep_solutions=warm_start,
                    output=out_mode,
                )
            seconds = time.perf_counter() - t0
            res = _result(
                step.plan, step.labels, step.screen, Theta, seconds, self.solver,
                step.lam, routed=self.executor.route,
                oversize=self.executor.last_oversize,
                assemble_seconds=self.executor.last_assemble_seconds,
                dispatch_seconds=self.executor.last_dispatch_seconds,
            )
            results.append(res)
            prev = res
        return results

    # -- data-matrix input (out-of-core screening) -------------------------

    def run_from_data(
        self,
        X: np.ndarray,
        lam: float,
        *,
        stream=None,
        p_max: int | None = None,
        warm_W: np.ndarray | None = None,
        output: str | None = None,
    ) -> GlassoResult:
        """One solve screened straight from the (n, p) data matrix.

        The dense S never exists: ``repro.stream`` screens tile-by-tile,
        materializes only the per-component blocks, and the solve proceeds
        through the ordinary plan/execute stages (``stream`` takes a
        ``StreamConfig`` or kwargs dict)."""
        from repro.stream import stream_screen

        if stream is None:
            stream = self.stream
        with self._trace_ctx(
            "engine.run", lam=float(lam), p=int(np.shape(X)[1]), source="data"
        ):
            with span("engine.screen", backend="stream"):
                sc = stream_screen(
                    X, [lam], config=stream, oversize=self.oversize
                )
            return self.run(
                sc.S,
                lam,
                labels=sc.labels[0],
                screen_stats=sc.stats[0],
                p_max=p_max,
                warm_W=warm_W,
                output=output,
            )

    def run_path_from_data(
        self,
        X: np.ndarray,
        lambdas,
        *,
        stream=None,
        warm_start: bool = True,
        p_max: int | None = None,
        output: str | None = None,
    ) -> list[GlassoResult]:
        """A descending lambda path screened straight from X: one streaming
        screen covers the whole grid (Theorem 2 — the compacted edges above
        the grid minimum determine every partition), then the standard
        diffed-plan execution runs over materialized blocks."""
        from repro.stream import plan_path_streaming

        if stream is None:
            stream = self.stream
        lambdas = list(lambdas)
        with self._trace_ctx(
            "engine.path", n_lams=len(lambdas), p=int(np.shape(X)[1]),
            source="data",
        ):
            with span("engine.plan", backend="stream"):
                path, sc = plan_path_streaming(
                    X,
                    lambdas,
                    config=stream,
                    dtype=self.np_dtype,
                    classify_structures=self.executor.route,
                    oversize=self.oversize,
                )
            return self._execute_path(
                sc.S, path, warm_start=warm_start, p_max=p_max, output=output
            )

"""Wave packer: cross-bucket megabatching for the fused bucket BCD.

The planner's lifetime bucketing is what makes warm starts exact — every
(size, structure, membership) gets its own bucket so a component's previous
padded solution can follow it along a lambda path.  The price is dispatch
fragmentation: a p=2400 path step can carry a hundred-odd iterative buckets
of a handful of tiny blocks each, and the per-launch host overhead (not the
math) becomes the solve stage (the ``bench_select`` warm-arm anomaly).  This
module re-packs all fused-eligible iterative buckets of one plan step —
across bucket boundaries — into size-binned megabatches and solves each bin
with ONE ``kernels.bucket_glasso`` launch per wave.

Bitwise contract (pinned by tests/test_fused.py):

* **Bin re-padding is exact.**  Re-padding a (s, s) padded block into a
  (bin, bin) slot with an identity diagonal changes no lane's bits: padded
  columns are eq.-(10)-screened no-ops, the cross region stays exactly
  zero, and the extra zeros drop out of every max-reduction.  The ONE
  quantity that would change is the convergence scale ``mean|S - diag S|``
  (denominator s^2 vs bin^2) — so ``bucket_scales`` computes it at the
  SOURCE shape and the kernel takes it as a per-lane input.

* **Warm and cold lanes share one signature.**  Cold lanes synthesize the
  warm pair the solver would have built itself — W0 = S + lam*I (off the
  diagonal S + 0 is exact; the diagonal is reset in-solver either way) and
  Theta0 = I — so a megabatch freely mixes warm and cold source buckets.

* **No launch has leading dim 1.**  XLA specializes unit batch dims (the
  vmap squeezes away and dot codegen changes), making batch-1 results
  differ by 1 ulp from the same lane at batch >= 2 — the only batch-size
  dependence we measured.  ``min_batch2`` duplicates a single lane and
  slices the result; ``compiled_bucket_solver`` applies the same rule to
  UNfused launches, so fused == unfused holds lane-for-lane under ``==``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: padded megabatch sizes — multiples of 8 (TPU sublane) spanning the small
#: iterative tail; a block bigger than the last bin is not fused-eligible
FUSED_BINS = (8, 16, 32, 64)


def fused_bin(size: int) -> int | None:
    """Smallest bin that fits ``size``, or None (too big to fuse)."""
    for b in FUSED_BINS:
        if size <= b:
            return b
    return None


def min_batch2(fn, *args):
    """Call ``fn`` with every arg's leading dim >= 2, slicing back to 1.

    The batch-1 codegen rule above — applied to fused launches here and to
    unfused ones inside ``compiled_bucket_solver``."""
    if args[0].shape[0] != 1:
        return fn(*args)
    doubled = fn(*(jnp.concatenate([a, a]) for a in args))
    if isinstance(doubled, tuple):
        return tuple(o[:1] for o in doubled)
    return doubled[:1]


def bucket_scales(stacked: jax.Array) -> jax.Array:
    """Per-lane convergence scale at the SOURCE bucket shape: (n,) of
    ``mean|S - diag S| + 1e-12`` — what ``glasso_bcd`` would have derived
    for each lane had it been dispatched unfused.  One compiled entry per
    (size, dtype) in the process-global cache; lanes from every bucket of
    a size are batched through one call per wave."""
    from repro.engine.executor import compiled_cached  # local: avoid cycle

    s = stacked.shape[1]
    key = ("__bucket_scales__", int(s), jnp.dtype(stacked.dtype).name)

    def build():
        def one(Sb):
            off = jnp.abs(Sb - jnp.diag(jnp.diag(Sb)))
            return jnp.mean(off) + jnp.asarray(1e-12, Sb.dtype)

        return jax.jit(jax.vmap(one))

    return min_batch2(compiled_cached(key, build), stacked)


def repad_stack(stack: jax.Array, bin_: int, diag) -> jax.Array:
    """(n, s, s) -> (n, bin, bin): zero border, ``diag`` on the padded
    diagonal.  diag=1.0 re-pads S/Theta stacks (identity padding, matching
    ``blocks.pad_block``); diag=1+lam re-pads W stacks (diagonal KKT of the
    padded coordinates, matching ``BucketExecutor._warm_stack``)."""
    n, s, _ = stack.shape
    if s == bin_:
        return stack
    eye = jnp.eye(bin_, dtype=stack.dtype)
    base = jnp.zeros((n, bin_, bin_), stack.dtype) + diag * eye
    return base.at[:, :s, :s].set(stack)


def compiled_fused_solver(bin_: int, dtype, opts_key: tuple):
    """Fetch-or-build the fused megabatch solver for one (bin, dtype, opts).

    Returned callable: fn(blocks, lams, scales, W0, T0) -> (Theta, sweeps),
    all leading dims N.  Cached alongside the unfused executables in the
    process-global compiled cache."""
    from repro.engine.executor import compiled_cached  # local: avoid cycle
    from repro.kernels.bucket_glasso import fused_bcd_stack

    key = ("__fused_bcd__", int(bin_), jnp.dtype(dtype).name, opts_key)

    def build():
        opts = dict(opts_key)

        def run(blocks, lams, scales, W0, T0):
            return fused_bcd_stack(blocks, lams, scales, W0, T0, **opts)

        return run

    return compiled_cached(key, build)

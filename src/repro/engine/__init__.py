"""Plan->Execute engine for the Theorem-1 screening pipeline.

Layers (DESIGN.md):
    registry   screening backends behind one ``backend=`` string + the
               structure -> solver routing ladder + the capability-tagged
               Solver protocol (``SolverSpec``/``register_solver``)
    structure  component subgraph classification (singleton/pair/tree/
               chordal/general, plus the planner-assigned "oversize" class
               behind the mesh-spanning sharded route) feeding the ladder
    planner    incremental lambda-path planning (one union-find pass, diffed
               bucket plans, per-bucket structure tags)
    executor   async multi-device bucket dispatch + process-global compiled
               solver cache + verified closed-form fast paths
    api        the ``Engine`` facade that ``repro.core.glasso`` wraps
"""

from repro.engine.registry import (
    SolverSpec,
    available_cc_backends,
    available_solvers,
    get_cc_backend,
    label_components,
    register_cc_backend,
    register_solver,
    route_for,
    set_route,
    solver_routes,
    solver_spec,
)
from repro.engine.structure import STRUCTURES, classify_component
from repro.engine.planner import (
    PathPlan,
    PathStep,
    bucket_key,
    build_plan_incremental,
    component_lifetimes,
    plan_path,
)
from repro.engine.executor import (
    BucketExecutor,
    compiled_bucket_solver,
    compiled_cache_stats,
)
from repro.engine.api import Engine, GlassoResult
from repro.engine.options import EngineOptions, normalize_options

__all__ = [
    "Engine",
    "EngineOptions",
    "GlassoResult",
    "normalize_options",
    "BucketExecutor",
    "PathPlan",
    "PathStep",
    "STRUCTURES",
    "SolverSpec",
    "available_cc_backends",
    "available_solvers",
    "register_solver",
    "solver_spec",
    "bucket_key",
    "build_plan_incremental",
    "component_lifetimes",
    "classify_component",
    "compiled_bucket_solver",
    "compiled_cache_stats",
    "get_cc_backend",
    "label_components",
    "plan_path",
    "register_cc_backend",
    "route_for",
    "set_route",
    "solver_routes",
]

"""Async bucket executor: place -> dispatch -> (only then) block -> assemble.

Design points, each mapped to a paper/ROADMAP concern:

* **Compiled-solver cache.**  One jitted ``vmap``-ed solver per
  (solver, bucket size, dtype, warm?, opts) key, shared process-wide — a
  lambda path, a benchmark sweep, and every concurrent serving request reuse
  the same executables.  lam is a TRACED per-block vector, so neither a new
  lambda nor a coalesced batch with mixed lambdas recompiles.  Hits/misses are
  counted (``executor.compiled_hit`` / ``executor.compiled_miss``).

* **Async dispatch.**  JAX dispatch is asynchronous; the executor submits
  every bucket of a plan (LPT-placed across local devices when there are
  several — ``schedule.lpt_assign`` with the b^3 cost model, the paper's
  footnote-4 clubbing) and only synchronizes at assembly
  (``jax.block_until_ready`` on the batch of results).  Serial host loops
  around one-bucket-at-a-time ``np.asarray`` calls are gone.

* **Warm-start donation.**  W0 stacks are donated to the solver call on
  backends that support buffer donation (TPU/GPU), so a lambda path does not
  hold two copies of the largest bucket's iterate.

* **Structure-routed solver ladder.**  Each bucket carries the structure
  class the planner assigned (``engine.structure``); ``registry.route_for``
  maps it to a route: "closed_form" (pair/tree — the batched Pallas forest
  kernel plus an in-jit KKT check), "chordal" (host clique-tree direct
  solve), or "iterative" (the configured bcd/pg/admm solver).  Non-iterative
  routes are VERIFIED: the closed forms satisfy the edge KKT by
  construction, but non-edge dual feasibility can fail on adversarial
  matrices, so blocks whose residual exceeds ``route_check_tol`` are
  re-dispatched to the iterative solver (``router.fallback.*`` counters).
  Routing changes cost, never the answer.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks as blocks_mod
from repro.core.instrument import bump, counts, timed_dispatch
from repro.core.schedule import lpt_assign
from repro.obs.trace import span
from repro.core.solvers import SOLVERS, WARM_START_SOLVERS
from repro.core.solvers.closed_form import (
    glasso_chordal_host,
    glasso_forest_stack,
    kkt_ok_stack,
    kkt_residual_host,
)

_CACHE_LOCK = threading.Lock()
_COMPILED: dict[tuple, Any] = {}


def _donate_supported() -> bool:
    return jax.default_backend() not in ("cpu",)


def _validate_solver_opts(solver: str, opts: dict) -> None:
    """Reject unknown solver kwargs up front — inside jit/vmap they surface
    as an opaque TypeError at the first bucket dispatch."""
    import inspect

    try:
        params = inspect.signature(SOLVERS[solver]).parameters
    except (TypeError, ValueError):  # jit wrapper without a signature
        return
    accepted = {
        n for n, p in params.items()
        if p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)
    } - {"S", "lam"}
    unknown = sorted(set(opts) - accepted)
    if unknown:
        raise TypeError(
            f"solver {solver!r} does not accept option(s) {unknown}; "
            f"accepted: {sorted(accepted)}"
        )


def _theta_warm(solver: str) -> bool:
    """Does this solver consume a Theta-side seed alongside W0?  (Spec meta;
    saves the solver re-inverting a W0 the caller derived from a Theta it
    already held.)"""
    from repro.core.solvers import solver_spec

    return bool(solver_spec(solver).meta.get("theta_warm"))


def compiled_bucket_solver(
    solver: str, size: int, dtype, *, warm: bool, warm_theta: bool = False,
    opts_key: tuple = ()
):
    """Fetch-or-build the jitted batched solver for one bucket shape family.

    Signature of the returned callable:
        fn(blocks[n,size,size], lams[n])                 warm=False
        fn(blocks[n,size,size], lams[n], W0[n,...])      warm=True (W0 donated
                                                         off-CPU)
        fn(blocks[n,size,size], lams[n], W0, Theta0)     warm_theta=True too —
                                                         solvers whose spec
                                                         consumes the Theta
                                                         seed directly

    Every returned callable enforces the MIN-BATCH-2 rule (``waves.
    min_batch2``): a single-lane stack is duplicated to 2 and the result
    sliced back, because XLA specializes away unit batch dims and the
    resulting codegen differs from the same lane at batch >= 2 by 1 ulp.
    Pinning every launch to batch >= 2 is what makes results independent of
    batch size — the invariant the wave packer's bitwise fused == unfused
    equality stands on.
    """
    key = (
        solver, int(size), jnp.dtype(dtype).name, bool(warm), bool(warm_theta),
        opts_key,
    )
    with _CACHE_LOCK:
        fn = _COMPILED.get(key)
        if fn is not None:
            bump("executor.compiled_hit")
            return fn
        bump("executor.compiled_miss")
        from repro.engine.waves import min_batch2  # local: avoid cycle

        solver_fn = SOLVERS[solver]
        opts = dict(opts_key)
        if warm and warm_theta:

            def run(blocks, lams, W0, T0):
                return jax.vmap(
                    lambda Sb, lm, w0, t0: solver_fn(
                        Sb, lm, W0=w0, Theta0=t0, **opts
                    )
                )(blocks, lams, W0, T0)

            jitted = jax.jit(run, donate_argnums=(2,) if _donate_supported() else ())
        elif warm:

            def run(blocks, lams, W0):
                return jax.vmap(
                    lambda Sb, lm, w0: solver_fn(Sb, lm, W0=w0, **opts)
                )(blocks, lams, W0)

            jitted = jax.jit(run, donate_argnums=(2,) if _donate_supported() else ())
        else:

            def run(blocks, lams):
                return jax.vmap(lambda Sb, lm: solver_fn(Sb, lm, **opts))(
                    blocks, lams
                )

            jitted = jax.jit(run)

        def fn(*args, _jitted=jitted):
            return min_batch2(_jitted, *args)

        _COMPILED[key] = fn
        return fn


def compiled_closed_form(size: int, dtype, *, tol: float, verify: bool = True):
    """Fetch-or-build the jitted batched closed-form forest solver + verifier.

    Returned callable: fn(blocks[n,size,size], lams[n]) -> (Theta[n,...],
    ok[n]) where ok certifies the KKT residual within tol (scaled by max|S|).
    ``verify=False`` skips the batched-inverse check and returns ok=True —
    sound ONLY for the "pair" class, where the closed form has no non-edge
    dual constraints to violate (a 2x2 support is complete), so it is exact
    by construction.  Shares the process-global compiled cache with the
    iterative solvers, so serving, paths, and benchmarks reuse one
    executable per (size, dtype)."""
    key = (
        "__closed_form__", int(size), jnp.dtype(dtype).name, float(tol), verify
    )
    with _CACHE_LOCK:
        fn = _COMPILED.get(key)
        if fn is not None:
            bump("executor.compiled_hit")
            return fn
        bump("executor.compiled_miss")

        def run(blocks, lams):
            thetas = glasso_forest_stack(blocks, lams)
            if verify:
                ok = kkt_ok_stack(blocks, lams, thetas, tol=tol)
            else:
                ok = jnp.ones(blocks.shape[0], dtype=bool)
            return thetas, ok

        fn = jax.jit(run)
        _COMPILED[key] = fn
        return fn


def dispatch_repair(
    solver: str,
    dtype,
    opts_key: tuple,
    size: int,
    blocks: np.ndarray,
    lams: np.ndarray,
    candidates,
):
    """Async re-dispatch of rejected fast-path blocks to the iterative tail.

    Shared by the executor and the serving batcher so repairs behave
    identically everywhere: the rejected candidate is PD (the KKT check
    treats non-PD as an infinite residual), just dual-infeasible — so its
    inverse is an excellent W iterate to warm-start from, typically cutting
    the repair to a few sweeps.  ``lams`` is per-block (serving repairs can
    mix lambdas)."""
    sub = jnp.asarray(np.asarray(blocks), dtype)
    lams_d = jnp.asarray(np.asarray(lams), dtype)
    warm = solver in WARM_START_SOLVERS
    theta_warm = warm and _theta_warm(solver)
    W0 = T0 = None
    if warm:
        cand = jnp.asarray(np.asarray(candidates), dtype)
        W0 = jnp.linalg.inv(cand)
        # a candidate can be rejected BECAUSE it is singular: those rows
        # get the cold start W = S + lam*I instead of a NaN iterate
        finite = jnp.all(jnp.isfinite(W0), axis=(1, 2), keepdims=True)
        cold = sub + lams_d[:, None, None] * jnp.eye(size, dtype=dtype)
        W0 = jnp.where(finite, W0, cold)
        if theta_warm:
            # the candidate IS the Theta seed — passing it spares the solver
            # inverting W0 right back (a second O(size^3) for nothing);
            # fallen-back rows get the matching cold Theta seed
            eye = jnp.eye(size, dtype=bool)
            diag = jnp.diagonal(sub, axis1=1, axis2=2)
            cold_T = jnp.where(
                eye[None], (1.0 / (diag + lams_d[:, None]))[:, :, None], 0.0
            )
            T0 = jnp.where(finite, cand, cold_T)
    fn = compiled_bucket_solver(
        solver, size, dtype, warm=warm, warm_theta=theta_warm, opts_key=opts_key
    )
    bump("executor.dispatches")
    if theta_warm:
        out, _ = timed_dispatch(fn, sub, lams_d, W0, T0)
    elif warm:
        out, _ = timed_dispatch(fn, sub, lams_d, W0)
    else:
        out, _ = timed_dispatch(fn, sub, lams_d)
    return out


def solve_sharded_bucket(
    bucket: blocks_mod.Bucket,
    lams: np.ndarray,
    S,
    *,
    solver: str,
    dtype,
    opts_key: tuple,
    tol: float,
    warm_thetas: list | None = None,
) -> tuple[np.ndarray, dict]:
    """Mesh-spanning solve of one oversize bucket (route "sharded").

    Per block: shard-direct gather (``stream.materialize.shard_gather`` —
    the (b, b) block streams row-chunk by row-chunk into device shards, a
    full host copy never exists), the sharded ADMM
    (``core.solvers.glasso_sharded``), and its distributed KKT verdict.
    Blocks whose residual exceeds ``tol * max(1, max|S|)`` fall back to a
    SINGLE-DEVICE iterative solve warm-started from the rejected candidate
    (the shared ``dispatch_repair``) — correct, but memory-bound, so it is
    counted loudly: ``solver.oversize.fallbacks`` + ``router.fallback.
    oversize``.  Returns (padded (n, size, size) Theta stack, info dict
    {dispatched, inner_iters, fallbacks} for ``GlassoResult.oversize``).

    Shared by the engine executor and the serving batcher, like
    ``dispatch_repair`` — oversize admission behaves identically everywhere.
    """
    from repro.core.jax_compat import local_device_mesh
    from repro.core.solvers.sharded import glasso_sharded
    from repro.stream.materialize import shard_gather

    mesh = local_device_mesh()
    np_dtype = np.dtype(jnp.dtype(dtype).name)
    n = len(bucket.comps)
    out = np.zeros((n, bucket.size, bucket.size), dtype=np_dtype)
    info = {"dispatched": 0, "inner_iters": 0, "fallbacks": 0}
    failed: list[int] = []
    for i, comp in enumerate(bucket.comps):
        b = len(comp)
        lam = float(lams[i])
        S_sh = shard_gather(S, comp, mesh, dtype=np_dtype)
        theta0 = None if warm_thetas is None else warm_thetas[i]
        res, _ = timed_dispatch(
            glasso_sharded, S_sh, lam, mesh=mesh, b=b, Theta0=theta0,
            kkt_target=tol,
        )
        info["dispatched"] += 1
        info["inner_iters"] += res.inner_iters
        padded = np.eye(bucket.size, dtype=np_dtype) / (1.0 + lam)
        padded[:b, :b] = res.Theta
        out[i] = padded
        scale = max(1.0, res.s_max)
        if not res.kkt_residual <= tol * scale:  # NaN-safe: not (nan <= x)
            failed.append(i)
    if failed:
        idx = np.asarray(failed)
        info["fallbacks"] = int(idx.size)
        bump("solver.oversize.fallbacks", int(idx.size))
        bump(f"router.fallback.{bucket.structure}", int(idx.size))
        blocks = np.stack(
            [
                blocks_mod.pad_block(
                    blocks_mod.gather_submatrix(
                        S, bucket.comps[k], dtype=np_dtype
                    ),
                    bucket.size,
                )
                for k in idx
            ]
        )
        fixed = dispatch_repair(
            solver, dtype, opts_key, bucket.size, blocks,
            np.asarray(lams)[idx], out[idx],
        )
        out[idx] = np.asarray(jax.block_until_ready(fixed))
    return out, info


def solve_chordal_bucket(
    bucket: blocks_mod.Bucket, lams: np.ndarray, *, tol: float
) -> tuple[np.ndarray, np.ndarray]:
    """Host clique-tree direct solve of one chordal bucket.

    Returns (padded Theta stack, per-block ok).  Cost is sum |C|^3 over
    maximal cliques per block — the chordal analog of the zero-fill sparse
    Cholesky — versus hundreds of O(size^3) iterations on the iterative
    path.  Verification failures are left to the caller's fallback."""
    n = bucket.blocks.shape[0]
    thetas = np.empty_like(np.asarray(bucket.blocks))
    ok = np.zeros(n, dtype=bool)
    for i, comp in enumerate(bucket.comps):
        b = len(comp)
        lam = float(lams[i])
        blk = np.asarray(bucket.blocks[i][:b, :b])
        padded = np.eye(bucket.size, dtype=thetas.dtype) / (1.0 + lam)
        try:
            theta = glasso_chordal_host(blk, lam)
            res = kkt_residual_host(blk, lam, theta)
            scale = max(1.0, float(np.abs(blk).max()))
            ok[i] = res <= tol * scale
            padded[:b, :b] = theta
        except (ValueError, np.linalg.LinAlgError):
            ok[i] = False
        thetas[i] = padded
    return thetas, ok


def compiled_cached(key: tuple, builder):
    """Fetch-or-build an arbitrary executable in the process-global compiled
    cache (hit/miss counted like every other entry).  The extension point
    the JOINT executor uses: its bucket keys gain the class count K and the
    penalty, but the cache, its lock, and its stats stay one thing — a
    serving mix of single-class and joint requests shares one steady
    state."""
    with _CACHE_LOCK:
        fn = _COMPILED.get(key)
        if fn is not None:
            bump("executor.compiled_hit")
            return fn
        bump("executor.compiled_miss")
        fn = builder()
        _COMPILED[key] = fn
        return fn


def compiled_cache_stats() -> dict[str, int]:
    return {
        "entries": len(_COMPILED),
        "hits": counts().get("executor.compiled_hit", 0),
        "misses": counts().get("executor.compiled_miss", 0),
    }


@dataclass
class _Pending:
    bucket: blocks_mod.Bucket
    out: Any                       # jax array (device routes) or np (chordal)
    ok: Any = None                 # per-block KKT flags for verified routes
    stacked: Any = None            # device input stack (reuse cache)
    key: tuple = ()
    repair: Any = None             # (row idx, in-flight iterative re-solve)


@dataclass
class _FusedLane:
    """One fused-eligible bucket deferred into a (device, bin) megabatch.

    The wave packer collects these during the bucket loop and launches one
    ``kernels.bucket_glasso`` call per group; ``pending.out`` receives the
    lane's (n, size, size) slice of the packed result."""

    pending: _Pending
    size: int                      # source bucket size (bin >= size)
    n: int                         # blocks in the bucket
    lams: Any                      # (n,) device lambda vector
    W0: Any = None                 # warm covariance stack or None (cold)
    T0: Any = None                 # warm Theta stack or None (cold)
    scales: Any = None             # (n,) source-shape convergence scales


@dataclass
class BucketExecutor:
    """Solves plans; owns the per-path warm-start state.

    One instance per logical stream of related solves (a ``glasso`` call, a
    ``glasso_path``, one serving batch); the compiled cache underneath is
    global."""

    solver: str = "bcd"
    dtype: Any = jnp.float64
    solver_opts: dict = field(default_factory=dict)
    devices: list | None = None
    route: bool = True             # structure-routed ladder; False = PR-1 path
    route_check_tol: float = 1e-6  # KKT acceptance for closed-form candidates
    # wave packer: fuse all small iterative buckets of a plan step into one
    # bucket_glasso launch per size bin (resolved to a bool by the Engine
    # from EngineOptions.fused; buckets routed "fused" fuse regardless)
    fused: bool = False
    # EngineOptions(trace="jax"): wrap each solve_plan dispatch wave in a
    # jax.profiler.TraceAnnotation so device-side profiler timelines line
    # up with the host span tree
    jax_annotations: bool = False
    # bucket_key -> previous padded solution / input stacks (device arrays):
    # reused buckets warm-start from their own previous solution and skip the
    # host->device re-upload of their bit-identical padded blocks.
    _prev_solutions: dict = field(default_factory=dict)
    _prev_blocks: dict = field(default_factory=dict)
    # oversize accounting of the MOST RECENT solve_plan call (dispatched /
    # inner_iters / fallbacks) — surfaced as GlassoResult.oversize
    last_oversize: dict = field(default_factory=dict)
    # assembly-stage seconds of the MOST RECENT solve_plan call — surfaced
    # as GlassoResult.assemble_seconds (process-wide: engine.assemble_us)
    last_assemble_seconds: float = 0.0
    # host seconds spent ISSUING async dispatches (closed-form, iterative,
    # fused, repairs) in the MOST RECENT solve_plan call — surfaced as
    # GlassoResult.dispatch_seconds so the launch overhead the wave packer
    # targets is attributed to its own stage, not folded into solve time
    last_dispatch_seconds: float = 0.0

    def __post_init__(self):
        from repro.core.solvers import solver_spec
        from repro.engine.waves import FUSED_BINS

        if self.solver not in SOLVERS:
            raise ValueError(
                f"unknown solver {self.solver!r}; available: {sorted(SOLVERS)}"
            )
        _validate_solver_opts(self.solver, self.solver_opts)
        if self.devices is None:
            self.devices = list(jax.local_devices())
        self._opts_key = tuple(sorted(self.solver_opts.items()))
        # fused eligibility: the solver must declare the fused_stack
        # capability AND every solver opt must be one the fused kernel
        # replays (anything else would silently change the packed solve)
        meta = solver_spec(self.solver).meta
        self._max_fused = int(meta.get("max_fused_size", FUSED_BINS[-1]))
        self._fused_capable = bool(meta.get("fused_stack")) and set(
            self.solver_opts
        ) <= {"max_sweeps", "n_cd", "tol", "node_screen"}

    # -- placement ---------------------------------------------------------

    def _bucket_cost(self, bucket: blocks_mod.Bucket) -> float:
        """Estimated DEVICE solve cost of one bucket: count x size^3 scaled
        by the structure class's route, not just padded size.  A chordal
        bucket solves on the HOST (zero device time — placing it as if it
        cost n*b^3 starves a device for nothing), a closed-form bucket is
        one fused elementwise pass (~b^2 per block), only the iterative tail
        actually pays b^3-per-sweep on its device.  Sharded buckets span the
        whole mesh and are not LPT-placed at all (cost 0 here; their device
        time is accounted by the sharded dispatch itself)."""
        from repro.engine.registry import route_for  # local: avoid cycle

        route = route_for(bucket.structure) if self.route else "iterative"
        n = len(bucket.comps)
        if route in ("chordal", "sharded", "assemble"):
            return 0.0
        if route == "closed_form":
            return n * float(bucket.size) ** 2
        return n * float(bucket.size) ** 3

    def _place(
        self, buckets: list[blocks_mod.Bucket], priorities=None
    ) -> list:
        """LPT assignment of buckets to local devices by estimated cost.

        ``priorities`` (per-bucket, higher = more urgent) seats urgent
        buckets first — the serving control plane passes its SLO class
        through here so an interactive request's buckets dispatch ahead of
        best-effort co-travellers on every device queue."""
        if len(self.devices) <= 1 or not buckets:
            return [None] * len(buckets)
        cost = [self._bucket_cost(b) for b in buckets]
        assign = lpt_assign(
            cost, len(self.devices), cost=float, priorities=priorities
        )
        return [self.devices[w] for w in assign.worker_of]

    # -- warm starts -------------------------------------------------------

    def _warm_stack(
        self,
        bucket: blocks_mod.Bucket,
        key,
        lam: float,
        warm_W: np.ndarray | None,
        warm_Theta: np.ndarray | None = None,
    ):
        """(W0 stack, Theta0 stack or None) for one bucket, or (None, None).

        Reused bucket with a cached previous solution: W0 = inv(prev Theta)
        batched on device (the padded block of Theta is blkdiag, so its
        inverse's padded diagonal is finite; it is then reset to 1+lam), and
        the previous Theta itself rides along as the Theta0 seed for solvers
        whose spec consumes it (no second inversion inside the solver).
        Merged/fresh buckets prefer ``warm_Theta`` (the previous solution
        itself, dense or block-sparse — its cross-component entries are exact
        zeros, so each gathered restriction is the Theorem-2 block-diagonal
        PD warm start): the Theta stack is gathered once and W0 = inv(T0) is
        computed batched on device, so no dense (p, p) W ever exists on the
        host.  ``warm_W`` remains the fallback for callers that hold a W
        iterate but no Theta (the single-solve ``warm_W=`` API) — no Theta
        stack there."""
        T0 = None
        prev = self._prev_solutions.get(key)
        np_dtype = np.dtype(jnp.dtype(self.dtype).name)
        if prev is not None:
            prev = jnp.asarray(prev, self.dtype)
            W0 = jnp.linalg.inv(prev)
            T0 = prev
        elif warm_Theta is not None:
            tstacks = [
                blocks_mod.pad_block(
                    blocks_mod.gather_submatrix(warm_Theta, c, dtype=np_dtype),
                    bucket.size,
                )
                for c in bucket.comps
            ]
            T0 = jnp.asarray(np.stack(tstacks), self.dtype)
            # padded T0 diagonal is the identity (pad_block), so the batched
            # inverse is finite; the padded W diagonal is reset below anyway
            W0 = jnp.linalg.inv(T0)
        elif warm_W is not None:
            # gather through the protocol: warm_W may be a dense array or a
            # block-sparse previous result (whose cross-component entries
            # are exact zeros — the merged-component block-diagonal restriction)
            stacks = [
                blocks_mod.pad_block(
                    blocks_mod.gather_submatrix(warm_W, c, dtype=np_dtype),
                    bucket.size,
                )
                for c in bucket.comps
            ]
            W0 = jnp.asarray(np.stack(stacks), self.dtype)
        else:
            return None, None
        # padded diagonal of a W iterate must be 1 + lam (diagonal KKT)
        idx = jnp.arange(bucket.size)
        pad_mask = jnp.stack(
            [idx >= len(c) for c in bucket.comps]
        )  # (n, size) True on padded coords
        eye = jnp.eye(bucket.size, dtype=bool)
        fix = pad_mask[:, :, None] & eye[None, :, :]
        W0 = jnp.where(fix, jnp.asarray(1.0 + lam, W0.dtype), W0)
        off = pad_mask[:, :, None] ^ pad_mask[:, None, :]
        return jnp.where(off, jnp.zeros((), W0.dtype), W0), T0

    # -- solve -------------------------------------------------------------

    def solve_plan(
        self,
        plan: blocks_mod.Plan,
        lam: float,
        S: np.ndarray,
        *,
        warm_W: np.ndarray | None = None,
        warm_Theta: np.ndarray | None = None,
        reused_keys: frozenset = frozenset(),
        keep_solutions: bool = False,
        output: str = "dense",
        priorities=None,
    ) -> np.ndarray:
        """Dispatch all buckets, then assemble Theta.

        ``priorities`` (optional, per-bucket, higher = more urgent) makes
        the multi-device placement priority-aware — see ``_place``.

        ``output="sparse"`` hands the per-bucket solution stacks to
        ``blocks.assemble_sparse`` — the result is a ``SparseTheta`` built
        on zero-copy views of those stacks, and no (p, p) buffer is ever
        allocated; ``"dense"`` (default) scatters into the global matrix as
        before.

        ``reused_keys`` marks buckets whose padded arrays were carried over by
        the planner; their previous solutions (if retained via
        ``keep_solutions``) seed the warm start without touching the host.

        Routing ladder: buckets take the route their structure class maps to
        (``registry.route_for``), every non-iterative candidate is
        KKT-verified, and failures are re-dispatched to the iterative solver
        before assembly — see ``_verify_and_fallback``."""
        if self.jax_annotations:
            from jax.profiler import TraceAnnotation

            with TraceAnnotation("glasso.solve_plan"):
                return self._solve_plan(
                    plan, lam, S, warm_W=warm_W, warm_Theta=warm_Theta,
                    reused_keys=reused_keys, keep_solutions=keep_solutions,
                    output=output, priorities=priorities,
                )
        return self._solve_plan(
            plan, lam, S, warm_W=warm_W, warm_Theta=warm_Theta,
            reused_keys=reused_keys, keep_solutions=keep_solutions,
            output=output, priorities=priorities,
        )

    def _solve_plan(
        self,
        plan: blocks_mod.Plan,
        lam: float,
        S: np.ndarray,
        *,
        warm_W: np.ndarray | None = None,
        warm_Theta: np.ndarray | None = None,
        reused_keys: frozenset = frozenset(),
        keep_solutions: bool = False,
        output: str = "dense",
        priorities=None,
    ) -> np.ndarray:
        from repro.engine.planner import bucket_key  # local: avoid cycle at import
        from repro.engine.registry import route_for  # local: avoid cycle at import

        from repro.engine.waves import fused_bin

        if self.route and len(plan.isolated):
            bump("router.route.singleton", int(len(plan.isolated)))
        self.last_oversize = {}
        self.last_dispatch_seconds = 0.0
        placements = self._place(plan.buckets, priorities=priorities)
        pending: list[_Pending] = []
        sharded_pending: list[_Pending] = []
        fused_groups: dict[tuple, list[_FusedLane]] = {}
        for bucket, device in zip(plan.buckets, placements):
            key = bucket_key(bucket)
            n = len(bucket.comps)
            route = route_for(bucket.structure) if self.route else "iterative"
            if self.route:
                bump(f"router.route.{bucket.structure}", n)
            if route == "sharded":
                # mesh-spanning blocking solve: queued after the async small
                # buckets below so their dispatches are in flight first
                p = _Pending(bucket=bucket, out=None, key=key)
                pending.append(p)
                sharded_pending.append(p)
                continue
            if route == "chordal":
                # host direct solve: no device round-trip for the candidate.
                # KKT failures are known IMMEDIATELY (host), so their repair
                # dispatches into the same async wave as everything else
                # instead of serializing after the barrier.
                (out, ok), _ = timed_dispatch(
                    solve_chordal_bucket,
                    bucket, np.full(n, lam), tol=self.route_check_tol,
                )
                p = _Pending(bucket=bucket, out=out, ok=None, key=key)
                if not ok.all():
                    idx = np.flatnonzero(~ok)
                    bump(f"router.fallback.{bucket.structure}", int(idx.size))
                    p.repair = self._dispatch_repair(bucket, idx, out[idx], lam)
                pending.append(p)
                continue
            stacked = self._prev_blocks.get(key) if key in reused_keys else None
            if stacked is None:
                stacked = jnp.asarray(bucket.blocks, self.dtype)
                if device is not None:
                    stacked = jax.device_put(stacked, device)
            elif device is not None and list(stacked.devices()) != [device]:
                # LPT may move a reused bucket between lambdas; a D2D copy
                # still beats re-uploading from host
                stacked = jax.device_put(stacked, device)
            lams = jnp.full((n,), lam, self.dtype)
            if device is not None:
                lams = jax.device_put(lams, device)
            if route == "closed_form":
                fn = compiled_closed_form(
                    bucket.size,
                    self.dtype,
                    tol=self.route_check_tol,
                    verify=bucket.structure != "pair",
                )
                (theta, ok), dt = timed_dispatch(fn, stacked, lams)
                self.last_dispatch_seconds += dt
                bump("executor.dispatches")
                pending.append(
                    _Pending(bucket=bucket, out=theta, ok=ok, stacked=stacked, key=key)
                )
                continue
            if self.solver in WARM_START_SOLVERS:
                use_key = key if key in reused_keys else None
                W0, T0 = self._warm_stack(
                    bucket, use_key, lam, warm_W, warm_Theta
                )
            else:
                W0 = T0 = None  # solver discards W0: skip the inversions
            if not (T0 is not None and _theta_warm(self.solver)):
                T0 = None
            if device is not None and W0 is not None:
                W0 = jax.device_put(W0, device)
                if T0 is not None:
                    T0 = jax.device_put(T0, device)
            fuse = (
                route == "fused" or (route == "iterative" and self.fused)
            ) and self._fused_capable and bucket.size <= self._max_fused
            bin_ = fused_bin(bucket.size) if fuse else None
            if bin_ is not None:
                # wave packer: defer into the (device, bin) megabatch — the
                # launch happens once per group after this loop
                p = _Pending(bucket=bucket, out=None, stacked=stacked, key=key)
                pending.append(p)
                fused_groups.setdefault((device, bin_), []).append(
                    _FusedLane(
                        pending=p, size=bucket.size, n=n, lams=lams,
                        W0=W0, T0=T0,
                    )
                )
                continue
            fn = compiled_bucket_solver(
                self.solver,
                bucket.size,
                self.dtype,
                warm=W0 is not None,
                warm_theta=T0 is not None,
                opts_key=self._opts_key,
            )
            if T0 is not None:
                out, dt = timed_dispatch(fn, stacked, lams, W0, T0)
            elif W0 is not None:
                out, dt = timed_dispatch(fn, stacked, lams, W0)
            else:
                out, dt = timed_dispatch(fn, stacked, lams)
            self.last_dispatch_seconds += dt
            bump("executor.dispatches")
            pending.append(_Pending(bucket=bucket, out=out, stacked=stacked, key=key))

        fused_sweeps = self._dispatch_fused(fused_groups, lam)

        # oversize buckets: mesh-spanning sharded solves, one blocking call
        # per giant block, while the small async dispatches above are already
        # in flight.  Warm start: a bucket reused from the previous lambda
        # seeds Theta0 from its own previous padded solution (the dense
        # warm_W path would require inverting a giant block on the host —
        # exactly the allocation the route avoids).
        totals = {"dispatched": 0, "inner_iters": 0, "fallbacks": 0}
        for p in sharded_pending:
            bucket = p.bucket
            prev = (
                self._prev_solutions.get(p.key) if p.key in reused_keys else None
            )
            warm_thetas = None
            if prev is not None:
                prev = np.asarray(prev)
                warm_thetas = [
                    prev[i][: len(c), : len(c)]
                    for i, c in enumerate(bucket.comps)
                ]
            n = len(bucket.comps)
            p.out, info = solve_sharded_bucket(
                bucket,
                np.full(n, lam),
                S,
                solver=self.solver,
                dtype=self.dtype,
                opts_key=self._opts_key,
                tol=self.route_check_tol,
                warm_thetas=warm_thetas,
            )
            for k in totals:
                totals[k] += info[k]
        if totals["dispatched"]:
            self.last_oversize = totals

        # single synchronization point: everything above was async dispatch
        with span("engine.barrier"):
            jax.block_until_ready(
                [p.out for p in pending if isinstance(p.out, jax.Array)]
                + [p.repair[1] for p in pending if p.repair is not None]
            )
        for sw in fused_sweeps:
            # per-launch sweeps are ready (same barrier); the saving is what
            # the megabatch's slowest lane would have cost every other lane
            # had they iterated in lockstep without in-kernel early exit
            sw = np.asarray(sw)
            if sw.size:
                bump(
                    "solver.fused.lockstep_sweeps_saved",
                    int(sw.max()) * int(sw.size) - int(sw.sum()),
                )
        for p in pending:
            if p.repair is not None:
                idx, fixed = p.repair
                p.out = np.array(p.out)
                p.out[idx] = np.asarray(fixed)
        self._verify_and_fallback(pending, lam)

        new_solutions: dict = {}
        new_blocks: dict = {}
        if keep_solutions:
            for p in pending:
                new_solutions[p.key] = p.out
                if p.stacked is not None:
                    new_blocks[p.key] = p.stacked
        self._prev_solutions = new_solutions
        self._prev_blocks = new_blocks
        t0 = time.perf_counter()
        with span("engine.assemble", output=output):
            sols = [np.asarray(p.out) for p in pending]
            if output == "sparse":
                Theta = blocks_mod.assemble_sparse(plan, sols, S)
            else:
                Theta = blocks_mod.assemble_dense(plan, sols, S)
        self.last_assemble_seconds = time.perf_counter() - t0
        bump("engine.assemble_us", int(self.last_assemble_seconds * 1e6))
        return Theta

    def _dispatch_fused(
        self, groups: dict[tuple, list[_FusedLane]], lam: float
    ) -> list:
        """Launch every (device, bin) megabatch: ONE fused solver call per
        group per wave, scattered back into each lane's ``pending.out``.

        Packing is bitwise-transparent (see ``engine.waves``): blocks re-pad
        with an identity diagonal, warm W stacks with 1+lam (the diagonal
        KKT of padded coordinates, matching ``_warm_stack``), cold lanes
        synthesize the pair the solver would have built (W0 = S + lam*I,
        Theta0 = I), and each lane's convergence scale is computed at its
        SOURCE shape — one batched launch per (device, size) — so packing
        changes which executable runs, never any lane's tolerance or bits.
        Returns the per-launch sweep-count arrays (read after the barrier
        for ``solver.fused.lockstep_sweeps_saved``)."""
        if not groups:
            return []
        from repro.engine.waves import (
            bucket_scales,
            compiled_fused_solver,
            min_batch2,
            repad_stack,
        )

        by_size: dict[tuple, list[_FusedLane]] = {}
        for (device, _), lanes in groups.items():
            for ln in lanes:
                by_size.setdefault((device, ln.size), []).append(ln)
        for lanes in by_size.values():
            stacks = (
                lanes[0].pending.stacked
                if len(lanes) == 1
                else jnp.concatenate([ln.pending.stacked for ln in lanes])
            )
            scales = bucket_scales(stacks)
            off = 0
            for ln in lanes:
                ln.scales = scales[off:off + ln.n]
                off += ln.n

        lam_c = jnp.asarray(lam, self.dtype)
        one = jnp.ones((), self.dtype)
        sweeps_out = []
        for (device, bin_), lanes in sorted(
            groups.items(), key=lambda kv: (str(kv[0][0]), kv[0][1])
        ):
            blk_p, lam_p, sc_p, w_p, t_p = [], [], [], [], []
            for ln in lanes:
                stacked = ln.pending.stacked
                blk_p.append(repad_stack(stacked, bin_, one))
                lam_p.append(ln.lams)
                sc_p.append(ln.scales)
                if ln.W0 is None:
                    # cold init at SOURCE shape — off-diagonal S + 0 is
                    # exact; the diagonal is reset in-solver either way
                    w = stacked + lam_c * jnp.eye(ln.size, dtype=self.dtype)
                else:
                    w = ln.W0
                w_p.append(repad_stack(w, bin_, one + lam_c))
                if ln.T0 is None:
                    t = jnp.zeros((ln.n, bin_, bin_), self.dtype) + jnp.eye(
                        bin_, dtype=self.dtype
                    )
                else:
                    t = repad_stack(ln.T0, bin_, one)
                t_p.append(t)

            def cat(xs):
                return xs[0] if len(xs) == 1 else jnp.concatenate(xs)

            fn = compiled_fused_solver(bin_, self.dtype, self._opts_key)
            (theta, sweeps), dt = timed_dispatch(
                min_batch2, fn, cat(blk_p), cat(lam_p), cat(sc_p),
                cat(w_p), cat(t_p),
            )
            self.last_dispatch_seconds += dt
            bump("executor.dispatches")
            bump("solver.fused.dispatches")
            bump("solver.fused.blocks_packed", sum(ln.n for ln in lanes))
            off = 0
            for ln in lanes:
                ln.pending.out = theta[off:off + ln.n, :ln.size, :ln.size]
                off += ln.n
            sweeps_out.append(sweeps)
        return sweeps_out

    def _dispatch_repair(
        self, bucket: blocks_mod.Bucket, idx: np.ndarray, candidates, lam: float
    ):
        """Bucket-shaped wrapper over the shared ``dispatch_repair``."""
        t0 = time.perf_counter()
        out = dispatch_repair(
            self.solver,
            self.dtype,
            self._opts_key,
            bucket.size,
            np.asarray(bucket.blocks)[idx],
            np.full(int(idx.size), lam),
            candidates,
        )
        self.last_dispatch_seconds += time.perf_counter() - t0
        return (idx, out)

    def _verify_and_fallback(self, pending: list[_Pending], lam: float) -> None:
        """Re-dispatch every closed-form block whose KKT check failed to the
        iterative solver (the ladder's tail) and splice the repaired rows
        into the pending stacks.  Rare by design — the fast-path classes
        satisfy the KKT by construction except for non-edge dual feasibility
        on adversarial matrices — but this is what makes routing SAFE."""
        repairs = []
        for p in pending:
            if p.ok is None:
                continue
            ok = np.asarray(p.ok)
            if ok.all():
                continue
            idx = np.flatnonzero(~ok)
            bump(f"router.fallback.{p.bucket.structure}", int(idx.size))
            repairs.append((p, self._dispatch_repair(p.bucket, idx, np.asarray(p.out)[idx], lam)))
        if not repairs:
            return
        jax.block_until_ready([r[1][1] for r in repairs])
        for p, (idx, fixed) in repairs:
            out = np.array(p.out)  # copy: np.asarray of a jax array is read-only
            out[idx] = np.asarray(fixed)
            p.out = out

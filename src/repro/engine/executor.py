"""Async bucket executor: place -> dispatch -> (only then) block -> assemble.

Design points, each mapped to a paper/ROADMAP concern:

* **Compiled-solver cache.**  One jitted ``vmap``-ed solver per
  (solver, bucket size, dtype, warm?, opts) key, shared process-wide — a
  lambda path, a benchmark sweep, and every concurrent serving request reuse
  the same executables.  lam is a TRACED per-block vector, so neither a new
  lambda nor a coalesced batch with mixed lambdas recompiles.  Hits/misses are
  counted (``executor.compiled_hit`` / ``executor.compiled_miss``).

* **Async dispatch.**  JAX dispatch is asynchronous; the executor submits
  every bucket of a plan (LPT-placed across local devices when there are
  several — ``schedule.lpt_assign`` with the b^3 cost model, the paper's
  footnote-4 clubbing) and only synchronizes at assembly
  (``jax.block_until_ready`` on the batch of results).  Serial host loops
  around one-bucket-at-a-time ``np.asarray`` calls are gone.

* **Warm-start donation.**  W0 stacks are donated to the solver call on
  backends that support buffer donation (TPU/GPU), so a lambda path does not
  hold two copies of the largest bucket's iterate.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks as blocks_mod
from repro.core.instrument import bump, counts
from repro.core.schedule import lpt_assign
from repro.core.solvers import SOLVERS, WARM_START_SOLVERS

_CACHE_LOCK = threading.Lock()
_COMPILED: dict[tuple, Any] = {}


def _donate_supported() -> bool:
    return jax.default_backend() not in ("cpu",)


def _validate_solver_opts(solver: str, opts: dict) -> None:
    """Reject unknown solver kwargs up front — inside jit/vmap they surface
    as an opaque TypeError at the first bucket dispatch."""
    import inspect

    try:
        params = inspect.signature(SOLVERS[solver]).parameters
    except (TypeError, ValueError):  # jit wrapper without a signature
        return
    accepted = {
        n for n, p in params.items()
        if p.kind in (p.KEYWORD_ONLY, p.POSITIONAL_OR_KEYWORD)
    } - {"S", "lam"}
    unknown = sorted(set(opts) - accepted)
    if unknown:
        raise TypeError(
            f"solver {solver!r} does not accept option(s) {unknown}; "
            f"accepted: {sorted(accepted)}"
        )


def compiled_bucket_solver(
    solver: str, size: int, dtype, *, warm: bool, opts_key: tuple = ()
):
    """Fetch-or-build the jitted batched solver for one bucket shape family.

    Signature of the returned callable:
        fn(blocks[n,size,size], lams[n])            when warm=False
        fn(blocks[n,size,size], lams[n], W0[n,...]) when warm=True (W0 donated
                                                    off-CPU)
    """
    key = (solver, int(size), jnp.dtype(dtype).name, bool(warm), opts_key)
    with _CACHE_LOCK:
        fn = _COMPILED.get(key)
        if fn is not None:
            bump("executor.compiled_hit")
            return fn
        bump("executor.compiled_miss")
        solver_fn = SOLVERS[solver]
        opts = dict(opts_key)
        if warm:

            def run(blocks, lams, W0):
                return jax.vmap(
                    lambda Sb, l, w0: solver_fn(Sb, l, W0=w0, **opts)
                )(blocks, lams, W0)

            fn = jax.jit(run, donate_argnums=(2,) if _donate_supported() else ())
        else:

            def run(blocks, lams):
                return jax.vmap(lambda Sb, l: solver_fn(Sb, l, **opts))(
                    blocks, lams
                )

            fn = jax.jit(run)
        _COMPILED[key] = fn
        return fn


def compiled_cache_stats() -> dict[str, int]:
    return {
        "entries": len(_COMPILED),
        "hits": counts().get("executor.compiled_hit", 0),
        "misses": counts().get("executor.compiled_miss", 0),
    }


@dataclass
class _Pending:
    bucket: blocks_mod.Bucket
    out: jax.Array


@dataclass
class BucketExecutor:
    """Solves plans; owns the per-path warm-start state.

    One instance per logical stream of related solves (a ``glasso`` call, a
    ``glasso_path``, one serving batch); the compiled cache underneath is
    global."""

    solver: str = "bcd"
    dtype: Any = jnp.float64
    solver_opts: dict = field(default_factory=dict)
    devices: list | None = None
    # bucket_key -> previous padded solution / input stacks (device arrays):
    # reused buckets warm-start from their own previous solution and skip the
    # host->device re-upload of their bit-identical padded blocks.
    _prev_solutions: dict = field(default_factory=dict)
    _prev_blocks: dict = field(default_factory=dict)

    def __post_init__(self):
        if self.solver not in SOLVERS:
            raise ValueError(
                f"unknown solver {self.solver!r}; available: {sorted(SOLVERS)}"
            )
        _validate_solver_opts(self.solver, self.solver_opts)
        if self.devices is None:
            self.devices = list(jax.local_devices())
        self._opts_key = tuple(sorted(self.solver_opts.items()))

    # -- placement ---------------------------------------------------------

    def _place(self, buckets: list[blocks_mod.Bucket]) -> list:
        """LPT assignment of buckets to local devices (b^3 * n_blocks cost)."""
        if len(self.devices) <= 1 or not buckets:
            return [None] * len(buckets)
        cost = [b.blocks.shape[0] * float(b.size) ** 3 for b in buckets]
        assign = lpt_assign(cost, len(self.devices), cost=float)
        return [self.devices[w] for w in assign.worker_of]

    # -- warm starts -------------------------------------------------------

    def _warm_stack(
        self, bucket: blocks_mod.Bucket, key, lam: float, warm_W: np.ndarray | None
    ):
        """W0 stack for one bucket, or None.

        Reused bucket with a cached previous solution: W0 = inv(prev Theta)
        batched on device (the padded block of Theta is blkdiag, so its
        inverse's padded diagonal is finite; it is then reset to 1+lam).
        Otherwise fall back to gathering from the dense warm_W (merged
        components: block-diagonal of the old sub-components, valid PD warm
        start by Theorem 2)."""
        prev = self._prev_solutions.get(key)
        if prev is not None:
            W0 = jnp.linalg.inv(prev)
        elif warm_W is not None:
            stacks = []
            for c in bucket.comps:
                blk = warm_W[np.ix_(c, c)].astype(np.dtype(jnp.dtype(self.dtype).name))
                stacks.append(blocks_mod.pad_block(blk, bucket.size))
            W0 = jnp.asarray(np.stack(stacks), self.dtype)
        else:
            return None
        # padded diagonal of a W iterate must be 1 + lam (diagonal KKT)
        n = W0.shape[0]
        idx = jnp.arange(bucket.size)
        pad_mask = jnp.stack(
            [idx >= len(c) for c in bucket.comps]
        )  # (n, size) True on padded coords
        eye = jnp.eye(bucket.size, dtype=bool)
        fix = pad_mask[:, :, None] & eye[None, :, :]
        W0 = jnp.where(fix, jnp.asarray(1.0 + lam, W0.dtype), W0)
        off = pad_mask[:, :, None] ^ pad_mask[:, None, :]
        return jnp.where(off, jnp.zeros((), W0.dtype), W0)

    # -- solve -------------------------------------------------------------

    def solve_plan(
        self,
        plan: blocks_mod.Plan,
        lam: float,
        S: np.ndarray,
        *,
        warm_W: np.ndarray | None = None,
        reused_keys: frozenset = frozenset(),
        keep_solutions: bool = False,
    ) -> np.ndarray:
        """Dispatch all buckets, then assemble the dense Theta.

        ``reused_keys`` marks buckets whose padded arrays were carried over by
        the planner; their previous solutions (if retained via
        ``keep_solutions``) seed the warm start without touching the host."""
        from repro.engine.planner import bucket_key  # local: avoid cycle at import

        placements = self._place(plan.buckets)
        pending: list[_Pending] = []
        new_solutions: dict = {}
        new_blocks: dict = {}
        for bucket, device in zip(plan.buckets, placements):
            key = bucket_key(bucket)
            n = bucket.blocks.shape[0]
            stacked = self._prev_blocks.get(key) if key in reused_keys else None
            if stacked is None:
                stacked = jnp.asarray(bucket.blocks, self.dtype)
                if device is not None:
                    stacked = jax.device_put(stacked, device)
            elif device is not None and list(stacked.devices()) != [device]:
                # LPT may move a reused bucket between lambdas; a D2D copy
                # still beats re-uploading from host
                stacked = jax.device_put(stacked, device)
            lams = jnp.full((n,), lam, self.dtype)
            if self.solver in WARM_START_SOLVERS:
                use_key = key if key in reused_keys else None
                W0 = self._warm_stack(bucket, use_key, lam, warm_W)
            else:
                W0 = None  # solver discards W0: skip the batched inversions
            if device is not None:
                lams = jax.device_put(lams, device)
                if W0 is not None:
                    W0 = jax.device_put(W0, device)
            fn = compiled_bucket_solver(
                self.solver,
                bucket.size,
                self.dtype,
                warm=W0 is not None,
                opts_key=self._opts_key,
            )
            out = fn(stacked, lams, W0) if W0 is not None else fn(stacked, lams)
            bump("executor.dispatches")
            pending.append(_Pending(bucket=bucket, out=out))
            if keep_solutions:
                new_solutions[key] = out
                new_blocks[key] = stacked

        # single synchronization point: everything above was async dispatch
        jax.block_until_ready([p.out for p in pending])
        self._prev_solutions = new_solutions
        self._prev_blocks = new_blocks
        return blocks_mod.assemble_dense(plan, [np.asarray(p.out) for p in pending], S)
